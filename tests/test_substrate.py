"""Substrate tests: optimizers, schedules, data pipeline, checkpointing,
partition rules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs.base import HierAvgParams
from repro.core import HierTopology
from repro.data.loader import HierDataLoader
from repro.data.synthetic import (make_classification_task, make_markov_task,
                                  markov_lm_batch)
from repro.optim import (adamw, clip_by_global_norm, constant_lr, cosine_lr,
                         global_norm, sgd, step_decay_lr)
from repro.parallel.sharding import PartitionRules, safe_pspec
from jax.sharding import PartitionSpec as P


# ------------------------------ optim -------------------------------- #

def test_sgd_plain_matches_manual():
    opt = sgd(0.1)
    params = {"w": jnp.array([1.0, 2.0])}
    grads = {"w": jnp.array([0.5, -1.0])}
    st = opt.init(params)
    new, _ = opt.update(grads, params, st, jnp.zeros((), jnp.int32))
    np.testing.assert_allclose(np.asarray(new["w"]), [0.95, 2.1], rtol=1e-6)


def test_sgd_momentum():
    opt = sgd(0.1, momentum=0.9)
    params = {"w": jnp.zeros(2)}
    grads = {"w": jnp.ones(2)}
    st = opt.init(params)
    p1, st = opt.update(grads, params, st, jnp.zeros((), jnp.int32))
    p2, st = opt.update(grads, p1, st, jnp.ones((), jnp.int32))
    # v1 = 1, p1 = -0.1 ; v2 = 1.9, p2 = -0.1 - 0.19
    np.testing.assert_allclose(np.asarray(p2["w"]), [-0.29, -0.29],
                               rtol=1e-6)


def test_adamw_converges_quadratic():
    opt = adamw(0.1)
    params = {"w": jnp.array([5.0])}
    st = opt.init(params)
    step = jnp.zeros((), jnp.int32)
    for i in range(200):
        g = {"w": 2 * params["w"]}
        params, st = opt.update(g, params, st, step + i)
    assert abs(float(params["w"][0])) < 0.1


def test_schedules():
    f = step_decay_lr(0.1, [150], [0.1])   # the paper's recipe
    assert float(f(0)) == pytest.approx(0.1)
    assert float(f(151)) == pytest.approx(0.01)
    c = cosine_lr(1.0, 100)
    assert float(c(0)) == pytest.approx(1.0)
    assert float(c(100)) == pytest.approx(0.1, abs=1e-6)


def test_clip_by_global_norm():
    tree = {"a": jnp.ones(4) * 3.0}
    clipped, n = clip_by_global_norm(tree, 1.0)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    assert float(n) == pytest.approx(6.0)


# ------------------------------ data --------------------------------- #

def test_markov_task_entropy_floor():
    logits, floor = make_markov_task(16, temperature=1.0)
    assert 0.0 < floor < np.log(16)
    b = markov_lm_batch(jax.random.PRNGKey(0), 8, 32, logits)
    assert b["tokens"].shape == (8, 32)
    assert b["labels"].shape == (8, 32)
    assert int(b["tokens"].max()) < 16


def test_loader_shapes_and_independence():
    topo = HierTopology(1, 2, 2)
    hier = HierAvgParams(k1=2, k2=4)
    sample = make_classification_task(8, 3)
    ld = HierDataLoader(sample, topo=topo, hier=hier, per_learner_batch=4,
                        seed=0)
    rb = ld.next_round()
    assert rb["x"].shape == (2, 2, 1, 2, 2, 4, 8)
    # learners see different data within the same step
    step0 = rb["x"][0, 0, 0]
    assert not np.allclose(np.asarray(step0[0, 0]), np.asarray(step0[0, 1]))
    # deterministic across loaders with the same seed
    ld2 = HierDataLoader(sample, topo=topo, hier=hier, per_learner_batch=4,
                         seed=0)
    np.testing.assert_allclose(np.asarray(rb["x"]),
                               np.asarray(ld2.next_round()["x"]))


def test_round_batch_shardings_any_plan_depth():
    """Schedule-aware shard assignment (data/loader.py) is generic in
    the plan depth: the leading step-axis prefix tracks len(batch_dims)
    for 1-, 2-, and 3-level plans — and for deeper hypothetical
    schedules — instead of a baked <=3-entry prefix."""
    from jax.sharding import PartitionSpec as P
    from repro.data.loader import (round_batch_pspec,
                                   round_batch_shardings)
    mesh = jax.make_mesh((1, 1, 1, 1), ("pod", "group", "local", "fsdp"))
    plans = {"local@4": 1, "local@2/global@4": 2,
             "local@2/pod@4/global@8": 3}
    for spec, depth in plans.items():
        hier = HierAvgParams(plan=spec)
        assert len(hier.batch_dims) == depth
        leaf_ndim = depth + 3 + 1 + 1        # steps + learners + B + feat
        ps = round_batch_pspec(hier.batch_dims, leaf_ndim, mesh)
        assert tuple(ps) == ((None,) * depth
                             + ("pod", "group", "local", "fsdp", None))
    # deeper than any named plan today: the prefix still tracks the dims
    deep_dims = (2, 2, 2, 2, 2)
    ps = round_batch_pspec(deep_dims, len(deep_dims) + 4, mesh)
    assert tuple(ps) == ((None,) * 5 + ("pod", "group", "local", "fsdp"))
    # meshes without an fsdp axis just drop the example-dim shard
    mesh3 = jax.make_mesh((1, 1, 1), ("pod", "group", "local"))
    ps3 = round_batch_pspec((2, 2), 7, mesh3)
    assert tuple(ps3) == (None, None, "pod", "group", "local", None, None)
    # non-divisible dims are dropped by the safety net, not crashed on
    ps_safe = round_batch_pspec((2,), 5, mesh3, leaf_shape=(2, 1, 1, 1, 7))
    assert isinstance(ps_safe, P)
    # a leaf too short for the step+learner prefix is refused loudly,
    # never silently mis-sharded with truncated learner axes
    with pytest.raises(ValueError):
        round_batch_pspec((2, 2), 4, mesh3)
    # end-to-end: a loader given only the mesh derives the shardings and
    # places a 3-level round batch
    topo = HierTopology(1, 1, 1)
    hier = HierAvgParams(plan="local@1/pod@2/global@4")
    ld = HierDataLoader(make_classification_task(8, 3), topo=topo,
                        hier=hier, per_learner_batch=4, seed=0, mesh=mesh)
    rb = ld.next_round()
    assert rb["x"].shape == (2, 2, 1, 1, 1, 1, 4, 8)
    assert ld.shardings is not None
    assert tuple(ld.shardings["x"].spec)[:3] == (None, None, None)
    shards = round_batch_shardings(mesh, hier, rb)
    assert shards["x"].mesh.shape == mesh.shape


# --------------------------- checkpoint ------------------------------ #

def test_checkpoint_roundtrip(tmp_path):
    tree = {"layers": {"w": jnp.arange(6.0).reshape(2, 3),
                       "b": jnp.ones(3)},
            "head": jnp.full((4,), 2.5)}
    save_checkpoint(str(tmp_path / "ck"), tree, step=7,
                    metadata={"arch": "test"})
    like = jax.tree.map(jnp.zeros_like, tree)
    restored = restore_checkpoint(str(tmp_path / "ck"), like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path / "ck"), {"w": jnp.ones(3)})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path / "ck"), {"w": jnp.ones(4)})


# ------------------------- partition rules --------------------------- #

def test_partition_rules_paths():
    r = PartitionRules()
    assert r.inner_spec("layers/attn/wq", 2) == ("fsdp", "model")
    assert r.inner_spec("layers/attn/wo", 2) == ("model", "fsdp")
    assert r.inner_spec("layers/ffn/experts/w_gate", 3) == \
        ("model", "fsdp", None)
    assert r.inner_spec("layers/cm/wv", 2) == ("model", "fsdp")
    assert r.inner_spec("layers/tm/wk", 2) == ("fsdp", "model")
    assert r.inner_spec("embed", 2) == ("model", None)


def test_spec_leading_axes_stacked():
    r = PartitionRules()
    # stacked learners + layer-stack dim + 2-D weight
    s = r.spec_for("layers/attn/wq", (1, 2, 2, 24, 64, 64),
                   stacked_learners=True)
    assert tuple(s) == ("pod", "group", "local", None, "fsdp", "model")
    s = r.spec_for("layers/attn/wq", (24, 64, 64), stacked_learners=False)
    assert tuple(s) == (None, "fsdp", "model")


def _abstract_mesh(sizes, names):
    """AbstractMesh across jax versions: ((name, size), ...) pairs vs the
    newer (sizes, names) signature."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(zip(names, sizes)))
    except TypeError:
        return AbstractMesh(tuple(sizes), tuple(names))


def test_safe_pspec_drops_nondivisible():
    mesh = _abstract_mesh((1, 1), ("data", "model"))
    # size-1 axes divide everything
    s = safe_pspec(P("data", "model"), (25, 7), mesh)
    assert tuple(s) == ("data", "model")
    mesh4 = _abstract_mesh((2, 2), ("data", "model"))
    s = safe_pspec(P("data", "model"), (25, 8), mesh4)
    assert tuple(s) == (None, "model")
    # tuple axes multiply
    s = safe_pspec(P(("data", "model")), (8,), mesh4)
    assert tuple(s) == (("data", "model"),)
    s = safe_pspec(P(("data", "model")), (6,), mesh4)
    assert tuple(s) == (None,)
