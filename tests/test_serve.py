"""Serving engine behaviour."""
import jax
import pytest
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build
from repro.serve import GenerationConfig, ServeEngine, describe_cache

pytestmark = pytest.mark.slow


def _engine(arch="rwkv6-1.6b", max_new=6, temperature=0.0):
    cfg = get_config(arch).reduced()
    bundle = build(cfg, cache_dtype=jnp.float32)
    params = bundle.init(jax.random.PRNGKey(0))
    eng = ServeEngine(bundle, params, max_len=64,
                      gen=GenerationConfig(max_new_tokens=max_new,
                                           temperature=temperature))
    return cfg, bundle, params, eng


def test_greedy_generation_matches_manual_decode():
    cfg, bundle, params, eng = _engine()
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    out = eng.generate(prompts)
    # manual greedy loop
    logits, cache = bundle.prefill(params, {"tokens": prompts,
                                            "max_len": 64})
    toks = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    toks.append(np.asarray(tok))
    for _ in range(5):
        logits, cache = bundle.decode_step(params, tok, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        toks.append(np.asarray(tok))
    manual = np.stack(toks, 1)
    np.testing.assert_array_equal(out, manual)


def test_generation_deterministic_greedy():
    cfg, bundle, params, eng = _engine()
    prompts = jnp.ones((2, 8), jnp.int32)
    a = eng.generate(prompts)
    b = eng.generate(prompts)
    np.testing.assert_array_equal(a, b)


def test_serve_queue_slots():
    cfg, bundle, params, eng = _engine(max_new=4)
    rng = np.random.default_rng(0)
    reqs = [rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
            for _ in range(5)]
    results = eng.serve_queue(reqs, slots=2)
    assert len(results) == 5
    assert sorted(r.request_id for r in results) == [0, 1, 2, 3, 4]
    for r in results:
        assert r.tokens.shape[0] == 4


def test_serve_queue_pow2_bucketing_bounds_compiles():
    """Mixed prompt lengths pad to power-of-two buckets, so the number of
    compiled prefill programs is log-bounded — checked with the trace-time
    compile counter, not timing."""
    cfg, bundle, params, eng = _engine(max_new=3)
    rng = np.random.default_rng(0)
    mk = lambda n: rng.integers(0, cfg.vocab_size, size=n)  # noqa: E731
    # lengths 5 and 7 share the 8-bucket; 13 lands in the 16-bucket
    eng.serve_queue([mk(5)], slots=1)
    assert eng.prefill_traces == 1
    eng.serve_queue([mk(7)], slots=1)
    assert eng.prefill_traces == 1          # same bucket: no retrace
    eng.serve_queue([mk(13)], slots=1)
    assert eng.prefill_traces == 2          # new bucket: one more
    assert eng.decode_traces == 1           # decode never re-specializes


def test_serve_queue_eos_trims_result():
    cfg, bundle, params, eng = _engine(max_new=6)
    rng = np.random.default_rng(1)
    req = rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
    probe = eng.serve_queue([req], slots=1)
    eos = int(probe[0].tokens[2])           # greedy => reproducible
    cfg2, bundle2, params2, _ = cfg, bundle, params, None
    eng2 = ServeEngine(bundle2, params2, max_len=64,
                       gen=GenerationConfig(max_new_tokens=6,
                                            temperature=0.0, eos_id=eos))
    r = eng2.serve_queue([req], slots=1)[0]
    assert r.tokens[-1] == eos
    assert len(r.tokens) <= 3               # trimmed at first EOS
    assert r.steps == len(r.tokens)
    np.testing.assert_array_equal(r.tokens,
                                  probe[0].tokens[:len(r.tokens)])


def test_serve_queue_reports_wasted_decode_steps():
    """The dense wave engine burns the full scan even when a request's
    budget (or EOS) ends it early — RequestResult.decode_steps exposes
    exactly that cost."""
    cfg, bundle, params, eng = _engine(max_new=8)
    rng = np.random.default_rng(2)
    reqs = [rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
            for _ in range(2)]
    res = eng.serve_queue(reqs, slots=2, max_new=[2, 8])
    assert res[0].steps == len(res[0].tokens) == 2
    assert res[1].steps == 8
    # both requests rode the same 7-step wave scan
    assert res[0].decode_steps == res[1].decode_steps == 7
    wasted = (res[0].decode_steps - (res[0].steps - 1)) \
        / res[0].decode_steps
    assert wasted == pytest.approx(6 / 7)


def test_cache_accounting():
    for arch, kind in [("rwkv6-1.6b", "ssm-state"),
                       ("hymba-1.5b", "hybrid(window+state)"),
                       ("deepseek-v2-lite-16b", "mla-latent"),
                       ("yi-34b", "full-kv")]:
        cfg = get_config(arch)
        d = describe_cache(cfg, batch=4, max_len=1024)
        assert d["kind"] == kind
        assert d["bytes"] > 0
    # rolling window cache is max_len-independent
    cfg = get_config("yi-34b")
    a = describe_cache(cfg, 1, 32768, rolling=True)
    b = describe_cache(cfg, 1, 524288, rolling=True)
    assert a["bytes"] == b["bytes"]
