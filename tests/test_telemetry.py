"""Telemetry plane: metrics rows, span traces, device-side gradstats.

Fast tier covers the host pieces (MetricsLogger schema contract, JSONL
round-trip, Chrome-trace nesting, gradstats vs numpy oracles, the
CostAwarePlan.observe signal path) and the in-process bit-identity of
the telemetry-on round on the serial and pipelined engines.  The slow
tier adds the fsdp=2 subprocess bit-identity leg and the serving-engine
telemetry rows on a real (reduced) arch.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.autotune import CostAwarePlan
from repro.configs.base import HierAvgParams
from repro.core import HierTopology, Simulator
from repro.telemetry import (ROW_SCHEMAS, SCHEMA_VERSION, MetricsLogger,
                             SpanTracer, TelemetryConfig, codec_error,
                             ef_mass, group_divergence, resolve_telemetry,
                             validate_jsonl)

_REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

TOPO = HierTopology(2, 2, 2)
PLAN = "local@2/pod@4/global@8:topk:0.25"


# ------------------------------------------------------------------- #
# MetricsLogger: channels, rows, schema contract, JSONL round-trip

def test_typed_channels_snapshot():
    m = MetricsLogger()
    m.count("rounds")
    m.count("rounds", 2)
    m.gauge("pages_in_use", 7)
    for v in (1.0, 2.0, 3.0, 10.0):
        m.histogram("wall", v)
    snap = m.snapshot()
    assert snap["counters"]["rounds"] == 3
    assert snap["gauges"]["pages_in_use"] == 7.0
    h = snap["histograms"]["wall"]
    assert h["count"] == 4 and h["min"] == 1.0 and h["max"] == 10.0


def test_row_schema_golden_keys():
    """The frozen per-subsystem REQUIRED key sets — the compatibility
    contract downstream readers (CI JSONL smoke, CostAwarePlan.observe)
    rely on.  Changing these sets must bump SCHEMA_VERSION; this test is
    the tripwire."""
    assert SCHEMA_VERSION == 1
    assert ROW_SCHEMAS["train_round"] == frozenset({
        "schema_version", "subsystem", "round", "loss", "wall_s"})
    assert ROW_SCHEMAS["serve_step"] == frozenset({
        "schema_version", "subsystem", "step", "active_slots",
        "occupancy", "new_tokens", "pages_in_use"})
    assert ROW_SCHEMAS["serve_summary"] == frozenset({
        "schema_version", "subsystem", "engine", "requests", "tokens",
        "decode_steps", "wall_s", "tokens_per_s", "wasted_ratio",
        "refill_events", "peak_pages_in_use"})


def test_log_row_stamps_and_validates():
    m = MetricsLogger()
    row = m.log_row("train_round", round=0, loss=1.5, wall_s=0.01)
    assert row["schema_version"] == SCHEMA_VERSION
    assert row["subsystem"] == "train_round"
    with pytest.raises(ValueError, match="unknown telemetry subsystem"):
        m.log_row("nope", x=1)
    with pytest.raises(ValueError, match="missing required keys"):
        m.log_row("train_round", round=0)        # no loss / wall_s


def test_ring_buffer_and_subsystem_filter():
    m = MetricsLogger(ring=4)
    for r in range(6):
        m.log_row("train_round", round=r, loss=0.0, wall_s=0.0)
    m.log_row("serve_summary", engine="dense", requests=1, tokens=2,
              decode_steps=1, wall_s=0.1, tokens_per_s=20.0,
              wasted_ratio=0.0, refill_events=0, peak_pages_in_use=0)
    rounds = [r["round"] for r in m.rows("train_round")]
    assert rounds == [3, 4, 5]                   # oldest evicted
    assert len(list(m.rows("serve_summary"))) == 1


def test_jsonl_roundtrip_and_validation(tmp_path):
    path = str(tmp_path / "m.jsonl")
    with MetricsLogger(path, flush_every=2) as m:
        m.log_row("train_round", round=0, loss=float("nan"), wall_s=0.01,
                  extra=np.float32(3.0))
        m.log_row("train_round", round=1, loss=0.5, wall_s=0.01)
    rows = validate_jsonl(path)
    assert [r["round"] for r in rows] == [0, 1]
    assert rows[0]["loss"] is None               # nan -> null, strict JSON
    assert rows[0]["extra"] == 3.0               # numpy unwrapped

    bad = str(tmp_path / "bad.jsonl")
    with open(bad, "w") as f:
        f.write(json.dumps({"subsystem": "train_round",
                            "schema_version": SCHEMA_VERSION,
                            "round": 0}) + "\n")
    with pytest.raises(ValueError, match="missing"):
        validate_jsonl(bad)


# ------------------------------------------------------------------- #
# SpanTracer: Chrome-trace export round-trip, nesting

def test_chrome_trace_roundtrips_and_nests(tmp_path):
    tracer = SpanTracer()
    f = jax.jit(lambda x: (x * x).sum())
    x = jnp.ones((8, 8))
    for r in range(2):
        with tracer.span(f"round[{r}]") as rnd:
            with tracer.span("device", cat="device"):
                tracer.fence(f(x))
            with tracer.span("host_sync"):
                jax.device_get(f(x))
        tracer.add_modeled_children(rnd, [("compress", 1e-6),
                                          ("collective", 2e-6)])
    path = str(tmp_path / "trace.json")
    tracer.export_chrome_trace(path)
    with open(path) as fh:
        doc = json.load(fh)                      # must parse as strict JSON
    events = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    assert len(events) == 10                     # 2 x (round + 2 + 2 modeled)
    assert any(e.get("ph") == "M" for e in doc["traceEvents"])
    rounds = [e for e in events if e["name"].startswith("round")]
    children = [e for e in events if not e["name"].startswith("round")]
    assert len(rounds) == 2
    # timestamps monotonically ordered parent-to-parent, and every child
    # nested inside some parent's [ts, ts+dur] window
    assert rounds[0]["ts"] <= rounds[1]["ts"]
    for c in children:
        assert any(p["ts"] <= c["ts"]
                   and c["ts"] + c["dur"] <= p["ts"] + p["dur"] + 1
                   for p in rounds), c
    cats = {e["cat"] for e in events}
    assert {"host", "device", "modeled"} <= cats


# ------------------------------------------------------------------- #
# gradstats vs numpy oracles

class _Lvl:
    def __init__(self, axes):
        self.axes = axes


def test_group_divergence_matches_numpy():
    rng = np.random.default_rng(0)
    leaf = rng.normal(size=(2, 2, 2, 3, 5)).astype(np.float32)
    params = {"w": jnp.asarray(leaf)}
    for axes in ((2,), (1, 2), (0, 1, 2)):
        got = float(group_divergence(params, axes))
        m = leaf.mean(axis=axes, keepdims=True)
        want = float(np.square(leaf - m).sum(axis=(3, 4)).mean())
        assert got == pytest.approx(want, rel=1e-5)


def test_codec_error_zero_for_exact_mean_positive_for_lossy():
    rng = np.random.default_rng(1)
    pre = rng.normal(size=(1, 1, 4, 6)).astype(np.float32)
    exact = np.broadcast_to(pre.mean(axis=2, keepdims=True), pre.shape)
    zero = float(codec_error({"w": jnp.asarray(exact)},
                             {"w": jnp.asarray(pre)}, (2,)))
    assert zero == pytest.approx(0.0, abs=1e-10)
    lossy = exact + 0.1
    err = float(codec_error({"w": jnp.asarray(lossy)},
                            {"w": jnp.asarray(pre)}, (2,)))
    want = float(np.square(lossy - exact).sum()
                 / (np.square(exact).sum() + 1e-30))
    assert err == pytest.approx(want, rel=1e-5)


def test_ef_mass_reads_err_and_skips_ints():
    class EF:
        err = {"a": jnp.asarray(np.full((2, 3), 2.0, np.float32)),
               "idx": jnp.asarray(np.ones((4,), np.int32))}

    assert float(ef_mass(EF())) == pytest.approx(24.0)   # ints skipped
    # no .err attr: every float leaf counts
    assert float(ef_mass({"x": jnp.asarray(np.ones((5,), np.float32))})
                 ) == pytest.approx(5.0)


def test_resolve_telemetry_knob():
    assert resolve_telemetry(None) is None
    assert resolve_telemetry(False) is None
    assert resolve_telemetry(True) == TelemetryConfig()
    cfg = TelemetryConfig(grad_var=False)
    assert resolve_telemetry(cfg) is cfg
    with pytest.raises(TypeError):
        resolve_telemetry("yes")


# ------------------------------------------------------------------- #
# bit-identity + row logging through the Simulator

def _sim(cls_task, *, telemetry=None, metrics=None, overlap=True):
    hier = HierAvgParams(plan=PLAN, bucket_bytes=1024, overlap=overlap)
    return Simulator(cls_task["loss_fn"], cls_task["init_fn"],
                     cls_task["sample"], topo=TOPO, hier=hier, seed=5,
                     per_learner_batch=8,
                     eval_batch=cls_task["eval_batch"],
                     telemetry=telemetry, metrics=metrics)


@pytest.mark.parametrize("overlap", [False, True],
                         ids=["serial", "pipelined"])
def test_telemetry_on_is_bit_identical(cls_task, overlap):
    """The device-side stats are pure observers: enabling them must not
    move one bit of the trajectory on either bucket schedule."""
    off = _sim(cls_task, overlap=overlap).run(2)
    on = _sim(cls_task, telemetry=True, overlap=overlap).run(2)
    np.testing.assert_array_equal(off.losses, on.losses)
    np.testing.assert_array_equal(off.eval_losses, on.eval_losses)
    assert on.stats and all(k.startswith("telemetry/") for k in on.stats)
    # lossy topk level shows real compression error; mean levels don't
    assert float(np.max(on.stats["telemetry/codec_err/global"])) > 0.0
    assert float(np.max(on.stats["telemetry/codec_err/local"])) == \
        pytest.approx(0.0, abs=1e-9)


def test_simulator_logs_schema_valid_rows(cls_task, tmp_path):
    path = str(tmp_path / "rows.jsonl")
    logger = MetricsLogger(path, flush_every=1)
    res = _sim(cls_task, telemetry=True, metrics=logger).run(3)
    logger.close()
    rows = validate_jsonl(path)
    train = [r for r in rows if r["subsystem"] == "train_round"]
    assert [r["round"] for r in train] == [0, 1, 2]
    assert all(r["wall_s"] > 0 for r in train)
    assert any(k.startswith("telemetry/") for k in train[0])
    assert res.measured_wall_s is not None and len(res.measured_wall_s) == 3
    snap = logger.snapshot()
    assert snap["counters"]["train/rounds"] == 3
    assert snap["histograms"]["train/round_wall_s"]["count"] == 3


def test_costaware_observe_ingests_rows():
    ctl = CostAwarePlan(plan=PLAN, topo=TOPO)
    assert ctl.observed_wall_s is None and ctl.wall_bias() is None
    for w in (9.0, 0.002, 0.003, 0.004):     # compile-round outlier first
        ctl.observe({"wall_s": w,
                     "active_frac": {"global": 0.5, "pod": 1.0}})
    assert ctl.observed_wall_s == pytest.approx(0.004)   # median rides it out
    assert ctl.observed_active_frac["global"] == pytest.approx(0.5)
    assert ctl.observed_active_frac["pod"] == pytest.approx(1.0)
    assert ctl.modeled_round_wall_s > 0.0
    assert ctl.wall_bias() == pytest.approx(
        0.004 / ctl.modeled_round_wall_s)


# ------------------------------------------------------------------- #
# fsdp=2 subprocess bit-identity (slow)

_FSDP_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=16")
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from repro.configs.base import HierAvgParams
from repro.configs.resnet18_cifar import MLPConfig
from repro.core import (HierTopology, init_state, make_hier_round,
                        unstack_first)
from repro.data.synthetic import make_classification_task
from repro.models.resnet import mlp_cls_init, mlp_cls_loss
from repro.optim import sgd
from repro.parallel.sharding import shard_plan

cfg = MLPConfig(in_dim=16, hidden=(32,), n_classes=4)
sample = make_classification_task(16, 4, seed=11, noise=0.5)
loss_fn = lambda p, b: mlp_cls_loss(p, b)
eval_batch = sample(jax.random.PRNGKey(123), 256)
topo = HierTopology(2, 2, 2)
B = 16
h = HierAvgParams(k1=2, k2=8,
                  plan="local@2:mean:bucketed/pod@4:mean:bucketed/"
                       "global@8:mean:bucketed")
opt = sgd(0.05)
mesh = Mesh(np.array(jax.devices()[:16]).reshape(2, 2, 2, 2, 1),
            ("pod", "group", "local", "fsdp", "model"))
shards = shard_plan(mesh)


def run(telemetry):
    rnd = jax.jit(make_hier_round(loss_fn, opt, h, shards=shards,
                                  telemetry=telemetry))
    state = init_state(topo, lambda k: mlp_cls_init(k, cfg), opt,
                       jax.random.PRNGKey(0), plan=h.resolved_plan,
                       shards=shards)
    dims = tuple(h.resolved_plan.batch_dims)
    losses, dk = [], jax.random.PRNGKey(42)
    for r in range(2):
        dk, sk = jax.random.split(dk)
        batch = sample(sk, h.k2 * topo.n_learners * B)
        shaped = jax.tree.map(
            lambda x: x.reshape(dims + topo.shape + (B,) + x.shape[1:]),
            batch)
        state, _ = rnd(state, shaped)
        l, _ = loss_fn(unstack_first(state.params), eval_batch)
        losses.append(float(l))
    return losses


print(json.dumps({"off": run(None), "on": run(True)}))
"""


@pytest.mark.slow
def test_telemetry_bit_identical_at_fsdp2():
    """The observers must also be invisible on the reduce-scatter/
    all-gather sharded engine (fresh 16-host-device subprocess)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(_REPO, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    r = subprocess.run([sys.executable, "-c", _FSDP_CHILD], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["off"] == out["on"]


# ------------------------------------------------------------------- #
# serving engine telemetry (slow: builds a reduced real arch)

@pytest.mark.slow
def test_paged_engine_emits_steps_and_summary():
    from repro.configs import get_config
    from repro.models import build
    from repro.serve import GenerationConfig, PagedServeEngine

    cfg = get_config("yi-34b").reduced()
    bundle = build(cfg, cache_dtype=jnp.float32)
    params = bundle.init(jax.random.PRNGKey(0))
    m = MetricsLogger()
    eng = PagedServeEngine(bundle, params, slots=2, page_size=8,
                           max_len=24,
                           gen=GenerationConfig(max_new_tokens=4),
                           metrics=m)
    rng = np.random.default_rng(0)
    reqs = [rng.integers(0, cfg.vocab_size, size=12).astype(np.int32)
            for _ in range(4)]
    results = eng.serve_queue(reqs)
    assert len(results) == 4
    steps = list(m.rows("serve_step"))
    assert steps and all(0 < s["active_slots"] <= 2 for s in steps)
    assert all(s["pages_in_use"] >= 0 for s in steps)
    assert [s["step"] for s in steps] == list(range(len(steps)))
    summary = eng.steady_state_summary()
    logged = list(m.rows("serve_summary"))[-1]
    assert all(logged[k] == v for k, v in summary.items())
    assert summary["engine"] == "paged"
    assert summary["requests"] == 4
    assert summary["peak_pages_in_use"] > 0
    assert summary["refill_events"] >= 2      # 4 reqs through 2 slots
    assert 0.0 < summary["mean_occupancy"] <= 1.0
    assert summary["wasted_ratio"] == 0.0     # token-level refill


@pytest.mark.slow
def test_dense_engine_summary_exposes_wasted_steps():
    from repro.configs import get_config
    from repro.models import build
    from repro.serve import GenerationConfig, ServeEngine

    cfg = get_config("rwkv6-1.6b").reduced()
    bundle = build(cfg, cache_dtype=jnp.float32)
    params = bundle.init(jax.random.PRNGKey(0))
    m = MetricsLogger()
    eng = ServeEngine(bundle, params, max_len=64,
                      gen=GenerationConfig(max_new_tokens=6),
                      metrics=m)
    rng = np.random.default_rng(0)
    reqs = [rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
            for _ in range(3)]
    # per-request budgets below the wave length => provably wasted steps
    eng.serve_queue(reqs, slots=2, max_new=[2, 2, 2])
    s = eng.steady_state_summary()
    assert s["engine"] == "dense" and s["requests"] == 3
    assert s["decode_steps"] == 3 * 5          # full wave scan, always
    assert s["wasted_ratio"] > 0.0
    assert s["refill_events"] == 0 and s["peak_pages_in_use"] == 0
    assert list(m.rows("serve_summary"))       # row logged
