import os
import sys

# src/ layout without install
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

import jax.numpy as jnp  # noqa: E402
import pytest  # noqa: E402

from repro.configs.resnet18_cifar import MLPConfig  # noqa: E402
from repro.data.synthetic import make_classification_task  # noqa: E402
from repro.models.resnet import mlp_cls_init, mlp_cls_loss  # noqa: E402


@pytest.fixture(scope="session")
def cls_task():
    """A small learnable classification task + model (shared by core tests)."""
    cfg = MLPConfig(in_dim=16, hidden=(32,), n_classes=4)
    sample = make_classification_task(16, 4, seed=11, noise=0.5)
    loss_fn = lambda p, b: mlp_cls_loss(p, b)  # noqa: E731
    init_fn = lambda k: mlp_cls_init(k, cfg)   # noqa: E731
    eval_batch = sample(jax.random.PRNGKey(123), 256)
    return {"loss_fn": loss_fn, "init_fn": init_fn, "sample": sample,
            "eval_batch": eval_batch, "cfg": cfg}
