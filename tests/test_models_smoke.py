"""Per-architecture smoke tests: REDUCED variant of each assigned arch runs
one forward/train step on CPU; output shapes and finiteness asserted.
Also checks decode-vs-full-forward consistency per family.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config

pytestmark = pytest.mark.slow
from repro.models import build
from repro.models.common import count_params, text_positions
from repro.models.stubs import make_train_batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    if cfg.uses_moe:
        assert cfg.n_experts <= 4
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    assert count_params(params) > 0
    batch = make_train_batch(jax.random.PRNGKey(1), cfg, batch=2, seq_len=32)
    loss, metrics = jax.jit(bundle.loss_fn)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    grads = jax.grad(lambda p: bundle.loss_fn(p, batch)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat), arch
    # one SGD step moves the loss
    params2 = jax.tree.map(lambda p, g: p - 0.1 * g, params, grads)
    loss2, _ = jax.jit(bundle.loss_fn)(params2, batch)
    assert bool(jnp.isfinite(loss2))
    assert float(loss2) < float(loss) + 1.0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_shapes(arch):
    cfg = get_config(arch).reduced()
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(2))
    B, S = 2, 16
    batch = {"tokens": jnp.ones((B, S), jnp.int32), "max_len": 32}
    if cfg.family == "audio":
        batch["frames"] = jnp.zeros((B, cfg.frontend_tokens, cfg.d_model))
    logits, cache = bundle.prefill(params, batch)
    assert logits.shape == (B, cfg.padded_vocab)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache = bundle.decode_step(params, tok, cache)
    assert logits2.shape == (B, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits2).all()), arch


@pytest.mark.parametrize("arch", ["yi-34b", "rwkv6-1.6b", "hymba-1.5b",
                                  "qwen2-vl-2b", "seamless-m4t-large-v2"])
def test_decode_matches_forward(arch):
    """prefill(8 tokens) + decode(1) == full forward over 9 tokens."""
    cfg = get_config(arch).reduced()
    bundle = build(cfg, cache_dtype=jnp.float32)
    params = bundle.init(jax.random.PRNGKey(3))
    toks = jax.random.randint(jax.random.PRNGKey(4), (1, 9), 0,
                              cfg.vocab_size)
    if cfg.family == "audio":
        frames = 0.1 * jax.random.normal(
            jax.random.PRNGKey(5), (1, cfg.frontend_tokens, cfg.d_model))
        full, _ = bundle.loss_fn, None
        lg, cache = bundle.prefill({**params}, {"frames": frames,
                                                "tokens": toks[:, :8],
                                                "max_len": 16})
        lg2, _ = bundle.decode_step(params, toks[:, 8], cache)
        # consistency vs running prefill over all 9 and comparing last logits
        lg_all, _ = bundle.prefill(params, {"frames": frames,
                                            "tokens": toks, "max_len": 16})
        np.testing.assert_allclose(np.asarray(lg_all), np.asarray(lg2),
                                   rtol=2e-4, atol=2e-4)
        return
    pos = text_positions(1, 9)
    if cfg.mrope:
        pos = jnp.stack([pos, pos, pos], -1)
    h, _ = bundle.forward(params, params["embed"][toks], pos)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    full_logits = (h @ head)[0, -1]
    lg, cache = bundle.prefill(params, {"tokens": toks[:, :8],
                                        "max_len": 16})
    lg2, cache = bundle.decode_step(params, toks[:, 8], cache)
    np.testing.assert_allclose(np.asarray(full_logits), np.asarray(lg2[0]),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ["deepseek-v2-lite-16b",
                                  "phi3.5-moe-42b-a6.6b"])
def test_moe_decode_matches_forward_dropless(arch):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg,
                              capacity_factor=float(cfg.n_experts)
                              / cfg.top_k)
    bundle = build(cfg, cache_dtype=jnp.float32)
    params = bundle.init(jax.random.PRNGKey(6))
    toks = jax.random.randint(jax.random.PRNGKey(7), (1, 9), 0,
                              cfg.vocab_size)
    h, _ = bundle.forward(params, params["embed"][toks],
                          text_positions(1, 9))
    full_logits = (h @ params["lm_head"])[0, -1]
    lg, cache = bundle.prefill(params, {"tokens": toks[:, :8],
                                        "max_len": 16})
    lg2, cache = bundle.decode_step(params, toks[:, 8], cache)
    np.testing.assert_allclose(np.asarray(full_logits), np.asarray(lg2[0]),
                               rtol=2e-4, atol=2e-4)


def test_rolling_window_decode_bounded_cache():
    cfg = get_config("yi-34b").reduced()
    bundle = build(cfg, rolling_decode=True, cache_dtype=jnp.float32)
    params = bundle.init(jax.random.PRNGKey(8))
    toks = jnp.ones((1, 8), jnp.int32)
    _, cache = bundle.prefill(params, {"tokens": toks, "max_len": 4096})
    # rolling buffer is window-sized regardless of max_len
    assert cache["k"].shape[2] == cfg.long_context_window
    tok = jnp.zeros((1,), jnp.int32)
    for _ in range(3):
        lg, cache = bundle.decode_step(params, tok, cache)
    assert bool(jnp.isfinite(lg).all())


def test_sliding_window_masks_old_tokens():
    """With window w, token at pos p must not attend to pos < p - w + 1."""
    from repro.kernels.ref import flash_attention_ref
    q = jnp.ones((1, 8, 1, 4))
    k = jnp.ones((1, 8, 1, 4))
    v = jnp.arange(8.0)[None, :, None, None] * jnp.ones((1, 8, 1, 4))
    out_full = flash_attention_ref(q, k, v, causal=True)
    out_win = flash_attention_ref(q, k, v, causal=True, window=2)
    # with window 2 the last query averages positions 6 and 7 -> 6.5
    np.testing.assert_allclose(np.asarray(out_win[0, -1, 0, 0]), 6.5,
                               rtol=1e-5)
    assert float(out_full[0, -1, 0, 0]) != 6.5
