"""Sharded (fsdp>1) reduction stack, end to end.

The acceptance surface of the shard-aware bucket layout: the compiled
SPMD HLO of a sharded bucket reduction must lower to reduce-scatter +
all-gather (never a full all-reduce for the buckets, and no stray
all-to-all / collective-permute from a non-shard-local reshape), the
result must be bit-identical to the per-leaf *replicated* oracle for the
lossless payloads (mean, cast), and EF state — carried in shard space
(codec view: shards merged into the local-learner axis) — must
round-trip through checkpoint save/restore back onto the mesh.

Device count must be forced before jax initializes, so everything that
needs the 8-device (4 learners x 2 shards) mesh runs in a subprocess
(same pattern as tests/test_pipeline.py).  Layout/metadata tests
(replica groups, safe_pspec non-dividing drops) run in-process.
"""
import json
import os
import subprocess
import sys
import types
import warnings

import numpy as np
import pytest

from repro.parallel.sharding import (PSpecDropWarning, ShardPlan,
                                     replica_groups, resolve_pspec,
                                     safe_pspec)
from repro.testing import count_collective_ops

_REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")
import json, sys
import jax, jax.numpy as jnp
from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.comm import get_reducer, reduce_with
from repro.core.topology import global_average
# the SAME builder benchmarks/bench_bucketing.py measures for the
# sharded A/B rows — verified structure and benchmarked program agree
from repro.testing import AB_SMALL_CAP, build_sharded_ab_reduction

d = sys.argv[1]
out = {}

# compiled HLO of the sharded bucket reduction, both schedules
for sched in ("serial", "pipelined"):
    b = build_sharded_ab_reduction(sched, AB_SMALL_CAP)
    p = jax.device_put(b["params"], b["shardings"][0])
    s = jax.device_put(b["state"], b["shardings"][1])
    open(os.path.join(d, sched + ".hlo"), "w").write(
        b["fn"].lower(p, s).compile().as_text())
    out[sched + "_buckets"] = b["n_buckets"]

# bit-identity vs the per-leaf REPLICATED oracle (same reducer, no
# bucketing, no mesh) for the lossless payloads
for spec in ("mean", "cast:bfloat16"):
    b = build_sharded_ab_reduction("serial", AB_SMALL_CAP, spec=spec)
    p = jax.device_put(b["params"], b["shardings"][0])
    s = jax.device_put(b["state"], b["shardings"][1])
    got, _ = b["fn"](p, s)
    leaf_red = get_reducer(spec)
    leaf_state = leaf_red.init_state(
        jax.tree.map(jnp.zeros_like, b["params"]))
    want, _ = reduce_with(leaf_red, global_average, b["params"],
                          leaf_state)
    out["maxdiff_" + spec.split(":")[0]] = max(
        float(jnp.max(jnp.abs(g.astype(jnp.float32)
                              - w.astype(jnp.float32))))
        for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)))

# fused qint8 through the sharded RS/AG path: shard-run packing shifts
# the quantizer's block boundaries vs the per-leaf layout, so parity is
# the per-block error bound vs the dense mean, not bit-identity; the
# fused pack must still ship ONE message per bucket
b = build_sharded_ab_reduction("serial", AB_SMALL_CAP, spec="qint8:128")
p = jax.device_put(b["params"], b["shardings"][0])
s = jax.device_put(b["state"], b["shardings"][1])
got, _ = b["fn"](p, s)
dense, _ = reduce_with(get_reducer("mean"), global_average,
                       b["params"], ())
out["maxdiff_qint8"] = max(
    float(jnp.max(jnp.abs(g - w)))
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(dense)))
out["absmax_qint8"] = max(
    float(jnp.max(jnp.abs(x))) for x in jax.tree.leaves(b["params"]))
out["qint8_messages"] = int(b["reducer"].n_messages(b["tree1"]))
out["qint8_buckets"] = int(b["n_buckets"])

# EF / reducer state round-trips through checkpoint in shard space
for tag, spec in (("topk", "topk:0.05"), ("qint8", "qint8")):
    b = build_sharded_ab_reduction("serial", AB_SMALL_CAP, spec=spec)
    p = jax.device_put(b["params"], b["shardings"][0])
    s = jax.device_put(b["state"], b["shardings"][1])
    _, s1 = b["fn"](p, s)
    ck = os.path.join(d, "ck_" + tag)
    save_checkpoint(ck, s1, step=1)
    like = jax.device_put(jax.tree.map(jnp.zeros_like, s1),
                          b["shardings"][1])
    s2 = restore_checkpoint(ck, like)
    out[tag + "_equal"] = all(
        bool(jnp.array_equal(a, r)) for a, r in
        zip(jax.tree.leaves(s1), jax.tree.leaves(s2)))
    out[tag + "_mesh_backed"] = all(
        getattr(x.sharding, "mesh", None) is not None
        for x in jax.tree.leaves(s2))
    out[tag + "_state_shapes"] = sorted(
        {str(tuple(x.shape)) for x in jax.tree.leaves(s1)})
    out[tag + "_nonzero"] = any(
        float(jnp.max(jnp.abs(x))) > 0 for x in jax.tree.leaves(s1)
        if jnp.issubdtype(x.dtype, jnp.floating))
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def sharded_run(tmp_path_factory):
    d = str(tmp_path_factory.mktemp("sharded"))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", _CHILD, d], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    meta = json.loads(r.stdout.strip().splitlines()[-1])
    with open(os.path.join(d, "serial.hlo")) as f:
        serial = f.read()
    with open(os.path.join(d, "pipelined.hlo")) as f:
        pipelined = f.read()
    return serial, pipelined, meta


def test_sharded_buckets_lower_to_reduce_scatter_all_gather(sharded_run):
    """The acceptance criterion verbatim: with fsdp=2 the compiled SPMD
    program reduces every bucket with reduce-scatter + all-gather — zero
    full all-reduce — and the shard-local pack/unpack reshapes introduce
    no all-to-all or collective-permute."""
    serial, pipelined, meta = sharded_run
    n = meta["serial_buckets"]
    assert n >= 8                     # really multi-bucket
    for txt in (serial, pipelined):
        c = count_collective_ops(txt)
        assert c["all_reduce"] == 0, c
        assert c["reduce_scatter"] > 0 and c["all_gather"] > 0, c
        assert c["all_to_all"] == 0 and c["collective_permute"] == 0, c
    # serial unrolls one RS/AG pair per active mesh axis per bucket (the
    # default (1,2,2) topo has two active learner axes at the global
    # level); the pipeline's scan keeps the count O(1) in buckets
    cs = count_collective_ops(serial)
    assert cs["reduce_scatter"] == 2 * n
    # at least the scatter-mean's forward gathers; GSPMD may add more
    # around the sparse codec
    assert cs["all_gather"] >= 2 * n
    cp = count_collective_ops(pipelined)
    assert cp["reduce_scatter"] + cp["all_gather"] <= 16


def test_sharded_mean_and_cast_match_replicated_oracle(sharded_run):
    """Sharded bucketed mean/cast are bit-identical to the per-leaf
    replicated reduction (the RS chain walks the same per-axis tree as
    the replicated grouped mean, so not even the summation order
    differs)."""
    _, _, meta = sharded_run
    assert meta["maxdiff_mean"] == 0.0
    assert meta["maxdiff_cast"] == 0.0


def test_sharded_fused_qint8_within_quant_error(sharded_run):
    """fsdp=2 coverage for the fused single-buffer qint8 pack: the
    sharded bucket reduction lands within the quantizer's error bound
    of the dense mean, and ships exactly one packed message per
    bucket."""
    _, _, meta = sharded_run
    assert meta["maxdiff_qint8"] <= meta["absmax_qint8"] / 100.0, meta
    assert meta["qint8_messages"] == meta["qint8_buckets"], meta


def test_sharded_ef_state_roundtrips_through_checkpoint(sharded_run):
    """Sparse EF state lives in shard space — codec view, shards merged
    into the local-learner axis (lead S*F = 2*2 = 4 on the default
    topo) — and restores bit-exactly onto its mesh-backed shardings.
    qint8 runs the same save/restore path (stateless today, so the
    round-trip degenerates to the empty tree)."""
    _, _, meta = sharded_run
    assert meta["topk_nonzero"]       # EF actually carried something
    assert meta["topk_equal"] and meta["topk_mesh_backed"]
    lead_merged = [s for s in meta["topk_state_shapes"]
                   if s.startswith("(1, 2, 4")]
    assert lead_merged, meta["topk_state_shapes"]
    assert meta["qint8_equal"] and meta["qint8_mesh_backed"]


_SWEEP_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=16")
import json
import jax, jax.numpy as jnp
import numpy as np
jax.config.update("jax_enable_x64", False)
from jax.sharding import Mesh
from repro.configs.base import HierAvgParams
from repro.configs.resnet18_cifar import MLPConfig
from repro.core import (HierTopology, init_state, make_hier_round,
                        unstack_first)
from repro.data.synthetic import make_classification_task
from repro.models.resnet import mlp_cls_init, mlp_cls_loss
from repro.optim import sgd
from repro.parallel.sharding import shard_plan

cfg = MLPConfig(in_dim=16, hidden=(32,), n_classes=4)
sample = make_classification_task(16, 4, seed=11, noise=0.5)
loss_fn = lambda p, b: mlp_cls_loss(p, b)
eval_batch = sample(jax.random.PRNGKey(123), 256)
topo = HierTopology(2, 2, 2)
B = 16
h = HierAvgParams(k1=2, k2=8,
                  plan="local@2:mean:bucketed/pod@4:mean:bucketed/"
                       "global@8:mean:bucketed")
opt = sgd(0.05)


def run(shards):
    rnd = jax.jit(make_hier_round(loss_fn, opt, h, shards=shards))
    state = init_state(topo, lambda k: mlp_cls_init(k, cfg), opt,
                       jax.random.PRNGKey(0), plan=h.resolved_plan,
                       shards=shards)
    dims = tuple(h.resolved_plan.batch_dims)
    losses, dk = [], jax.random.PRNGKey(42)
    for r in range(3):
        dk, sk = jax.random.split(dk)
        batch = sample(sk, h.k2 * topo.n_learners * B)
        shaped = jax.tree.map(
            lambda x: x.reshape(dims + topo.shape + (B,) + x.shape[1:]),
            batch)
        state, _ = rnd(state, shaped)
        l, _ = loss_fn(unstack_first(state.params), eval_batch)
        losses.append(float(l))
    return losses


out = {"fsdp1": run(None)}
mesh = Mesh(np.array(jax.devices()[:16]).reshape(2, 2, 2, 2, 1),
            ("pod", "group", "local", "fsdp", "model"))
out["fsdp2"] = run(shard_plan(mesh))
print(json.dumps(out))
"""


@pytest.mark.slow
def test_three_level_sweep_at_fsdp2_matches_replicated():
    """The fsdp=2 leg of the 3-level convergence sweep (the sweep itself
    — pod on/off vs the Thm-3.2 bars — lives in tests/test_hier_avg.py):
    the same 3-level bucketed-mean plan on a 2x2x2 topology, trained
    replicated and trained with every learner 2-way sharded on a forced
    16-host-device mesh, must produce the same loss trajectory — the
    RS/AG decomposition is an implementation detail, not an algorithm
    change — and must converge."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", _SWEEP_CHILD], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["fsdp2"][-1] < 0.8 * out["fsdp2"][0], out
    np.testing.assert_allclose(out["fsdp1"], out["fsdp2"],
                               rtol=1e-4, atol=1e-4)


# ------------------- replica groups (no devices) --------------------- #

def _mesh_stub(shape, names):
    """replica_groups/level_replica_groups only touch ``devices.shape``
    and ``axis_names`` — a stub stands in for an 8-device mesh."""
    return types.SimpleNamespace(devices=np.empty(shape), axis_names=names)


_HIER_NAMES = ("pod", "group", "local", "fsdp", "model")


def test_replica_groups_keep_shard_axis():
    """A global reduction on a (1,2,2,2,1) hier mesh keeps fsdp: each
    shard averages only with its 4 peers (row-major device order,
    reduced axes minor)."""
    mesh = _mesh_stub((1, 2, 2, 2, 1), _HIER_NAMES)
    assert replica_groups(mesh, ("pod", "group", "local")) \
        == [[0, 2, 4, 6], [1, 3, 5, 7]]
    # local level: one group per (group, fsdp) coordinate
    assert replica_groups(mesh, ("local",)) \
        == [[0, 2], [1, 3], [4, 6], [5, 7]]


def test_level_replica_groups_matches_plan_axes():
    from repro.launch.mesh import level_replica_groups
    mesh = _mesh_stub((1, 2, 2, 2, 1), _HIER_NAMES)
    assert level_replica_groups(mesh, "global") \
        == replica_groups(mesh, ("pod", "group", "local"))
    assert level_replica_groups(mesh, "local") \
        == replica_groups(mesh, ("local",))
    # pod level spans group+local on a single-pod mesh
    assert level_replica_groups(mesh, "pod") \
        == replica_groups(mesh, ("group", "local"))


# ------------- safe_pspec non-dividing drop (regression) ------------- #

def _abstract_mesh(sizes, names):
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(zip(names, sizes)))
    except TypeError:                          # older signature
        return AbstractMesh(tuple(sizes), tuple(names))


def test_safe_pspec_surfaces_nondividing_model_zoo_shapes():
    """The shapes that historically hit the silent-replication fallback:
    hymba's 25 attention heads vs TP-16 and seamless' 256206-token vocab
    vs TP-16 don't divide — the drop must warn (PSpecDropWarning) and
    resolve_pspec must expose exactly which axes fell off, so layout and
    billing key off the resolved spec."""
    from jax.sharding import PartitionSpec as P
    mesh = _abstract_mesh((2, 16), ("fsdp", "model"))
    # hymba: 25 heads -> head-stacked (25, 128) leaf, TP on the head dim
    resolved, dropped = resolve_pspec(P("model", None), (25, 128), mesh)
    assert tuple(resolved) == (None, None)
    assert dropped == ((0, "model"),)
    with pytest.warns(PSpecDropWarning, match="25, 128"):
        assert safe_pspec(P("model", None), (25, 128), mesh) \
            == P(None, None)
    # seamless: vocab 256206 = 2 * 128103 divides fsdp=2 but not TP-16
    resolved, dropped = resolve_pspec(P("model", "fsdp"), (256206, 1024),
                                      mesh)
    assert tuple(resolved) == (None, "fsdp")
    assert dropped == ((0, "model"),)
    # dividing specs resolve unchanged, drop-free and warning-free
    with warnings.catch_warnings():
        warnings.simplefilter("error", PSpecDropWarning)
        assert safe_pspec(P("fsdp", "model"), (256206, 1024), mesh) \
            == P("fsdp", "model")


def test_shard_plan_mirrors_safe_pspec_drop():
    """ShardPlan.leaf_shard_dim (what the bucket layout packs from) and
    the resolve_pspec drop agree: a non-dividing leaf stays flat, a
    dividing one shards its rules-resolved dim."""
    mesh = _abstract_mesh((1, 2, 2, 2, 1), _HIER_NAMES)
    sp = ShardPlan(mesh=mesh)
    # hymba-style head-count leaf: fallback (fsdp, model) on (25, 128),
    # 25 % 2 != 0 -> replicated, exactly the safe_pspec drop
    assert sp.leaf_shard_dim("blocks/0/attn/heads", (25, 128)) is None
    # the same rule with a dividing dim shards dim 0
    assert sp.leaf_shard_dim("blocks/0/attn/wq", (1600, 512)) == 0
    # seamless embed: rules put only "model" on the vocab dim -> no
    # fsdp dim anywhere, replicated for the reduction stack
    assert sp.leaf_shard_dim("embed", (256206, 1024)) is None
