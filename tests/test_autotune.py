"""Autotune subsystem (src/repro/autotune/): calibration fit, artifact
round-trip, the cost-aware period controller, and the plan search.

Everything here drives the machinery with SYNTHETIC cost models /
samples — deterministic, no timing dependence (the acceptance
requirement).  The one measured round-trip (probe subprocess on the
8-host-device mesh -> fit -> loose-tolerance prediction check) is
@slow; CI exercises the same path via ``benchmarks.run --only autotune
--smoke``.
"""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.autotune import (CPU_MEDIAN_REL_ERR, Calibration, CostAwarePlan,
                            ProbePoint, SearchSpace, fit_comm_model,
                            predict_seconds, recommend_plan,
                            resolve_comm_model, search_plans)
from repro.autotune.calibrate import ENV_CALIBRATION
from repro.configs.base import HierAvgParams
from repro.core.theory import (CommModel, level_reduction_seconds,
                               param_template, plan_comm_per_round)
from repro.core.plan import ReductionPlan
from repro.core.topology import HierTopology

TRUE = CommModel(fast_bw=2.0e8, slow_bw=1.0e7, latency=3.0e-4,
                 compress_bw=5.0e8)


def synth_samples(model: CommModel, *, noise: float = 0.0, seed: int = 0):
    """Probe-shaped samples generated FROM a known model (the fit's
    identifiability oracle): both tiers, two payload sizes, multi-
    message and codec points."""
    rng = np.random.default_rng(seed)
    out = []
    for tier, n in (("ici", 8), ("ici", 4), ("dci", 8)):
        for v in (1 << 17, 1 << 20, 1 << 22):
            for m, codec in ((1, False), (8, False), (1, True)):
                s = dict(level="global", tier=tier, n=n, payload_bytes=v,
                         dense_bytes=4 * v, messages=m, has_codec=codec,
                         spec="synth")
                t = predict_seconds(model, s)
                s["min_us"] = t * (1.0 + noise * rng.standard_normal()) \
                    * 1e6
                out.append(s)
    return out


# ------------------------------ calibration --------------------------- #

def test_fit_recovers_known_model_exactly():
    cal = fit_comm_model(synth_samples(TRUE))
    assert set(cal.fitted) == {"fast_bw", "slow_bw", "latency",
                               "compress_bw"}
    m = cal.model
    assert m.fast_bw == pytest.approx(TRUE.fast_bw, rel=1e-6)
    assert m.slow_bw == pytest.approx(TRUE.slow_bw, rel=1e-6)
    assert m.latency == pytest.approx(TRUE.latency, rel=1e-6)
    assert m.compress_bw == pytest.approx(TRUE.compress_bw, rel=1e-6)
    assert cal.median_rel_err < 1e-6


def test_fit_with_noise_stays_close():
    cal = fit_comm_model(synth_samples(TRUE, noise=0.05, seed=3))
    # the columns are collinear-ish, so 5% time noise amplifies — the
    # claim is order-of-magnitude robustness, not precision
    assert cal.model.fast_bw == pytest.approx(TRUE.fast_bw, rel=0.6)
    assert cal.model.slow_bw == pytest.approx(TRUE.slow_bw, rel=0.6)
    # the fit's own round-trip diagnostic reflects the injected noise,
    # well inside the documented CPU tolerance
    assert cal.median_rel_err < CPU_MEDIAN_REL_ERR


def test_fit_without_dci_samples_keeps_base_slow_bw():
    ici_only = [s for s in synth_samples(TRUE) if s["tier"] == "ici"]
    base = CommModel()
    cal = fit_comm_model(ici_only, base=base)
    assert "slow_bw" not in cal.fitted
    assert cal.model.slow_bw == base.slow_bw          # default kept
    assert cal.model.fast_bw == pytest.approx(TRUE.fast_bw, rel=1e-6)


def test_fit_per_codec_compress_bw(tmp_path):
    """Codec-labeled samples fit one compress_bw per family into
    ``CommModel.codec_bw`` (reported as ``compress_bw[<codec>]``);
    codecs the fit never saw fall back to the shared constant, and the
    artifact round-trips the per-codec rates."""
    true = CommModel(fast_bw=2.0e8, slow_bw=1.0e7, latency=3.0e-4,
                     compress_bw=5.0e8,
                     codec_bw=(("powersgd", 1.0e8), ("qint8", 2.0e9)))
    samples = []
    for tier, n in (("ici", 8), ("dci", 8)):
        for v in (1 << 17, 1 << 20, 1 << 22):
            for m, codec in ((1, ""), (8, ""), (1, "topk"),
                             (1, "qint8"), (1, "powersgd")):
                s = dict(tier=tier, n=n, payload_bytes=v,
                         dense_bytes=4 * v, messages=m,
                         has_codec=bool(codec), codec=codec)
                s["min_us"] = predict_seconds(true, s) * 1e6
                samples.append(s)
    cal = fit_comm_model(samples)
    m = cal.model
    assert {"compress_bw[powersgd]", "compress_bw[qint8]",
            "compress_bw[topk]"} <= set(cal.fitted)
    assert m.compress_bw_for("qint8") == pytest.approx(2.0e9, rel=1e-6)
    assert m.compress_bw_for("powersgd") == pytest.approx(1.0e8, rel=1e-6)
    # topk had no codec_bw entry in `true`, so its per-codec column
    # recovers the shared rate it was generated with
    assert m.compress_bw_for("topk") == pytest.approx(5.0e8, rel=1e-6)
    # a codec the fit never saw falls back to the shared constant —
    # here unfitted (every codec sample was labeled), so the base value
    assert "compress_bw" not in cal.fitted
    assert m.compress_bw_for("randk") == m.compress_bw == \
        CommModel().compress_bw
    assert cal.median_rel_err < 1e-6
    # artifact round-trip preserves the per-codec rates
    path = str(tmp_path / "codec.json")
    cal.save(path)
    loaded = Calibration.load(path)
    assert loaded.model == m
    assert loaded.model.compress_bw_for("qint8") \
        == pytest.approx(2.0e9, rel=1e-6)
    with open(path) as f:
        assert "codec_bw" in json.load(f)["comm_model"]
    # theory's serial bill prices codec compute through the same
    # per-codec lookup the fit produced
    topo = HierTopology(1, 2, 4)
    template = param_template(1 << 20, dtype="float32", n_leaves=4)
    plan = ReductionPlan.parse("local@2/global@8:qint8:128")
    lvl = plan.levels[-1]
    with_codec = level_reduction_seconds(lvl, topo, template, m)
    shared = level_reduction_seconds(
        lvl, topo, template, dataclasses.replace(m, codec_bw=None))
    # the fitted qint8 rate (2e9 B/s) is far below the shared base
    # constant (150e9), so the per-codec bill must scale compute_s by
    # exactly that ratio
    assert with_codec[1] == pytest.approx(
        shared[1] * m.compress_bw / 2.0e9, rel=1e-9)
    assert with_codec[1] > shared[1]


def test_calibration_artifact_roundtrip_and_resolve(tmp_path, monkeypatch):
    cal = fit_comm_model(synth_samples(TRUE))
    path = str(tmp_path / "calib.json")
    cal.save(path)
    loaded = Calibration.load(path)
    assert loaded.model == cal.model
    assert loaded.fitted == cal.fitted
    assert loaded.n_samples == cal.n_samples
    # resolution order: explicit path > env var > default
    assert resolve_comm_model(path) == cal.model
    monkeypatch.delenv(ENV_CALIBRATION, raising=False)
    assert resolve_comm_model() is None
    assert resolve_comm_model(default=CommModel()) == CommModel()
    monkeypatch.setenv(ENV_CALIBRATION, path)
    assert resolve_comm_model() == cal.model
    # a configured-but-missing artifact fails loudly, never silently
    # degrading to built-in constants
    monkeypatch.setenv(ENV_CALIBRATION, str(tmp_path / "typo.jsn"))
    with pytest.raises(FileNotFoundError, match="typo.jsn"):
        resolve_comm_model()
    with pytest.raises(FileNotFoundError, match="argument"):
        resolve_comm_model(str(tmp_path / "nope.json"))
    # json is the documented artifact shape
    with open(path) as f:
        d = json.load(f)
    assert set(d) >= {"comm_model", "fitted", "diagnostics"}
    assert set(d["comm_model"]) == {"fast_bw", "slow_bw", "latency",
                                    "compress_bw"}


def test_predict_matches_theory_serial_bill():
    """predict_seconds (the fit's model) and
    theory.level_reduction_seconds (the planner's bill) are the same
    formula — calibration and costing cannot drift apart."""
    topo = HierTopology(2, 2, 2)
    template = param_template(1 << 20, dtype="float32", n_leaves=4)
    plan = ReductionPlan.parse("local@2/global@8:topk:0.05")
    for lvl in plan.levels:
        comm_s, compute_s, wall_s = level_reduction_seconds(
            lvl, topo, template, TRUE)
        n = 1
        for a in lvl.axes:
            n *= topo.shape[a]
        s = dict(tier="dci" if (0 in lvl.axes and topo.pods > 1) else "ici",
                 n=n,
                 payload_bytes=lvl.reducer.payload_bytes(template),
                 dense_bytes=4 * (1 << 20),
                 messages=lvl.reducer.n_messages(template),
                 has_codec=getattr(lvl.reducer, "has_codec", True))
        assert predict_seconds(TRUE, s) == pytest.approx(
            comm_s + compute_s, rel=1e-9)
        assert wall_s == pytest.approx(comm_s + compute_s, rel=1e-9)


def test_calibration_load_rejects_non_artifact_json(tmp_path):
    """Feeding the wrong JSON (e.g. BENCH_autotune.json records) fails
    with a message naming the expected artifact, not an opaque
    AttributeError."""
    p = tmp_path / "records.json"
    p.write_text(json.dumps([{"name": "calibration"}]))
    with pytest.raises(ValueError, match="comm_model"):
        Calibration.load(str(p))
    p2 = tmp_path / "odd.json"
    p2.write_text(json.dumps({"foo": 1}))
    with pytest.raises(ValueError, match="calibration artifact"):
        Calibration.load(str(p2))


def test_analytic_roofline_honours_fitted_only(tmp_path, monkeypatch):
    """A configured artifact displaces ONLY the constants it fitted:
    an ICI-only calibration leaves the roofline's v5e DCI_BW in place
    (the artifact's unfitted slow_bw is a CommModel default, not a
    measurement)."""
    from repro.configs import get_config
    from repro.launch.analytic import analytic_roofline
    cfg = get_config("yi-34b")
    monkeypatch.delenv(ENV_CALIBRATION, raising=False)
    base = analytic_roofline(cfg, "train_4k", multi_pod=True)
    # slow_bw present in the model but NOT fitted -> DCI terms unchanged
    ici_only = Calibration(
        model=dataclasses.replace(CommModel(), fast_bw=1.0e9),
        fitted=("fast_bw",), n_samples=4, median_rel_err=0.1,
        max_rel_err=0.2)
    p = str(tmp_path / "ici.json")
    ici_only.save(p)
    monkeypatch.setenv(ENV_CALIBRATION, p)
    part = analytic_roofline(cfg, "train_4k", multi_pod=True)
    assert part.collective_parts["global_avg"] == pytest.approx(
        base.collective_parts["global_avg"])          # DCI untouched
    assert part.collective_parts["local_avg"] > \
        base.collective_parts["local_avg"]            # ICI 50x slower
    # a fitted slow_bw DOES displace the DCI constant
    both = dataclasses.replace(ici_only, fitted=("fast_bw", "slow_bw"))
    both.save(p)
    full = analytic_roofline(cfg, "train_4k", multi_pod=True)
    assert full.collective_parts["global_avg"] != pytest.approx(
        base.collective_parts["global_avg"])
    # a Calibration passed directly (dryrun --autotune) behaves the
    # same fitted-only way, without the env var
    monkeypatch.delenv(ENV_CALIBRATION)
    direct = analytic_roofline(cfg, "train_4k", multi_pod=True,
                               comm_model=ici_only)
    assert direct.collective_parts["global_avg"] == pytest.approx(
        base.collective_parts["global_avg"])
    assert direct.collective_parts["local_avg"] == pytest.approx(
        part.collective_parts["local_avg"])


# ------------------------------ controller ---------------------------- #

BASE3 = "local@2/pod@8/global@32"
TOPO2 = HierTopology(2, 2, 2)
BALANCED = CommModel(fast_bw=5.0e10, slow_bw=2.5e10)
SKEWED = CommModel(fast_bw=5.0e10, slow_bw=2.5e8)   # DCI 100x slower


def _ctl(cm, **kw):
    return CostAwarePlan(BASE3, TOPO2, cm,
                         template=param_template(1 << 22, n_leaves=8),
                         **kw)


def test_cost_aware_pod_period_shrinks_under_skewed_dci():
    """THE acceptance property: a skewed probed DCI/ICI cost ratio
    changes the pod period — expensive global reductions are substituted
    by more frequent (cheap, ICI) pod averaging (Hier-AVG §3.3)."""
    pod_bal = _ctl(BALANCED).periods_for(10.0)[1]
    pod_skew = _ctl(SKEWED).periods_for(10.0)[1]
    assert pod_skew < pod_bal
    assert pod_skew == 2          # floored at the (fixed) inner period


def test_cost_aware_nesting_and_ladder():
    ctl = _ctl(SKEWED)
    for loss in (10.0, 5.0, 1.0, 0.01, 1e-5):
        p = ctl.plan_for(loss)           # construction re-validates
        periods = [l.period for l in p.levels]
        assert periods[0] == 2           # innermost fixed
        for lo, hi in zip(periods, periods[1:]):
            assert hi % lo == 0
    # ladder: outermost shrinks with the loss, like AdaptivePlan
    ctl.reset()
    hi = ctl.periods_for(10.0)[-1]
    lo = ctl.periods_for(1e-5)[-1]
    assert lo < hi == 32


def test_cost_aware_accepts_calibration_artifact(tmp_path):
    """A synthetic calibration ARTIFACT (file) drives the controller —
    the no-timing-dependence acceptance path."""
    cal = Calibration(model=SKEWED, fitted=("slow_bw",), n_samples=6,
                      median_rel_err=0.1, max_rel_err=0.2)
    path = str(tmp_path / "skew.json")
    cal.save(path)
    ctl = CostAwarePlan(BASE3, TOPO2, path,
                        template=param_template(1 << 22, n_leaves=8))
    assert ctl.periods_for(10.0)[1] == 2


def test_cost_aware_params_for_preserves_base_fields():
    base = HierAvgParams(k1=2, k2=8, bucket_bytes=123 << 10,
                         overlap=False)
    h = _ctl(SKEWED).params_for(10.0, base=base)
    assert h.bucket_bytes == 123 << 10
    assert h.overlap is False
    assert h.plan is not None and h.k2 == 32
    # without a base: defaults
    h2 = _ctl(SKEWED).params_for(10.0)
    assert h2.bucket_bytes != 123 << 10


def test_cost_aware_two_level_plan_degenerates_to_adaptive():
    from repro.core import AdaptivePlan
    ctl = CostAwarePlan("local@4/global@64", TOPO2, BALANCED,
                        template=param_template(1 << 20, n_leaves=4))
    ladder = AdaptivePlan("local@4/global@64")
    for loss in (8.0, 0.5, 1e-4):
        assert ctl.periods_for(loss) == \
            (4, ladder.outer_for(loss))
        ladder_periods = ladder.plan_for(loss)
        assert ctl.plan_for(loss).describe() == ladder_periods.describe()


# ------------------------------ plan search --------------------------- #

def test_search_flips_global_reducer_with_cost_model():
    """Skewed DCI -> compress the expensive global tier (topk wins);
    codec-bound (tiny compress_bw, fat pipes) -> dense mean wins."""
    template = param_template(1 << 22, n_leaves=8)
    skew = recommend_plan(TOPO2, SKEWED, template=template)
    assert skew.spec.split("/")[-1].startswith("global@") \
        and "topk:0.05" in skew.spec.split("/")[-1]
    codec_bound = dataclasses.replace(
        BALANCED, fast_bw=1e13, slow_bw=1e13, compress_bw=1e6)
    dense = recommend_plan(TOPO2, codec_bound, template=template)
    assert dense.spec.split("/")[-1] == f"global@{dense.outer}:mean"


def test_search_respects_thm32_feasibility():
    """Condition (3.5) gates K2: at gamma=0.05 periods >= 16 are
    inadmissible, and the winner must be feasible when any feasible
    candidate exists."""
    from repro.core.theory import thm32_condition
    template = param_template(1 << 22, n_leaves=8)
    ranked = search_plans(TOPO2, SKEWED, template=template, gamma=0.05)
    assert ranked[0].feasible
    for sp in ranked:
        assert sp.feasible == thm32_condition(1.0, 0.05, sp.outer)
    assert ranked[0].outer <= 8
    # every feasible plan ranks before every infeasible one
    flags = [sp.feasible for sp in ranked]
    assert flags == sorted(flags, reverse=True)


def test_search_scores_are_calibration_consistent():
    """comm_s_per_step is exactly theory.plan_comm_per_round of the
    RESOLVED (bucketed/pipelined) candidate under the given model — the
    search costs what resolve_plan will actually run, and inherits
    whatever was calibrated."""
    from repro.comm import DEFAULT_BUCKET_BYTES
    from repro.core.plan import apply_bucketing
    template = param_template(1 << 22, n_leaves=8)
    space = SearchSpace(levels=("local", "global"),
                        periods={"local": (2,), "global": (8,)},
                        reducers={"local": ("mean",),
                                  "global": ("topk:0.05",)})
    (sp,) = search_plans(TOPO2, SKEWED, template=template, space=space)
    plan = ReductionPlan.parse(sp.spec)       # raw spec round-trips
    resolved = apply_bucketing(plan, DEFAULT_BUCKET_BYTES, True)
    costs = plan_comm_per_round(resolved, TOPO2, template, SKEWED)
    expect = sum(c.overlap_s for c in costs) / plan.total_period
    assert sp.comm_s_per_step == pytest.approx(expect, rel=1e-12)
    # the resolved bill differs from the raw per-leaf serial one (the
    # global topk level buckets 8 leaves into fewer messages), so
    # costing raw would misprice the candidate
    raw = sum(c.overlap_s for c in
              plan_comm_per_round(plan, TOPO2, template, SKEWED)) \
        / plan.total_period
    assert raw != pytest.approx(sp.comm_s_per_step, rel=1e-6)


# ------------------------------ probe shapes -------------------------- #

def test_probe_point_json_roundtrip_and_grid():
    from repro.autotune.probe import default_grid
    pt = ProbePoint("pod", (2, 2, 2), "topk:0.05", 4, (32, 32), 1 << 15)
    assert ProbePoint.from_json(pt.to_json()) == pt
    smoke, full = default_grid(smoke=True), default_grid(smoke=False)
    assert len(smoke) < len(full)
    # every CommModel parameter is identifiable from either grid:
    # both tiers, a multi-message point, and a codec point present
    for grid in (smoke, full):
        tiers = {("dci" if (p.level == "global" and p.topo[0] > 1)
                  else "ici") for p in grid}
        assert tiers == {"ici", "dci"}
        assert any(p.cap < 1 << 20 for p in grid)      # multi-bucket
        assert any(p.spec != "mean" for p in grid)     # codec
        assert sum(p.spec == "mean" and p.topo[0] == 1
                   and p.cap >= 1 << 20 for p in grid) >= 2  # bw slope


@pytest.mark.slow
def test_probe_calibrate_roundtrip_on_8dev_mesh():
    """The measured acceptance path: real probe samples (fresh
    subprocess per point, 8 forced host devices) -> fit -> the
    calibrated model predicts the measured per-level reduction times
    within the documented LOOSE CPU tolerance (median rel err, see
    autotune/calibrate.py docstring)."""
    from repro.autotune import default_grid, run_probe
    samples = run_probe(default_grid(smoke=True), reps=5)
    assert len(samples) == len(default_grid(smoke=True))
    cal = fit_comm_model(samples)
    assert cal.fitted                      # something was identifiable
    assert cal.median_rel_err <= CPU_MEDIAN_REL_ERR, (
        cal.median_rel_err, cal.model)
    # per-sample round trip, the quantity the tolerance is stated over
    errs = [abs(predict_seconds(cal.model, s) - s["min_us"] * 1e-6)
            / (s["min_us"] * 1e-6) for s in samples]
    assert float(np.median(errs)) <= CPU_MEDIAN_REL_ERR
