"""Elastic membership (repro/elastic), end to end.

The acceptance surface of participation-masked reductions: the masked
grouped mean must be bit-identical to the dense one at full
participation (serial, pipelined, and — in a forced-device subprocess —
fsdp=2 sharded engines), degenerate masks must degrade gracefully
(single survivor = that survivor's params, all-absent = identity, never
NaN), an absent learner's EF carry must survive a missed fire
bit-exactly, fault schedules must be pure functions of (seed, unit,
round) across processes, and a checkpointed fleet reshape must
bit-preserve survivors while remapping (or loudly dropping) reducer
state.  The n_eff expected-cost billing must collapse to the dense bill
at drop_prob=0.
"""
import hashlib
import json
import os
import subprocess
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import HierAvgParams
from repro.core import (HierTopology, Simulator, init_state,
                        make_hier_round, make_sgd_step, where_active)
from repro.core.plan import resolve_plan
from repro.core.theory import (CommModel, effective_participants,
                               param_template, plan_comm_per_round)
from repro.core.topology import (GLOBAL_ARRAY_AXES, POD_ARRAY_AXES,
                                 average_over)
from repro.elastic import (CommStateDropWarning, FaultSchedule,
                           checkpoint_topology, elastic_restore,
                           learner_index_map, parse_faults,
                           reshape_comm_state, save_elastic_checkpoint)
from repro.optim import sgd

_REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _assert_trees_equal(a, b, what=""):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=what)


def _stacked_leaves(tree, topo):
    """Leaves carrying the full [pods, G, S] stacked lead (skips PRNG
    keys and scalars)."""
    return [x for x in jax.tree.leaves(tree)
            if x.ndim >= 3 and tuple(x.shape[:3]) == topo.shape]


# --------------------------------------------------------------------- #
# masked grouped mean
# --------------------------------------------------------------------- #

def test_masked_mean_full_participation_bit_identical():
    """mask=all-ones must be bit-for-bit the dense mean at every level."""
    topo = HierTopology(2, 2, 2)
    key = jax.random.PRNGKey(0)
    tree = {"w": jax.random.normal(key, topo.shape + (5, 3)),
            "b": jax.random.normal(jax.random.split(key)[0],
                                   topo.shape + (7,))}
    ones = jnp.ones(topo.shape, bool)
    for axes in ((2,), POD_ARRAY_AXES, GLOBAL_ARRAY_AXES):
        _assert_trees_equal(average_over(tree, axes, mask=ones),
                            average_over(tree, axes), what=str(axes))


def test_masked_mean_renormalizes_over_survivors():
    """Absent learners get weight 0; the mean renormalizes over the
    survivor count — matches the numpy oracle exactly."""
    topo = HierTopology(1, 2, 2)
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(1),
                                     topo.shape + (6,)))
    m = np.ones(topo.shape, bool)
    m[0, 0, 0] = False
    got = average_over({"x": jnp.asarray(x)}, GLOBAL_ARRAY_AXES,
                       mask=jnp.asarray(m))["x"]
    w = m.astype(x.dtype).reshape(topo.shape + (1,))
    want = np.broadcast_to((x * w).sum((0, 1, 2), keepdims=True) / w.sum(),
                           x.shape)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_masked_mean_single_survivor_group():
    """A group reduced to one survivor averages to exactly that
    survivor's values (no drift from the renormalization)."""
    topo = HierTopology(2, 2, 2)
    x = jax.random.normal(jax.random.PRNGKey(2), topo.shape + (4,))
    m = np.zeros(topo.shape, bool)
    m[0, 1, 0] = True          # pod 0: single survivor
    m[1] = True                # pod 1: fully active
    got = average_over({"x": x}, POD_ARRAY_AXES, mask=jnp.asarray(m))["x"]
    want0 = np.broadcast_to(np.asarray(x)[0, 1, 0], (2, 2, 4))
    np.testing.assert_array_equal(np.asarray(got)[0], want0)
    want1 = np.broadcast_to(np.asarray(x)[1].mean((0, 1)), (2, 2, 4))
    np.testing.assert_allclose(np.asarray(got)[1], want1, rtol=1e-6)


def test_masked_mean_all_absent_is_finite_and_where_active_keeps_old():
    """All-absent group: the masked mean degrades to zeros (max(count,1)
    guard — never NaN) and the where_active select keeps the old tree
    bit-exactly, so the reduction is an identity."""
    topo = HierTopology(1, 2, 2)
    old = {"x": jax.random.normal(jax.random.PRNGKey(3), topo.shape + (4,))}
    zeros = jnp.zeros(topo.shape, bool)
    avg = average_over(old, GLOBAL_ARRAY_AXES, mask=zeros)
    assert np.all(np.isfinite(np.asarray(avg["x"])))
    assert np.all(np.asarray(avg["x"]) == 0.0)
    _assert_trees_equal(where_active(zeros, avg, old), old)


def test_where_active_codec_view_and_global_leaves():
    """Leaf alignment: [pods, G, S*F] codec-view leaves repeat each
    learner's bit over its F shard rows; non-stacked leaves (PRNG keys)
    always take new."""
    topo = HierTopology(1, 2, 2)
    m = np.ones(topo.shape, bool)
    m[0, 0, 1] = False
    new = {"ef": jnp.arange(24, dtype=jnp.float32).reshape(1, 2, 4, 3),
           "key": jnp.array([1, 2], jnp.uint32)}
    old = {"ef": jnp.zeros((1, 2, 4, 3)), "key": jnp.array([9, 9],
                                                          jnp.uint32)}
    out = where_active(jnp.asarray(m), new, old)
    got = np.asarray(out["ef"])
    # learner (0,0,1) owns shard rows 2:4 of group 0 — restored to old
    np.testing.assert_array_equal(got[0, 0, 2:4], 0.0)
    np.testing.assert_array_equal(got[0, 0, 0:2],
                                  np.asarray(new["ef"])[0, 0, 0:2])
    np.testing.assert_array_equal(got[0, 1], np.asarray(new["ef"])[0, 1])
    np.testing.assert_array_equal(np.asarray(out["key"]),
                                  np.asarray(new["key"]))


# --------------------------------------------------------------------- #
# elastic round program
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("overlap", [False, True],
                         ids=["serial", "pipelined"])
def test_elastic_full_participation_bit_identical(cls_task, overlap):
    """A fault schedule that never fires (flaky p=0) must train
    bit-identically to the dense round program — losses AND final params
    — on both the serial and the pipelined bucket engines (small
    bucket_bytes forces a real multi-bucket schedule)."""
    topo = HierTopology(1, 2, 2)
    hier = HierAvgParams(plan="local@2/global@4:topk:0.25",
                         bucket_bytes=2048, overlap=overlap)
    runs = {}
    for name, faults in [("dense", None), ("masked", "flaky:0.0")]:
        sim = Simulator(cls_task["loss_fn"], cls_task["init_fn"],
                        cls_task["sample"], topo=topo, hier=hier,
                        optimizer=sgd(0.05), seed=7,
                        per_learner_batch=8, faults=faults)
        runs[name] = sim.run(3)
    np.testing.assert_array_equal(runs["dense"].losses,
                                  runs["masked"].losses)
    _assert_trees_equal(runs["dense"].state.params,
                        runs["masked"].state.params)
    _assert_trees_equal(runs["dense"].state.comm_state,
                        runs["masked"].state.comm_state)
    assert np.all(runs["masked"].active_fracs == 1.0)
    assert runs["masked"].round_wall_s is not None
    assert runs["dense"].active_fracs is None


def test_all_absent_round_is_pure_local_sgd(cls_task):
    """An all-false mask turns the round into per-learner SGD: identical
    to scanning make_sgd_step with no reduction at all, and the metrics
    report active_frac 0."""
    topo = HierTopology(1, 2, 2)
    hier = HierAvgParams(plan="global@2:mean")
    opt = sgd(0.05)
    key = jax.random.PRNGKey(4)
    rnd = jax.jit(make_hier_round(cls_task["loss_fn"], opt, hier,
                                  elastic=True))
    batch = cls_task["sample"](jax.random.PRNGKey(5),
                               2 * topo.n_learners * 8)
    shaped = jax.tree.map(
        lambda x: x.reshape((2,) + topo.shape + (8,) + x.shape[1:]), batch)
    state = init_state(topo, cls_task["init_fn"], opt, key,
                       plan=resolve_plan(hier))
    none_active = jnp.zeros((1,) + topo.shape, bool)
    out, metrics = rnd(state, shaped, none_active)
    assert float(metrics["active_frac/global"]) == 0.0

    step = jax.jit(make_sgd_step(cls_task["loss_fn"], opt))
    ref = init_state(topo, cls_task["init_fn"], opt, key,
                     plan=resolve_plan(hier))
    for t in range(2):
        ref, _ = step(ref, jax.tree.map(lambda x: x[t], shaped))
    _assert_trees_equal(out.params, ref.params, "all-absent != pure SGD")
    for leaf in jax.tree.leaves(out.params):
        assert np.all(np.isfinite(np.asarray(leaf)))


def test_ef_bit_preserved_across_missed_fire(cls_task):
    """An absent learner's error-feedback carry must come out of the
    round bit-identical to how it went in (it neither contributed to nor
    observed the reduction), while present learners' EF advances."""
    topo = HierTopology(1, 2, 2)
    hier = HierAvgParams(plan="global@2:topk:0.25")
    opt = sgd(0.05)
    rnd = jax.jit(make_hier_round(cls_task["loss_fn"], opt, hier,
                                  elastic=True))
    state = init_state(topo, cls_task["init_fn"], opt,
                       jax.random.PRNGKey(6), plan=resolve_plan(hier))
    batch = cls_task["sample"](jax.random.PRNGKey(7),
                               2 * topo.n_learners * 8)
    shaped = jax.tree.map(
        lambda x: x.reshape((2,) + topo.shape + (8,) + x.shape[1:]), batch)
    active = np.ones((1,) + topo.shape, bool)
    active[0, 0, 0, 0] = False
    before = _stacked_leaves(state.comm_state, topo)
    assert before, "topk plan should carry stacked EF state"
    before = [np.asarray(x) for x in before]
    out, _ = rnd(state, shaped, jnp.asarray(active))
    after = _stacked_leaves(out.comm_state, topo)
    changed = False
    for b, a in zip(before, after):
        a = np.asarray(a)
        np.testing.assert_array_equal(
            a[0, 0, 0], b[0, 0, 0],
            err_msg="absent learner's EF touched across a missed fire")
        changed = changed or not np.array_equal(a[0, 0, 1], b[0, 0, 1])
    assert changed, "present learners' EF should advance"
    # the absent learner's params kept its own local-SGD trajectory:
    # distinct from the survivors' averaged params
    p = np.asarray(jax.tree.leaves(out.params)[0])
    assert not np.array_equal(p[0, 0, 0], p[0, 0, 1])
    np.testing.assert_array_equal(p[0, 0, 1], p[0, 1, 1])


# --------------------------------------------------------------------- #
# fault schedules
# --------------------------------------------------------------------- #

def test_fault_schedule_deterministic_and_order_free():
    topo = HierTopology(2, 2, 2)
    levels = ("local", "pod", "global")
    spec = "crash:0.1/flaky:pod:0.3:2/straggler:0.5:1.0"
    dl = {"local": 0.5, "pod": 1.0, "global": 2.0}
    a = FaultSchedule(spec, topo, levels, seed=3, deadlines=dl)
    b = FaultSchedule(spec, topo, levels, seed=3, deadlines=dl)
    for r in (5, 0, 3, 5, 1):           # out of order, repeated
        np.testing.assert_array_equal(a.active(r), b.active(r))
    assert a.describe() == b.describe()
    assert parse_faults(a.describe()) == a.clauses
    # a different seed moves the pattern
    c = FaultSchedule(spec, topo, levels, seed=4, deadlines=dl)
    assert any(not np.array_equal(a.active(r), c.active(r))
               for r in range(8))


def test_fault_schedule_crash_is_permanent():
    topo = HierTopology(1, 2, 2)
    fs = FaultSchedule("crash:0.3", topo, ("global",), seed=5)
    masks = np.stack([fs.active(r)[0].reshape(-1) for r in range(20)])
    for j in range(topo.n_learners):
        down = np.where(~masks[:, j])[0]
        if down.size:
            assert not masks[down[0]:, j].any(), "crashed learner rejoined"
    assert not masks[-1].all(), "p=0.3 over 20 rounds should crash someone"


def test_fault_schedule_flaky_granularity_and_down_window():
    topo = HierTopology(2, 2, 2)
    pod = FaultSchedule("flaky:pod:0.5", topo, ("global",), seed=1)
    hit = False
    for r in range(8):
        m = pod.active(r)[0]
        # whole pods flap together
        assert all(len(set(m[p].reshape(-1).tolist())) == 1
                   for p in range(2))
        hit = hit or not m.all()
    assert hit
    # a longer outage window only removes participation, on the same
    # underlying hit stream
    short = FaultSchedule("flaky:0.4:1", topo, ("global",), seed=2)
    long = FaultSchedule("flaky:0.4:3", topo, ("global",), seed=2)
    s = np.stack([short.active(r) for r in range(10)])
    l = np.stack([long.active(r) for r in range(10)])
    assert np.all(l <= s)
    assert l.sum() < s.sum()


def test_fault_schedule_level_restriction_and_straggler_deadlines():
    topo = HierTopology(1, 2, 2)
    levels = ("local", "global")
    fs = FaultSchedule("flaky:1.0@global", topo, levels, seed=0)
    m = fs.active(0)
    assert m[0].all() and not m[1].any()
    with pytest.raises(ValueError, match="names level"):
        FaultSchedule("crash:0.1@nosuch", topo, levels, seed=0)
    # stragglers miss every level whose deadline their delay exceeds:
    # the cheap level's survivor set nests inside the expensive level's
    fs = FaultSchedule("straggler:1.0:1.0", topo, levels, seed=9,
                       deadlines={"local": 0.05, "global": 50.0})
    masks = np.stack([fs.active(r) for r in range(6)])
    assert np.all(masks[:, 0] <= masks[:, 1])
    assert masks[:, 0].sum() < masks[:, 1].sum()
    # p=0 never masks anyone
    calm = FaultSchedule("straggler:0.0", topo, levels, seed=9)
    assert calm.active(0).all()


def test_fault_spec_grammar_errors():
    for bad in ("bogus:0.5", "crash:1.5", "crash:-0.1", "crash",
                "flaky:0.2:0", "flaky:tower:0.2", "", "straggler"):
        with pytest.raises(ValueError):
            parse_faults(bad)


def test_fault_schedule_deterministic_across_processes():
    """Satellite (f): the mask stream is reconstructable from
    (spec, seed, round) alone — a fresh process produces the identical
    masks (the bench A/B subprocess legs rely on this)."""
    spec = "crash:0.1/flaky:pod:0.3:2/straggler:0.5:1.0"
    dl = {"local": 0.5, "global": 2.0}
    topo = HierTopology(2, 2, 2)
    fs = FaultSchedule(spec, topo, ("local", "global"), seed=11,
                       deadlines=dl)
    here = hashlib.sha256(
        b"".join(fs.active(r).tobytes() for r in range(6))).hexdigest()
    child = (
        "import hashlib, json, sys\n"
        "from repro.core import HierTopology\n"
        "from repro.elastic import FaultSchedule\n"
        "fs = FaultSchedule(%r, HierTopology(2, 2, 2),\n"
        "                   ('local', 'global'), seed=11, deadlines=%r)\n"
        "h = hashlib.sha256(\n"
        "    b''.join(fs.active(r).tobytes() for r in range(6)))\n"
        "print(json.dumps({'sha': h.hexdigest()}))\n" % (spec, dl))
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(_REPO, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    r = subprocess.run([sys.executable, "-c", child], env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr
    assert json.loads(r.stdout.strip().splitlines()[-1])["sha"] == here


# --------------------------------------------------------------------- #
# fleet reshape
# --------------------------------------------------------------------- #

def test_learner_index_map():
    old, new = HierTopology(1, 2, 2), HierTopology(1, 3, 2)
    src, joiner = learner_index_map(old, new)
    np.testing.assert_array_equal(src, [0, 1, 2, 3, 0, 0])
    np.testing.assert_array_equal(joiner, [False] * 4 + [True] * 2)
    src, joiner = learner_index_map(new, old)       # shrink
    np.testing.assert_array_equal(src, [0, 1, 2, 3])
    assert not joiner.any()
    src, _ = learner_index_map(old, new, survivors=[3, 1], donor=3)
    np.testing.assert_array_equal(src, [3, 1, 3, 3, 3, 3])
    for bad in ({"survivors": [0, 0]}, {"survivors": [7]},
                {"survivors": list(range(5))}, {"survivors": []}):
        with pytest.raises(ValueError):
            learner_index_map(old, HierTopology(1, 2, 2), **bad)


def test_checkpointed_reshape_roundtrip_bit_preserves(cls_task, tmp_path):
    """Grow 4 -> 6 learners, then shrink back: survivors' params and
    bucket-space EF are bit-preserved both ways, joiners clone the donor
    with a ZEROED error residual, and the round-trip is exact."""
    old_topo, new_topo = HierTopology(1, 2, 2), HierTopology(1, 3, 2)
    hier = HierAvgParams(plan="global@2:topk:0.25")
    sim = Simulator(cls_task["loss_fn"], cls_task["init_fn"],
                    cls_task["sample"], topo=old_topo, hier=hier,
                    optimizer=sgd(0.05), seed=13, per_learner_batch=8)
    state = sim.run(2).state
    d4 = str(tmp_path / "fleet4")
    save_elastic_checkpoint(d4, state, old_topo, step=2, plan=sim.plan)
    assert checkpoint_topology(d4) == old_topo

    like6 = init_state(new_topo, cls_task["init_fn"], sgd(0.05),
                       jax.random.PRNGKey(99), plan=resolve_plan(hier))
    got6 = elastic_restore(d4, like6, new_topo=new_topo)
    for old_leaf, new_leaf in zip(_stacked_leaves(state.params, old_topo),
                                  _stacked_leaves(got6.params, new_topo)):
        o = np.asarray(old_leaf).reshape((-1,) + old_leaf.shape[3:])
        n = np.asarray(new_leaf).reshape((-1,) + new_leaf.shape[3:])
        np.testing.assert_array_equal(n[:4], o, "survivors not preserved")
        np.testing.assert_array_equal(n[4], o[0], "joiner != donor clone")
    # joiners' EF residual is zeroed (a cloned residual would double-count
    # the donor's untransmitted mass); survivors' EF is bit-preserved
    err6 = _stacked_leaves(got6.comm_state["global"].err, new_topo)
    err4 = _stacked_leaves(state.comm_state["global"].err, old_topo)
    for e6, e4 in zip(err6, err4):
        e6 = np.asarray(e6).reshape((-1,) + e6.shape[3:])
        np.testing.assert_array_equal(
            e6[:4], np.asarray(e4).reshape((-1,) + e4.shape[3:]))
        np.testing.assert_array_equal(e6[4:], 0.0)

    d6 = str(tmp_path / "fleet6")
    save_elastic_checkpoint(d6, got6, new_topo, step=2, plan=sim.plan)
    like4 = init_state(old_topo, cls_task["init_fn"], sgd(0.05),
                       jax.random.PRNGKey(98), plan=resolve_plan(hier))
    back = elastic_restore(d6, like4, new_topo=old_topo)
    _assert_trees_equal(back.params, state.params, "round-trip params")
    _assert_trees_equal(back.comm_state, state.comm_state,
                        "round-trip comm_state")


def test_reshape_drops_codec_view_state_with_warning():
    """Shard-space (codec-view) reducer state is not lead-invariant —
    the reshape must refuse to guess, warn loudly, and drop it."""
    from repro.comm.sparse import EFState
    old_topo, new_topo = HierTopology(1, 2, 2), HierTopology(1, 3, 2)
    cs = {"global": EFState(
        ref=[jnp.ones((1, 2, 4, 7))],        # S*F = 4 != S = 2: codec view
        err=[jnp.zeros((1, 2, 4, 7))],
        key=jax.random.PRNGKey(0))}
    src, joiner = learner_index_map(old_topo, new_topo)
    with pytest.warns(CommStateDropWarning, match="global"):
        out = reshape_comm_state(cs, old_topo, new_topo, src, joiner)
    assert out["global"] == ()


def test_restore_learner_count_mismatch_diagnostic(cls_task, tmp_path):
    """Satellite (a): plain restore_checkpoint onto a different fleet
    size must fail with a diagnostic naming the learner grids and both
    counts and pointing at elastic_restore."""
    from repro.checkpoint import restore_checkpoint
    topo = HierTopology(1, 2, 2)
    state = init_state(topo, cls_task["init_fn"], sgd(0.05),
                       jax.random.PRNGKey(0))
    d = str(tmp_path / "ck")
    save_elastic_checkpoint(d, state, topo)
    like = init_state(HierTopology(1, 3, 2), cls_task["init_fn"],
                      sgd(0.05), jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="learner-count mismatch") as ei:
        restore_checkpoint(d, like)
    msg = str(ei.value)
    assert "(1, 2, 2)" in msg and "(1, 3, 2)" in msg
    assert "4 learners" in msg and "6" in msg
    assert "elastic_restore" in msg


# --------------------------------------------------------------------- #
# expected-cost billing (n_eff)
# --------------------------------------------------------------------- #

def test_effective_participants():
    assert effective_participants(8, 0.0) == 8.0
    assert effective_participants(8, 1.0) == 1.0
    assert effective_participants(1, 0.7) == 1.0
    vals = [effective_participants(8, p) for p in (0.0, 0.2, 0.5, 1.0)]
    assert vals == sorted(vals, reverse=True)
    assert effective_participants(8, -0.5) == 8.0   # clamped
    assert effective_participants(8, 2.0) == 1.0


def test_plan_comm_drop_prob_billing():
    from repro.core.plan import ReductionPlan
    plan = ReductionPlan.parse("local@2/global@8")
    topo = HierTopology(2, 2, 2)
    template = param_template(1 << 16, n_leaves=4)
    cm = CommModel()
    dense = plan_comm_per_round(plan, topo, template, cm)
    same = plan_comm_per_round(plan, topo, template, cm, drop_prob=0.0)
    for a, b in zip(dense, same):       # p=0 bills identically to dense
        assert a.seconds_per_round == b.seconds_per_round
        assert a.overlap_s == b.overlap_s
        assert b.n_eff == b.participants
    lossy = plan_comm_per_round(plan, topo, template, cm, drop_prob=0.3)
    for a, b in zip(dense, lossy):
        assert b.drop_prob == 0.3
        assert 1.0 < b.n_eff < b.participants
        assert b.seconds_per_round < a.seconds_per_round
    # per-level dict: only the named tier is billed under dropout
    mixed = plan_comm_per_round(plan, topo, template, cm,
                                drop_prob={"global": 0.5})
    assert mixed[0].drop_prob == 0.0
    assert mixed[0].seconds_per_round == dense[0].seconds_per_round
    assert mixed[1].drop_prob == 0.5
    assert mixed[1].seconds_per_round < dense[1].seconds_per_round
    # p=1: only the (expected) lone survivor remains -> zero comm wire
    alone = plan_comm_per_round(plan, topo, template, cm, drop_prob=1.0)
    assert all(c.seconds_per_round == 0.0 for c in alone)


def test_search_and_controller_take_drop_prob():
    from repro.autotune.controller import CostAwarePlan
    from repro.autotune.search import search_plans
    topo = HierTopology(2, 2, 2)
    template = param_template(1 << 16, n_leaves=4)
    dense = search_plans(topo, template=template)
    lossy = search_plans(topo, template=template, drop_prob=0.5)
    assert {s.spec for s in dense} == {s.spec for s in lossy}
    by_spec = {s.spec: s for s in dense}
    assert all(s.comm_s_per_step <= by_spec[s.spec].comm_s_per_step
               for s in lossy)
    assert any(s.comm_s_per_step < by_spec[s.spec].comm_s_per_step
               for s in lossy)
    ctl_d = CostAwarePlan("local@2/pod@4/global@8", topo,
                          template=template)
    ctl_l = CostAwarePlan("local@2/pod@4/global@8", topo,
                          template=template, drop_prob={"global": 0.5})
    assert ctl_l.level_costs[:2] == ctl_d.level_costs[:2]
    assert ctl_l.level_costs[2] < ctl_d.level_costs[2]
    assert ctl_l.periods_for(10.0)      # still produces a valid lattice


# --------------------------------------------------------------------- #
# the headline: dropout convergence within the theory bars
# --------------------------------------------------------------------- #

@pytest.mark.slow
def test_pod_dropout_within_thm32_bars(cls_task):
    """The PR's headline claim: a 3-level fleet with 20% pod-level
    dropout converges within the Thm 3.2 bound bar of the fault-free
    run (bar priced at the dropout run's effective participant count)."""
    from repro.core.theory import thm32_bound, thm32_condition
    topo = HierTopology(2, 2, 2)
    res = {}
    for name, faults in [("faultfree", None), ("dropout20",
                                               "flaky:pod:0.2")]:
        sim = Simulator(cls_task["loss_fn"], cls_task["init_fn"],
                        cls_task["sample"], topo=topo,
                        hier=HierAvgParams(k1=2, k2=8,
                                           plan="local@2/pod@4/global@8"),
                        optimizer=sgd(0.05), seed=3, per_learner_batch=16,
                        eval_batch=cls_task["eval_batch"], faults=faults)
        res[name] = sim.run(4)
    dp = res["dropout20"]
    assert dp.active_fracs is not None and dp.active_fracs.shape == (4, 3)
    assert 0.0 < dp.active_fracs.mean() < 1.0, "20% dropout never fired"
    assert dp.round_wall_s is not None and np.all(dp.round_wall_s > 0)
    F1, L, M, gamma, P, B, N = 2.0, 1.0, 1.0, 0.05, 8, 16, 4
    assert thm32_condition(L, gamma, K2=8)
    bar = thm32_bound(F1, L, M, gamma, K1=2, K2=8, S=2,
                      P=effective_participants(P, 0.2), B=B, N=N)
    for name in res:
        losses = res[name].eval_losses
        assert losses[-1] < 0.65 * losses[0], (name, losses)
    gap = abs(dp.eval_losses[-1] - res["faultfree"].eval_losses[-1])
    assert gap <= bar, (gap, bar)
    assert gap <= 0.05, f"empirical dropout gap blew up: {gap}"


# --------------------------------------------------------------------- #
# fsdp=2 sharded engine (forced-device subprocess, as tests/test_sharded)
# --------------------------------------------------------------------- #

_SHARDED_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.comm import reduce_with
from repro.core.topology import GLOBAL_ARRAY_AXES, average_over
from repro.testing import (AB_SMALL_CAP, build_sharded_ab_reduction,
                           count_collective_ops)

b = build_sharded_ab_reduction("serial", AB_SMALL_CAP, spec="mean")
p = jax.device_put(b["params"], b["shardings"][0])
s = jax.device_put(b["state"], b["shardings"][1])
topo_shape = (1, 2, 2)
out = {}

def masked_fn(mask):
    return jax.jit(lambda pp, ss: reduce_with(
        b["reducer"],
        lambda t, cf=None, specs=None: average_over(
            t, GLOBAL_ARRAY_AXES, cf, specs, mask),
        pp, ss), in_shardings=b["shardings"])

# full participation: bit-identical to the dense sharded reduction, and
# the masked lowering stays pure reduce-scatter/all-gather
fn_full = masked_fn(jnp.ones(topo_shape, bool))
got_full, _ = fn_full(p, s)
got_dense, _ = b["fn"](p, s)
out["full_maxdiff"] = max(
    float(jnp.max(jnp.abs(a - c))) for a, c in
    zip(jax.tree.leaves(got_full), jax.tree.leaves(got_dense)))
out["collectives"] = count_collective_ops(
    fn_full.lower(p, s).compile().as_text())

# partial participation matches the replicated masked-mean oracle
m = np.ones(topo_shape, bool); m[0, 0, 0] = False
got_part, _ = masked_fn(jnp.asarray(m))(p, s)
w = m.astype(np.float32).reshape(topo_shape + (1, 1))
md = 0.0
for a, x in zip(jax.tree.leaves(got_part), jax.tree.leaves(b["params"])):
    x = np.asarray(x)
    want = (x * w).sum(axis=(0, 1, 2), keepdims=True) / w.sum()
    md = max(md, float(np.max(np.abs(
        np.asarray(a) - np.broadcast_to(want, x.shape)))))
out["partial_maxdiff"] = md
print(json.dumps(out))
"""


@pytest.mark.slow
def test_sharded_masked_reduction_subprocess():
    """fsdp=2: the participation mask is applied in wire space, so the
    shard-aware bucket path keeps its reduce-scatter/all-gather lowering
    and its numerics — full-mask bit-identical to dense, partial mask
    equal to the replicated oracle."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(_REPO, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    r = subprocess.run([sys.executable, "-c", _SHARDED_CHILD], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["full_maxdiff"] == 0.0
    assert out["partial_maxdiff"] == 0.0
    assert out["collectives"]["all_reduce"] == 0
    assert out["collectives"]["reduce_scatter"] > 0
    assert out["collectives"]["all_gather"] > 0
