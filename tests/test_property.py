"""Hypothesis property tests on the system's invariants.

``hypothesis`` is an optional dependency: when absent the whole module is
skipped (not an error), so tier-1 collection under ``-x`` never aborts.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.strategies as st  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.core import global_average, local_average, pod_average
from repro.core.theory import (third_term_poly, thm34_objective,
                               thm36_hier_bound, thm36_kavg_bound)

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25,
    suppress_health_check=list(hypothesis.HealthCheck))
hypothesis.settings.load_profile("ci")

shapes = st.tuples(st.integers(1, 2), st.integers(1, 3), st.integers(1, 4))


@given(shapes, st.integers(0, 2 ** 31 - 1))
def test_averaging_preserves_global_mean(shape, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape + (3,))
    for avg in (local_average, global_average, pod_average):
        y = avg({"w": x})["w"]
        np.testing.assert_allclose(float(y.mean()), float(x.mean()),
                                   rtol=1e-5, atol=1e-6)


@given(shapes, st.integers(0, 2 ** 31 - 1))
def test_averaging_idempotent(shape, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape + (2,))
    for avg in (local_average, global_average):
        y = avg({"w": x})["w"]
        z = avg({"w": y})["w"]
        np.testing.assert_allclose(np.asarray(z), np.asarray(y), rtol=1e-6)


@given(shapes, st.integers(0, 2 ** 31 - 1))
def test_global_after_local_equals_global(shape, seed):
    """Hierarchy consistency: local then global == global (means of means
    with equal cluster sizes)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), shape + (2,))
    a = global_average(local_average({"w": x}))["w"]
    b = global_average({"w": x})["w"]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-6)


@given(st.integers(1, 64), st.integers(2, 16), st.integers(1, 32))
def test_thm35_third_term_monotone_in_k1(k1, s, k2_extra):
    """Theorem 3.5(1): the bound's K1/S polynomial is non-decreasing in K1
    (for K1 >= 2, S > 1, K2 >= K1)."""
    k2 = k1 + k2_extra
    if k1 + 1 > k2:
        return
    a = third_term_poly(k2, k1, s)
    b = third_term_poly(k2, min(k1 + 1, k2), s)
    assert b >= a - 1e-9


@given(st.integers(1, 64), st.integers(1, 15), st.integers(0, 64))
def test_thm35_third_term_decreasing_in_s(k1, s, k2_extra):
    """Theorem 3.5(2): strictly decreasing in S."""
    k2 = k1 + k2_extra
    a = third_term_poly(k2, k1, s)
    b = third_term_poly(k2, k1, s + 1)
    assert b <= a + 1e-9


@given(st.integers(2, 64), st.floats(0.0, 0.6),
       st.floats(1e-4, 1.0), st.floats(1e-6, 1e-2))
def test_thm36_hier_beats_kavg(k, a, alpha, eta):
    """Theorem 3.6: H(K) < chi(K) for K >= 2, a in [0, 0.6] — Hier-AVG with
    K2=(1+a)K, K1=1, S=4 has a strictly smaller bound than K-AVG(K)."""
    h = thm36_hier_bound(k, a, alpha, eta)
    c = thm36_kavg_bound(k, alpha, eta)
    assert h < c + 1e-12


@given(st.floats(1e-3, 10.0), st.floats(1e-7, 1e-3), st.floats(1e-9, 1e-5),
       st.integers(1, 8), st.integers(1, 16))
def test_thm34_objective_positive_and_k2_search(alpha, beta, eta, k1, s):
    """B(K2) is positive and the argmin over K2 is well defined."""
    vals = [thm34_objective(k2, k1, s, alpha, beta, eta)
            for k2 in [1] + list(range(k1, 65, k1))]
    assert all(v > 0 for v in vals)


@given(st.integers(1, 3), st.integers(1, 3), st.integers(1, 4),
       st.integers(0, 2 ** 31 - 1))
def test_consensus_invariant_after_global_average(p, g, s, seed):
    """All learners equal after global averaging, for any topology shape."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (p, g, s, 5))
    y = global_average({"w": x})["w"]
    flat = y.reshape(p * g * s, 5)
    assert bool(jnp.allclose(flat, flat[0:1], atol=1e-6))
