"""End-to-end behaviour tests for the full system."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config

pytestmark = pytest.mark.slow
from repro.configs.base import HierAvgParams
from repro.core import HierTopology, Simulator, unstack_first
from repro.data.synthetic import make_markov_task, markov_lm_batch
from repro.models import build
from repro.optim import sgd

ROOT = os.path.join(os.path.dirname(__file__), "..")
ENV = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))


def test_hier_avg_trains_reduced_lm():
    """Full-stack: Hier-AVG trains a reduced pool arch (hymba) on a Markov
    LM task."""
    cfg = get_config("hymba-1.5b").reduced()
    bundle = build(cfg)
    logits_T, floor = make_markov_task(cfg.vocab_size, temperature=2.0)

    def sample(key, n):
        return markov_lm_batch(key, n, 16, logits_T)

    topo = HierTopology(1, 2, 2)
    sim = Simulator(bundle.loss_fn, bundle.init, sample, topo=topo,
                    hier=HierAvgParams(k1=2, k2=4), optimizer=sgd(0.5),
                    per_learner_batch=4, seed=0,
                    eval_batch=sample(jax.random.PRNGKey(77), 32))
    r = sim.run(6)
    assert r.eval_losses[-1] < r.eval_losses[0] - 0.05
    assert np.isfinite(r.eval_losses).all()


def test_train_driver_cli():
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "rwkv6-1.6b",
         "--rounds", "2", "--k1", "1", "--k2", "2", "--learners", "2",
         "--s", "2", "--batch", "2", "--seq", "16"],
        capture_output=True, text=True, env=ENV, cwd=ROOT, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "round   1" in out.stdout


def test_serve_driver_cli():
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "qwen2-vl-2b", "--requests", "3", "--slots", "2",
         "--prompt-len", "8", "--max-new", "4"],
        capture_output=True, text=True, env=ENV, cwd=ROOT, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "3 requests" in out.stdout


def test_dryrun_cli_one_case(tmp_path):
    """The multi-pod dry-run machinery lowers+compiles a full-size case in a
    fresh process (512 host devices)."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "rwkv6-1.6b", "--shape", "decode_32k", "--out", str(tmp_path)],
        capture_output=True, text=True, env=ENV, cwd=ROOT, timeout=560)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.load(open(tmp_path / "rwkv6-1.6b__decode_32k__1pod.json"))
    assert rec["chips"] == 256
    assert rec["roofline"]["bottleneck"] in ("compute", "memory",
                                             "collective")


def test_checkpoint_resume_training(tmp_path, cls_task):
    """Save averaged model mid-training, restore, continue — the next round
    is identical to continuing without the save/restore."""
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    from repro.core import init_state, make_hier_round, stack_like
    from repro.core.hier_avg import TrainState

    topo = HierTopology(1, 2, 2)
    h = HierAvgParams(k1=2, k2=2)
    opt = sgd(0.05)
    rf = jax.jit(make_hier_round(cls_task["loss_fn"], opt, h))
    state = init_state(topo, cls_task["init_fn"], opt, jax.random.PRNGKey(0))

    def rb(seed):
        b = cls_task["sample"](jax.random.PRNGKey(seed),
                               h.k2 * topo.n_learners * 4)
        return jax.tree.map(
            lambda x: x.reshape((h.beta, h.k1) + topo.shape + (4,)
                                + x.shape[1:]), b)

    state, _ = rf(state, rb(1))
    avg = unstack_first(state.params)
    save_checkpoint(str(tmp_path / "ck"), avg, step=int(state.step))

    restored = restore_checkpoint(str(tmp_path / "ck"),
                                  jax.tree.map(jnp.zeros_like, avg))
    state2 = TrainState(stack_like(topo, restored),
                        opt.init(stack_like(topo, restored)), state.step)
    s_a, m_a = rf(state, rb(2))
    s_b, m_b = rf(state2, rb(2))
    np.testing.assert_allclose(float(m_a["loss"]), float(m_b["loss"]),
                               rtol=1e-5)
