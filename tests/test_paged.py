"""Paged KV cache + continuous-batching engine.

Fast tier: the block allocator's free/reuse invariants and the
``cache_bytes`` accounting (including the encoder-decoder regression).
Slow tier: paged-vs-dense greedy bit-parity across model families and the
PagedServeEngine's refill / ordering / pool behaviour.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.serve import (BlockAllocator, cache_bytes, page_bytes, pages_for,
                         pool_pages)
from repro.serve.kvcache import describe_cache


# ===================================================================== #
# accounting (fast tier)
# ===================================================================== #

def test_cache_bytes_counts_cross_attention_encdec():
    """Regression: encoder-decoder archs hold a self-attention AND a
    cross-attention K/V cache per decoder layer; ``cache_bytes`` computed
    the doubled layer count but returned the single-stack size."""
    cfg = get_config("seamless-m4t-large-v2")
    assert cfg.is_encoder_decoder
    esize = 2  # bf16
    per = 2 * 128 * cfg.n_kv_heads * cfg.resolved_head_dim * esize
    expected = 3 * (2 * cfg.n_layers) * per
    assert cache_bytes(cfg, 3, 128) == expected
    # exactly double the equivalent decoder-only stack
    dec_only = dataclasses.replace(cfg, is_encoder_decoder=False)
    assert cache_bytes(cfg, 3, 128) == 2 * cache_bytes(dec_only, 3, 128)
    assert describe_cache(cfg, 3, 128)["bytes"] == expected


def test_page_bytes_and_pool_sizing():
    cfg = get_config("yi-34b").reduced()
    assert page_bytes(cfg, 16) == cache_bytes(cfg, 1, 16)
    assert pages_for(1, 16) == 1 and pages_for(16, 16) == 1
    assert pages_for(17, 16) == 2
    # slots mode: every slot can hold a full max_len sequence (+ null)
    assert pool_pages(cfg, 16, slots=3, max_len=64) == 3 * 4 + 1
    # budget mode: whatever the bytes buy
    b = page_bytes(cfg, 16)
    assert pool_pages(cfg, 16, budget_bytes=5 * b + b // 2) == 5 + 1


def test_block_allocator_reserve_take_release():
    a = BlockAllocator(6)                 # 5 usable pages + null
    assert a.free_pages == 5 and a.unreserved_pages == 5
    assert a.reserve(3)
    assert not a.reserve(3)               # only 2 unreserved left
    assert a.reserve(2)
    p1, p2 = a.take(), a.take()
    assert p1 != p2 and 0 < p1 < 6 and 0 < p2 < 6
    assert a.free_pages == 3
    a.release([p1, p2], reserved_left=3)  # finish early: 3 unused units
    assert a.free_pages == 5 and a.unreserved_pages == 5
    assert a.peak_in_use == 2


def test_block_allocator_never_hands_out_null_page():
    a = BlockAllocator(4)
    assert a.reserve(3)
    pages = [a.take() for _ in range(3)]
    assert 0 not in pages and sorted(pages) == [1, 2, 3]


def test_block_allocator_misuse_raises():
    a = BlockAllocator(4)
    with pytest.raises(RuntimeError, match="without a matching reserve"):
        a.take()
    assert a.reserve(2)
    p = a.take()
    with pytest.raises(ValueError, match="bad page id"):
        a.release([0])
    with pytest.raises(ValueError, match="bad page id"):
        a.release([7])
    a.release([p], reserved_left=1)
    with pytest.raises(ValueError, match="double free"):
        a.release([p])
    with pytest.raises(ValueError, match="bad reservation release"):
        a.release([], reserved_left=5)
    with pytest.raises(ValueError, match=">= 2 pages"):
        BlockAllocator(1)


def test_block_allocator_reuse_is_immediate():
    """Pages released by a finished sequence satisfy the very next
    reservation — the free/reuse property continuous batching rides on."""
    a = BlockAllocator(5)                 # 4 usable
    assert a.reserve(4)
    held = [a.take() for _ in range(4)]
    assert not a.reserve(1)               # pool exhausted
    a.release(held[:2])
    assert a.reserve(2)                   # freed pages immediately usable
    again = [a.take(), a.take()]
    assert set(again) == set(held[:2])
    a.release(again)
    a.release(held[2:])
    assert a.free_pages == 4


# ===================================================================== #
# paged-vs-dense parity + engine behaviour (slow tier: builds models)
# ===================================================================== #

_slow = pytest.mark.slow


def _bundle(arch, **kw):
    from repro.models import build
    cfg = get_config(arch).reduced()
    if cfg.uses_moe:
        # expert-capacity dropping depends on the routing group, so a
        # capacity-bound MoE routes chunked prefill differently from the
        # full prompt; a dropless factor makes chunking invisible
        # (models/moe.py) and parity exact
        cfg = dataclasses.replace(
            cfg, capacity_factor=float(cfg.n_experts) / cfg.top_k)
    bundle = build(cfg, cache_dtype=jnp.float32, decode_impl="xla", **kw)
    params = bundle.init(jax.random.PRNGKey(0))
    return cfg, bundle, params


@_slow
@pytest.mark.parametrize("arch", [
    "yi-34b",                  # dense GQA
    "starcoder2-15b",          # sliding-window GQA
    "qwen2-vl-2b",             # vlm backbone (M-RoPE)
    "deepseek-v2-lite-16b",    # MLA latent + MoE + first_k_dense
])
def test_paged_greedy_matches_dense(arch):
    """Chunked paged prefill + paged decode produce bit-identical greedy
    tokens to the contiguous-cache path (fp32 cache)."""
    cfg, bundle, params = _bundle(arch)
    B, PLEN, NEW, PAGE, CHUNK = 2, 9, 5, 8, 4
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, PLEN), 0,
                                 cfg.vocab_size)

    logits, cache = bundle.prefill(params, {"tokens": prompts,
                                            "max_len": 64})
    toks = [np.asarray(jnp.argmax(logits, -1))]
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(NEW - 1):
        logits, cache = bundle.decode_step(params, tok, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        toks.append(np.asarray(tok))
    dense = np.stack(toks, 1)

    maxp = pages_for(PLEN + NEW + CHUNK, PAGE)
    pages = bundle.init_paged_cache(1 + B * maxp, PAGE)
    tables = jnp.asarray(
        np.arange(1, 1 + B * maxp, dtype=np.int32).reshape(B, maxp))
    padded = -(-PLEN // CHUNK) * CHUNK
    ptoks = jnp.pad(prompts, ((0, 0), (0, padded - PLEN)))
    last = None
    for c0 in range(0, padded, CHUNK):
        lg, pages = bundle.prefill_paged_chunk(
            params, ptoks[:, c0:c0 + CHUNK], pages, tables,
            jnp.asarray(c0, jnp.int32))
        if c0 <= PLEN - 1 < c0 + CHUNK:
            last = lg[:, PLEN - 1 - c0]
    toks = [np.asarray(jnp.argmax(last, -1))]
    tok = jnp.argmax(last, -1).astype(jnp.int32)
    lengths = jnp.full((B,), PLEN, jnp.int32)
    active = jnp.ones((B,), bool)
    for _ in range(NEW - 1):
        lg, pages = bundle.decode_step_paged(params, tok, pages, tables,
                                             lengths, active)
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
        toks.append(np.asarray(tok))
        lengths = lengths + 1
    np.testing.assert_array_equal(dense, np.stack(toks, 1))


@_slow
def test_paged_engine_matches_dense_engine_greedy():
    """Whole-engine parity: the paged engine's chunked prefill + masked
    slot decode returns the same greedy tokens as the dense wave engine
    (uniform prompt lengths, so wave padding is a no-op)."""
    from repro.serve import GenerationConfig, PagedServeEngine, ServeEngine
    cfg, bundle, params = _bundle("yi-34b")
    gen = GenerationConfig(max_new_tokens=6, temperature=0.0)
    rng = np.random.default_rng(0)
    reqs = [rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
            for _ in range(5)]
    dense = ServeEngine(bundle, params, max_len=64, gen=gen)
    paged = PagedServeEngine(bundle, params, slots=2, page_size=8,
                             max_len=64, prefill_chunk=8,
                             cache_dtype=jnp.float32, gen=gen)
    dres = dense.serve_queue(reqs, slots=2)
    pres = paged.serve_queue(reqs)
    for d, p in zip(dres, pres):
        assert d.request_id == p.request_id
        np.testing.assert_array_equal(d.tokens, p.tokens)
    # token-level refill never recompiles: one trace per program
    assert paged.prefill_traces == 1 and paged.decode_traces == 1


@_slow
def test_paged_engine_queue_order_and_pool_reuse():
    """More requests than slots, mixed prompt lengths and budgets: FIFO
    admission keeps results ordered; every page returns to the pool."""
    from repro.serve import GenerationConfig, PagedServeEngine
    cfg, bundle, params = _bundle("yi-34b")
    gen = GenerationConfig(max_new_tokens=8, temperature=0.7, seed=3)
    rng = np.random.default_rng(1)
    reqs = [rng.integers(0, cfg.vocab_size, size=int(n)).astype(np.int32)
            for n in rng.integers(3, 20, size=7)]
    budgets = [int(b) for b in rng.integers(1, 9, size=7)]
    eng = PagedServeEngine(bundle, params, slots=3, page_size=8,
                           max_len=64, prefill_chunk=8,
                           cache_dtype=jnp.float32, gen=gen)
    res = eng.serve_queue(reqs, max_new=budgets)
    assert [r.request_id for r in res] == list(range(7))
    for r, b in zip(res, budgets):
        assert r.steps == len(r.tokens) == b
        assert r.decode_steps == b - 1      # budget hit => zero waste
    assert eng.alloc.free_pages == eng.alloc.n_pages - 1
    assert eng.alloc.peak_in_use <= 3 * eng.max_pages_per_seq


@_slow
def test_paged_engine_tiny_pool_serializes_but_serves():
    """A pool sized for exactly one sequence forces head-of-line
    admission: the engine degrades to serial service, never deadlocks,
    and still preserves order — the admission-reservation invariant."""
    from repro.serve import GenerationConfig, PagedServeEngine
    cfg, bundle, params = _bundle("yi-34b")
    gen = GenerationConfig(max_new_tokens=4, temperature=0.0)
    # each request needs 2 pages (padded prompt 16 toks / page 8);
    # a 2-page budget admits exactly one at a time
    budget = 2 * page_bytes(cfg, 8, cache_dtype=jnp.float32)
    eng = PagedServeEngine(bundle, params, slots=3, page_size=8,
                           max_len=24, prefill_chunk=8,
                           budget_bytes=budget, cache_dtype=jnp.float32,
                           gen=gen)
    rng = np.random.default_rng(2)
    reqs = [rng.integers(0, cfg.vocab_size, size=10).astype(np.int32)
            for _ in range(4)]
    res = eng.serve_queue(reqs)
    assert [r.request_id for r in res] == [0, 1, 2, 3]
    assert all(r.steps == 4 for r in res)
    assert eng.alloc.peak_in_use == 2      # strictly serial
    assert eng.alloc.free_pages == eng.alloc.n_pages - 1


@_slow
def test_paged_engine_eos_frees_slot_early():
    """EOS mid-stream trims the result AND stops spending decode steps on
    the slot (the wasted-step claim)."""
    from repro.serve import GenerationConfig, PagedServeEngine
    cfg, bundle, params = _bundle("yi-34b")
    rng = np.random.default_rng(3)
    reqs = [rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
            for _ in range(2)]
    probe = PagedServeEngine(
        bundle, params, slots=2, page_size=8, max_len=64, prefill_chunk=8,
        cache_dtype=jnp.float32,
        gen=GenerationConfig(max_new_tokens=6, temperature=0.0))
    full = probe.serve_queue(reqs)
    eos = int(full[0].tokens[2])          # greedy => reproducible
    eng = PagedServeEngine(
        bundle, params, slots=2, page_size=8, max_len=64, prefill_chunk=8,
        cache_dtype=jnp.float32,
        gen=GenerationConfig(max_new_tokens=6, temperature=0.0,
                             eos_id=eos))
    res = eng.serve_queue(reqs)
    r0 = res[0]
    assert r0.tokens[-1] == eos
    # trimmed at the FIRST eos occurrence (<= position 2), and the slot
    # stopped spending decode steps right there
    assert len(r0.tokens) <= 3
    assert r0.decode_steps == len(r0.tokens) - 1
    np.testing.assert_array_equal(r0.tokens,
                                  full[0].tokens[:len(r0.tokens)])


@_slow
@pytest.mark.parametrize("arch", ["rwkv6-1.6b", "hymba-1.5b",
                                  "seamless-m4t-large-v2"])
def test_paged_engine_rejects_stateful_families(arch):
    """ssm / hybrid / encoder-decoder caches are not positional pages;
    the paged engine refuses them with a pointer at ServeEngine."""
    from repro.models import build
    from repro.serve import PagedServeEngine
    cfg = get_config(arch).reduced()
    bundle = build(cfg)
    assert bundle.decode_step_paged is None
    with pytest.raises(ValueError, match="use ServeEngine"):
        PagedServeEngine(bundle, None)     # raises before touching params
