"""Pallas kernel validation: shape/dtype sweeps, interpret=True vs the
pure-jnp oracles in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _tol(dtype):
    return ATOL[jnp.bfloat16 if dtype == jnp.bfloat16 else jnp.float32]


# the larger interpret-mode sweep shapes are slow-tier; scripts/test_fast.sh
# still runs the full kernel suite explicitly (pytest -m "" tests/test_kernels.py)
_slow = pytest.mark.slow


@pytest.mark.parametrize("b,s,hq,hkv,d", [
    (1, 128, 1, 1, 64),
    pytest.param(2, 256, 4, 2, 64, marks=_slow),
    pytest.param(1, 256, 8, 8, 128, marks=_slow),
    (2, 128, 6, 2, 32),
    pytest.param(1, 512, 4, 1, 64, marks=_slow),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal(b, s, hq, hkv, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, hq, d), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, d), dtype)
    o_ref = ref.flash_attention_ref(q, k, v, causal=True)
    o = ops.flash_attention(q, k, v, causal=True, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("window", [32, 100, 256])
def test_flash_attention_window(window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    b, s, hq, hkv, d = 2, 256, 4, 2, 64
    q = jax.random.normal(ks[0], (b, s, hq, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    o_ref = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    o = ops.flash_attention(q, k, v, causal=True, window=window,
                            impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=2e-5,
                               rtol=2e-5)


@pytest.mark.parametrize("block_q,block_k", [(64, 64), (128, 64), (64, 128)])
def test_flash_attention_blocks(block_q, block_k):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    b, s, h, d = 1, 256, 2, 64
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    o_ref = ref.flash_attention_ref(q, k, v, causal=True)
    o = ops.flash_attention(q, k, v, causal=True, impl="pallas_interpret",
                            block_q=block_q, block_k=block_k)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=2e-5,
                               rtol=2e-5)


@pytest.mark.parametrize("compaction", ["scan", "onehot"])
@pytest.mark.parametrize("rows,n,k,block_n", [
    (1, 64, 1, 64),
    (5, 300, 30, 128),      # n not a block multiple -> padded tail
    (3, 1024, 102, 256),
    (2, 128, 128, 64),      # k == n (everything transmitted)
    (4, 17, 3, 1024),       # block_n > n
])
def test_topk_compress_interpret_matches_ref(rows, n, k, block_n,
                                             compaction):
    """Fused threshold+compaction kernel == lax.top_k oracle (fp32 inputs
    have no magnitude ties, so the selections agree exactly) — for both
    the scalable carried-offset compaction and the legacy one-hot."""
    x = jax.random.normal(jax.random.PRNGKey(n + k), (rows, n))
    v_ref, i_ref = ref.topk_compress_ref(x, k)
    v, i = ops.topk_compress(x, k, impl="pallas_interpret", block_n=block_n,
                             compaction=compaction)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))
    np.testing.assert_allclose(np.asarray(v), np.asarray(v_ref), rtol=1e-6)


def test_topk_compress_bf16_magnitudes():
    """bf16 rounds values onto a coarse grid, so magnitude ties at the
    threshold are legal tie-breaks — the *selected magnitudes* must still
    match the oracle even when the tied indices differ."""
    x = jax.random.normal(jax.random.PRNGKey(7), (3, 256), jnp.bfloat16)
    v_ref, _ = ref.topk_compress_ref(x, 25)
    v, i = ops.topk_compress(x, 25, impl="pallas_interpret")
    assert i.dtype == jnp.int32 and v.dtype == x.dtype
    a = np.sort(np.abs(np.asarray(v, np.float32)), axis=-1)
    b = np.sort(np.abs(np.asarray(v_ref, np.float32)), axis=-1)
    np.testing.assert_allclose(a, b, rtol=1e-6)


def test_topk_compress_heavy_tailed_magnitudes():
    """Scale-free threshold search: a 1e8 outlier next to ~1.0 values must
    not cost selection precision (regression: value-domain bisection lost
    ~23 bits here and kept wrong elements)."""
    x = 0.9 + 0.1 * jax.random.uniform(jax.random.PRNGKey(11), (1, 8193))
    x = x.at[0, 4000].set(1e8)
    v_ref, i_ref = ref.topk_compress_ref(x, 100)
    v, i = ops.topk_compress(x, 100, impl="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))
    np.testing.assert_array_equal(np.asarray(v), np.asarray(v_ref))


def test_topk_compress_ties_and_zeros():
    """Exact tie handling: tied magnitudes at the threshold break to the
    lowest indices (lax.top_k's stable order) and zero rows are legal."""
    x = jnp.zeros((2, 64)).at[0, 5].set(0.5).at[0, 9].set(0.5) \
        .at[0, 40].set(-0.5).at[1, 60].set(-2.0)
    v_ref, i_ref = ref.topk_compress_ref(x, 2)
    v, i = ops.topk_compress(x, 2, impl="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))
    np.testing.assert_array_equal(np.asarray(v), np.asarray(v_ref))


def test_topk_compress_indices_sorted_and_exact_k():
    x = jax.random.normal(jax.random.PRNGKey(8), (4, 500))
    for impl in ("xla", "pallas_interpret"):
        v, i = ops.topk_compress(x, 50, impl=impl)
        i = np.asarray(i)
        assert (np.diff(i, axis=-1) > 0).all()        # strictly ascending
        assert v.shape == (4, 50) and i.shape == (4, 50)


def test_topk_compress_row_cap_gated_on_legacy_compaction():
    """The 2^24 flat-row cap belongs to the legacy one-hot compaction
    (fp32 index accumulation); the scan compaction keeps exact int32
    indices and must trace past it.  The error names the offending
    shape."""
    big = jax.ShapeDtypeStruct((2, 2 ** 24 + 64), jnp.float32)
    with pytest.raises(ValueError, match=r"\(2, 16777280\)"):
        jax.eval_shape(lambda x: ops.topk_compress(
            x, 8, impl="pallas", compaction="onehot"), big)
    # explicit scan AND the default auto dispatch trace past the cap
    for compaction in ("scan", "auto"):
        v, i = jax.eval_shape(lambda x, c=compaction: ops.topk_compress(
            x, 8, impl="pallas", compaction=c), big)
        assert v.shape == (2, 8) and i.shape == (2, 8)
        assert i.dtype == jnp.int32


@pytest.mark.slow
def test_topk_compress_scan_row_beyond_2e24_interpret():
    """The scalable compaction's whole point: a >2^24-element row with
    outliers planted ABOVE 2^24 keeps exact indices (the legacy engine's
    fp32 accumulation cannot represent them).  ~3 min in interpret mode
    on 2 CPU cores — slow tier; scripts/test_fast.sh deselects it."""
    n = 2 ** 24 + 4096
    x = jax.random.normal(jax.random.PRNGKey(0), (1, n), jnp.float32)
    # plant magnitudes at high indices, including odd offsets a float
    # rounds away (2^24 + 1 is the first unrepresentable int32 in fp32)
    for j, off in enumerate((1, 3, 1001, 4095)):
        x = x.at[0, 2 ** 24 + off].set(100.0 + j)
    v_ref, i_ref = ref.topk_compress_ref(x, 64)
    v, i = ops.topk_compress(x, 64, impl="pallas_interpret",
                             compaction="scan")
    assert int(np.asarray(i).max()) > 2 ** 24
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))
    np.testing.assert_allclose(np.asarray(v), np.asarray(v_ref), rtol=1e-6)


try:
    from hypothesis import given, settings
    import hypothesis.strategies as hst

    @settings(deadline=None, max_examples=10)
    @given(hst.integers(1, 4), hst.integers(1, 700), hst.integers(1, 100),
           hst.sampled_from([64, 128, 1024]), hst.booleans())
    def test_property_topk_scan_compaction_roundtrip(rows, n, k, block_n,
                                                     heavy):
        """Hypothesis sweep of the scan compaction against the oracle,
        including heavy-tailed rows (1e8 outlier next to ~1 values)."""
        k = min(k, n)
        x = jax.random.normal(jax.random.PRNGKey(n * 31 + k), (rows, n))
        if heavy:
            x = x.at[:, n // 2].set(1e8)
        v_ref, i_ref = ref.topk_compress_ref(x, k)
        v, i = ops.topk_compress(x, k, impl="pallas_interpret",
                                 block_n=block_n, compaction="scan")
        np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))
        np.testing.assert_allclose(np.asarray(v), np.asarray(v_ref),
                                   rtol=1e-6)
except ImportError:                                   # pragma: no cover
    pass


# ------------- codec kernels: batched QR + fused qint8 pack ---------- #

def _proj(q):
    """Projector QQ^T — the convention-free quantity PowerSGD consumes
    (the kernel's CGS2 column signs may differ from LAPACK's)."""
    return jnp.einsum("...ij,...kj->...ik", q, q)


@pytest.mark.parametrize("shape", [
    (1, 8, 2),
    (5, 33, 2),               # non-pow2 rows
    pytest.param((8, 96, 4), marks=_slow),
    (3, 57, 3),               # GQA-style odd panel dims
    (2, 7, 5),                # near-square, a barely >= r
    pytest.param((4, 2, 4, 78, 2), marks=_slow),   # extra batch dims
])
def test_batched_qr_interpret_matches_oracle(shape):
    """CGS2 kernel vs jnp.linalg.qr: projector parity plus
    orthonormality of the kernel's own Q."""
    p = jax.random.normal(jax.random.PRNGKey(sum(shape)), shape)
    q = ops.batched_qr(p, impl="pallas_interpret")
    q_ref = ref.batched_qr_ref(p)
    assert q.shape == p.shape and q.dtype == p.dtype
    np.testing.assert_allclose(np.asarray(_proj(q)),
                               np.asarray(_proj(q_ref)),
                               atol=5e-6, rtol=1e-5)
    r = shape[-1]
    gram = np.asarray(jnp.einsum("...ji,...jk->...ik", q, q))
    np.testing.assert_allclose(gram, np.broadcast_to(np.eye(r), gram.shape),
                               atol=5e-6)


def test_batched_qr_xla_impl_is_oracle():
    p = jax.random.normal(jax.random.PRNGKey(1), (3, 20, 2))
    np.testing.assert_array_equal(
        np.asarray(ops.batched_qr(p, impl="xla")),
        np.asarray(ref.batched_qr_ref(p)))


def test_batched_qr_rank_deficient_column_zero_not_nan():
    """A zero input column must come back as a ZERO Q column (the EF
    residual re-accumulates its mass), never NaNs from rsqrt(0); the
    surviving columns stay orthonormal."""
    p = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 3))
    p = p.at[..., 2].set(0.0)
    q = np.asarray(ops.batched_qr(p, impl="pallas_interpret"))
    assert np.isfinite(q).all()
    np.testing.assert_array_equal(q[..., 2], np.zeros_like(q[..., 2]))
    gram = np.einsum("bji,bjk->bik", q[..., :2], q[..., :2])
    np.testing.assert_allclose(gram, np.broadcast_to(np.eye(2), (2, 2, 2)),
                               atol=5e-6)


def test_batched_qr_rejects_wide_panels():
    with pytest.raises(ValueError, match="tall panel"):
        ops.batched_qr(jnp.zeros((2, 3, 5)), impl="pallas_interpret")


@pytest.mark.parametrize("rows,n,block", [
    (1, 37, 8),               # partial final block
    (5, 1000, 128),
    (2, 57, 16),              # GQA-style odd length
    (4, 128, 128),            # exact block multiple
    pytest.param(3, 4096, 256, marks=_slow),
])
def test_qint8_pack_bit_identical_under_jit(rows, n, block):
    """Fused pack/unpack (interpret) == oracle == the legacy two-pass
    quantizer, BIT-exact — all three under jit (XLA's eager constant
    folding of the /127 scale division differs by 1 ulp from the jitted
    program; reducers always run jitted)."""
    from repro.comm.quant import dequantize_block, quantize_block
    x = jax.random.normal(jax.random.PRNGKey(rows * n), (rows, n))
    pack_k = jax.jit(lambda x: ops.qint8_pack(x, block,
                                              impl="pallas_interpret"))
    pack_r = jax.jit(lambda x: ref.qint8_pack_ref(x, block))
    w_k, w_r = pack_k(x), pack_r(x)
    nb = -(-n // block)
    assert w_k.dtype == jnp.int8 and w_k.shape == (rows, nb, block + 4)
    np.testing.assert_array_equal(np.asarray(w_k), np.asarray(w_r))
    un_k = jax.jit(lambda w: ops.qint8_unpack(w, n,
                                              impl="pallas_interpret"))
    un_r = jax.jit(lambda w: ref.qint8_unpack_ref(w, n))
    got = np.asarray(un_k(w_k))
    np.testing.assert_array_equal(got, np.asarray(un_r(w_r)))
    legacy = jax.jit(
        lambda x: dequantize_block(*quantize_block(x, block), n))
    np.testing.assert_array_equal(got, np.asarray(legacy(x)))
    # round-trip error bound the reducer's docstring promises
    scale = np.abs(np.asarray(x)).max() / 127.0
    assert np.abs(got - np.asarray(x)).max() <= scale * 0.5 + 1e-7


def test_qint8_pack_xla_impl_is_oracle():
    x = jax.random.normal(jax.random.PRNGKey(2), (3, 300))
    w = ops.qint8_pack(x, 64, impl="xla")
    np.testing.assert_array_equal(np.asarray(w),
                                  np.asarray(ref.qint8_pack_ref(x, 64)))
    np.testing.assert_array_equal(
        np.asarray(ops.qint8_unpack(w, 300, impl="xla")),
        np.asarray(ref.qint8_unpack_ref(w, 300)))


try:
    from hypothesis import given, settings as _csettings
    import hypothesis.strategies as _cst

    @_csettings(deadline=None, max_examples=10)
    @given(_cst.integers(1, 6), _cst.integers(2, 600),
           _cst.integers(1, 4))
    def test_property_batched_qr_projector(batch, a, r):
        """Hypothesis sweep: projector parity on random tall panels,
        arbitrary (non-pow2, near-square) dims."""
        r = min(r, a)
        p = jax.random.normal(jax.random.PRNGKey(batch * 977 + a),
                              (batch, a, r))
        q = ops.batched_qr(p, impl="pallas_interpret")
        np.testing.assert_allclose(
            np.asarray(_proj(q)), np.asarray(_proj(ref.batched_qr_ref(p))),
            atol=1e-4, rtol=1e-4)

    @_csettings(deadline=None, max_examples=10)
    @given(_cst.integers(1, 4), _cst.integers(1, 512),
           _cst.sampled_from([8, 32, 128]))
    def test_property_qint8_pack_roundtrip(rows, n, block):
        """Hypothesis sweep: fused wire buffer bit-equal to the oracle
        and round-trip error inside the absmax/254 per-element bound."""
        x = jax.random.normal(jax.random.PRNGKey(rows * 401 + n),
                              (rows, n))
        pack = jax.jit(lambda x: ops.qint8_pack(x, block,
                                                impl="pallas_interpret"))
        un = jax.jit(lambda w: ops.qint8_unpack(w, n,
                                                impl="pallas_interpret"))
        ref_pack = jax.jit(lambda x: ref.qint8_pack_ref(x, block))
        np.testing.assert_array_equal(np.asarray(pack(x)),
                                      np.asarray(ref_pack(x)))
        got = np.asarray(un(pack(x)))
        scale = np.abs(np.asarray(x)).max() / 127.0
        assert np.abs(got - np.asarray(x)).max() <= scale * 0.5 + 1e-7
except ImportError:                                   # pragma: no cover
    pass


# ------------------------- flash decode ------------------------------ #

def _paged_case(key, b, hq, hkv, d, page, maxp, dtype=jnp.float32,
                shuffle=True, max_len=None):
    """Random paged-attention inputs with a scattered block table."""
    ks = jax.random.split(key, 3)
    n_pages = 1 + b * maxp
    q = jax.random.normal(ks[0], (b, hq, d), dtype)
    k_pages = jax.random.normal(ks[1], (hkv, n_pages, page, d), dtype)
    v_pages = jax.random.normal(ks[2], (hkv, n_pages, page, d), dtype)
    ids = np.arange(1, n_pages)
    if shuffle:   # physical pages deliberately out of sequence order
        ids = np.random.default_rng(b * 7 + maxp).permutation(ids)
    tables = jnp.asarray(ids.reshape(b, maxp).astype(np.int32))
    hi = max_len or maxp * page
    lengths = jnp.asarray(
        np.random.default_rng(d).integers(1, hi + 1, size=b), jnp.int32)
    return q, k_pages, v_pages, tables, lengths


@pytest.mark.parametrize("b,hq,hkv,d,page,maxp", [
    (1, 1, 1, 64, 8, 2),
    (2, 4, 2, 64, 8, 3),
    pytest.param(3, 8, 8, 32, 16, 2, marks=_slow),     # MHA (g=1)
    pytest.param(1, 6, 2, 128, 8, 4, marks=_slow),
    (2, 8, 2, 32, 16, 2),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_matches_ref(b, hq, hkv, d, page, maxp, dtype):
    """Paged decode kernel (interpret) == XLA gather oracle, through a
    shuffled block table and ragged per-sequence lengths."""
    q, kp, vp, tbl, lens = _paged_case(jax.random.PRNGKey(0), b, hq, hkv,
                                       d, page, maxp, dtype)
    o_ref = ref.flash_decode_ref(q, kp, vp, tbl, lens)
    o = ops.flash_decode(q, kp, vp, tbl, lens, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("window", [1, 5, 16, 100])
def test_flash_decode_window(window):
    """Sliding-window masking incl. pages that short-circuit entirely
    out of the window."""
    q, kp, vp, tbl, lens = _paged_case(jax.random.PRNGKey(1), 2, 4, 2, 64,
                                       8, 4)
    o_ref = ref.flash_decode_ref(q, kp, vp, tbl, lens, window=window)
    o = ops.flash_decode(q, kp, vp, tbl, lens, window=window,
                         impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=2e-5,
                               rtol=2e-5)


def test_flash_decode_inactive_slots_zero():
    """lengths == 0 (inactive serving slots) must output exact zeros in
    both the oracle and the kernel — not NaNs from an empty softmax."""
    q, kp, vp, tbl, lens = _paged_case(jax.random.PRNGKey(2), 3, 4, 2, 32,
                                       8, 2)
    lens = lens.at[1].set(0)
    for impl in ("xla", "pallas_interpret"):
        o = np.asarray(ops.flash_decode(q, kp, vp, tbl, lens, impl=impl))
        assert np.isfinite(o).all()
        np.testing.assert_array_equal(o[1], np.zeros_like(o[1]))


def test_flash_decode_null_page_tail_ignored():
    """Unallocated block-table tail entries point at the null page 0;
    whatever garbage lives there must not leak into masked positions."""
    q, kp, vp, tbl, lens = _paged_case(jax.random.PRNGKey(3), 2, 4, 2, 32,
                                       8, 3, max_len=8)
    # sequences fit in page 0 of their table; null out the tail entries
    tbl0 = tbl.at[:, 1:].set(0)
    kp = kp.at[:, 0].set(1e6)            # poison the null page
    vp = vp.at[:, 0].set(-1e6)
    o_ref = ref.flash_decode_ref(q, kp, vp, tbl0, lens)
    o = ops.flash_decode(q, kp, vp, tbl0, lens, impl="pallas_interpret")
    assert np.isfinite(np.asarray(o)).all()
    assert np.abs(np.asarray(o)).max() < 1e3
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=2e-5,
                               rtol=2e-5)


def test_flash_decode_gather_pages_roundtrip():
    """gather_pages (the oracle's dense materialization) inverts the
    paged layout: writing token t of sequence b to page tbl[b, t//page]
    offset t%page reads back at dense position t."""
    b, hkv, d, page, maxp = 2, 2, 16, 4, 3
    n_pages = 1 + b * maxp
    pages = jnp.zeros((hkv, n_pages, page, d))
    tbl = jnp.asarray(np.arange(1, n_pages).reshape(b, maxp).astype(np.int32))
    val = jax.random.normal(jax.random.PRNGKey(4), (b, maxp * page, hkv, d))
    for t in range(maxp * page):
        pages = pages.at[:, tbl[:, t // page], t % page].set(
            val[:, t].transpose(1, 0, 2))
    dense = ref.gather_pages(pages, tbl)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(val))


try:
    from hypothesis import given, settings as hsettings
    import hypothesis.strategies as _hst

    @hsettings(deadline=None, max_examples=8)
    @given(_hst.integers(1, 3), _hst.sampled_from([1, 2, 4]),
           _hst.sampled_from([32, 64, 128]), _hst.sampled_from([8, 16]),
           _hst.integers(1, 3), _hst.integers(0, 12))
    def test_property_flash_decode(b, g, d, page, maxp, window):
        """Hypothesis sweep over head_dim / page size / pages-per-seq /
        GQA group / window against the oracle."""
        hkv = 2
        q, kp, vp, tbl, lens = _paged_case(
            jax.random.PRNGKey(b * 131 + d + page), b, hkv * g, hkv, d,
            page, maxp)
        o_ref = ref.flash_decode_ref(q, kp, vp, tbl, lens, window=window)
        o = ops.flash_decode(q, kp, vp, tbl, lens, window=window,
                             impl="pallas_interpret")
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                                   atol=2e-5, rtol=2e-5)
except ImportError:                                   # pragma: no cover
    pass


@pytest.mark.parametrize("b,s,h,d", [
    (1, 64, 1, 64),
    pytest.param(2, 128, 3, 64, marks=_slow),
    pytest.param(1, 192, 2, 128, marks=_slow),
    (2, 64, 4, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_wkv(b, s, h, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    r = (0.5 * jax.random.normal(ks[0], (b, s, h, d))).astype(dtype)
    k = (0.5 * jax.random.normal(ks[1], (b, s, h, d))).astype(dtype)
    v = (0.5 * jax.random.normal(ks[2], (b, s, h, d))).astype(dtype)
    w = (jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, d))) * 0.4
         + 0.55).astype(jnp.float32)
    u = 0.1 * jax.random.normal(ks[4], (h, d))
    s0 = jnp.zeros((b, h, d, d))
    y_ref, sT_ref = ref.rwkv6_wkv_ref(r, k, v, w, u, s0)
    y, sT = ops.rwkv6_wkv(r, k, v, w, u, s0, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=_tol(dtype) * 4, rtol=_tol(dtype) * 4)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sT_ref),
                               atol=1e-4, rtol=1e-4)


def test_rwkv6_wkv_chunking_and_state_resume():
    """Chunked kernel == oracle, and resuming from the midpoint state equals
    one continuous run (decode-path correctness)."""
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    b, s, h, d = 1, 128, 2, 64
    r = 0.5 * jax.random.normal(ks[0], (b, s, h, d))
    k = 0.5 * jax.random.normal(ks[1], (b, s, h, d))
    v = 0.5 * jax.random.normal(ks[2], (b, s, h, d))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, d))) * 0.4 + 0.55
    u = 0.1 * jax.random.normal(ks[4], (h, d))
    s0 = jnp.zeros((b, h, d, d))
    y_all, sT_all = ref.rwkv6_wkv_ref(r, k, v, w, u, s0)
    # two halves via the kernel, threading the state
    y1, s_mid = ops.rwkv6_wkv(r[:, :64], k[:, :64], v[:, :64], w[:, :64],
                              u, s0, impl="pallas_interpret", block_t=32)
    y2, sT = ops.rwkv6_wkv(r[:, 64:], k[:, 64:], v[:, 64:], w[:, 64:],
                           u, s_mid, impl="pallas_interpret", block_t=32)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_all), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sT_all),
                               atol=1e-4, rtol=1e-4)


def test_wkv_kernel_matches_model_decode_semantics():
    """Kernel recurrence equals the per-token decode formula in rwkv6.py."""
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    b, h, d = 2, 2, 64
    s = 8
    r = 0.5 * jax.random.normal(ks[0], (b, s, h, d))
    k = 0.5 * jax.random.normal(ks[1], (b, s, h, d))
    v = 0.5 * jax.random.normal(ks[2], (b, s, h, d))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, d))) * 0.4 + 0.55
    u = 0.1 * jax.random.normal(ks[4], (h, d))
    S = jnp.zeros((b, h, d, d))
    ys = []
    for t in range(s):
        kv = k[:, t][..., :, None] * v[:, t][..., None, :]
        y = jnp.einsum("bhj,bhji->bhi", r[:, t], S + u[None, :, :, None] * kv)
        S = w[:, t][..., :, None] * S + kv
        ys.append(y)
    y_manual = jnp.stack(ys, 1)
    y_k, S_k = ops.rwkv6_wkv(r, k, v, w, u, jnp.zeros((b, h, d, d)),
                             impl="pallas_interpret", block_t=8)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_manual),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(S_k), np.asarray(S), atol=1e-4,
                               rtol=1e-4)
