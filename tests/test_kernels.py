"""Pallas kernel validation: shape/dtype sweeps, interpret=True vs the
pure-jnp oracles in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _tol(dtype):
    return ATOL[jnp.bfloat16 if dtype == jnp.bfloat16 else jnp.float32]


@pytest.mark.parametrize("b,s,hq,hkv,d", [
    (1, 128, 1, 1, 64),
    (2, 256, 4, 2, 64),
    (1, 256, 8, 8, 128),
    (2, 128, 6, 2, 32),
    (1, 512, 4, 1, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal(b, s, hq, hkv, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, hq, d), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, d), dtype)
    o_ref = ref.flash_attention_ref(q, k, v, causal=True)
    o = ops.flash_attention(q, k, v, causal=True, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("window", [32, 100, 256])
def test_flash_attention_window(window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    b, s, hq, hkv, d = 2, 256, 4, 2, 64
    q = jax.random.normal(ks[0], (b, s, hq, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    o_ref = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    o = ops.flash_attention(q, k, v, causal=True, window=window,
                            impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=2e-5,
                               rtol=2e-5)


@pytest.mark.parametrize("block_q,block_k", [(64, 64), (128, 64), (64, 128)])
def test_flash_attention_blocks(block_q, block_k):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    b, s, h, d = 1, 256, 2, 64
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    o_ref = ref.flash_attention_ref(q, k, v, causal=True)
    o = ops.flash_attention(q, k, v, causal=True, impl="pallas_interpret",
                            block_q=block_q, block_k=block_k)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=2e-5,
                               rtol=2e-5)


@pytest.mark.parametrize("b,s,h,d", [
    (1, 64, 1, 64), (2, 128, 3, 64), (1, 192, 2, 128), (2, 64, 4, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_wkv(b, s, h, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    r = (0.5 * jax.random.normal(ks[0], (b, s, h, d))).astype(dtype)
    k = (0.5 * jax.random.normal(ks[1], (b, s, h, d))).astype(dtype)
    v = (0.5 * jax.random.normal(ks[2], (b, s, h, d))).astype(dtype)
    w = (jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, d))) * 0.4
         + 0.55).astype(jnp.float32)
    u = 0.1 * jax.random.normal(ks[4], (h, d))
    s0 = jnp.zeros((b, h, d, d))
    y_ref, sT_ref = ref.rwkv6_wkv_ref(r, k, v, w, u, s0)
    y, sT = ops.rwkv6_wkv(r, k, v, w, u, s0, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=_tol(dtype) * 4, rtol=_tol(dtype) * 4)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sT_ref),
                               atol=1e-4, rtol=1e-4)


def test_rwkv6_wkv_chunking_and_state_resume():
    """Chunked kernel == oracle, and resuming from the midpoint state equals
    one continuous run (decode-path correctness)."""
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    b, s, h, d = 1, 128, 2, 64
    r = 0.5 * jax.random.normal(ks[0], (b, s, h, d))
    k = 0.5 * jax.random.normal(ks[1], (b, s, h, d))
    v = 0.5 * jax.random.normal(ks[2], (b, s, h, d))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, d))) * 0.4 + 0.55
    u = 0.1 * jax.random.normal(ks[4], (h, d))
    s0 = jnp.zeros((b, h, d, d))
    y_all, sT_all = ref.rwkv6_wkv_ref(r, k, v, w, u, s0)
    # two halves via the kernel, threading the state
    y1, s_mid = ops.rwkv6_wkv(r[:, :64], k[:, :64], v[:, :64], w[:, :64],
                              u, s0, impl="pallas_interpret", block_t=32)
    y2, sT = ops.rwkv6_wkv(r[:, 64:], k[:, 64:], v[:, 64:], w[:, 64:],
                           u, s_mid, impl="pallas_interpret", block_t=32)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_all), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sT_all),
                               atol=1e-4, rtol=1e-4)


def test_wkv_kernel_matches_model_decode_semantics():
    """Kernel recurrence equals the per-token decode formula in rwkv6.py."""
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    b, h, d = 2, 2, 64
    s = 8
    r = 0.5 * jax.random.normal(ks[0], (b, s, h, d))
    k = 0.5 * jax.random.normal(ks[1], (b, s, h, d))
    v = 0.5 * jax.random.normal(ks[2], (b, s, h, d))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, d))) * 0.4 + 0.55
    u = 0.1 * jax.random.normal(ks[4], (h, d))
    S = jnp.zeros((b, h, d, d))
    ys = []
    for t in range(s):
        kv = k[:, t][..., :, None] * v[:, t][..., None, :]
        y = jnp.einsum("bhj,bhji->bhi", r[:, t], S + u[None, :, :, None] * kv)
        S = w[:, t][..., :, None] * S + kv
        ys.append(y)
    y_manual = jnp.stack(ys, 1)
    y_k, S_k = ops.rwkv6_wkv(r, k, v, w, u, jnp.zeros((b, h, d, d)),
                             impl="pallas_interpret", block_t=8)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_manual),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(S_k), np.asarray(S), atol=1e-4,
                               rtol=1e-4)
