"""Hier-AVG algorithm semantics: the paper's special-case equivalences and
reduction invariants, on a real learnable task (fixture ``cls_task``)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import HierAvgParams
from repro.core import (HierTopology, Simulator, global_average, init_state,
                        local_average, make_hier_round, make_hier_step,
                        make_kavg_round, make_sync_sgd_round, stack_like,
                        unstack_first)
from repro.core.hier_avg import make_sgd_step
from repro.optim import sgd


def _leaves_equal_across_learners(params, topo):
    for leaf in jax.tree.leaves(params):
        flat = leaf.reshape((topo.n_learners,) + leaf.shape[3:])
        if not bool(jnp.allclose(flat, flat[0:1], atol=1e-6)):
            return False
    return True


@pytest.mark.slow
def test_k1_eq_k2_equals_kavg(cls_task):
    """Hier-AVG with K1 == K2 reproduces K-AVG exactly (same data)."""
    topo = HierTopology(1, 2, 4)
    h = HierAvgParams(k1=6, k2=6)
    kw = dict(topo=topo, hier=h, optimizer=sgd(0.05), seed=5,
              eval_batch=cls_task["eval_batch"], per_learner_batch=8)
    r1 = Simulator(cls_task["loss_fn"], cls_task["init_fn"],
                   cls_task["sample"], algo="hier", **kw).run(3)
    r2 = Simulator(cls_task["loss_fn"], cls_task["init_fn"],
                   cls_task["sample"], algo="kavg", **kw).run(3)
    np.testing.assert_allclose(r1.eval_losses, r2.eval_losses, rtol=1e-5)


@pytest.mark.slow
def test_s1_local_averaging_is_identity(cls_task):
    """S == 1: local reductions are no-ops, so hier == kavg."""
    topo = HierTopology(1, 8, 1)
    h = HierAvgParams(k1=2, k2=6)
    kw = dict(topo=topo, hier=h, optimizer=sgd(0.05), seed=6,
              eval_batch=cls_task["eval_batch"], per_learner_batch=8)
    r1 = Simulator(cls_task["loss_fn"], cls_task["init_fn"],
                   cls_task["sample"], algo="hier", **kw).run(3)
    r2 = Simulator(cls_task["loss_fn"], cls_task["init_fn"],
                   cls_task["sample"], algo="kavg",
                   **dict(kw, hier=HierAvgParams(k1=6, k2=6))).run(3)
    np.testing.assert_allclose(r1.eval_losses, r2.eval_losses, rtol=1e-5)


def test_sync_sgd_is_k2_1(cls_task):
    topo = HierTopology(1, 2, 2)
    kw = dict(topo=topo, optimizer=sgd(0.05), seed=7,
              eval_batch=cls_task["eval_batch"], per_learner_batch=8)
    r1 = Simulator(cls_task["loss_fn"], cls_task["init_fn"],
                   cls_task["sample"], algo="hier",
                   hier=HierAvgParams(1, 1), **kw).run(3)
    r2 = Simulator(cls_task["loss_fn"], cls_task["init_fn"],
                   cls_task["sample"], algo="sync",
                   hier=HierAvgParams(1, 1), **kw).run(3)
    np.testing.assert_allclose(r1.eval_losses, r2.eval_losses, rtol=1e-5)


def test_round_ends_with_consensus(cls_task):
    """After the global reduction all P learners hold identical params."""
    topo = HierTopology(1, 2, 4)
    h = HierAvgParams(k1=2, k2=4)
    opt = sgd(0.05)
    round_fn = jax.jit(make_hier_round(cls_task["loss_fn"], opt, h))
    state = init_state(topo, cls_task["init_fn"], opt,
                       jax.random.PRNGKey(0))
    batch = cls_task["sample"](jax.random.PRNGKey(1),
                               h.k2 * topo.n_learners * 8)
    shaped = jax.tree.map(
        lambda x: x.reshape((h.beta, h.k1) + topo.shape + (8,)
                            + x.shape[1:]), batch)
    state, _ = round_fn(state, shaped)
    assert _leaves_equal_across_learners(state.params, topo)


def test_divergence_between_reductions(cls_task):
    """Before any reduction, learners with different data have different
    params (they really train independently)."""
    topo = HierTopology(1, 2, 2)
    opt = sgd(0.05)
    step = jax.jit(make_sgd_step(cls_task["loss_fn"], opt))
    state = init_state(topo, cls_task["init_fn"], opt, jax.random.PRNGKey(0))
    batch = cls_task["sample"](jax.random.PRNGKey(2), topo.n_learners * 8)
    shaped = jax.tree.map(
        lambda x: x.reshape(topo.shape + (8,) + x.shape[1:]), batch)
    state, _ = step(state, shaped)
    assert not _leaves_equal_across_learners(state.params, topo)


def test_local_average_cluster_scope():
    """Local reduction averages within clusters only; clusters differ."""
    topo = HierTopology(1, 2, 2)
    base = {"w": jnp.arange(4.0).reshape(1, 2, 2)}
    out = local_average(base)
    np.testing.assert_allclose(np.asarray(out["w"][0, 0]), [0.5, 0.5])
    np.testing.assert_allclose(np.asarray(out["w"][0, 1]), [2.5, 2.5])
    g = global_average(base)
    np.testing.assert_allclose(np.asarray(g["w"]), 1.5 * np.ones((1, 2, 2)))


def test_step_api_matches_round_api(cls_task):
    """make_hier_step applied K2 times == make_hier_round once."""
    topo = HierTopology(1, 2, 2)
    h = HierAvgParams(k1=2, k2=4)
    opt = sgd(0.05)
    key = jax.random.PRNGKey(3)
    state_a = init_state(topo, cls_task["init_fn"], opt, key)
    state_b = init_state(topo, cls_task["init_fn"], opt, key)
    n = h.k2 * topo.n_learners * 4
    batch = cls_task["sample"](jax.random.PRNGKey(4), n)
    shaped = jax.tree.map(
        lambda x: x.reshape((h.beta, h.k1) + topo.shape + (4,)
                            + x.shape[1:]), batch)
    round_fn = jax.jit(make_hier_round(cls_task["loss_fn"], opt, h))
    state_a, _ = round_fn(state_a, shaped)

    step_fn = jax.jit(make_hier_step(cls_task["loss_fn"], opt, h))
    for b in range(h.beta):
        for k in range(h.k1):
            mb = jax.tree.map(lambda x: x[b, k], shaped)
            state_b, _ = step_fn(state_b, mb)
    for la, lb in zip(jax.tree.leaves(state_a.params),
                      jax.tree.leaves(state_b.params)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("microbatch", [2, 4])
def test_microbatch_grad_accumulation_equivalence(cls_task, microbatch):
    """microbatch=2/4 gives the same update as microbatch=1 (linear loss in
    batch -> identical mean gradient) to fp32 tolerance."""
    topo = HierTopology(1, 1, 2)
    opt = sgd(0.05)
    key = jax.random.PRNGKey(5)
    s1 = init_state(topo, cls_task["init_fn"], opt, key)
    s2 = init_state(topo, cls_task["init_fn"], opt, key)
    batch = cls_task["sample"](jax.random.PRNGKey(6), topo.n_learners * 8)
    shaped = jax.tree.map(
        lambda x: x.reshape(topo.shape + (8,) + x.shape[1:]), batch)
    st1 = jax.jit(make_sgd_step(cls_task["loss_fn"], opt, microbatch=1))
    st2 = jax.jit(make_sgd_step(cls_task["loss_fn"], opt,
                                microbatch=microbatch))
    s1, _ = st1(s1, shaped)
    s2, _ = st2(s2, shaped)
    for la, lb in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   atol=1e-5, rtol=1e-5)


def test_hier_avg_converges(cls_task):
    topo = HierTopology(1, 2, 4)
    sim = Simulator(cls_task["loss_fn"], cls_task["init_fn"],
                    cls_task["sample"], topo=topo,
                    hier=HierAvgParams(k1=2, k2=8), optimizer=sgd(0.1),
                    eval_batch=cls_task["eval_batch"], seed=1,
                    per_learner_batch=16)
    r = sim.run(10)
    assert r.eval_losses[-1] < 0.7 * r.eval_losses[0]
    assert r.eval_accs[-1] > 0.6


def test_bf16_averaging_converges(cls_task):
    """Beyond-paper: reductions with a bf16 payload (the "cast" reducer,
    ex-``avg_dtype``; half all-reduce payload) track fp32 averaging closely
    on a real task."""
    from repro.core.hier_avg import init_state
    topo = HierTopology(1, 2, 4)
    h = HierAvgParams(k1=2, k2=4)
    opt = sgd(0.05)
    key = jax.random.PRNGKey(9)
    batch = cls_task["sample"](jax.random.PRNGKey(10),
                               h.k2 * topo.n_learners * 8)
    shaped = jax.tree.map(
        lambda x: x.reshape((h.beta, h.k1) + topo.shape + (8,)
                            + x.shape[1:]), batch)
    r32 = jax.jit(make_hier_round(cls_task["loss_fn"], opt, h))
    r16 = jax.jit(make_hier_round(cls_task["loss_fn"], opt, h,
                                  reducer="cast:bfloat16"))
    sa = init_state(topo, cls_task["init_fn"], opt, key)
    sb = init_state(topo, cls_task["init_fn"], opt, key)
    for _ in range(3):
        sa, ma = r32(sa, shaped)
        sb, mb = r16(sb, shaped)
    assert abs(float(ma["loss"]) - float(mb["loss"])) < 0.02


@pytest.mark.slow
def test_three_level_pod_sweep_within_thm32_bars(cls_task):
    """3-level convergence sweep (pod level on/off) on the bench grid:
    on a 2-pod topology the plan with the pod level enabled must track
    the 2-level plan and both must converge — the ordering Thm 3.2
    predicts, since the pod level only *adds* intermediate averaging
    (``third_term_poly`` falls as the averaging set grows), so its bound
    bar sits at or below the 2-level one.  The fsdp=2 variant of this
    sweep runs on the forced-host-device mesh in tests/test_sharded.py
    (device count must be forced before jax initializes)."""
    from repro.core.theory import thm32_bound, thm32_condition
    topo = HierTopology(2, 2, 2)
    losses = {}
    for name, plan in [("off", "local@2/global@8"),
                       ("on", "local@2/pod@4/global@8")]:
        sim = Simulator(cls_task["loss_fn"], cls_task["init_fn"],
                        cls_task["sample"], topo=topo,
                        hier=HierAvgParams(k1=2, k2=8, plan=plan),
                        optimizer=sgd(0.05), seed=3,
                        per_learner_batch=16,
                        eval_batch=cls_task["eval_batch"])
        losses[name] = sim.run(4).eval_losses
    # the theory bars: nominal constants inside the (3.5) regime; the
    # pod level's closest 2-level surrogate averages S_eff=4 learners
    # every K1_eff=4 steps
    F1, L, M, gamma, P, B, N = 2.0, 1.0, 1.0, 0.05, 8, 16, 4
    assert thm32_condition(L, gamma, K2=8)
    bar_on = thm32_bound(F1, L, M, gamma, K1=4, K2=8, S=4, P=P, B=B, N=N)
    bar_off = thm32_bound(F1, L, M, gamma, K1=2, K2=8, S=2, P=P, B=B,
                          N=N)
    assert bar_on <= bar_off
    # and the measured sweep respects them: both converge, pod-on never
    # meaningfully above pod-off
    for name in ("on", "off"):
        assert losses[name][-1] < 0.65 * losses[name][0], (name, losses)
    assert losses["on"][-1] <= losses["off"][-1] + 0.01, losses


def test_adaptive_k2_controller():
    """AdaptiveK2: large K2 far from optimum, shrinks toward K1 as the loss
    falls, always keeps K1 | K2 (paper §3.3 heuristic)."""
    from repro.core import AdaptiveK2
    ctl = AdaptiveK2(k1=4, k2_max=64)
    assert ctl.k2_for(10.0) == 64          # initial loss -> max interval
    k_half = ctl.k2_for(5.0)
    k_tenth = ctl.k2_for(0.15)
    assert 4 <= k_tenth <= k_half <= 64
    assert k_half % 4 == 0 and k_tenth % 4 == 0
    h = ctl.params_for(0.15)
    assert h.k1 == 4 and h.k2 == k_tenth
