"""ReductionPlan (core/plan.py): spec grammar, nesting validation, legacy
(k1, k2) bit-identity, N-level round/step semantics, the AdaptivePlan
ladder, and the PowerSGD low-rank reducer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import LowRankState, PowerSGDReducer, get_reducer, reduce_with
from repro.configs.base import HierAvgParams
from repro.core import (AdaptivePlan, HierTopology, ReductionPlan, Simulator,
                        global_average, init_state, make_hier_round,
                        make_hier_step, resolve_plan)
from repro.core.theory import (CommModel, param_template,
                               plan_comm_per_round)
from repro.optim import sgd

PLAN3 = "local@4:cast:bfloat16/pod@8/global@16:topk:0.05"


# ------------------------------ spec grammar -------------------------- #

def test_parse_roundtrip_and_defaults():
    p = ReductionPlan.parse(PLAN3)
    assert [l.name for l in p.levels] == ["local", "pod", "global"]
    assert [l.period for l in p.levels] == [4, 8, 16]
    assert [l.axes for l in p.levels] == [(2,), (1, 2), (0, 1, 2)]
    # unspecified reducer defaults to mean; describe() round-trips
    assert p.levels[1].reducer.describe() == "mean"
    assert ReductionPlan.parse(p.describe()).describe() == p.describe()
    assert p.total_period == 16
    assert p.batch_dims == (2, 2, 4)
    assert dict(p.counts_per_round()) == {"local": 2, "pod": 1, "global": 1}


def test_from_k1_k2_matches_legacy_layout():
    p = ReductionPlan.from_k1_k2(4, 8, "topk:0.1")
    assert p.batch_dims == (2, 4)           # (beta, K1)
    assert p.describe() == "local@4:topk:0.1/global@8:topk:0.1"


@pytest.mark.parametrize("bad", [
    "local@4",                       # single level is fine -> see below
    "pod@4/local@8",                 # axes shrink outward
    "local@3/global@8",              # period does not divide
    "local@8/global@4",              # periods decrease
    "local@4/local@8",               # duplicate name
    "rack@4/global@8",               # unknown level name
    "local@x/global@8",              # bad period
    "local@4/global@8:gzip",         # unknown reducer
    "local4/global@8",               # missing @
])
def test_invalid_specs_raise(bad):
    if bad == "local@4":             # a 1-level plan IS valid (K-AVG)
        p = ReductionPlan.parse(bad)
        assert p.batch_dims == (4,)
        return
    with pytest.raises(ValueError):
        ReductionPlan.parse(bad)


def test_hier_params_plan_backfills_k1_k2():
    h = HierAvgParams(plan=PLAN3)
    assert (h.k1, h.k2, h.steps_per_round) == (4, 16, 16)
    assert h.batch_dims == (2, 2, 4)
    with pytest.raises(ValueError):
        HierAvgParams(plan="local@8/global@4")
    # legacy params keep their validation
    with pytest.raises(ValueError):
        HierAvgParams(k1=3, k2=8)


def test_resolve_plan_precedence():
    from repro.comm import Pipelined
    h = HierAvgParams(k1=2, k2=4, reducer="qint8:128")
    # compressed reducers are bucketed by default (comm/bucket.py), on
    # the pipelined (overlapped) schedule since HierAvgParams.overlap
    # defaults on.  Auto-chosen engines describe as ':bucketed' (the
    # engine is the knob's choice, not part of the spec), so the spec
    # round-trips under any overlap setting; only an explicit
    # ':pipelined' pin prints as one.
    p = resolve_plan(h)
    assert all(isinstance(l.reducer, Pipelined) for l in p.levels)
    assert p.describe() == \
        "local@2:qint8:128:bucketed/global@4:qint8:128:bucketed"
    # overlap=False pins the serial bucket schedule (PR 3 behavior)
    hs = HierAvgParams(k1=2, k2=4, reducer="qint8:128", overlap=False)
    ps = resolve_plan(hs)
    assert not any(isinstance(l.reducer, Pipelined) for l in ps.levels)
    assert ps.describe() == \
        "local@2:qint8:128:bucketed/global@4:qint8:128:bucketed"
    # ... as does the per-level ":serial" spec modifier
    hser = HierAvgParams(k1=2, k2=4, reducer="qint8:128:serial")
    assert resolve_plan(hser).describe() == \
        "local@2:qint8:128:serial:bucketed/global@4:qint8:128:serial:bucketed"
    # ... while an explicit ":pipelined" wins over overlap=False
    hpipe = HierAvgParams(k1=2, k2=4, reducer="qint8:128:pipelined",
                          overlap=False)
    assert resolve_plan(hpipe).describe() == \
        "local@2:qint8:128:pipelined/global@4:qint8:128:pipelined"
    # bucket_bytes=0 pins the legacy per-leaf pipeline
    h0 = HierAvgParams(k1=2, k2=4, reducer="qint8:128", bucket_bytes=0)
    assert resolve_plan(h0).describe() == \
        "local@2:qint8:128/global@4:qint8:128"
    # ... as does the ":perleaf" spec modifier, per level
    hp = HierAvgParams(k1=2, k2=4, reducer="qint8:128:perleaf")
    assert resolve_plan(hp).describe() == \
        "local@2:qint8:128:perleaf/global@4:qint8:128:perleaf"
    # the dense mean is never auto-bucketed (default path unchanged)
    assert resolve_plan(HierAvgParams(k1=2, k2=4)).describe() == \
        "local@2:mean/global@4:mean"
    # explicit reducer overrides every level (legacy single-reducer knob),
    # then bucketing applies on top (pipelined engine, auto -> ':bucketed')
    p2 = resolve_plan(h, reducer="cast:bfloat16")
    assert all(isinstance(l.reducer, Pipelined) for l in p2.levels)
    assert all(l.reducer.describe() == "cast:bfloat16:bucketed"
               for l in p2.levels)
    # explicit plan wins over the config
    p3 = resolve_plan(h, plan="local@1/pod@2/global@4")
    assert len(p3.levels) == 3


# --------------------- legacy <-> 2-level plan bit-identity ----------- #

@pytest.mark.parametrize("reducer", [
    "mean", "cast:bfloat16",
    pytest.param("topk:0.25", marks=pytest.mark.slow)])
def test_legacy_params_bit_identical_to_2level_plan(cls_task, reducer):
    """HierAvgParams(k1, k2, reducer) trajectories are bit-identical to the
    equivalent explicit 2-level plan spec."""
    topo = HierTopology(1, 2, 2)
    kw = dict(topo=topo, optimizer=sgd(0.05), seed=3,
              eval_batch=cls_task["eval_batch"], per_learner_batch=8)
    legacy = Simulator(cls_task["loss_fn"], cls_task["init_fn"],
                       cls_task["sample"],
                       hier=HierAvgParams(k1=4, k2=8, reducer=reducer),
                       **kw).run(3)
    spec = f"local@4:{reducer}/global@8:{reducer}"
    planned = Simulator(cls_task["loss_fn"], cls_task["init_fn"],
                        cls_task["sample"],
                        hier=HierAvgParams(plan=spec), **kw).run(3)
    np.testing.assert_array_equal(legacy.losses, planned.losses)
    np.testing.assert_array_equal(legacy.eval_losses, planned.eval_losses)
    for a, b in zip(jax.tree.leaves(legacy.state.params),
                    jax.tree.leaves(planned.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------- N-level semantics ------------------------ #

def test_all_period_1_plan_equals_sync_sgd(cls_task):
    """A 3-level plan with period=1 everywhere averages everyone every
    step == synchronous parallel SGD (means of nested means)."""
    topo = HierTopology(2, 2, 2)
    kw = dict(topo=topo, optimizer=sgd(0.05), seed=5,
              eval_batch=cls_task["eval_batch"], per_learner_batch=8)
    r1 = Simulator(cls_task["loss_fn"], cls_task["init_fn"],
                   cls_task["sample"], algo="hier",
                   hier=HierAvgParams(plan="local@1/pod@1/global@1"),
                   **kw).run(4)
    r2 = Simulator(cls_task["loss_fn"], cls_task["init_fn"],
                   cls_task["sample"], algo="sync",
                   hier=HierAvgParams(k1=1, k2=1), **kw).run(4)
    np.testing.assert_allclose(r1.eval_losses, r2.eval_losses, rtol=1e-5)


def test_step_api_matches_round_api_3level(cls_task):
    """make_hier_step applied total_period times == make_hier_round once,
    exercising the per-level counter masks of all three levels."""
    topo = HierTopology(2, 1, 2)
    h = HierAvgParams(plan="local@2/pod@4/global@8")
    opt = sgd(0.05)
    key = jax.random.PRNGKey(3)
    state_a = init_state(topo, cls_task["init_fn"], opt, key)
    state_b = init_state(topo, cls_task["init_fn"], opt, key)
    n = h.steps_per_round * topo.n_learners * 4
    batch = cls_task["sample"](jax.random.PRNGKey(4), n)
    shaped = jax.tree.map(
        lambda x: x.reshape(h.batch_dims + topo.shape + (4,)
                            + x.shape[1:]), batch)
    round_fn = jax.jit(make_hier_round(cls_task["loss_fn"], opt, h))
    state_a, _ = round_fn(state_a, shaped)

    step_fn = jax.jit(make_hier_step(cls_task["loss_fn"], opt, h))
    flat = jax.tree.map(
        lambda x: x.reshape((h.steps_per_round,) + topo.shape + (4,)
                            + x.shape[len(h.batch_dims) + 4:]), shaped)
    for t in range(h.steps_per_round):
        mb = jax.tree.map(lambda x: x[t], flat)
        state_b, _ = step_fn(state_b, mb)
    for la, lb in zip(jax.tree.leaves(state_a.params),
                      jax.tree.leaves(state_b.params)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   atol=1e-5, rtol=1e-5)


def test_3level_mixed_reducer_plan_trains(cls_task):
    """The acceptance plan (cast local / mean pod / topk global) trains
    end-to-end in the Simulator."""
    topo = HierTopology(2, 2, 2)
    sim = Simulator(cls_task["loss_fn"], cls_task["init_fn"],
                    cls_task["sample"], topo=topo,
                    hier=HierAvgParams(plan=PLAN3), optimizer=sgd(0.1),
                    eval_batch=cls_task["eval_batch"], seed=1,
                    per_learner_batch=8)
    r = sim.run(5)
    assert np.isfinite(r.eval_losses).all()
    assert r.eval_losses[-1] < 0.8 * r.eval_losses[0]
    # per-level payload accounting: topk global is the smallest
    per_level = sim.payload_bytes_per_level()
    assert set(per_level) == {"local", "pod", "global"}
    assert per_level["global"] < per_level["local"] <= per_level["pod"]


def test_pod_level_consensus_scope(cls_task):
    """After a pod-level reduction learners agree within a pod but not
    across pods; after the global one everyone agrees."""
    topo = HierTopology(2, 2, 2)
    h = HierAvgParams(plan="local@1/pod@2/global@4")
    opt = sgd(0.05)
    step_fn = jax.jit(make_hier_step(cls_task["loss_fn"], opt, h))
    state = init_state(topo, cls_task["init_fn"], opt, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    for t in range(1, h.steps_per_round + 1):
        key, kb = jax.random.split(key)
        batch = cls_task["sample"](kb, topo.n_learners * 8)
        shaped = jax.tree.map(
            lambda x: x.reshape(topo.shape + (8,) + x.shape[1:]), batch)
        state, _ = step_fn(state, shaped)
        leaf = jax.tree.leaves(state.params)[0]
        per_pod = leaf.reshape((2, 4) + leaf.shape[3:])
        pod_consensus = all(
            bool(jnp.allclose(per_pod[p], per_pod[p, 0:1], atol=1e-6))
            for p in range(2))
        cross_pod = bool(jnp.allclose(per_pod[0], per_pod[1], atol=1e-6))
        if t == 2:          # pod fires (t%2==0, t%4!=0)
            assert pod_consensus and not cross_pod
        if t == 4:          # global fires
            assert pod_consensus and cross_pod


# ------------------------------ schedules ----------------------------- #

def test_adaptive_plan_ladder():
    """AdaptivePlan scales the outermost period only: wide while the loss
    is high, down to the next-inner period near convergence, inner levels
    untouched."""
    ctl = AdaptivePlan("local@2:cast:bfloat16/pod@4/global@32:topk:0.1")
    p0 = ctl.plan_for(10.0)                 # initial loss -> max interval
    assert p0.total_period == 32
    p_half = ctl.plan_for(5.0)
    p_tiny = ctl.plan_for(0.05)
    assert 4 <= p_tiny.total_period <= p_half.total_period <= 32
    for p in (p0, p_half, p_tiny):
        # inner periods and per-level reducers never move
        assert [l.period for l in p.levels[:-1]] == [2, 4]
        assert p.levels[0].reducer.describe() == "cast:bfloat16"
        assert p.levels[-1].reducer.describe() == "topk:0.1"
        assert p.total_period % 4 == 0      # nesting kept
    h = ctl.params_for(0.05)
    assert h.plan == p_tiny.describe()
    assert h.k2 == p_tiny.total_period


def test_adaptive_plan_non_power_of_two_ratios():
    """Ladder bounds that are not powers of two of each other: the outer
    period stays in [outer_min, outer_max], a multiple of the next-inner
    period, and monotone in the loss."""
    ctl = AdaptivePlan("local@3/global@24")          # ratio 8, inner 3
    assert ctl.outer_for(9.0) == 24
    outs = [ctl.outer_for(9.0 * 2.0 ** -k) for k in range(6)]
    assert outs[0] == 24 and outs[-1] == 3
    for a, b in zip(outs, outs[1:]):
        assert b <= a and b % 3 == 0 and 3 <= b <= 24
    ctl2 = AdaptivePlan("local@5/global@20", outer_min=10)  # ratio 2
    assert ctl2.outer_for(1.0) == 20
    assert ctl2.outer_for(1e-6) == 10                 # floored at min
    with pytest.raises(ValueError):                   # min below inner
        AdaptivePlan("local@4/global@16", outer_min=2)
    with pytest.raises(ValueError):                   # min not multiple
        AdaptivePlan("local@4/global@16", outer_min=6)


def test_adaptive_plan_outer_min_equals_outer_max():
    """A ladder with no room: every loss maps to the one admissible
    period (and nothing divides by zero on the degenerate span)."""
    ctl = AdaptivePlan("local@4/global@4")
    for loss in (100.0, 1.0, 1e-8):
        assert ctl.outer_for(loss) == 4
    ctl2 = AdaptivePlan("local@4/global@32", outer_min=32)
    for loss in (100.0, 1.0, 1e-8):
        assert ctl2.outer_for(loss) == 32


def test_adaptive_plan_loss_anchor_carry_and_reset():
    """_loss0 anchors on the FIRST observed loss and carries across
    params_for calls; reset() re-anchors for a fresh run."""
    ctl = AdaptivePlan("local@4/global@64")
    assert ctl.params_for(8.0).k2 == 64              # anchor = 8.0
    assert ctl.params_for(1.0).k2 < 64               # 1/8 of anchor
    # a later HIGHER loss does not move the anchor (frac capped at 1)
    assert ctl.params_for(80.0).k2 == 64
    assert ctl._loss0 == 8.0
    ctl.reset()
    assert ctl._loss0 is None
    # the same small loss is now the anchor -> wide interval again
    assert ctl.params_for(1.0).k2 == 64
    # AdaptiveK2 delegates
    from repro.core import AdaptiveK2
    k2ctl = AdaptiveK2(k1=4, k2_max=64)
    assert k2ctl.k2_for(4.0) == 64 and k2ctl.k2_for(0.05) < 64
    k2ctl.reset()
    assert k2ctl.k2_for(0.05) == 64


def test_adaptive_params_for_preserves_base_fields():
    """params_for(loss, base=...) keeps the caller's non-schedule fields
    (bucket_bytes / overlap / reducer) via dataclasses.replace instead
    of silently resetting them to defaults."""
    base = HierAvgParams(k1=4, k2=64, reducer="qint8:128",
                         bucket_bytes=512 << 10, overlap=False)
    ctl = AdaptivePlan("local@4:topk:0.1/global@64:topk:0.1")
    h = ctl.params_for(5.0, base=base)
    assert (h.bucket_bytes, h.overlap) == (512 << 10, False)
    assert h.plan is not None and h.k2 == 64 and h.k1 == 4
    # the adapted plan's reducers win over base.reducer (plan is set)
    assert "topk:0.1" in h.resolved_plan.levels[-1].reducer.describe()
    from repro.core import AdaptiveK2
    k2ctl = AdaptiveK2(k1=4, k2_max=32)
    base2 = HierAvgParams(plan="local@2/global@8", bucket_bytes=0,
                          overlap=False)
    h2 = k2ctl.params_for(3.0, base=base2)
    # plan cleared so the adapted (k1, k2) actually take effect
    assert h2.plan is None and (h2.k1, h2.k2) == (4, 32)
    assert (h2.bucket_bytes, h2.overlap) == (0, False)
    # legacy no-base path unchanged
    assert ctl.params_for(5.0).bucket_bytes != 0


def test_adaptive_k2_delegates_to_plan_ladder():
    """The legacy AdaptiveK2 API is the 2-level specialization."""
    from repro.core import AdaptiveK2
    ctl = AdaptiveK2(k1=4, k2_max=64)
    ctl2 = AdaptivePlan("local@4/global@64")
    assert ctl.k2_for(8.0) == ctl2.outer_for(8.0) == 64
    assert ctl.k2_for(0.1) == ctl2.outer_for(0.1)
    # legacy tolerance: non-divisible bounds are floored, not rejected
    loose = AdaptiveK2(k1=4, k2_max=10, k2_min=6)
    assert (loose.k2_max, loose.k2_min) == (8, 4)
    assert loose.k2_for(1.0) == 8 and loose.k2_for(1e-6) == 4


# --------------------------- per-level costing ------------------------ #

def test_plan_comm_per_round_tiers_and_counts():
    plan = ReductionPlan.parse(PLAN3)
    topo = HierTopology(2, 2, 4)
    cm = CommModel()
    template = param_template(1_000_000, dtype="float32")
    costs = {c.name: c for c in plan_comm_per_round(plan, topo, template,
                                                    cm)}
    assert costs["local"].participants == 4
    assert costs["pod"].participants == 8
    assert costs["global"].participants == 16
    # local/pod ride ICI; only the global level crosses DCI
    assert costs["local"].bandwidth == cm.fast_bw
    assert costs["pod"].bandwidth == cm.fast_bw
    assert costs["global"].bandwidth == cm.slow_bw
    # subsumption: 4 local slots per round, 2 coincide with outer levels
    assert costs["local"].count_per_round == 2
    assert costs["pod"].count_per_round == 1
    # compressed payloads: cast halves fp32, topk 5% ~ 10x smaller
    dense = 4_000_000
    assert costs["local"].payload_bytes <= 0.51 * dense
    assert costs["global"].payload_bytes <= 0.11 * dense
    # single-pod topology: nothing crosses DCI
    costs1 = plan_comm_per_round(plan, HierTopology(1, 2, 4), template, cm)
    assert all(c.bandwidth == cm.fast_bw for c in costs1)


# ------------------------------ PowerSGD ------------------------------ #

def test_powersgd_registry_and_payload():
    red = get_reducer("powersgd:4")
    assert isinstance(red, PowerSGDReducer) and red.rank == 4
    assert get_reducer("powersgd").rank == 2
    with pytest.raises(ValueError):
        get_reducer("powersgd:0")
    # matrix leaves go low-rank, vectors stay dense fp32
    tree = {"w": jnp.zeros((64, 48)), "b": jnp.zeros((64,))}
    assert red.payload_bytes(tree) == (64 + 48) * 4 * 4 + 64 * 4
    dense = 64 * 48 * 4 + 64 * 4
    assert dense / red.payload_bytes(tree) > 5.0


def test_powersgd_rank_r_delta_roundtrip():
    """A delta that is exactly rank-r is reconstructed (near-)exactly by
    one warm-started power iteration + EF: the residual is ~0."""
    topo = HierTopology(1, 1, 2)
    key = jax.random.PRNGKey(0)
    u = jax.random.normal(key, topo.shape + (32, 2))
    v = jax.random.normal(jax.random.fold_in(key, 1), topo.shape + (2, 24))
    x = u @ v                                  # per-learner rank-2 matrix
    red = PowerSGDReducer(rank=2)
    st = red.init_state({"w": jnp.zeros_like(x)})   # ref=0 -> delta == x
    payload, st = red.compress({"w": x}, st)
    err = jax.tree.leaves(st.err)[0]
    assert float(jnp.max(jnp.abs(err))) < 1e-3 * float(jnp.max(jnp.abs(x)))
    xhat = red.decompress(payload, {"w": x}, st)["w"]
    np.testing.assert_allclose(np.asarray(xhat), np.asarray(x), atol=1e-3)


def test_powersgd_warm_q_and_ef_update():
    topo = HierTopology(1, 1, 2)
    x = jax.random.normal(jax.random.PRNGKey(2), topo.shape + (16, 12))
    red = PowerSGDReducer(rank=2)
    st0 = red.init_state({"w": jnp.zeros_like(x)})
    q0 = jax.tree.leaves(st0.q)[0]
    payload, st1 = red.compress({"w": x}, st0)
    q1 = jax.tree.leaves(st1.q)[0]
    assert q0.shape == q1.shape == topo.shape + (12, 2)
    assert not bool(jnp.allclose(q0, q1))      # Q warm start advanced
    # EF residual is exactly the unreconstructed mass
    (p_hat, q_new), = payload
    approx = jnp.einsum("nar,nbr->nab", p_hat, q_new).reshape(x.shape)
    np.testing.assert_allclose(np.asarray(jax.tree.leaves(st1.err)[0]),
                               np.asarray(x - approx), atol=1e-5)


def test_powersgd_hier_round_keeps_consensus(cls_task):
    topo = HierTopology(1, 2, 2)
    h = HierAvgParams(k1=2, k2=4, reducer="powersgd:2")
    opt = sgd(0.05)
    round_fn = jax.jit(make_hier_round(cls_task["loss_fn"], opt, h))
    state = init_state(topo, cls_task["init_fn"], opt,
                       jax.random.PRNGKey(0), plan=h.resolved_plan)
    assert isinstance(state.comm_state["global"], LowRankState)
    batch = cls_task["sample"](jax.random.PRNGKey(1),
                               h.k2 * topo.n_learners * 8)
    shaped = jax.tree.map(
        lambda x: x.reshape((h.beta, h.k1) + topo.shape + (8,)
                            + x.shape[1:]), batch)
    state, _ = round_fn(state, shaped)
    for leaf in jax.tree.leaves(state.params):
        flat = leaf.reshape((topo.n_learners,) + leaf.shape[3:])
        assert bool(jnp.allclose(flat, flat[0:1], atol=1e-6))


@pytest.mark.slow
def test_powersgd_convergence_near_dense(cls_task):
    """PowerSGD Hier-AVG reaches within 3% eval accuracy of the dense
    mean on the shared classification task."""
    topo = HierTopology(1, 2, 4)
    h = HierAvgParams(k1=2, k2=8)
    kw = dict(topo=topo, hier=h, optimizer=sgd(0.1), seed=1,
              eval_batch=cls_task["eval_batch"], per_learner_batch=16)
    dense = Simulator(cls_task["loss_fn"], cls_task["init_fn"],
                      cls_task["sample"], reducer="mean", **kw).run(10)
    lowrank = Simulator(cls_task["loss_fn"], cls_task["init_fn"],
                        cls_task["sample"], reducer="powersgd:4",
                        **kw).run(10)
    assert lowrank.final_eval_acc >= dense.final_eval_acc - 0.03, (
        lowrank.final_eval_acc, dense.final_eval_acc)


def test_global_average_matches_reduce_with_mean():
    """Sanity: the plan's outermost mean is the paper's global average."""
    topo = HierTopology(2, 1, 2)
    x = jax.random.normal(jax.random.PRNGKey(7), topo.shape + (5,))
    red = get_reducer("mean")
    out, _ = reduce_with(red, global_average, {"w": x}, ())
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(global_average({"w": x})["w"]))
