"""Reducer subsystem (comm/): codec round-trip bounds, error-feedback
residual behavior, the avg_dtype -> cast regression, and compressed
Hier-AVG convergence vs the dense mean."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (CastReducer, EFState, MeanReducer, QInt8Reducer,
                        RandKReducer, Reducer, TopKReducer, get_reducer,
                        reduce_with)
from repro.comm.quant import dequantize_block, quantize_block
from repro.configs.base import HierAvgParams
from repro.core import (HierTopology, Simulator, global_average, init_state,
                        local_average, make_hier_round)
from repro.optim import sgd


def _tree(key, topo, shapes=((6, 5), (7,), (3, 4, 2))):
    ks = jax.random.split(key, len(shapes))
    return {f"w{i}": jax.random.normal(k, topo.shape + s)
            for i, (k, s) in enumerate(zip(ks, shapes))}


# ------------------------------ registry ------------------------------ #

def test_get_reducer_specs():
    assert isinstance(get_reducer("mean"), MeanReducer)
    assert get_reducer("cast").payload_dtype == jnp.bfloat16
    assert get_reducer("cast:float16").payload_dtype == jnp.float16
    assert get_reducer("topk:0.05").ratio == 0.05
    assert get_reducer("randk").ratio == 0.1
    assert get_reducer("qint8:128").block == 128
    r = get_reducer("topk:0.2")
    assert get_reducer(r) is r          # instances pass through
    with pytest.raises(ValueError):
        get_reducer("gzip")
    with pytest.raises(ValueError):
        HierAvgParams(k1=2, k2=4, reducer="gzip")


# ------------------------------ mean / cast --------------------------- #

def test_mean_reducer_is_identity_average():
    topo = HierTopology(1, 2, 2)
    tree = _tree(jax.random.PRNGKey(0), topo)
    red = MeanReducer()
    out, st = reduce_with(red, global_average, tree, red.init_state(tree))
    expect = global_average(tree)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(expect)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert st == ()


def test_cast_reducer_matches_legacy_avg_dtype():
    """Regression: the removed ``avg_dtype=jnp.bfloat16`` path is exactly
    the "cast:bfloat16" reducer (narrow, mean in the narrow dtype, widen)."""
    topo = HierTopology(2, 2, 2)
    tree = _tree(jax.random.PRNGKey(1), topo)

    def legacy_avg_dtype(avg_fn, tree, avg_dtype):  # the old _avg body
        dtypes = jax.tree.map(lambda x: x.dtype, tree)
        narrowed = jax.tree.map(lambda x: x.astype(avg_dtype), tree)
        out = avg_fn(narrowed, None)
        return jax.tree.map(lambda x, d: x.astype(d), out, dtypes)

    red = CastReducer(jnp.bfloat16)
    for avg_fn in (local_average, global_average):
        want = legacy_avg_dtype(avg_fn, tree, jnp.bfloat16)
        got, _ = reduce_with(red, avg_fn, tree, ())
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cast_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 512))
    red = CastReducer(jnp.bfloat16)
    payload, _ = red.compress({"w": x}, ())
    back = red.decompress(payload, {"w": x}, ())["w"].astype(jnp.float32)
    # bf16 keeps 8 mantissa bits -> relative error < 2^-8
    rel = np.abs(np.asarray(back - x)) / np.maximum(np.abs(np.asarray(x)),
                                                    1e-6)
    assert rel.max() < 2.0 ** -8


# ------------------------------ qint8 --------------------------------- #

def test_qint8_roundtrip_error_bound():
    x = 3.0 * jax.random.normal(jax.random.PRNGKey(3), (4, 1000))
    q, scale = quantize_block(x, block=128)
    back = dequantize_block(q, scale, 1000)
    # error <= scale/2 per element, scale = blockwise absmax / 127
    err = np.abs(np.asarray(back) - np.asarray(x))
    bound = np.repeat(np.asarray(scale)[:, :, 0], 128, axis=1)[:, :1000] / 2
    assert (err <= bound + 1e-7).all()


def test_qint8_payload_accounting():
    tree = {"w": jnp.zeros((1000,)), "b": jnp.zeros((10,))}
    twopass = QInt8Reducer(block=128, fused=False)
    # 1000 -> 1000 B + 8 scales * 4 B ; 10 -> 10 B + 1 scale * 4 B
    assert twopass.payload_bytes(tree) == 1000 + 32 + 10 + 4
    dense = MeanReducer().payload_bytes(tree)
    assert dense == 4040 and dense / twopass.payload_bytes(tree) > 3.8
    # the fused pack ships whole (block + 4 B scale) wire blocks, zero
    # tail included: 8 blocks for w, 1 for b — honestly billed
    fused = QInt8Reducer(block=128)
    assert fused.payload_bytes(tree) == (8 + 1) * (128 + 4)
    assert dense / fused.payload_bytes(tree) > 3.3
    # and collapses the per-reduction message count 2 -> 1 per leaf
    assert fused.n_messages(tree) == 2 and twopass.n_messages(tree) == 4
    # spec round-trip for both wire layouts
    assert get_reducer("qint8:128").describe() == "qint8:128"
    assert get_reducer("qint8:128:twopass").describe() \
        == "qint8:128:twopass"
    assert get_reducer("qint8:twopass").block == 256
    assert not get_reducer("qint8:twopass").fused


def test_qint8_fused_reduction_matches_twopass_bitwise():
    """The fused single-buffer wire format is a PACKING change only:
    under jit (reducers always run jitted) the dequantized values are
    bit-identical to the legacy two-pass quantize path, so the whole
    reduction agrees bitwise."""
    topo = HierTopology(1, 2, 2)
    key = jax.random.PRNGKey(9)
    tree = {"w": jax.random.normal(key, topo.shape + (13, 7)),
            "b": jax.random.normal(jax.random.fold_in(key, 1),
                                   topo.shape + (37,))}
    out_f, _ = jax.jit(lambda t: reduce_with(
        get_reducer("qint8:32"), global_average, t, ()))(tree)
    out_t, _ = jax.jit(lambda t: reduce_with(
        get_reducer("qint8:32:twopass"), global_average, t, ()))(tree)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(out_f[k]),
                                      np.asarray(out_t[k]))


# ------------------------------ sparse + EF --------------------------- #

def test_topk_selects_largest_and_updates_residual():
    topo = HierTopology(1, 1, 2)
    x = jax.random.normal(jax.random.PRNGKey(4), topo.shape + (100,))
    red = TopKReducer(ratio=0.1)
    st = red.init_state({"w": jnp.zeros_like(x)})  # ref=0 -> delta == x
    payload, st = red.compress({"w": x}, st)
    vals, idx = payload[0]
    assert vals.shape == (2, 10) and idx.shape == (2, 10)
    # transmitted coordinates are the 10 largest |x| per learner
    flat = np.abs(np.asarray(x).reshape(2, 100))
    for r in range(2):
        want = set(np.argsort(-flat[r])[:10].tolist())
        assert set(np.asarray(idx)[r].tolist()) == want
    # residual holds exactly the untransmitted mass
    err = np.asarray(jax.tree.leaves(st.err)[0]).reshape(2, 100)
    dense = np.zeros((2, 100), np.float32)
    for r in range(2):
        dense[r, np.asarray(idx)[r]] = np.asarray(vals)[r]
    np.testing.assert_allclose(err, np.asarray(x).reshape(2, 100) - dense,
                               atol=1e-6)


def test_randk_shared_support():
    topo = HierTopology(1, 1, 4)
    x = jax.random.normal(jax.random.PRNGKey(5), topo.shape + (50,))
    red = RandKReducer(ratio=0.2)
    st = red.init_state({"w": jnp.zeros_like(x)})
    (vals, idx), = red.compress({"w": x}, st)[0]
    assert idx.shape == (4, 10)
    # every learner transmits the same support
    assert (np.asarray(idx) == np.asarray(idx)[0:1]).all()


def test_topk_error_feedback_residual_stays_bounded(cls_task):
    """EF residual norms stay small relative to the params over many
    rounds (the residual is re-injected, not accumulated unboundedly)."""
    topo = HierTopology(1, 2, 2)
    h = HierAvgParams(k1=2, k2=4)
    opt = sgd(0.05)
    red = TopKReducer(ratio=0.1)
    round_fn = jax.jit(make_hier_round(cls_task["loss_fn"], opt, h,
                                       reducer=red))
    state = init_state(topo, cls_task["init_fn"], opt,
                       jax.random.PRNGKey(0), reducer=red)
    key = jax.random.PRNGKey(1)
    norms = []
    for _ in range(8):
        key, kb = jax.random.split(key)
        batch = cls_task["sample"](kb, h.k2 * topo.n_learners * 8)
        shaped = jax.tree.map(
            lambda x: x.reshape((h.beta, h.k1) + topo.shape + (8,)
                                + x.shape[1:]), batch)
        state, _ = round_fn(state, shaped)
        # comm_state is keyed by plan level (local/global EF are separate)
        err_sq = sum(float(jnp.sum(jnp.square(l)))
                     for lvl in state.comm_state.values()
                     for l in jax.tree.leaves(lvl.err))
        norms.append(err_sq ** 0.5)
    p_norm = sum(float(jnp.sum(jnp.square(l)))
                 for l in jax.tree.leaves(state.params)) ** 0.5
    assert all(n < 0.5 * p_norm for n in norms), (norms, p_norm)
    # no monotone blow-up: the late residuals are no larger than 2x any
    # earlier plateau
    assert norms[-1] < 2.0 * max(norms[:4]) + 1e-3, norms


def test_hier_round_with_topk_keeps_global_consensus(cls_task):
    """After the (compressed) global reduction all P learners agree."""
    topo = HierTopology(1, 2, 2)
    h = HierAvgParams(k1=2, k2=4)
    opt = sgd(0.05)
    red = TopKReducer(ratio=0.25)
    round_fn = jax.jit(make_hier_round(cls_task["loss_fn"], opt, h,
                                       reducer=red))
    state = init_state(topo, cls_task["init_fn"], opt,
                       jax.random.PRNGKey(0), reducer=red)
    batch = cls_task["sample"](jax.random.PRNGKey(1),
                               h.k2 * topo.n_learners * 8)
    shaped = jax.tree.map(
        lambda x: x.reshape((h.beta, h.k1) + topo.shape + (8,)
                            + x.shape[1:]), batch)
    state, _ = round_fn(state, shaped)
    for leaf in jax.tree.leaves(state.params):
        flat = leaf.reshape((topo.n_learners,) + leaf.shape[3:])
        assert bool(jnp.allclose(flat, flat[0:1], atol=1e-6))


def test_step_api_with_reducer_keeps_consensus(cls_task):
    """The masked step API threads/blends per-level comm_state correctly:
    compress runs every step but each level's EF state and the params only
    change on that level's reduction steps, and the K2 boundary still ends
    in global consensus."""
    from repro.core import make_hier_step
    topo = HierTopology(1, 2, 2)
    h = HierAvgParams(k1=2, k2=4)
    opt = sgd(0.05)
    red = TopKReducer(ratio=0.25)
    step_fn = jax.jit(make_hier_step(cls_task["loss_fn"], opt, h,
                                     reducer=red))
    state = init_state(topo, cls_task["init_fn"], opt,
                       jax.random.PRNGKey(0), reducer=red)
    refs = {name: jax.tree.leaves(lvl.ref)[0]
            for name, lvl in state.comm_state.items()}
    key = jax.random.PRNGKey(1)
    for t in range(1, h.k2 + 1):
        key, kb = jax.random.split(key)
        batch = cls_task["sample"](kb, topo.n_learners * 8)
        shaped = jax.tree.map(
            lambda x: x.reshape(topo.shape + (8,) + x.shape[1:]), batch)
        state, _ = step_fn(state, shaped)
        now = {name: jax.tree.leaves(lvl.ref)[0]
               for name, lvl in state.comm_state.items()}
        fired = {"local": t % h.k1 == 0 and t % h.k2 != 0,
                 "global": t % h.k2 == 0}
        for name in refs:
            if fired[name]:
                refs[name] = now[name]
            else:   # this level did not reduce -> its EF ref untouched
                assert bool(jnp.allclose(now[name], refs[name], atol=0)), \
                    (name, t)
    for leaf in jax.tree.leaves(state.params):
        flat = leaf.reshape((topo.n_learners,) + leaf.shape[3:])
        assert bool(jnp.allclose(flat, flat[0:1], atol=1e-6))


# ------------------------------ convergence --------------------------- #

@pytest.mark.slow
@pytest.mark.parametrize("spec,tol", [
    ("cast:bfloat16", 0.02), ("qint8:128", 0.02), ("topk:0.1", 0.02),
    # random-k is the weakest selector: with honest PER-LEVEL error
    # feedback (the global reference is the last global consensus, not a
    # free ride on the dense local refs as before the ReductionPlan
    # refactor) its global coverage is only `ratio` of coordinates per
    # round, so it needs a larger ratio / looser bar.  Bucketed (the
    # default) draws ONE shared support over the whole flat model — the
    # textbook random-k of Stich et al. — which loses the per-leaf
    # stratification freebie (a small bias leaf can go unsampled for
    # rounds, riding the EF residual), hence the wider bar vs ":perleaf".
    ("randk:0.25", 0.05),
    ("randk:0.25:perleaf", 0.03),
])
def test_reducer_hier_avg_near_dense(cls_task, spec, tol):
    """Compressed Hier-AVG reaches near-dense eval accuracy."""
    topo = HierTopology(1, 2, 4)
    h = HierAvgParams(k1=2, k2=8)
    kw = dict(topo=topo, hier=h, optimizer=sgd(0.1), seed=1,
              eval_batch=cls_task["eval_batch"], per_learner_batch=16)
    dense = Simulator(cls_task["loss_fn"], cls_task["init_fn"],
                      cls_task["sample"], reducer="mean", **kw).run(10)
    comp = Simulator(cls_task["loss_fn"], cls_task["init_fn"],
                     cls_task["sample"], reducer=spec, **kw).run(10)
    assert comp.final_eval_acc >= dense.final_eval_acc - tol, (
        spec, comp.final_eval_acc, dense.final_eval_acc)


def test_payload_reduction_factors(cls_task):
    """topk(10%) cuts the global-reduction payload >= 4x vs dense."""
    topo = HierTopology(1, 2, 2)
    h = HierAvgParams(k1=2, k2=4)
    kw = dict(topo=topo, hier=h, eval_batch=None, per_learner_batch=8)
    dense = Simulator(cls_task["loss_fn"], cls_task["init_fn"],
                      cls_task["sample"], reducer="mean", **kw)
    topk = Simulator(cls_task["loss_fn"], cls_task["init_fn"],
                     cls_task["sample"], reducer="topk:0.1", **kw)
    ratio = (dense.payload_bytes_per_reduction()
             / topk.payload_bytes_per_reduction())
    assert ratio >= 4.0, ratio
