"""Launch-layer units: HLO collective parser, analytic roofline model,
partition specs for serving, mesh factories (shape-only, no devices)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, INPUT_SHAPES, get_config
from repro.configs.base import HierAvgParams, ParallelLayout
from repro.launch import hlo_analysis as ha
from repro.launch.analytic import analytic_roofline

HLO_SAMPLE = """
  %ar = bf16[128,4096]{1,0} all-reduce(%x), channel_id=1, replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %ag = f32[64,1024]{1,0} all-gather(%y), channel_id=2, replica_groups={{0,1},{2,3}}, dimensions={0}
  %rs = f32[32,1024]{1,0} reduce-scatter(%z), channel_id=3, replica_groups={{0,1,2,3}}, to_apply=%add
  %a2a = bf16[16,16]{1,0} all-to-all(%w), channel_id=4, replica_groups={{0,1,2,3,4,5,6,7}}
  %cp = bf16[8,8]{1,0} collective-permute(%v), channel_id=5, source_target_pairs={{0,1}}
  %not_a_collective = f32[2,2]{1,0} add(%a, %b)
"""


def test_parse_collectives_kinds_and_groups():
    ops = ha.parse_collectives(HLO_SAMPLE)
    kinds = sorted(o.kind for o in ops)
    assert kinds == ["all-gather", "all-reduce", "all-to-all",
                     "collective-permute", "reduce-scatter"]
    ar = next(o for o in ops if o.kind == "all-reduce")
    assert ar.group_size == 4
    assert ar.payload_bytes == 128 * 4096 * 2
    # ring model: 2V(n-1)/n
    np.testing.assert_allclose(ar.link_bytes,
                               ar.payload_bytes * 2 * 3 / 4)
    rs = next(o for o in ops if o.kind == "reduce-scatter")
    np.testing.assert_allclose(rs.link_bytes, rs.payload_bytes * 3)


def test_roofline_terms_math():
    ops = ha.parse_collectives(HLO_SAMPLE)
    t = ha.roofline_terms({"flops": 197e12, "bytes accessed": 819e9}, ops,
                          steps=1)
    np.testing.assert_allclose(t["compute_s"], 1.0)
    np.testing.assert_allclose(t["memory_s"], 1.0)
    assert t["bottleneck"] in ("compute", "memory", "collective")


@pytest.mark.parametrize("arch", ALL_ARCHS)
@pytest.mark.parametrize("shape", list(INPUT_SHAPES))
def test_analytic_roofline_all_pairs(arch, shape):
    """The analytic model is finite/positive for all 40 pairs, both meshes,
    and decode shapes are never collective-bound (sanity)."""
    cfg = get_config(arch)
    for mp in (False, True):
        r = analytic_roofline(cfg, shape, multi_pod=mp)
        for v in (r.compute_s, r.memory_s, r.collective_s):
            assert np.isfinite(v) and v >= 0
        assert r.model_flops_per_device > 0
        if INPUT_SHAPES[shape].kind == "decode":
            assert r.bottleneck == "memory"


def test_analytic_k2_monotonicity():
    """Larger K2 strictly reduces the global-averaging collective term —
    the quantitative form of the paper's thesis."""
    cfg = get_config("phi3.5-moe-42b-a6.6b")
    vals = []
    for k2 in (4, 8, 16, 32):
        r = analytic_roofline(cfg, "train_4k", multi_pod=True,
                              hier=HierAvgParams(4, k2))
        vals.append(r.collective_parts["global_avg"])
    assert all(b < a for a, b in zip(vals, vals[1:]))


def test_analytic_tp_tradeoff_rwkv():
    """The §Perf pair-1 finding is a property of the model, not a one-off:
    for the attention-free arch, TP=2 layouts dominate TP=16 on the
    collective term."""
    import dataclasses
    cfg = get_config("rwkv6-1.6b")
    base = analytic_roofline(cfg, "train_4k")
    opt = analytic_roofline(
        dataclasses.replace(cfg, layout=ParallelLayout(32, 4, 1, 2, 1)),
        "train_4k")
    assert opt.collective_s < 0.15 * base.collective_s
    assert opt.bottleneck == "compute"


def test_mesh_factories_shapes():
    from repro.launch.mesh import device_count_required
    assert device_count_required() == 256
    assert device_count_required(multi_pod=True) == 512
    lay = ParallelLayout(4, 4, 1, 16)
    assert lay.chips_per_pod == 256
    lay.validate(256)
    with pytest.raises(ValueError):
        ParallelLayout(4, 4, 1, 8).validate(256)


def test_layout_parse():
    from repro.launch.cases import parse_layout
    lay = parse_layout("32x4x1x2:4")
    assert (lay.groups, lay.local, lay.fsdp, lay.tp,
            lay.microbatch) == (32, 4, 1, 2, 4)
