"""Bucketed flat-buffer reductions (comm/bucket.py): layout construction,
pack/unpack round-trips (property-tested over dtype-mixed pytrees and
model-zoo param shapes), bit-exactness of bucketed mean/cast vs the
per-leaf path across a 3-level plan, the global-k topk oracle, and the
layout-checked EF state init."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (Bucketed, BucketLayout, EFState, Pipelined,
                        get_reducer, reduce_with)
from repro.configs.base import HierAvgParams
from repro.core import (HierTopology, Simulator, global_average, init_state,
                        make_hier_round, resolve_plan)
from repro.core.hier_avg import make_hier_step, shard_round_batch
from repro.core.topology import stack_like
from repro.optim import sgd

TOPO = HierTopology(1, 2, 2)


def _mixed_tree(topo=TOPO):
    key = jax.random.PRNGKey(0)
    mk = lambda i, s, d=jnp.float32: jax.random.normal(  # noqa: E731
        jax.random.fold_in(key, i), topo.shape + s).astype(d)
    return {
        "w0": mk(0, (6, 5)),
        "b0": mk(1, (7,)),
        "h": mk(2, (3, 4, 2), jnp.bfloat16),
        "scalar": mk(3, ()),
        "w1": mk(4, (8, 3), jnp.bfloat16),
    }


# ------------------------------ layout -------------------------------- #

def test_layout_groups_by_dtype_and_caps_size():
    tree = _mixed_tree()
    lay = BucketLayout.build(tree)        # uncapped in practice (4 MiB)
    assert lay.n_leaves == 5
    by_dtype = {b.dtype: b for b in lay.buckets}
    assert set(by_dtype) == {"float32", "bfloat16"}
    assert by_dtype["float32"].size == 6 * 5 + 7 + 1
    assert by_dtype["bfloat16"].size == 3 * 4 * 2 + 8 * 3
    # a tight cap splits the float32 group; leaves are never split, and an
    # over-cap leaf (w0: 30 elements > 8-element cap) gets its own bucket
    # (dict leaves flatten in sorted key order: b0, scalar, w0)
    tight = BucketLayout.build(tree, bucket_bytes=8 * 4)
    f32 = [b for b in tight.buckets if b.dtype == "float32"]
    assert [b.size for b in f32] == [8, 30]
    # slots record exact offsets within their bucket
    assert [(s.offset, s.size) for s in f32[0].slots] == [(0, 7), (7, 1)]
    assert f32[1].slots[0].size == 30


def test_pack_unpack_roundtrip_mixed_dtypes():
    tree = _mixed_tree()
    for bucket_bytes in (0, 16, 4 << 20):
        lay = BucketLayout.build(tree, bucket_bytes=bucket_bytes)
        back = lay.unpack(lay.pack(tree))
        for k in tree:
            assert back[k].dtype == tree[k].dtype
            np.testing.assert_array_equal(np.asarray(back[k]),
                                          np.asarray(tree[k]))


def test_matrix_mode_pads_and_roundtrips():
    tree = _mixed_tree()
    lay = BucketLayout.build(tree, matrix=True)
    for b in lay.buckets:
        assert len(b.shape) == 2 and b.padded_size >= b.size
    back = lay.unpack(lay.pack(tree))
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(tree[k]))


def test_model_zoo_param_shapes_roundtrip():
    """Real model-zoo param pytrees (reduced configs, eval_shape only — no
    arrays) survive pack/unpack with shapes and dtypes intact."""
    from repro.configs import get_config
    from repro.models import build
    for arch in ("hymba-1.5b", "deepseek-v2-lite-16b"):
        bundle = build(get_config(arch).reduced())
        params1 = jax.eval_shape(bundle.init,
                                 jax.ShapeDtypeStruct((2,), jnp.uint32))
        params = jax.eval_shape(lambda p: stack_like(TOPO, p), params1)
        lay = BucketLayout.build(params)
        assert lay.n_buckets < lay.n_leaves
        out = jax.eval_shape(lambda t: lay.unpack(lay.pack(t)), params)
        assert (jax.tree.map(lambda l: (l.shape, l.dtype), out)
                == jax.tree.map(lambda l: (l.shape, l.dtype), params))


# --------------------- hypothesis property tests ---------------------- #

try:
    import hypothesis
    import hypothesis.strategies as st
    from hypothesis import given, settings

    _HYP = True

    leaf_shapes = st.lists(
        st.tuples(st.sampled_from([(3,), (2, 4), (5,), (1, 2, 3), ()]),
                  st.sampled_from(["float32", "bfloat16", "float16"])),
        min_size=1, max_size=6)

    @settings(deadline=None, max_examples=25)
    @given(leaf_shapes, st.integers(0, 64),
           st.tuples(st.integers(1, 2), st.integers(1, 2),
                     st.integers(1, 3)))
    def test_property_pack_unpack_roundtrip(leaves, cap, topo_shape):
        tree = {}
        for i, (shape, dtype) in enumerate(leaves):
            n = int(np.prod(topo_shape + shape)) if shape \
                else int(np.prod(topo_shape))
            tree[f"l{i}"] = (jnp.arange(n, dtype=jnp.float32)
                             .reshape(topo_shape + shape)
                             .astype(dtype))
        lay = BucketLayout.build(tree, bucket_bytes=cap)
        back = lay.unpack(lay.pack(tree))
        for k in tree:
            assert back[k].dtype == tree[k].dtype
            np.testing.assert_array_equal(np.asarray(back[k]),
                                          np.asarray(tree[k]))
        # every element lands in exactly one slot of one bucket
        assert sum(b.size for b in lay.buckets) \
            == sum(int(np.prod(topo_shape + s)) // int(np.prod(topo_shape))
                   for s, _ in leaves)
except ImportError:                                   # pragma: no cover
    _HYP = False


# ----------------------- bucketed reducer parity ---------------------- #

def test_bucketed_mean_and_cast_bit_identical_single_reduction():
    tree = _mixed_tree()
    for spec in ("mean", "cast:bfloat16"):
        per_leaf, _ = reduce_with(get_reducer(spec), global_average,
                                  tree, ())
        bucketed, _ = reduce_with(Bucketed(get_reducer(spec)),
                                  global_average, tree, ())
        for k in tree:
            np.testing.assert_array_equal(np.asarray(bucketed[k]),
                                          np.asarray(per_leaf[k]))


def test_bucketed_cast_bit_identical_across_3level_plan(cls_task):
    """Full-trajectory bit-exactness: a 3-level cast/mean plan trained
    with bucketing on vs off (per-leaf) gives byte-identical params."""
    spec = "local@2:cast:bfloat16/pod@4/global@8:cast:bfloat16"
    topo = HierTopology(2, 1, 2)
    kw = dict(topo=topo, optimizer=sgd(0.05), seed=2,
              eval_batch=cls_task["eval_batch"], per_learner_batch=8)
    bucketed = Simulator(cls_task["loss_fn"], cls_task["init_fn"],
                         cls_task["sample"],
                         hier=HierAvgParams(plan=spec), **kw).run(3)
    perleaf = Simulator(cls_task["loss_fn"], cls_task["init_fn"],
                        cls_task["sample"],
                        hier=HierAvgParams(plan=spec, bucket_bytes=0),
                        **kw).run(3)
    np.testing.assert_array_equal(bucketed.losses, perleaf.losses)
    for a, b in zip(jax.tree.leaves(bucketed.state.params),
                    jax.tree.leaves(perleaf.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bucketed_topk_matches_flat_lax_topk_oracle():
    """Global-k selection: the bucketed topk payload is exactly
    lax.top_k over each learner's whole flattened (f32) model."""
    topo = HierTopology(1, 1, 4)
    key = jax.random.PRNGKey(3)
    tree = {"a": jax.random.normal(key, topo.shape + (9, 3)),
            "b": jax.random.normal(jax.random.fold_in(key, 1),
                                   topo.shape + (17,))}
    red = Bucketed(get_reducer("topk:0.25"))
    st = red.init_state(jax.tree.map(jnp.zeros_like, tree))  # ref=0
    (vals, idx), = red.compress(tree, st)[0]
    n = 9 * 3 + 17
    k = max(1, round(0.25 * n))
    assert vals.shape == (4, k)
    flat = np.concatenate([np.asarray(tree["a"]).reshape(4, -1),
                           np.asarray(tree["b"]).reshape(4, -1)], axis=-1)
    want_vals, want_idx = jax.lax.top_k(jnp.abs(jnp.asarray(flat)), k)
    for r in range(4):
        assert set(np.asarray(idx)[r].tolist()) \
            == set(np.asarray(want_idx)[r].tolist())
        np.testing.assert_allclose(
            np.sort(np.abs(np.asarray(vals)[r])),
            np.sort(np.asarray(want_vals)[r]), rtol=1e-6)


def test_bucketed_topk_3level_plan_trains_with_bucket_space_ef(cls_task):
    """A 3-level plan with stateful EF reducers at two levels trains to
    consensus with per-level EF state carried in bucket space."""
    spec = "local@2:topk:0.5/pod@4/global@8:topk:0.25"
    topo = HierTopology(2, 1, 2)
    h = HierAvgParams(plan=spec)
    plan = h.resolved_plan
    opt = sgd(0.05)
    round_fn = jax.jit(make_hier_round(cls_task["loss_fn"], opt, h))
    state = init_state(topo, cls_task["init_fn"], opt,
                       jax.random.PRNGKey(0), plan=plan)
    # EF state is bucket-space: one ref/err entry per bucket, not per leaf
    n_leaves = len(jax.tree.leaves(state.params))
    for name in ("local", "global"):
        ef = state.comm_state[name]
        assert isinstance(ef, EFState)
        assert len(ef.ref) < n_leaves
        assert all(r.ndim == 4 for r in ef.ref)    # [pods, G, S, n]
    batch = cls_task["sample"](jax.random.PRNGKey(1),
                               h.k2 * topo.n_learners * 8)
    shaped = jax.tree.map(
        lambda x: x.reshape(h.batch_dims + topo.shape + (8,)
                            + x.shape[1:]), batch)
    state, metrics = round_fn(state, shaped)
    assert np.isfinite(float(metrics["loss"]))
    for leaf in jax.tree.leaves(state.params):
        flat = leaf.reshape((topo.n_learners,) + leaf.shape[3:])
        assert bool(jnp.allclose(flat, flat[0:1], atol=1e-6))


def test_layout_checked_init_rejects_mismatched_state(cls_task):
    """Carrying per-leaf (or differently-bucketed) EF state into a
    bucketed round fails loudly, not by silent misalignment."""
    topo = HierTopology(1, 2, 2)
    h = HierAvgParams(k1=2, k2=4, reducer="topk:0.25")
    opt = sgd(0.05)
    round_fn = jax.jit(make_hier_round(cls_task["loss_fn"], opt, h))
    # state built for the PER-LEAF pipeline (bucket_bytes=0)
    bad = init_state(topo, cls_task["init_fn"], opt, jax.random.PRNGKey(0),
                     plan=resolve_plan(
                         HierAvgParams(k1=2, k2=4, reducer="topk:0.25",
                                       bucket_bytes=0)))
    batch = cls_task["sample"](jax.random.PRNGKey(1),
                               h.k2 * topo.n_learners * 8)
    shaped = jax.tree.map(
        lambda x: x.reshape((h.beta, h.k1) + topo.shape + (8,)
                            + x.shape[1:]), batch)
    with pytest.raises((ValueError, TypeError)):
        round_fn(bad, shaped)


def test_explicit_bucketed_modifier_inherits_config_cap():
    """A ':bucketed' spec modifier honors HierAvgParams.bucket_bytes (the
    wrapper's cap is 'inherit' until plan resolution re-caps it)."""
    h = HierAvgParams(k1=2, k2=4, reducer="topk:0.05:bucketed",
                      bucket_bytes=64)
    for lvl in resolve_plan(h).levels:
        assert isinstance(lvl.reducer, Bucketed)
        assert lvl.reducer.effective_bucket_bytes == 64
    # with auto-bucketing off, the explicit marker stays at the default
    h0 = HierAvgParams(k1=2, k2=4, reducer="topk:0.05:bucketed",
                       bucket_bytes=0)
    for lvl in resolve_plan(h0).levels:
        assert isinstance(lvl.reducer, Bucketed)
        assert lvl.reducer.effective_bucket_bytes == 4 << 20


def test_init_state_spec_string_plan_matches_default_round(cls_task):
    """init_state(plan=<spec string>) applies the same default bucketing
    resolve_plan does, so a round built from a default HierAvgParams
    accepts the state; bucket_bytes=0 rebuilds the per-leaf state."""
    spec = "local@2:topk:0.5/global@4:topk:0.25"
    topo = HierTopology(1, 2, 2)
    h = HierAvgParams(plan=spec)
    opt = sgd(0.05)
    round_fn = jax.jit(make_hier_round(cls_task["loss_fn"], opt, h))
    state = init_state(topo, cls_task["init_fn"], opt,
                       jax.random.PRNGKey(0), plan=spec)
    batch = cls_task["sample"](jax.random.PRNGKey(1),
                               h.k2 * topo.n_learners * 8)
    shaped = jax.tree.map(
        lambda x: x.reshape(h.batch_dims + topo.shape + (8,)
                            + x.shape[1:]), batch)
    state, metrics = round_fn(state, shaped)
    assert np.isfinite(float(metrics["loss"]))
    # explicit override routes to the per-leaf layout
    perleaf = init_state(topo, cls_task["init_fn"], opt,
                         jax.random.PRNGKey(0), plan=spec, bucket_bytes=0)
    n_leaves = len(jax.tree.leaves(perleaf.params))
    assert len(jax.tree.leaves(perleaf.comm_state["global"].ref)) \
        == n_leaves
    assert len(jax.tree.leaves(state.comm_state["global"].ref)) < n_leaves


# ----------------------- pipelined bucket schedule --------------------- #

def test_uniform_layout_pads_groups_and_roundtrips():
    """uniform=True (the pipelined engine's layout) pads every bucket of
    a multi-bucket dtype group to the group max; single-bucket groups
    keep their exact size; pack/unpack still round-trips."""
    tree = _mixed_tree()
    lay = BucketLayout.build(tree, bucket_bytes=64, uniform=True)
    by_dtype = {}
    for b in lay.buckets:
        by_dtype.setdefault(b.dtype, []).append(b)
    for dtype, group in by_dtype.items():
        if len(group) > 1:
            assert len({b.shape for b in group}) == 1     # rectangular
            assert all(b.padded_size >= b.size for b in group)
    back = lay.unpack(lay.pack(tree))
    for k in tree:
        assert back[k].dtype == tree[k].dtype
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(tree[k]))
    # ragged and uniform layouts agree when every group has one bucket
    big = BucketLayout.build(tree, uniform=True)
    assert [b.shape for b in big.buckets] \
        == [b.shape for b in BucketLayout.build(tree).buckets]


def test_matrix_uniform_layout_common_panel_and_roundtrips():
    """matrix+uniform (the pipelined PowerSGD layout, previously
    refused): every bucket of a multi-bucket group pads to the
    elementwise-max common panel shape, so the scan's stacked stages are
    rectangular; pack/unpack still round-trips bit-exactly."""
    tree = _mixed_tree()
    lay = BucketLayout.build(tree, bucket_bytes=64, matrix=True,
                             uniform=True)
    by_dtype = {}
    for b in lay.buckets:
        assert len(b.shape) == 2
        by_dtype.setdefault(b.dtype, []).append(b)
    for group in by_dtype.values():
        if len(group) > 1:
            assert len({b.shape for b in group}) == 1
            assert all(b.padded_size >= b.size for b in group)
    assert any(len(g) > 1 for g in by_dtype.values())  # really exercised
    back = lay.unpack(lay.pack(tree))
    for k in tree:
        assert back[k].dtype == tree[k].dtype
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(tree[k]))


def _abstract_shard_plan(F=2):
    """ShardPlan over an AbstractMesh — layout resolution needs only the
    mesh axis sizes, so layout unit tests run without multiple devices."""
    from jax.sharding import AbstractMesh

    from repro.parallel.sharding import ShardPlan
    mesh = AbstractMesh((("pod", 1), ("group", 2), ("local", 2),
                         ("fsdp", F), ("model", 1)))
    return ShardPlan(mesh=mesh)


def test_shard_aware_layout_packs_per_shard_runs():
    """fsdp>1 layouts pack sharded leaves into per-shard runs (wire view
    [*lead, F, run]), pad every run to a multiple of the learner count
    (so each level's reduce-scatter tiles), and round-trip pack/unpack
    bit-exactly."""
    tree = _mixed_tree()
    sp = _abstract_shard_plan()
    lay = BucketLayout.build(tree, shards=sp)
    sharded = {b.dtype: b for b in lay.buckets if b.shards > 1}
    flat = {b.dtype: b for b in lay.buckets if b.shards == 1}
    # rank>=2 leaves shard trailing dim 0 over fsdp (DEFAULT_RULES
    # fallback); w0 [6,5] and w1 [8,3] divide F=2, h [3,4,2] does not
    # (3 % 2) and stays flat — the safe_pspec drop, mirrored exactly
    assert sharded["float32"].size == 6 * 5 // 2
    assert sharded["bfloat16"].size == 8 * 3 // 2
    assert flat["bfloat16"].size == 3 * 4 * 2
    for b in lay.buckets:
        assert b.shape[-1] % sp.n_lead == 0
    # wire view: per-shard run 15 padded to 16, F-major axis explicit
    assert sharded["float32"].shape == (2, 16)
    back = lay.unpack(lay.pack(tree))
    for k in tree:
        assert back[k].dtype == tree[k].dtype
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(tree[k]))
    # codec view merges shards into the local-learner axis (shard space)
    packed = lay.pack(tree)
    codec = lay.codec_view(packed)
    for b, w, c in zip(lay.buckets, packed, codec):
        if b.shards > 1:
            assert w.shape[:3] == (1, 2, 2) and c.shape[:3] == (1, 2, 4)
        np.testing.assert_array_equal(
            np.asarray(lay._to_wire(b, c)), np.asarray(w))


def test_matrix_mode_refuses_sharded_leaves():
    """Low-rank (matrix-mode) reducers cannot act on a per-shard run:
    building a matrix layout under an fsdp>1 ShardPlan refuses loudly,
    naming the offending leaf; fsdp=1 stays byte-identical."""
    tree = _mixed_tree()
    with pytest.raises(NotImplementedError, match="fsdp"):
        BucketLayout.build(tree, matrix=True, shards=_abstract_shard_plan())
    lay = BucketLayout.build(tree, shards=None)
    assert lay.n_leaves == 5
    assert [b.shape for b in lay.buckets] \
        == [b.shape for b in BucketLayout.build(tree).buckets]


def test_contradictory_schedule_modifiers_raise():
    with pytest.raises(ValueError, match="contradictory"):
        get_reducer("topk:0.05:perleaf:pipelined")
    with pytest.raises(ValueError, match="contradictory"):
        get_reducer("topk:0.05:pipelined:serial")


@pytest.mark.parametrize("spec", ["mean", "cast:bfloat16"])
def test_pipelined_bit_identical_to_serial_single_reduction(spec):
    """Pipelining is a schedule change only: multi-bucket mean/cast
    reductions are bit-identical serial vs pipelined."""
    tree = _mixed_tree()
    ser, _ = reduce_with(Bucketed(get_reducer(spec), 64), global_average,
                         tree, ())
    pip, _ = reduce_with(Pipelined(get_reducer(spec), 64), global_average,
                         tree, ())
    for k in tree:
        np.testing.assert_array_equal(np.asarray(pip[k]),
                                      np.asarray(ser[k]))


def test_pipelined_cast_trajectory_bit_identical_to_serial(cls_task):
    """Full-trajectory bit-exactness: a 3-level cast plan trained with
    overlap on vs off (multi-bucket: tiny cap) gives byte-identical
    params — pipelining must not change math."""
    spec = "local@2:cast:bfloat16/pod@4/global@8:cast:bfloat16"
    topo = HierTopology(2, 1, 2)
    kw = dict(topo=topo, optimizer=sgd(0.05), seed=2,
              eval_batch=cls_task["eval_batch"], per_learner_batch=8)
    piped = Simulator(cls_task["loss_fn"], cls_task["init_fn"],
                      cls_task["sample"],
                      hier=HierAvgParams(plan=spec, bucket_bytes=256,
                                         overlap=True), **kw).run(3)
    serial = Simulator(cls_task["loss_fn"], cls_task["init_fn"],
                       cls_task["sample"],
                       hier=HierAvgParams(plan=spec, bucket_bytes=256,
                                          overlap=False), **kw).run(3)
    np.testing.assert_array_equal(piped.losses, serial.losses)
    for a, b in zip(jax.tree.leaves(piped.state.params),
                    jax.tree.leaves(serial.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _run_steps(step_fn, state, shaped, k2):
    flat = jax.tree.map(lambda x: x.reshape((k2,) + x.shape[2:]), shaped)
    for t in range(k2):
        state, _ = step_fn(state, jax.tree.map(lambda x: x[t], flat))
    return state


@pytest.mark.parametrize("spec", ["mean:bucketed", "cast:bfloat16"])
def test_pipelined_step_api_bit_identical_to_serial(cls_task, spec):
    """Per-API bit-exactness: the step-wise (lax.cond-masked) API under
    the pipelined schedule == the same API under the serial schedule,
    for mean/cast at a multi-bucket cap.  Pipelining must not change
    math in either API.  (``mean:bucketed`` — not ``:pipelined``, which
    would pin the engine and defeat the overlap toggle — resolves to
    Pipelined with overlap=True and plain Bucketed with overlap=False.)"""
    topo = HierTopology(1, 2, 2)
    states, params = {}, {}
    for overlap in (True, False):
        h = HierAvgParams(k1=2, k2=4, reducer=spec, bucket_bytes=256,
                          overlap=overlap)
        opt = sgd(0.05)
        step_fn = jax.jit(make_hier_step(cls_task["loss_fn"], opt, h))
        s = init_state(topo, cls_task["init_fn"], opt,
                       jax.random.PRNGKey(0), plan=h.resolved_plan)
        batch = cls_task["sample"](jax.random.PRNGKey(1),
                                   h.k2 * topo.n_learners * 8)
        shaped = shard_round_batch(batch, h, topo)
        params[overlap] = _run_steps(step_fn, s, shaped, h.k2).params
    for a, b in zip(jax.tree.leaves(params[True]),
                    jax.tree.leaves(params[False])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pipelined_step_api_matches_round_api_mean(cls_task):
    """Step-wise counter masking and the scan-nest round agree for the
    pipelined bucketed mean.  Across APIs the round program also runs
    the (subsumed) local mean at the outer boundary — a float
    reassociation of the same average, so the cross-API comparison is
    allclose at fp32 resolution; bit-exactness is asserted WITHIN each
    API by test_pipelined_step_api_bit_identical_to_serial and the
    trajectory test above (pipelining itself changes nothing)."""
    topo = HierTopology(1, 2, 2)
    h = HierAvgParams(k1=2, k2=4, reducer="mean:pipelined",
                      bucket_bytes=256)
    opt = sgd(0.05)
    round_fn = jax.jit(make_hier_round(cls_task["loss_fn"], opt, h))
    step_fn = jax.jit(make_hier_step(cls_task["loss_fn"], opt, h))
    key = jax.random.PRNGKey(0)
    s_round = init_state(topo, cls_task["init_fn"], opt, key,
                         plan=h.resolved_plan)
    s_step = init_state(topo, cls_task["init_fn"], opt, key,
                        plan=h.resolved_plan)
    batch = cls_task["sample"](jax.random.PRNGKey(1),
                               h.k2 * topo.n_learners * 8)
    shaped = shard_round_batch(batch, h, topo)
    s_round, _ = round_fn(s_round, shaped)
    s_step = _run_steps(step_fn, s_step, shaped, h.k2)
    for a, b in zip(jax.tree.leaves(s_round.params),
                    jax.tree.leaves(s_step.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-6, atol=1e-6)


def test_pipelined_topk_multibucket_trains_with_uniform_ef(cls_task):
    """A 2-level plan with EF topk at both levels, forced multi-bucket
    (tiny cap): the pipelined engine trains to consensus and carries
    uniform (padded) bucket-space EF state."""
    spec = "local@2:topk:0.5/global@4:topk:0.25"
    topo = HierTopology(1, 2, 2)
    h = HierAvgParams(plan=spec, bucket_bytes=256)
    plan = h.resolved_plan
    assert all(isinstance(l.reducer, Pipelined) for l in plan.levels)
    opt = sgd(0.05)
    round_fn = jax.jit(make_hier_round(cls_task["loss_fn"], opt, h))
    state = init_state(topo, cls_task["init_fn"], opt,
                       jax.random.PRNGKey(0), plan=plan)
    # multi-bucket, uniform within the f32 group
    ef = state.comm_state["global"]
    assert len(ef.ref) > 1
    assert len({r.shape for r in ef.ref}) == 1
    batch = cls_task["sample"](jax.random.PRNGKey(1),
                               h.k2 * topo.n_learners * 8)
    shaped = shard_round_batch(batch, h, topo)
    state, metrics = round_fn(state, shaped)
    assert np.isfinite(float(metrics["loss"]))
    for leaf in jax.tree.leaves(state.params):
        flat = leaf.reshape((topo.n_learners,) + leaf.shape[3:])
        assert bool(jnp.allclose(flat, flat[0:1], atol=1e-6))
    # a second round accepts the carried state (structure is stable)
    state, metrics = round_fn(state, shaped)
    assert np.isfinite(float(metrics["loss"]))


def test_pipelined_overlap_mismatched_state_fails_loudly(cls_task):
    """Serial-schedule EF state into a pipelined multi-bucket round (or
    vice versa) trips the layout check, not silent misalignment."""
    topo = HierTopology(1, 2, 2)
    h = HierAvgParams(k1=2, k2=4, reducer="topk:0.25", bucket_bytes=72)
    opt = sgd(0.05)
    round_fn = jax.jit(make_hier_round(cls_task["loss_fn"], opt, h))
    bad = init_state(topo, cls_task["init_fn"], opt, jax.random.PRNGKey(0),
                     plan=resolve_plan(HierAvgParams(
                         k1=2, k2=4, reducer="topk:0.25", bucket_bytes=72,
                         overlap=False)))
    batch = cls_task["sample"](jax.random.PRNGKey(1),
                               h.k2 * topo.n_learners * 8)
    shaped = shard_round_batch(batch, h, topo)
    with pytest.raises((ValueError, TypeError)):
        round_fn(bad, shaped)


def test_overlap_false_demotes_auto_pipelined_plan(cls_task):
    """The init_state escape hatch: re-resolving an already-pipelined
    (auto, not ':pipelined'-pinned) plan with overlap=False demotes it
    to the serial engine, so the state it builds matches a serial round
    (regression: auto Pipelined wrappers were treated as explicit pins
    and kept their uniform-padded layout)."""
    from repro.core.plan import apply_bucketing
    # resolved with overlap default on -> auto-Pipelined levels (cap 72)
    h = HierAvgParams(k1=2, k2=4, reducer="topk:0.25", bucket_bytes=72)
    resolved = resolve_plan(h)
    assert all(isinstance(l.reducer, Pipelined) for l in resolved.levels)
    demoted = apply_bucketing(resolved, 72, overlap=False)
    assert all(type(l.reducer) is Bucketed for l in demoted.levels)
    # ... while an explicit ':pipelined' pin survives the demotion
    pinned = resolve_plan(HierAvgParams(
        k1=2, k2=4, reducer="topk:0.25:pipelined", bucket_bytes=72))
    assert all(isinstance(l.reducer, Pipelined)
               for l in apply_bucketing(pinned, 72, overlap=False).levels)
    # end to end: state built from the PIPELINED instance with
    # overlap=False runs in a serial overlap=False round
    topo = HierTopology(1, 2, 2)
    hs = HierAvgParams(k1=2, k2=4, reducer="topk:0.25", bucket_bytes=72,
                       overlap=False)
    opt = sgd(0.05)
    round_fn = jax.jit(make_hier_round(cls_task["loss_fn"], opt, hs))
    state = init_state(topo, cls_task["init_fn"], opt,
                       jax.random.PRNGKey(0), plan=resolved,
                       bucket_bytes=72, overlap=False)
    batch = cls_task["sample"](jax.random.PRNGKey(1),
                               hs.k2 * topo.n_learners * 8)
    shaped = shard_round_batch(batch, hs, topo)
    state, metrics = round_fn(state, shaped)
    assert np.isfinite(float(metrics["loss"]))


def test_pipelined_qint8_reduces_within_quant_error():
    """Stateless quantizing codec through the pipeline: the uniform
    padding shifts qint8's block boundaries vs the ragged serial layout
    (so no bit-exactness claim), but the reduction must still land
    within the codec's per-block error bound of the true mean."""
    tree = _mixed_tree()
    dense, _ = reduce_with(get_reducer("mean"), global_average, tree, ())
    pip, _ = reduce_with(Pipelined(get_reducer("qint8:32"), 64),
                         global_average, tree, ())
    for k in tree:
        a = np.asarray(pip[k], np.float32)
        b = np.asarray(dense[k], np.float32)
        bound = np.abs(np.asarray(tree[k], np.float32)).max() / 100.0
        np.testing.assert_allclose(a, b, atol=max(bound, 0.05))


def test_pipelined_powersgd_bit_identical_to_serial_schedule():
    """PowerSGD rides the pipeline (per-bucket warm-start state splits;
    EF/ref finalized INSIDE the scan): on the same uniform matrix
    layout, the pipelined schedule is bit-identical to the serial one —
    outputs AND the carried state (ref, err, warm-started q).  The
    layouts must match for the claim (ragged vs common-panel padding
    changes the matrix reshape), so the serial leg runs Bucketed.reduce
    unbound on the SAME Pipelined reducer."""
    tree = _mixed_tree()
    f32 = {k: v for k, v in tree.items() if v.dtype == jnp.float32}
    pip_red = Pipelined(get_reducer("powersgd:2"), 64)
    st0 = pip_red.init_state(jax.tree.map(jnp.zeros_like, f32))
    n_b = pip_red.layout_for(f32).n_buckets
    assert n_b >= 2                      # a real multi-stage pipeline
    assert pip_red.inner.split_bucket_states(st0, n_b) is not None
    ser, ser_st = Bucketed.reduce(pip_red, global_average, f32, st0)
    pip, pip_st = reduce_with(pip_red, global_average, f32, st0)
    for k in f32:
        np.testing.assert_array_equal(np.asarray(pip[k]),
                                      np.asarray(ser[k]))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), pip_st, ser_st)


def test_pipelined_topk_ef_bit_identical_to_serial_schedule():
    """Stateful sparse EF codec through the finalize-in-scan path: same
    uniform layout, serial vs pipelined schedules agree bitwise on
    outputs AND the carried EF state (residual, reference) — the EF
    update must not see stale or re-materialized references when it
    moves inside the scan body."""
    tree = _mixed_tree()
    f32 = {k: v for k, v in tree.items() if v.dtype == jnp.float32}
    pip_red = Pipelined(get_reducer("topk:0.3"), 64)
    st0 = pip_red.init_state(jax.tree.map(jnp.zeros_like, f32))
    assert pip_red.layout_for(f32).n_buckets >= 2
    ser, ser_st = Bucketed.reduce(pip_red, global_average, f32, st0)
    pip, pip_st = reduce_with(pip_red, global_average, f32, st0)
    for k in f32:
        np.testing.assert_array_equal(np.asarray(pip[k]),
                                      np.asarray(ser[k]))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), pip_st, ser_st)
    # and the EF state is genuinely non-trivial (the codec dropped mass)
    assert any(float(jnp.max(jnp.abs(x))) > 0
               for x in jax.tree.leaves(pip_st)
               if jnp.issubdtype(x.dtype, jnp.floating))


# ------------------------------ accounting ---------------------------- #

def test_bucketed_payload_and_message_accounting():
    tree = {"w": jnp.zeros((100, 10)), "b": jnp.zeros((10,)),
            "v": jnp.zeros((77,))}
    dense = get_reducer("mean")
    assert dense.n_messages(tree) == 3
    bucketed_cast = Bucketed(get_reducer("cast:bfloat16"))
    # one f32 bucket -> one collective; payload bytes unchanged vs per-leaf
    assert bucketed_cast.n_messages(tree) == 1
    assert bucketed_cast.payload_bytes(tree) \
        == get_reducer("cast:bfloat16").payload_bytes(tree)
    # global k: one k of the whole model, not one per leaf
    topk = Bucketed(get_reducer("topk:0.1"))
    n = 100 * 10 + 10 + 77
    assert topk.payload_bytes(tree) == max(1, round(0.1 * n)) * 8
    # fused qint8 ships ONE packed buffer per bucket; the twopass
    # baseline bills the int8 payload and the fp32 scales separately
    assert Bucketed(get_reducer("qint8:128")).n_messages(tree) == 1
    assert Bucketed(get_reducer("qint8:128:twopass")).n_messages(tree) == 2
    assert get_reducer("qint8:128").n_messages(tree) == 3
    assert get_reducer("qint8:128:twopass").n_messages(tree) == 6
    # powersgd: two factor messages per compressible matrix bucket;
    # un-bucketed, two for the compressible w plus one each for the
    # dense-fallback 1-D b and v
    assert Bucketed(get_reducer("powersgd:2")).n_messages(tree) == 2
    assert get_reducer("powersgd:2").n_messages(tree) == 4


def test_plan_comm_costing_bills_messages():
    from repro.core.theory import CommModel, plan_comm_per_round
    tree = {"w": jax.ShapeDtypeStruct((100, 10), jnp.float32),
            "b": jax.ShapeDtypeStruct((10,), jnp.float32)}
    topo = HierTopology(1, 2, 4)
    cm = CommModel()
    per_leaf = plan_comm_per_round(
        resolve_plan(HierAvgParams(k1=2, k2=4, reducer="qint8:128",
                                   bucket_bytes=0)), topo, tree, cm)
    bucketed = plan_comm_per_round(
        resolve_plan(HierAvgParams(k1=2, k2=4, reducer="qint8:128")),
        topo, tree, cm)
    assert per_leaf[0].messages == 2 and bucketed[0].messages == 1
    # no more wire bytes (packing saves partial qint8 blocks), strictly
    # less startup latency
    for pl, bk in zip(per_leaf, bucketed):
        assert bk.payload_bytes <= pl.payload_bytes
        assert bk.seconds_per_round < pl.seconds_per_round
