"""Numeric validation of the paper's theorems as stated."""
import math

import numpy as np
import pytest

from repro.core.schedules import thm31_gamma, thm31_k2
from repro.core.theory import (CommModel, comm_advantage, comm_per_k2_steps,
                               optimal_k2, third_term_poly, thm31_bound,
                               thm31_rate_at_optimum, thm32_bound,
                               thm32_condition, thm34_condition, thm34_terms,
                               thm36_hier_bound, thm36_kavg_bound)


def test_thm31_rate_matches_bound_at_optimum():
    """Plugging gamma=sqrt(PB/T), K2=T^.25/(PB)^.75 into (3.2) gives (3.4)."""
    F0, L, M, MG = 5.0, 2.0, 1.0, 1.0
    P, B, T = 16, 32, 2 ** 24
    gamma = thm31_gamma(P, B, T)
    k2 = T ** 0.25 / (P * B) ** 0.75
    lhs = thm31_bound(F0, L, M, MG, gamma, k2, P, B, T)
    rhs = thm31_rate_at_optimum(F0, L, M, MG, P, B, T)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-9)


def test_thm31_standard_rate_scaling():
    """The optimized bound scales as 1/sqrt(PBT)."""
    F0, L, M, MG = 5.0, 2.0, 1.0, 1.0
    r1 = thm31_rate_at_optimum(F0, L, M, MG, 16, 32, 1 << 20)
    r2 = thm31_rate_at_optimum(F0, L, M, MG, 16, 32, 1 << 22)
    np.testing.assert_allclose(r1 / r2, 2.0, rtol=1e-9)
    r3 = thm31_rate_at_optimum(F0, L, M, MG, 64, 32, 1 << 20)
    np.testing.assert_allclose(r1 / r3, 2.0, rtol=1e-9)


def test_thm32_bound_reduces_to_kavg_form():
    """K1=1, S=1 (or K1=K2): the K1/S polynomial becomes (K2-1)(4K2-2)
    -> the K-AVG third term; with K1=K2 the S term vanishes entirely."""
    k2 = 16
    poly_kavg = third_term_poly(k2, 1, 1)
    assert poly_kavg == (k2 - 1) * (4 * k2 - 2)
    poly_eq = third_term_poly(k2, k2, 7)
    assert poly_eq == (k2 - 1) * (4 * k2 - 2)  # S drops out when K1=K2


def test_thm32_condition_small_gamma():
    assert thm32_condition(L=10.0, gamma=1e-4, K2=32)
    assert not thm32_condition(L=10.0, gamma=0.5, K2=32)


def test_thm34_condition_far_from_optimum():
    """Large F1-F* satisfies (3.11) -> some K2 > 1 is faster; tiny F1-F*
    does not."""
    L, M, gamma, T, P, B, S = 2.0, 1.0, 0.01, 10_000, 16, 32, 4
    assert thm34_condition(1e3, L, M, gamma, T, P, B, S)
    assert not thm34_condition(1e-6, L, M, gamma, T, P, B, S)
    # and the argmin indeed moves off 1
    alpha, beta, eta = thm34_terms(1e3, L, M, gamma, T, P, B)
    assert optimal_k2(4, S, alpha, beta, eta) > 1
    alpha, beta, eta = thm34_terms(1e-6, L, M, gamma, T, P, B)
    assert optimal_k2(4, S, alpha, beta, eta) == 1


def test_thm35_monotonicity_exact():
    for k2 in (8, 32, 128):
        vals_k1 = [third_term_poly(k2, k1, 4) for k1 in range(2, k2 + 1)]
        assert all(b >= a for a, b in zip(vals_k1, vals_k1[1:]))
        vals_s = [third_term_poly(k2, 4, s) for s in range(1, 17)]
        assert all(b <= a for a, b in zip(vals_s, vals_s[1:]))


def test_thm36_dominance_region():
    for k in (2, 8, 32, 128):
        for a in (0.0, 0.2, 0.4, 0.6):
            assert thm36_hier_bound(k, a, 0.1, 1e-4) < \
                thm36_kavg_bound(k, 0.1, 1e-4)


def test_comm_model_hier_saves_over_kavg():
    """The paper's motivation quantified: at equal data, Hier-AVG spends
    less reduction time than K-AVG once P is large."""
    model_bytes = 1e9  # ~500M params bf16
    for P in (16, 32, 64, 256):
        adv = comm_advantage(model_bytes, K=8, a=0.5, P=P, S=4)
        assert adv > 0, P
    # and local reductions really are cheaper than global ones
    cm = CommModel()
    loc, glo = comm_per_k2_steps(model_bytes, 1, 12, P=64, S=4, cm=cm)
    assert loc / max(12 // 1 - 1, 1) < glo  # per-event local << global
