"""Deeper model-layer tests: M-RoPE, MoE chunk invariance + load balance,
RWKV shift semantics, encoder bidirectionality, vocab padding."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build
from repro.models.common import (apply_rope, mrope_cos_sin, rope_cos_sin,
                                 text_positions)

pytestmark = pytest.mark.slow
from repro.models.moe import moe_apply, moe_init
from repro.models.stubs import mrope_positions


def test_mrope_reduces_to_rope_for_text():
    """Text tokens have t == h == w positions: M-RoPE must equal 1-D RoPE."""
    hd, theta = 128, 1e6
    pos = text_positions(2, 16)
    pos3 = jnp.stack([pos, pos, pos], -1)
    c1, s1 = rope_cos_sin(pos, hd, theta)
    c2, s2 = mrope_cos_sin(pos3, hd, theta, (16, 24, 24))
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)


def test_mrope_vision_positions_differ_from_text():
    pos = mrope_positions(1, 16, 4)           # 4x4 grid + 4 text tokens
    c, s = mrope_cos_sin(pos, 128, 1e4, (16, 24, 24))
    # two patches in the same row share t,h but differ in w -> different sin
    assert not np.allclose(np.asarray(s[0, 0]), np.asarray(s[0, 1]))
    # text positions are strictly increasing after the vision block
    assert int(pos[0, -1, 0]) > int(pos[0, -2, 0]) - 1


def test_rope_rotation_preserves_norm():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 64))
    cos, sin = rope_cos_sin(text_positions(2, 8), 64, 1e4)
    y = apply_rope(x, cos[:, :, None], sin[:, :, None])
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)


def test_moe_chunk_invariance_dropless():
    """With dropless capacity, chunked routing == unchunked routing."""
    key = jax.random.PRNGKey(0)
    p = moe_init(key, 32, 64, n_experts=4, n_shared=1)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32))
    cf = 4.0 / 2  # E / top_k -> dropless
    y1, a1 = moe_apply(p, x, n_experts=4, top_k=2, capacity_factor=cf,
                       chunk=16)
    y2, a2 = moe_apply(p, x, n_experts=4, top_k=2, capacity_factor=cf,
                       chunk=64)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


def test_moe_aux_loss_uniform_router_is_one():
    """Switch aux loss == 1 under a perfectly uniform router."""
    key = jax.random.PRNGKey(2)
    p = moe_init(key, 16, 32, n_experts=4, n_shared=0)
    p = dict(p, router=jnp.zeros((16, 4)))    # uniform probs
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 64, 16))
    _, aux = moe_apply(p, x, n_experts=4, top_k=2, capacity_factor=2.0)
    # me = 1/4 each; ce = top-2 ties -> 2/4 average; aux = 4*sum(1/4*1/2)/2=1
    np.testing.assert_allclose(float(aux), 1.0, atol=0.3)


def test_moe_drops_tokens_at_low_capacity():
    """Tiny capacity must change outputs (tokens dropped to residual)."""
    key = jax.random.PRNGKey(4)
    p = moe_init(key, 16, 32, n_experts=2, n_shared=0)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 32, 16))
    y_full, _ = moe_apply(p, x, n_experts=2, top_k=1, capacity_factor=2.0)
    y_tiny, _ = moe_apply(p, x, n_experts=2, top_k=1, capacity_factor=0.1)
    assert not np.allclose(np.asarray(y_full), np.asarray(y_tiny))
    # dropped tokens produce exactly zero routed output
    assert float(jnp.abs(y_tiny).sum()) < float(jnp.abs(y_full).sum())


def test_rwkv_shift_is_causal():
    """Token i's time-mix input depends on token i-1, never on i+1."""
    cfg = get_config("rwkv6-1.6b").reduced()
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0,
                              cfg.vocab_size)
    h1, _ = bundle.forward(params, params["embed"][toks])
    toks2 = toks.at[:, -1].set((toks[:, -1] + 1) % cfg.vocab_size)
    h2, _ = bundle.forward(params, params["embed"][toks2])
    # perturbing the LAST token must not change earlier positions
    np.testing.assert_allclose(np.asarray(h1[:, :-1]),
                               np.asarray(h2[:, :-1]), atol=1e-5)
    assert not np.allclose(np.asarray(h1[:, -1]), np.asarray(h2[:, -1]))


def test_decoder_lm_is_causal():
    cfg = get_config("yi-34b").reduced()
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 10), 0,
                              cfg.vocab_size)
    pos = text_positions(1, 10)
    h1, _ = bundle.forward(params, params["embed"][toks], pos)
    toks2 = toks.at[:, 5].set((toks[:, 5] + 1) % cfg.vocab_size)
    h2, _ = bundle.forward(params, params["embed"][toks2], pos)
    np.testing.assert_allclose(np.asarray(h1[:, :5]), np.asarray(h2[:, :5]),
                               atol=1e-5)
    assert not np.allclose(np.asarray(h1[:, 5:]), np.asarray(h2[:, 5:]))


def test_encoder_is_bidirectional():
    cfg = get_config("seamless-m4t-large-v2").reduced()
    from repro.models.encdec import build_encdec
    bundle = build_encdec(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    frames = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (1, 8,
                                                             cfg.d_model))
    batch = {"frames": frames, "tokens": jnp.ones((1, 4), jnp.int32),
             "labels": jnp.ones((1, 4), jnp.int32)}
    l1, _ = bundle.loss_fn(params, batch)
    # perturbing the LAST frame changes the loss (decoder reads all frames
    # through cross-attention; encoder is bidirectional)
    frames2 = frames.at[:, -1].add(1.0)
    l2, _ = bundle.loss_fn(params, dict(batch, frames=frames2))
    assert float(l1) != float(l2)


def test_padded_vocab_sharding_friendly():
    for arch in ("seamless-m4t-large-v2", "hymba-1.5b", "qwen2-vl-2b"):
        cfg = get_config(arch)
        assert cfg.padded_vocab % 128 == 0
        assert cfg.padded_vocab >= cfg.vocab_size
        assert cfg.padded_vocab % 16 == 0  # TP-16 shardable
