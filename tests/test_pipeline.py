"""Overlap verification for the pipelined bucket schedule (comm/bucket.py
Pipelined), from the compiled SPMD HLO on 8 forced host devices.

What "overlap" means at the HLO level: inside the pipeline's scan body,
the grouped all-reduce for stage *i-1* must consume ONLY the loop carry —
never this iteration's compress output — so a backend with async
collectives can hoist the compress between ``all-reduce-start`` and
``all-reduce-done``.  The CPU backend keeps collectives synchronous (no
start/done pair to span), so the test asserts the *schedulability*
precondition directly on the dependence graph, plus the program-size
claim: collective op count O(1) in the bucket count vs the serial path's
2 per bucket.  When the backend does split collectives (TPU/GPU), the
start/done spanning check kicks in automatically.

Device count must be forced before jax initializes, so the compile runs
in a subprocess.
"""
import os
import re
import subprocess
import sys

import pytest

_REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")
import json, sys
# the SAME builder benchmarks/bench_bucketing.py measures — the
# overlap-verified program and the benchmarked program cannot drift
from repro.testing import AB_SMALL_CAP, build_ab_reduction

out = {}
for name in ("serial", "pipelined"):
    b = build_ab_reduction(name, AB_SMALL_CAP)
    txt = b["fn"].lower(b["params"], b["state"]).compile().as_text()
    open(os.path.join(sys.argv[1], name + ".hlo"), "w").write(txt)
    out[name + "_buckets"] = b["n_buckets"]
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def hlo_pair(tmp_path_factory):
    d = str(tmp_path_factory.mktemp("hlo"))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", _CHILD, d], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    import json
    meta = json.loads(r.stdout.strip().splitlines()[-1])
    with open(os.path.join(d, "serial.hlo")) as f:
        serial = f.read()
    with open(os.path.join(d, "pipelined.hlo")) as f:
        pipelined = f.read()
    return serial, pipelined, meta


from repro.testing import count_allreduce_ops as _collective_ops  # noqa: E402


def _computations(txt):
    """name -> list of op lines, for every computation in the module."""
    comps, cur, lines = {}, None, []
    for line in txt.splitlines():
        m = re.match(r"(?:ENTRY )?%?([\w.\-]+)\s*\(.*\)\s*->.*{", line)
        if m:
            cur, lines = m.group(1), []
            comps[cur] = lines
        elif cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                lines.append(line)
    return comps


def _defs_and_deps(lines):
    """op name -> set of operand op names (same-computation only)."""
    defs = {}
    for ln in lines:
        m = re.match(r"\s*(?:ROOT\s+)?%([\w.\-]+)\s*=", ln)
        if m:
            defs[m.group(1)] = ln
    deps = {}
    for name, ln in defs.items():
        body = ln.split("=", 1)[1]
        deps[name] = {t for t in re.findall(r"%([\w.\-]+)", body)
                      if t in defs and t != name}
    return defs, deps


def _closure(start, deps):
    seen, todo = set(), list(start)
    while todo:
        n = todo.pop()
        if n in seen:
            continue
        seen.add(n)
        todo.extend(deps.get(n, ()))
    return seen


def test_pipelined_program_size_is_o1_in_buckets(hlo_pair):
    """Serial unrolls one all-reduce pair per bucket; the pipeline's scan
    keeps the collective op count constant."""
    serial, pipelined, meta = hlo_pair
    n = meta["serial_buckets"]
    assert n >= 8                    # the A/B really is multi-bucket
    assert _collective_ops(serial) == 2 * n
    assert _collective_ops(pipelined) <= 6


def test_pipelined_collective_overlaps_next_compress(hlo_pair):
    """Inside the scan body, the all-reduce depends only on the loop
    carry — not on the TopK/sort compress ops issued in the same
    iteration — so an async backend can run the compress inside the
    collective's start/done window.  On backends that split collectives,
    additionally require the start/done pair to span the compress."""
    _, pipelined, _ = hlo_pair
    comps = _computations(pipelined)
    body = None
    for name, lines in comps.items():
        blob = "\n".join(lines)
        has_ar = "all-reduce(" in blob or "all-reduce-start(" in blob
        has_compress = "custom-call" in blob or "sort(" in blob
        if has_ar and has_compress:
            body = lines
            break
    assert body is not None, \
        "no computation holds both the collective and the compress — " \
        "the pipeline's scan body should contain both"
    defs, deps = _defs_and_deps(body)
    ar_ops = [n for n, ln in defs.items()
              if "all-reduce(" in ln or "all-reduce-start(" in ln]
    compress_ops = {n for n, ln in defs.items()
                    if "custom-call" in ln or re.search(r"\bsort\(", ln)}
    assert ar_ops and compress_ops
    reached = _closure([t for n in ar_ops for t in deps[n]], deps)
    overlap_blockers = reached & compress_ops
    assert not overlap_blockers, \
        f"the scan body's all-reduce depends on this iteration's " \
        f"compress ({sorted(overlap_blockers)[:4]}...) — the collective " \
        f"must consume only the loop carry"
    # async backends: the done must come after the compress in schedule
    # order, i.e. the start/done pair spans it
    blob = "\n".join(body)
    if "all-reduce-start(" in blob:
        start = blob.index("all-reduce-start(")
        done = blob.index("all-reduce-done(")
        compress_at = blob.index("custom-call")
        assert start < compress_at < done
