"""Paper Fig. 1 + Fig. 2 — impact of K2 on training and test accuracy.

Paper setup: P=32 learners, K1=4, S=4, K2 in {8, 16, 32}, four CNNs on
CIFAR-10.  Here: P=16 learners (CPU budget), same K1/S/K2 grid, MLP on the
gaussian-mixture CIFAR stand-in.  The paper's claim to validate: larger K2
does NOT reduce training convergence and often gives equal-or-better test
accuracy, at 2-4x fewer global reductions.
"""
from __future__ import annotations

from typing import List

from repro.configs.base import HierAvgParams
from repro.core import HierTopology
from benchmarks.common import Row, cls_setup, fmt, run_variant

# equal data budget: rounds * K2 = const (paper: fixed epochs)
TOTAL_STEPS = 192


def run() -> List[Row]:
    setup = cls_setup()
    topo = HierTopology(pods=1, groups=4, local=4)      # P=16, S=4
    rows: List[Row] = []
    for k2 in (8, 16, 32):
        hier = HierAvgParams(k1=4, k2=k2)
        res, us = run_variant(setup, topo=topo, hier=hier,
                              rounds=TOTAL_STEPS // k2, seed=3)
        rows.append((f"fig1_2/k2={k2}", us,
                     fmt(res) + f" global_reductions={TOTAL_STEPS // k2}"))
    return rows
