"""Elastic-membership benchmark: dropout convergence, masked overhead,
reshape round-trip, cross-process fault determinism.

Four sections, machine-readable records in ``RECORDS`` (benchmarks/
run.py writes them to BENCH_elastic.json / .smoke.json):

1. **Dropout convergence** (the PR's headline): the 3-level fleet with
   20% pod-level dropout (``flaky:pod:0.2``) vs the fault-free run on
   the same seed/data.  The ``elastic/dropout20`` record carries the
   final-loss gap and the Theorem 3.2 bound bar priced at the dropout
   run's *effective* participant count
   (``theory.effective_participants``) — ``within_bars`` is CI-gated.

2. **Masked overhead**: a fault schedule that never fires
   (``flaky:0.0``) against the dense round program — the all-ones mask
   must be bit-identical in losses AND add only a small wall-clock
   overhead (the mask is one fused multiply + renormalize per grouped
   mean).  ``overhead_frac`` is CI-gated at a lenient 2-core-container
   bound; the point is catching an accidental second reduction, not
   hardware-grade timing.

3. **Reshape round-trip**: checkpoint a 4-learner fleet mid-run (topk
   error feedback carried), ``elastic_restore`` onto 6 learners, then
   back onto 4 — survivors bit-preserved, joiners donor-cloned with
   zeroed EF residual, round-trip exact (all CI-gated).

4. **Fault determinism**: the mask stream of a mixed
   crash/flaky/straggler schedule, hashed in-process and in a FRESH
   subprocess — must agree (the schedule is a pure function of
   (seed, unit, round); the A/B legs above rely on it).

``run(smoke=True)`` (CI) shortens the convergence legs.

Standalone: PYTHONPATH=src python -m benchmarks.bench_elastic [--smoke]
"""
from __future__ import annotations

import hashlib
import json
import os
import subprocess
import sys
import tempfile
from typing import Dict, List

import jax
import numpy as np

from benchmarks.common import Row, cls_setup, timed_run
from repro.configs.base import HierAvgParams
from repro.core import HierTopology, Simulator, init_state
from repro.core.plan import resolve_plan
from repro.core.theory import (effective_participants, thm32_bound,
                               thm32_condition)
from repro.elastic import (FaultSchedule, elastic_restore,
                           save_elastic_checkpoint)
from repro.optim import sgd

RECORDS: List[Dict] = []

_REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

TOPO = HierTopology(2, 2, 2)
PLAN = "local@2/pod@4/global@8"
DROP = "flaky:pod:0.2"
# Thm 3.2 constants, matching tests/test_hier_avg.py's 3-level sweep
F1, L, M, GAMMA, B = 2.0, 1.0, 1.0, 0.05, 16
# loose ceiling for the masked-program overhead on a noisy shared-CPU
# container; the regression this catches is structural (an extra
# reduction or a broken jit cache), not a few-percent drift
OVERHEAD_CEILING = 0.35

DET_SPEC = "crash:0.1/flaky:pod:0.3:2/straggler:0.5:1.0"
DET_DEADLINES = {"local": 0.5, "pod": 1.0, "global": 2.0}


def _sim(setup, faults=None, seed: int = 3) -> Simulator:
    return Simulator(setup["loss_fn"], setup["init_fn"], setup["sample"],
                     topo=TOPO, hier=HierAvgParams(plan=PLAN),
                     optimizer=sgd(GAMMA), seed=seed, per_learner_batch=B,
                     eval_batch=setup["eval_batch"], faults=faults)


def _dropout_rows(setup, rounds: int, smoke: bool) -> List[Row]:
    rows: List[Row] = []
    res, us = {}, {}
    for name, faults in (("faultfree", None), ("dropout20", DROP)):
        res[name], us[name] = timed_run(_sim(setup, faults), rounds)
    ff, dp = res["faultfree"], res["dropout20"]
    gap = abs(float(dp.eval_losses[-1]) - float(ff.eval_losses[-1]))
    n_eff = effective_participants(TOPO.n_learners, 0.2)
    bar = thm32_bound(F1, L, M, GAMMA, K1=2, K2=8, S=2, P=n_eff, B=B,
                      N=rounds)
    fracs = dp.active_fracs.mean(axis=0)
    RECORDS.append({
        "name": "elastic/faultfree", "us": us["faultfree"],
        "rounds": rounds, "plan": PLAN, "topo": list(TOPO.shape),
        "final_train_loss": float(ff.losses[-1]),
        "final_eval_loss": float(ff.eval_losses[-1]),
        "final_eval_acc": float(ff.eval_accs[-1]), "smoke": smoke,
    })
    RECORDS.append({
        "name": "elastic/dropout20", "us": us["dropout20"],
        "rounds": rounds, "plan": PLAN, "faults": DROP,
        "final_train_loss": float(dp.losses[-1]),
        "final_eval_loss": float(dp.eval_losses[-1]),
        "final_eval_acc": float(dp.eval_accs[-1]),
        "loss_gap": gap, "thm32_bar": float(bar),
        "thm32_condition": bool(thm32_condition(L, GAMMA, K2=8)),
        "within_bars": bool(gap <= bar), "n_eff": float(n_eff),
        "mean_active_frac": {n: float(f) for n, f in
                             zip(("local", "pod", "global"), fracs)},
        "mean_round_wall_s": float(dp.round_wall_s.mean()),
        "smoke": smoke,
    })
    rows.append(("elastic/faultfree", us["faultfree"],
                 f"eval_loss={ff.eval_losses[-1]:.4f}"))
    rows.append(("elastic/dropout20", us["dropout20"],
                 f"eval_loss={dp.eval_losses[-1]:.4f} gap={gap:.4f} "
                 f"bar={bar:.3f} within={gap <= bar} "
                 f"frac={fracs.mean():.3f}"))
    return rows


def _overhead_row(setup, rounds: int, smoke: bool) -> Row:
    import time
    # warm both jit caches first (the elastic program is a different —
    # and bigger — trace than the dense one; compile time is not the
    # claim), then INTERLEAVE the timed reps and take each leg's min:
    # this box's scheduler noise is bimodal and sequential A/B legs
    # would bill one leg's bad luck as the other's overhead
    reps = 2 if smoke else 4
    sims, best, res = {}, {}, {}
    for name, faults in (("dense", None), ("masked", "flaky:0.0")):
        sims[name] = _sim(setup, faults)
        sims[name].run(1)
        best[name] = None
    for _ in range(reps):
        for name, sim in sims.items():
            t0 = time.time()
            res[name] = sim.run(rounds)
            u = (time.time() - t0) / rounds * 1e6
            best[name] = u if best[name] is None else min(best[name], u)
    dense_us, dense_res = best["dense"], res["dense"]
    masked_us, masked_res = best["masked"], res["masked"]
    overhead = (masked_us - dense_us) / dense_us
    identical = bool(np.array_equal(dense_res.losses, masked_res.losses))
    RECORDS.append({
        "name": "elastic/masked_overhead", "us": masked_us,
        "dense_us": dense_us, "overhead_frac": float(overhead),
        "overhead_ceiling": OVERHEAD_CEILING,
        "bit_identical_losses": identical, "rounds": rounds,
        "smoke": smoke,
    })
    return ("elastic/masked_overhead", masked_us,
            f"dense_us={dense_us:.0f} overhead={overhead:+.1%} "
            f"bit_identical={identical}")


def _reshape_row(setup, smoke: bool) -> Row:
    import time
    old_topo, new_topo = HierTopology(1, 2, 2), HierTopology(1, 3, 2)
    hier = HierAvgParams(plan="global@2:topk:0.25")
    sim = Simulator(setup["loss_fn"], setup["init_fn"], setup["sample"],
                    topo=old_topo, hier=hier, optimizer=sgd(GAMMA),
                    seed=13, per_learner_batch=8)
    state = sim.run(2).state
    plan = resolve_plan(hier)

    def rows_of(tree, topo):
        return [np.asarray(x).reshape((-1,) + x.shape[3:])
                for x in jax.tree.leaves(tree)
                if hasattr(x, "ndim") and x.ndim >= 3
                and tuple(x.shape[:3]) == topo.shape]

    with tempfile.TemporaryDirectory() as d:
        d4, d6 = os.path.join(d, "f4"), os.path.join(d, "f6")
        save_elastic_checkpoint(d4, state, old_topo, step=2, plan=sim.plan)
        t0 = time.time()
        like6 = init_state(new_topo, setup["init_fn"], sgd(GAMMA),
                           jax.random.PRNGKey(99), plan=plan)
        got6 = elastic_restore(d4, like6, new_topo=new_topo)
        grow_s = time.time() - t0
        survivors_ok = all(
            np.array_equal(n[:4], o) for o, n in
            zip(rows_of(state.params, old_topo),
                rows_of(got6.params, new_topo)))
        ef_ok = all(
            np.array_equal(n[:4], o) for o, n in
            zip(rows_of(state.comm_state, old_topo),
                rows_of(got6.comm_state, new_topo)))
        err_zeroed = all(
            np.all(n[4:] == 0) for n in
            rows_of(got6.comm_state["global"].err, new_topo))
        save_elastic_checkpoint(d6, got6, new_topo, step=2, plan=sim.plan)
        like4 = init_state(old_topo, setup["init_fn"], sgd(GAMMA),
                           jax.random.PRNGKey(98), plan=plan)
        back = elastic_restore(d6, like4, new_topo=old_topo)
        roundtrip = all(
            np.array_equal(np.asarray(a), np.asarray(b)) for a, b in
            zip(jax.tree.leaves(state.params) +
                jax.tree.leaves(state.comm_state),
                jax.tree.leaves(back.params) +
                jax.tree.leaves(back.comm_state)))
    RECORDS.append({
        "name": "elastic/reshape_roundtrip", "us": grow_s * 1e6,
        "old_learners": old_topo.n_learners,
        "new_learners": new_topo.n_learners,
        "survivors_bit_preserved": bool(survivors_ok),
        "ef_remapped": bool(ef_ok),
        "joiner_err_zeroed": bool(err_zeroed),
        "roundtrip_exact": bool(roundtrip), "smoke": smoke,
    })
    return ("elastic/reshape_roundtrip", grow_s * 1e6,
            f"survivors={survivors_ok} ef={ef_ok} "
            f"err_zeroed={err_zeroed} roundtrip={roundtrip}")


def _determinism_row(smoke: bool) -> Row:
    fs = FaultSchedule(DET_SPEC, TOPO, ("local", "pod", "global"),
                       seed=11, deadlines=DET_DEADLINES)
    here = hashlib.sha256(
        b"".join(fs.active(r).tobytes() for r in range(8))).hexdigest()
    child = (
        "import hashlib, json\n"
        "from repro.core import HierTopology\n"
        "from repro.elastic import FaultSchedule\n"
        "fs = FaultSchedule(%r, HierTopology(2, 2, 2),\n"
        "                   ('local', 'pod', 'global'), seed=11,\n"
        "                   deadlines=%r)\n"
        "h = hashlib.sha256(\n"
        "    b''.join(fs.active(r).tobytes() for r in range(8)))\n"
        "print(json.dumps({'sha': h.hexdigest()}))\n"
        % (DET_SPEC, DET_DEADLINES))
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(_REPO, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    r = subprocess.run([sys.executable, "-c", child], env=env,
                       capture_output=True, text=True, timeout=300)
    sha = (json.loads(r.stdout.strip().splitlines()[-1])["sha"]
           if r.returncode == 0 else None)
    match = bool(sha == here)
    RECORDS.append({
        "name": "elastic/fault_determinism", "us": 0.0,
        "spec": DET_SPEC, "seed": 11, "rounds_hashed": 8,
        "inprocess_sha": here, "subprocess_sha": sha,
        "match": match, "smoke": smoke,
    })
    return ("elastic/fault_determinism", 0.0,
            f"match={match} sha={here[:12]}")


def run(smoke: bool = False) -> List[Row]:
    RECORDS.clear()
    setup = cls_setup(in_dim=16, n_classes=4, hidden=(32,), noise=0.5,
                      seed=11)
    rounds = 4 if smoke else 12
    rows = _dropout_rows(setup, rounds, smoke)
    rows.append(_overhead_row(setup, 3 if smoke else 6, smoke))
    rows.append(_reshape_row(setup, smoke))
    rows.append(_determinism_row(smoke))
    return rows


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    print("name,us_per_call,derived")
    for n, us, derived in run(smoke=smoke):
        print(f"{n},{us:.0f},{derived}")
    with open(os.path.join(
            _REPO, "BENCH_elastic.smoke.json" if smoke
            else "BENCH_elastic.json"), "w") as f:
        json.dump(RECORDS, f, indent=2)
