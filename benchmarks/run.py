"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Mapping:
  bench_k2          -> paper Fig. 1 (train acc) + Fig. 2 (test acc) K2 sweep
  bench_k1_s        -> paper Fig. 3 (K1 sweep) + Fig. 4 (S sweep)
  bench_vs_kavg     -> paper Table 1 (Hier-AVG vs K-AVG, P in {16,32,64})
  bench_large_proxy -> paper Fig. 5 (larger-scale vs K-AVG)
  bench_adaptive_k2 -> paper §3.3 'adaptive K2' remark (beyond-paper ablation)
  bench_layouts     -> beyond-paper per-arch layout optimization sweep
  bench_comm        -> the paper's communication-saving claim, quantified
  bench_compression -> reducer sweep: payload bytes vs converged accuracy
  roofline          -> §Roofline rows from the dry-run artifacts (if present)

Run: PYTHONPATH=src python -m benchmarks.run [--only fig1]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark module name")
    args = ap.parse_args()

    from benchmarks import (bench_adaptive_k2, bench_comm, bench_compression,
                            bench_k1_s, bench_k2, bench_large_proxy,
                            bench_layouts, bench_vs_kavg, roofline)
    suites = [
        ("bench_k2", bench_k2.run),
        ("bench_k1_s", bench_k1_s.run),
        ("bench_vs_kavg", bench_vs_kavg.run),
        ("bench_large_proxy", bench_large_proxy.run),
        ("bench_adaptive_k2", bench_adaptive_k2.run),
        ("bench_layouts", bench_layouts.run),
        ("bench_comm", bench_comm.run),
        ("bench_compression", bench_compression.run),
        ("roofline", roofline.run),
    ]
    print("name,us_per_call,derived")
    failed = 0
    for name, fn in suites:
        if args.only and args.only not in name:
            continue
        try:
            for row in fn():
                n, us, derived = row
                print(f"{n},{us:.0f},{derived}", flush=True)
        except Exception:  # noqa: BLE001
            failed += 1
            print(f"{name},0,ERROR", flush=True)
            traceback.print_exc()
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
