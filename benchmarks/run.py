"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Mapping:
  bench_k2          -> paper Fig. 1 (train acc) + Fig. 2 (test acc) K2 sweep
  bench_k1_s        -> paper Fig. 3 (K1 sweep) + Fig. 4 (S sweep)
  bench_vs_kavg     -> paper Table 1 (Hier-AVG vs K-AVG, P in {16,32,64})
  bench_large_proxy -> paper Fig. 5 (larger-scale vs K-AVG)
  bench_adaptive_k2 -> paper §3.3 'adaptive K2' remark (beyond-paper ablation)
  bench_layouts     -> beyond-paper per-arch layout optimization sweep
  bench_comm        -> the paper's communication-saving claim, quantified
  bench_compression -> reducer sweep: payload bytes vs converged accuracy
  bench_bucketing   -> per-leaf vs bucketed reduction A/B (comm/bucket.py)
  bench_autotune    -> probe -> calibrate -> recommend pipeline (autotune/)
  bench_serving     -> paged continuous batching vs dense wave serving A/B
                       + flash-decode kernel vs oracle (serve/, kernels/)
  bench_elastic     -> elastic membership: 20%-dropout convergence vs the
                       Thm 3.2 bars, masked-reduction overhead, fleet
                       reshape round-trip, fault determinism (elastic/)
  bench_telemetry   -> telemetry plane: gradstats bit-identity on the
                       serial/pipelined/fsdp=2 engines, logger host
                       overhead, measured-vs-modeled reduction walls,
                       Chrome-trace + JSONL round-trips (telemetry/)
  roofline          -> §Roofline rows from the dry-run artifacts (if present)

``bench_bucketing`` additionally writes machine-readable
``BENCH_reduction.json`` at the repo root (schema per row: name, us,
payload_B, collectives; the serial-vs-pipelined A/B rows add n_buckets,
compile_s, warm_us, min_us, speedup_vs_serial, same_hlo_as_serial; the
sharded fsdp=2 A/B rows add wire_payload_B plus reduce_scatter /
all_gather op counts — CI asserts zero bucket all-reduces and half the
replicated wire payload on those) so
successive PRs can track the reduction-path perf trajectory; CI uploads
it as an artifact and fails if the A/B rows go missing.  Likewise
``bench_autotune`` writes ``BENCH_autotune.json`` (the ``calibration``
record with fitted CommModel constants + round-trip fit error, the
``recommended/*`` plan-search records, and the ``controller/*`` adapted
periods); CI runs its probe+calibrate smoke and fails if the calibration
or recommended-plan records go missing.  ``bench_serving`` writes
``BENCH_serving.json`` (per-slot-count dense/paged rows with
tokens_per_s, p99_ms, wasted_ratio, decode_steps and speedup_vs_dense on
the paged rows, plus the flashdecode oracle/kernel pair); CI runs its
2-round smoke and fails if the paged+dense or flashdecode rows go
missing.  ``bench_elastic`` writes ``BENCH_elastic.json`` (the
fault-free vs 20%-pod-dropout convergence pair with loss_gap /
thm32_bar / within_bars, the masked-overhead A/B, the 4->6->4 reshape
round-trip flags, and the cross-process fault-schedule hash); CI runs
its smoke and asserts within_bars, determinism, and the reshape
bit-preservation flags.  ``bench_telemetry`` writes
``BENCH_telemetry.json`` (the three per-engine bit_identical flags, the
logger host-overhead A/B vs its documented ceiling, the
measured-vs-modeled wall agreement with per-point rel errors, and the
trace/JSONL round-trip flags); CI runs its smoke and asserts
bit-identity on every engine, the overhead ceiling, within_tolerance,
and the export flags.

Run: PYTHONPATH=src python -m benchmarks.run [--only fig1] [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

_REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark module name")
    ap.add_argument("--smoke", action="store_true",
                    help="minimal rounds (CI regression canary)")
    args = ap.parse_args()

    if args.only is not None and args.only in "bench_bucketing":
        # >= 8 host devices so bench_bucketing can compile the
        # SPMD-partitioned reduction and count its grouped collectives
        # from HLO; set before the suites import jax (below), and ONLY
        # for a filtered bucketing run so every other suite's timings
        # keep their single-device baseline (in unfiltered full runs
        # bench_bucketing reports collectives=0 instead — use
        # `--only bucketing` for the collective counts, as CI does)
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=8").strip()

    from benchmarks import (bench_adaptive_k2, bench_autotune,
                            bench_bucketing, bench_comm, bench_compression,
                            bench_elastic, bench_k1_s, bench_k2,
                            bench_large_proxy, bench_layouts,
                            bench_serving, bench_telemetry, bench_vs_kavg,
                            roofline)
    suites = [
        ("bench_k2", bench_k2.run),
        ("bench_k1_s", bench_k1_s.run),
        ("bench_vs_kavg", bench_vs_kavg.run),
        ("bench_large_proxy", bench_large_proxy.run),
        ("bench_adaptive_k2", bench_adaptive_k2.run),
        ("bench_layouts", bench_layouts.run),
        ("bench_comm", bench_comm.run),
        ("bench_compression", bench_compression.run),
        ("bench_bucketing",
         lambda: bench_bucketing.run(smoke=args.smoke)),
        ("bench_autotune",
         lambda: bench_autotune.run(smoke=args.smoke)),
        ("bench_serving",
         lambda: bench_serving.run(smoke=args.smoke)),
        ("bench_elastic",
         lambda: bench_elastic.run(smoke=args.smoke)),
        ("bench_telemetry",
         lambda: bench_telemetry.run(smoke=args.smoke)),
        ("roofline", roofline.run),
    ]
    print("name,us_per_call,derived")
    failed = 0
    for name, fn in suites:
        if args.only and args.only not in name:
            continue
        try:
            for row in fn():
                n, us, derived = row
                print(f"{n},{us:.0f},{derived}", flush=True)
        except Exception:  # noqa: BLE001
            failed += 1
            print(f"{name},0,ERROR", flush=True)
            traceback.print_exc()
        records = {"bench_bucketing": (bench_bucketing, "BENCH_reduction"),
                   "bench_autotune": (bench_autotune, "BENCH_autotune"),
                   "bench_serving": (bench_serving, "BENCH_serving"),
                   "bench_elastic": (bench_elastic, "BENCH_elastic"),
                   "bench_telemetry": (bench_telemetry,
                                       "BENCH_telemetry")}
        if name in records and records[name][0].RECORDS:
            # smoke runs go to a sibling file so they never clobber the
            # checked-in full-round snapshot (README "Bucketed reductions")
            mod, stem = records[name]
            fname = f"{stem}.smoke.json" if args.smoke else f"{stem}.json"
            out = os.path.join(_REPO_ROOT, fname)
            with open(out, "w") as f:
                json.dump(mod.RECORDS, f, indent=2)
            print(f"# wrote {out}", file=sys.stderr, flush=True)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
