"""Paper Fig. 5 — larger-scale Hier-AVG vs K-AVG (ImageNet-1K proxy).

Paper: ResNet-18 on ImageNet, P=16, K-AVG K=43 vs Hier-AVG K2=43, K1=20,
S=4; Hier-AVG wins on train AND test accuracy from epoch 1.  Proxy here: a
reduced hymba-1.5b LM trained on a Markov-chain corpus (hardest learnable
synthetic task we have) with the same (K, K1, S) RELATIONSHIPS scaled down:
K-AVG K=12 vs Hier-AVG K2=12, K1=6, S=4.
"""
from __future__ import annotations

import time
from typing import List

import jax

from repro.configs import get_config
from repro.configs.base import HierAvgParams
from repro.core import HierTopology, Simulator
from repro.data.synthetic import make_markov_task, markov_lm_batch
from repro.models import build
from repro.optim import sgd
from benchmarks.common import Row

ROUNDS = 4
SEQ = 32


def run() -> List[Row]:
    cfg = get_config("hymba-1.5b").reduced()
    bundle = build(cfg)
    chain, floor = make_markov_task(cfg.vocab_size, temperature=2.0)

    def sample(key, n):
        return markov_lm_batch(key, n, SEQ, chain)

    eval_batch = sample(jax.random.PRNGKey(4242), 64)
    topo = HierTopology(1, 4, 4)      # P=16, S=4
    rows: List[Row] = []
    for name, algo, hier in [
        ("fig5/kavg_k12", "kavg", HierAvgParams(12, 12)),
        ("fig5/hier_k2=12_k1=6_s4", "hier", HierAvgParams(6, 12)),
    ]:
        sim = Simulator(bundle.loss_fn, bundle.init, sample, topo=topo,
                        hier=hier, algo=algo, optimizer=sgd(0.5),
                        per_learner_batch=2, eval_batch=eval_batch, seed=17)
        t0 = time.time()
        res = sim.run(ROUNDS)
        us = (time.time() - t0) / ROUNDS * 1e6
        rows.append((name, us,
                     f"train_loss={res.losses[-1]:.4f} "
                     f"test_loss={res.eval_losses[-1]:.4f} "
                     f"entropy_floor={floor:.3f}"))
    return rows
