"""Autotune pipeline benchmark: probe -> calibrate -> recommend.

Three sections, machine-readable records in ``RECORDS`` (benchmarks/
run.py writes them to BENCH_autotune.json / .smoke.json):

1. **Probe + calibrate** (the measured rows): the probe grid runs real
   grouped reductions on the 8-forced-host-device mesh — one FRESH
   subprocess per point, because on this box collective wall-clock is
   bimodal and compile times depend on in-process warm state (see
   autotune/probe.py) — and ``fit_comm_model`` least-squares-fits the
   CommModel.  The ``calibration`` record carries the fitted constants
   plus the round-trip diagnostics: ``median_rel_err`` must stay within
   the documented LOOSE CPU tolerance (``CPU_MEDIAN_REL_ERR`` — 2-core
   container, scheduler-bound collectives; the harness is the
   deliverable here, not hardware-grade constants).

2. **Plan recommendations**: the enumerate-and-rank search under (a)
   the calibration actually measured, (b) a synthetically DCI-skewed
   variant (slow_bw / 32), and (c) a codec-bound variant (compress_bw /
   256) — the recommended plan must shift with the cost model, which is
   the whole point of calibrating.

3. **CostAwarePlan controller**: the adapted periods (pod included)
   under the measured vs the skewed model, at high and low loss — the
   ROADMAP's "adapt the pod period from observed DCI/ICI cost ratios"
   made visible in a benchmark row.

``run(smoke=True)`` (CI) probes the 6-point smoke grid with few reps.

Standalone: PYTHONPATH=src python -m benchmarks.bench_autotune [--smoke]
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.autotune import (CPU_MEDIAN_REL_ERR, CostAwarePlan,
                            default_grid, fit_comm_model, recommend_plan,
                            run_probe)
from repro.core.theory import param_template
from repro.core.topology import HierTopology
from benchmarks.common import Row

RECORDS: List[Dict] = []

# the 2-pod production-shaped view the recommendations are sized for
RECO_TOPO = HierTopology(2, 4, 4)
RECO_TEMPLATE_PARAMS = 1 << 23


def _scenarios(model):
    return (
        ("measured", model),
        ("skewed_dci", dataclasses.replace(model,
                                           slow_bw=model.slow_bw / 32)),
        ("codec_bound", dataclasses.replace(
            model, compress_bw=model.compress_bw / 256)),
    )


def run(smoke: bool = False) -> List[Row]:
    RECORDS.clear()
    rows: List[Row] = []

    # -- 1. probe + calibrate ------------------------------------------ #
    samples = run_probe(default_grid(smoke=smoke), reps=5 if smoke else 12)
    cal = fit_comm_model(samples)
    m = cal.model
    rec = {
        "name": "calibration",
        "fast_bw": m.fast_bw, "slow_bw": m.slow_bw,
        "latency": m.latency, "compress_bw": m.compress_bw,
        "codec_bw": dict(m.codec_bw or ()),
        "fitted": list(cal.fitted), "n_samples": cal.n_samples,
        "median_rel_err": round(cal.median_rel_err, 4),
        "max_rel_err": round(cal.max_rel_err, 4),
        "tolerance_median_rel_err": CPU_MEDIAN_REL_ERR,
        "within_tolerance": cal.median_rel_err <= CPU_MEDIAN_REL_ERR,
        "smoke": smoke,
    }
    RECORDS.append(rec)
    rows.append(("autotune/calibration", 0.0,
                 f"fitted={','.join(cal.fitted)} "
                 f"fast_bw={m.fast_bw:.3e} slow_bw={m.slow_bw:.3e} "
                 f"latency={m.latency:.2e} compress_bw={m.compress_bw:.3e} "
                 f"median_rel_err={cal.median_rel_err:.2f} "
                 f"(tol {CPU_MEDIAN_REL_ERR}) "
                 f"within_tolerance={rec['within_tolerance']}"))
    for s in samples:
        rows.append((
            f"autotune/probe/{s['level']}@{s['tier']}/{s['spec']}"
            f"/{s['payload_bytes']}B/m{s['messages']}", s["min_us"],
            f"warm_us={s['warm_us']:.0f} compile_s={s['compile_s']:.2f} "
            f"n={s['n']}"))

    # -- 2. recommendations under measured vs synthetic skews ---------- #
    template = param_template(RECO_TEMPLATE_PARAMS, n_leaves=32)
    for scen, cm in _scenarios(m):
        best = recommend_plan(RECO_TOPO, cm, template=template)
        RECORDS.append({
            "name": f"recommended/{scen}", "plan": best.spec,
            "comm_s_per_step": best.comm_s_per_step,
            "sec_per_step": best.sec_per_step,
            "objective": best.objective, "score": best.score,
            "outer": best.outer, "feasible": best.feasible,
        })
        rows.append((f"autotune/recommended/{scen}", 0.0,
                     f"plan={best.spec} "
                     f"comm_ms_per_step={best.comm_s_per_step * 1e3:.3f} "
                     f"score={best.score:.3e} feasible={best.feasible}"))

    # -- 3. the cost-aware controller's periods ------------------------ #
    base = "local@2/pod@8/global@32"
    for scen, cm in _scenarios(m)[:2]:
        ctl = CostAwarePlan(base, RECO_TOPO, cm, template=template)
        hi, lo = ctl.periods_for(10.0), ctl.periods_for(1e-4)
        ctl.reset()
        RECORDS.append({
            "name": f"controller/{scen}", "base": base,
            "level_costs_s": [round(c, 9) for c in ctl.level_costs],
            "periods_high_loss": list(hi), "periods_low_loss": list(lo),
        })
        rows.append((f"autotune/controller/{scen}", 0.0,
                     f"base={base} high_loss={hi} low_loss={lo}"))
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    for n, us, d in run(smoke=args.smoke):
        print(f"{n},{us:.0f},{d}")
