"""Telemetry-plane benchmark: bit-identity, host overhead, wall agreement.

Five sections, machine-readable records in ``RECORDS`` (benchmarks/
run.py writes them to BENCH_telemetry.json / .smoke.json):

1. **Bit-identity** (the subsystem's core contract): the device-side
   gradstats are pure observers — enabling ``telemetry=`` on
   ``make_hier_round`` must not move a single bit of the training
   trajectory.  Checked on the SERIAL and PIPELINED bucket engines
   in-process (``telemetry/bit_identity/{serial,pipelined}``) and on the
   fsdp=2 reduce-scatter/all-gather engine in a fresh 16-host-device
   subprocess (``telemetry/bit_identity/sharded``).  All three
   ``bit_identical`` flags are CI-gated.

2. **Host overhead**: a Simulator with a MetricsLogger attached (rows +
   JSONL sink + the per-round ``block_until_ready`` fence the wall
   measurement needs) against the plain buffered run, telemetry OFF in
   both so the delta is pure host plumbing.  Interleaved-min A/B like
   bench_elastic's masked-overhead leg; ``overhead_frac`` is CI-gated at
   a lenient 2-core-container ceiling — the regression this catches is a
   reintroduced per-round device sync, not a few-percent drift.

3. **Wall agreement**: ISSUE 10's "measured round wall agrees with the
   modeled wall".  A full CPU training round is compute-dominated (ms of
   XLA:CPU matmuls the comm model deliberately does not bill), so the
   agreement leg times what the model DOES bill: real grouped-reduction
   programs via ``autotune/probe.py`` (fresh subprocess per point), fits
   a CommModel with ``autotune/calibrate.py``, then reconstructs each
   point's wall through the ``theory.scheduled_wall`` stack —
   ``allreduce_time`` + per-message latency + ``compress_bw_for`` — and
   gates the median relative error at the documented loose CPU
   tolerance (``WALL_MEDIAN_REL_ERR``, mirroring calibrate.py's
   ``CPU_MEDIAN_REL_ERR``).

4. **Trace export**: SpanTracer round-trip — nested spans around a real
   jitted dispatch, exported Chrome trace parses with ``json.load`` and
   every child span nests inside its parent (CI-gated ``ok``).

5. **Row validity**: the JSONL the bit-identity logger leg wrote passes
   ``validate_jsonl`` (schema_version + required keys per subsystem).

``run(smoke=True)`` (CI) shortens rounds and the probe grid.

Standalone: PYTHONPATH=src python -m benchmarks.bench_telemetry [--smoke]
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time
from typing import Dict, List, Optional

import numpy as np

from benchmarks.common import Row, cls_setup
from repro.autotune.calibrate import fit_comm_model, predict_seconds
from repro.autotune.probe import (PROBE_CAP_SMALL, ProbePoint, run_probe)
from repro.configs.base import HierAvgParams
from repro.core import HierTopology, Simulator
from repro.core.theory import scheduled_wall
from repro.telemetry import (MetricsLogger, SpanTracer, validate_jsonl)

RECORDS: List[Dict] = []

_REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

TOPO = HierTopology(2, 2, 2)
# a compressing outer level (auto-bucketed) + a small bucket cap so the
# serial/pipelined engines actually schedule multiple buckets
PLAN = "local@2/pod@4/global@8:topk:0.25"
BUCKET = 1024
GAMMA, B = 0.05, 16
# CI ceiling for the logger's per-round host cost (fence + row build +
# buffered JSONL write) on a noisy 2-core container.  The structural
# regression this catches is a reintroduced per-metric blocking
# device_get in the round loop (the PR-10 hotspot), which costs
# multiples, not fractions.
OVERHEAD_CEILING = 0.5
# loose CPU tolerance for measured-vs-modeled reduction walls; mirrors
# calibrate.CPU_MEDIAN_REL_ERR (0.75) with a little slack because this
# leg round-trips through the scheduled_wall reconstruction rather than
# the fit's own feature matrix
WALL_MEDIAN_REL_ERR = 0.8


def _sim(setup, *, telemetry=None, metrics=None, overlap: bool = True,
         seed: int = 3) -> Simulator:
    hier = HierAvgParams(plan=PLAN, bucket_bytes=BUCKET, overlap=overlap)
    return Simulator(setup["loss_fn"], setup["init_fn"], setup["sample"],
                     topo=TOPO, hier=hier, optimizer=None, seed=seed,
                     per_learner_batch=B, eval_batch=setup["eval_batch"],
                     telemetry=telemetry, metrics=metrics)


# ------------------------------------------------------------------- #
# 1. bit-identity (serial / pipelined in-process, sharded subprocess)

def _bit_identity_rows(setup, rounds: int, smoke: bool,
                       jsonl_path: str) -> List[Row]:
    rows: List[Row] = []
    for engine, overlap in (("serial", False), ("pipelined", True)):
        t0 = time.time()
        off = _sim(setup, overlap=overlap).run(rounds)
        # the logger rides along on the serial leg so section 5 has a
        # JSONL to validate; it cannot move bits (host-side only)
        logger = (MetricsLogger(jsonl_path, flush_every=1)
                  if engine == "serial" else None)
        on = _sim(setup, telemetry=True, metrics=logger,
                  overlap=overlap).run(rounds)
        if logger is not None:
            logger.close()
        us = (time.time() - t0) / rounds * 1e6
        identical = bool(np.array_equal(off.losses, on.losses)
                         and np.array_equal(off.eval_losses,
                                            on.eval_losses))
        n_stats = len(on.stats or {})
        RECORDS.append({
            "name": f"telemetry/bit_identity/{engine}", "us": us,
            "rounds": rounds, "plan": PLAN, "overlap": overlap,
            "bit_identical": identical, "n_stat_keys": n_stats,
            "final_loss_off": float(off.losses[-1]),
            "final_loss_on": float(on.losses[-1]), "smoke": smoke,
        })
        rows.append((f"telemetry/bit_identity/{engine}", us,
                     f"bit_identical={identical} stats={n_stats}"))
    return rows


_SHARDED_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=16")
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from repro.configs.base import HierAvgParams
from repro.configs.resnet18_cifar import MLPConfig
from repro.core import (HierTopology, init_state, make_hier_round,
                        unstack_first)
from repro.data.synthetic import make_classification_task
from repro.models.resnet import mlp_cls_init, mlp_cls_loss
from repro.optim import sgd
from repro.parallel.sharding import shard_plan

cfg = MLPConfig(in_dim=16, hidden=(32,), n_classes=4)
sample = make_classification_task(16, 4, seed=11, noise=0.5)
loss_fn = lambda p, b: mlp_cls_loss(p, b)
eval_batch = sample(jax.random.PRNGKey(123), 256)
topo = HierTopology(2, 2, 2)
B = 16
h = HierAvgParams(k1=2, k2=8,
                  plan="local@2:mean:bucketed/pod@4:mean:bucketed/"
                       "global@8:mean:bucketed")
opt = sgd(0.05)
mesh = Mesh(np.array(jax.devices()[:16]).reshape(2, 2, 2, 2, 1),
            ("pod", "group", "local", "fsdp", "model"))
shards = shard_plan(mesh)


def run(telemetry):
    rnd = jax.jit(make_hier_round(loss_fn, opt, h, shards=shards,
                                  telemetry=telemetry))
    state = init_state(topo, lambda k: mlp_cls_init(k, cfg), opt,
                       jax.random.PRNGKey(0), plan=h.resolved_plan,
                       shards=shards)
    dims = tuple(h.resolved_plan.batch_dims)
    losses, dk, n_stats = [], jax.random.PRNGKey(42), 0
    for r in range(3):
        dk, sk = jax.random.split(dk)
        batch = sample(sk, h.k2 * topo.n_learners * B)
        shaped = jax.tree.map(
            lambda x: x.reshape(dims + topo.shape + (B,) + x.shape[1:]),
            batch)
        state, m = rnd(state, shaped)
        n_stats = sum(1 for k in m if k.startswith("telemetry/"))
        l, _ = loss_fn(unstack_first(state.params), eval_batch)
        losses.append(float(l))
    return losses, n_stats


off, _ = run(None)
on, n_stats = run(True)
print(json.dumps({"off": off, "on": on, "n_stats": n_stats}))
"""


def _sharded_row(smoke: bool) -> Row:
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(_REPO, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    t0 = time.time()
    r = subprocess.run([sys.executable, "-c", _SHARDED_CHILD], env=env,
                       capture_output=True, text=True, timeout=600)
    us = (time.time() - t0) * 1e6
    if r.returncode != 0:
        identical, n_stats, detail = False, 0, r.stderr.strip()[-400:]
    else:
        out = json.loads(r.stdout.strip().splitlines()[-1])
        identical = bool(out["off"] == out["on"])
        n_stats = int(out["n_stats"])
        detail = f"losses={out['on']}"
    RECORDS.append({
        "name": "telemetry/bit_identity/sharded", "us": us,
        "fsdp": 2, "rounds": 3, "bit_identical": identical,
        "n_stat_keys": n_stats, "smoke": smoke,
    })
    return ("telemetry/bit_identity/sharded", us,
            f"bit_identical={identical} stats={n_stats} {detail[:60]}")


# ------------------------------------------------------------------- #
# 2. host overhead of the attached logger (telemetry OFF both legs)

def _overhead_row(setup, rounds: int, smoke: bool) -> Row:
    reps = 2 if smoke else 4
    sims, best, res = {}, {}, {}
    with tempfile.TemporaryDirectory() as d:
        for name in ("plain", "logged"):
            metrics = (MetricsLogger(os.path.join(d, "m.jsonl"))
                       if name == "logged" else None)
            sims[name] = _sim(setup, metrics=metrics)
            sims[name].run(1)       # warm the jit cache
            best[name] = None
        for _ in range(reps):
            for name, sim in sims.items():
                t0 = time.time()
                res[name] = sim.run(rounds)
                u = (time.time() - t0) / rounds * 1e6
                best[name] = u if best[name] is None else min(best[name], u)
        sims["logged"].metrics.close()
    plain_us, logged_us = best["plain"], best["logged"]
    overhead = (logged_us - plain_us) / plain_us
    identical = bool(np.array_equal(res["plain"].losses,
                                    res["logged"].losses))
    walls = res["logged"].measured_wall_s
    RECORDS.append({
        "name": "telemetry/host_overhead", "us": logged_us,
        "plain_us": plain_us, "overhead_frac": float(overhead),
        "overhead_ceiling": OVERHEAD_CEILING,
        "bit_identical_losses": identical,
        "mean_measured_wall_s": float(np.mean(walls)),
        "rounds": rounds, "smoke": smoke,
    })
    return ("telemetry/host_overhead", logged_us,
            f"plain_us={plain_us:.0f} overhead={overhead:+.1%} "
            f"ceiling={OVERHEAD_CEILING:.0%} bit_identical={identical}")


# ------------------------------------------------------------------- #
# 3. measured reduction walls vs the scheduled_wall model

def _wall_points(smoke: bool) -> List[ProbePoint]:
    ici, dci = (1, 2, 4), (2, 2, 2)
    pts = [
        ProbePoint("global", ici, "mean", 8, (64, 64)),
        ProbePoint("global", dci, "mean", 8, (96, 96)),
        ProbePoint("global", ici, "topk:0.05", 8, (160, 160)),
    ]
    if not smoke:
        pts += [
            ProbePoint("global", ici, "mean", 8, (160, 160)),
            ProbePoint("global", ici, "mean", 8, (64, 64),
                       PROBE_CAP_SMALL),
        ]
    return pts


def _modeled_wall_s(cm, s: Dict) -> float:
    """Reconstruct one probe point's wall through the same theory stack
    ``level_reduction_seconds`` bills a serial level with: fused-message
    ring + per-message ring startups, codec compute per dense byte,
    composed by ``scheduled_wall`` on the serial schedule."""
    n, m = s["n"], s["messages"]
    bw = cm.fast_bw if s["tier"] == "ici" else cm.slow_bw
    comm_s = (cm.allreduce_time(s["wire_bytes"], n, bw)
              + (m - 1) * 2.0 * (n - 1) * cm.latency)
    compute_s = (s["dense_bytes"] / cm.compress_bw_for(s.get("codec") or "")
                 if s.get("has_codec", True) else 0.0)
    return scheduled_wall(compute_s / m, comm_s / m, m, False)


def _wall_agreement_row(smoke: bool, reps: int) -> Row:
    t0 = time.time()
    samples = run_probe(points=_wall_points(smoke), reps=reps)
    us = (time.time() - t0) * 1e6
    cal = fit_comm_model(samples)
    rel, per_point = [], []
    for s in samples:
        measured = s["min_us"] * 1e-6
        modeled = _modeled_wall_s(cal.model, s)
        # sanity: the reconstruction must match calibrate.py's own
        # prediction path (same formulas, two code paths)
        assert abs(modeled - predict_seconds(cal.model, s)) \
            <= 1e-9 + 1e-6 * measured
        rel.append(abs(modeled - measured) / measured)
        per_point.append({
            "point": f"{s['level']}@{s['tier']}:{s['spec']}"
                     f":{s['payload_bytes']}B:m{s['messages']}",
            "measured_us": s["min_us"],
            "modeled_us": round(modeled * 1e6, 1),
            "rel_err": round(rel[-1], 3),
        })
    med = float(np.median(rel))
    within = bool(med <= WALL_MEDIAN_REL_ERR)
    RECORDS.append({
        "name": "telemetry/wall_agreement", "us": us,
        "n_points": len(samples), "median_rel_err": med,
        "max_rel_err": float(np.max(rel)),
        "tolerance": WALL_MEDIAN_REL_ERR, "within_tolerance": within,
        "fitted": list(cal.fitted), "points": per_point, "smoke": smoke,
    })
    return ("telemetry/wall_agreement", us,
            f"median_rel_err={med:.2f} tol={WALL_MEDIAN_REL_ERR} "
            f"within={within} points={len(samples)}")


# ------------------------------------------------------------------- #
# 4. Chrome-trace export round-trip    5. JSONL row validity

def _trace_row(smoke: bool) -> Row:
    import jax
    import jax.numpy as jnp

    tracer = SpanTracer()
    f = jax.jit(lambda x: (x @ x).sum())
    x = jnp.ones((64, 64))
    t0 = time.time()
    for r in range(2):
        with tracer.span(f"round[{r}]") as rnd:
            with tracer.span("device", cat="device"):
                tracer.fence(f(x))
            with tracer.span("host_sync"):
                float(f(x))
        tracer.add_modeled_children(rnd, [("compress", 1e-6),
                                          ("collective", 2e-6)])
    us = (time.time() - t0) * 1e6
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "trace.json")
        tracer.export_chrome_trace(path)
        with open(path) as fh:
            doc = json.load(fh)
    events = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    parents = {e["name"]: e for e in events if e["name"].startswith("round")}
    nested = all(
        any(p["ts"] <= e["ts"] and
            e["ts"] + e["dur"] <= p["ts"] + p["dur"] + 1
            for p in parents.values())
        for e in events if not e["name"].startswith("round"))
    ok = bool(len(events) >= 8 and nested)
    RECORDS.append({
        "name": "telemetry/trace_export", "us": us,
        "n_events": len(events), "nested": bool(nested), "ok": ok,
        "smoke": smoke,
    })
    return ("telemetry/trace_export", us,
            f"events={len(events)} nested={nested} ok={ok}")


def _rows_row(jsonl_path: str, rounds: int, smoke: bool) -> Row:
    try:
        rows = validate_jsonl(jsonl_path)
        n_train = sum(1 for r in rows if r["subsystem"] == "train_round")
        stat_keys = sum(1 for k in rows[0] if k.startswith("telemetry/"))
        ok = bool(n_train == rounds and stat_keys > 0)
        detail = ""
    except (ValueError, OSError, IndexError) as e:
        n_train, stat_keys, ok, detail = 0, 0, False, str(e)[:120]
    RECORDS.append({
        "name": "telemetry/rows", "us": 0.0, "n_train_rows": n_train,
        "n_stat_keys_in_row": stat_keys, "rows_ok": ok, "smoke": smoke,
    })
    return ("telemetry/rows", 0.0,
            f"train_rows={n_train} stat_keys={stat_keys} ok={ok} {detail}")


# ------------------------------------------------------------------- #

def run(smoke: bool = False) -> List[Row]:
    RECORDS.clear()
    setup = cls_setup(in_dim=16, n_classes=4, hidden=(32,), noise=0.5,
                      seed=11)
    rounds = 3 if smoke else 8
    rows: List[Row] = []
    with tempfile.TemporaryDirectory() as d:
        jsonl = os.path.join(d, "metrics.jsonl")
        rows += _bit_identity_rows(setup, rounds, smoke, jsonl)
        rows.append(_rows_row(jsonl, rounds, smoke))
    rows.append(_sharded_row(smoke))
    rows.append(_overhead_row(setup, 3 if smoke else 6, smoke))
    rows.append(_wall_agreement_row(smoke, reps=6 if smoke else 12))
    rows.append(_trace_row(smoke))
    return rows


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    print("name,us_per_call,derived")
    for n, us, derived in run(smoke=smoke):
        print(f"{n},{us:.0f},{derived}")
    with open(os.path.join(
            _REPO, "BENCH_telemetry.smoke.json" if smoke
            else "BENCH_telemetry.json"), "w") as f:
        json.dump(RECORDS, f, indent=2)
