"""Paper §3.3 remark — "adaptive choice of K2 may be better for convergence"
(beyond-paper ablation).

Compares static K2=8, static K2=32, and the AdaptiveK2 ladder (start at 32
while far from the optimum, shrink as the loss falls) at an equal total
step budget, counting global reductions actually paid.
"""
from __future__ import annotations

import time
from typing import List

import jax

from repro.configs.base import HierAvgParams
from repro.core import AdaptiveK2, HierTopology, Simulator
from repro.core.hier_avg import init_state, make_hier_round
from repro.optim import sgd
from benchmarks.common import Row, cls_setup

TOTAL_STEPS = 192
K1 = 4


def _run_static(setup, k2: int):
    topo = HierTopology(1, 4, 4)
    sim = Simulator(setup["loss_fn"], setup["init_fn"], setup["sample"],
                    topo=topo, hier=HierAvgParams(K1, k2), optimizer=sgd(0.1),
                    per_learner_batch=16, eval_batch=setup["eval_batch"],
                    seed=23)
    t0 = time.time()
    res = sim.run(TOTAL_STEPS // k2)
    us = (time.time() - t0) / (TOTAL_STEPS // k2) * 1e6
    return res, us, TOTAL_STEPS // k2


def _run_adaptive(setup):
    """Round-by-round K2 from the controller (round fns cached per K2)."""
    topo = HierTopology(1, 4, 4)
    opt = sgd(0.1)
    ctl = AdaptiveK2(k1=K1, k2_max=32)
    state = init_state(topo, setup["init_fn"], opt, jax.random.PRNGKey(23))
    fns, key = {}, jax.random.PRNGKey(99)
    steps = syncs = 0
    loss = None
    t0 = time.time()
    import jax.numpy as jnp
    while steps < TOTAL_STEPS:
        h = ctl.params_for(loss if loss is not None else 1e9)
        if h.k2 not in fns:
            fns[h.k2] = jax.jit(make_hier_round(setup["loss_fn"], opt, h))
        key, kb = jax.random.split(key)
        n = h.k2 * topo.n_learners * 16
        batch = setup["sample"](kb, n)
        shaped = jax.tree.map(
            lambda x: x.reshape((h.beta, h.k1) + topo.shape + (16,)
                                + x.shape[1:]), batch)
        state, metrics = fns[h.k2](state, shaped)
        loss = float(metrics["loss"])
        steps += h.k2
        syncs += 1
    dt = time.time() - t0
    el, em = jax.jit(setup["loss_fn"])(
        jax.tree.map(lambda x: x[0, 0, 0], state.params),
        setup["eval_batch"])
    return float(el), float(em["accuracy"]), syncs, dt / syncs * 1e6


def run() -> List[Row]:
    setup = cls_setup()
    rows: List[Row] = []
    for k2 in (8, 32):
        res, us, syncs = _run_static(setup, k2)
        rows.append((f"adaptive_k2/static_k2={k2}", us,
                     f"test_loss={res.eval_losses[-1]:.4f} "
                     f"test_acc={res.eval_accs[-1]:.4f} "
                     f"global_reductions={syncs}"))
    el, ea, syncs, us = _run_adaptive(setup)
    rows.append(("adaptive_k2/adaptive(32->4)", us,
                 f"test_loss={el:.4f} test_acc={ea:.4f} "
                 f"global_reductions={syncs}"))
    return rows
