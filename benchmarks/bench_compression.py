"""Reducer sweep: payload bytes vs converged accuracy (comm/).

For each reducer x (K1, K2, S) grid point, run the simulator on the shared
classification task and report the per-learner global-reduction payload,
the compression factor vs the dense fp32 mean, and the converged eval
accuracy (delta vs dense mean on the same grid point).  This quantifies the
PR's claim: reductions can be sparse in *payload* (topk 10% -> ~5x fewer
wire bytes) on top of the paper's sparsity in *time* (K2 >> K1), at parity
accuracy.
"""
from __future__ import annotations

from typing import List

from repro.configs.base import HierAvgParams
from repro.core import HierTopology, Simulator
from repro.optim import sgd
from benchmarks.common import Row, cls_setup, timed_run

REDUCERS = ("mean", "cast:bfloat16", "qint8:128", "topk:0.1", "randk:0.1")
GRID = (  # (K1, K2, S) with P = 8 learners
    (2, 8, 4),
    (4, 16, 2),
)
ROUNDS = 12


def _measure(setup, topo, k1: int, k2: int, spec: str):
    hier = HierAvgParams(k1=k1, k2=k2, reducer=spec)
    sim = Simulator(setup["loss_fn"], setup["init_fn"],
                    setup["sample"], topo=topo, hier=hier,
                    optimizer=sgd(0.1), per_learner_batch=16,
                    eval_batch=setup["eval_batch"], seed=3)
    res, us = timed_run(sim, ROUNDS)
    return res, us, sim.payload_bytes_per_reduction()


def run() -> List[Row]:
    setup = cls_setup()
    rows: List[Row] = []
    for k1, k2, s in GRID:
        topo = HierTopology(pods=1, groups=8 // s, local=s)
        # the dense fp32 baseline runs FIRST, explicitly — every other row
        # divides by its payload/accuracy, so it must not depend on where
        # (or whether) "mean" appears in REDUCERS
        dense_res, dense_us, dense_bytes = _measure(setup, topo, k1, k2,
                                                    "mean")
        dense_acc = dense_res.final_eval_acc
        for spec in REDUCERS:
            if spec == "mean":
                res, us, payload = dense_res, dense_us, dense_bytes
            else:
                res, us, payload = _measure(setup, topo, k1, k2, spec)
            derived = (f"payload_B={payload} "
                       f"reduction_x={dense_bytes / payload:.2f} "
                       f"eval_acc={res.final_eval_acc:.4f} "
                       f"acc_vs_dense={res.final_eval_acc - dense_acc:+.4f}")
            rows.append(
                (f"compress/K1={k1},K2={k2},S={s}/{spec}", us, derived))
    return rows
