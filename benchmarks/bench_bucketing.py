"""Per-leaf vs bucketed reduction A/B (comm/bucket.py).

Three measurements per reducer variant on a deep (many-leaf) MLP:

  * wall-clock per Hier-AVG round (Simulator, CPU),
  * analytic per-learner payload bytes of one global reduction,
  * grouped collectives per global reduction, counted from compiled HLO
    (launch/hlo_analysis.py) of the reduction jitted over an 8-way
    learner mesh — this needs >= 8 host devices
    (``--xla_force_host_platform_device_count``, set by benchmarks/run.py
    and by this module when run standalone); with fewer devices the
    collective count is reported as 0 with a note.

The headline claim: bucketing turns O(n_leaves) grouped collectives into
O(n_buckets) per reduction at unchanged payload, with no wall-clock
regression — and gives topk a global k-of-the-model selection.

``run(smoke=True)`` (CI) does 2 rounds instead of 12.  Machine-readable
records for BENCH_reduction.json are left in ``RECORDS``.

Standalone: PYTHONPATH=src python -m benchmarks.bench_bucketing [--smoke]
"""
from __future__ import annotations

import os

if "jax" not in __import__("sys").modules:   # standalone: force devices
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8").strip()

from typing import Dict, List   # noqa: E402

import jax                      # noqa: E402
import numpy as np              # noqa: E402
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P  # noqa: E402

from repro.comm import reduce_with                      # noqa: E402
from repro.configs.base import HierAvgParams            # noqa: E402
from repro.core import HierTopology, Simulator          # noqa: E402
from repro.core.plan import resolve_plan                # noqa: E402
from repro.core.topology import global_average, stack_like  # noqa: E402
from repro.launch import hlo_analysis as ha             # noqa: E402
from repro.optim import sgd                             # noqa: E402
from benchmarks.common import Row, cls_setup, timed_run  # noqa: E402

# deep-ish MLP: 7 layers x (w, b) = 14 leaves, so the per-leaf path pays
# 14 grouped collectives where the bucketed path pays 1 (one f32 bucket)
HIDDEN = (48,) * 6
VARIANTS = (
    ("mean", "mean", 0),                 # dense reference (never bucketed)
    ("topk:0.05:perleaf", "topk:0.05", 0),
    ("topk:0.05:bucketed", "topk:0.05", 4 << 20),
    ("qint8:128:perleaf", "qint8:128", 0),
    ("qint8:128:bucketed", "qint8:128", 4 << 20),
)
ROUNDS = 12

# machine-readable rows for BENCH_reduction.json (benchmarks/run.py)
RECORDS: List[Dict] = []


def _hlo_collectives(reducer, init_fn) -> int:
    """Grouped all-reduces one global reduction dispatches, from the
    compiled (SPMD-partitioned) HLO over an 8-learner mesh."""
    if jax.device_count() < 8:
        return 0
    topo = HierTopology(1, 2, 4)
    mesh = Mesh(np.array(jax.devices()[:8]).reshape(topo.shape),
                ("pod", "group", "local"))

    params1 = jax.eval_shape(init_fn, jax.ShapeDtypeStruct((2,), np.uint32))
    params = jax.eval_shape(lambda p: stack_like(topo, p), params1)
    state = jax.eval_shape(reducer.init_state, params)

    def shard(leaf):
        spec = P("pod", "group", "local") if leaf.ndim >= 3 else P()
        return NamedSharding(mesh, spec)

    def reduction(p, s):
        return reduce_with(reducer, global_average, p, s)

    shardings = (jax.tree.map(shard, params), jax.tree.map(shard, state))
    hlo = jax.jit(reduction, in_shardings=shardings) \
        .lower(params, state).compile().as_text()
    summary = ha.collective_summary(ha.parse_collectives(hlo))
    return summary.get("all-reduce", {}).get("count", 0)


def run(smoke: bool = False) -> List[Row]:
    RECORDS.clear()
    setup = cls_setup(hidden=HIDDEN)
    rounds = 2 if smoke else ROUNDS
    topo = HierTopology(1, 2, 2)
    rows: List[Row] = []
    for name, spec, bucket_bytes in VARIANTS:
        hier = HierAvgParams(k1=2, k2=4, reducer=spec,
                             bucket_bytes=bucket_bytes)
        sim = Simulator(setup["loss_fn"], setup["init_fn"], setup["sample"],
                        topo=topo, hier=hier, optimizer=sgd(0.1),
                        per_learner_batch=16,
                        eval_batch=setup["eval_batch"], seed=7)
        res, us = timed_run(sim, rounds)
        payload = sim.payload_bytes_per_reduction()
        global_red = resolve_plan(hier).levels[-1].reducer
        colls = _hlo_collectives(global_red, setup["init_fn"])
        derived = (f"payload_B={payload} collectives={colls} "
                   f"eval_acc={res.final_eval_acc:.4f}")
        rows.append((f"bucketing/{name}", us, derived))
        RECORDS.append({"name": name, "us": round(us, 1),
                        "payload_B": payload, "collectives": colls})
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    for n, us, d in run(smoke=args.smoke):
        print(f"{n},{us:.0f},{d}")
