"""Per-leaf vs bucketed vs pipelined reduction A/B (comm/bucket.py).

Two sections, both on 8 forced host devices (benchmarks/run.py sets
``--xla_force_host_platform_device_count=8`` for ``--only bucketing``;
this module does the same standalone):

1. **Full training rounds** (Simulator, single device — the PR 3 rows):
   wall-clock per Hier-AVG round, analytic per-learner payload bytes, and
   grouped collectives per global reduction counted from the compiled
   SPMD HLO.  The bucketed rows pin the serial schedule so they stay
   comparable with the PR 3 snapshot.

2. **Reduction-schedule A/B** (the tentpole rows): the jitted global
   reduction of a 12-leaf/3 MB stacked tree over the 8-way learner mesh,
   serial ``Bucketed`` vs the double-buffered ``Pipelined`` engine, at a
   large cap (1 bucket — the schedules coincide) and a small cap
   (12 buckets — the pipeline has stages to overlap).  ``us`` is
   build+compile+``rounds`` executions per round — compile included, like
   every other row in this harness, because program size is where the
   scan-based pipeline wins on CPU: the serial path unrolls one
   compress/collective chain per bucket (O(n_buckets) HLO, one
   ``all-reduce`` pair per bucket), the pipeline compiles one scan body
   (O(1) HLO, collectives hoisted into the loop).  ``collectives`` for
   these rows is the all-reduce *op count in the program* — the
   program-size claim, 2 per bucket serial vs O(1) pipelined.  The
   ``topk:0.05:pipelined`` record carries ``speedup_vs_serial`` — the
   acceptance bar is >= 1.2x over the serial baseline at the same cap.

3. **Codec-kernel A/B** (the codec rows): per codec family, the legacy
   baseline vs the kernel/engine path this PR lands — ``powersgd:2``
   per-leaf (two collectives per leaf, per-leaf QR) vs pipelined
   matrix-bucketed (two collectives per four-leaf bucket, batched QR,
   EF finalized inside the scan), and ``qint8:128:twopass`` per-leaf
   (separate int8 + scale messages) vs the fused single-buffer pack
   pipelined (ONE message per bucket).  Bucket cap ``AB_CODEC_CAP``
   keeps 6 four-leaf buckets so the message-count collapse is visible
   in the records (``messages``); the pipelined rows carry
   ``speedup_vs_serial`` over their per-leaf baseline.  Alongside, the
   ``kernels/*`` records pin Pallas-kernel (interpret mode on CPU) vs
   XLA-oracle parity: ``max_abs_diff_vs_oracle`` per kernel.

4. **Sharded RS/AG A/B** (the fsdp>1 rows): the same global reduction
   with every learner 2-way fsdp-sharded (4 learners x 2 shards = the
   same 8 host devices) vs the replicated baseline at the same learner
   topology.  The sharded rows record the collective op mix (zero bucket
   all-reduces; reduce-scatter + all-gather instead) and
   ``wire_payload_B`` — the per-host wire bytes, half the replicated
   payload because each host compresses and ships only its own shard
   slice.

``run(smoke=True)`` (CI) does 2 rounds instead of 12.  Machine-readable
records for BENCH_reduction.json are left in ``RECORDS``.

Standalone: PYTHONPATH=src python -m benchmarks.bench_bucketing [--smoke]
"""
from __future__ import annotations

import json
import os
import time

if "jax" not in __import__("sys").modules:   # standalone: force devices
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8").strip()

from typing import Dict, List   # noqa: E402

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P  # noqa: E402

from repro.comm import reduce_with                      # noqa: E402
from repro.configs.base import HierAvgParams            # noqa: E402
from repro.core import HierTopology, Simulator          # noqa: E402
from repro.core.plan import resolve_plan                # noqa: E402
from repro.core.topology import global_average, stack_like  # noqa: E402
from repro.launch import hlo_analysis as ha             # noqa: E402
from repro.optim import sgd                             # noqa: E402
from benchmarks.common import Row, cls_setup, timed_run  # noqa: E402

# deep-ish MLP: 7 layers x (w, b) = 14 leaves, so the per-leaf path pays
# 14 grouped collectives where the bucketed path pays 1 (one f32 bucket)
HIDDEN = (48,) * 6
# (row name, reducer spec, bucket_bytes, overlap) — overlap=False pins the
# PR 3 serial schedule so the snapshot rows stay comparable across PRs
VARIANTS = (
    ("mean", "mean", 0, False),              # dense reference (never bucketed)
    ("topk:0.05:perleaf", "topk:0.05", 0, False),
    ("topk:0.05:bucketed", "topk:0.05", 4 << 20, False),
    ("qint8:128:perleaf", "qint8:128", 0, False),
    ("qint8:128:bucketed", "qint8:128", 4 << 20, False),
)
ROUNDS = 12

# -- reduction-schedule A/B: shape and builder shared with
# tests/test_pipeline.py via repro.testing (both must measure the SAME
# program).  Each variant is measured in a FRESH subprocess so neither
# engine inherits the other's warm XLA/LLVM state — on a small CPU box
# the wall-clock of host-device collectives is noisy, and the bucket
# count is chosen high enough that the structural gap (serial compiles
# one compress/collective chain per bucket, the pipeline one scan body)
# dominates that noise.
from repro.testing import (AB_LARGE_CAP, AB_SMALL_CAP,  # noqa: E402
                           build_ab_reduction, build_sharded_ab_reduction,
                           count_allreduce_ops, count_collective_ops)

# machine-readable rows for BENCH_reduction.json (benchmarks/run.py)
RECORDS: List[Dict] = []


def _hlo_collectives(reducer, init_fn) -> int:
    """Grouped all-reduces one global reduction dispatches, from the
    compiled (SPMD-partitioned) HLO over an 8-learner mesh."""
    if jax.device_count() < 8:
        return 0
    topo = HierTopology(1, 2, 4)
    mesh = Mesh(np.array(jax.devices()[:8]).reshape(topo.shape),
                ("pod", "group", "local"))

    params1 = jax.eval_shape(init_fn, jax.ShapeDtypeStruct((2,), np.uint32))
    params = jax.eval_shape(lambda p: stack_like(topo, p), params1)
    state = jax.eval_shape(reducer.init_state, params)

    def shard(leaf):
        spec = P("pod", "group", "local") if leaf.ndim >= 3 else P()
        return NamedSharding(mesh, spec)

    def reduction(p, s):
        return reduce_with(reducer, global_average, p, s)

    shardings = (jax.tree.map(shard, params), jax.tree.map(shard, state))
    hlo = jax.jit(reduction, in_shardings=shardings) \
        .lower(params, state).compile().as_text()
    summary = ha.collective_summary(ha.parse_collectives(hlo))
    return summary.get("all-reduce", {}).get("count", 0)


def _ab_measure(sched: str, cap: int, rounds: int, *,
                spec: str = "topk:0.05",
                sharded: bool = False, topo_shape=None) -> Dict:
    """One A/B variant, measured in THIS process (the child side of the
    subprocess-per-variant harness): build the shared reduction
    (repro.testing — same program tests/test_pipeline.py verifies),
    compile, execute ``rounds`` times.  ``us`` is
    (compile + executions) / rounds — compile included, like every other
    row in this harness; ``warm_us``/``min_us`` summarize the per-round
    executions.  ``sharded=True`` builds the fsdp=2 variant (same
    builder tests/test_sharded.py verifies) whose buckets reduce via
    reduce-scatter + all-gather instead of all-reduce."""
    import hashlib
    build = build_sharded_ab_reduction if sharded else build_ab_reduction
    kw = {"topo_shape": tuple(topo_shape)} if topo_shape else {}
    b = build(sched, cap, spec=spec, **kw)
    p_sh = jax.device_put(b["params"], b["shardings"][0])
    s_sh = jax.device_put(b["state"], b["shardings"][1])

    t0 = time.time()
    # execute through the AOT-compiled executable: calling the jitted fn
    # would trace+compile a second time (the jit dispatch cache is
    # separate from the AOT path), double-counting compile in `us`
    compiled = b["fn"].lower(p_sh, s_sh).compile()
    compile_s = time.time() - t0
    per_exec = []
    for _ in range(rounds):
        t1 = time.time()
        out = jax.block_until_ready(compiled(p_sh, s_sh))  # noqa: F841
        per_exec.append(time.time() - t1)
    us = (compile_s + sum(per_exec)) / rounds * 1e6
    txt = compiled.as_text()
    ops = count_collective_ops(txt)
    return {
        "us": round(us, 1),
        "payload_B": b["reducer"].payload_bytes(b["tree1"]),
        # what actually crosses the wire per host: == payload_B when
        # replicated, payload_B / shards for the sharded rows
        "wire_payload_B": b["reducer"].wire_payload_bytes(b["tree1"]),
        "collectives": count_allreduce_ops(txt),
        "reduce_scatter": ops["reduce_scatter"],
        "all_gather": ops["all_gather"],
        # analytic grouped-collective dispatch count — the quantity the
        # fused qint8 pack (2 msgs -> 1 per bucket) and matrix bucketing
        # (2 msgs per leaf -> per bucket) collapse
        "messages": int(b["reducer"].n_messages(b["tree1"])),
        "n_buckets": b["n_buckets"],
        "compile_s": round(compile_s, 2),
        "warm_us": round(float(np.median(per_exec)) * 1e6, 1),
        "min_us": round(min(per_exec) * 1e6, 1),
        "hlo_md5": hashlib.md5(txt.encode()).hexdigest(),
    }


def _reduction_ab(rounds: int) -> List[Row]:
    """Serial vs pipelined reduction schedule, small vs large buckets,
    on the 8-host-device mesh — one fresh subprocess per variant so the
    engines compile and run under identical conditions."""
    import subprocess
    import sys

    rows: List[Row] = []
    repo = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8"
                        ).strip()

    serial_rec: Dict[str, Dict] = {}
    for cap, cap_tag in ((AB_LARGE_CAP, "@1bucket"), (AB_SMALL_CAP, "")):
        for sched in ("serial", "pipelined"):
            name = f"topk:0.05:{sched}{cap_tag}"
            r = subprocess.run(
                [sys.executable, "-m", "benchmarks.bench_bucketing",
                 "--ab-variant", sched, "--ab-cap", str(cap),
                 "--rounds", str(rounds)],
                env=env, cwd=repo, capture_output=True, text=True,
                timeout=900)
            if r.returncode != 0:
                rows.append((f"bucketing/red8/{name}", 0.0,
                             "ERROR " + r.stderr.strip()[-200:]))
                continue
            rec = json.loads(r.stdout.strip().splitlines()[-1])
            md5 = rec.pop("hlo_md5")
            rec["name"] = name
            if sched == "serial":
                serial_rec[cap_tag] = {"us": rec["us"], "md5": md5}
            else:
                base = serial_rec.get(cap_tag)
                if base:
                    rec["speedup_vs_serial"] = round(
                        base["us"] / rec["us"], 2)
                    # single-bucket layouts fall back to the serial
                    # schedule — identical programs; any timing delta in
                    # that pair is harness noise, and the record says so
                    rec["same_hlo_as_serial"] = (md5 == base["md5"])
            RECORDS.append(rec)
            derived = (f"n_buckets={rec['n_buckets']} "
                       f"hlo_all_reduces={rec['collectives']} "
                       f"compile_s={rec['compile_s']:.2f} "
                       f"warm_us={rec['warm_us']:.0f}"
                       + (f" speedup_vs_serial="
                          f"{rec.get('speedup_vs_serial', 0):.2f} "
                          f"same_hlo={rec.get('same_hlo_as_serial')}"
                          if sched == "pipelined" else ""))
            rows.append((f"bucketing/red8/{name}", rec["us"], derived))
    return rows


# codec A/B bucket cap: 24 leaves x 24 KiB -> 4 leaves per bucket -> 6
# buckets, so the per-bucket message bill is visibly below the per-leaf
# one (powersgd 48 -> 12 msgs, fused qint8 48 -> 6) while the pipeline
# still has stages to overlap
AB_CODEC_CAP = 96 << 10


def _codec_ab(rounds: int) -> List[Row]:
    """Per-codec baseline-vs-kernel-path A/B (module docstring §3):
    subprocess-per-variant like :func:`_reduction_ab`, the pipelined row
    of each pair carries ``speedup_vs_serial`` over its per-leaf
    baseline."""
    import subprocess
    import sys

    rows: List[Row] = []
    repo = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8"
                        ).strip()

    pairs = (
        # (row name, child variant, reducer spec); first of each pair is
        # the baseline the second's speedup is measured against
        (("powersgd:2:perleaf", "perleaf", "powersgd:2"),
         ("powersgd:2:pipelined", "pipelined", "powersgd:2")),
        (("qint8:128:twopass:perleaf", "perleaf", "qint8:128:twopass"),
         ("qint8:128:pipelined", "pipelined", "qint8:128")),
    )
    for (base_name, base_var, base_spec), (name, var, spec) in pairs:
        base_rec = None
        for nm, v, sp in ((base_name, base_var, base_spec),
                          (name, var, spec)):
            r = subprocess.run(
                [sys.executable, "-m", "benchmarks.bench_bucketing",
                 "--ab-variant", v, "--ab-cap", str(AB_CODEC_CAP),
                 "--ab-spec", sp, "--rounds", str(rounds)],
                env=env, cwd=repo, capture_output=True, text=True,
                timeout=900)
            if r.returncode != 0:
                rows.append((f"bucketing/codec/{nm}", 0.0,
                             "ERROR " + r.stderr.strip()[-200:]))
                continue
            rec = json.loads(r.stdout.strip().splitlines()[-1])
            rec.pop("hlo_md5", None)
            rec["name"] = nm
            if v == "perleaf":
                base_rec = rec
            elif base_rec:
                rec["speedup_vs_serial"] = round(
                    base_rec["us"] / rec["us"], 2)
                rec["baseline"] = base_name
            RECORDS.append(rec)
            derived = (f"n_buckets={rec['n_buckets']} "
                       f"messages={rec['messages']} "
                       f"hlo_all_reduces={rec['collectives']} "
                       f"compile_s={rec['compile_s']:.2f}"
                       + (f" speedup_vs_serial="
                          f"{rec.get('speedup_vs_serial', 0):.2f}"
                          if v == "pipelined" else ""))
            rows.append((f"bucketing/codec/{nm}", rec["us"], derived))
    return rows


def _kernel_parity() -> List[Row]:
    """Pallas codec-kernel vs XLA-oracle parity records (interpret mode
    — the same kernel program a TPU would run, executed on CPU).  Pinned
    in BENCH_reduction.json so CI catches kernel drift without TPU
    hardware: batched QR compares projectors QQ^T (the kernel's CGS2
    sign convention differs from LAPACK's), fused qint8 must match the
    legacy two-pass quantizer bit-exactly under jit."""
    from repro.comm.quant import dequantize_block, quantize_block
    from repro.kernels import ops

    rows: List[Row] = []
    key = jax.random.PRNGKey(0)

    p = jax.random.normal(key, (8, 96, 4), dtype=jnp.float32)
    proj = lambda q: jnp.einsum("bij,bkj->bik", q, q)  # noqa: E731
    t0 = time.time()
    q_k = ops.batched_qr(p, impl="pallas_interpret")
    qr_us = (time.time() - t0) * 1e6
    qr_diff = float(jnp.max(jnp.abs(
        proj(q_k) - proj(ops.batched_qr(p, impl="xla")))))
    rec = {"name": "kernels/batched_qr", "impl": "pallas_interpret",
           "us": round(qr_us, 1), "shape": list(p.shape),
           "max_abs_diff_vs_oracle": qr_diff}
    RECORDS.append(rec)
    rows.append(("bucketing/kernels/batched_qr", round(qr_us, 1),
                 f"max_abs_diff_vs_oracle={qr_diff:.2e}"))

    x = jax.random.normal(jax.random.fold_in(key, 1), (5, 1000),
                          dtype=jnp.float32)
    roundtrip = jax.jit(lambda x: ops.qint8_unpack(
        ops.qint8_pack(x, 128, impl="pallas_interpret"), x.shape[1],
        impl="pallas_interpret"))
    legacy = jax.jit(lambda x: dequantize_block(
        *quantize_block(x, 128), x.shape[1]))
    t0 = time.time()
    got = roundtrip(x)
    q_us = (time.time() - t0) * 1e6
    q_diff = float(jnp.max(jnp.abs(got - legacy(x))))
    rec = {"name": "kernels/qint8_pack", "impl": "pallas_interpret",
           "us": round(q_us, 1), "shape": list(x.shape), "block": 128,
           "max_abs_diff_vs_oracle": q_diff}
    RECORDS.append(rec)
    rows.append(("bucketing/kernels/qint8_pack", round(q_us, 1),
                 f"max_abs_diff_vs_oracle={q_diff:.2e}"))
    return rows


def _sharded_ab(rounds: int) -> List[Row]:
    """All-reduce vs reduce-scatter+all-gather A/B at the SAME 4-learner
    topology: the fsdp=1 replicated baseline reduces full buckets with
    grouped all-reduces; the fsdp=2 rows (4 learners x 2 shards, all 8
    host devices) must show zero bucket all-reduces, reduce-scatter +
    all-gather instead, and half the wire payload (each host ships only
    the shard slice it owns).  Fresh subprocess per variant, same
    harness rationale as :func:`_reduction_ab`."""
    import subprocess
    import sys

    rows: List[Row] = []
    repo = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8"
                        ).strip()

    variants = (
        # replicated baseline on the sharded rows' learner topology
        ("topk:0.05:serial@4L",
         ["--ab-variant", "serial", "--ab-topo", "1,2,2"]),
        ("topk:0.05:serial:sharded",
         ["--ab-variant", "serial", "--ab-sharded"]),
        ("topk:0.05:pipelined:sharded",
         ["--ab-variant", "pipelined", "--ab-sharded"]),
    )
    for name, extra in variants:
        r = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_bucketing", *extra,
             "--ab-cap", str(AB_SMALL_CAP), "--rounds", str(rounds)],
            env=env, cwd=repo, capture_output=True, text=True,
            timeout=900)
        if r.returncode != 0:
            rows.append((f"bucketing/sharded/{name}", 0.0,
                         "ERROR " + r.stderr.strip()[-200:]))
            continue
        rec = json.loads(r.stdout.strip().splitlines()[-1])
        rec.pop("hlo_md5", None)
        rec["name"] = name
        RECORDS.append(rec)
        derived = (f"n_buckets={rec['n_buckets']} "
                   f"all_reduce={rec['collectives']} "
                   f"rs={rec['reduce_scatter']} ag={rec['all_gather']} "
                   f"wire_B={rec['wire_payload_B']} "
                   f"payload_B={rec['payload_B']}")
        rows.append((f"bucketing/sharded/{name}", rec["us"], derived))
    return rows


def run(smoke: bool = False) -> List[Row]:
    RECORDS.clear()
    setup = cls_setup(hidden=HIDDEN)
    rounds = 2 if smoke else ROUNDS
    topo = HierTopology(1, 2, 2)
    rows: List[Row] = []
    for name, spec, bucket_bytes, overlap in VARIANTS:
        hier = HierAvgParams(k1=2, k2=4, reducer=spec,
                             bucket_bytes=bucket_bytes, overlap=overlap)
        sim = Simulator(setup["loss_fn"], setup["init_fn"], setup["sample"],
                        topo=topo, hier=hier, optimizer=sgd(0.1),
                        per_learner_batch=16,
                        eval_batch=setup["eval_batch"], seed=7)
        res, us = timed_run(sim, rounds)
        payload = sim.payload_bytes_per_reduction()
        global_red = resolve_plan(hier).levels[-1].reducer
        colls = _hlo_collectives(global_red, setup["init_fn"])
        derived = (f"payload_B={payload} collectives={colls} "
                   f"eval_acc={res.final_eval_acc:.4f}")
        rows.append((f"bucketing/{name}", us, derived))
        RECORDS.append({"name": name, "us": round(us, 1),
                        "payload_B": payload, "collectives": colls})
    rows.extend(_reduction_ab(rounds))
    rows.extend(_codec_ab(rounds))
    rows.extend(_kernel_parity())
    rows.extend(_sharded_ab(rounds))
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ab-variant",
                    choices=("serial", "pipelined", "perleaf"),
                    default=None, help="child mode: measure ONE "
                    "reduction-schedule variant and print a json record")
    ap.add_argument("--ab-cap", type=int, default=AB_SMALL_CAP)
    ap.add_argument("--ab-spec", default="topk:0.05",
                    help="child mode: reducer spec for the variant "
                         "(the codec A/B passes powersgd/qint8 here)")
    ap.add_argument("--ab-sharded", action="store_true",
                    help="child mode: measure the fsdp=2 sharded variant "
                         "(reduce-scatter + all-gather buckets)")
    ap.add_argument("--ab-topo", default=None,
                    help="child mode: learner topology override, e.g. "
                         "'1,2,2' for the 4-learner replicated baseline")
    ap.add_argument("--rounds", type=int, default=ROUNDS)
    args = ap.parse_args()
    if args.ab_variant:
        topo = tuple(int(x) for x in args.ab_topo.split(",")) \
            if args.ab_topo else None
        print(json.dumps(_ab_measure(args.ab_variant, args.ab_cap,
                                     args.rounds, spec=args.ab_spec,
                                     sharded=args.ab_sharded,
                                     topo_shape=topo)))
    else:
        for n, us, d in run(smoke=args.smoke):
            print(f"{n},{us:.0f},{d}")
