"""Paper Fig. 3 (impact of K1) + Fig. 4 (impact of S) on training loss.

Paper setup: K2=32, P=16; Fig 3 varies K1 in {4, 8} at S=4; Fig 4 varies
S in {2, 4} at K1=4.  Claim (Thm 3.5): smaller K1 and larger S give lower
training loss at the same data budget.
"""
from __future__ import annotations

from typing import List

from repro.configs.base import HierAvgParams
from repro.core import HierTopology
from benchmarks.common import Row, cls_setup, fmt, run_variant

ROUNDS = 8   # x K2=32 steps


def run() -> List[Row]:
    setup = cls_setup()
    rows: List[Row] = []
    # Fig 3: K1 sweep at S=4
    topo = HierTopology(pods=1, groups=4, local=4)
    for k1 in (4, 8):
        hier = HierAvgParams(k1=k1, k2=32)
        res, us = run_variant(setup, topo=topo, hier=hier, rounds=ROUNDS,
                              seed=5)
        rows.append((f"fig3/k1={k1}(s=4)", us, fmt(res)))
    # Fig 4: S sweep at K1=4 (same P=16)
    for groups, s in ((8, 2), (4, 4)):
        topo = HierTopology(pods=1, groups=groups, local=s)
        hier = HierAvgParams(k1=4, k2=32)
        res, us = run_variant(setup, topo=topo, hier=hier, rounds=ROUNDS,
                              seed=5)
        rows.append((f"fig4/s={s}(k1=4)", us, fmt(res)))
    return rows
