"""§Roofline report: reads experiments/dryrun/*.json and emits the
per-(arch x shape x mesh) three-term table (compute / memory / collective
seconds, dominant bottleneck, MODEL_FLOPS/HLO_FLOPs utilization).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_records(pattern: str = "*.json") -> List[Dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, pattern))):
        recs.append(json.load(open(f)))
    return recs


def markdown_table(recs: List[Dict], mesh: str = "1pod-256") -> str:
    lines = [
        "| arch | shape | compute ms | memory ms | collective ms | "
        "bottleneck | useful FLOPs | peak GiB |",
        "|---|---|---:|---:|---:|---|---:|---:|",
    ]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        t = r["roofline"]
        useful = t.get("useful_flops_ratio")
        if useful is None and t.get("model_flops_per_device"):
            useful = t["model_flops_per_device"] / (t["compute_s"] * 197e12)
        lines.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {1e3 * t['compute_s']:.2f} | {1e3 * t['memory_s']:.2f} "
            f"| {1e3 * t['collective_s']:.2f} | {t['bottleneck']} "
            f"| {useful or 0:.2f} "
            f"| {r['memory']['peak_est_bytes'] / 2**30:.2f} |")
    return "\n".join(lines)


def run():
    """Benchmark-harness entry: emit one row per dry-run artifact."""
    rows = []
    for r in load_records():
        t = r["roofline"]
        rows.append((
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            1e6 * max(t["compute_s"], t["memory_s"], t["collective_s"]),
            f"bottleneck={t['bottleneck']} "
            f"c/m/coll_ms={1e3*t['compute_s']:.2f}/"
            f"{1e3*t['memory_s']:.2f}/{1e3*t['collective_s']:.2f}"))
    return rows


if __name__ == "__main__":
    recs = load_records()
    print(markdown_table(recs, "1pod-256"))
    print()
    print(markdown_table(recs, "2pod-512"))
