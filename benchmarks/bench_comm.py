"""Communication accounting — the paper's motivation made quantitative.

For every assigned architecture: reduction seconds per K2-step cycle for
Hier-AVG vs K-AVG under the ring model (theory.CommModel, ICI vs DCI
bandwidths), plus — when the dry-run artifacts exist — the measured
per-device collective link-bytes of the compiled hier_round.
"""
from __future__ import annotations

import glob
import json
import os
from typing import List

from repro.configs import ALL_ARCHS, get_config
from repro.core.theory import CommModel, comm_per_k2_steps
from benchmarks.common import Row

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def run() -> List[Row]:
    cm = CommModel()
    rows: List[Row] = []
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        model_bytes = cfg.param_count() * 2          # bf16
        lay = cfg.layout
        P = max(lay.learners_per_pod, 2)             # >=2 for cross-pod
        S = max(lay.local, 2)
        k1, k2 = 4, 8
        loc, glo = comm_per_k2_steps(model_bytes, k1, k2, P, S, cm)
        _, glo_kavg = comm_per_k2_steps(model_bytes, k2, k2, P, 1, cm)
        hier_ms = (loc + glo) / k2 * 1e3
        kavg_k1 = k1  # K-AVG syncing as often as hier's local cadence
        _, glo_k1 = comm_per_k2_steps(model_bytes, kavg_k1, kavg_k1, P, 1,
                                      cm)
        kavg_ms = glo_k1 / kavg_k1 * 1e3
        derived = (f"hier_ms_per_step={hier_ms:.2f} "
                   f"kavg_same_cadence_ms={kavg_ms:.2f} "
                   f"saving={1 - hier_ms / max(kavg_ms, 1e-12):.1%}")
        f = os.path.join(DRYRUN_DIR, f"{arch}__train_4k__1pod.json")
        if os.path.exists(f):
            rec = json.load(open(f))
            hlo = rec.get("roofline_hlo_per_body", rec.get("roofline"))
            lb = hlo["collective_link_bytes"]
            steps = hlo.get("steps", 1)
            derived += f" measured_link_MB_per_step={lb / steps / 2**20:.0f}"
        rows.append((f"comm/{arch}", 0.0, derived))
    return rows
