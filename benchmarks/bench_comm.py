"""Communication accounting — the paper's motivation made quantitative.

For every assigned architecture:
  * the legacy hier-vs-K-AVG headline (reduction seconds per K2-step cycle
    under the ring model, ICI vs DCI bandwidths);
  * a per-level cost breakdown of a 3-level ICI/DCI-aligned ReductionPlan
    (``local@4:cast:bfloat16 / pod@8:mean / global@16:topk:0.05``) — each
    level costed over its own link tier and its own *compressed* payload
    (theory.plan_comm_per_round);
  * when the dry-run artifacts exist, the measured per-device collective
    link-bytes of the compiled hier_round.
"""
from __future__ import annotations

import json
import os
from typing import List

from repro.autotune.calibrate import ENV_CALIBRATION, resolve_comm_model
from repro.comm import DEFAULT_BUCKET_BYTES
from repro.configs import ALL_ARCHS, get_config
from repro.core.plan import ReductionPlan, apply_bucketing
from repro.core.theory import (CommModel, comm_per_k2_steps, param_template,
                               plan_comm_per_round)
from repro.core.topology import HierTopology
from benchmarks.common import Row

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")

PLAN_SPEC = "local@4:cast:bfloat16/pod@8:mean/global@16:topk:0.05"


def run() -> List[Row]:
    # a calibration artifact ($REPRO_CALIBRATION, autotune/calibrate.py)
    # swaps the built-in link/latency/codec constants for measured ones
    cal = resolve_comm_model()
    cm = cal or CommModel()
    # resolved like a round builder would: compressed levels bucketed on
    # the pipelined schedule, so the per-level rows carry the overlap term
    plan = apply_bucketing(ReductionPlan.parse(PLAN_SPEC),
                           DEFAULT_BUCKET_BYTES)
    rows: List[Row] = [(
        "comm/model", 0.0,
        (f"calibrated[{os.environ.get(ENV_CALIBRATION, '')}] "
         if cal is not None else "builtin ")
        + f"fast_bw={cm.fast_bw:.3e} slow_bw={cm.slow_bw:.3e} "
        + f"latency={cm.latency:.2e} compress_bw={cm.compress_bw:.3e}")]
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        model_bytes = cfg.param_count() * 2          # bf16
        lay = cfg.layout
        P = max(lay.learners_per_pod, 2)             # >=2 for cross-pod
        S = max(lay.local, 2)
        k1, k2 = 4, 8
        loc, glo = comm_per_k2_steps(model_bytes, k1, k2, P, S, cm)
        hier_ms = (loc + glo) / k2 * 1e3
        kavg_k1 = k1  # K-AVG syncing as often as hier's local cadence
        _, glo_k1 = comm_per_k2_steps(model_bytes, kavg_k1, kavg_k1, P, 1,
                                      cm)
        kavg_ms = glo_k1 / kavg_k1 * 1e3
        derived = (f"hier_ms_per_step={hier_ms:.2f} "
                   f"kavg_same_cadence_ms={kavg_ms:.2f} "
                   f"saving={1 - hier_ms / max(kavg_ms, 1e-12):.1%}")
        f = os.path.join(DRYRUN_DIR, f"{arch}__train_4k__1pod.json")
        if os.path.exists(f):
            with open(f) as fh:
                rec = json.load(fh)
            hlo = rec.get("roofline_hlo_per_body", rec.get("roofline"))
            lb = hlo["collective_link_bytes"]
            steps = hlo.get("steps", 1)
            derived += f" measured_link_MB_per_step={lb / steps / 2**20:.0f}"
        rows.append((f"comm/{arch}", 0.0, derived))

        # per-level breakdown of the 3-level plan on the 2-pod topology;
        # payloads vs the dense fp32 mean (bench_compression's baseline).
        # A realistic leaf structure (~8 matrices per block) lets the
        # bucketed levels show their message counts and overlap term.
        topo = HierTopology(pods=2, groups=lay.groups, local=lay.local)
        template = param_template(cfg.param_count(), dtype="float32",
                                  n_leaves=max(1, 8 * cfg.n_layers))
        dense = cfg.param_count() * 4
        for lc in plan_comm_per_round(plan, topo, template, cm):
            ms_per_step = lc.seconds_per_round / plan.total_period * 1e3
            overlap_ms = lc.overlap_s / plan.total_period * 1e3
            tier = "dci" if lc.bandwidth == cm.slow_bw else "ici"
            rows.append((
                f"comm/{arch}/plan/{lc.name}", 0.0,
                f"period={lc.period} n={lc.participants} "
                f"payload_MB={lc.payload_bytes / 2**20:.1f} "
                f"compress_x={dense / max(lc.payload_bytes, 1):.1f} "
                f"count_per_round={lc.count_per_round} tier={tier} "
                f"msgs={lc.messages} ms_per_step={ms_per_step:.3f} "
                f"overlap_ms_per_step={overlap_ms:.3f} "
                f"overlap_x={lc.overlap_speedup:.2f}"))
    return rows
