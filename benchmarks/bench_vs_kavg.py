"""Paper Table 1 — Hier-AVG vs K-AVG at matched data budgets.

Paper rows: (P=16, K-AVG K=32) vs (Hier-AVG K2=64, K1 in {2,4,16}, S=4);
(P=32, K=4) vs (K2=8, K1=4, S=8); (P=64, K=4) vs (K2=8, K1=1, S=4).
Claim: with HALF the global reductions, Hier-AVG matches or beats K-AVG's
test accuracy.  P=64 runs on CPU here, so row 3 uses a shorter budget.
"""
from __future__ import annotations

from typing import List

from repro.configs.base import HierAvgParams
from repro.core import HierTopology
from benchmarks.common import Row, cls_setup, fmt, run_variant

TOTAL_STEPS = 256


def run() -> List[Row]:
    setup = cls_setup()
    rows: List[Row] = []

    # --- P=16 block: K-AVG K=32 vs Hier-AVG K2=64 ---
    topo = HierTopology(1, 4, 4)
    res, us = run_variant(setup, topo=topo, hier=HierAvgParams(32, 32),
                          algo="kavg", rounds=TOTAL_STEPS // 32, seed=11)
    rows.append(("table1/p16/kavg_k32", us, fmt(res)))
    for k1 in (2, 4, 16):
        res, us = run_variant(setup, topo=topo,
                              hier=HierAvgParams(k1=k1, k2=64),
                              rounds=TOTAL_STEPS // 64, seed=11)
        rows.append((f"table1/p16/hier_k2=64_k1={k1}_s4", us, fmt(res)))

    # --- P=32 block: K-AVG K=4 vs Hier-AVG K2=8, S=8 ---
    topo = HierTopology(1, 8, 4)
    res, us = run_variant(setup, topo=topo, hier=HierAvgParams(4, 4),
                          algo="kavg", rounds=96 // 4, seed=12,
                          per_learner_batch=8)
    rows.append(("table1/p32/kavg_k4", us, fmt(res)))
    topo_s8 = HierTopology(1, 4, 8)
    res, us = run_variant(setup, topo=topo_s8, hier=HierAvgParams(4, 8),
                          rounds=96 // 8, seed=12, per_learner_batch=8)
    rows.append(("table1/p32/hier_k2=8_k1=4_s8", us, fmt(res)))

    # --- P=64 block: K-AVG K=4 vs Hier-AVG K2=8, K1=1, S=4 ---
    topo = HierTopology(1, 16, 4)
    res, us = run_variant(setup, topo=topo, hier=HierAvgParams(4, 4),
                          algo="kavg", rounds=64 // 4, seed=13,
                          per_learner_batch=4)
    rows.append(("table1/p64/kavg_k4", us, fmt(res)))
    res, us = run_variant(setup, topo=topo, hier=HierAvgParams(1, 8),
                          rounds=64 // 8, seed=13, per_learner_batch=4)
    rows.append(("table1/p64/hier_k2=8_k1=1_s4", us, fmt(res)))
    return rows
