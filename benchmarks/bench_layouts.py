"""Beyond-paper: per-architecture layout optimization (train_4k).

For every assigned arch, sweep all G x S x F x TP factorizations of the
256-chip pod (S in {2,4}, learner batch >= 1, microbatch chosen so the
per-device activation carry fits ~4 GiB) through the analytic roofline and
report baseline vs best layout.  This generalizes §Perf pair 1 to the whole
pool; winners for the three hillclimbed pairs were compile-verified
(experiments/hillclimb/).
"""
from __future__ import annotations

import dataclasses
from typing import List

from repro.configs import ALL_ARCHS, get_config
from repro.configs.base import ParallelLayout
from repro.launch.analytic import analytic_roofline
from benchmarks.common import Row

GLOBAL_BATCH = 256
SEQ = 4096
CARRY_BUDGET = 4 * 2 ** 30   # per-device saved-activation budget


def _candidates(cfg):
    n_bytes = cfg.param_count() * 2
    for tp in (1, 2, 4, 8, 16):
        data = 256 // tp
        for s in (2, 4):
            for f in (1, 2, 4, 8, 16, 32):
                if data % (s * f) or f > data:
                    continue
                g = data // (s * f)
                learners = g * s
                if GLOBAL_BATCH % learners or GLOBAL_BATCH // learners < 1:
                    continue
                # per-device weights+grads must fit ~12 GiB
                if n_bytes / (f * tp) * 3 > 12 * 2 ** 30:
                    continue
                b_l = GLOBAL_BATCH // learners
                # pick the smallest microbatch whose carry fits the budget
                micro = 1
                while micro <= b_l:
                    carry = (b_l // micro) * SEQ * cfg.d_model * 2 \
                        / f * cfg.n_layers
                    if carry <= CARRY_BUDGET:
                        break
                    micro *= 2
                if micro > b_l:
                    continue
                yield ParallelLayout(g, s, f, tp, micro)


def _score(cfg, lay):
    c = dataclasses.replace(cfg, layout=lay)
    r = analytic_roofline(c, "train_4k")
    return max(r.compute_s, r.memory_s, r.collective_s), r


def run() -> List[Row]:
    rows: List[Row] = []
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        base_t, base_r = _score(cfg, cfg.layout)
        best_lay, best_t, best_r = cfg.layout, base_t, base_r
        for lay in _candidates(cfg):
            t, r = _score(cfg, lay)
            if t < best_t:
                best_lay, best_t, best_r = lay, t, r
        gain = base_t / best_t if best_t else 1.0
        rows.append((
            f"layout_opt/{arch}", 1e6 * best_t,
            f"baseline={cfg.layout.groups}x{cfg.layout.local}x"
            f"{cfg.layout.fsdp}x{cfg.layout.tp}:{cfg.layout.microbatch}"
            f"({1e3*base_t:.0f}ms) "
            f"best={best_lay.groups}x{best_lay.local}x{best_lay.fsdp}x"
            f"{best_lay.tp}:{best_lay.microbatch}({1e3*best_t:.0f}ms) "
            f"speedup={gain:.2f}x bottleneck={best_r.bottleneck}"))
    return rows
