"""Continuous-batching serving A/B: paged PagedServeEngine vs the dense
wave-batched ServeEngine, plus the flash-decode kernel vs its XLA oracle.

Methodology (mirrors bench_bucketing's reduction A/B): every engine
variant runs in a FRESH subprocess so neither inherits the other's warm
XLA/LLVM state, prints one json record on stdout, and the parent
assembles the rows.  The trace is a seeded mixed-length workload — both
prompt lengths AND per-request token budgets vary (the budget plays the
role EOS plays in production: requests finish at different steps).  At
equal slot count the dense engine must decode every wave to the longest
budget and pad every prompt to the wave bucket, while the paged engine
refills a finished slot on the very next token — ``wasted_ratio`` is the
fraction of dense decode-slot steps that produced no kept token, and the
``paged@B`` rows carry ``speedup_vs_dense``.

Rows:
  serving/{dense,paged}@B     tokens/s + p99 latency at B slots over the
                              mixed trace (1 warm run, then timed rounds)
  serving/flashdecode/*       the paged attention kernel A/B at serving
                              shape: XLA gather oracle timing vs the
                              Pallas kernel (compiled on TPU; interpreted
                              on CPU, where only its max |diff| vs the
                              oracle is meaningful, not its wall-clock)

``run(smoke=True)`` (CI) uses 2 timed rounds, one slot count, and a
smaller trace.  Machine-readable records for BENCH_serving.json are left
in ``RECORDS``.

Standalone: PYTHONPATH=src python -m benchmarks.bench_serving [--smoke]
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import Dict, List, Tuple

import numpy as np

from benchmarks.common import Row

ARCH = "yi-34b"
PAGE_SIZE = 8
PREFILL_CHUNK = 16
MAX_LEN = 128    # headroom: 64-bucket prompts + the 48-token budget tail
ROUNDS = 6
SLOT_COUNTS = (2, 4, 8)

# machine-readable rows for BENCH_serving.json (benchmarks/run.py)
RECORDS: List[Dict] = []


def _trace(n: int, seed: int = 0) -> Tuple[List[np.ndarray], List[int]]:
    """Mixed-length request trace: prompts 4..40 tokens, long-tailed
    per-request token budgets (the EOS stand-in).  Decode lengths in
    production are short-headed with a long tail — most requests stop
    after a few tokens, a minority runs long — which is the workload
    continuous batching targets: a dense wave decodes EVERY request to
    the wave's longest survivor, so its wasted-step ratio is
    1 - mean/max of the wave's lengths (~0.7 here)."""
    rng = np.random.default_rng(seed)
    plens = rng.integers(4, 41, size=n)
    short = rng.integers(2, 9, size=n)
    long_ = rng.integers(24, 49, size=n)
    budgets = [int(b) for b in
               np.where(rng.random(n) < 0.75, short, long_)]
    prompts = [rng.integers(0, 512, size=int(p)).astype(np.int32)
               for p in plens]
    return prompts, budgets


def _measure_engine(engine: str, slots: int, rounds: int,
                    n_requests: int) -> Dict:
    """Child mode: serve the trace with ONE engine variant and report
    throughput/latency.  One warm run compiles everything; ``rounds``
    timed runs follow (tokens/s from the median, p99 from pooled
    per-request latencies)."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import build
    from repro.serve import GenerationConfig, PagedServeEngine, ServeEngine

    cfg = get_config(ARCH).reduced()
    bundle = build(cfg, cache_dtype=jnp.float32, decode_impl="auto")
    params = bundle.init(jax.random.PRNGKey(0))
    prompts, budgets = _trace(n_requests)
    gen = GenerationConfig(max_new_tokens=max(budgets), temperature=0.0)

    if engine == "paged":
        eng = PagedServeEngine(bundle, params, slots=slots,
                               page_size=PAGE_SIZE, max_len=MAX_LEN,
                               prefill_chunk=PREFILL_CHUNK,
                               cache_dtype=jnp.float32, gen=gen)
        serve = lambda: eng.serve_queue(prompts, max_new=budgets)  # noqa: E731
    else:
        eng = ServeEngine(bundle, params, max_len=MAX_LEN, gen=gen)
        serve = lambda: eng.serve_queue(prompts, slots=slots,   # noqa: E731
                                        max_new=budgets)

    results = serve()                                  # warm (compiles)
    tokens = sum(r.steps for r in results)
    decode_steps = sum(r.decode_steps for r in results)
    walls, lats = [], []
    for _ in range(rounds):
        t0 = time.time()
        out = serve()
        walls.append(time.time() - t0)
        lats.extend(eng.finish_times.values())
        assert sum(r.steps for r in out) == tokens
    wall = float(np.median(walls))
    return {
        "tokens": tokens,
        "decode_steps": decode_steps,
        # fraction of decode-slot work that produced no kept token
        # (tokens includes the free prefill-sampled first token per req)
        "wasted_ratio": round(
            1.0 - (tokens - len(results)) / max(1, decode_steps), 3),
        "tokens_per_s": round(tokens / wall, 1),
        "p99_ms": round(float(np.percentile(lats, 99)) * 1e3, 1),
        "p50_ms": round(float(np.percentile(lats, 50)) * 1e3, 1),
        "wall_s": round(wall, 3),
        "requests": len(results),
        "prefill_traces": eng.prefill_traces,
        "decode_traces": eng.decode_traces,
    }


def _measure_flash(which: str, rounds: int) -> Dict:
    """Child mode: the decode-attention kernel at serving shape — the XLA
    gather oracle vs the Pallas flash-decode kernel (compiled on TPU,
    interpreted elsewhere).  Both report timing; the kernel row adds its
    max |diff| vs the oracle (the bit-parity claim lives in
    tests/test_kernels.py — this is the drift canary)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops as kops

    B, HQ, HKV, D, PAGE, MAXP = 8, 8, 4, 64, 16, 8
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(keys[0], (B, HQ, D), jnp.float32)
    n_pages = 1 + B * MAXP
    k_pages = jax.random.normal(keys[1], (HKV, n_pages, PAGE, D),
                                jnp.float32)
    v_pages = jax.random.normal(keys[2], (HKV, n_pages, PAGE, D),
                                jnp.float32)
    tables = jnp.asarray(
        np.arange(1, 1 + B * MAXP, dtype=np.int32).reshape(B, MAXP))
    lengths = jnp.asarray(
        np.random.default_rng(0).integers(1, MAXP * PAGE, size=B),
        jnp.int32)

    impl = "xla" if which == "oracle" else (
        "pallas" if jax.default_backend() == "tpu" else "pallas_interpret")
    fn = jax.jit(lambda *a: kops.flash_decode(*a, impl=impl))
    t0 = time.time()
    out = jax.block_until_ready(fn(q, k_pages, v_pages, tables, lengths))
    compile_s = time.time() - t0
    per = []
    for _ in range(rounds):
        t1 = time.time()
        jax.block_until_ready(fn(q, k_pages, v_pages, tables, lengths))
        per.append(time.time() - t1)
    rec = {
        "impl": impl,
        "us": round(float(np.median(per)) * 1e6, 1),
        "compile_s": round(compile_s, 2),
        "shape": f"B{B}xH{HQ}/{HKV}xD{D}xpage{PAGE}x{MAXP}",
    }
    if which != "oracle":
        ref = kops.flash_decode(q, k_pages, v_pages, tables, lengths,
                                impl="xla")
        rec["max_abs_diff_vs_oracle"] = float(jnp.abs(out - ref).max())
    return rec


def _child(argv: List[str]) -> Dict:
    repo = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-m", "benchmarks.bench_serving",
                        *argv], env=env, cwd=repo, capture_output=True,
                       text=True, timeout=1800)
    if r.returncode != 0:
        raise RuntimeError(r.stderr.strip()[-400:])
    return json.loads(r.stdout.strip().splitlines()[-1])


def run(smoke: bool = False) -> List[Row]:
    RECORDS.clear()
    rounds = 2 if smoke else ROUNDS
    slot_counts = (2,) if smoke else SLOT_COUNTS
    rows: List[Row] = []

    for slots in slot_counts:
        n_requests = 3 * slots if not smoke else 5
        dense_rec = None
        for engine in ("dense", "paged"):
            name = f"serving/{engine}@{slots}"
            try:
                rec = _child(["--engine", engine, "--slots", str(slots),
                              "--rounds", str(rounds),
                              "--requests", str(n_requests)])
            except RuntimeError as e:  # noqa: BLE001
                rows.append((name, 0.0, f"ERROR {e}"))
                continue
            rec["name"] = name
            if engine == "dense":
                dense_rec = rec
            elif dense_rec:
                rec["speedup_vs_dense"] = round(
                    rec["tokens_per_s"] / max(1e-9,
                                              dense_rec["tokens_per_s"]), 2)
            RECORDS.append(rec)
            derived = (f"tok/s={rec['tokens_per_s']} "
                       f"p99_ms={rec['p99_ms']} "
                       f"wasted={rec['wasted_ratio']} "
                       f"steps={rec['decode_steps']} "
                       f"traces={rec['prefill_traces']}"
                       f"+{rec['decode_traces']}"
                       + (f" speedup={rec.get('speedup_vs_dense')}"
                          if engine == "paged" else ""))
            rows.append((name, rec["wall_s"] * 1e6 / max(1, rec["tokens"]),
                         derived))

    for which in ("oracle", "kernel"):
        name = f"serving/flashdecode/{which}"
        try:
            rec = _child(["--flash", which, "--rounds", str(rounds)])
        except RuntimeError as e:  # noqa: BLE001
            rows.append((name, 0.0, f"ERROR {e}"))
            continue
        rec["name"] = name
        RECORDS.append(rec)
        derived = f"impl={rec['impl']} {rec['shape']}"
        if "max_abs_diff_vs_oracle" in rec:
            derived += f" max_diff={rec['max_abs_diff_vs_oracle']:.2e}"
        rows.append((name, rec["us"], derived))
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--engine", choices=("dense", "paged"), default=None,
                    help="child mode: serve the trace with one engine "
                         "and print a json record")
    ap.add_argument("--flash", choices=("oracle", "kernel"), default=None,
                    help="child mode: time one decode-attention impl")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--rounds", type=int, default=ROUNDS)
    args = ap.parse_args()
    if args.engine:
        print(json.dumps(_measure_engine(args.engine, args.slots,
                                         args.rounds, args.requests)))
    elif args.flash:
        print(json.dumps(_measure_flash(args.flash, args.rounds)))
    else:
        for n, us, d in run(smoke=args.smoke):
            print(f"{n},{us:.0f},{d}")
