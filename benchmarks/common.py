"""Shared benchmark plumbing.

Every benchmark returns rows ``(name, us_per_call, derived)`` — wall time
per Hier-AVG round and the experiment's headline metric — which run.py
prints as CSV (one function per paper table/figure).
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax
import numpy as np

from repro.configs.base import HierAvgParams
from repro.configs.resnet18_cifar import MLPConfig
from repro.core import HierTopology, Simulator
from repro.data.synthetic import make_classification_task
from repro.models.resnet import mlp_cls_init, mlp_cls_loss
from repro.optim import sgd

Row = Tuple[str, float, str]


def cls_setup(in_dim: int = 32, n_classes: int = 10, hidden=(64, 64),
              noise: float = 0.8, seed: int = 21):
    """The CIFAR stand-in used by the paper-shape benchmarks."""
    cfg = MLPConfig(in_dim=in_dim, hidden=hidden, n_classes=n_classes)
    sample = make_classification_task(in_dim, n_classes, seed=seed,
                                      noise=noise)
    return {
        "loss_fn": lambda p, b: mlp_cls_loss(p, b),
        "init_fn": lambda k: mlp_cls_init(k, cfg),
        "sample": sample,
        "eval_batch": sample(jax.random.PRNGKey(9999), 2048),
    }


def timed_run(sim: Simulator, rounds: int):
    t0 = time.time()
    res = sim.run(rounds)
    dt = time.time() - t0
    return res, dt / rounds * 1e6   # us per round


def run_variant(setup: Dict, *, topo: HierTopology, hier: HierAvgParams,
                algo: str = "hier", lr: float = 0.1, rounds: int = 12,
                per_learner_batch: int = 16, seed: int = 0):
    sim = Simulator(setup["loss_fn"], setup["init_fn"], setup["sample"],
                    topo=topo, hier=hier, algo=algo, optimizer=sgd(lr),
                    per_learner_batch=per_learner_batch,
                    eval_batch=setup["eval_batch"], seed=seed)
    return timed_run(sim, rounds)


def fmt(res) -> str:
    return (f"train_loss={res.losses[-1]:.4f} "
            f"test_loss={res.eval_losses[-1]:.4f} "
            f"test_acc={res.eval_accs[-1]:.4f}")
