from repro.serve.engine import ServeEngine, GenerationConfig  # noqa: F401
from repro.serve.kvcache import cache_bytes, describe_cache  # noqa: F401
