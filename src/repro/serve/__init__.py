from repro.serve.engine import (GenerationConfig, PagedServeEngine,  # noqa: F401
                                RequestResult, ServeEngine)
from repro.serve.kvcache import (BlockAllocator, cache_bytes,  # noqa: F401
                                 describe_cache, page_bytes, pages_for,
                                 pool_pages)
