"""KV-cache accounting + the paged-cache block allocator.

Cache construction itself lives with each model family
(ModelBundle.init_cache / init_paged_cache): full GQA cache, rolling
sliding-window buffer, compressed MLA latents, RWKV/Mamba constant-size
states.  These helpers size them for serving/dry-run planning, and
:class:`BlockAllocator` owns the page pool of the paged serving engine
(serve/engine.py PagedServeEngine): fixed-size pages, per-sequence block
tables, admission reservations gated by the same ``cache_bytes``
accounting, pages freed and reused the moment a sequence finishes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def cache_bytes(cfg: ArchConfig, batch: int, max_len: int,
                *, rolling: bool = False, cache_dtype=jnp.bfloat16) -> int:
    """Analytic per-replica cache size in bytes."""
    esize = jnp.dtype(cache_dtype).itemsize
    L = cfg.n_layers
    if cfg.family == "ssm":
        hd = cfg.resolved_head_dim
        per = cfg.ssm_heads * hd * hd * 4 + 2 * cfg.d_model * 4
        return batch * L * per
    if cfg.family == "hybrid":
        w = cfg.sliding_window
        kv = 2 * w * cfg.n_kv_heads * cfg.resolved_head_dim * esize
        di = cfg.d_model * cfg.ssm_expand
        ssm = di * cfg.ssm_state * 4 + 3 * di * 4
        return batch * L * (kv + ssm)
    if cfg.kv_lora_rank:
        per = max_len * (cfg.kv_lora_rank + cfg.qk_rope_head_dim) * esize
        return batch * L * per
    length = cfg.long_context_window if rolling else max_len
    per = 2 * length * cfg.n_kv_heads * cfg.resolved_head_dim * esize
    # encoder-decoder archs also hold a cross-attention K/V cache per
    # decoder layer (over the encoder sequence) — same per-position cost
    n_layers = L + (cfg.n_layers if cfg.is_encoder_decoder else 0)
    return batch * n_layers * per


def page_bytes(cfg: ArchConfig, page_size: int,
               *, cache_dtype=jnp.bfloat16) -> int:
    """Bytes one pool page (``page_size`` cache positions, all layers)
    costs — ``cache_bytes`` at batch=1, max_len=page_size.  The unit the
    paged engine's admission accounting is denominated in."""
    return cache_bytes(cfg, 1, page_size, cache_dtype=cache_dtype)


def describe_cache(cfg: ArchConfig, batch: int, max_len: int,
                   *, rolling: bool = False) -> Dict[str, Any]:
    b = cache_bytes(cfg, batch, max_len, rolling=rolling)
    kind = ("ssm-state" if cfg.family == "ssm"
            else "hybrid(window+state)" if cfg.family == "hybrid"
            else "mla-latent" if cfg.kv_lora_rank
            else "rolling-window" if rolling else "full-kv")
    return {"kind": kind, "bytes": b, "gib": b / 2 ** 30,
            "bytes_per_seq": b // max(batch, 1)}


# ===================================================================== #
# paged pool allocator
# ===================================================================== #

def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold ``n_tokens`` cache positions."""
    return -(-n_tokens // page_size)


@dataclasses.dataclass
class BlockAllocator:
    """Host-side free-list allocator for the paged KV pool.

    Page ids index the device-side pool arrays ([Hkv, P, page, D] per
    layer).  Page 0 is reserved as the **null page**: unallocated block-
    table entries point at it (so gathers always read a valid index) and
    masked-out writes from inactive slots land there — it is never handed
    to a sequence.

    Admission is two-phase so decode can grow tables on demand without
    ever deadlocking mid-sequence:

      * ``reserve(n)`` at admission claims capacity for the sequence's
        worst case (prompt + max_new tokens) without pinning physical
        pages; refuse admission when it fails.
      * ``take()`` converts one reservation unit into a physical page id
        as the sequence actually reaches it (prefill chunks, then decode
        crossing a page boundary).
      * ``release(pages, reserved)`` returns both the moment the
        sequence finishes — the freed pages are immediately reusable by
        the next admission.
    """

    n_pages: int                       # pool size INCLUDING the null page
    _free: List[int] = dataclasses.field(default_factory=list)
    _reserved: int = 0
    # high-water mark of physical pages handed out, for pool-sizing tests
    peak_in_use: int = 0

    def __post_init__(self):
        if self.n_pages < 2:
            raise ValueError(f"pool needs >= 2 pages (one is the null "
                             f"page), got {self.n_pages}")
        self._free = list(range(self.n_pages - 1, 0, -1))  # pop() -> low ids

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        """Physical pages currently handed out (telemetry gauge)."""
        return self.n_pages - 1 - len(self._free)

    @property
    def unreserved_pages(self) -> int:
        return len(self._free) - self._reserved

    def reserve(self, n: int) -> bool:
        """Claim capacity for ``n`` pages; False if it would oversubscribe."""
        if n > self.unreserved_pages:
            return False
        self._reserved += n
        return True

    def take(self) -> int:
        """Convert one reserved unit into a physical page id."""
        if self._reserved <= 0:
            raise RuntimeError("take() without a matching reserve()")
        if not self._free:
            raise RuntimeError("page pool exhausted despite reservation")
        self._reserved -= 1
        page = self._free.pop()
        in_use = self.n_pages - 1 - len(self._free)
        self.peak_in_use = max(self.peak_in_use, in_use)
        return page

    def release(self, pages: List[int], reserved_left: int = 0) -> None:
        """Return a finished sequence's pages + unused reservation."""
        for p in pages:
            if not 0 < p < self.n_pages:
                raise ValueError(f"bad page id {p}")
            if p in self._free:
                raise ValueError(f"double free of page {p}")
        self._free.extend(pages)
        if reserved_left < 0 or reserved_left > self._reserved:
            raise ValueError(f"bad reservation release {reserved_left} "
                             f"(outstanding {self._reserved})")
        self._reserved -= reserved_left


def pool_pages(cfg: ArchConfig, page_size: int, *,
               budget_bytes: Optional[int] = None,
               slots: int = 0, max_len: int = 0,
               cache_dtype=jnp.bfloat16) -> int:
    """Size the page pool (incl. the null page).

    With ``budget_bytes`` the pool is whatever the byte budget buys at
    ``page_bytes`` per page (the ``cache_bytes``-gated admission story);
    otherwise it defaults to every slot holding a full ``max_len``
    sequence (the dense-equivalent worst case).
    """
    if budget_bytes is not None:
        n = budget_bytes // max(1, page_bytes(cfg, page_size,
                                              cache_dtype=cache_dtype))
    else:
        n = slots * pages_for(max_len, page_size)
    return int(n) + 1              # + null page
