"""KV-cache accounting helpers.

Cache construction itself lives with each model family
(ModelBundle.init_cache): full GQA cache, rolling sliding-window buffer,
compressed MLA latents, RWKV/Mamba constant-size states.  These helpers
size them for serving/dry-run planning.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def cache_bytes(cfg: ArchConfig, batch: int, max_len: int,
                *, rolling: bool = False, cache_dtype=jnp.bfloat16) -> int:
    """Analytic per-replica cache size in bytes."""
    esize = jnp.dtype(cache_dtype).itemsize
    L = cfg.n_layers
    if cfg.family == "ssm":
        hd = cfg.resolved_head_dim
        per = cfg.ssm_heads * hd * hd * 4 + 2 * cfg.d_model * 4
        return batch * L * per
    if cfg.family == "hybrid":
        w = cfg.sliding_window
        kv = 2 * w * cfg.n_kv_heads * cfg.resolved_head_dim * esize
        di = cfg.d_model * cfg.ssm_expand
        ssm = di * cfg.ssm_state * 4 + 3 * di * 4
        return batch * L * (kv + ssm)
    if cfg.kv_lora_rank:
        per = max_len * (cfg.kv_lora_rank + cfg.qk_rope_head_dim) * esize
        return batch * L * per
    length = cfg.long_context_window if rolling else max_len
    per = 2 * length * cfg.n_kv_heads * cfg.resolved_head_dim * esize
    n_layers = L + (cfg.n_layers if cfg.is_encoder_decoder else 0)
    return batch * L * per


def describe_cache(cfg: ArchConfig, batch: int, max_len: int,
                   *, rolling: bool = False) -> Dict[str, Any]:
    b = cache_bytes(cfg, batch, max_len, rolling=rolling)
    kind = ("ssm-state" if cfg.family == "ssm"
            else "hybrid(window+state)" if cfg.family == "hybrid"
            else "mla-latent" if cfg.kv_lora_rank
            else "rolling-window" if rolling else "full-kv")
    return {"kind": kind, "bytes": b, "gib": b / 2 ** 30,
            "bytes_per_seq": b // max(batch, 1)}
