"""Batched serving engine: prefill + jitted decode loop + slot-based
continuous batching (lite).

The decode loop is a single jitted ``lax.scan`` over ``max_new_tokens``
steps, so the whole generation of a batch is two XLA programs (prefill,
scan-decode) regardless of length.  The request loop keeps a fixed number of
batch slots and refills finished slots from the queue — the standard
production pattern, minus preemption.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import ModelBundle


@dataclasses.dataclass(frozen=True)
class GenerationConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0        # 0 => greedy
    eos_id: int = -1                # -1 => never stop early
    seed: int = 0


@dataclasses.dataclass
class RequestResult:
    request_id: int
    prompt: np.ndarray
    tokens: np.ndarray              # generated tokens (trimmed at EOS)
    steps: int


class ServeEngine:
    def __init__(self, bundle: ModelBundle, params, *,
                 max_len: int = 1024,
                 gen: GenerationConfig = GenerationConfig()):
        self.bundle = bundle
        self.params = params
        self.max_len = max_len
        self.gen = gen
        self._prefill = jax.jit(self._prefill_impl)
        self._decode_scan = jax.jit(self._decode_scan_impl,
                                    static_argnames=("steps",))

    # ------------------------------------------------------------ #

    def _prefill_impl(self, params, batch):
        # max_len is a static python int (cache allocation size), not traced
        return self.bundle.prefill(params,
                                   dict(batch, max_len=self.max_len))

    def _sample(self, logits, key):
        if self.gen.temperature <= 0.0:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.gen.temperature).astype(jnp.int32)

    def _decode_scan_impl(self, params, first_tok, cache, key, *, steps: int):
        def step(carry, k):
            tok, cache = carry
            logits, cache = self.bundle.decode_step(params, tok, cache)
            nxt = self._sample(logits, k)
            return (nxt, cache), nxt

        keys = jax.random.split(key, steps)
        (last, cache), toks = jax.lax.scan(step, (first_tok, cache), keys)
        return toks.T, cache          # [B, steps]

    # ------------------------------------------------------------ #

    def generate(self, prompts: jax.Array,
                 extras: Optional[Dict[str, Any]] = None) -> np.ndarray:
        """prompts [B, S] int32 -> generated tokens [B, max_new_tokens]."""
        batch = {"tokens": prompts}
        if extras:
            batch.update(extras)
        logits, cache = self._prefill(self.params, batch)
        key = jax.random.PRNGKey(self.gen.seed)
        k0, key = jax.random.split(key)
        first = self._sample(logits, k0)
        out = [np.asarray(first)[:, None]]
        if self.gen.max_new_tokens > 1:
            toks, cache = self._decode_scan(self.params, first, cache, key,
                                            steps=self.gen.max_new_tokens - 1)
            out.append(np.asarray(toks))
        return np.concatenate(out, axis=1)

    # ------------------------------------------------------------ #

    def serve_queue(self, requests: Sequence[np.ndarray], *,
                    slots: int = 4) -> List[RequestResult]:
        """Slot-based batched serving of a request queue.

        Requests (token arrays, same length per wave) are grouped into waves
        of ``slots``; each wave shares prefill + decode programs (recompiled
        only when the prompt length changes).
        """
        results: List[RequestResult] = []
        queue = list(enumerate(requests))
        eos = self.gen.eos_id
        while queue:
            wave = queue[:slots]
            queue = queue[slots:]
            ids = [i for i, _ in wave]
            lens = {len(p) for _, p in wave}
            # pad the wave to a single prompt length (left-pad with 0)
            L = max(lens)
            prompts = np.zeros((len(wave), L), np.int32)
            for r, (_, p) in enumerate(wave):
                prompts[r, L - len(p):] = p
            toks = self.generate(jnp.asarray(prompts))
            for r, rid in enumerate(ids):
                t = toks[r]
                if eos >= 0 and (t == eos).any():
                    t = t[: int(np.argmax(t == eos)) + 1]
                results.append(RequestResult(rid, prompts[r], t, len(t)))
        return results
