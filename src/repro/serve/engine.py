"""Serving engines: wave-batched dense baseline + paged continuous batching.

``ServeEngine`` is the dense baseline: prefill + one jitted ``lax.scan``
over ``max_new_tokens`` decode steps, requests grouped into fixed waves.
Every request in a wave decodes to ``max_new_tokens`` even if it hit EOS
at step 2 — the wasted steps are what ``RequestResult.decode_steps``
makes visible and what ``PagedServeEngine`` eliminates.

``PagedServeEngine`` is token-level continuous batching over a paged KV
cache (serve/kvcache.py):

  * one jitted decode step over a FIXED slot array with an active mask —
    slot population changes never recompile, they only flip mask bits;
  * finished slots are refilled from the queue between steps, their pages
    released to the pool the moment they finish;
  * newcomers prefill in fixed-size chunks interleaved with resident
    decode steps, so a long prompt never stalls the running batch, and
    the traced chunk base means any prompt length reuses one compiled
    chunk program.

Note on MoE archs: expert capacity applies per routing group, so a
capacity-dropped MoE routes chunked prefill groups differently from a
full-prompt prefill.  With a dropless capacity factor
(``cf >= n_experts / top_k``) chunking is mathematically invisible and
paged/dense greedy outputs are bit-identical (see models/moe.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import ModelBundle
from repro.serve.kvcache import BlockAllocator, pages_for, pool_pages


@dataclasses.dataclass(frozen=True)
class GenerationConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0        # 0 => greedy
    eos_id: int = -1                # -1 => never stop early
    seed: int = 0


@dataclasses.dataclass
class RequestResult:
    request_id: int
    prompt: np.ndarray
    tokens: np.ndarray              # generated tokens (trimmed at EOS)
    steps: int                      # == len(tokens) (post-trim)
    # decode iterations actually spent on this request (prefill's free
    # first token excluded).  For the dense wave engine this is always
    # max_new_tokens - 1 — EOS does not stop the wave — so
    # (decode_steps - (steps - 1)) / decode_steps is the wasted-step
    # ratio the paged engine's token-level refill removes.
    decode_steps: int = 0


def _bucket_len(n: int, floor: int = 8) -> int:
    """Next power-of-two >= n (>= floor) — the serve_queue prompt pad
    target, so arbitrary prompt lengths hit a log-bounded set of compiled
    prefill shapes instead of one program per length."""
    b = floor
    while b < n:
        b *= 2
    return b


def _queue_summary(engine: str, results: List[RequestResult],
                   wall_s: float, *, refill_events: int = 0,
                   peak_pages_in_use: int = 0, pool_pages: int = 0,
                   mean_occupancy: float = 0.0) -> Dict[str, Any]:
    """One steady-state summary dict per serve_queue call, shared by
    both engines (telemetry ``serve_summary`` row shape).  The wasted
    ratio is the fraction of decode-slot work that produced no kept
    token (bench_serving convention: each request's first token is the
    prefill's free sample)."""
    tokens = sum(r.steps for r in results)
    decode_steps = sum(r.decode_steps for r in results)
    return {
        "engine": engine, "requests": len(results), "tokens": tokens,
        "decode_steps": decode_steps, "wall_s": round(wall_s, 4),
        "tokens_per_s": round(tokens / wall_s, 1) if wall_s > 0 else 0.0,
        "wasted_ratio": round(
            1.0 - (tokens - len(results)) / max(1, decode_steps), 3),
        "refill_events": refill_events,
        "peak_pages_in_use": peak_pages_in_use,
        "pool_pages": pool_pages,
        "mean_occupancy": round(mean_occupancy, 3),
    }


class ServeEngine:
    def __init__(self, bundle: ModelBundle, params, *,
                 max_len: int = 1024,
                 gen: GenerationConfig = GenerationConfig(),
                 metrics: Optional[Any] = None):
        self.bundle = bundle
        self.params = params
        self.max_len = max_len
        self.gen = gen
        # optional telemetry/metrics.py MetricsLogger: serve_summary
        # rows per serve_queue call (the dense engine has no per-step
        # slot dynamics worth a serve_step stream)
        self.metrics = metrics
        self.last_summary: Optional[Dict[str, Any]] = None
        # trace-time counters: the increment is a python side effect, so
        # it runs only when jit actually (re)traces — a cheap compile
        # counter for tests and for spotting shape-bucketing regressions.
        self.prefill_traces = 0
        self.decode_traces = 0
        self.finish_times: Dict[int, float] = {}
        self._prefill = jax.jit(self._prefill_impl)
        self._decode_scan = jax.jit(self._decode_scan_impl,
                                    static_argnames=("steps",))

    def steady_state_summary(self) -> Optional[Dict[str, Any]]:
        """Summary of the last ``serve_queue`` call (None before one)."""
        return self.last_summary

    # ------------------------------------------------------------ #

    def _prefill_impl(self, params, batch):
        # max_len is a static python int (cache allocation size), not traced
        self.prefill_traces += 1
        return self.bundle.prefill(params,
                                   dict(batch, max_len=self.max_len))

    def _sample(self, logits, key):
        if self.gen.temperature <= 0.0:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.gen.temperature).astype(jnp.int32)

    def _decode_scan_impl(self, params, first_tok, cache, key, *, steps: int):
        self.decode_traces += 1

        def step(carry, k):
            tok, cache = carry
            logits, cache = self.bundle.decode_step(params, tok, cache)
            nxt = self._sample(logits, k)
            return (nxt, cache), nxt

        keys = jax.random.split(key, steps)
        (last, cache), toks = jax.lax.scan(step, (first_tok, cache), keys)
        return toks.T, cache          # [B, steps]

    # ------------------------------------------------------------ #

    def generate(self, prompts: jax.Array,
                 extras: Optional[Dict[str, Any]] = None) -> np.ndarray:
        """prompts [B, S] int32 -> generated tokens [B, max_new_tokens]."""
        batch = {"tokens": prompts}
        if extras:
            batch.update(extras)
        logits, cache = self._prefill(self.params, batch)
        key = jax.random.PRNGKey(self.gen.seed)
        k0, key = jax.random.split(key)
        first = self._sample(logits, k0)
        out = [np.asarray(first)[:, None]]
        if self.gen.max_new_tokens > 1:
            toks, cache = self._decode_scan(self.params, first, cache, key,
                                            steps=self.gen.max_new_tokens - 1)
            out.append(np.asarray(toks))
        return np.concatenate(out, axis=1)

    # ------------------------------------------------------------ #

    def serve_queue(self, requests: Sequence[np.ndarray], *,
                    slots: int = 4,
                    max_new: Optional[Sequence[int]] = None
                    ) -> List[RequestResult]:
        """Slot-based batched serving of a request queue.

        Requests (token arrays) are grouped into waves of ``slots``; each
        wave left-pads to the power-of-two bucket of its longest prompt,
        so mixed-length queues compile one prefill program per bucket
        (log many) instead of one per distinct length.

        ``max_new`` optionally carries a per-request token budget (like a
        per-request sampling param).  The wave still decodes the full
        ``gen.max_new_tokens`` scan — a request that wanted fewer tokens
        burns the remaining steps as padding, which is exactly the
        wasted-step cost ``decode_steps`` exposes and the paged engine
        avoids.  Per-request completion times (seconds since the call
        started) are left in ``self.finish_times``.
        """
        results: List[RequestResult] = []
        queue = list(enumerate(requests))
        eos = self.gen.eos_id
        self.finish_times: Dict[int, float] = {}
        t0 = time.time()
        while queue:
            wave = queue[:slots]
            queue = queue[slots:]
            ids = [i for i, _ in wave]
            longest = max(len(p) for _, p in wave)
            if longest > self.max_len:
                raise ValueError(f"prompt length {longest} exceeds "
                                 f"max_len {self.max_len}")
            # pad the wave to the bucketed prompt length (left-pad with 0)
            L = min(_bucket_len(longest), self.max_len)
            prompts = np.zeros((len(wave), L), np.int32)
            for r, (_, p) in enumerate(wave):
                prompts[r, L - len(p):] = p
            toks = self.generate(jnp.asarray(prompts))
            done = time.time() - t0
            for r, rid in enumerate(ids):
                t = toks[r]
                if max_new is not None:
                    t = t[: max_new[rid]]
                if eos >= 0 and (t == eos).any():
                    t = t[: int(np.argmax(t == eos)) + 1]
                results.append(RequestResult(
                    rid, prompts[r], t, len(t),
                    decode_steps=self.gen.max_new_tokens - 1))
                self.finish_times[rid] = done
        self.last_summary = _queue_summary(
            "dense", results, time.time() - t0)
        if self.metrics is not None:
            self.metrics.log_row("serve_summary", **self.last_summary)
            self.metrics.flush()
        return results


# ===================================================================== #
# paged continuous batching
# ===================================================================== #

@dataclasses.dataclass
class _Slot:
    """Host-side bookkeeping for one batch slot."""
    state: str = "free"             # free | prefill | decode
    rid: int = -1
    prompt: Optional[np.ndarray] = None
    plen: int = 0
    target: int = 0                 # token budget for this request
    base: int = 0                   # next prefill chunk start
    pages: List[int] = dataclasses.field(default_factory=list)
    reserved: int = 0               # reservation units not yet taken
    toks: List[int] = dataclasses.field(default_factory=list)
    decode_steps: int = 0
    last_tok: int = 0


class PagedServeEngine:
    """Token-level continuous batching over a paged KV cache.

    The decode hot loop is ONE jitted step over a fixed ``slots``-wide
    array: per-slot cache lengths, an active mask, and a block table are
    the only things that change between steps, so admission / completion
    never recompiles anything.  Admission is gated by the page pool
    (``cache_bytes``-denominated budget): a request enters a free slot
    only when the allocator can reserve its worst-case page count, and
    its pages return to the pool the moment it finishes.
    """

    def __init__(self, bundle: ModelBundle, params, *,
                 slots: int = 4, page_size: int = 16,
                 max_len: int = 1024, prefill_chunk: int = 32,
                 budget_bytes: Optional[int] = None,
                 cache_dtype=jnp.bfloat16,
                 gen: GenerationConfig = GenerationConfig(),
                 metrics: Optional[Any] = None):
        if bundle.decode_step_paged is None:
            raise ValueError(
                f"arch '{bundle.cfg.name}' (family {bundle.cfg.family}) has "
                f"a constant-size or unsupported decode state; paged "
                f"serving needs a positional KV/latent cache — use "
                f"ServeEngine")
        self.bundle = bundle
        self.params = params
        self.slots = slots
        self.page_size = page_size
        self.max_len = max_len
        self.chunk = prefill_chunk
        self.gen = gen
        # tables (and the no-budget pool default) cover the chunk-padded
        # max length: the last prefill chunk writes masked garbage past
        # the true prompt end, and those positions still need real pages
        self.max_pages_per_seq = pages_for(self._padded(max_len), page_size)

        n_pages = pool_pages(bundle.cfg, page_size,
                             budget_bytes=budget_bytes, slots=slots,
                             max_len=self._padded(max_len),
                             cache_dtype=cache_dtype)
        self.alloc = BlockAllocator(n_pages)
        self.pages = bundle.init_paged_cache(n_pages, page_size)
        self._slots = [_Slot() for _ in range(slots)]
        self._tables = np.zeros((slots, self.max_pages_per_seq), np.int32)
        self._lengths = np.zeros((slots,), np.int32)

        self.prefill_traces = 0
        self.decode_traces = 0
        self.finish_times: Dict[int, float] = {}
        self._t0 = 0.0
        # optional telemetry/metrics.py MetricsLogger: per-decode-step
        # serve_step rows (slot occupancy, pool pressure) + one
        # serve_summary row per serve_queue call
        self.metrics = metrics
        self.last_summary: Optional[Dict[str, Any]] = None
        # admissions that landed AFTER some resident finished during the
        # current serve_queue call — i.e. token-level slot refills, the
        # continuous-batching events the dense wave engine cannot have
        self.refill_events = 0
        self._finishes_this_call = 0
        # host slot state changed since the last device upload
        self._dirty = True
        # pages donated: the pool is rebound to the returned buffer each
        # step, so the O(pool) arrays are updated in place
        self._decode = jax.jit(self._decode_impl, donate_argnums=(2,))
        self._prefill_chunk = jax.jit(self._prefill_impl, donate_argnums=(2,))

    def steady_state_summary(self) -> Optional[Dict[str, Any]]:
        """Summary of the last ``serve_queue`` call (None before one)."""
        return self.last_summary

    # ------------------------------------------------------------ #
    # jitted device steps

    def _decode_impl(self, params, toks, pages, tables, lengths, active,
                     key, step):
        """One decode step.  Everything the steady-state loop needs next
        step comes back as device arrays (next tokens, advanced lengths,
        advanced rng step), so a run of decode steps with stable slot
        population does ZERO host->device uploads — the host only reads
        the sampled tokens back to check budgets/EOS."""
        self.decode_traces += 1
        logits, pages = self.bundle.decode_step_paged(
            params, toks, pages, tables, lengths, active)
        if self.gen.temperature <= 0.0:
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        else:
            nxt = jax.random.categorical(
                jax.random.fold_in(key, step),
                logits / self.gen.temperature).astype(jnp.int32)
        return (jnp.where(active, nxt, 0), pages,
                lengths + active.astype(jnp.int32), step + 1)

    def _prefill_impl(self, params, toks, pages, table, base):
        self.prefill_traces += 1
        return self.bundle.prefill_paged_chunk(params, toks, pages, table,
                                               base)

    # ------------------------------------------------------------ #
    # host-side slot machinery

    def _padded(self, plen: int) -> int:
        return -(-plen // self.chunk) * self.chunk

    def _need_pages(self, plen: int, target: int) -> int:
        """Worst-case pages a request can touch: the full generation
        (prompt + its token budget) or the chunk-padded prefill tail,
        whichever reaches further (padded positions must be writable even
        though they are masked garbage)."""
        reach = max(plen + target, self._padded(plen))
        return pages_for(reach, self.page_size)

    def _grow_to(self, i: int, n_tokens: int) -> None:
        """Ensure slot i's table has pages covering positions [0, n_tokens)."""
        s = self._slots[i]
        while len(s.pages) * self.page_size < n_tokens:
            if s.reserved <= 0:
                raise RuntimeError("slot outgrew its admission reservation")
            pg = self.alloc.take()
            s.reserved -= 1
            self._tables[i, len(s.pages)] = pg
            s.pages.append(pg)
            self._dirty = True

    def _admit(self, i: int, rid: int, prompt: np.ndarray,
               target: int) -> bool:
        plen = len(prompt)
        if plen + target > self.max_len:
            raise ValueError(
                f"request {rid}: prompt {plen} + max_new {target} "
                f"exceeds max_len {self.max_len}")
        need = self._need_pages(plen, target)
        if not self.alloc.reserve(need):
            return False
        if self._finishes_this_call > 0:
            self.refill_events += 1
        s = self._slots[i]
        s.state, s.rid, s.plen, s.base = "prefill", rid, plen, 0
        s.target = target
        s.prompt = np.asarray(prompt, np.int32)
        s.pages, s.reserved, s.toks, s.decode_steps = [], need, [], 0
        self._tables[i, :] = 0
        self._lengths[i] = 0
        self._dirty = True
        return True

    def _finish(self, i: int, results: Dict[int, RequestResult]) -> None:
        s = self._slots[i]
        t = np.asarray(s.toks, np.int32)
        results[s.rid] = RequestResult(s.rid, s.prompt, t, len(t),
                                       decode_steps=s.decode_steps)
        self.finish_times[s.rid] = time.time() - self._t0
        self._finishes_this_call += 1
        self.alloc.release(s.pages, reserved_left=s.reserved)
        self._tables[i, :] = 0
        self._lengths[i] = 0
        self._slots[i] = _Slot()
        self._dirty = True

    def _push_token(self, i: int, tok: int,
                    results: Dict[int, RequestResult]) -> None:
        """Record a sampled token; finish the slot on EOS / token budget."""
        s = self._slots[i]
        s.toks.append(tok)
        s.last_tok = tok
        done = (len(s.toks) >= s.target
                or (self.gen.eos_id >= 0 and tok == self.gen.eos_id))
        if done:
            self._finish(i, results)

    # ------------------------------------------------------------ #

    def serve_queue(self, requests: Sequence[np.ndarray], *,
                    max_new: Optional[Sequence[int]] = None
                    ) -> List[RequestResult]:
        """Continuously-batched serving of a request queue.

        Admission is FIFO (head-of-line: a request too large for the
        remaining pool blocks later ones, preserving queue order);
        results come back ordered by request id.  ``max_new`` optionally
        carries per-request token budgets (default: the engine-wide
        ``gen.max_new_tokens``); a slot that reaches its budget or EOS is
        refilled on the very next step — no wasted decode steps.
        Per-request completion times land in ``self.finish_times``.
        """
        queue = list(enumerate(requests))
        results: Dict[int, RequestResult] = {}
        key = jax.random.PRNGKey(self.gen.seed)
        step = jnp.zeros((), jnp.int32)     # rng step, advanced on device
        self.finish_times: Dict[int, float] = {}
        self._t0 = time.time()
        self.refill_events = 0
        self._finishes_this_call = 0
        decode_step_idx = 0
        occ_sum = 0.0
        # device-side steady state: uploaded only when host slot state
        # changes (admit / finish / page growth / prefill completion);
        # between events a decode step is ONE dispatch + one token
        # readback, nothing else
        self._dirty = True
        toks_d = tables_d = lengths_d = active_d = None

        while queue or any(s.state != "free" for s in self._slots):
            # 1. admit newcomers into free slots (FIFO, pool-gated)
            for i, s in enumerate(self._slots):
                if not queue:
                    break
                if s.state == "free":
                    rid, prompt = queue[0]
                    target = (max_new[rid] if max_new is not None
                              else self.gen.max_new_tokens)
                    if not self._admit(i, rid, prompt, target):
                        break           # head-of-line: wait for pages
                    queue.pop(0)

            # 2. one prefill chunk per admitting slot (residents keep
            #    decoding between chunks — a long prompt never stalls them)
            for i, s in enumerate(self._slots):
                if s.state != "prefill":
                    continue
                self._grow_to(i, s.base + self.chunk)
                padded = np.zeros((self.chunk,), np.int32)
                span = s.prompt[s.base:s.base + self.chunk]
                padded[:len(span)] = span
                logits, self.pages = self._prefill_chunk(
                    self.params, jnp.asarray(padded)[None], self.pages,
                    jnp.asarray(self._tables[i:i + 1]),
                    jnp.asarray(s.base, jnp.int32))
                s.base += self.chunk
                if s.base >= s.plen:    # prompt fully cached -> sample
                    last = logits[0, s.plen - 1 - (s.base - self.chunk)]
                    if self.gen.temperature <= 0.0:
                        tok = int(jnp.argmax(last, -1))
                    else:
                        key, k = jax.random.split(key)
                        tok = int(jax.random.categorical(
                            k, last / self.gen.temperature))
                    s.state = "decode"
                    self._lengths[i] = s.plen
                    self._dirty = True
                    self._push_token(i, tok, results)

            # 3. one decode step over every resident (fixed shapes: the
            #    slot array never changes size, only the active mask)
            active = [s.state == "decode" for s in self._slots]
            if any(active):
                for i in range(self.slots):
                    if active[i]:       # page for the token being written
                        self._grow_to(i, int(self._lengths[i]) + 1)
                if self._dirty:         # slot population changed: upload
                    toks_d = jnp.asarray(
                        np.array([s.last_tok for s in self._slots],
                                 np.int32))
                    tables_d = jnp.asarray(self._tables)
                    lengths_d = jnp.asarray(self._lengths)
                    active_d = jnp.asarray(np.array(active))
                    self._dirty = False
                toks_d, self.pages, lengths_d, step = self._decode(
                    self.params, toks_d, self.pages, tables_d, lengths_d,
                    active_d, key, step)
                nxt = np.asarray(toks_d)
                n_active = sum(active)
                new_tokens = 0
                for i in range(self.slots):
                    if active[i]:
                        self._lengths[i] += 1
                        self._slots[i].decode_steps += 1
                        self._push_token(i, int(nxt[i]), results)
                        new_tokens += 1
                occ_sum += n_active / self.slots
                if self.metrics is not None:
                    self.metrics.log_row(
                        "serve_step", step=decode_step_idx,
                        active_slots=n_active,
                        occupancy=round(n_active / self.slots, 3),
                        new_tokens=new_tokens,
                        pages_in_use=self.alloc.in_use)
                decode_step_idx += 1

        out = [results[rid] for rid in sorted(results)]
        self.last_summary = _queue_summary(
            "paged", out, time.time() - self._t0,
            refill_events=self.refill_events,
            peak_pages_in_use=self.alloc.peak_in_use,
            pool_pages=self.alloc.n_pages - 1,
            mean_occupancy=occ_sum / max(1, decode_step_idx))
        if self.metrics is not None:
            self.metrics.log_row("serve_summary", **self.last_summary)
            self.metrics.flush()
        return out
