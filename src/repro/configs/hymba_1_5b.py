"""Hymba-1.5B — hybrid block with PARALLEL attention + Mamba(SSM) heads
[arXiv:2411.13676].

Hymba fuses attention heads and SSM heads inside the same layer (outputs are
normalized and averaged). Most layers use sliding-window attention; we model
that with a global ``sliding_window`` (the few full-attention layers of the
release are approximated by the window — noted in DESIGN.md). The SSM path is
a selective-scan (Mamba-style) head with state size 16.
"""
from repro.configs.base import ArchConfig, ParallelLayout, register


@register("hymba-1.5b")
def hymba_1_5b() -> ArchConfig:
    return ArchConfig(
        name="hymba-1.5b",
        family="hybrid",
        source="[arXiv:2411.13676]",
        n_layers=32,
        d_model=1600,
        n_heads=25,
        n_kv_heads=5,
        head_dim=64,
        d_ff=5504,
        vocab_size=32001,
        ssm_state=16,
        ssm_heads=25,
        ssm_expand=2,
        sliding_window=1024,
        layout=ParallelLayout(groups=4, local=4, fsdp=1, tp=16, microbatch=2),
    )
