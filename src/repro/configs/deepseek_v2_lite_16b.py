"""DeepSeek-V2-Lite (16B total / 2.4B active) — MLA attention
(kv_lora_rank=512) + fine-grained MoE: 2 shared + 64 routed experts, top-6,
first layer dense [arXiv:2405.04434].

Note on the pool spec: the assignment line reads "MoE 64e top-6 ... 2
shared+160 routed". 160 routed contradicts 64e and the source paper's Lite
configuration (64 routed + 2 shared, top-6); we follow the source paper /
model card. d_ff=1408 is the per-expert (and shared-expert) width; the single
leading dense layer uses the release's 10944 FFN width.
"""
from repro.configs.base import ArchConfig, ParallelLayout, register


@register("deepseek-v2-lite-16b")
def deepseek_v2_lite() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v2-lite-16b",
        family="moe",
        source="[arXiv:2405.04434]",
        n_layers=27,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,           # MLA: per-head latent decompression
        d_ff=10944,              # dense first layer
        expert_d_ff=1408,
        n_experts=64,
        n_shared_experts=2,
        top_k=6,
        first_k_dense=1,
        vocab_size=102400,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
        layout=ParallelLayout(groups=2, local=2, fsdp=4, tp=16, microbatch=4),
    )
