"""StarCoder2-15B — GQA + RoPE code model, sliding-window attention 4096
[arXiv:2402.19173]."""
from repro.configs.base import ArchConfig, ParallelLayout, register


@register("starcoder2-15b")
def starcoder2_15b() -> ArchConfig:
    return ArchConfig(
        name="starcoder2-15b",
        family="dense",
        source="[arXiv:2402.19173]",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=4,
        head_dim=128,
        d_ff=24576,
        vocab_size=49152,
        sliding_window=4096,
        act="gelu",
        layout=ParallelLayout(groups=2, local=2, fsdp=4, tp=16, microbatch=8),
    )
