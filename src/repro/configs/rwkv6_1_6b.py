"""RWKV-6 "Finch" 1.6B — attention-free RNN with data-dependent decay
[arXiv:2404.05892].

Sequence mixing is the WKV6 recurrence (O(1) state per head), so decode —
including long_500k — carries a constant-size state instead of a KV cache.
The WKV recurrence is implemented as a chunked Pallas kernel
(kernels/rwkv6_wkv.py) with a pure-jnp oracle.
"""
from repro.configs.base import ArchConfig, ParallelLayout, register


@register("rwkv6-1.6b")
def rwkv6_1_6b() -> ArchConfig:
    return ArchConfig(
        name="rwkv6-1.6b",
        family="ssm",
        source="[arXiv:2404.05892]",
        n_layers=24,
        d_model=2048,
        n_heads=0,              # attention-free
        n_kv_heads=0,
        head_dim=64,            # WKV head size
        ssm_heads=32,           # 2048 / 64
        ssm_state=64,           # per-head state is head_dim x head_dim
        d_ff=7168,
        vocab_size=65536,
        layout=ParallelLayout(groups=4, local=4, fsdp=1, tp=16, microbatch=2),
    )
