"""Qwen2-VL-2B — VLM language backbone with M-RoPE (multimodal rotary
position embedding over (temporal, height, width) sections) and dynamic
resolution [arXiv:2409.12191].

The ViT vision encoder + projector is STUBBED per assignment: ``input_specs``
supplies patch embeddings [B, n_patches, d_model] plus the (t, h, w) position
grid that M-RoPE consumes; the 28-layer LM is fully implemented.
"""
from repro.configs.base import ArchConfig, ParallelLayout, register


@register("qwen2-vl-2b")
def qwen2_vl_2b() -> ArchConfig:
    return ArchConfig(
        name="qwen2-vl-2b",
        family="vlm",
        source="[arXiv:2409.12191]",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        head_dim=128,
        d_ff=8960,
        vocab_size=151936,
        rope_theta=1.0e6,
        mrope=True,
        mrope_sections=(16, 24, 24),   # t/h/w split of the 64 rotary pairs
        frontend="vision_patches",
        frontend_tokens=256,           # stub: one image -> 256 patch embeddings
        tie_embeddings=True,
        layout=ParallelLayout(groups=4, local=4, fsdp=1, tp=16, microbatch=2),
    )
