"""SeamlessM4T-Large-v2 — speech/text encoder-decoder backbone
[arXiv:2308.11596].

The mel-spectrogram + conformer conv frontend is STUBBED per assignment:
``input_specs`` feeds precomputed frame embeddings [B, T_frames, d_model]
into the 24-layer text/speech encoder; the 24-layer decoder is fully
implemented (self-attn + cross-attn + FFN).
"""
from repro.configs.base import ArchConfig, ParallelLayout, register


@register("seamless-m4t-large-v2")
def seamless_m4t_large_v2() -> ArchConfig:
    return ArchConfig(
        name="seamless-m4t-large-v2",
        family="audio",
        source="[arXiv:2308.11596]",
        n_layers=24,             # decoder layers
        n_encoder_layers=24,
        is_encoder_decoder=True,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        head_dim=64,
        d_ff=8192,
        vocab_size=256206,
        frontend="audio_frames",
        frontend_tokens=1024,    # stub: ~20s of speech at 50 frames/s
        act="relu",
        layout=ParallelLayout(groups=4, local=4, fsdp=1, tp=16, microbatch=2),
    )
