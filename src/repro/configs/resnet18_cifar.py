"""The paper's own experimental model family.

The Hier-AVG paper trains ResNet-18 / GoogLeNet / MobileNet / VGG19 on
CIFAR-10 (and ResNet on ImageNet-1K).  For the paper-validation benchmarks we
provide a compact JAX ResNet (models/resnet.py) plus an MLP classifier for
fast CPU sweeps.  These configs drive benchmarks/, not the dry-run pool.
"""
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class CNNConfig:
    name: str = "resnet18-cifar"
    depth_blocks: Tuple[int, ...] = (2, 2, 2, 2)   # resnet-18 layout
    width: int = 16                                 # narrow for CPU sims
    n_classes: int = 10
    image_size: int = 32
    channels: int = 3


@dataclass(frozen=True)
class MLPConfig:
    name: str = "mlp-classifier"
    in_dim: int = 64
    hidden: Tuple[int, ...] = (128, 128)
    n_classes: int = 10


def resnet18_cifar() -> CNNConfig:
    return CNNConfig()


def mlp_classifier() -> MLPConfig:
    return MLPConfig()
