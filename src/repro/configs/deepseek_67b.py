"""DeepSeek-67B — dense llama-arch GQA decoder [arXiv:2401.02954]."""
from repro.configs.base import ArchConfig, ParallelLayout, register


@register("deepseek-67b")
def deepseek_67b() -> ArchConfig:
    return ArchConfig(
        name="deepseek-67b",
        family="dense",
        source="[arXiv:2401.02954]",
        n_layers=95,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=22016,
        vocab_size=102400,
        # One learner per pod (FSDP-16 x TP-16): hierarchy on the pod axis.
        layout=ParallelLayout(groups=1, local=1, fsdp=16, tp=16, microbatch=32),
    )
