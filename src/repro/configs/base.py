"""Configuration system for the Hier-AVG framework.

Every assigned architecture is an :class:`ArchConfig` registered under its
pool id (``--arch <id>``).  Configs are plain frozen dataclasses so they are
hashable (usable as static args to ``jax.jit``) and trivially serializable.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple

# the one definition of the default bucket cap (no circular import:
# comm/ never imports configs/)
from repro.comm.bucket import DEFAULT_BUCKET_BYTES


@dataclass(frozen=True)
class ParallelLayout:
    """How one pod's 16-way data axis is factored for this architecture.

    ``groups * local * fsdp`` must equal the data-axis size of the pod mesh
    (16 on the production v5e pod).  ``local`` is the paper's ``S`` (learners
    per local-averaging cluster), ``groups`` the number of clusters per pod,
    and ``fsdp`` the ZeRO-style shard factor *inside* one learner.
    """

    groups: int = 4
    local: int = 4
    fsdp: int = 1
    tp: int = 16
    microbatch: int = 1   # gradient-accumulation splits per SGD step

    @property
    def data_ways(self) -> int:
        return self.groups * self.local * self.fsdp

    @property
    def learners_per_pod(self) -> int:
        return self.groups * self.local

    @property
    def chips_per_pod(self) -> int:
        return self.data_ways * self.tp

    def validate(self, chips_per_pod: int = 256) -> None:
        """Any G*S*F*TP factorization of the pod is a valid layout (the
        production pod is 256 chips; the spec's (16, 16) data x model view
        is the TP=16 slice of this family)."""
        if self.chips_per_pod != chips_per_pod:
            raise ValueError(
                f"layout {self} uses {self.chips_per_pod} chips/pod, "
                f"expected {chips_per_pod}"
            )


@dataclass(frozen=True)
class HierAvgParams:
    """The paper's algorithm knobs (Algorithm 1), generalized to an N-level
    reduction hierarchy.

    ``plan`` is a ReductionPlan spec string (core/plan.py), e.g.
    ``"local@4:cast:bfloat16/pod@8/global@16:topk:0.05"``.  When set it
    wins over ``k1``/``k2``/``reducer`` (which are back-filled from the
    plan: ``k1`` = innermost period, ``k2`` = outermost); when unset, the
    legacy ``(k1, k2, reducer)`` trio builds the paper's 2-level plan
    bit-identically.

    ``bucket_bytes`` caps the flat-buffer buckets compressed reducers pack
    the pytree into before reducing (comm/bucket.py): compressed levels
    run one grouped collective per bucket instead of per leaf, and sparse
    reducers pick k globally per bucket.  ``0`` disables auto-bucketing
    (reducers marked ``:bucketed`` in the spec still pack); the dense
    ``mean`` is never auto-bucketed, so the default path is unchanged.

    ``overlap`` picks the bucket *schedule*: on (default), bucketed
    levels run the pipelined engine (comm/bucket.py Pipelined) — a
    double-buffered ``lax.scan`` that issues bucket *i*'s grouped
    collective before bucket *i+1*'s compress so async-collective
    backends overlap the two; off (``--no-overlap``) pins the strictly
    serial compress-then-reduce schedule.  Per-level ``:pipelined`` /
    ``:serial`` spec modifiers override the knob.  Single-bucket layouts
    are identical either way.
    """

    k1: int = 4          # innermost (local) averaging interval (SGD steps)
    k2: int = 8          # outermost (global) averaging interval
    # S (cluster size) comes from ParallelLayout.local / topology, and P from
    # the topology's total learner count.
    reducer: str = "mean"  # reduction payload spec, e.g. "topk:0.1" (comm/)
    plan: Optional[str] = None  # N-level plan spec; wins over k1/k2/reducer
    bucket_bytes: int = DEFAULT_BUCKET_BYTES
    overlap: bool = True  # pipelined (overlapped) bucket schedule

    def __post_init__(self):
        if self.bucket_bytes < 0:
            raise ValueError(
                f"bucket_bytes must be >= 0, got {self.bucket_bytes}")
        if self.plan is not None:
            # lazy import: core.plan owns parsing; this validates level
            # names, reducer specs, and period/axes nesting at build time
            from repro.core.plan import ReductionPlan
            p = ReductionPlan.parse(self.plan)
            # back-fill the legacy knobs so k1/k2-reading code (analytic
            # model, logging, schedules) stays meaningful
            object.__setattr__(self, "k1", p.levels[0].period)
            object.__setattr__(self, "k2", p.total_period)
            return
        if self.k1 < 1 or self.k2 < self.k1:
            raise ValueError(f"need 1 <= K1 <= K2, got K1={self.k1} K2={self.k2}")
        if self.k2 % self.k1 != 0:
            raise ValueError(f"K2 ({self.k2}) must be a multiple of K1 ({self.k1})")
        # lazy import: comm owns spec parsing; resolving (and discarding)
        # the reducer validates family AND arguments at config-build time
        from repro.comm import get_reducer
        get_reducer(self.reducer)

    @property
    def beta(self) -> int:
        return self.k2 // self.k1

    @property
    def resolved_plan(self):
        """The ReductionPlan this config describes (parsed fresh), with
        ``bucket_bytes`` bucketing applied — identical to what
        ``resolve_plan(self)`` gives the round builders, so comm state
        initialized from it always matches."""
        from repro.core.plan import ReductionPlan, apply_bucketing
        if self.plan is not None:
            p = ReductionPlan.parse(self.plan)
        else:
            p = ReductionPlan.from_k1_k2(self.k1, self.k2, self.reducer)
        return apply_bucketing(p, self.bucket_bytes, self.overlap)

    @property
    def batch_dims(self) -> Tuple[int, ...]:
        """Leading round-batch dims (outermost ratio first); the 2-level
        plan gives the familiar (beta, k1)."""
        return self.resolved_plan.batch_dims

    @property
    def steps_per_round(self) -> int:
        """SGD steps per round == the outermost period (== k2)."""
        return self.k2


@dataclass(frozen=True)
class ArchConfig:
    """A model architecture from the assigned pool.

    The union of fields across all six families (dense / moe / ssm / hybrid /
    vlm / audio); unused fields stay at their zero defaults.
    """

    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    source: str                      # citation ([arXiv:...] / [hf:...])

    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0                 # 0 => attention-free (rwkv)
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 0                # 0 => d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0             # per-expert FFN width (0 => d_ff)
    first_k_dense: int = 0           # leading dense layers before MoE stack
    router_aux_coef: float = 0.01    # load-balance loss weight
    capacity_factor: float = 1.25    # expert capacity slack (>=E/top_k: dropless)

    # --- MLA (DeepSeek-V2) ---
    kv_lora_rank: int = 0            # 0 => standard GQA
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # --- SSM / hybrid ---
    ssm_state: int = 0               # SSM state size (mamba); rwkv head-state
    ssm_heads: int = 0               # parallel SSM heads (hymba) / rwkv heads
    ssm_expand: int = 1

    # --- encoder-decoder / multimodal ---
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    frontend: str = ""               # "" | "audio_frames" | "vision_patches"
    frontend_tokens: int = 0         # stub frontend sequence length (train shapes)

    # --- attention details ---
    sliding_window: int = 0          # 0 => full causal; >0 => SWA window
    long_context_window: int = 8192  # rolling-buffer window used for long_500k
    rope_theta: float = 1.0e4
    mrope: bool = False              # Qwen2-VL multimodal RoPE
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)

    norm_eps: float = 1.0e-5
    tie_embeddings: bool = False
    act: str = "silu"

    layout: ParallelLayout = field(default_factory=ParallelLayout)

    # ------------------------------------------------------------------ #

    def __post_init__(self):
        if self.n_heads and self.n_kv_heads and self.n_heads % self.n_kv_heads:
            raise ValueError(
                f"{self.name}: n_heads {self.n_heads} not divisible by "
                f"n_kv_heads {self.n_kv_heads}"
            )

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 128 so the vocab dim always
        shards over TP-16 (embedding/lm_head allocation size; labels stay
        within the true vocab)."""
        return -(-self.vocab_size // 128) * 128

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.n_heads:
            return self.d_model // self.n_heads
        return 0

    @property
    def is_subquadratic(self) -> bool:
        """True if the arch has a native sub-quadratic sequence mixer."""
        return self.family in ("ssm", "hybrid")

    @property
    def uses_moe(self) -> bool:
        return self.n_experts > 0

    def param_count(self) -> int:
        """Analytic (approximate) parameter count for roofline MODEL_FLOPS."""
        d, L = self.d_model, self.n_layers
        hd = self.resolved_head_dim
        n = 0
        # embeddings (+ output head unless tied)
        n += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family == "ssm":  # rwkv6
            per_layer += 4 * d * d          # r,k,v,g time-mix projections
            per_layer += d * d              # output
            per_layer += int(1.5 * d * self.d_ff)  # channel mix (k,v, r gate)
        else:
            if self.n_heads:
                q = self.n_heads * hd
                if self.kv_lora_rank:  # MLA
                    per_layer += d * self.kv_lora_rank
                    per_layer += self.kv_lora_rank * self.n_heads * (
                        self.qk_nope_head_dim + self.v_head_dim)
                    per_layer += d * self.n_heads * (
                        self.qk_nope_head_dim + self.qk_rope_head_dim)
                    per_layer += self.n_heads * self.v_head_dim * d
                else:
                    kv = self.n_kv_heads * hd
                    per_layer += d * (q + 2 * kv) + q * d
            if self.family == "hybrid":
                # parallel SSM heads alongside attention
                per_layer += 2 * d * d * self.ssm_expand
            mats = 3 if self.act == "silu" else 2  # swiglu vs gelu/relu MLP
            if self.uses_moe:
                eff = self.expert_d_ff or self.d_ff
                per_layer += mats * d * eff * (self.n_experts + self.n_shared_experts)
                per_layer += d * self.n_experts  # router
            else:
                per_layer += mats * d * self.d_ff
        n += per_layer * L
        if self.is_encoder_decoder:
            # encoder layers: self-attn + ffn; decoder already counted above,
            # add cross-attention for decoder layers
            enc = self.n_encoder_layers
            q = self.n_heads * hd
            kv = self.n_kv_heads * hd
            mats = 3 if self.act == "silu" else 2
            n += enc * (d * (q + 2 * kv) + q * d + mats * d * self.d_ff)
            n += L * (d * (q + 2 * kv) + q * d)  # cross attn
        return n

    def active_param_count(self) -> int:
        """Params touched per token (== param_count unless MoE)."""
        if not self.uses_moe:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        eff = self.expert_d_ff or self.d_ff
        mats = 3 if self.act == "silu" else 2
        total = self.param_count()
        all_experts = mats * d * eff * self.n_experts * (L - self.first_k_dense)
        active = mats * d * eff * self.top_k * (L - self.first_k_dense)
        return total - all_experts + active

    # ------------------------------------------------------------------ #

    def reduced(self) -> "ArchConfig":
        """A smoke-test variant of the same family: 2 layers, d_model<=512,
        <=4 experts — runs a real forward/train step on one CPU device."""
        d = min(self.d_model, 256)
        n_heads = 0
        n_kv = 0
        hd = 0
        if self.n_heads:
            n_heads = min(self.n_heads, 4)
            n_kv = max(1, min(self.n_kv_heads, n_heads))
            while n_heads % n_kv:
                n_kv -= 1
            hd = 32
        changes = dict(
            name=self.name + "-reduced",
            n_layers=2,
            d_model=d,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=hd,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            layout=ParallelLayout(1, 1, 1, 1),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            long_context_window=128,
        )
        if self.uses_moe:
            changes.update(
                n_experts=min(self.n_experts, 4),
                n_shared_experts=min(self.n_shared_experts, 1),
                top_k=min(self.top_k, 2),
                expert_d_ff=min(self.expert_d_ff or self.d_ff, 128),
                first_k_dense=min(self.first_k_dense, 1),
            )
        if self.kv_lora_rank:
            changes.update(
                kv_lora_rank=64, qk_nope_head_dim=32, qk_rope_head_dim=16,
                v_head_dim=32, head_dim=0,
            )
        if self.ssm_state:
            changes.update(ssm_state=min(self.ssm_state, 8),
                           ssm_heads=min(self.ssm_heads, 4) or 4)
        if self.family == "ssm":
            changes.update(ssm_heads=4, head_dim=d // 4)
        if self.is_encoder_decoder:
            changes.update(n_encoder_layers=2)
        if self.frontend:
            changes.update(frontend_tokens=min(self.frontend_tokens, 16) or 16)
        if self.mrope:
            d2 = (changes.get("head_dim") or hd) // 2
            s1 = d2 // 4
            s2 = (d2 - s1) // 2
            changes.update(mrope_sections=(s1, s2, d2 - s1 - s2))
        return dataclasses.replace(self, **changes)

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2, default=str)


# ---------------------------------------------------------------------- #
# Input shapes (assigned)
# ---------------------------------------------------------------------- #

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------- #
# Registry
# ---------------------------------------------------------------------- #

_REGISTRY: Dict[str, Callable[[], ArchConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ArchConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ArchConfig:
    # import arch modules lazily so the registry is populated
    from repro import configs as _pkg  # noqa: F401  (triggers submodule imports)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(_REGISTRY)}")
    cfg = _REGISTRY[name]()
    return cfg


def list_archs():
    from repro import configs as _pkg  # noqa: F401
    return sorted(_REGISTRY)
