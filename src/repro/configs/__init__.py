"""Config registry: ``get_config("<arch-id>")`` / ``list_archs()``."""
from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES,
    ArchConfig,
    HierAvgParams,
    InputShape,
    ParallelLayout,
    get_config,
    list_archs,
    register,
)

# importing the arch modules populates the registry
from repro.configs import (  # noqa: F401
    deepseek_67b,
    deepseek_v2_lite_16b,
    hymba_1_5b,
    mistral_large_123b,
    phi3_5_moe_42b,
    qwen2_vl_2b,
    resnet18_cifar,
    rwkv6_1_6b,
    seamless_m4t_large_v2,
    starcoder2_15b,
    yi_34b,
)

ALL_ARCHS = (
    "yi-34b",
    "seamless-m4t-large-v2",
    "hymba-1.5b",
    "rwkv6-1.6b",
    "qwen2-vl-2b",
    "mistral-large-123b",
    "phi3.5-moe-42b-a6.6b",
    "deepseek-67b",
    "starcoder2-15b",
    "deepseek-v2-lite-16b",
)
