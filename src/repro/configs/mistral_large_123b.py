"""Mistral-Large-Instruct-2407 (123B) — dense GQA decoder
[hf:mistralai/Mistral-Large-Instruct-2407]."""
from repro.configs.base import ArchConfig, ParallelLayout, register


@register("mistral-large-123b")
def mistral_large_123b() -> ArchConfig:
    return ArchConfig(
        name="mistral-large-123b",
        family="dense",
        source="[hf:mistralai/Mistral-Large-Instruct-2407]",
        n_layers=88,
        d_model=12288,
        n_heads=96,
        n_kv_heads=8,
        head_dim=128,
        d_ff=28672,
        vocab_size=32768,
        rope_theta=1.0e6,
        # 123B: one learner per pod (FSDP-16 x TP-16); the Hier-AVG hierarchy
        # lives on the pod axis — local = intra-pod, global = cross-pod DCI.
        layout=ParallelLayout(groups=1, local=1, fsdp=16, tp=16, microbatch=32),
    )
