"""Yi-34B — dense llama-arch GQA decoder [arXiv:2403.04652]."""
from repro.configs.base import ArchConfig, ParallelLayout, register


@register("yi-34b")
def yi_34b() -> ArchConfig:
    return ArchConfig(
        name="yi-34b",
        family="dense",
        source="[arXiv:2403.04652]",
        n_layers=60,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        head_dim=128,
        d_ff=20480,
        vocab_size=64000,
        rope_theta=5.0e6,
        # 34B bf16 params need >= 8-way FSDP on 16GB HBM alongside TP-16:
        # 2 learners/pod, one local cluster of S=2 per pod.
        layout=ParallelLayout(groups=1, local=2, fsdp=8, tp=16, microbatch=16),
    )
