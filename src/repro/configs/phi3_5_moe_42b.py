"""Phi-3.5-MoE (42B total / 6.6B active) — 16 experts, top-2 routing
[hf:microsoft/Phi-3.5-MoE-instruct]."""
from repro.configs.base import ArchConfig, ParallelLayout, register


@register("phi3.5-moe-42b-a6.6b")
def phi35_moe() -> ArchConfig:
    return ArchConfig(
        name="phi3.5-moe-42b-a6.6b",
        family="moe",
        source="[hf:microsoft/Phi-3.5-MoE-instruct]",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=6400,
        expert_d_ff=6400,
        n_experts=16,
        top_k=2,
        vocab_size=32064,
        # 16 experts shard 1:1 over the TP-16 axis (expert parallelism).
        layout=ParallelLayout(groups=1, local=2, fsdp=8, tp=16, microbatch=16),
    )
