"""Bucketed flat-buffer reductions: pack the pytree once, compress and
all-reduce a few big contiguous buckets instead of one collective per leaf.

The per-leaf pipeline (comm/reducer.py) pays O(n_leaves) grouped
collectives and O(n_leaves) compression kernel launches per reduction, and
sparse reducers pick k *per leaf* — while the convergence analyses they
lean on (Stich et al., arXiv:1805.09767) assume top-k over the full
parameter vector.  Packing fixes all three at once (the PowerSGD /
Hivemind "flat grads" recipe):

  * :class:`BucketLayout` — computed once per (treedef, shapes, dtypes)
    from the param pytree: dtype-grouped, size-capped buckets of the
    per-learner trailing dims, preserving the stacked ``[pods, G, S]``
    learner axes.  ``pack`` is one reshape + one concat per bucket (no
    per-leaf dispatch on the hot path); ``unpack`` is static slices.
  * :class:`Bucketed` — wraps any comm/ Reducer so it sees whole buckets
    as its leaves: O(n_buckets) collectives, a *global* k-of-the-model
    selection for topk/randk (more accuracy per payload byte), and one
    tiled kernel pass over a flat buffer instead of many ragged launches.

Layout contract: buckets carry the same stacked learner axes as the leaves
they pack (``[pods, G, S, n]``; matrix-mode ``[pods, G, S, a, b]``), so the
grouped means of core/topology.py — and GSPMD's lowering of them to grouped
all-reduces — apply to buckets unchanged.  Packing permutes no values and
the learner-axis mean is elementwise, so bucketed mean/cast are
*bit-identical* to the per-leaf path (test-enforced); bucketed topk/randk
differ by design (global k vs per-leaf k).

Error-feedback state lives in bucket space: ``Bucketed.init_state`` packs
the params first, and every compress re-derives the layout and checks the
carried state against it, so a layout/state mismatch fails loudly instead
of silently misaligning residuals.

Shard-aware layouts (``fsdp > 1``): when built with a
:class:`~repro.parallel.sharding.ShardPlan`, leaves whose trailing dims are
fsdp-sharded (resolved by the same rules + divisibility logic as
``safe_pspec``) pack into *per-shard runs* — bucket shape
``[pods, G, S, F, run]`` with the ``F`` axis carrying the shard coordinate,
so each host packs only the slice it owns and packing stays collective-free.
The codec then sees the *merged* view ``[pods, G, S*F, run]`` (shards act as
extra learners), so top-k/EF selection is per-shard and error-feedback state
lives in shard space; the grouped mean runs on the *wire* view through
``core/topology.py``'s explicit reduce-scatter + all-gather lowering instead
of an all-reduce that would re-materialize every shard.  Runs (sharded and
flat alike) are padded to a multiple of the learner count so every level's
reduce-scatter tiles evenly.

:class:`Pipelined` (the default engine when ``HierAvgParams.overlap`` is
on) runs the same bucket codec on a double-buffered schedule — a
``lax.scan`` over uniform buckets that issues stage *i*'s grouped
collective before stage *i+1*'s compress, so async-collective backends
overlap the two and the program stays O(1) in bucket count.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.comm.reducer import N_LEARNER_AXES, Reducer, serial_reduce
from repro.parallel.sharding import ShardPlan, _path_str

# Default per-bucket cap (bytes of one learner's slice).  4 MiB keeps a
# whole fp32 bucket row (~1M elements) inside a TPU core's VMEM budget for
# the Pallas topk_compress kernel, and is large enough that transformer
# blocks pack into a handful of buckets.  The single source of truth:
# HierAvgParams.bucket_bytes and --bucket-bytes default to this.
DEFAULT_BUCKET_BYTES = 4 << 20


@dataclass(frozen=True)
class BucketSlot:
    """Where one leaf lives inside its bucket.

    In a sharded bucket (``BucketSpec.shards > 1``) ``offset``/``size``
    are in *per-shard* elements — the run each of the F shard coordinates
    contributes, ``size = leaf_size / F``.
    """

    leaf: int                  # index into the flattened tree
    offset: int                # element offset within the bucket (run)
    size: int                  # per-learner (per-shard if sharded) count
    shape: Tuple[int, ...]     # per-learner trailing shape
    shard_dim: Optional[int] = None   # which trailing dim fsdp shards


@dataclass(frozen=True)
class BucketSpec:
    """One contiguous, single-dtype bucket."""

    dtype: str                 # canonical dtype name (hashable)
    size: int                  # unpadded run length (per-shard if sharded)
    shape: Tuple[int, ...]     # per-learner bucket shape: (run,) flat,
                               # (F, run) sharded, or (a, b) zero-padded
                               # in matrix mode
    slots: Tuple[BucketSlot, ...]
    shards: int = 1            # fsdp shard count F (1 == replicated run)

    @property
    def padded_size(self) -> int:
        return math.prod(self.shape)


def _matrix_shape(size: int) -> Tuple[int, int]:
    """Near-square (a, b) with a*b >= size — matrix view for low-rank
    reducers (pad is zero-filled and stripped on unpack)."""
    a = max(1, int(math.isqrt(size)))
    b = -(-size // a)
    return a, b


def _split_shard(x, lead: int, sd: int, F: int):
    """``[*lead, *trailing]`` -> ``[*lead, F, run]``: expose the fsdp
    shard coordinate of trailing dim ``sd`` as an explicit F-major axis.
    GSPMD shards a dim into F contiguous blocks, so the split reshape,
    the transpose, and the final flatten are all shard-local — no
    collective is issued by packing."""
    a = lead + sd
    d = x.shape[a]
    y = x.reshape(x.shape[:a] + (F, d // F) + x.shape[a + 1:])
    y = jnp.moveaxis(y, a, lead)
    return y.reshape(y.shape[:lead + 1] + (-1,))


def _join_shard(y, lead: int, sd: int, shape: Tuple[int, ...], F: int):
    """Inverse of :func:`_split_shard`: ``[*lead, F, run]`` back to the
    leaf's per-learner ``shape`` (also shard-local)."""
    rest = shape[:sd] + (shape[sd] // F,) + shape[sd + 1:]
    y = y.reshape(y.shape[:lead] + (F,) + rest)
    y = jnp.moveaxis(y, lead, lead + sd)
    return y.reshape(y.shape[:lead] + tuple(shape))


@dataclass(frozen=True)
class BucketLayout:
    """Static packing plan for one pytree (shape/dtype) signature.

    ``lead_axes`` is the number of leading stacked-learner axes every leaf
    carries (3 for train-state trees, 0 for the single-learner templates
    ``payload_bytes`` sizes).
    """

    treedef: Any
    lead_axes: int
    buckets: Tuple[BucketSpec, ...]
    shards: Optional[ShardPlan] = None

    @property
    def lead_invariant(self) -> bool:
        """True when the packed run layout is independent of the learner
        count — the property the elastic fleet reshape
        (repro/elastic/reshape.py) relies on to re-index bucket-space EF
        state across a join/leave by a pure lead-axes gather.  Flat
        (``shards is None``) layouts qualify: slots and run lengths are
        computed from per-learner trailing dims only.  Shard-aware
        layouts do not — runs are padded to a multiple of the lead mesh
        size and the codec view merges shards into the local axis — so
        their reducer state is dropped loudly on reshape instead."""
        return self.shards is None

    # ------------------------------------------------------------------ #
    # construction
    # ------------------------------------------------------------------ #

    @classmethod
    def build(cls, tree, *, bucket_bytes: int = DEFAULT_BUCKET_BYTES,
              lead_axes: int = N_LEARNER_AXES,
              matrix: bool = False, uniform: bool = False,
              shards: Optional[ShardPlan] = None
              ) -> "BucketLayout":
        """Dtype-grouped, size-capped buckets in leaf order.

        A leaf larger than ``bucket_bytes`` gets a bucket of its own
        (leaves are never split across buckets); ``bucket_bytes <= 0``
        means one bucket per dtype.

        ``uniform=True`` zero-pads every bucket of a group to the
        group's largest bucket, so the buckets form a rectangular
        schedule a ``lax.scan`` can iterate (the pipelined engine's
        requirement); single-bucket groups keep their exact size, so
        uniform and ragged layouts agree whenever there is nothing to
        scan over.  Matrix-mode groups pad to the group's largest
        ``(a, b)`` panel elementwise — every bucket of the group becomes
        the same near-square matrix, which is what lets PowerSGD's
        buckets join the scan (the padded tail is zero, which low-rank
        factorization preserves exactly at convergence of the zero
        block, and unpack strips it).  NOTE a uniform matrix layout
        therefore reshapes bucket data to a *different* (a, b) than the
        ragged serial layout does — same-schedule comparisons must use
        the same layout (see tests/test_bucket.py).

        ``shards`` — the :class:`~repro.parallel.sharding.ShardPlan` of
        an ``fsdp > 1`` ``ParallelLayout`` — makes the layout
        shard-aware: leaves whose trailing dims the plan shards (resolved
        per leaf path with the same divisibility fallback as
        ``safe_pspec``) go to *sharded* buckets with one run per shard
        (``shape = (F, run)``), packed from each host's own slice; leaves
        the plan leaves replicated pack flat as before.  All runs are
        padded to a multiple of the learner count so every level's
        reduce-scatter + all-gather lowering tiles evenly.  Matrix-mode
        (low-rank) reducers cannot act on a per-shard run, so matrix +
        sharded leaves still refuses.
        """
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        F = shards.size if shards is not None else 1
        n_lead = shards.n_lead if shards is not None else 1
        groups: Dict[Tuple[str, bool],
                     List[Tuple[int, Tuple[int, ...], int,
                                Optional[int]]]] = {}
        for i, (kp, leaf) in enumerate(flat):
            if len(leaf.shape) < lead_axes:
                raise ValueError(
                    f"leaf {i} has shape {tuple(leaf.shape)} but the layout "
                    f"expects {lead_axes} leading learner axes")
            shape = tuple(leaf.shape[lead_axes:])
            size = math.prod(shape) if shape else 1
            name = jnp.dtype(leaf.dtype).name
            sd = None
            if shards is not None and F > 1:
                sd = shards.leaf_shard_dim(_path_str(kp), shape)
            if sd is not None and matrix:
                raise NotImplementedError(
                    f"matrix-mode (low-rank) reducers cannot pack "
                    f"fsdp-sharded leaves: leaf {_path_str(kp)} is sharded "
                    f"on trailing dim {sd}; use a coordinate-wise reducer "
                    f"(mean/cast/topk/randk/qint8) under fsdp>1, or run "
                    f"PowerSGD with fsdp=1")
            run = size // F if sd is not None else size
            groups.setdefault((name, sd is not None), []).append(
                (i, shape, run, sd))

        buckets: List[BucketSpec] = []
        for (name, sharded), entries in groups.items():  # insertion order
            itemsize = jnp.dtype(name).itemsize
            shard_n = F if sharded else 1
            cap = (bucket_bytes // itemsize) if bucket_bytes > 0 else 0
            cap = max(1, cap // shard_n) if cap else 0  # per-shard units
            slots: List[BucketSlot] = []
            filled = 0

            def flush():
                nonlocal slots, filled
                if not slots:
                    return
                if matrix:
                    shape: Tuple[int, ...] = _matrix_shape(filled)
                else:
                    run_p = filled if shards is None \
                        else -(-filled // n_lead) * n_lead
                    shape = (shard_n, run_p) if sharded else (run_p,)
                buckets.append(BucketSpec(name, filled, shape,
                                          tuple(slots), shard_n))
                slots, filled = [], 0

            group_start = len(buckets)
            for i, shape, run, sd in entries:
                if cap and slots and filled + run > cap:
                    flush()
                slots.append(BucketSlot(i, filled, run, shape, sd))
                filled += run
            flush()
            if uniform and len(buckets) - group_start > 1:
                group = buckets[group_start:]
                if matrix:
                    # common near-square panel: elementwise max over the
                    # group's (a, b) shapes, so every bucket reshapes to
                    # the same matrix and the scan is rectangular
                    pad_shape: Tuple[int, ...] = tuple(
                        max(b.shape[d] for b in group)
                        for d in range(len(group[0].shape)))
                    buckets[group_start:] = [
                        BucketSpec(b.dtype, b.size, pad_shape,
                                   b.slots, b.shards)
                        for b in group]
                else:
                    pad_n = max(b.shape[-1] for b in group)
                    buckets[group_start:] = [
                        BucketSpec(b.dtype, b.size, b.shape[:-1] + (pad_n,),
                                   b.slots, b.shards)
                        for b in group]
        return cls(treedef, lead_axes, tuple(buckets), shards)

    # ------------------------------------------------------------------ #
    # derived facts
    # ------------------------------------------------------------------ #

    @property
    def n_buckets(self) -> int:
        return len(self.buckets)

    @property
    def n_leaves(self) -> int:
        return sum(len(b.slots) for b in self.buckets)

    def bucket_structs(self, lead: Tuple[int, ...] = ()
                       ) -> List[jax.ShapeDtypeStruct]:
        """Shape/dtype templates of the packed buckets (for analytic
        accounting — no arrays allocated)."""
        return [jax.ShapeDtypeStruct(lead + b.shape, jnp.dtype(b.dtype))
                for b in self.buckets]

    def describe(self) -> str:
        return (f"{self.n_leaves} leaves -> {self.n_buckets} bucket(s): "
                + ", ".join(
                    (f"{b.dtype}[{b.shards}x{b.size}]" if b.shards > 1
                     else f"{b.dtype}[{b.size}]")
                    for b in self.buckets))

    # ------------------------------------------------------------------ #
    # pack / unpack
    # ------------------------------------------------------------------ #

    def pack(self, tree) -> List[jax.Array]:
        """Pytree -> list of bucket arrays ``[*lead, *bucket.shape]`` (the
        *wire* view: sharded buckets are ``[*lead, F, run]``).

        One reshape per leaf (free — layout metadata only) and one concat
        per bucket; values are never permuted across learners or shards,
        so elementwise reductions over the lead axes commute with packing
        bit-for-bit, and for sharded buckets every reshape/transpose is
        shard-local (see :func:`_split_shard`).
        """
        leaves = self.treedef.flatten_up_to(tree)
        out: List[jax.Array] = []
        for b in self.buckets:
            lead = tuple(leaves[b.slots[0].leaf].shape[:self.lead_axes])
            nl = len(lead)
            if b.shards > 1:
                parts = [_split_shard(leaves[s.leaf], nl, s.shard_dim,
                                      b.shards) for s in b.slots]
            else:
                parts = [leaves[s.leaf].reshape(lead + (s.size,))
                         for s in b.slots]
            flat = parts[0] if len(parts) == 1 \
                else jnp.concatenate(parts, axis=-1)
            if b.shards == 1 and len(b.shape) > 1:   # matrix view
                pad = b.padded_size - b.size
                if pad:
                    flat = jnp.pad(flat, [(0, 0)] * (flat.ndim - 1)
                                   + [(0, pad)])
                flat = flat.reshape(lead + b.shape)
            else:
                run_pad = b.shape[-1] - b.size
                if run_pad:
                    flat = jnp.pad(flat, [(0, 0)] * (flat.ndim - 1)
                                   + [(0, run_pad)])
            out.append(flat)
        return out

    def unpack(self, buckets) -> Any:
        """Inverse of :meth:`pack` (padding stripped; wire view in)."""
        leaves: List[Any] = [None] * self.n_leaves
        for b, arr in zip(self.buckets, buckets):
            lead = tuple(arr.shape[:arr.ndim - len(b.shape)])
            nl = len(lead)
            if b.shards > 1:
                for s in b.slots:
                    piece = jax.lax.slice_in_dim(arr, s.offset,
                                                 s.offset + s.size, axis=-1)
                    leaves[s.leaf] = _join_shard(piece, nl, s.shard_dim,
                                                 s.shape, b.shards)
                continue
            flat = arr.reshape(lead + (b.padded_size,))
            for s in b.slots:
                piece = jax.lax.slice_in_dim(flat, s.offset,
                                             s.offset + s.size, axis=-1)
                leaves[s.leaf] = piece.reshape(lead + s.shape)
        return self.treedef.unflatten(leaves)

    # ------------------------------------------------------------------ #
    # wire view <-> codec view (shard-aware layouts)
    # ------------------------------------------------------------------ #
    #
    # Sharded buckets have two equivalent reshapes:
    #   wire view  [pods, G, S, F, run] — what pack() emits and what the
    #       reduce-scatter/all-gather mean consumes (the fsdp axis is a
    #       batch dim the collectives never touch);
    #   codec view [pods, G, S*F, run] — what the wrapped reducer sees:
    #       shards act as extra learner rows, so per-learner codecs
    #       (top-k selection, EF residuals, qint8 blocks) become
    #       *per-shard* with zero codec changes, and EF state is carried
    #       in shard space.
    # Both reshapes merge/split fully-sharded mesh dims in major-minor
    # order, so they are shard-local (no data movement).  Flat buckets
    # pass through unchanged.

    def _to_codec(self, b: BucketSpec, arr):
        if b.shards == 1:
            return arr
        la = self.lead_axes
        return arr.reshape(arr.shape[:la - 1]
                           + (arr.shape[la - 1] * b.shards,)
                           + arr.shape[la + 1:])

    def _to_wire(self, b: BucketSpec, arr):
        if b.shards == 1:
            return arr
        la = self.lead_axes
        return arr.reshape(arr.shape[:la - 1]
                           + (arr.shape[la - 1] // b.shards, b.shards)
                           + arr.shape[la:])

    def codec_view(self, buckets) -> List[jax.Array]:
        return [self._to_codec(b, a) for b, a in zip(self.buckets, buckets)]

    def wire_view(self, buckets) -> List[jax.Array]:
        return [self._to_wire(b, a) for b, a in zip(self.buckets, buckets)]

    def bucket_shardings(self):
        """Per-bucket NamedShardings for the wire view (None entries keep
        the plain all-reduce mean), or None when the whole layout is
        replicated (fsdp=1) and the fast path applies unchanged."""
        if self.shards is None:
            return None
        lead = tuple(self.shards.lead)
        if self.lead_axes != len(lead):
            return None               # accounting layouts (lead_axes=0)
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = self.shards.mesh
        specs = []
        for b in self.buckets:
            if b.shards > 1:
                specs.append(NamedSharding(
                    mesh, P(*lead, self.shards.axis, None)))
            elif len(b.shape) == 1:
                specs.append(NamedSharding(mesh, P(*lead, None)))
            else:                     # matrix buckets: plain path
                specs.append(None)
        return specs


# --------------------------------------------------------------------- #
# the Bucketed reducer wrapper
# --------------------------------------------------------------------- #

def _signature(tree, lead_axes: int):
    leaves, treedef = jax.tree.flatten(tree)
    return (treedef, lead_axes,
            tuple((tuple(l.shape), jnp.dtype(l.dtype).name)
                  for l in leaves))


class Bucketed(Reducer):
    """Run any comm/ Reducer on packed buckets instead of raw leaves.

    The wrapped reducer's codec is unchanged — it simply sees n_buckets
    flat (or, for ``wants_matrix`` reducers like PowerSGD, near-square)
    leaves instead of n_leaves ragged ones.  Stateful reducers carry their
    EF/warm-start state in bucket space; ``init_state`` must therefore be
    built from the same layout the round uses (``compress`` checks).
    """

    name = "bucketed"
    # Pipelined overrides: uniform (scan-able) bucket shapes + the
    # interleaved schedule
    uniform_layout = False
    # set by the explicit ":pipelined" spec modifier (comm/__init__.py):
    # plan resolution must NOT demote this wrapper to the serial engine
    # when the plan's overlap knob is off.  Auto-pipelined wrappers
    # (created by apply_bucketing from overlap=True) leave it False so a
    # later resolution with overlap=False can rebuild them serial.
    pipeline_pin = False

    def __init__(self, inner: Reducer, bucket_bytes: Optional[int] = None,
                 shards: Optional[ShardPlan] = None):
        """``bucket_bytes=None`` means "inherit": the layout uses
        DEFAULT_BUCKET_BYTES until plan resolution (core/plan.py
        apply_bucketing) re-caps the wrapper with the plan's
        ``HierAvgParams.bucket_bytes`` — so an explicit ``:bucketed``
        spec modifier still honors the config knob.

        ``shards`` (a :class:`~repro.parallel.sharding.ShardPlan`, from
        an ``fsdp > 1`` layout) makes every layout this wrapper builds
        shard-aware and switches the grouped means to the
        reduce-scatter/all-gather lowering; None keeps the replicated
        fast path byte-identical to before."""
        if isinstance(inner, Bucketed):
            if shards is None:
                shards = inner.shards
            inner = inner.inner
        if bucket_bytes is not None and bucket_bytes < 0:
            raise ValueError(
                f"bucket_bytes must be >= 0, got {bucket_bytes}")
        self.inner = inner
        self.bucket_bytes = None if bucket_bytes is None \
            else int(bucket_bytes)
        self.shards = shards
        self.stateful = inner.stateful
        self._layouts: Dict[Any, BucketLayout] = {}

    @property
    def effective_bucket_bytes(self) -> int:
        return DEFAULT_BUCKET_BYTES if self.bucket_bytes is None \
            else self.bucket_bytes

    @property
    def has_codec(self) -> bool:
        return self.inner.has_codec

    @property
    def codec_name(self) -> str:
        # per-codec compute pricing keys on the wrapped codec, not the
        # engine ("bucketed"/"pipelined" are schedules, not codecs)
        return self.inner.codec_name

    # -- layout ---------------------------------------------------------- #

    def layout_for(self, tree, lead_axes: int = N_LEARNER_AXES
                   ) -> BucketLayout:
        """The (cached) layout for this tree signature — shapes and dtypes
        are static under jit, so this is trace-time work only."""
        key = (_signature(tree, lead_axes), self.shards)
        lay = self._layouts.get(key)
        if lay is None:
            lay = BucketLayout.build(
                tree, bucket_bytes=self.effective_bucket_bytes,
                lead_axes=lead_axes,
                matrix=getattr(self.inner, "wants_matrix", False),
                uniform=self.uniform_layout,
                shards=self.shards)
            self._layouts[key] = lay
        return lay

    def _check_state(self, lay: BucketLayout, state, lead: Tuple[int, ...]):
        refs = getattr(state, "ref", None)
        if refs is None:
            return
        got = [tuple(r.shape) for r in jax.tree.leaves(refs)]
        # EF state lives in shard space: codec-view shapes, where the F
        # shard rows merge into the last learner axis
        want = [lead[:-1] + (lead[-1] * b.shards,) + b.shape[1:]
                if b.shards > 1 else lead + b.shape
                for b in lay.buckets]
        if got != want:
            raise ValueError(
                "bucketed reducer state does not match the bucket layout "
                f"(state buckets {got}, layout wants {want}); build the "
                "initial state with init_state(..., plan=...) using the "
                "same plan/bucket_bytes the round was built with")

    # -- carried state --------------------------------------------------- #

    def init_state(self, params):
        lay = self.layout_for(params)
        # codec view: for shard-aware layouts the EF/warm-start state is
        # per-shard ([pods, G, S*F, run]) — shard space
        return self.inner.init_state(lay.codec_view(lay.pack(params)))

    # -- codec ----------------------------------------------------------- #

    def compress(self, tree, state):
        lay = self.layout_for(tree)
        buckets = lay.codec_view(lay.pack(tree))
        if self.stateful:
            lead = tuple(jax.tree.leaves(tree)[0].shape[:lay.lead_axes])
            self._check_state(lay, state, lead)
        return self.inner.compress(buckets, state)

    def decompress(self, payload, like, state):
        lay = self.layout_for(like)
        # the reconstruction stays in bucket space: the grouped mean that
        # follows (core/topology.py) is elementwise over the lead axes, so
        # it averages buckets exactly as it would leaves.  Returned in the
        # WIRE view ([pods, G, S, F, run] for sharded buckets) so the
        # learner-axis mean — plain or reduce-scatter/all-gather — never
        # mixes shard coordinates.
        xhat = self.inner.decompress(payload, lay.codec_view(lay.pack(like)),
                                     state)
        return lay.wire_view(xhat)

    def finalize(self, avg_tree, orig_tree, state):
        lay = self.layout_for(orig_tree)
        out, state = self.inner.finalize(
            lay.codec_view(avg_tree),
            lay.codec_view(lay.pack(orig_tree)), state)
        return lay.unpack(lay.wire_view(out)), state

    # -- the serial schedule --------------------------------------------- #

    def reduce(self, avg_fn, tree, state, constraint_fn=None):
        """The serial composition, shard-aware: when the layout carries a
        ShardPlan, the per-bucket grouped mean goes through the explicit
        reduce-scatter + all-gather lowering (core/topology.py) via the
        ``bucket_specs`` hook; fsdp=1 layouts run the unchanged serial
        path."""
        specs = self.layout_for(tree).bucket_shardings()
        if specs is not None:
            inner_avg = avg_fn

            def avg_fn(t, cf=None):            # noqa: F811
                return inner_avg(t, cf, specs)
        return serial_reduce(self, avg_fn, tree, state, constraint_fn)

    # -- accounting ------------------------------------------------------ #

    def payload_bytes(self, tree) -> int:
        lay = self.layout_for(tree, lead_axes=0)
        return self.inner.payload_bytes(lay.bucket_structs())

    def wire_payload_bytes(self, tree) -> int:
        """Bytes per *device*: sharded buckets move only the 1/F shard
        slice through their reduce-scatter/all-gather (the ring moves the
        same total volume as an all-reduce of the slice), so each sharded
        bucket bills at payload / F."""
        lay = self.layout_for(tree, lead_axes=0)
        total = 0
        for b, struct in zip(lay.buckets, lay.bucket_structs()):
            total += self.inner.payload_bytes([struct]) // max(1, b.shards)
        return int(total)

    def n_messages(self, tree) -> int:
        """Grouped collectives per reduction: what the inner codec
        dispatches per *bucket* rather than per leaf — one for
        single-buffer codecs, two per bucket for the two-pass qint8
        (payload + scale arrays ride separately) and per compressible
        bucket for PowerSGD (the P^ and Q' factors)."""
        lay = self.layout_for(tree, lead_axes=0)
        return self.inner.n_messages(lay.bucket_structs())

    def _describe(self) -> str:
        return f"{self.inner.describe()}:bucketed"


# --------------------------------------------------------------------- #
# the pipelined (overlapped) bucket schedule
# --------------------------------------------------------------------- #

class Pipelined(Bucketed):
    """Bucketed reductions on a software-pipelined, double-buffered
    schedule: while bucket *i*'s reconstruction is in its grouped
    collective, bucket *i+1* is already compressing.

    The per-bucket stages are expressed as one ``lax.scan`` over the
    bucket schedule (uniform, zero-padded buckets — see
    ``BucketLayout.build(uniform=True)``), with the collective for stage
    *i* issued at the top of iteration *i+1*, before that iteration's
    compress.  The two are data-independent — the collective consumes
    only the loop carry — so a backend with async collectives
    (``all-reduce-start``/``-done``) can run stage *i+1*'s compress
    inside stage *i*'s collective window; tests/test_pipeline.py asserts
    this structure on the compiled HLO.  The scan also keeps the program
    size O(1) in the bucket count (the serial path unrolls one
    compress/collective/decompress chain per bucket), which is what
    keeps compile time flat when a multi-GB model packs into hundreds of
    buckets.

    Semantics: pipelining is a schedule change only.  ``mean``/``cast``
    are bit-identical to the serial Bucketed path (test-enforced);
    ``topk`` selects k over the zero-padded uniform bucket (padding is
    never selected, but k = ratio * padded size, so k can differ by a
    few coordinates from the ragged serial layout); ``randk`` draws its
    per-bucket support from a per-stage folded key (a different — equally
    fresh — stream than the serial path); ``powersgd`` factorizes the
    group's common near-square panel (a different matrix reshape than the
    ragged serial layout — same-layout schedules are bit-identical,
    test-enforced).  Stateful codecs run their ``finalize`` — dtype
    restoration AND the EF/ref update — *inside* the scan, one stage
    behind the collective, so no post-loop pass re-materializes refs;
    the serial-schedule composition on the same layout is bit-identical.
    Reducers whose carried state cannot be split per bucket
    (``split_bucket_states`` -> None, e.g. per-leaf state handed to the
    bucket engine) and single-bucket layouts fall back to the serial
    schedule inside ``reduce`` — same math, nothing to overlap.
    """

    name = "pipelined"
    overlaps = True            # theory.plan_comm_per_round costing hint
    # every group pads to a rectangular schedule — flat runs to the max
    # run length, matrix (PowerSGD) groups to the common (max a, max b)
    # panel — so all codecs scan
    uniform_layout = True

    # -- per-bucket stage ------------------------------------------------ #

    def _stage(self, bucket, st):
        """compress+reconstruct one bucket: the compute half of a
        pipeline stage (the collective half is the avg_fn call)."""
        payload, st2 = self.inner.compress([bucket], st)
        xhat = self.inner.decompress(payload, [bucket], st2)
        return xhat[0], st2

    # -- the schedule ---------------------------------------------------- #

    def reduce(self, avg_fn, tree, state, constraint_fn=None):
        """The whole reduction, pipelined per bucket (called by
        ``reduce_with`` instead of the serial composition)."""
        lay = self.layout_for(tree)
        n = lay.n_buckets
        sts = (self.inner.split_bucket_states(state, n) if self.stateful
               else [() for _ in range(n)])
        if n < 2 or sts is None:
            # nothing to overlap / unsplittable state: serial schedule
            # (Bucketed.reduce — shard-aware when the layout is)
            return Bucketed.reduce(self, avg_fn, tree, state, constraint_fn)
        if self.stateful:
            lead = tuple(jax.tree.leaves(tree)[0].shape[:lay.lead_axes])
            self._check_state(lay, state, lead)
        specs = lay.bucket_shardings()
        # stages and state run in the codec view (shard space); only the
        # grouped mean round-trips through the wire view
        buckets = lay.codec_view(lay.pack(tree))

        def bucket_avg(i):
            """The grouped-mean half of bucket *i*'s stage, as a
            single-argument fn of the codec-view reconstruction."""
            if specs is None:
                return lambda xhat: avg_fn(xhat, constraint_fn)
            b = lay.buckets[i]

            def gavg(xhat):
                wire = lay._to_wire(b, xhat)
                out = avg_fn([wire], constraint_fn, [specs[i]])[0]
                return lay._to_codec(b, out)
            return gavg

        outs: List[Any] = [None] * n
        fin_sts: List[Any] = list(sts)
        # scan needs rectangular xs: pipeline each (dtype, shape, shards)
        # run of the uniform layout (sharded and flat buckets never mix —
        # their ranks and specs differ); a run of one has no neighbor to
        # overlap.  Buckets within a run share shape/shards, hence the
        # same wire spec, so one traced avg serves the whole scan.
        groups: Dict[Tuple[str, Tuple[int, ...], int], List[int]] = {}
        for i, b in enumerate(lay.buckets):
            groups.setdefault((b.dtype, b.shape, b.shards), []).append(i)
        for idxs in groups.values():
            if len(idxs) == 1:
                i = idxs[0]
                xhat, st2 = self._stage(buckets[i], sts[i])
                outb, st_f = self.inner.finalize(
                    [bucket_avg(i)(xhat)], [buckets[i]], st2)
                outs[i] = outb[0]
                fin_sts[i] = st_f
            else:
                self._pipeline(idxs, buckets, sts, outs, fin_sts,
                               bucket_avg(idxs[0]))

        # every bucket is already finalized (dtype restored, EF refs
        # updated) by its own stage — no post-loop finalize pass
        new_state = (self.inner.join_bucket_states(state, fin_sts)
                     if self.stateful else state)
        return lay.unpack(lay.wire_view(outs)), new_state

    def _pipeline(self, idxs, buckets, sts, outs, fin_sts, gavg):
        """Double-buffered scan over one uniform bucket run: iteration
        *j* issues the collective for stage *j-1*'s reconstruction (the
        carry), finalizes that stage in-scan (dtype restoration + EF/ref
        update, one stage behind the collective), and then compresses
        bucket *j* — so the collective never waits on this iteration's
        compute, and vice versa."""
        stateful = self.stateful
        # prologue: fill the pipeline with stage 0's compress
        xhat0, st0 = self._stage(buckets[idxs[0]], sts[idxs[0]])
        xs = jnp.stack([buckets[i] for i in idxs[1:]])
        if stateful:
            st_xs = jax.tree.map(lambda *ls: jnp.stack(ls),
                                 *[sts[i] for i in idxs[1:]])

        def body(carry, x):
            xh_p, st_p = carry
            # collective for the carried stage FIRST — it depends only on
            # the carry, so stage j's compress below is free to overlap it
            out_p = gavg(xh_p)
            b, st = x if stateful else (x, ())
            # finalize the carried stage with bucket j standing in as the
            # shape/dtype template — legal because the run is uniform and
            # finalize's contract is template-only (comm/reducer.py)
            outb, st_f = self.inner.finalize([out_p], [b], st_p)
            xhat, st2 = self._stage(b, st)
            return (xhat, st2), (outb[0], st_f)

        xs_all = (xs, st_xs) if stateful else xs
        (xh_l, st_l), (outs_rest, st_rest) = jax.lax.scan(
            body, (xhat0, st0), xs_all)
        # epilogue: drain the pipeline — the final stage's collective and
        # finalize
        outb_l, st_fl = self.inner.finalize(
            [gavg(xh_l)], [buckets[idxs[-1]]], st_l)
        outs[idxs[-1]] = outb_l[0]
        fin_sts[idxs[-1]] = st_fl
        # ys entry j is stage idxs[j] (the stage carried INTO iteration
        # j), already finalized
        for j, i in enumerate(idxs[:-1]):
            outs[i] = jax.tree.map(lambda l, j=j: l[j], outs_rest)
            if stateful:
                fin_sts[i] = jax.tree.map(lambda l, j=j: l[j], st_rest)

    def _describe(self) -> str:
        # only an explicit ':pipelined' pin round-trips as one: auto
        # wrappers (engine chosen by the plan's overlap knob) describe as
        # ':bucketed', so re-parsing the spec under a different overlap
        # setting re-chooses the engine instead of silently pinning it
        suffix = ":pipelined" if self.pipeline_pin else ":bucketed"
        return f"{self.inner.describe()}{suffix}"
