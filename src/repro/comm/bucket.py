"""Bucketed flat-buffer reductions: pack the pytree once, compress and
all-reduce a few big contiguous buckets instead of one collective per leaf.

The per-leaf pipeline (comm/reducer.py) pays O(n_leaves) grouped
collectives and O(n_leaves) compression kernel launches per reduction, and
sparse reducers pick k *per leaf* — while the convergence analyses they
lean on (Stich et al., arXiv:1805.09767) assume top-k over the full
parameter vector.  Packing fixes all three at once (the PowerSGD /
Hivemind "flat grads" recipe):

  * :class:`BucketLayout` — computed once per (treedef, shapes, dtypes)
    from the param pytree: dtype-grouped, size-capped buckets of the
    per-learner trailing dims, preserving the stacked ``[pods, G, S]``
    learner axes.  ``pack`` is one reshape + one concat per bucket (no
    per-leaf dispatch on the hot path); ``unpack`` is static slices.
  * :class:`Bucketed` — wraps any comm/ Reducer so it sees whole buckets
    as its leaves: O(n_buckets) collectives, a *global* k-of-the-model
    selection for topk/randk (more accuracy per payload byte), and one
    tiled kernel pass over a flat buffer instead of many ragged launches.

Layout contract: buckets carry the same stacked learner axes as the leaves
they pack (``[pods, G, S, n]``; matrix-mode ``[pods, G, S, a, b]``), so the
grouped means of core/topology.py — and GSPMD's lowering of them to grouped
all-reduces — apply to buckets unchanged.  Packing permutes no values and
the learner-axis mean is elementwise, so bucketed mean/cast are
*bit-identical* to the per-leaf path (test-enforced); bucketed topk/randk
differ by design (global k vs per-leaf k).

Error-feedback state lives in bucket space: ``Bucketed.init_state`` packs
the params first, and every compress re-derives the layout and checks the
carried state against it, so a layout/state mismatch fails loudly instead
of silently misaligning residuals.

:class:`Pipelined` (the default engine when ``HierAvgParams.overlap`` is
on) runs the same bucket codec on a double-buffered schedule — a
``lax.scan`` over uniform buckets that issues stage *i*'s grouped
collective before stage *i+1*'s compress, so async-collective backends
overlap the two and the program stays O(1) in bucket count.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.comm.reducer import N_LEARNER_AXES, Reducer, serial_reduce

# Default per-bucket cap (bytes of one learner's slice).  4 MiB keeps a
# whole fp32 bucket row (~1M elements) inside a TPU core's VMEM budget for
# the Pallas topk_compress kernel, and is large enough that transformer
# blocks pack into a handful of buckets.  The single source of truth:
# HierAvgParams.bucket_bytes and --bucket-bytes default to this.
DEFAULT_BUCKET_BYTES = 4 << 20


@dataclass(frozen=True)
class BucketSlot:
    """Where one leaf lives inside its bucket."""

    leaf: int                  # index into the flattened tree
    offset: int                # element offset within the bucket
    size: int                  # per-learner element count
    shape: Tuple[int, ...]     # per-learner trailing shape


@dataclass(frozen=True)
class BucketSpec:
    """One contiguous, single-dtype bucket."""

    dtype: str                 # canonical dtype name (hashable)
    size: int                  # unpadded per-learner element count
    shape: Tuple[int, ...]     # per-learner bucket shape: (size,) flat, or
                               # (a, b) zero-padded in matrix mode
    slots: Tuple[BucketSlot, ...]

    @property
    def padded_size(self) -> int:
        return math.prod(self.shape)


def _matrix_shape(size: int) -> Tuple[int, int]:
    """Near-square (a, b) with a*b >= size — matrix view for low-rank
    reducers (pad is zero-filled and stripped on unpack)."""
    a = max(1, int(math.isqrt(size)))
    b = -(-size // a)
    return a, b


@dataclass(frozen=True)
class BucketLayout:
    """Static packing plan for one pytree (shape/dtype) signature.

    ``lead_axes`` is the number of leading stacked-learner axes every leaf
    carries (3 for train-state trees, 0 for the single-learner templates
    ``payload_bytes`` sizes).
    """

    treedef: Any
    lead_axes: int
    buckets: Tuple[BucketSpec, ...]

    # ------------------------------------------------------------------ #
    # construction
    # ------------------------------------------------------------------ #

    @classmethod
    def build(cls, tree, *, bucket_bytes: int = DEFAULT_BUCKET_BYTES,
              lead_axes: int = N_LEARNER_AXES,
              matrix: bool = False, uniform: bool = False,
              shard_axes: Optional[Tuple[str, ...]] = None
              ) -> "BucketLayout":
        """Dtype-grouped, size-capped buckets in leaf order.

        A leaf larger than ``bucket_bytes`` gets a bucket of its own
        (leaves are never split across buckets); ``bucket_bytes <= 0``
        means one bucket per dtype.

        ``uniform=True`` zero-pads every bucket of a dtype group to the
        group's largest bucket, so the buckets form a rectangular
        schedule a ``lax.scan`` can iterate (the pipelined engine's
        requirement); single-bucket groups keep their exact size, so
        uniform and ragged layouts agree whenever there is nothing to
        scan over.

        ``shard_axes`` names mesh axes that shard the leaves' *trailing*
        (per-learner) dims — e.g. ``("fsdp",)`` under a
        ``ParallelLayout(fsdp>1)``.  Packing such leaves into one flat
        bucket would concatenate coordinates owned by different shards
        and turn the per-bucket grouped collective into a cross-shard
        gather; shard-aware bucketing (one bucket run per shard) is not
        implemented yet, so this refuses loudly instead of silently
        building a layout whose collectives re-materialize every shard.
        """
        if shard_axes:
            raise NotImplementedError(
                f"shard-aware bucketing is not implemented: leaves are "
                f"sharded over mesh axes {tuple(shard_axes)} (an fsdp>1 "
                f"ParallelLayout), and packing cross-shard leaves into "
                f"one flat bucket would make each bucket collective "
                f"re-materialize all shards; run with fsdp=1 or "
                f"bucket_bytes=0 (per-leaf reductions) until per-shard "
                f"bucket runs land")
        if matrix and uniform:
            raise ValueError(
                "uniform (pipelined) layouts are flat-only; matrix-mode "
                "reducers (PowerSGD) run the serial bucket schedule")
        leaves, treedef = jax.tree.flatten(tree)
        per_dtype: Dict[str, List[Tuple[int, Tuple[int, ...], int]]] = {}
        for i, leaf in enumerate(leaves):
            if len(leaf.shape) < lead_axes:
                raise ValueError(
                    f"leaf {i} has shape {tuple(leaf.shape)} but the layout "
                    f"expects {lead_axes} leading learner axes")
            shape = tuple(leaf.shape[lead_axes:])
            size = math.prod(shape) if shape else 1
            name = jnp.dtype(leaf.dtype).name
            per_dtype.setdefault(name, []).append((i, shape, size))

        buckets: List[BucketSpec] = []
        for name, entries in per_dtype.items():   # insertion order (3.7+)
            itemsize = jnp.dtype(name).itemsize
            cap = (bucket_bytes // itemsize) if bucket_bytes > 0 else 0
            slots: List[BucketSlot] = []
            filled = 0

            def flush():
                nonlocal slots, filled
                if not slots:
                    return
                shape = (_matrix_shape(filled) if matrix else (filled,))
                buckets.append(BucketSpec(name, filled, shape,
                                          tuple(slots)))
                slots, filled = [], 0

            group_start = len(buckets)
            for i, shape, size in entries:
                if cap and slots and filled + size > cap:
                    flush()
                slots.append(BucketSlot(i, filled, size, shape))
                filled += size
            flush()
            if uniform and len(buckets) - group_start > 1:
                group = buckets[group_start:]
                pad_n = max(b.size for b in group)
                buckets[group_start:] = [
                    BucketSpec(b.dtype, b.size, (pad_n,), b.slots)
                    for b in group]
        return cls(treedef, lead_axes, tuple(buckets))

    # ------------------------------------------------------------------ #
    # derived facts
    # ------------------------------------------------------------------ #

    @property
    def n_buckets(self) -> int:
        return len(self.buckets)

    @property
    def n_leaves(self) -> int:
        return sum(len(b.slots) for b in self.buckets)

    def bucket_structs(self, lead: Tuple[int, ...] = ()
                       ) -> List[jax.ShapeDtypeStruct]:
        """Shape/dtype templates of the packed buckets (for analytic
        accounting — no arrays allocated)."""
        return [jax.ShapeDtypeStruct(lead + b.shape, jnp.dtype(b.dtype))
                for b in self.buckets]

    def describe(self) -> str:
        return (f"{self.n_leaves} leaves -> {self.n_buckets} bucket(s): "
                + ", ".join(f"{b.dtype}[{b.size}]" for b in self.buckets))

    # ------------------------------------------------------------------ #
    # pack / unpack
    # ------------------------------------------------------------------ #

    def pack(self, tree) -> List[jax.Array]:
        """Pytree -> list of bucket arrays ``[*lead, *bucket.shape]``.

        One reshape per leaf (free — layout metadata only) and one concat
        per bucket; values are never permuted, so elementwise reductions
        over the lead axes commute with packing bit-for-bit.
        """
        leaves = self.treedef.flatten_up_to(tree)
        out: List[jax.Array] = []
        for b in self.buckets:
            lead = tuple(leaves[b.slots[0].leaf].shape[:self.lead_axes])
            parts = [leaves[s.leaf].reshape(lead + (s.size,))
                     for s in b.slots]
            flat = parts[0] if len(parts) == 1 \
                else jnp.concatenate(parts, axis=-1)
            if b.shape != (b.size,):
                pad = b.padded_size - b.size
                if pad:
                    flat = jnp.pad(flat, [(0, 0)] * len(lead) + [(0, pad)])
                flat = flat.reshape(lead + b.shape)
            out.append(flat)
        return out

    def unpack(self, buckets) -> Any:
        """Inverse of :meth:`pack` (padding stripped)."""
        leaves: List[Any] = [None] * self.n_leaves
        for b, arr in zip(self.buckets, buckets):
            lead = tuple(arr.shape[:arr.ndim - len(b.shape)])
            flat = arr.reshape(lead + (b.padded_size,))
            for s in b.slots:
                piece = jax.lax.slice_in_dim(flat, s.offset,
                                             s.offset + s.size, axis=-1)
                leaves[s.leaf] = piece.reshape(lead + s.shape)
        return self.treedef.unflatten(leaves)


# --------------------------------------------------------------------- #
# the Bucketed reducer wrapper
# --------------------------------------------------------------------- #

def _signature(tree, lead_axes: int):
    leaves, treedef = jax.tree.flatten(tree)
    return (treedef, lead_axes,
            tuple((tuple(l.shape), jnp.dtype(l.dtype).name)
                  for l in leaves))


class Bucketed(Reducer):
    """Run any comm/ Reducer on packed buckets instead of raw leaves.

    The wrapped reducer's codec is unchanged — it simply sees n_buckets
    flat (or, for ``wants_matrix`` reducers like PowerSGD, near-square)
    leaves instead of n_leaves ragged ones.  Stateful reducers carry their
    EF/warm-start state in bucket space; ``init_state`` must therefore be
    built from the same layout the round uses (``compress`` checks).
    """

    name = "bucketed"
    # Pipelined overrides: uniform (scan-able) bucket shapes + the
    # interleaved schedule
    uniform_layout = False
    # set by the explicit ":pipelined" spec modifier (comm/__init__.py):
    # plan resolution must NOT demote this wrapper to the serial engine
    # when the plan's overlap knob is off.  Auto-pipelined wrappers
    # (created by apply_bucketing from overlap=True) leave it False so a
    # later resolution with overlap=False can rebuild them serial.
    pipeline_pin = False

    def __init__(self, inner: Reducer, bucket_bytes: Optional[int] = None):
        """``bucket_bytes=None`` means "inherit": the layout uses
        DEFAULT_BUCKET_BYTES until plan resolution (core/plan.py
        apply_bucketing) re-caps the wrapper with the plan's
        ``HierAvgParams.bucket_bytes`` — so an explicit ``:bucketed``
        spec modifier still honors the config knob."""
        if isinstance(inner, Bucketed):
            inner = inner.inner
        if bucket_bytes is not None and bucket_bytes < 0:
            raise ValueError(
                f"bucket_bytes must be >= 0, got {bucket_bytes}")
        self.inner = inner
        self.bucket_bytes = None if bucket_bytes is None \
            else int(bucket_bytes)
        self.stateful = inner.stateful
        self._layouts: Dict[Any, BucketLayout] = {}

    @property
    def effective_bucket_bytes(self) -> int:
        return DEFAULT_BUCKET_BYTES if self.bucket_bytes is None \
            else self.bucket_bytes

    @property
    def has_codec(self) -> bool:
        return self.inner.has_codec

    # -- layout ---------------------------------------------------------- #

    def layout_for(self, tree, lead_axes: int = N_LEARNER_AXES
                   ) -> BucketLayout:
        """The (cached) layout for this tree signature — shapes and dtypes
        are static under jit, so this is trace-time work only."""
        key = _signature(tree, lead_axes)
        lay = self._layouts.get(key)
        if lay is None:
            lay = BucketLayout.build(
                tree, bucket_bytes=self.effective_bucket_bytes,
                lead_axes=lead_axes,
                matrix=getattr(self.inner, "wants_matrix", False),
                uniform=self.uniform_layout)
            self._layouts[key] = lay
        return lay

    def _check_state(self, lay: BucketLayout, state, lead: Tuple[int, ...]):
        refs = getattr(state, "ref", None)
        if refs is None:
            return
        got = [tuple(r.shape) for r in jax.tree.leaves(refs)]
        want = [lead + b.shape for b in lay.buckets]
        if got != want:
            raise ValueError(
                "bucketed reducer state does not match the bucket layout "
                f"(state buckets {got}, layout wants {want}); build the "
                "initial state with init_state(..., plan=...) using the "
                "same plan/bucket_bytes the round was built with")

    # -- carried state --------------------------------------------------- #

    def init_state(self, params):
        lay = self.layout_for(params)
        return self.inner.init_state(lay.pack(params))

    # -- codec ----------------------------------------------------------- #

    def compress(self, tree, state):
        lay = self.layout_for(tree)
        buckets = lay.pack(tree)
        if self.stateful:
            lead = tuple(jax.tree.leaves(tree)[0].shape[:lay.lead_axes])
            self._check_state(lay, state, lead)
        return self.inner.compress(buckets, state)

    def decompress(self, payload, like, state):
        lay = self.layout_for(like)
        # the reconstruction stays in bucket space: the grouped mean that
        # follows (core/topology.py) is elementwise over the lead axes, so
        # it averages buckets exactly as it would leaves
        return self.inner.decompress(payload, lay.pack(like), state)

    def finalize(self, avg_tree, orig_tree, state):
        lay = self.layout_for(orig_tree)
        out, state = self.inner.finalize(avg_tree, lay.pack(orig_tree),
                                         state)
        return lay.unpack(out), state

    # -- accounting ------------------------------------------------------ #

    def payload_bytes(self, tree) -> int:
        lay = self.layout_for(tree, lead_axes=0)
        return self.inner.payload_bytes(lay.bucket_structs())

    def n_messages(self, tree) -> int:
        """Grouped collectives per reduction: one per bucket, not per
        leaf."""
        return self.layout_for(tree, lead_axes=0).n_buckets

    def _describe(self) -> str:
        return f"{self.inner.describe()}:bucketed"


# --------------------------------------------------------------------- #
# the pipelined (overlapped) bucket schedule
# --------------------------------------------------------------------- #

class Pipelined(Bucketed):
    """Bucketed reductions on a software-pipelined, double-buffered
    schedule: while bucket *i*'s reconstruction is in its grouped
    collective, bucket *i+1* is already compressing.

    The per-bucket stages are expressed as one ``lax.scan`` over the
    bucket schedule (uniform, zero-padded buckets — see
    ``BucketLayout.build(uniform=True)``), with the collective for stage
    *i* issued at the top of iteration *i+1*, before that iteration's
    compress.  The two are data-independent — the collective consumes
    only the loop carry — so a backend with async collectives
    (``all-reduce-start``/``-done``) can run stage *i+1*'s compress
    inside stage *i*'s collective window; tests/test_pipeline.py asserts
    this structure on the compiled HLO.  The scan also keeps the program
    size O(1) in the bucket count (the serial path unrolls one
    compress/collective/decompress chain per bucket), which is what
    keeps compile time flat when a multi-GB model packs into hundreds of
    buckets.

    Semantics: pipelining is a schedule change only.  ``mean``/``cast``
    are bit-identical to the serial Bucketed path (test-enforced);
    ``topk`` selects k over the zero-padded uniform bucket (padding is
    never selected, but k = ratio * padded size, so k can differ by a
    few coordinates from the ragged serial layout); ``randk`` draws its
    per-bucket support from a per-stage folded key (a different — equally
    fresh — stream than the serial path).  Reducers whose carried state
    cannot be split per bucket (``split_bucket_states`` -> None, e.g.
    PowerSGD's warm-started Q) and single-bucket layouts fall back to the
    serial schedule inside ``reduce`` — same math, nothing to overlap.
    """

    name = "pipelined"
    overlaps = True            # theory.plan_comm_per_round costing hint

    @property
    def uniform_layout(self) -> bool:
        # matrix-mode (PowerSGD) buckets stay ragged: they cannot scan
        # (and fall back to the serial schedule below anyway)
        return not getattr(self.inner, "wants_matrix", False)

    # -- per-bucket stage ------------------------------------------------ #

    def _stage(self, bucket, st):
        """compress+reconstruct one bucket: the compute half of a
        pipeline stage (the collective half is the avg_fn call)."""
        payload, st2 = self.inner.compress([bucket], st)
        xhat = self.inner.decompress(payload, [bucket], st2)
        return xhat[0], st2

    # -- the schedule ---------------------------------------------------- #

    def reduce(self, avg_fn, tree, state, constraint_fn=None):
        """The whole reduction, pipelined per bucket (called by
        ``reduce_with`` instead of the serial composition)."""
        lay = self.layout_for(tree)
        n = lay.n_buckets
        sts = (self.inner.split_bucket_states(state, n) if self.stateful
               else [() for _ in range(n)])
        if n < 2 or sts is None:
            # nothing to overlap / unsplittable state: serial schedule
            return serial_reduce(self, avg_fn, tree, state, constraint_fn)
        if self.stateful:
            lead = tuple(jax.tree.leaves(tree)[0].shape[:lay.lead_axes])
            self._check_state(lay, state, lead)
        buckets = lay.pack(tree)

        outs: List[Any] = [None] * n
        new_sts: List[Any] = list(sts)
        # scan needs rectangular xs: pipeline each (dtype, shape) run of
        # the uniform layout; a run of one has no neighbor to overlap
        groups: Dict[Tuple[str, Tuple[int, ...]], List[int]] = {}
        for i, b in enumerate(lay.buckets):
            groups.setdefault((b.dtype, b.shape), []).append(i)
        for idxs in groups.values():
            if len(idxs) == 1:
                i = idxs[0]
                xhat, st2 = self._stage(buckets[i], sts[i])
                outs[i] = avg_fn(xhat, constraint_fn)
                new_sts[i] = st2
            else:
                self._pipeline(idxs, buckets, sts, outs, new_sts,
                               avg_fn, constraint_fn)

        new_state = (self.inner.join_bucket_states(state, new_sts)
                     if self.stateful else state)
        out_buckets, new_state = self.inner.finalize(outs, buckets,
                                                     new_state)
        return lay.unpack(out_buckets), new_state

    def _pipeline(self, idxs, buckets, sts, outs, new_sts, avg_fn,
                  constraint_fn):
        """Double-buffered scan over one uniform bucket run: iteration
        *j* issues the collective for stage *j-1*'s reconstruction (the
        carry) and then compresses bucket *j* — so the collective never
        waits on this iteration's compute, and vice versa."""
        stateful = self.stateful
        # prologue: fill the pipeline with stage 0's compress
        xhat0, st0 = self._stage(buckets[idxs[0]], sts[idxs[0]])
        new_sts[idxs[0]] = st0
        xs = jnp.stack([buckets[i] for i in idxs[1:]])
        if stateful:
            st_xs = jax.tree.map(lambda *ls: jnp.stack(ls),
                                 *[sts[i] for i in idxs[1:]])

        def body(carry, x):
            # collective for the carried stage FIRST — it depends only on
            # the carry, so stage j's compress below is free to overlap it
            out_prev = avg_fn(carry, constraint_fn)
            b, st = x if stateful else (x, ())
            xhat, st2 = self._stage(b, st)
            return xhat, (out_prev, st2)

        xs_all = (xs, st_xs) if stateful else xs
        last, (outs_rest, st_rest) = jax.lax.scan(body, xhat0, xs_all)
        # epilogue: drain the pipeline — the final stage's collective
        outs[idxs[-1]] = avg_fn(last, constraint_fn)
        for j, i in enumerate(idxs[:-1]):
            outs[i] = jax.tree.map(lambda l, j=j: l[j], outs_rest)
        if stateful:
            for j, i in enumerate(idxs[1:]):
                new_sts[i] = jax.tree.map(lambda l, j=j: l[j], st_rest)

    def _describe(self) -> str:
        # only an explicit ':pipelined' pin round-trips as one: auto
        # wrappers (engine chosen by the plan's overlap knob) describe as
        # ':bucketed', so re-parsing the spec under a different overlap
        # setting re-chooses the engine instead of silently pinning it
        suffix = ":pipelined" if self.pipeline_pin else ":bucketed"
        return f"{self.inner.describe()}{suffix}"
