"""Bucketed flat-buffer reductions: pack the pytree once, compress and
all-reduce a few big contiguous buckets instead of one collective per leaf.

The per-leaf pipeline (comm/reducer.py) pays O(n_leaves) grouped
collectives and O(n_leaves) compression kernel launches per reduction, and
sparse reducers pick k *per leaf* — while the convergence analyses they
lean on (Stich et al., arXiv:1805.09767) assume top-k over the full
parameter vector.  Packing fixes all three at once (the PowerSGD /
Hivemind "flat grads" recipe):

  * :class:`BucketLayout` — computed once per (treedef, shapes, dtypes)
    from the param pytree: dtype-grouped, size-capped buckets of the
    per-learner trailing dims, preserving the stacked ``[pods, G, S]``
    learner axes.  ``pack`` is one reshape + one concat per bucket (no
    per-leaf dispatch on the hot path); ``unpack`` is static slices.
  * :class:`Bucketed` — wraps any comm/ Reducer so it sees whole buckets
    as its leaves: O(n_buckets) collectives, a *global* k-of-the-model
    selection for topk/randk (more accuracy per payload byte), and one
    tiled kernel pass over a flat buffer instead of many ragged launches.

Layout contract: buckets carry the same stacked learner axes as the leaves
they pack (``[pods, G, S, n]``; matrix-mode ``[pods, G, S, a, b]``), so the
grouped means of core/topology.py — and GSPMD's lowering of them to grouped
all-reduces — apply to buckets unchanged.  Packing permutes no values and
the learner-axis mean is elementwise, so bucketed mean/cast are
*bit-identical* to the per-leaf path (test-enforced); bucketed topk/randk
differ by design (global k vs per-leaf k).

Error-feedback state lives in bucket space: ``Bucketed.init_state`` packs
the params first, and every compress re-derives the layout and checks the
carried state against it, so a layout/state mismatch fails loudly instead
of silently misaligning residuals.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.comm.reducer import N_LEARNER_AXES, Reducer

# Default per-bucket cap (bytes of one learner's slice).  4 MiB keeps a
# whole fp32 bucket row (~1M elements) inside a TPU core's VMEM budget for
# the Pallas topk_compress kernel, and is large enough that transformer
# blocks pack into a handful of buckets.  The single source of truth:
# HierAvgParams.bucket_bytes and --bucket-bytes default to this.
DEFAULT_BUCKET_BYTES = 4 << 20


@dataclass(frozen=True)
class BucketSlot:
    """Where one leaf lives inside its bucket."""

    leaf: int                  # index into the flattened tree
    offset: int                # element offset within the bucket
    size: int                  # per-learner element count
    shape: Tuple[int, ...]     # per-learner trailing shape


@dataclass(frozen=True)
class BucketSpec:
    """One contiguous, single-dtype bucket."""

    dtype: str                 # canonical dtype name (hashable)
    size: int                  # unpadded per-learner element count
    shape: Tuple[int, ...]     # per-learner bucket shape: (size,) flat, or
                               # (a, b) zero-padded in matrix mode
    slots: Tuple[BucketSlot, ...]

    @property
    def padded_size(self) -> int:
        return math.prod(self.shape)


def _matrix_shape(size: int) -> Tuple[int, int]:
    """Near-square (a, b) with a*b >= size — matrix view for low-rank
    reducers (pad is zero-filled and stripped on unpack)."""
    a = max(1, int(math.isqrt(size)))
    b = -(-size // a)
    return a, b


@dataclass(frozen=True)
class BucketLayout:
    """Static packing plan for one pytree (shape/dtype) signature.

    ``lead_axes`` is the number of leading stacked-learner axes every leaf
    carries (3 for train-state trees, 0 for the single-learner templates
    ``payload_bytes`` sizes).
    """

    treedef: Any
    lead_axes: int
    buckets: Tuple[BucketSpec, ...]

    # ------------------------------------------------------------------ #
    # construction
    # ------------------------------------------------------------------ #

    @classmethod
    def build(cls, tree, *, bucket_bytes: int = DEFAULT_BUCKET_BYTES,
              lead_axes: int = N_LEARNER_AXES,
              matrix: bool = False) -> "BucketLayout":
        """Dtype-grouped, size-capped buckets in leaf order.

        A leaf larger than ``bucket_bytes`` gets a bucket of its own
        (leaves are never split across buckets); ``bucket_bytes <= 0``
        means one bucket per dtype.
        """
        leaves, treedef = jax.tree.flatten(tree)
        per_dtype: Dict[str, List[Tuple[int, Tuple[int, ...], int]]] = {}
        for i, leaf in enumerate(leaves):
            if len(leaf.shape) < lead_axes:
                raise ValueError(
                    f"leaf {i} has shape {tuple(leaf.shape)} but the layout "
                    f"expects {lead_axes} leading learner axes")
            shape = tuple(leaf.shape[lead_axes:])
            size = math.prod(shape) if shape else 1
            name = jnp.dtype(leaf.dtype).name
            per_dtype.setdefault(name, []).append((i, shape, size))

        buckets: List[BucketSpec] = []
        for name, entries in per_dtype.items():   # insertion order (3.7+)
            itemsize = jnp.dtype(name).itemsize
            cap = (bucket_bytes // itemsize) if bucket_bytes > 0 else 0
            slots: List[BucketSlot] = []
            filled = 0

            def flush():
                nonlocal slots, filled
                if not slots:
                    return
                shape = (_matrix_shape(filled) if matrix else (filled,))
                buckets.append(BucketSpec(name, filled, shape,
                                          tuple(slots)))
                slots, filled = [], 0

            for i, shape, size in entries:
                if cap and slots and filled + size > cap:
                    flush()
                slots.append(BucketSlot(i, filled, size, shape))
                filled += size
            flush()
        return cls(treedef, lead_axes, tuple(buckets))

    # ------------------------------------------------------------------ #
    # derived facts
    # ------------------------------------------------------------------ #

    @property
    def n_buckets(self) -> int:
        return len(self.buckets)

    @property
    def n_leaves(self) -> int:
        return sum(len(b.slots) for b in self.buckets)

    def bucket_structs(self, lead: Tuple[int, ...] = ()
                       ) -> List[jax.ShapeDtypeStruct]:
        """Shape/dtype templates of the packed buckets (for analytic
        accounting — no arrays allocated)."""
        return [jax.ShapeDtypeStruct(lead + b.shape, jnp.dtype(b.dtype))
                for b in self.buckets]

    def describe(self) -> str:
        return (f"{self.n_leaves} leaves -> {self.n_buckets} bucket(s): "
                + ", ".join(f"{b.dtype}[{b.size}]" for b in self.buckets))

    # ------------------------------------------------------------------ #
    # pack / unpack
    # ------------------------------------------------------------------ #

    def pack(self, tree) -> List[jax.Array]:
        """Pytree -> list of bucket arrays ``[*lead, *bucket.shape]``.

        One reshape per leaf (free — layout metadata only) and one concat
        per bucket; values are never permuted, so elementwise reductions
        over the lead axes commute with packing bit-for-bit.
        """
        leaves = self.treedef.flatten_up_to(tree)
        out: List[jax.Array] = []
        for b in self.buckets:
            lead = tuple(leaves[b.slots[0].leaf].shape[:self.lead_axes])
            parts = [leaves[s.leaf].reshape(lead + (s.size,))
                     for s in b.slots]
            flat = parts[0] if len(parts) == 1 \
                else jnp.concatenate(parts, axis=-1)
            if b.shape != (b.size,):
                pad = b.padded_size - b.size
                if pad:
                    flat = jnp.pad(flat, [(0, 0)] * len(lead) + [(0, pad)])
                flat = flat.reshape(lead + b.shape)
            out.append(flat)
        return out

    def unpack(self, buckets) -> Any:
        """Inverse of :meth:`pack` (padding stripped)."""
        leaves: List[Any] = [None] * self.n_leaves
        for b, arr in zip(self.buckets, buckets):
            lead = tuple(arr.shape[:arr.ndim - len(b.shape)])
            flat = arr.reshape(lead + (b.padded_size,))
            for s in b.slots:
                piece = jax.lax.slice_in_dim(flat, s.offset,
                                             s.offset + s.size, axis=-1)
                leaves[s.leaf] = piece.reshape(lead + s.shape)
        return self.treedef.unflatten(leaves)


# --------------------------------------------------------------------- #
# the Bucketed reducer wrapper
# --------------------------------------------------------------------- #

def _signature(tree, lead_axes: int):
    leaves, treedef = jax.tree.flatten(tree)
    return (treedef, lead_axes,
            tuple((tuple(l.shape), jnp.dtype(l.dtype).name)
                  for l in leaves))


class Bucketed(Reducer):
    """Run any comm/ Reducer on packed buckets instead of raw leaves.

    The wrapped reducer's codec is unchanged — it simply sees n_buckets
    flat (or, for ``wants_matrix`` reducers like PowerSGD, near-square)
    leaves instead of n_leaves ragged ones.  Stateful reducers carry their
    EF/warm-start state in bucket space; ``init_state`` must therefore be
    built from the same layout the round uses (``compress`` checks).
    """

    name = "bucketed"

    def __init__(self, inner: Reducer, bucket_bytes: Optional[int] = None):
        """``bucket_bytes=None`` means "inherit": the layout uses
        DEFAULT_BUCKET_BYTES until plan resolution (core/plan.py
        apply_bucketing) re-caps the wrapper with the plan's
        ``HierAvgParams.bucket_bytes`` — so an explicit ``:bucketed``
        spec modifier still honors the config knob."""
        if isinstance(inner, Bucketed):
            inner = inner.inner
        if bucket_bytes is not None and bucket_bytes < 0:
            raise ValueError(
                f"bucket_bytes must be >= 0, got {bucket_bytes}")
        self.inner = inner
        self.bucket_bytes = None if bucket_bytes is None \
            else int(bucket_bytes)
        self.stateful = inner.stateful
        self._layouts: Dict[Any, BucketLayout] = {}

    @property
    def effective_bucket_bytes(self) -> int:
        return DEFAULT_BUCKET_BYTES if self.bucket_bytes is None \
            else self.bucket_bytes

    # -- layout ---------------------------------------------------------- #

    def layout_for(self, tree, lead_axes: int = N_LEARNER_AXES
                   ) -> BucketLayout:
        """The (cached) layout for this tree signature — shapes and dtypes
        are static under jit, so this is trace-time work only."""
        key = _signature(tree, lead_axes)
        lay = self._layouts.get(key)
        if lay is None:
            lay = BucketLayout.build(
                tree, bucket_bytes=self.effective_bucket_bytes,
                lead_axes=lead_axes,
                matrix=getattr(self.inner, "wants_matrix", False))
            self._layouts[key] = lay
        return lay

    def _check_state(self, lay: BucketLayout, state, lead: Tuple[int, ...]):
        refs = getattr(state, "ref", None)
        if refs is None:
            return
        got = [tuple(r.shape) for r in jax.tree.leaves(refs)]
        want = [lead + b.shape for b in lay.buckets]
        if got != want:
            raise ValueError(
                "bucketed reducer state does not match the bucket layout "
                f"(state buckets {got}, layout wants {want}); build the "
                "initial state with init_state(..., plan=...) using the "
                "same plan/bucket_bytes the round was built with")

    # -- carried state --------------------------------------------------- #

    def init_state(self, params):
        lay = self.layout_for(params)
        return self.inner.init_state(lay.pack(params))

    # -- codec ----------------------------------------------------------- #

    def compress(self, tree, state):
        lay = self.layout_for(tree)
        buckets = lay.pack(tree)
        if self.stateful:
            lead = tuple(jax.tree.leaves(tree)[0].shape[:lay.lead_axes])
            self._check_state(lay, state, lead)
        return self.inner.compress(buckets, state)

    def decompress(self, payload, like, state):
        lay = self.layout_for(like)
        # the reconstruction stays in bucket space: the grouped mean that
        # follows (core/topology.py) is elementwise over the lead axes, so
        # it averages buckets exactly as it would leaves
        return self.inner.decompress(payload, lay.pack(like), state)

    def finalize(self, avg_tree, orig_tree, state):
        lay = self.layout_for(orig_tree)
        out, state = self.inner.finalize(avg_tree, lay.pack(orig_tree),
                                         state)
        return lay.unpack(out), state

    # -- accounting ------------------------------------------------------ #

    def payload_bytes(self, tree) -> int:
        lay = self.layout_for(tree, lead_axes=0)
        return self.inner.payload_bytes(lay.bucket_structs())

    def n_messages(self, tree) -> int:
        """Grouped collectives per reduction: one per bucket, not per
        leaf."""
        return self.layout_for(tree, lead_axes=0).n_buckets

    def _describe(self) -> str:
        return f"{self.inner.describe()}:bucketed"
