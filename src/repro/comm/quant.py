"""Per-block int8 scale quantization reducer.

Each learner quantizes its parameters blockwise (absmax scale per block of
``block`` consecutive elements, int8 mantissa) — 1 byte/element + 4
bytes/block on the wire vs 4 bytes/element dense.  Stateless: the
round-trip error is bounded by ``absmax(block) / 254`` per element, which
test_comm.py asserts, so no error feedback is carried.

Two wire layouts:

  * **fused** (default): one pass through ``kernels/ops.py::qint8_pack``
    emits a single contiguous int8 buffer per leaf/bucket — payload and
    bitcast fp32 scales interleaved per block — so each reduction ships
    ONE message instead of two (``n_messages``).  The final partial
    block is zero-padded on the wire, which ``payload_bytes`` bills
    honestly (``nb * (block + 4)`` bytes).
  * **twopass** (``qint8:<block>:twopass``): the legacy
    :func:`quantize_block`/:func:`dequantize_block` pair — int8 payload
    and fp32 scale arrays ride the collective as SEPARATE messages
    (2 per leaf/bucket), the baseline the fused-pack A/B measures
    against.

Both quantize with identical math (the fused scale bytes are a bitcast,
not a cast), so the dequantized values are bit-identical under jit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.comm.reducer import N_LEARNER_AXES, Reducer, per_learner_size
from repro.kernels import ops


def _blocked(x2d, block: int):
    """[rows, n] -> ([rows, nb, block], n) zero-padded to a block multiple."""
    rows, n = x2d.shape
    nb = -(-n // block)
    pad = nb * block - n
    if pad:
        x2d = jnp.pad(x2d, ((0, 0), (0, pad)))
    return x2d.reshape(rows, nb, block)


def quantize_block(x2d, block: int):
    """[rows, n] fp -> (q int8 [rows, nb, block], scale fp32 [rows, nb, 1])."""
    xb = _blocked(x2d.astype(jnp.float32), block)
    scale = jnp.max(jnp.abs(xb), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_block(q, scale, n: int):
    """Inverse of quantize_block: -> [rows, n] fp32 (padding stripped)."""
    rows = q.shape[0]
    x = q.astype(jnp.float32) * scale
    return x.reshape(rows, -1)[:, :n]


class QInt8Reducer(Reducer):
    """int8 payload with per-block fp32 scales; averaging in fp32."""

    name = "qint8"
    bucket_by_default = True
    has_codec = True

    def __init__(self, block: int = 256, fused: bool = True,
                 impl: str = "auto"):
        if block < 1:
            raise ValueError(f"qint8 block must be >= 1, got {block}")
        self.block = int(block)
        self.fused = bool(fused)
        # pack/unpack kernel dispatch (kernels/ops.py): "auto" | "xla"
        # | "pallas" | "pallas_interpret"
        self.impl = impl

    def _flat(self, leaf):
        rows = 1
        for d in leaf.shape[:N_LEARNER_AXES]:
            rows *= d
        return leaf.reshape(rows, per_learner_size(leaf))

    def compress(self, tree, state):
        if self.fused:
            payload = [ops.qint8_pack(self._flat(leaf), self.block,
                                      impl=self.impl)
                       for leaf in jax.tree.leaves(tree)]
        else:
            payload = [quantize_block(self._flat(leaf), self.block)
                       for leaf in jax.tree.leaves(tree)]
        return payload, state

    def decompress(self, payload, like, state):
        leaves, treedef = jax.tree.flatten(like)
        if self.fused:
            out = [ops.qint8_unpack(w, per_learner_size(leaf),
                                    impl=self.impl).reshape(leaf.shape)
                   for w, leaf in zip(payload, leaves)]
        else:
            out = [dequantize_block(q, s, per_learner_size(leaf)
                                    ).reshape(leaf.shape)
                   for (q, s), leaf in zip(payload, leaves)]
        return treedef.unflatten(out)

    def finalize(self, avg_tree, orig_tree, state):
        out = jax.tree.map(lambda a, o: a.astype(o.dtype),
                           avg_tree, orig_tree)
        return out, state

    def n_messages(self, tree) -> int:
        """Fused: one packed buffer per leaf/bucket.  Two-pass: the int8
        payload AND the fp32 scale array each ride as their own
        collective — the honest baseline bill the fused A/B beats."""
        per = 1 if self.fused else 2
        return per * len(jax.tree.leaves(tree))

    def payload_bytes(self, tree) -> int:
        total = 0
        for leaf in jax.tree.leaves(tree):
            n = leaf.size
            nb = -(-n // self.block)
            if self.fused:
                # the packed wire buffer ships whole blocks: the final
                # partial block's zero tail is transmitted
                total += nb * (self.block + 4)
            else:
                total += n + nb * 4
        return int(total)

    def _describe(self) -> str:
        return f"qint8:{self.block}" + ("" if self.fused else ":twopass")
