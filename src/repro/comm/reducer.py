"""Pluggable compressed reducers for Hier-AVG's local/global reductions.

The paper makes the global reduction sparse *in time* (K2 >> K1).  Reducers
make every reduction sparse *in payload* as well: a :class:`Reducer` defines
what each learner puts on the wire.

    payload, state = reducer.compress(tree, state)     # per-learner payload
    xhat = reducer.decompress(payload, tree, state)    # learner approximation
    out = avg_fn(xhat, constraint_fn)                  # grouped all-reduce
    out, state = reducer.finalize(out, tree, state)    # dtype/EF bookkeeping

so the reduction becomes ``mean_j xhat_j`` over each learner's
reconstruction.  Wire-cost caveat: in this stacked-learner formulation the
grouped all-reduce itself moves the *reconstructed* leaves — the ``cast``
reducer genuinely narrows the reduce words (the mean runs in the payload
dtype), but for topk/randk/qint8 the payload savings reported by
``payload_bytes`` model what a payload-aware collective (sparse/quantized
all-gather) would transmit, not what this lowering puts on the wire.  What
is exact everywhere is the *numerics*: training sees precisely the
information a compressed link would deliver, which is what the convergence
benchmarks measure.  Error-feedback reducers (comm/sparse.py) carry
residual state threaded through ``TrainState.comm_state``.

Layout contract: every leaf carries the stacked-learner axes
[pods, G, S, *shape] (see core/topology.py); reducers compress each
learner's trailing ``*shape`` dims independently.  ``payload_bytes`` is the
analytic per-learner wire size and expects a *single-learner* tree (no
learner axes).
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

N_LEARNER_AXES = 3   # [pods, G, S] — the stacked-learner leading axes


def learner_shape(leaf) -> Tuple[int, ...]:
    """Per-learner trailing shape of a stacked leaf."""
    return tuple(leaf.shape[N_LEARNER_AXES:])


def per_learner_size(leaf) -> int:
    n = 1
    for d in learner_shape(leaf):
        n *= d
    return n


class Reducer:
    """Base reducer == today's dense full-precision mean (identity codec).

    Subclasses override ``compress``/``decompress`` (and ``finalize`` for
    dtype restoration or error-feedback reference updates).  Stateless
    reducers keep ``init_state`` returning ``()`` so ``TrainState`` is
    unchanged for the default path.
    """

    name = "mean"
    stateful = False
    # -- bucketing hints (comm/bucket.py) -------------------------------- #
    # wrap this reducer in Bucketed automatically when the plan's
    # bucket_bytes knob is on?  True for coordinate-wise codecs (cast /
    # topk / randk / qint8) where packing only helps; False for the dense
    # mean (already one fused collective's worth of work per leaf, and
    # per-leaf is the bit-exactness reference) and for reducers whose
    # codec exploits per-leaf structure (PowerSGD) — those opt in via the
    # ":bucketed" spec modifier.
    bucket_by_default = False
    # instance-level opt-out set by the ":perleaf" spec modifier
    # (comm/__init__.py get_reducer); plan resolution respects it
    bucket_opt_out = False
    # instance-level opt-out set by the ":serial" spec modifier: bucketed
    # reductions for this reducer stay on the serial (non-pipelined)
    # schedule even when the plan's overlap knob is on
    overlap_opt_out = False
    # does compress/decompress do real per-element work?  False for the
    # identity mean; the comm cost model (core/theory.py) bills codec
    # compute — the overlappable half of a pipeline stage — only when
    # True.  Subclasses with a codec set it.
    has_codec = False
    # pack buckets as near-square matrices instead of flat vectors (what a
    # low-rank codec needs to act on a bucket at all)
    wants_matrix = False

    @property
    def codec_name(self) -> str:
        """Codec family label for per-codec compute pricing: the key the
        cost model looks up in ``CommModel.codec_bw`` (calibrated by
        ``autotune/calibrate.py`` from codec-labeled probe points) and
        the value the probe stamps on each sample.  The spec-name for
        codec reducers, "" for the identity mean (no codec compute to
        bill)."""
        return self.name if self.has_codec else ""

    # -- carried state -------------------------------------------------- #
    def init_state(self, params) -> Any:
        return ()

    def split_bucket_states(self, state, n: int):
        """Per-bucket views of the carried state, for the pipelined
        bucket schedule (comm/bucket.py Pipelined): entry ``i`` is the
        state ``compress``/``decompress`` need when handed bucket ``i``
        alone.  Stateless reducers split trivially; stateful reducers
        whose state is per-bucket (the sparse EF pair) override this
        together with :meth:`join_bucket_states`.  Returning ``None``
        means the state cannot be split — the pipelined engine falls
        back to the serial schedule (e.g. per-leaf state handed to the
        bucket engine, or a state built against a stale layout).
        """
        if self.stateful:
            return None
        return [() for _ in range(n)]

    def join_bucket_states(self, state, per_bucket):
        """Inverse of :meth:`split_bucket_states`: recombine the
        per-bucket states threaded through the pipeline into the carried
        state structure ``init_state`` produced."""
        return state

    # -- codec ---------------------------------------------------------- #
    def compress(self, tree, state) -> Tuple[Any, Any]:
        return tree, state

    def decompress(self, payload, like, state):
        """Reconstruct each learner's approximation.  ``like`` is the
        original tree, used only as a shape/dtype template."""
        return payload

    def finalize(self, avg_tree, orig_tree, state) -> Tuple[Any, Any]:
        """Post-reduction hook: restore dtypes / update EF references.

        Contract: implementations consume ``orig_tree`` only as a
        shape/dtype template (EF references update from ``avg_tree``,
        never from ``orig_tree``'s values).  The pipelined bucket engine
        relies on this to finalize each stage inside the scan with the
        *current* iteration's bucket standing in as the template for the
        carried stage — legal because a scan group is shape/dtype
        uniform.
        """
        return avg_tree, state

    # -- accounting ----------------------------------------------------- #
    def payload_bytes(self, tree) -> int:
        """Wire bytes one learner transmits per reduction (single-learner
        tree)."""
        return int(sum(leaf.size * jnp.dtype(leaf.dtype).itemsize
                       for leaf in jax.tree.leaves(tree)))

    def wire_payload_bytes(self, tree) -> int:
        """Bytes one *device* puts on the wire per reduction.  Equal to
        :meth:`payload_bytes` on the replicated (fsdp=1) path; the
        shard-aware bucket engine (comm/bucket.py) overrides it to bill
        the reduce-scatter/all-gather lowering, where each device moves
        only its 1/F shard slice of every sharded bucket."""
        return self.payload_bytes(tree)

    def n_messages(self, tree) -> int:
        """Grouped collectives one reduction dispatches (single-learner
        tree): one per leaf on the per-leaf path; Bucketed overrides with
        one per bucket."""
        return len(jax.tree.leaves(tree))

    def describe(self) -> str:
        """Spec string this reducer round-trips through ``get_reducer``;
        subclasses override :meth:`_describe`, the ":perleaf" / ":serial"
        opt-out suffixes are appended here."""
        out = self._describe()
        if self.bucket_opt_out:
            out += ":perleaf"
        if self.overlap_opt_out:
            out += ":serial"
        return out

    def _describe(self) -> str:
        return self.name

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.describe()})"


MeanReducer = Reducer


class CastReducer(Reducer):
    """Narrow-dtype payload (bf16/fp16/fp8): the all-reduce moves
    ``payload_dtype`` words; master params keep their dtype.

    This absorbs the old ``avg_dtype`` special case of ``make_hier_round``
    exactly: for >=16-bit payloads the mean itself is computed in the
    payload dtype (what ``avg_dtype`` did); sub-16-bit payloads (fp8)
    accumulate in bf16 since XLA has no fp8 reduction arithmetic.
    """

    name = "cast"
    bucket_by_default = True
    has_codec = True

    def __init__(self, dtype=jnp.bfloat16):
        self.payload_dtype = jnp.dtype(dtype)
        self.acc_dtype = (self.payload_dtype
                          if self.payload_dtype.itemsize >= 2 else
                          jnp.dtype(jnp.bfloat16))

    def compress(self, tree, state):
        return jax.tree.map(
            lambda x: x.astype(self.payload_dtype), tree), state

    def decompress(self, payload, like, state):
        if self.acc_dtype == self.payload_dtype:
            return payload
        return jax.tree.map(lambda x: x.astype(self.acc_dtype), payload)

    def finalize(self, avg_tree, orig_tree, state):
        out = jax.tree.map(lambda a, o: a.astype(o.dtype),
                           avg_tree, orig_tree)
        return out, state

    def payload_bytes(self, tree) -> int:
        return int(sum(leaf.size * self.payload_dtype.itemsize
                       for leaf in jax.tree.leaves(tree)))

    def _describe(self) -> str:
        return f"cast:{self.payload_dtype.name}"


def serial_reduce(reducer: Reducer, avg_fn: Callable, tree, state,
                  constraint_fn: Optional[Callable] = None):
    """The serial composition: compress the whole tree, reconstruct,
    average, finalize — every stage completes before the next starts."""
    payload, state = reducer.compress(tree, state)
    xhat = reducer.decompress(payload, tree, state)
    out = avg_fn(xhat, constraint_fn)
    return reducer.finalize(out, tree, state)


def reduce_with(reducer: Reducer, avg_fn: Callable, tree, state,
                constraint_fn: Optional[Callable] = None):
    """Run one compressed reduction: compress -> decompress -> average ->
    finalize.  ``avg_fn(tree, constraint_fn)`` is one of the grouped means
    from core/topology.py (local_average / global_average / pod_average).

    A reducer may own the whole reduction schedule by defining
    ``reduce(avg_fn, tree, state, constraint_fn)`` — the pipelined bucket
    engine (comm/bucket.py Pipelined) uses this to interleave per-bucket
    compress stages with the grouped collectives instead of running the
    serial composition above.

    Elastic-masking contract: participation masks (repro/elastic) ride
    INSIDE ``avg_fn`` — the round builder closes the per-round ``active``
    mask over ``average_over(..., mask=...)`` before handing ``avg_fn``
    here, so reducers, bucket engines, and this dispatcher stay
    mask-oblivious.  What a reducer must guarantee is only what it
    already does: compress/decompress/finalize are per-learner-local
    (vectorized over the stacked lead axes, no cross-learner mixing
    outside ``avg_fn``), so an absent learner's payload simply gets
    weight 0 in the masked mean and its EF carry is restored wholesale
    by the caller's ``where_active`` select after finalize.

    Returns ``(averaged_tree, new_reducer_state)``.
    """
    own = getattr(reducer, "reduce", None)
    if own is not None:
        return own(avg_fn, tree, state, constraint_fn)
    return serial_reduce(reducer, avg_fn, tree, state, constraint_fn)
