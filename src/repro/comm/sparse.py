"""Sparse (top-k / random-k) reducers with error feedback.

Each learner transmits only k coordinates of its *delta since the last
reduction* plus the accumulated error-feedback residual (Stich et al.,
arXiv:1805.09767 — memory/EF makes sparsified averaging converge at the
dense rate):

    delta_j = (w_j - ref_j) + e_j            # progress + carried residual
    payload = select_k(delta_j)              # magnitude top-k or random-k
    e_j'    = delta_j - dense(payload)       # what was NOT transmitted
    xhat_j  = ref_j + dense(payload)
    out     = mean_j xhat_j ; ref <- out     # reference tracks consensus

The reference/residual pair lives in :class:`EFState` and is threaded
through ``TrainState.comm_state`` by core/hier_avg.py.  The hot compress
path (flatten -> abs -> threshold -> gather) dispatches through
kernels/ops.py::topk_compress (``impl="xla" | "pallas" | "pallas_interpret"``).

Shard-space EF contract (``fsdp > 1``): this module never sees shards.
The bucket engine (comm/bucket.py) hands codecs the *codec view* — shards
merged into the local-learner axis, ``[pods, G, S*F, run]`` — so each
shard row selects its own top-k and carries its own ``ref``/``err``
exactly as an unsharded learner would.  The EF invariant that makes the
reduce-scatter + all-gather decomposition sound: a shard's residual is a
function only of coordinates that shard owns, so EF state lives, updates,
and checkpoints entirely in shard space (no cross-shard state motion; see
tests/test_sharded.py for the checkpoint round-trip).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.comm.reducer import (N_LEARNER_AXES, Reducer, learner_shape,
                                per_learner_size)
from repro.kernels import ops


class EFState(NamedTuple):
    """Error-feedback carry, stacked like the params ([pods, G, S, *shape])."""
    ref: Any        # each learner's view of the last reduction result
    err: Any        # untransmitted residual, fp32
    key: jax.Array  # PRNG key (consumed by random-k; carried by top-k)


def _rows(leaf) -> int:
    r = 1
    for d in leaf.shape[:N_LEARNER_AXES]:
        r *= d
    return r


def _scatter_rows(vals, idx, n):
    """Dense [rows, n] from per-row (vals, idx) — the decompress scatter."""
    rows = vals.shape[0]
    out = jnp.zeros((rows, n), jnp.float32)
    return out.at[jnp.arange(rows)[:, None], idx].set(
        vals.astype(jnp.float32))


class _SparseEFReducer(Reducer):
    """Shared machinery for top-k / random-k; subclasses pick the support."""

    stateful = True
    has_codec = True
    # bucketed by default: k-of-the-bucket approximates the global
    # k-of-the-model selection the EF analyses assume (comm/bucket.py)
    bucket_by_default = True

    def __init__(self, ratio: float = 0.1, impl: str = "xla"):
        if not 0.0 < ratio <= 1.0:
            raise ValueError(
                f"{self.name} ratio must be in (0, 1], got {ratio}")
        self.ratio = float(ratio)
        self.impl = impl

    def k_for(self, n: int) -> int:
        return max(1, min(n, int(round(self.ratio * n))))

    def init_state(self, params) -> EFState:
        err = jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), params)
        # ref gets its OWN buffers: aliasing the params would make a
        # donated TrainState donate the same buffer twice
        ref = jax.tree.map(jnp.copy, params)
        return EFState(ref=ref, err=err, key=jax.random.PRNGKey(0))

    # -- pipelined bucket schedule (comm/bucket.py Pipelined) ------------ #
    # The EF pair is naturally per-bucket once bucketed (ref/err are lists
    # of bucket arrays), so the pipeline can thread one (ref, err) pair
    # per stage.  The key stream follows the serial convention (one split
    # per reduction, fold_in per bucket): top-k selection is
    # key-independent, so pipelined == serial bit-for-bit; random-k draws
    # its per-bucket support from the folded key, a different (equally
    # fresh) stream than the serial path's.

    def split_bucket_states(self, state: EFState, n: int):
        refs = jax.tree.leaves(state.ref)
        errs = jax.tree.leaves(state.err)
        if len(refs) != n or len(errs) != n:
            return None                      # not bucket-aligned state
        _, sub = jax.random.split(state.key)
        return [EFState(ref=[refs[i]], err=[errs[i]],
                        key=jax.random.fold_in(sub, i))
                for i in range(n)]

    def join_bucket_states(self, state: EFState, per_bucket):
        key, _ = jax.random.split(state.key)   # same advance as compress
        return EFState(ref=[s.ref[0] for s in per_bucket],
                       err=[s.err[0] for s in per_bucket], key=key)

    def _select(self, delta2d, k: int, key):  # -> (vals, idx) per row
        raise NotImplementedError

    def compress(self, tree, state: EFState):
        key, sub = jax.random.split(state.key)
        leaves, treedef = jax.tree.flatten(tree)
        refs = jax.tree.leaves(state.ref)
        errs = jax.tree.leaves(state.err)
        payload, new_errs = [], []
        for i, (x, r, e) in enumerate(zip(leaves, refs, errs)):
            rows, n = _rows(x), per_learner_size(x)
            delta = (x.astype(jnp.float32) - r.astype(jnp.float32)
                     ).reshape(rows, n) + e.reshape(rows, n)
            vals, idx = self._select(delta, self.k_for(n),
                                     jax.random.fold_in(sub, i))
            new_errs.append(
                (delta - _scatter_rows(vals, idx, n)).reshape(e.shape))
            payload.append((vals, idx))
        return payload, EFState(state.ref, treedef.unflatten(new_errs), key)

    def decompress(self, payload, like, state: EFState):
        leaves, treedef = jax.tree.flatten(like)
        refs = jax.tree.leaves(state.ref)
        xhat = []
        for (vals, idx), x, r in zip(payload, leaves, refs):
            dense = _scatter_rows(vals, idx, per_learner_size(x))
            xhat.append(r.astype(jnp.float32)
                        + dense.reshape(x.shape))
        return treedef.unflatten(xhat)

    def finalize(self, avg_tree, orig_tree, state: EFState):
        out = jax.tree.map(lambda a, o: a.astype(o.dtype),
                           avg_tree, orig_tree)
        # the averaged result is every learner's next reference; copied so
        # the round's output params and ref never share a buffer (the
        # TrainState is donated back on the next call — same double-
        # donation hazard init_state documents)
        ref = jax.tree.map(jnp.copy, out)
        return out, state._replace(ref=ref)

    def payload_bytes(self, tree) -> int:
        # fp32 value + int32 index per transmitted coordinate
        return int(sum(self.k_for(leaf.size) * 8
                       for leaf in jax.tree.leaves(tree)))

    def _describe(self) -> str:
        return f"{self.name}:{self.ratio:g}"


class TopKReducer(_SparseEFReducer):
    """Per-leaf magnitude top-k of the EF-corrected delta."""

    name = "topk"

    def _select(self, delta2d, k, key):
        return ops.topk_compress(delta2d, k, impl=self.impl)


class RandKReducer(_SparseEFReducer):
    """Random-k with a shared support: all learners transmit the same k
    coordinates each round (drawn fresh from the carried key), so the
    grouped mean of the sparse payloads is itself k-sparse.  Unselected
    coordinates ride the EF residual into a later round."""

    name = "randk"

    def _select(self, delta2d, k, key):
        n = delta2d.shape[1]
        idx = jax.random.choice(key, n, shape=(k,), replace=False)
        idx = jnp.sort(idx).astype(jnp.int32)
        idx2d = jnp.broadcast_to(idx[None, :], (delta2d.shape[0], k))
        vals = jnp.take_along_axis(delta2d, idx2d, axis=1)
        return vals, idx2d
