"""Compressed-reduction subsystem: pluggable reducers for Hier-AVG.

Pick a reducer by spec string (``HierAvgParams.reducer`` / ``--reducer``):

    "mean"                dense full-precision mean (today's behavior)
    "cast[:dtype]"        narrow payload dtype, default bfloat16
                          (replaces the removed ``avg_dtype`` knob)
    "topk[:ratio]"        magnitude top-k of the delta, error feedback
    "randk[:ratio]"       shared-support random-k, error feedback
    "qint8[:block]"       per-block int8 scale quantization
    "powersgd[:rank]"     PowerSGD low-rank factors, EF + warm-started Q

e.g. ``get_reducer("topk:0.05")`` transmits 5% of coordinates.

A trailing ``:bucketed`` / ``:perleaf`` modifier forces packing on or off
for that reducer (comm/bucket.py): ``"topk:0.05:bucketed"`` compresses and
all-reduces whole flat buckets (global k-of-the-model selection, one
collective per bucket); ``"topk:0.05:perleaf"`` pins the legacy per-leaf
pipeline even when the plan's ``bucket_bytes`` knob is on.  Without a
modifier, plan resolution (core/plan.py) buckets compressed reducers by
default.
"""
from repro.comm.reducer import (CastReducer, MeanReducer,  # noqa: F401
                                Reducer, reduce_with)
from repro.comm.sparse import (EFState, RandKReducer,  # noqa: F401
                               TopKReducer)
from repro.comm.quant import QInt8Reducer  # noqa: F401
from repro.comm.lowrank import LowRankState, PowerSGDReducer  # noqa: F401
from repro.comm.bucket import (DEFAULT_BUCKET_BYTES,  # noqa: F401
                               Bucketed, BucketLayout)

REDUCER_NAMES = ("mean", "cast", "topk", "randk", "qint8", "powersgd")
_MODIFIERS = ("bucketed", "perleaf")


def get_reducer(spec, **kw) -> Reducer:
    """Resolve a reducer from a spec string (or pass a Reducer through).

    ``kw`` (e.g. ``impl="pallas"`` for sparse reducers) overrides defaults.
    """
    if isinstance(spec, Reducer):
        return spec
    if spec is None:
        return MeanReducer()
    spec = str(spec)
    modifier = None
    head, _, tail = spec.rpartition(":")
    if head and tail in _MODIFIERS:
        spec, modifier = head, tail
    name, _, arg = spec.partition(":")
    if name == "mean":
        red = MeanReducer()
    elif name == "cast":
        red = CastReducer(arg or "bfloat16")
    elif name == "topk":
        red = TopKReducer(float(arg or 0.1), **kw)
    elif name == "randk":
        red = RandKReducer(float(arg or 0.1), **kw)
    elif name == "qint8":
        red = QInt8Reducer(int(arg or 256))
    elif name == "powersgd":
        red = PowerSGDReducer(int(arg or 2))
    else:
        raise ValueError(
            f"unknown reducer spec {spec!r}; known: {REDUCER_NAMES} "
            f"(+ optional ':bucketed' / ':perleaf' modifier)")
    if modifier == "bucketed":
        return Bucketed(red)
    if modifier == "perleaf":
        red.bucket_opt_out = True   # declared on Reducer; describe()
        # appends ":perleaf" from it, so the spec round-trips
    return red
