"""Compressed-reduction subsystem: pluggable reducers for Hier-AVG.

Pick a reducer by spec string (``HierAvgParams.reducer`` / ``--reducer``):

    "mean"                dense full-precision mean (today's behavior)
    "cast[:dtype]"        narrow payload dtype, default bfloat16
                          (replaces the removed ``avg_dtype`` knob)
    "topk[:ratio]"        magnitude top-k of the delta, error feedback
    "randk[:ratio]"       shared-support random-k, error feedback
    "qint8[:block]"       per-block int8 scale quantization
    "powersgd[:rank]"     PowerSGD low-rank factors, EF + warm-started Q

e.g. ``get_reducer("topk:0.05")`` transmits 5% of coordinates.
"""
from repro.comm.reducer import (CastReducer, MeanReducer,  # noqa: F401
                                Reducer, reduce_with)
from repro.comm.sparse import (EFState, RandKReducer,  # noqa: F401
                               TopKReducer)
from repro.comm.quant import QInt8Reducer  # noqa: F401
from repro.comm.lowrank import LowRankState, PowerSGDReducer  # noqa: F401

REDUCER_NAMES = ("mean", "cast", "topk", "randk", "qint8", "powersgd")


def get_reducer(spec, **kw) -> Reducer:
    """Resolve a reducer from a spec string (or pass a Reducer through).

    ``kw`` (e.g. ``impl="pallas"`` for sparse reducers) overrides defaults.
    """
    if isinstance(spec, Reducer):
        return spec
    if spec is None:
        return MeanReducer()
    name, _, arg = str(spec).partition(":")
    if name == "mean":
        return MeanReducer()
    if name == "cast":
        return CastReducer(arg or "bfloat16")
    if name == "topk":
        return TopKReducer(float(arg or 0.1), **kw)
    if name == "randk":
        return RandKReducer(float(arg or 0.1), **kw)
    if name == "qint8":
        return QInt8Reducer(int(arg or 256))
    if name == "powersgd":
        return PowerSGDReducer(int(arg or 2))
    raise ValueError(
        f"unknown reducer spec {spec!r}; known: {REDUCER_NAMES}")
