"""Compressed-reduction subsystem: pluggable reducers for Hier-AVG.

Pick a reducer by spec string (``HierAvgParams.reducer`` / ``--reducer``):

    "mean"                dense full-precision mean (today's behavior)
    "cast[:dtype]"        narrow payload dtype, default bfloat16
                          (replaces the removed ``avg_dtype`` knob)
    "topk[:ratio]"        magnitude top-k of the delta, error feedback
    "randk[:ratio]"       shared-support random-k, error feedback
    "qint8[:block]"       per-block int8 scale quantization (fused
                          single-buffer pack; ``:twopass`` pins the
                          legacy two-message quantize path)
    "powersgd[:rank]"     PowerSGD low-rank factors, EF + warm-started Q

e.g. ``get_reducer("topk:0.05")`` transmits 5% of coordinates.

A trailing ``:bucketed`` / ``:perleaf`` modifier forces packing on or off
for that reducer (comm/bucket.py): ``"topk:0.05:bucketed"`` compresses and
all-reduces whole flat buckets (global k-of-the-model selection, one
collective per bucket); ``"topk:0.05:perleaf"`` pins the legacy per-leaf
pipeline even when the plan's ``bucket_bytes`` knob is on.  Without a
modifier, plan resolution (core/plan.py) buckets compressed reducers by
default.

A trailing ``:pipelined`` / ``:serial`` modifier forces the bucket
*schedule*: ``:pipelined`` runs the double-buffered overlapped engine
(comm/bucket.py Pipelined) even when ``HierAvgParams.overlap`` is off;
``:serial`` pins the strictly sequential compress-then-reduce schedule.
Without a modifier, plan resolution pipelines bucketed reducers whenever
the plan's ``overlap`` knob (default on) allows.
"""
from repro.comm.reducer import (CastReducer, MeanReducer,  # noqa: F401
                                Reducer, reduce_with, serial_reduce)
from repro.comm.sparse import (EFState, RandKReducer,  # noqa: F401
                               TopKReducer)
from repro.comm.quant import QInt8Reducer  # noqa: F401
from repro.comm.lowrank import LowRankState, PowerSGDReducer  # noqa: F401
from repro.comm.bucket import (DEFAULT_BUCKET_BYTES,  # noqa: F401
                               Bucketed, BucketLayout, Pipelined)

REDUCER_NAMES = ("mean", "cast", "topk", "randk", "qint8", "powersgd")
_MODIFIERS = ("bucketed", "perleaf", "pipelined", "serial")


def get_reducer(spec, **kw) -> Reducer:
    """Resolve a reducer from a spec string (or pass a Reducer through).

    ``kw`` (e.g. ``impl="pallas"`` for sparse reducers) overrides defaults.
    """
    if isinstance(spec, Reducer):
        return spec
    if spec is None:
        return MeanReducer()
    spec = str(spec)
    modifiers = []
    while True:                     # modifiers may stack (":bucketed:serial")
        head, _, tail = spec.rpartition(":")
        if head and tail in _MODIFIERS:
            spec = head
            modifiers.append(tail)
        else:
            break
    if "perleaf" in modifiers and ("pipelined" in modifiers
                                   or "bucketed" in modifiers):
        raise ValueError(
            f"contradictory modifiers {modifiers} on reducer spec "
            f"{spec!r}: ':perleaf' disables the packing ':pipelined'/"
            f"':bucketed' require")
    if "pipelined" in modifiers and "serial" in modifiers:
        raise ValueError(
            f"contradictory modifiers {modifiers} on reducer spec "
            f"{spec!r}: pick one of ':pipelined' / ':serial'")
    name, _, arg = spec.partition(":")
    if name == "mean":
        red = MeanReducer()
    elif name == "cast":
        red = CastReducer(arg or "bfloat16")
    elif name == "topk":
        red = TopKReducer(float(arg or 0.1), **kw)
    elif name == "randk":
        red = RandKReducer(float(arg or 0.1), **kw)
    elif name == "qint8":
        # "qint8[:block][:twopass]" — ":twopass" pins the legacy
        # two-message quantize path (the fused-pack A/B baseline)
        if arg == "twopass" or arg.endswith(":twopass"):
            kw.setdefault("fused", False)
            arg = arg[:-len("twopass")].rstrip(":")
        red = QInt8Reducer(int(arg or 256), **kw)
    elif name == "powersgd":
        red = PowerSGDReducer(int(arg or 2), **kw)
    else:
        raise ValueError(
            f"unknown reducer spec {spec!r}; known: {REDUCER_NAMES} "
            f"(+ optional ':bucketed'/':perleaf' and "
            f"':pipelined'/':serial' modifiers)")
    if "perleaf" in modifiers:
        red.bucket_opt_out = True   # declared on Reducer; describe()
        # appends ":perleaf" from it, so the spec round-trips
        if "serial" in modifiers:
            red.overlap_opt_out = True
        return red
    if "pipelined" in modifiers:
        wrapped = Pipelined(red)
        wrapped.pipeline_pin = True   # explicit pin: plan resolution
        # keeps the pipelined engine even when overlap is off
        return wrapped
    if "bucketed" in modifiers:
        wrapped = Bucketed(red)
        if "serial" in modifiers:
            wrapped.overlap_opt_out = True
        return wrapped
    if "serial" in modifiers:
        # schedule pin on the raw reducer: plan resolution may still
        # auto-bucket it, but will keep the serial (non-pipelined) engine
        red.overlap_opt_out = True
    return red
