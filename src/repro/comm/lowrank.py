"""PowerSGD-style low-rank reducer with error feedback and warm-started Q.

Vogels et al. (arXiv:1905.13727): compress each parameter matrix M [a, b]
to a rank-r factorization via one step of subspace iteration, warm-started
from the previous round's right factor Q:

    P  = M Q                 # [a, r] left factor
    P^ = orthonormalize(P)   # batched QR
    Q' = M^T P^              # [b, r] right factor (next round's warm start)
    M^ = P^ Q'^T             # the rank-r approximation on the wire

Per learner the payload is (a + b) * r fp32 words instead of a * b — for
the global tier of a ReductionPlan this is typically 100-1000x smaller.
Like the sparse reducers (comm/sparse.py), compression acts on the
*delta since the last reduction* plus the error-feedback residual, so the
untransmitted mass rides into later rounds and averaging converges at the
dense rate.  In the stacked-learner formulation the grouped mean runs over
each learner's reconstruction ``ref + P^ Q'^T`` (mean of rank-r
approximations; aggregate-then-orthogonalize needs a payload-aware
collective — same wire-cost caveat as comm/reducer.py).

Leaves whose per-learner shape is not a matrix with min(a, b) > r (biases,
norm gains) are transmitted dense — the PowerSGD paper's "rank-1 tensors
uncompressed" rule.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.comm.reducer import N_LEARNER_AXES, Reducer, learner_shape
from repro.kernels import ops


class LowRankState(NamedTuple):
    """PowerSGD carry, stacked like the params ([pods, G, S, ...])."""
    ref: Any        # each learner's view of the last reduction result
    err: Any        # untransmitted residual, fp32
    q: Any          # per-leaf warm-start Q [pods, G, S, b, r]; () if dense


def _rows(leaf) -> int:
    r = 1
    for d in leaf.shape[:N_LEARNER_AXES]:
        r *= d
    return r


def _matrix_dims(shape) -> tuple:
    """Per-learner shape -> (a, b) matrix view: leading dim x the rest."""
    a = shape[0]
    b = 1
    for d in shape[1:]:
        b *= d
    return a, b


def _orthonormalize(p, impl: str = "auto"):
    """Batched QR over the leading (learner) dim: [rows, a, r] -> Q factor.

    Dispatches through ``kernels/ops.py::batched_qr``: the CGS2 Pallas
    kernel (kernels/batched_qr.py, one program per learner row) on a TPU
    backend, the LAPACK/Householder ``jnp.linalg.qr`` oracle elsewhere.
    The two differ only in per-column signs, which cancel in the
    ``P^ Q'^T`` reconstruction.
    """
    return ops.batched_qr(p, impl=impl)


class PowerSGDReducer(Reducer):
    """Rank-r payload (``powersgd:<rank>``) with EF and warm-started Q."""

    name = "powersgd"
    stateful = True
    has_codec = True
    # NOT bucketed by default: the low-rank codec exploits each weight
    # matrix's own row/column structure, which flat packing destroys.
    # Explicit "powersgd:<r>:bucketed" still works — wants_matrix makes
    # the layout pack near-square [a, b] buckets the codec can factorize.
    bucket_by_default = False
    wants_matrix = True

    def __init__(self, rank: int = 2, impl: str = "auto"):
        if rank < 1:
            raise ValueError(f"powersgd rank must be >= 1, got {rank}")
        self.rank = int(rank)
        # QR kernel dispatch (kernels/ops.py): "auto" | "xla" | "pallas"
        # | "pallas_interpret"
        self.impl = impl

    def _compressible(self, leaf) -> bool:
        s = learner_shape(leaf)
        if len(s) < 2:
            return False
        a, b = _matrix_dims(s)
        return min(a, b) > self.rank

    def init_state(self, params) -> LowRankState:
        err = jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), params)
        leaves, treedef = jax.tree.flatten(params)
        key = jax.random.PRNGKey(0)
        qs = []
        for i, leaf in enumerate(leaves):
            if self._compressible(leaf):
                _, b = _matrix_dims(learner_shape(leaf))
                qs.append(jax.random.normal(
                    jax.random.fold_in(key, i),
                    leaf.shape[:N_LEARNER_AXES] + (b, self.rank),
                    jnp.float32))
            else:
                qs.append(())
        # fresh buffers for ref (see comm/sparse.py: donation aliasing)
        return LowRankState(ref=jax.tree.map(jnp.copy, params), err=err,
                            q=treedef.unflatten(qs))

    def compress(self, tree, state: LowRankState):
        leaves, treedef = jax.tree.flatten(tree)
        refs = jax.tree.leaves(state.ref)
        errs = jax.tree.leaves(state.err)
        qs = treedef.flatten_up_to(state.q)
        payload, new_errs, new_qs = [], [], []
        for x, r, e, q in zip(leaves, refs, errs, qs):
            delta = (x.astype(jnp.float32) - r.astype(jnp.float32)) + e
            if not self._compressible(x):
                payload.append(delta)          # dense fallback on the wire
                new_errs.append(jnp.zeros_like(e))
                new_qs.append(q)
                continue
            rows = _rows(x)
            a, b = _matrix_dims(learner_shape(x))
            m = delta.reshape(rows, a, b)
            p_hat = _orthonormalize(m @ q.reshape(rows, b, self.rank),
                                    impl=self.impl)
            q_new = jnp.einsum("nab,nar->nbr", m, p_hat)
            approx = jnp.einsum("nar,nbr->nab", p_hat, q_new)
            payload.append((p_hat, q_new))
            new_errs.append((m - approx).reshape(e.shape))
            new_qs.append(q_new.reshape(q.shape))
        return payload, LowRankState(state.ref,
                                     treedef.unflatten(new_errs),
                                     treedef.unflatten(new_qs))

    def decompress(self, payload, like, state: LowRankState):
        leaves, treedef = jax.tree.flatten(like)
        refs = jax.tree.leaves(state.ref)
        xhat = []
        for pl, x, r in zip(payload, leaves, refs):
            if isinstance(pl, tuple):
                p_hat, q_new = pl
                approx = jnp.einsum("nar,nbr->nab", p_hat, q_new)
                xhat.append(r.astype(jnp.float32)
                            + approx.reshape(x.shape))
            else:
                xhat.append(r.astype(jnp.float32) + pl)
        return treedef.unflatten(xhat)

    def finalize(self, avg_tree, orig_tree, state: LowRankState):
        out = jax.tree.map(lambda a, o: a.astype(o.dtype),
                           avg_tree, orig_tree)
        # next reference, copied so output params/ref never share a
        # buffer under donation (see comm/sparse.py finalize)
        ref = jax.tree.map(jnp.copy, out)
        return out, state._replace(ref=ref)

    def split_bucket_states(self, state: LowRankState, n_buckets: int):
        """Per-bucket states for the pipelined scan (comm/bucket.py).

        In the bucket engine ``init_state`` saw the list of packed
        buckets, so ref/err/q are parallel lists — one entry per bucket
        (q is ``()`` for a non-compressible bucket shape).  Anything
        else (per-leaf state, stale layout) returns None -> serial
        fallback.
        """
        refs, errs, qs = state.ref, state.err, state.q
        if not (isinstance(refs, list) and isinstance(errs, list)
                and isinstance(qs, list) and len(refs) == n_buckets
                and len(errs) == n_buckets and len(qs) == n_buckets):
            return None
        return [LowRankState(ref=[refs[i]], err=[errs[i]], q=[qs[i]])
                for i in range(n_buckets)]

    def join_bucket_states(self, state: LowRankState,
                           states) -> LowRankState:
        """Inverse of :meth:`split_bucket_states` after per-bucket
        compress+finalize ran inside the scan."""
        return LowRankState(ref=[s.ref[0] for s in states],
                            err=[s.err[0] for s in states],
                            q=[s.q[0] for s in states])

    def n_messages(self, tree) -> int:
        """Two collectives per compressible leaf (the P^ and Q'
        factors), one for each dense-fallback leaf."""
        total = 0
        for leaf in jax.tree.leaves(tree):
            s = tuple(leaf.shape)
            if len(s) >= 2 and min(_matrix_dims(s)) > self.rank:
                total += 2
            else:
                total += 1
        return int(total)

    def payload_bytes(self, tree) -> int:
        total = 0
        for leaf in jax.tree.leaves(tree):
            s = tuple(leaf.shape)
            if len(s) >= 2:
                a, b = _matrix_dims(s)
                if min(a, b) > self.rank:
                    total += (a + b) * self.rank * 4
                    continue
            total += per_learner_size_dense(leaf)
        return int(total)

    def _describe(self) -> str:
        return f"powersgd:{self.rank}"


def per_learner_size_dense(leaf) -> int:
    """fp32 dense bytes of a single-learner leaf (the fallback wire cost)."""
    n = 1
    for d in leaf.shape:
        n *= d
    return n * 4
