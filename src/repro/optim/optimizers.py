"""Minimal functional optimizers (no optax on this container).

An :class:`Optimizer` is a pair of pure functions; state pytrees mirror the
param pytree, so they stack/shard transparently under the Hier-AVG
stacked-learner layout (each learner gets its own optimizer state slice).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], Tuple[Any, Any]]
    # update(grads, params, opt_state, step) -> (new_params, new_opt_state)


def _lr_at(lr, step):
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


def sgd(lr, momentum: float = 0.0, nesterov: bool = False,
        weight_decay: float = 0.0) -> Optimizer:
    """Plain / momentum SGD — the paper's optimizer (lr 0.1 -> 0.01 step decay)."""

    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, params, state, step):
        g = _lr_at(lr, step)

        def upd(p, gr, m=None):
            gr = gr.astype(jnp.float32)
            if weight_decay:
                gr = gr + weight_decay * p.astype(jnp.float32)
            if momentum == 0.0:
                return (p.astype(jnp.float32) - g * gr).astype(p.dtype), None
            m_new = momentum * m + gr
            d = gr + momentum * m_new if nesterov else m_new
            return (p.astype(jnp.float32) - g * d).astype(p.dtype), \
                m_new.astype(m.dtype)

        if momentum == 0.0:
            new_params = jax.tree.map(lambda p, gr: upd(p, gr)[0], params,
                                      grads)
            return new_params, ()
        out = jax.tree.map(upd, params, grads, state)
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_state = jax.tree.map(lambda o: o[1], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
        return new_params, new_state

    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:

    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"mu": z, "nu": jax.tree.map(jnp.zeros_like, z)}

    def update(grads, params, state, step):
        g = _lr_at(lr, step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd(p, gr, mu, nu):
            gr = gr.astype(jnp.float32)
            mu = b1 * mu + (1 - b1) * gr
            nu = b2 * nu + (1 - b2) * jnp.square(gr)
            d = (mu / c1) / (jnp.sqrt(nu / c2) + eps)
            if weight_decay:
                d = d + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - g * d).astype(p.dtype), mu, nu

        out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
        is3 = lambda x: isinstance(x, tuple)
        return (jax.tree.map(lambda o: o[0], out, is_leaf=is3),
                {"mu": jax.tree.map(lambda o: o[1], out, is_leaf=is3),
                 "nu": jax.tree.map(lambda o: o[2], out, is_leaf=is3)})

    return Optimizer(init, update)
