from repro.optim.optimizers import Optimizer, adamw, sgd  # noqa: F401
from repro.optim.schedules import (constant_lr, cosine_lr,  # noqa: F401
                                   step_decay_lr, warmup_cosine_lr)
from repro.optim.clip import clip_by_global_norm, global_norm  # noqa: F401
