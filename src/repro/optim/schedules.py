"""Learning-rate schedules.

The paper's CIFAR recipe: constant 0.1 for 150 epochs, then 0.01
(``step_decay_lr``).  Theorem 3.1's rate-optimal constant step is
``gamma = sqrt(P*B/T)`` (``thm31_lr``).
"""
from __future__ import annotations

import math
from typing import Sequence, Tuple

import jax.numpy as jnp


def constant_lr(lr: float):
    def f(step):
        return jnp.asarray(lr, jnp.float32)
    return f


def step_decay_lr(base: float, boundaries: Sequence[int],
                  decays: Sequence[float]):
    """Paper-style piecewise-constant decay (e.g. 0.1 -> 0.01 at epoch 150)."""
    bs = tuple(boundaries)
    ds = tuple(decays)
    assert len(bs) == len(ds)

    def f(step):
        lr = jnp.asarray(base, jnp.float32)
        for b, d in zip(bs, ds):
            lr = jnp.where(step >= b, base * d, lr)
        return lr
    return f


def cosine_lr(base: float, total_steps: int, final_frac: float = 0.1):
    def f(step):
        t = jnp.clip(step / max(1, total_steps), 0.0, 1.0)
        c = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.asarray(base * (final_frac + (1 - final_frac) * c),
                           jnp.float32)
    return f


def warmup_cosine_lr(base: float, warmup: int, total_steps: int,
                     final_frac: float = 0.1):
    cos = cosine_lr(base, max(1, total_steps - warmup), final_frac)

    def f(step):
        w = jnp.minimum(step / max(1, warmup), 1.0)
        return w * cos(jnp.maximum(step - warmup, 0))
    return f


def thm31_lr(P: int, B: int, T: int) -> float:
    """Theorem 3.1 rate-optimal constant step size: sqrt(P*B/T)."""
    return math.sqrt(P * B / T)


def thm31_k2(P: int, B: int, T: int) -> int:
    """Theorem 3.1 admissible global-averaging interval T^1/4 / (PB)^3/4."""
    return max(1, int(round(T ** 0.25 / (P * B) ** 0.75)))
