"""Autotune: close the loop between the analytic comm-cost stack and the
hardware.

``probe`` measures real grouped reductions (fresh subprocess per point,
forced-host-device mesh); ``calibrate`` fits
:class:`repro.core.theory.CommModel` from the samples and serializes a
JSON calibration artifact (``$REPRO_CALIBRATION`` /
``resolve_comm_model`` let bench_comm, the analytic roofline, and
topology_demo cost with it instead of the built-in constants);
``controller.CostAwarePlan`` adapts every reduction period — the pod
level included — from the calibrated per-level cost ratios plus the
loss ladder; ``search`` enumerates and ranks whole plans (periods x
reducers per level) by calibrated wall-clock x the Thm-3.4 convergence
objective, exposed as ``--autotune`` on launch/train.py and
launch/dryrun.py and benchmarked by benchmarks/bench_autotune.py.
"""
from repro.autotune.calibrate import (CPU_MEDIAN_REL_ERR,  # noqa: F401
                                      Calibration, calibrate_file,
                                      fit_comm_model, predict_seconds,
                                      resolve_calibration,
                                      resolve_comm_model)
from repro.autotune.controller import CostAwarePlan  # noqa: F401
from repro.autotune.probe import (ProbePoint, default_grid,  # noqa: F401
                                  load_samples, measure_point, run_probe)
from repro.autotune.search import (ScoredPlan, SearchSpace,  # noqa: F401
                                   enumerate_specs, recommend_plan,
                                   search_plans)
