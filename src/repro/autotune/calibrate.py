"""Fit :class:`repro.core.theory.CommModel` parameters from probe samples.

The serial-schedule cost stack the probe measures is linear in four
non-negative parameters:

    t  =  [2 V (n-1) / n] * (1/fast_bw)        (ICI samples)
        + [2 V (n-1) / n] * (1/slow_bw)        (DCI samples)
        + [2 (n-1) m]     * latency            (per-message ring startups)
        + [D]             * (1/compress_bw)    (codec samples)

with V the wire payload, n the participants, m the dispatched messages,
D the dense (uncompressed) bytes — exactly
``CommModel.allreduce_time(V, n, bw) + (m-1)·2(n-1)·latency +
D/compress_bw``, the same bill ``theory.level_reduction_seconds`` puts
on a serial level.  :func:`fit_comm_model` solves the non-negative
least-squares problem exactly (best feasible column subset); parameters
whose feature column is all-zero (e.g. no DCI samples in a smoke grid)
or that the fit zeroes out keep the base model's value and are excluded
from ``Calibration.fitted``.

Per-codec compute: samples stamped with a non-empty ``codec`` label
(``Reducer.codec_name``, recorded by probe.py) move their dense-bytes
support out of the shared ``compress_bw`` column into one column per
codec family — topk's select+scatter, qint8's fused quantize+pack and
powersgd's einsum+QR run at very different bytes/s, and a single shared
rate mis-prices whichever codecs weren't probed.  Fitted rates land in
``CommModel.codec_bw`` (reported as ``compress_bw[<codec>]`` in
``fitted``); unlabeled codec samples keep fitting the shared constant,
and ``CommModel.compress_bw_for`` falls back to it for any codec the
fit didn't see — so old probe artifacts and codec-free grids behave
exactly as before.

The result serializes to a JSON **calibration artifact** that
``bench_comm`` / ``launch/analytic.py`` / ``examples/topology_demo.py``
load instead of the built-in constants (``resolve_comm_model``, env var
``REPRO_CALIBRATION``), and that ``CostAwarePlan`` /
``autotune/search.py`` turn into period and plan choices.

Tolerance note: on the CPU container the fit is LOOSE by design —
collective wall-clock on 2 oversubscribed cores is scheduler-bound, so
the acceptance check (tests/test_autotune.py, bench_autotune) asserts
median relative error within a documented 0.75 (i.e. predictions within
~2x for at least half the samples), not hardware-grade accuracy.  The
harness, not the constants, is the deliverable on CPU.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import os
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.theory import CommModel

ENV_CALIBRATION = "REPRO_CALIBRATION"

# parameter order of the feature matrix; fitted values are the
# coefficients' reciprocals for the bandwidths, the coefficient itself
# for latency
PARAMS = ("fast_bw", "slow_bw", "latency", "compress_bw")

# documented CPU tolerance (see module docstring): median relative
# prediction error the calibration round-trip must stay within
CPU_MEDIAN_REL_ERR = 0.75


@dataclass(frozen=True)
class Calibration:
    """A fitted CommModel plus fit provenance/diagnostics."""

    model: CommModel
    fitted: Tuple[str, ...]          # params that came from the fit
    n_samples: int
    median_rel_err: float
    max_rel_err: float
    time_field: str = "min_us"
    source: str = ""

    def save(self, path: str) -> None:
        cm = dataclasses.asdict(self.model)
        if not cm.get("codec_bw"):
            # keep the artifact's documented key set stable when no
            # per-codec rate was fitted
            cm.pop("codec_bw", None)
        with open(path, "w") as f:
            json.dump({
                "comm_model": cm,
                "fitted": list(self.fitted),
                "diagnostics": {
                    "n_samples": self.n_samples,
                    "median_rel_err": round(self.median_rel_err, 4),
                    "max_rel_err": round(self.max_rel_err, 4),
                    "time_field": self.time_field,
                },
                "source": self.source,
            }, f, indent=2)

    @classmethod
    def load(cls, path: str) -> "Calibration":
        with open(path) as f:
            d = json.load(f)
        if not isinstance(d, dict) or "comm_model" not in d:
            raise ValueError(
                f"{path} is not a calibration artifact (no 'comm_model' "
                f"key) — expected the JSON written by Calibration.save / "
                f"`python -m repro.autotune.calibrate`, not e.g. "
                f"BENCH_autotune.json benchmark records")
        diag = d.get("diagnostics", {})
        return cls(model=CommModel(**d["comm_model"]),
                   fitted=tuple(d.get("fitted", ())),
                   n_samples=int(diag.get("n_samples", 0)),
                   median_rel_err=float(diag.get("median_rel_err",
                                                 float("nan"))),
                   max_rel_err=float(diag.get("max_rel_err", float("nan"))),
                   time_field=diag.get("time_field", "min_us"),
                   source=d.get("source", path))


def sample_features(s: Dict) -> np.ndarray:
    """Feature row of one probe sample, ordered like ``PARAMS``."""
    v, n, m = s["payload_bytes"], s["n"], s["messages"]
    ring = 2.0 * v * (n - 1) / n if n > 1 else 0.0
    return np.array([
        ring if s["tier"] == "ici" else 0.0,
        ring if s["tier"] == "dci" else 0.0,
        2.0 * (n - 1) * m,
        float(s["dense_bytes"]) if s.get("has_codec", True) else 0.0,
    ])


def _codec_label(s: Dict) -> str:
    """Codec family of a sample ("" when unlabeled or codec-free): the
    per-codec fit groups dense-bytes support by this label."""
    if not s.get("has_codec", True):
        return ""
    return str(s.get("codec") or "")


def predict_seconds(model: CommModel, s: Dict) -> float:
    """The model's prediction for one probe sample — shared by the fit
    diagnostics and the round-trip acceptance test, and identical in
    form to ``theory.level_reduction_seconds`` on the serial schedule
    (including its per-codec ``compress_bw_for`` pricing)."""
    theta = np.array([1.0 / model.fast_bw, 1.0 / model.slow_bw,
                      model.latency,
                      1.0 / model.compress_bw_for(_codec_label(s))])
    return float(sample_features(s) @ theta)


def _nnls(A: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Exact non-negative least squares for a skinny (<= 4-column) A:
    try every column subset, keep the best feasible solution."""
    k = A.shape[1]
    best, best_res = np.zeros(k), float(np.dot(b, b))
    for r in range(1, k + 1):
        for cols in itertools.combinations(range(k), r):
            sub = A[:, cols]
            theta, *_ = np.linalg.lstsq(sub, b, rcond=None)
            if np.any(theta < 0):
                continue
            res = float(np.sum((sub @ theta - b) ** 2))
            if res < best_res - 1e-30:
                best_res = res
                best = np.zeros(k)
                best[list(cols)] = theta
    return best


def fit_comm_model(samples: Sequence[Dict], *,
                   base: Optional[CommModel] = None,
                   time_field: str = "min_us",
                   source: str = "") -> Calibration:
    """Least-squares calibration of CommModel from probe samples.

    ``time_field`` picks the per-sample statistic (``min_us`` by
    default; see probe.py for why the floor, not the mean).  Parameters
    without support in the samples (all-zero feature column, or zeroed
    by the non-negativity constraint) keep ``base``'s value.
    """
    if not samples:
        raise ValueError("need at least one probe sample")
    base = base or CommModel()
    A = np.stack([sample_features(s) for s in samples])
    b = np.array([s[time_field] * 1e-6 for s in samples])
    # per-codec columns: codec-labeled samples carry their dense-bytes
    # support in a column of their own; the shared compress_bw column
    # keeps only the unlabeled codec samples
    labels = np.array([_codec_label(s) for s in samples])
    codecs = sorted({c for c in labels if c})
    dense = A[:, 3].copy()
    A[:, 3] = np.where(labels == "", dense, 0.0)
    if codecs:
        A = np.concatenate(
            [A] + [np.where(labels == c, dense, 0.0)[:, None]
                   for c in codecs], axis=1)
    names = list(PARAMS) + [f"compress_bw[{c}]" for c in codecs]
    identifiable = np.abs(A).sum(axis=0) > 0
    theta = np.zeros(A.shape[1])
    theta[identifiable] = _nnls(A[:, identifiable], b)

    vals = {}
    codec_bw = []
    fitted = []
    for i, name in enumerate(names):
        coef = theta[i]
        if not identifiable[i] or coef <= 0:
            if i < len(PARAMS):
                vals[name] = getattr(base, name)
            continue            # unfitted codec: compress_bw_for falls
            # back to the shared constant
        if i >= len(PARAMS):
            codec_bw.append((codecs[i - len(PARAMS)], 1.0 / coef))
        else:
            vals[name] = coef if name == "latency" else 1.0 / coef
        fitted.append(name)
    model = CommModel(**vals, codec_bw=tuple(codec_bw) or None)

    rel = []
    for s in samples:
        t = s[time_field] * 1e-6
        if t > 0:
            rel.append(abs(predict_seconds(model, s) - t) / t)
    rel = rel or [float("nan")]
    return Calibration(model=model, fitted=tuple(fitted),
                       n_samples=len(samples),
                       median_rel_err=float(np.median(rel)),
                       max_rel_err=float(np.max(rel)),
                       time_field=time_field, source=source)


def calibrate_file(probe_path: str, out_path: Optional[str] = None,
                   **kw) -> Calibration:
    """probe.json -> Calibration (optionally saved as the artifact)."""
    from repro.autotune.probe import load_samples
    cal = fit_comm_model(load_samples(probe_path), source=probe_path, **kw)
    if out_path:
        cal.save(out_path)
    return cal


def resolve_calibration(path: Optional[str] = None
                        ) -> Optional[Calibration]:
    """The configured Calibration (explicit ``path``, else
    ``$REPRO_CALIBRATION``), or None.  Callers with their own built-in
    constants should consult ``.fitted`` — parameters NOT in it carry
    CommModel base defaults, not measurements, and must not displace a
    caller's different built-ins (launch/analytic.py's v5e DCI_BW)."""
    source = "argument"
    if not path:
        path = os.environ.get(ENV_CALIBRATION)
        source = f"${ENV_CALIBRATION}"
    if not path:
        return None
    if not os.path.exists(path):
        # an explicitly configured artifact that is missing must not
        # silently degrade to built-in constants — the caller believes
        # they are costing with measured hardware
        raise FileNotFoundError(
            f"calibration artifact {path!r} (from {source}) does not "
            f"exist")
    return Calibration.load(path)


def resolve_comm_model(path: Optional[str] = None, *,
                       default: Optional[CommModel] = None
                       ) -> Optional[CommModel]:
    """The CommModel consumers should cost with: an explicit calibration
    artifact ``path``, else ``$REPRO_CALIBRATION``, else ``default``
    (``None`` default lets callers keep their own built-in constants
    when nothing is calibrated).  Unfitted parameters of the artifact
    equal the CommModel defaults — fine for consumers whose built-ins
    ARE those defaults (bench_comm, topology_demo); consumers with
    other constants use :func:`resolve_calibration`."""
    cal = resolve_calibration(path)
    return cal.model if cal is not None else default


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("probe_json", help="probe artifact (autotune/probe.py)")
    ap.add_argument("--out", default="calibration.json")
    ap.add_argument("--time-field", default="min_us",
                    choices=("min_us", "warm_us"))
    args = ap.parse_args()
    cal = calibrate_file(args.probe_json, args.out,
                         time_field=args.time_field)
    m = cal.model
    print(f"fitted {cal.fitted} from {cal.n_samples} samples "
          f"(median_rel_err={cal.median_rel_err:.2f}, "
          f"max={cal.max_rel_err:.2f})")
    print(f"  fast_bw={m.fast_bw:.3e} B/s  slow_bw={m.slow_bw:.3e} B/s")
    print(f"  latency={m.latency:.3e} s    compress_bw={m.compress_bw:.3e}"
          f" B/s")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
