"""Enumerate-and-rank reduction-plan search under a calibrated cost model.

Scores every (periods x reducers per level) candidate on two axes and
ranks by their product — a time-to-target proxy in the fixed-data
regime:

* **seconds per SGD step** — the calibrated communication wall-clock
  (``theory.plan_comm_per_round`` under the fitted CommModel, i.e. each
  level on its own measured tier with its own compressed payload and
  overlap term) plus the caller's ``step_s`` compute floor;
* **bound constant per step** — the paper's Theorem 3.4 objective
  ``B(K2) = f(K2) g(K2)`` (theory.thm34_objective) with K1 = the
  candidate's innermost period, K2 = its outermost, S = the topology's
  cluster size: the convergence error constant per unit data at a fixed
  data budget.  Candidates violating the Theorem 3.2 admissibility
  condition (3.5) for their K2 are flagged infeasible and rank after
  every feasible plan.

So a plan only wins by spending LESS wall-clock per step without giving
up more convergence constant than it saves — e.g. under a skewed
(expensive-DCI) calibration the search stretches the global period
and/or compresses the global payload, while a cheap-DCI calibration
keeps dense frequent globals.  Deterministic given the calibration
artifact: tests drive it with synthetic models, no timing dependence.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple, Union

from repro.autotune.calibrate import Calibration
from repro.comm import DEFAULT_BUCKET_BYTES
from repro.core.plan import ReductionPlan, apply_bucketing
from repro.core.theory import (CommModel, param_template,
                               plan_comm_per_round, thm32_condition,
                               thm34_objective, thm34_terms)
from repro.core.topology import HierTopology

DEFAULT_PERIODS: Dict[str, Tuple[int, ...]] = {
    "local": (1, 2, 4),
    "pod": (2, 4, 8, 16),
    "global": (4, 8, 16, 32, 64),
}
DEFAULT_REDUCERS: Dict[str, Tuple[str, ...]] = {
    "local": ("mean", "cast:bfloat16"),
    "pod": ("mean",),
    "global": ("mean", "cast:bfloat16", "topk:0.05"),
}


@dataclass(frozen=True)
class SearchSpace:
    """Candidate grid: per-level periods and reducer specs.  Periods
    must nest (each divides the next) — non-nesting combinations are
    skipped during enumeration."""

    levels: Tuple[str, ...] = ("local", "pod", "global")
    periods: Dict[str, Tuple[int, ...]] = field(
        default_factory=lambda: dict(DEFAULT_PERIODS))
    reducers: Dict[str, Tuple[str, ...]] = field(
        default_factory=lambda: dict(DEFAULT_REDUCERS))


@dataclass(frozen=True)
class ScoredPlan:
    spec: str
    outer: int                  # K2 (outermost period)
    inner: int                  # K1 (innermost period)
    comm_s_per_step: float      # calibrated comm wall per SGD step
    sec_per_step: float         # step_s + comm_s_per_step
    objective: float            # Thm 3.4 B(K2) error constant
    score: float                # sec_per_step * objective
    feasible: bool              # Thm 3.2 condition (3.5) at this K2


def enumerate_specs(space: SearchSpace):
    """All nested (period, reducer) combinations as plan spec strings."""
    for periods in itertools.product(
            *(space.periods[n] for n in space.levels)):
        if any(hi % lo for lo, hi in zip(periods, periods[1:])):
            continue
        for reds in itertools.product(
                *(space.reducers[n] for n in space.levels)):
            yield "/".join(f"{n}@{p}:{r}" for n, p, r
                           in zip(space.levels, periods, reds))


def search_plans(topo: HierTopology,
                 comm: Union[Calibration, CommModel, None] = None, *,
                 template: Any = None,
                 space: Optional[SearchSpace] = None,
                 B: int = 32, T_ref: int = 4096,
                 gamma: float = 0.05, L: float = 1.0, M: float = 1.0,
                 F1_minus_Fstar: float = 1.0,
                 step_s: float = 0.0,
                 bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                 overlap: bool = True,
                 shards: Any = None,
                 drop_prob=0.0,
                 top: Optional[int] = None) -> List[ScoredPlan]:
    """Rank the candidate grid; best (lowest score, feasible first)
    first.  ``gamma``/``L``/``M``/``F1_minus_Fstar`` are the Thm 3.4
    constants (defaults: the paper's small-step regime — gamma small
    enough that a useful K2 range stays admissible under (3.5));
    ``step_s`` the per-SGD-step compute floor the comm bill rides on.

    Candidates are costed RESOLVED — bucketed on the pipelined schedule
    per ``bucket_bytes``/``overlap``, like ``resolve_plan`` will run
    them (and like bench_comm costs) — so codec candidates get their
    bucketed message counts and overlap credit, not a per-leaf serial
    bill the trained plan never pays.  The returned ``spec`` stays the
    raw plan string (resolution re-applies at build time).  ``shards``
    (parallel/sharding.py ShardPlan) bills fsdp>1 candidates at their
    reduce-scatter/all-gather wire bytes (payload/F per sharded
    bucket).

    ``drop_prob`` — score plans against an unreliable tier: a scalar (or
    ``{level_name: p}`` mapping) per-member miss probability; each
    level's ring terms are billed at ``effective_participants`` (elastic
    expected-cost mode, core/theory.py).  The Thm 3.4 objective is left
    at its dense constants — the masked mean keeps the averaging
    unbiased over survivors, so the cost side is where unreliability
    moves the ranking."""
    if isinstance(comm, Calibration):
        comm = comm.model
    cm = comm or CommModel()
    space = space or SearchSpace()
    if template is None:
        template = param_template(1 << 22, n_leaves=8)
    P = topo.n_learners
    S = max(topo.s, 1)
    alpha, beta, eta = thm34_terms(F1_minus_Fstar, L, M, gamma, T_ref, P, B)
    out: List[ScoredPlan] = []
    for spec in enumerate_specs(space):
        plan = ReductionPlan.parse(spec)
        resolved = apply_bucketing(plan, bucket_bytes, overlap,
                                   shards=shards)
        costs = plan_comm_per_round(resolved, topo, template, cm,
                                    drop_prob=drop_prob)
        comm_per_step = sum(c.overlap_s for c in costs) / plan.total_period
        k1 = plan.levels[0].period
        k2 = plan.total_period
        obj = thm34_objective(k2, k1, S, alpha, beta, eta)
        sec = step_s + comm_per_step
        out.append(ScoredPlan(
            spec=spec, outer=k2, inner=k1,
            comm_s_per_step=comm_per_step, sec_per_step=sec,
            objective=obj, score=sec * obj,
            feasible=thm32_condition(L, gamma, k2)))
    out.sort(key=lambda sp: (not sp.feasible, sp.score))
    return out[:top] if top else out


def recommend_plan(topo: HierTopology,
                   comm: Union[Calibration, CommModel, None] = None,
                   **kw) -> ScoredPlan:
    """The search winner (best feasible plan; best overall only if
    nothing in the grid satisfies condition (3.5))."""
    return search_plans(topo, comm, **kw)[0]
