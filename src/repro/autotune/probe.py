"""On-device measurement harness for comm-model calibration.

Times REAL grouped reductions — compress + grouped all-reduce +
finalize, the exact program ``repro.testing.build_ab_reduction`` hands
to benchmarks/bench_bucketing.py and tests/test_pipeline.py — per plan
level, payload size, reducer codec, and bucket count, on the
forced-host-device mesh.  The resulting samples feed
``autotune/calibrate.py``'s least-squares fit of
:class:`repro.core.theory.CommModel`.

CPU caveats (they shape the harness, see tests/test_pipeline.py and the
bench_bucketing subprocess-per-variant note):

* every probe point runs in a FRESH subprocess — on a small CPU box the
  wall-clock of host-device collectives is bimodal run-to-run and
  in-process measurement order perturbs XLA compile state, so no point
  may inherit another's warm LLVM/threadpool state (and the 8-device
  force must happen before jax initializes anyway);
* XLA:CPU lowers all-reduce synchronously (no ``all-reduce-start`` /
  ``-done``), so probes pin the SERIAL bucket schedule — the fit targets
  the serial cost stack, and the pipelined overlap term stays analytic;
* calibration consumes ``min_us`` (the floor is the least
  scheduler-noise-contaminated statistic on an oversubscribed box);
  ``warm_us`` (median) and ``compile_s`` are recorded for diagnostics.

Standalone:

    PYTHONPATH=src python -m repro.autotune.probe --out probe.json \
        [--smoke] [--reps N]
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

PROBE_CAP_LARGE = 4 << 20     # one bucket: isolates the wire-bytes term
PROBE_CAP_SMALL = 32 << 10    # many buckets: exposes per-message latency


@dataclass(frozen=True)
class ProbePoint:
    """One measured configuration: a ``level`` reduction on ``topo``
    with ``n_leaves`` leaves of ``leaf_shape`` fp32, reducer ``spec``,
    bucket cap ``cap`` (serial schedule)."""

    level: str = "global"
    topo: Tuple[int, int, int] = (1, 2, 4)
    spec: str = "mean"
    n_leaves: int = 8
    leaf_shape: Tuple[int, int] = (64, 64)
    cap: int = PROBE_CAP_LARGE

    def describe(self) -> str:
        p, g, s = self.topo
        return (f"{self.level}@{p}x{g}x{s}:{self.spec}:"
                f"{self.n_leaves}x{self.leaf_shape[0]}x"
                f"{self.leaf_shape[1]}:cap{self.cap}")

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self))

    @classmethod
    def from_json(cls, s: str) -> "ProbePoint":
        d = json.loads(s)
        d["topo"] = tuple(d["topo"])
        d["leaf_shape"] = tuple(d["leaf_shape"])
        return cls(**d)


def default_grid(smoke: bool = False) -> List[ProbePoint]:
    """The probe grid.  Designed so every CommModel parameter is
    identifiable: two payload sizes per tier (bandwidth slope vs
    intercept), a multi-bucket point (per-message latency), mean vs
    codec reducers at matched payloads (compress_bw), and a 2-pod
    topology whose global level classifies as DCI
    (``CommModel.bw_for_level``).  The smoke grid keeps one point per
    identifiable parameter — enough for the CI fit to be determined,
    nothing more."""
    ici = (1, 2, 4)     # 8 learners, one pod: every level rides ICI
    dci = (2, 2, 2)     # 8 learners, two pods: global crosses DCI
    pts = [
        # ICI bandwidth: two sizes, one bucket each
        ProbePoint("global", ici, "mean", 8, (64, 64)),
        ProbePoint("global", ici, "mean", 8, (160, 160)),
        # per-message latency: same bytes, many buckets
        ProbePoint("global", ici, "mean", 8, (64, 64), PROBE_CAP_SMALL),
        # codec compute: matched sizes, compressing reducers
        ProbePoint("global", ici, "topk:0.05", 8, (160, 160)),
        # DCI tier: 2-pod global, two sizes
        ProbePoint("global", dci, "mean", 8, (64, 64)),
        ProbePoint("global", dci, "mean", 8, (160, 160)),
    ]
    if smoke:
        return pts
    pts += [
        # more sizes per tier for a better-conditioned slope
        ProbePoint("global", ici, "mean", 8, (96, 96)),
        ProbePoint("global", dci, "mean", 8, (96, 96)),
        # sub-global scopes (fewer participants at the same tier)
        ProbePoint("local", ici, "mean", 8, (96, 96)),
        ProbePoint("pod", ici, "mean", 8, (96, 96)),
        ProbePoint("pod", dci, "mean", 8, (96, 96)),
        # codec variety: cast halves the payload, topk ~10x
        ProbePoint("global", ici, "cast:bfloat16", 8, (160, 160)),
        ProbePoint("global", ici, "topk:0.05", 8, (64, 64)),
        ProbePoint("global", dci, "topk:0.05", 8, (96, 96)),
        # per-codec compute rates at the matched 160x160 payload: the
        # fused qint8 pack and the powersgd batched QR run very
        # different arithmetic per dense byte, so calibrate.py fits
        # each family its own compress_bw column from these labels
        ProbePoint("global", ici, "qint8:128", 8, (160, 160)),
        ProbePoint("global", ici, "powersgd:2", 8, (160, 160)),
        # a second multi-bucket latency point
        ProbePoint("global", dci, "mean", 8, (64, 64), PROBE_CAP_SMALL),
    ]
    return pts


def measure_point(point: ProbePoint, reps: int = 12) -> Dict:
    """Measure one probe point IN THIS PROCESS (the subprocess child of
    :func:`run_probe`; callable directly in tests).  Builds the shared
    A/B reduction, AOT-compiles it once, executes ``reps`` times."""
    import jax
    import numpy as np

    from repro.core.plan import LEVEL_AXES
    from repro.core.theory import tier_for
    from repro.testing import build_ab_reduction

    b = build_ab_reduction("serial", point.cap, n_leaves=point.n_leaves,
                           leaf_shape=point.leaf_shape, spec=point.spec,
                           topo_shape=point.topo, level=point.level)
    p_sh = jax.device_put(b["params"], b["shardings"][0])
    s_sh = jax.device_put(b["state"], b["shardings"][1])
    t0 = time.time()
    compiled = b["fn"].lower(p_sh, s_sh).compile()
    compile_s = time.time() - t0
    per_exec = []
    for _ in range(reps):
        t1 = time.time()
        jax.block_until_ready(compiled(p_sh, s_sh))
        per_exec.append(time.time() - t1)

    red = b["reducer"]
    tree1 = b["tree1"]
    pods = point.topo[0]
    n = 1
    for a in LEVEL_AXES[point.level]:
        n *= point.topo[a]
    dense = int(sum(leaf.size * leaf.dtype.itemsize
                    for leaf in jax.tree.leaves(tree1)))
    rec = dataclasses.asdict(point)
    rec.update({
        "n": n,
        # the same classifier CommModel.bw_for_level bills with
        "tier": tier_for(LEVEL_AXES[point.level], pods),
        "dense_bytes": dense,
        "payload_bytes": int(red.payload_bytes(tree1)),
        # per-device bytes on the wire — differs from payload_bytes only
        # for fsdp-sharded layouts (reduce-scatter/all-gather moves 1/F
        # of each sharded bucket); the default grid is fsdp=1 so the
        # calibration fit is unchanged, but the field keeps the billed
        # quantity visible in every probe artifact
        "wire_bytes": int(red.wire_payload_bytes(tree1)),
        "messages": int(red.n_messages(tree1)),
        "has_codec": bool(getattr(red, "has_codec", True)),
        # codec family label ("" for the identity mean): calibrate.py
        # fits a per-codec compress_bw column from samples sharing a
        # label, so qint8 pack and powersgd QR stop being billed at the
        # same rate as topk thresholding
        "codec": str(getattr(red, "codec_name", "")),
        "reps": reps,
        "compile_s": round(compile_s, 3),
        "warm_us": round(float(np.median(per_exec)) * 1e6, 1),
        "min_us": round(min(per_exec) * 1e6, 1),
    })
    return rec


def run_probe(points: Optional[Sequence[ProbePoint]] = None, *,
              reps: int = 12, out: Optional[str] = None,
              smoke: bool = False, timeout: float = 600.0) -> List[Dict]:
    """Measure every point in a FRESH subprocess (see module docstring)
    and optionally write the samples to ``out`` as the probe artifact
    ``autotune/calibrate.py`` consumes."""
    points = list(points) if points is not None else default_grid(smoke)
    repo_src = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    env = dict(os.environ)
    env["PYTHONPATH"] = repo_src + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8"
                        ).strip()
    samples: List[Dict] = []
    for pt in points:
        r = subprocess.run(
            [sys.executable, "-m", "repro.autotune.probe",
             "--point", pt.to_json(), "--reps", str(reps)],
            env=env, capture_output=True, text=True, timeout=timeout)
        if r.returncode != 0:
            raise RuntimeError(
                f"probe point {pt.describe()} failed:\n"
                + r.stderr.strip()[-2000:])
        samples.append(json.loads(r.stdout.strip().splitlines()[-1]))
    if out:
        with open(out, "w") as f:
            json.dump({"meta": {"reps": reps, "smoke": smoke,
                                "n_points": len(samples),
                                "time_field": "min_us"},
                       "samples": samples}, f, indent=2)
    return samples


def load_samples(path: str) -> List[Dict]:
    with open(path) as f:
        d = json.load(f)
    return d["samples"] if isinstance(d, dict) else d


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--point", default=None,
                    help="child mode: measure ONE ProbePoint (json) and "
                         "print its sample record")
    ap.add_argument("--reps", type=int, default=12)
    ap.add_argument("--smoke", action="store_true",
                    help="few probe points (the CI grid)")
    ap.add_argument("--out", default="probe.json")
    args = ap.parse_args()
    if args.point:
        print(json.dumps(measure_point(ProbePoint.from_json(args.point),
                                       args.reps)))
        return
    samples = run_probe(reps=args.reps, out=args.out, smoke=args.smoke)
    for s in samples:
        print(f"{s['level']}@{s['tier']} {s['spec']:14s} "
              f"payload={s['payload_bytes']:>8d}B msgs={s['messages']:>2d} "
              f"min={s['min_us']:>9.1f}us warm={s['warm_us']:>9.1f}us "
              f"compile={s['compile_s']:.2f}s")
    print(f"# wrote {args.out} ({len(samples)} samples)", file=sys.stderr)


if __name__ == "__main__":
    # standalone / child mode: force the 8-host-device mesh.  Importing
    # jax (which `python -m` already did via the package __init__) does
    # NOT initialize its backends — XLA_FLAGS is read when the first
    # device call happens, inside measure_point — so setting it here is
    # still early enough.  Library imports never touch the environment.
    if "--xla_force_host_platform_device_count" \
            not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=8").strip()
    main()
