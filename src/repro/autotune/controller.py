"""Cost-aware multi-level period controller.

:class:`CostAwarePlan` generalizes :class:`repro.core.schedules.
AdaptivePlan` from "scale the outermost period on the loss ladder" to
"adapt EVERY reduction spacing from what the hardware actually costs":

* the **outermost** period still follows the loss ladder (far from the
  optimum -> wide interval, Thm 3.4 intuition; near convergence ->
  shrink toward the next-inner period) — Jiang & Agrawal
  (arXiv:2007.06134) show the averaging period is the lever worth
  adapting at runtime;
* every **intermediate** period (the pod level included — the ROADMAP
  follow-up) is set from the CALIBRATED cost ratio to its outer
  neighbour: level *i* fires ``~cost(i+1)/cost(i)`` times per level-
  *i+1* reduction, snapped to the nesting lattice.  With periods
  proportional to per-reduction cost, every tier spends roughly the
  same wire seconds per SGD step — and when the probed DCI/ICI ratio
  skews (global reductions get expensive relative to pod ones), the pod
  period SHRINKS: cheap intra-pod averaging substitutes for the
  expensive cross-DCI reduction, exactly Hier-AVG §3.3's "more frequent
  local averaging can replace global reductions";
* the **innermost** period is the SGD batching cadence and stays fixed,
  like AdaptivePlan's inner levels.

Costs come from a :class:`~repro.autotune.calibrate.Calibration` (or a
raw CommModel / artifact path) through
``theory.level_reduction_seconds`` — the same bill the analytic stack
reports — so a synthetic calibration artifact drives the controller
deterministically in tests (no timing dependence).
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, Mapping, Optional, Tuple, Union

from repro.autotune.calibrate import Calibration, resolve_comm_model
from repro.comm import DEFAULT_BUCKET_BYTES
from repro.configs.base import HierAvgParams
from repro.core.plan import ReductionPlan, apply_bucketing
from repro.core.schedules import AdaptivePlan
from repro.core.theory import (CommModel, level_reduction_seconds,
                               param_template)
from repro.core.topology import HierTopology


# bounded window of ingested telemetry rows (observe): enough to settle
# a median past warm-up noise, small enough to track a drifting fleet
OBS_WINDOW = 64


def _pow2_gap(ratio: float, max_gap: int) -> int:
    """Nearest power of two to ``ratio``, clamped to [1, max_gap]."""
    if ratio <= 1.0:
        return 1
    g = 2 ** int(round(math.log2(ratio)))
    return max(1, min(int(g), max_gap))


def _snap_divisor(target: int, outer: int, inner: int) -> int:
    """Largest divisor of ``outer`` that is a multiple of ``inner`` and
    <= max(target, inner) — keeps the period lattice (inner | p | outer)
    while honouring the cost-derived target."""
    best = inner
    d = inner
    while d <= outer:
        if outer % d == 0 and d <= max(target, inner):
            best = d
        d += inner
    return best


@dataclass
class CostAwarePlan:
    """Adapt all periods of ``plan`` (the widest schedule) from the loss
    AND the calibrated per-level reduction costs on ``topo``.

    ``comm`` is a Calibration, a CommModel, a calibration-artifact path,
    or None (then ``$REPRO_CALIBRATION`` or the built-in constants).
    ``template`` is a single-learner parameter tree for payload
    accounting (ShapeDtypeStructs fine; default a 4M-param stand-in).
    ``max_gap`` clamps any cost-derived spacing multiplier.
    ``bucket_bytes``/``overlap`` mirror HierAvgParams: levels are COSTED
    resolved (bucketed message counts, pipelined overlap credit), the
    schedule ``resolve_plan`` will actually run.
    """

    plan: Union[ReductionPlan, str]
    topo: HierTopology
    comm: Union[Calibration, CommModel, str, None] = None
    template: Any = None
    outer_min: Optional[int] = None
    max_gap: int = 64
    bucket_bytes: int = DEFAULT_BUCKET_BYTES
    overlap: bool = True
    # parallel/sharding.py ShardPlan for fsdp>1 meshes: the resolved
    # engines then bill the reduce-scatter/all-gather wire bytes
    # (payload/F per sharded bucket) instead of the replicated payload
    shards: Any = None
    # elastic expected-cost billing: scalar per-member miss probability
    # (or {level_name: p}) the level costs are priced under — an
    # unreliable outer tier shrinks its n_eff ring, which moves the cost
    # ratios and therefore the intermediate periods (theory.py)
    drop_prob: Any = 0.0
    _ladder: AdaptivePlan = field(init=False, repr=False)

    def __post_init__(self):
        if not isinstance(self.plan, ReductionPlan):
            self.plan = ReductionPlan.parse(self.plan)
        if isinstance(self.comm, Calibration):
            self.comm = self.comm.model
        elif isinstance(self.comm, str):
            self.comm = Calibration.load(self.comm).model
        elif self.comm is None:
            self.comm = resolve_comm_model(default=CommModel())
        if self.template is None:
            self.template = param_template(1 << 22, n_leaves=8)
        # the loss ladder drives the outermost period, as before
        self._ladder = AdaptivePlan(self.plan, outer_min=self.outer_min)
        # every level_costs input is fixed for the controller's
        # lifetime; compute once instead of re-walking the template
        # every params_for call of a training loop
        resolved = apply_bucketing(self.plan, self.bucket_bytes,
                                   self.overlap, shards=self.shards)
        self._level_costs = tuple(
            level_reduction_seconds(
                lvl, self.topo, self.template, self.comm,
                drop_prob=(self.drop_prob.get(lvl.name, 0.0)
                           if hasattr(self.drop_prob, "get")
                           else float(self.drop_prob)))[2]
            for lvl in resolved.levels)
        # runtime observations (telemetry train_round rows via observe)
        self._obs_walls: deque = deque(maxlen=OBS_WINDOW)
        self._obs_fracs: Dict[str, deque] = {}

    @property
    def level_costs(self) -> Tuple[float, ...]:
        """Calibrated scheduled-wall seconds of ONE reduction per level
        (innermost first), on each level's RESOLVED engine (bucketed /
        pipelined per the knobs) — the cost the round actually pays."""
        return self._level_costs

    def periods_for(self, loss: float) -> Tuple[int, ...]:
        """All N periods (innermost first) for the current loss.

        Outermost from the ladder; then outside-in, each intermediate
        level's period is its outer neighbour's divided by the
        power-of-two-snapped cost ratio — an expensive outer neighbour
        pulls the level's period DOWN (reduce more often on the cheap
        tier), a cost ratio near 1 leaves it riding the outer boundary.
        """
        levels = self.plan.levels
        costs = self.level_costs
        periods = [lvl.period for lvl in levels]
        periods[-1] = self._ladder.outer_for(loss)
        inner = periods[0]
        tiny = 1e-30
        for i in range(len(levels) - 2, 0, -1):
            gap = _pow2_gap(costs[i + 1] / max(costs[i], tiny),
                            self.max_gap)
            periods[i] = _snap_divisor(periods[i + 1] // gap,
                                       periods[i + 1], inner)
        return tuple(periods)

    def plan_for(self, loss: float) -> ReductionPlan:
        return self.plan.with_periods(self.periods_for(loss))

    def params_for(self, loss: float,
                   base: Optional[HierAvgParams] = None) -> HierAvgParams:
        """Like :meth:`AdaptivePlan.params_for`: ``base`` keeps every
        non-schedule field via ``dataclasses.replace``."""
        spec = self.plan_for(loss).describe()
        if base is None:
            return HierAvgParams(plan=spec)
        return dataclasses.replace(base, plan=spec)

    def reset(self) -> None:
        """Forget the ladder's loss anchor (new run)."""
        self._ladder.reset()

    # ------------------------------------------------------------ #
    # live telemetry ingestion (repro/telemetry — the first consumer)

    def observe(self, row: Mapping) -> None:
        """Ingest one measured ``train_round`` telemetry row
        (telemetry/metrics.py schema): the measured round ``wall_s`` and
        the per-level ``active_frac`` land in bounded windows so
        measured-vs-modeled wall (and live participation) are queryable
        at runtime.  Closing the loop — re-deriving ``drop_prob`` /
        periods from these windows — is the ROADMAP online-control
        follow-up; this is the signal path it plugs into."""
        w = row.get("wall_s")
        if w is not None and float(w) > 0.0:
            self._obs_walls.append(float(w))
        for name, f in (row.get("active_frac") or {}).items():
            self._obs_fracs.setdefault(
                name, deque(maxlen=OBS_WINDOW)).append(float(f))

    @property
    def observed_wall_s(self) -> Optional[float]:
        """Median measured round wall over the observation window
        (None until the first row; the median rides out the compile
        round and scheduler spikes)."""
        if not self._obs_walls:
            return None
        s = sorted(self._obs_walls)
        return s[len(s) // 2]

    @property
    def observed_active_frac(self) -> Dict[str, float]:
        """Mean observed participation fraction per level name."""
        return {n: sum(d) / len(d)
                for n, d in self._obs_fracs.items() if d}

    @property
    def modeled_round_wall_s(self) -> float:
        """The calibrated COMM bill of one round of ``plan``: billable
        reduction count x scheduled wall per level (no SGD compute —
        compare against ``observed_wall_s`` knowing measured walls
        include the compute the model does not bill)."""
        counts = dict(self.plan.counts_per_round())
        return sum(counts[lvl.name] * c
                   for lvl, c in zip(self.plan.levels, self._level_costs))

    def wall_bias(self) -> Optional[float]:
        """measured / modeled round wall (None until observed); the
        ratio a re-planner would scale the analytic bill by."""
        w = self.observed_wall_s
        m = self.modeled_round_wall_s
        return None if (w is None or m <= 0.0) else w / m
