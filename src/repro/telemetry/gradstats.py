"""Device-side gradient/parameter statistics inside the jitted round.

Everything here is a cheap ``jnp`` reduction added to the round program
behind the opt-in ``telemetry=`` knob on ``make_hier_round`` — pure
OBSERVERS: no statistic ever writes back into params/opt_state/EF, so a
telemetry-on round is bit-identical in losses to telemetry-off
(benchmarks/bench_telemetry.py gates this on the serial, pipelined, and
fsdp=2 engines).  The stats land as extra scalar keys in the round's
metrics dict (each outer ``lax.scan`` stacks them; the round's final
``tree.map(mean)`` collapses them to per-round means):

* ``telemetry/div_pre/<level>`` / ``div_post/<level>`` — mean over the
  level's learners of the squared distance to the level-group mean,
  summed over the parameter tree.  ``div_pre`` is the paper's Theorem
  3.2 pre-average discrepancy (the quantity Local SGD analyses bound —
  Stich 1805.09767); ``div_post`` shows what the reduction left behind
  (0 for an exact mean, > 0 under lossy codecs);
* ``telemetry/grad_norm_var/<level>`` — cross-learner variance of the
  per-learner squared gradient norm within the level's averaging
  groups: the Adaptive Periodic Averaging trigger signal (Jiang &
  Agrawal 2007.06134 — stretch periods when gradients agree, shrink
  when they diverge), plus ``telemetry/grad_sq_norm`` (fleet mean);
* ``telemetry/ef_mass/<level>`` — squared mass of the level's
  error-feedback residual (the untransmitted delta a sparse codec
  carries forward);
* ``telemetry/codec_err/<level>`` — relative squared error of the
  post-reduction params against the exact dense group mean of the
  pre-reduction params: the compression error the level's codec
  actually introduced this fire (~0 for the identity mean).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """Which device-side statistics the round computes (all on by
    default; each adds a handful of fused reductions per level fire)."""

    divergence: bool = True     # div_pre / div_post per level
    grad_var: bool = True       # grad_norm_var per level + grad_sq_norm
    ef_mass: bool = True        # EF residual mass per stateful level
    codec_err: bool = True      # codec error vs the exact dense mean


TelemetryKnob = Union[None, bool, TelemetryConfig]


def resolve_telemetry(knob: TelemetryKnob) -> Optional[TelemetryConfig]:
    """``None``/``False`` -> off; ``True`` -> all stats; a
    :class:`TelemetryConfig` passes through."""
    if knob is None or knob is False:
        return None
    if knob is True:
        return TelemetryConfig()
    if isinstance(knob, TelemetryConfig):
        return knob
    raise TypeError(
        f"telemetry= wants None/bool/TelemetryConfig, got {knob!r}")


def _learner_axes(x: jax.Array) -> Tuple[int, ...]:
    # stacked-learner layout: leaves are [pods, G, S, *shape]
    return tuple(range(3, x.ndim))


def group_divergence(params: Any, axes: Sequence[int]) -> jax.Array:
    """Mean over learners of ||w_j - mean_group(w)||^2, summed over the
    tree — the Thm-3.2 discrepancy at a level whose groups are the
    stacked ``axes``.  fp32 accumulation regardless of param dtype."""
    tot = jnp.zeros((), jnp.float32)
    for leaf in jax.tree.leaves(params):
        x = leaf.astype(jnp.float32)
        d = jnp.square(x - x.mean(axis=tuple(axes), keepdims=True))
        tot = tot + d.sum(axis=_learner_axes(x)).mean()
    return tot


def codec_error(post: Any, pre: Any, axes: Sequence[int]) -> jax.Array:
    """Relative squared error of the reduced params vs the exact dense
    group mean of the pre-reduction params, over the whole tree."""
    num = jnp.zeros((), jnp.float32)
    den = jnp.zeros((), jnp.float32)
    for p_leaf, q_leaf in zip(jax.tree.leaves(post), jax.tree.leaves(pre)):
        m = q_leaf.astype(jnp.float32).mean(axis=tuple(axes),
                                            keepdims=True)
        num = num + jnp.square(p_leaf.astype(jnp.float32) - m).sum()
        den = den + jnp.square(jnp.broadcast_to(m, p_leaf.shape)).sum()
    return num / (den + jnp.float32(1e-30))


def ef_mass(level_state: Any) -> jax.Array:
    """Squared mass of a level's error-feedback residual.  Sparse/qint8
    EF states carry the untransmitted residual in ``.err``; for other
    stateful reducers every float leaf counts (int leaves — top-k keys,
    counters — are skipped)."""
    src = getattr(level_state, "err", level_state)
    tot = jnp.zeros((), jnp.float32)
    for leaf in jax.tree.leaves(src):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            tot = tot + jnp.square(leaf.astype(jnp.float32)).sum()
    return tot


def level_stats(cfg: TelemetryConfig, level: Any, pre_params: Any,
                post_params: Any, comm_state: Any
                ) -> Dict[str, jax.Array]:
    """The per-fire statistics of one reduction at ``level`` (a
    ReductionLevel): pre/post divergence, codec error, EF mass."""
    out: Dict[str, jax.Array] = {}
    if cfg.divergence:
        out[f"telemetry/div_pre/{level.name}"] = \
            group_divergence(pre_params, level.axes)
        out[f"telemetry/div_post/{level.name}"] = \
            group_divergence(post_params, level.axes)
    if cfg.codec_err:
        out[f"telemetry/codec_err/{level.name}"] = \
            codec_error(post_params, pre_params, level.axes)
    if (cfg.ef_mass and level.reducer.stateful
            and isinstance(comm_state, dict)
            and level.name in comm_state):
        out[f"telemetry/ef_mass/{level.name}"] = \
            ef_mass(comm_state[level.name])
    return out


def make_grad_observer(cfg: Optional[TelemetryConfig],
                       levels: Sequence[Any]
                       ) -> Optional[Callable[[Any], Dict]]:
    """Observer the SGD step calls on the (stacked, fp32-accumulated)
    per-learner gradients: per-level within-group variance of the
    per-learner squared gradient norm — the Jiang & Agrawal period
    trigger — plus the fleet-mean squared norm."""
    if cfg is None or not cfg.grad_var:
        return None

    def observe(grads: Any) -> Dict[str, jax.Array]:
        sq = jnp.zeros((), jnp.float32)
        for leaf in jax.tree.leaves(grads):
            g = leaf.astype(jnp.float32)
            sq = sq + jnp.square(g).sum(axis=_learner_axes(g))
        out = {"telemetry/grad_sq_norm": sq.mean()}
        for lvl in levels:
            m = sq.mean(axis=tuple(lvl.axes), keepdims=True)
            out[f"telemetry/grad_norm_var/{lvl.name}"] = \
                jnp.square(sq - m).mean()
        return out

    return observe
