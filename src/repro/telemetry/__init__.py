"""Runtime telemetry: structured metrics rows, span tracing, and
device-side gradient statistics.

Three layers, composable and individually optional:

* :mod:`repro.telemetry.metrics` — :class:`MetricsLogger`: typed
  counter/gauge/histogram channels plus schema-versioned structured
  rows (JSONL sink + in-memory ring buffer);
* :mod:`repro.telemetry.spans` — :class:`SpanTracer`: host-side span
  timers with ``block_until_ready`` fencing, Chrome-trace export
  (Perfetto-viewable), optional ``jax.profiler`` bracketing;
* :mod:`repro.telemetry.gradstats` — device-side statistics inside the
  jitted round behind ``make_hier_round(..., telemetry=)``: per-level
  parameter divergence, gradient-norm variance, EF residual mass,
  codec compression error.

First consumer: ``repro.autotune.CostAwarePlan.observe`` ingests
``train_round`` rows to compare measured against modeled round walls.
"""
from repro.telemetry.gradstats import (TelemetryConfig, codec_error,
                                       ef_mass, group_divergence,
                                       level_stats, make_grad_observer,
                                       resolve_telemetry)
from repro.telemetry.metrics import (ROW_SCHEMAS, SCHEMA_VERSION,
                                     MetricsLogger, validate_jsonl)
from repro.telemetry.spans import SpanTracer

__all__ = [
    "MetricsLogger", "SpanTracer", "TelemetryConfig", "ROW_SCHEMAS",
    "SCHEMA_VERSION", "validate_jsonl", "resolve_telemetry",
    "group_divergence", "codec_error", "ef_mass", "level_stats",
    "make_grad_observer",
]
