"""Host-side span timers with device fencing, Chrome-trace export.

:class:`SpanTracer` decomposes a training round into phases the host
can honestly time:

* ``data`` — batch construction / reshaping;
* ``device`` — dispatch + device execution.  JAX dispatch is async, so
  a span that merely *calls* a jitted function measures dispatch only;
  call :meth:`SpanTracer.fence` on the results INSIDE the span to
  ``block_until_ready`` and bill the device wait where it belongs;
* ``host_sync`` — the device→host transfer (``jax.device_get``).

One fused jit program cannot be decomposed from the host (XLA:CPU has
no per-op timeline), so the compute / compress / collective split
inside the device span is attached as MODELED child spans
(:meth:`add_modeled_children`, ``cat="modeled"``) priced by
``theory.level_reduction_seconds`` — clearly labeled so nobody mistakes
an analytic bill for a measurement.  For real device profiles, pass
``profile_dir`` (the ``--profile-dir`` flag): spans are then bracketed
by ``jax.profiler`` trace annotations inside a
``jax.profiler.start_trace`` session, viewable in TensorBoard/Perfetto
alongside the XLA op timeline.

Export is the Chrome trace-event format (``{"traceEvents": [...]}``,
complete events, microsecond timestamps) — drop ``trace.json`` onto
https://ui.perfetto.dev to view.  Nesting is enforced by the context-
manager stack, so child spans are always contained in their parent's
[ts, ts+dur] interval (the property tests/test_telemetry.py pins).
"""
from __future__ import annotations

import json
import time
from contextlib import contextmanager
from typing import Any, Dict, List, Optional


class SpanTracer:
    """Collects host-side spans; optionally brackets them with
    ``jax.profiler`` annotations when ``profile_dir`` is set."""

    def __init__(self, profile_dir: Optional[str] = None):
        self.profile_dir = profile_dir
        self.spans: List[Dict[str, Any]] = []
        self._stack: List[Dict[str, Any]] = []
        self._t0 = time.perf_counter()
        self._profiling = False

    # ------------------------------------------------------------ #

    def _now(self) -> float:
        return time.perf_counter() - self._t0

    @contextmanager
    def span(self, name: str, cat: str = "host",
             args: Optional[Dict[str, Any]] = None):
        """Time a phase.  Yields the span record; on exit it carries
        ``ts``/``dur`` (seconds relative to tracer start)."""
        rec = {"name": name, "cat": cat, "ts": self._now(), "dur": 0.0,
               "depth": len(self._stack), "args": dict(args or {})}
        self._stack.append(rec)
        ann = None
        if self._profiling:
            import jax
            ann = jax.profiler.TraceAnnotation(name)
            ann.__enter__()
        try:
            yield rec
        finally:
            if ann is not None:
                ann.__exit__(None, None, None)
            self._stack.pop()
            rec["dur"] = self._now() - rec["ts"]
            self.spans.append(rec)

    def fence(self, value: Any) -> None:
        """``block_until_ready`` on ``value`` so the enclosing span is
        billed the device wait, not just the async dispatch."""
        import jax
        jax.block_until_ready(value)

    def add_modeled_children(self, parent: Dict[str, Any],
                             phases: List
                             ) -> None:
        """Attach analytic child spans ``[(name, dur_s), ...]`` laid out
        sequentially from ``parent``'s start, ``cat="modeled"`` — the
        per-level compute/compress/collective decomposition the host
        cannot measure inside one fused jit program."""
        t = parent["ts"]
        for name, dur in phases:
            self.spans.append({
                "name": name, "cat": "modeled", "ts": t,
                "dur": float(dur), "depth": parent["depth"] + 1,
                "args": {"modeled": True}})
            t += float(dur)

    # ------------------------------------------------------------ #
    # jax.profiler bracketing (--profile-dir)

    def start_profiler(self) -> None:
        if self.profile_dir and not self._profiling:
            import jax
            jax.profiler.start_trace(self.profile_dir)
            self._profiling = True

    def stop_profiler(self) -> None:
        if self._profiling:
            import jax
            jax.profiler.stop_trace()
            self._profiling = False

    # ------------------------------------------------------------ #

    def export_chrome_trace(self, path: str) -> None:
        """Write the collected spans as a Chrome trace-event file."""
        events: List[Dict[str, Any]] = [{
            "name": "process_name", "ph": "M", "pid": 0,
            "args": {"name": "repro host"}}]
        for s in self.spans:
            events.append({
                "name": s["name"], "cat": s["cat"], "ph": "X",
                "ts": round(s["ts"] * 1e6, 3),
                "dur": round(s["dur"] * 1e6, 3),
                "pid": 0, "tid": 0, "args": s["args"]})
        with open(path, "w") as f:
            json.dump({"traceEvents": events, "displayTimeUnit": "ms"},
                      f)
