"""Structured runtime metrics: typed channels + per-round rows.

:class:`MetricsLogger` is the host-side half of the telemetry plane
(the device-side half is gradstats.py).  It carries three typed
channels —

* **counters** — monotonically increasing integers (``rounds``,
  ``refill_events``);
* **gauges** — last-write-wins floats (``pages_in_use``);
* **histograms** — bounded reservoirs summarized as
  count/mean/min/p50/p95/max (``round_wall_s``);

— and a structured **row** stream: one dict per event (train round,
serve step, serve summary), stamped with ``schema_version`` and
validated against the frozen per-subsystem key schema in
:data:`ROW_SCHEMAS`.  Rows land in an in-memory ring buffer (cheap to
keep on; consumers like ``CostAwarePlan.observe`` read it back) and,
when a path is given, a JSONL file sink with buffered writes (one
``write()`` per ``flush_every`` rows, not per row — the sink must never
become the per-round host-sync hotspot it exists to measure).

Schema stability is a compatibility contract: removing a key from a
subsystem's REQUIRED set, or renaming a subsystem, breaks downstream
readers (CI's JSONL smoke, dashboards) — bump :data:`SCHEMA_VERSION`
and keep a migration note here when you must.  ADDING optional keys is
always safe; rows may carry any extras beyond the required set.

Non-finite floats are serialized as ``null`` so the JSONL stays strict
JSON (``json.load`` everywhere, not just Python).
"""
from __future__ import annotations

import json
import math
from collections import deque
from typing import Any, Dict, Iterator, List, Optional

import numpy as np

# bump on any backwards-incompatible row change (key removal/rename);
# see module docstring
SCHEMA_VERSION = 1

# frozen REQUIRED keys per subsystem — the golden sets
# tests/test_telemetry.py pins and ci.yml's JSONL smoke checks.
# ``schema_version``/``subsystem`` are stamped by log_row itself.
ROW_SCHEMAS: Dict[str, frozenset] = {
    # one row per training round (core/simulator.py, launch/train.py)
    "train_round": frozenset({
        "schema_version", "subsystem", "round", "loss", "wall_s"}),
    # one row per decode step of the paged serving engine
    "serve_step": frozenset({
        "schema_version", "subsystem", "step", "active_slots",
        "occupancy", "new_tokens", "pages_in_use"}),
    # one row per serve_queue call (both engines)
    "serve_summary": frozenset({
        "schema_version", "subsystem", "engine", "requests", "tokens",
        "decode_steps", "wall_s", "tokens_per_s", "wasted_ratio",
        "refill_events", "peak_pages_in_use"}),
}


def _jsonify(v: Any) -> Any:
    """Plain-JSON view of a row value: numpy scalars/arrays unwrapped,
    non-finite floats to null (strict-JSON portability)."""
    if isinstance(v, (np.generic,)):
        v = v.item()
    if isinstance(v, np.ndarray):
        return [_jsonify(x) for x in v.tolist()]
    if isinstance(v, float) and not math.isfinite(v):
        return None
    if isinstance(v, dict):
        return {k: _jsonify(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonify(x) for x in v]
    return v


def _summary(values: List[float]) -> Dict[str, float]:
    a = np.asarray(values, dtype=np.float64)
    return {"count": int(a.size), "mean": float(a.mean()),
            "min": float(a.min()), "p50": float(np.percentile(a, 50)),
            "p95": float(np.percentile(a, 95)), "max": float(a.max())}


class MetricsLogger:
    """Typed metric channels + a structured row stream.

    ``jsonl_path`` — optional JSONL sink (one JSON object per line).
    ``ring`` — in-memory row capacity (oldest rows evicted).
    ``flush_every`` — rows buffered between file writes.

    Usable as a context manager; ``close()`` flushes the sink.
    """

    def __init__(self, jsonl_path: Optional[str] = None, *,
                 ring: int = 1024, flush_every: int = 16):
        self.jsonl_path = jsonl_path
        self.ring: deque = deque(maxlen=ring)
        self.counters: Dict[str, int] = {}
        self.gauges: Dict[str, float] = {}
        self._hists: Dict[str, List[float]] = {}
        self._hist_cap = 4096
        self._flush_every = max(1, flush_every)
        self._buf: List[str] = []
        self._file = open(jsonl_path, "w") if jsonl_path else None
        self._seq = 0

    # ------------------------------------------------------------ #
    # typed channels

    def count(self, name: str, inc: int = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + int(inc)

    def gauge(self, name: str, value: float) -> None:
        self.gauges[name] = float(value)

    def histogram(self, name: str, value: float) -> None:
        h = self._hists.setdefault(name, [])
        if len(h) < self._hist_cap:      # bounded reservoir
            h.append(float(value))

    def snapshot(self) -> Dict[str, Any]:
        """Point-in-time view of every typed channel."""
        return {"counters": dict(self.counters),
                "gauges": dict(self.gauges),
                "histograms": {k: _summary(v)
                               for k, v in self._hists.items() if v}}

    # ------------------------------------------------------------ #
    # structured rows

    def log_row(self, subsystem: str, **fields: Any) -> Dict[str, Any]:
        """Emit one structured row; returns the stamped dict.

        Raises ``ValueError`` on an unknown subsystem or a missing
        required key (ROW_SCHEMAS) — a malformed producer should fail
        loudly at the write, not in a downstream reader.
        """
        if subsystem not in ROW_SCHEMAS:
            raise ValueError(
                f"unknown telemetry subsystem {subsystem!r}; known: "
                f"{sorted(ROW_SCHEMAS)}")
        row = {"schema_version": SCHEMA_VERSION, "subsystem": subsystem,
               "seq": self._seq}
        self._seq += 1
        row.update(fields)
        missing = ROW_SCHEMAS[subsystem] - row.keys()
        if missing:
            raise ValueError(
                f"{subsystem} row missing required keys {sorted(missing)}")
        self.ring.append(row)
        if self._file is not None:
            self._buf.append(json.dumps(_jsonify(row)))
            if len(self._buf) >= self._flush_every:
                self.flush()
        return row

    def rows(self, subsystem: Optional[str] = None
             ) -> Iterator[Dict[str, Any]]:
        for row in self.ring:
            if subsystem is None or row["subsystem"] == subsystem:
                yield row

    # ------------------------------------------------------------ #

    def flush(self) -> None:
        if self._file is not None and self._buf:
            self._file.write("\n".join(self._buf) + "\n")
            self._file.flush()
            self._buf = []

    def close(self) -> None:
        self.flush()
        if self._file is not None:
            self._file.close()
            self._file = None

    def __enter__(self) -> "MetricsLogger":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def validate_jsonl(path: str) -> List[Dict[str, Any]]:
    """Load and validate a metrics JSONL file.

    Every line must parse as a JSON object carrying ``schema_version``,
    a known ``subsystem``, and that subsystem's full required key set —
    the contract ci.yml's ``--metrics-out`` smoke enforces.  Returns the
    rows; raises ``ValueError`` with the offending line number otherwise.
    """
    rows: List[Dict[str, Any]] = []
    with open(path) as f:
        for i, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                row = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i}: unparseable JSONL: {e}")
            if not isinstance(row, dict):
                raise ValueError(f"{path}:{i}: row is not an object")
            sub = row.get("subsystem")
            if sub not in ROW_SCHEMAS:
                raise ValueError(
                    f"{path}:{i}: unknown subsystem {sub!r}")
            if row.get("schema_version") != SCHEMA_VERSION:
                raise ValueError(
                    f"{path}:{i}: schema_version "
                    f"{row.get('schema_version')!r} != {SCHEMA_VERSION}")
            missing = ROW_SCHEMAS[sub] - row.keys()
            if missing:
                raise ValueError(
                    f"{path}:{i}: {sub} row missing {sorted(missing)}")
            rows.append(row)
    return rows
