"""Fused absmax + quantize + pack for the qint8 codec — one pass over
the bucket, ONE contiguous wire buffer per bucket.

The legacy path (comm/quant.py ``quantize_block``/``dequantize_block``)
is two-pass and two-message: an absmax reduction materializes a
``[rows, nb]`` fp32 scale array, a second pass quantizes, and the int8
payload and the fp32 scales ride the collective as SEPARATE arrays —
doubling the per-bucket message count that latency-dominated tiers pay
for (see ``LevelCost.messages``).

This kernel fuses the scan and packs both into a single int8 buffer:

    wire[rows, nb, block + 4]
      wire[..., :block]  int8 quantized values (one block per row)
      wire[..., block:]  the block's fp32 scale, bitcast to 4 int8 bytes

Quantization math is IDENTICAL to the legacy path — ``scale =
max|x| / 127`` clamped at 1e-12, ``q = clip(round(x / scale), ±127)`` —
and the scale bytes are a bitcast (not a cast), so pack→unpack is
bit-identical to quantize→dequantize; tests assert exact equality
against both the pure-jnp oracle (kernels/ref.py) and the legacy
two-pass functions.

Layout notes: one program per learner row, the row's ``[nb, block]``
block matrix resident in VMEM; the wrapper pads the trailing dim to a
whole number of blocks (zero padding quantizes to zero and is sliced
off after unpack — the scale of an all-zero block is the 1e-12 clamp,
never a divide-by-zero).  The ``block + 4`` minor dim is deliberately
NOT lane-aligned: it is the wire format, and the 4-byte scale tail per
block is the whole point — misaligned stores are a one-time relayout in
VMEM, paid once per bucket instead of a second HBM pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import compiler_params

_SCALE_BYTES = 4       # one fp32 scale per block, bitcast to int8[4]
_SCALE_FLOOR = 1e-12   # matches comm/quant.py quantize_block


def _pack_kernel(x_ref, out_ref, *, block: int):
    xb = x_ref[0].astype(jnp.float32)                     # [nb, block]
    scale = jnp.max(jnp.abs(xb), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, _SCALE_FLOOR)              # [nb, 1]
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    # fp32 -> int8[4] bitcast appends the byte dim: [nb] -> [nb, 4]
    sb = jax.lax.bitcast_convert_type(scale[:, 0], jnp.int8)
    out_ref[0, :, :block] = q
    out_ref[0, :, block:] = sb


def _unpack_kernel(w_ref, out_ref, *, block: int):
    w = w_ref[0]                                          # [nb, block+4]
    q = w[:, :block].astype(jnp.float32)
    # int8[nb, 4] -> fp32[nb]: the byte dim collapses
    scale = jax.lax.bitcast_convert_type(w[:, block:], jnp.float32)
    out_ref[0] = q * scale[:, None]


def qint8_pack(x: jax.Array, block: int, *,
               interpret: bool = False) -> jax.Array:
    """``[rows, n] -> int8 [rows, nb, block + 4]`` fused wire buffer
    (``nb = ceil(n / block)``; the final partial block is zero-padded)."""
    rows, n = x.shape
    nb = -(-n // block)
    xb = x.astype(jnp.float32)
    if nb * block != n:
        xb = jnp.pad(xb, ((0, 0), (0, nb * block - n)))
    xb = xb.reshape(rows, nb, block)
    return pl.pallas_call(
        functools.partial(_pack_kernel, block=block),
        grid=(rows,),
        in_specs=[pl.BlockSpec((1, nb, block), lambda r: (r, 0, 0))],
        out_specs=pl.BlockSpec((1, nb, block + _SCALE_BYTES),
                               lambda r: (r, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, nb, block + _SCALE_BYTES),
                                       jnp.int8),
        compiler_params=compiler_params(("parallel",)),
        interpret=interpret,
    )(xb)


def qint8_unpack(wire: jax.Array, n: int, *,
                 interpret: bool = False) -> jax.Array:
    """``int8 [rows, nb, block + 4] -> fp32 [rows, n]`` dequantize —
    inverse of :func:`qint8_pack` (padding tail sliced off)."""
    rows, nb, width = wire.shape
    block = width - _SCALE_BYTES
    out = pl.pallas_call(
        functools.partial(_unpack_kernel, block=block),
        grid=(rows,),
        in_specs=[pl.BlockSpec((1, nb, width), lambda r: (r, 0, 0))],
        out_specs=pl.BlockSpec((1, nb, block), lambda r: (r, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, nb, block), jnp.float32),
        compiler_params=compiler_params(("parallel",)),
        interpret=interpret,
    )(wire)
    return out.reshape(rows, nb * block)[:, :n]
