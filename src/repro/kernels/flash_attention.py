"""Blockwise online-softmax (flash) attention as a Pallas TPU kernel.

TPU-native design (not a CUDA port):
  * Block shapes are multiples of the (8, 128) VREG tile and the q/k blocks
    feed the 128x128 MXU: block_q/block_k default 128.
  * Grid = (batch*heads, q_blocks, kv_blocks) with the kv dimension iterated
    sequentially ("arbitrary") so the running (m, l, acc) softmax state lives
    in VMEM scratch across kv steps — the HBM->VMEM streaming schedule is
    expressed entirely through BlockSpec index maps.
  * GQA is expressed in the index map: the kv BlockSpec maps query-head
    index h -> kv-head h // group, so K/V are streamed once per kv head
    without materializing the head-repeated tensors in HBM.
  * Causal + sliding-window masks are applied inside the kernel with
    block-level iota; fully-masked kv blocks short-circuit via pl.when.

Validated against kernels/ref.py::flash_attention_ref with interpret=True
(CPU) across shape/dtype sweeps (tests/test_kernels.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params

NEG_INF = -1.0e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: int, block_q: int,
                 block_k: int, kv_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    # skip kv blocks that are entirely in the future (causal) or entirely
    # fallen out of the sliding window
    run = jnp.bool_(True)
    if causal:
        run &= k_start <= q_start + block_q - 1
    if window:
        # newest query in this block is q_start+block_q-1; the oldest key it
        # can see is q_start - (window - 1)
        run &= k_start + block_k - 1 >= q_start - (window - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)               # [bq, d]
        k = k_ref[0].astype(jnp.float32)               # [bk, d]
        v = v_ref[0].astype(jnp.float32)               # [bk, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [bq, bk]

        if causal or window:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 1)
            mask = jnp.ones((block_q, block_k), jnp.bool_)
            if causal:
                mask &= kpos <= qpos
            if window:
                mask &= (qpos - kpos) < window
            s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                            # [bq, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == kv_blocks - 1)
    def _finalize():
        l = l_scr[...]
        o_ref[0] = (acc_scr[...] / jnp.where(l == 0.0, 1.0, l)
                    ).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    scale: Optional[float] = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False) -> jax.Array:
    """q [B,S,Hq,D]; k/v [B,T,Hkv,D] -> [B,S,Hq,D].

    S must be divisible by block_q and T by block_k (callers pad; the sweep
    tests cover the aligned shapes the models produce).
    """
    b, s, hq, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    assert s % block_q == 0 and t % block_k == 0, (s, t, block_q, block_k)
    if scale is None:
        scale = 1.0 / float(d) ** 0.5

    # [B, S, H, D] -> [B*H, S, D] so the grid's first axis is batch*heads
    qr = q.transpose(0, 2, 1, 3).reshape(b * hq, s, d)
    kr = k.transpose(0, 2, 1, 3).reshape(b * hkv, t, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * hkv, t, d)

    q_blocks = s // block_q
    kv_blocks = t // block_k
    grid = (b * hq, q_blocks, kv_blocks)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        # query head bh = bi*hq + h attends kv head h // group
        bi = bh // hq
        h = bh % hq
        return (bi * hkv + h // group, ki, 0)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, kv_blocks=kv_blocks)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), q_map),
            pl.BlockSpec((1, block_k, d), kv_map),
            pl.BlockSpec((1, block_k, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), q_map),
        out_shape=jax.ShapeDtypeStruct((b * hq, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=compiler_params(
            ("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr)

    return out.reshape(b, hq, s, d).transpose(0, 2, 1, 3)
