"""Public jit'd wrappers for the Pallas kernels with impl dispatch.

``impl``:
  * "xla"              — pure-jnp oracle (kernels/ref.py); default on CPU
  * "pallas"           — compiled Pallas kernel (TPU target)
  * "pallas_interpret" — Pallas kernel body interpreted in Python on CPU
                         (correctness validation without hardware)
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as kref


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: Optional[float] = None, impl: str = "xla",
                    interpret: Optional[bool] = None,
                    block_q: int = 128, block_k: int = 128) -> jax.Array:
    """Dispatchable attention: q [B,S,Hq,D], k/v [B,T,Hkv,D]."""
    if interpret is not None:  # legacy call style from models.attention
        impl = "pallas_interpret" if interpret else "pallas"
    if impl == "xla":
        return kref.flash_attention_ref(q, k, v, causal=causal,
                                        window=window, scale=scale)
    from repro.kernels.flash_attention import flash_attention as fa
    return fa(q, k, v, causal=causal, window=window, scale=scale,
              block_q=block_q, block_k=block_k,
              interpret=(impl == "pallas_interpret"))


def flash_decode(q, k_pages, v_pages, block_tables, lengths, *,
                 window: int = 0, scale: Optional[float] = None,
                 impl: str = "auto") -> jax.Array:
    """Dispatchable paged decode attention (the serving hot path).

    q [B, Hq, D] — one query token per sequence; k_pages/v_pages
    [Hkv, P, page, D] — the paged pool; block_tables [B, max_pages]
    int32; lengths [B] int32 (valid tokens per sequence incl. the query).

    ``impl="auto"`` picks the compiled Pallas kernel
    (kernels/flash_decode.py) on a TPU backend and the XLA gather oracle
    (kernels/ref.py::flash_decode_ref) everywhere else — same fallback
    contract as ``topk_compress``'s ``compaction="auto"``:
    ``"pallas_interpret"`` runs the kernel body in Python on CPU for
    correctness validation without hardware.
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "xla":
        return kref.flash_decode_ref(q, k_pages, v_pages, block_tables,
                                     lengths, window=window, scale=scale)
    from repro.kernels.flash_decode import flash_decode as fd
    return fd(q, k_pages, v_pages, block_tables, lengths, window=window,
              scale=scale, interpret=(impl == "pallas_interpret"))


def topk_compress(x, k: int, *, impl: str = "xla", block_n: int = 1024,
                  compaction: str = "auto") -> Tuple[jax.Array, jax.Array]:
    """Dispatchable magnitude top-k selection: x [rows, n] ->
    (values [rows, k], indices [rows, k] int32, ascending per row).

    With bucketed reductions (comm/bucket.py) a row is one whole flat
    bucket per learner — one tiled kernel pass instead of a ragged launch
    per leaf.  ``compaction`` picks the Pallas compaction engine
    (kernels/topk_compress.py): ``"scan"`` does O(n * block_n) work per
    row — independent of k — via per-chunk cumsum + carried-offset
    stores, and keeps indices in int32 so rows of any length are exact;
    the legacy ``"onehot"`` engine does O(n * k) matmul scatters and
    round-trips indices through fp32, capping rows at 2**24 elements —
    that cap is enforced here, on the legacy path only.  The default
    ``"auto"`` picks whichever tiles cheaper: ``"onehot"`` while
    ``k < block_n`` and the row is under the legacy cap (its [block_n, k]
    tile beats scan's fixed [block_n, block_n]), ``"scan"`` for large k
    or long rows.
    """
    if impl == "xla":
        return kref.topk_compress_ref(x, k)
    n = x.shape[-1]
    if compaction == "auto":
        compaction = "onehot" if (k < block_n and n < 2 ** 24) else "scan"
    elif compaction == "onehot" and n >= 2 ** 24:
        raise ValueError(
            f"pallas topk_compress compaction='onehot' caps rows at 2**24 "
            f"elements (indices accumulate in fp32), got x shape "
            f"{tuple(x.shape)} (n={n}); use compaction='scan', lower "
            f"HierAvgParams.bucket_bytes, or fall back to impl='xla'")
    from repro.kernels.topk_compress import topk_compress as tk
    return tk(x, k, block_n=block_n, compaction=compaction,
              interpret=(impl == "pallas_interpret"))


def batched_qr(p, *, impl: str = "auto") -> jax.Array:
    """Dispatchable batched thin-QR Q factor: ``[..., a, r] -> Q``.

    PowerSGD's orthonormalization hot path (comm/lowrank.py): one CGS2
    program per flattened ``[pods, G, S]`` learner row on TPU
    (kernels/batched_qr.py), the LAPACK/Householder ``jnp.linalg.qr``
    oracle elsewhere.  ``impl="auto"`` follows the ``flash_decode``
    convention: compiled Pallas on a TPU backend, XLA oracle everywhere
    else; ``"pallas_interpret"`` runs the kernel body in Python on CPU.
    Note the CGS2 kernel and the oracle agree on the projector
    ``Q Q^T``, not on per-column signs.
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "xla":
        return kref.batched_qr_ref(p)
    from repro.kernels.batched_qr import batched_qr as bqr
    return bqr(p, interpret=(impl == "pallas_interpret"))


def qint8_pack(x, block: int, *, impl: str = "auto") -> jax.Array:
    """Dispatchable fused quantize+pack: ``[rows, n] -> int8 [rows, nb,
    block + 4]`` — one contiguous wire buffer (payload + bitcast scales)
    so a qint8 bucket rides the collective as ONE message instead of
    two.  Bit-identical across impls (the scale bytes are a bitcast);
    ``impl="auto"`` = Pallas on TPU, oracle elsewhere.
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "xla":
        return kref.qint8_pack_ref(x, block)
    from repro.kernels.qint8_pack import qint8_pack as qp
    return qp(x, block, interpret=(impl == "pallas_interpret"))


def qint8_unpack(wire, n: int, *, impl: str = "auto") -> jax.Array:
    """Inverse of :func:`qint8_pack`: ``int8 [rows, nb, block + 4] ->
    fp32 [rows, n]``."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "xla":
        return kref.qint8_unpack_ref(wire, n)
    from repro.kernels.qint8_pack import qint8_unpack as qu
    return qu(wire, n, interpret=(impl == "pallas_interpret"))


def rwkv6_wkv(r, k, v, w, u, state, *, impl: str = "xla",
              block_t: int = 64) -> Tuple[jax.Array, jax.Array]:
    """Dispatchable WKV6: r/k/v/w [B,S,H,D], u [H,D], state [B,H,D,D]."""
    if impl == "xla":
        return kref.rwkv6_wkv_ref(r, k, v, w, u, state)
    from repro.kernels.rwkv6_wkv import rwkv6_wkv as wkv
    return wkv(r, k, v, w, u, state, block_t=block_t,
               interpret=(impl == "pallas_interpret"))
