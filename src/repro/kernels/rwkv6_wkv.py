"""RWKV-6 WKV recurrence as a chunked Pallas TPU kernel.

GPU implementations (e.g. the official CUDA wkv6 kernel) give each thread one
channel and loop serially over time in registers.  That shape does not map to
TPU; instead we:

  * keep the per-(batch, head) state matrix S [D, D] resident in VMEM
    scratch for the whole sequence,
  * stream r/k/v/w through VMEM in time-chunks of ``block_t`` via BlockSpec
    index maps (grid = (B*H, time_chunks), time sequential/"arbitrary"),
  * run the recurrence inside the chunk with a fori_loop over VMEM-resident
    rows — each step is rank-1 update + matvec on a [D, D] tile (D = 64 for
    the pool's RWKV config, one (8,128)-aligned VREG tile pair).

The chunk boundary state is also written out so callers can resume (decode).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref,
                s_scr, *, block_t: int, t_chunks: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _load_state():
        s_scr[...] = s0_ref[0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)          # [1, D] -> broadcast row
    r = r_ref[0].astype(jnp.float32)          # [block_t, D]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)

    def step(t, carry):
        y_acc = carry
        r_t = jax.lax.dynamic_slice_in_dim(r, t, 1, 0)   # [1, D]
        k_t = jax.lax.dynamic_slice_in_dim(k, t, 1, 0)
        v_t = jax.lax.dynamic_slice_in_dim(v, t, 1, 0)
        w_t = jax.lax.dynamic_slice_in_dim(w, t, 1, 0)
        S = s_scr[...]                                   # [D, D] (j, i)
        kv = k_t.T * v_t                                 # [D, D] rank-1
        # y[i] = sum_j r[j] (S[j,i] + u[j] kv[j,i])
        y_t = jax.lax.dot_general(
            r_t, S + u.T * kv, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)          # [1, D]
        s_scr[...] = w_t.T * S + kv
        y_acc = jax.lax.dynamic_update_slice_in_dim(y_acc, y_t, t, 0)
        return y_acc

    y = jax.lax.fori_loop(0, block_t, step,
                          jnp.zeros((block_t, r.shape[1]), jnp.float32))
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ti == t_chunks - 1)
    def _store_state():
        sT_ref[0] = s_scr[...]


def rwkv6_wkv(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
              u: jax.Array, state: jax.Array, *, block_t: int = 64,
              interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """r/k/v/w [B,S,H,D]; u [H,D]; state [B,H,D,D] -> (y [B,S,H,D], sT)."""
    b, s, h, d = r.shape
    block_t = min(block_t, s)
    assert s % block_t == 0, (s, block_t)
    t_chunks = s // block_t

    def bh(x):  # [B,S,H,D] -> [B*H, S, D]
        return x.transpose(0, 2, 1, 3).reshape(b * h, s, d)

    rr, kk, vv, ww = bh(r), bh(k), bh(v), bh(w)
    uu = jnp.broadcast_to(u[None], (b, h, d)).reshape(b * h, 1, d)
    s0 = state.reshape(b * h, d, d)

    seq_map = lambda i, ti: (i, ti, 0)
    fix_map = lambda i, ti: (i, 0, 0)

    kernel = functools.partial(_wkv_kernel, block_t=block_t,
                               t_chunks=t_chunks)
    y, sT = pl.pallas_call(
        kernel,
        grid=(b * h, t_chunks),
        in_specs=[
            pl.BlockSpec((1, block_t, d), seq_map),   # r
            pl.BlockSpec((1, block_t, d), seq_map),   # k
            pl.BlockSpec((1, block_t, d), seq_map),   # v
            pl.BlockSpec((1, block_t, d), seq_map),   # w
            pl.BlockSpec((1, 1, d), fix_map),          # u
            pl.BlockSpec((1, d, d), fix_map),          # s0
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, d), seq_map),
            pl.BlockSpec((1, d, d), fix_map),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, s, d), r.dtype),
            jax.ShapeDtypeStruct((b * h, d, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        compiler_params=compiler_params(("parallel", "arbitrary")),
        interpret=interpret,
    )(rr, kk, vv, ww, uu, s0)

    y = y.reshape(b, h, s, d).transpose(0, 2, 1, 3)
    return y, sT.reshape(b, h, d, d)
