"""Version compatibility for the Pallas TPU API surface.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``; all
kernels route through this shim so they build on either side of the rename.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu


def compiler_params(dimension_semantics):
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(dimension_semantics=tuple(dimension_semantics))
