"""Pure-jnp oracles for every Pallas kernel.

These are the semantics of record: kernel tests sweep shapes/dtypes and
assert_allclose against these functions, and the XLA model paths call them
directly (``impl="xla"``).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1.0e30


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        scale: Optional[float] = None) -> jax.Array:
    """Reference attention.

    q [B, S, Hq, D]; k/v [B, T, Hkv, D] with Hq % Hkv == 0.
    Returns [B, S, Hq, D] in q.dtype.
    """
    b, s, hq, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    if scale is None:
        scale = 1.0 / float(d) ** 0.5
    qg = q.reshape(b, s, hkv, g, d).astype(jnp.float32)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg,
                        k.astype(jnp.float32)) * scale
    if causal:
        qpos = jnp.arange(s)[:, None]
        kpos = jnp.arange(t)[None, :]
        m = kpos <= qpos
        if window:
            m &= (qpos - kpos) < window
        scores = jnp.where(m[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, hq, d).astype(q.dtype)


def gather_pages(pages: jax.Array, block_tables: jax.Array) -> jax.Array:
    """Materialize a paged pool as a dense per-sequence cache.

    pages [Hkv, P, page, D] (the serving pool layout: head-major so one
    kv head streams contiguously); block_tables [B, max_pages] int32 ->
    dense [B, max_pages * page, Hkv, D].  Entry ``j`` of the dense view is
    global cache position ``j`` because a sequence's block table lists its
    pages in position order.
    """
    hkv, _, page, d = pages.shape
    b, maxp = block_tables.shape
    g = pages[:, block_tables]                     # [Hkv, B, maxp, page, D]
    return g.transpose(1, 2, 3, 0, 4).reshape(b, maxp * page, hkv, d)


def flash_decode_ref(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                     block_tables: jax.Array, lengths: jax.Array, *,
                     window: int = 0,
                     scale: Optional[float] = None) -> jax.Array:
    """Reference paged decode attention (XLA gather path).

    One query token per sequence against a paged KV pool:
      q [B, Hq, D]; k_pages/v_pages [Hkv, P, page, D];
      block_tables [B, max_pages] int32; lengths [B] int32 — valid cache
      tokens per sequence INCLUDING the current one (the query sits at
      position lengths-1, already written into its page).

    Key j is visible iff j < lengths[b] and (window == 0 or
    lengths[b]-1 - j < window).  Sequences with lengths == 0 (inactive
    slots) produce zeros instead of NaN.  Returns [B, Hq, D] in q.dtype.
    """
    b, hq, d = q.shape
    hkv = k_pages.shape[0]
    g = hq // hkv
    if scale is None:
        scale = 1.0 / float(d) ** 0.5
    k = gather_pages(k_pages, block_tables)        # [B, T, Hkv, D]
    v = gather_pages(v_pages, block_tables)
    t = k.shape[1]
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    scores = jnp.einsum("bkgd,btkd->bkgt", qg,
                        k.astype(jnp.float32)) * scale
    kpos = jnp.arange(t)[None, :]
    valid = kpos < lengths[:, None]
    if window:
        valid &= (lengths[:, None] - 1 - kpos) < window
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    # all-masked rows (inactive slots): uniform probs would mix garbage,
    # so zero the output instead
    any_valid = valid.any(axis=1)[:, None, None, None]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", probs, v.astype(jnp.float32))
    out = jnp.where(any_valid, out, 0.0)
    return out.reshape(b, hq, d).astype(q.dtype)


def topk_compress_ref(x: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """Per-row magnitude top-k selection (the sparse-reducer hot path).

    x [rows, n] -> (values [rows, k] in x.dtype, indices [rows, k] int32).
    Indices are ascending per row (index order, not magnitude order), so the
    Pallas kernel's threshold+compaction pass produces identical output when
    the k-th magnitude is untied.
    """
    _, idx = jax.lax.top_k(jnp.abs(x.astype(jnp.float32)), k)
    idx = jnp.sort(idx, axis=-1)
    vals = jnp.take_along_axis(x, idx, axis=-1)
    return vals, idx.astype(jnp.int32)


def batched_qr_ref(p: jax.Array) -> jax.Array:
    """Batched thin-QR Q factor: ``[..., a, r] -> Q [..., a, r]``.

    XLA lowers this to one Householder QR per batch element (LAPACK on
    CPU).  Column signs follow LAPACK's convention; the Pallas CGS2
    kernel (kernels/batched_qr.py) may flip per-column signs, so parity
    tests compare the projector ``Q Q^T`` — the only quantity PowerSGD's
    reconstruction consumes — rather than the raw factor.
    """
    q, _ = jnp.linalg.qr(p.astype(jnp.float32))
    return q.astype(p.dtype)


_QINT8_SCALE_BYTES = 4


def qint8_pack_ref(x: jax.Array, block: int) -> jax.Array:
    """Fused quantize+pack oracle: ``[rows, n] -> int8 [rows, nb,
    block + 4]`` (int8 payload + bitcast fp32 scale per block — the wire
    format of kernels/qint8_pack.py).  Scale math is bit-identical to
    comm/quant.py ``quantize_block``; the zero-padded tail of the final
    partial block quantizes to zero.
    """
    rows, n = x.shape
    nb = -(-n // block)
    xb = x.astype(jnp.float32)
    if nb * block != n:
        xb = jnp.pad(xb, ((0, 0), (0, nb * block - n)))
    xb = xb.reshape(rows, nb, block)
    scale = jnp.max(jnp.abs(xb), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    sb = jax.lax.bitcast_convert_type(scale[..., 0], jnp.int8)
    return jnp.concatenate([q, sb], axis=-1)


def qint8_unpack_ref(wire: jax.Array, n: int) -> jax.Array:
    """Inverse of :func:`qint8_pack_ref`: ``int8 [rows, nb, block + 4]
    -> fp32 [rows, n]`` (padding tail sliced off)."""
    rows, nb, width = wire.shape
    block = width - _QINT8_SCALE_BYTES
    q = wire[..., :block].astype(jnp.float32)
    scale = jax.lax.bitcast_convert_type(wire[..., block:], jnp.float32)
    return (q * scale[..., None]).reshape(rows, nb * block)[:, :n]


def rwkv6_wkv_ref(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                  u: jax.Array, state: jax.Array
                  ) -> Tuple[jax.Array, jax.Array]:
    """RWKV-6 WKV recurrence, scanned over time in fp32.

    r/k/v/w: [B, S, H, D]; u: [H, D]; state: [B, H, D, D] (indexed [j, i]).

        y_t[i]  = sum_j r_t[j] * (S[j,i] + u[j] * k_t[j] * v_t[i])
        S'[j,i] = w_t[j] * S[j,i] + k_t[j] * v_t[i]

    Returns (y [B, S, H, D] in r.dtype, final state fp32).
    """
    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    uf = u.astype(jnp.float32)

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp          # each [B, H, D]
        kv = k_t[..., :, None] * v_t[..., None, :]          # [B,H,D,D]
        y = jnp.einsum("bhj,bhji->bhi", r_t,
                       S + uf[None, :, :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, y

    xs = tuple(x.swapaxes(0, 1) for x in (rf, kf, vf, wf))  # [S,B,H,D]
    final, ys = jax.lax.scan(step, state.astype(jnp.float32), xs)
    return ys.swapaxes(0, 1).astype(r.dtype), final
