"""Batched thin-QR as a Pallas TPU kernel — PowerSGD's orthonormalization
hot path (comm/lowrank.py ``_orthonormalize``).

The low-rank reducer needs the Q factor of a *tall-skinny* panel
``P = M Q_prev`` per learner: shape ``[rows, a, r]`` with ``rows`` the
flattened ``[pods, G, S]`` learner batch, ``a`` up to a bucket side
(hundreds..thousands) and ``r`` the PowerSGD rank (2..8).  XLA lowers
``jnp.linalg.qr`` to a per-matrix LAPACK/Householder custom call that
neither batches over learners nor fuses with the surrounding einsums —
on the per-leaf path it is the straggler that cannot bucket or pipeline.

TPU-native design: classical Gram-Schmidt with reorthogonalization
(CGS2), one program per batch row, the whole ``[a, r]`` panel held in
VMEM:

  * the q accumulator is ZERO-initialized, so projecting against the
    full q tile subtracts only the already-filled columns ``< j`` — the
    column loop needs no masking and the (lane-padded) columns past
    ``r`` stay zero;
  * each column does two projection passes (CGS2: a second pass restores
    orthogonality to fp32 working precision, where plain CGS loses it
    for ill-conditioned panels) — all VPU reductions over VMEM, no MXU;
  * a rank-deficient column (zero norm after projection) emits a ZERO
    column instead of dividing by ~0: for PowerSGD that contributes
    nothing to the approximation and the error-feedback residual
    re-accumulates the mass, whereas LAPACK would emit an arbitrary
    orthonormal completion direction.

Sign/convention caveat: CGS fixes each column's sign by the input
panel's, LAPACK by R's positive diagonal, so Q may differ from
``jnp.linalg.qr`` by per-column signs.  The *projector* ``Q Q^T`` — the
only thing PowerSGD's ``P^ Q'^T`` reconstruction consumes — is
convention-free; kernel tests compare projectors and orthonormality,
not raw factors (kernels/ref.py ``batched_qr_ref`` is the oracle).

Grid = (batch,): panels are padded to the fp32 sublane multiple (8) in
``a`` and to the lane multiple (128) in ``r``; zero-padding is exact
(zero rows contribute nothing to inner products, zero columns stay
zero) and is sliced off by the wrapper.

Validated against ``jnp.linalg.qr`` with interpret=True on CPU
(tests/test_kernels.py), including non-pow2 rows, tall/near-square
panels and GQA-style odd dims.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import compiler_params

_SUBLANE = 8      # fp32 second-minor tile multiple
_LANE = 128       # minor (lane) tile multiple
_EPS = 1e-30      # rank-deficiency floor on the squared column norm


def _qr_kernel(x_ref, q_ref, *, r: int):
    """One batch row: CGS2 over the ``r`` live columns of the panel."""
    q_ref[...] = jnp.zeros_like(q_ref)
    x = x_ref[0].astype(jnp.float32)                    # [a_pad, r_pad]
    for j in range(r):                                  # r is small: 2..8
        v = x[:, j:j + 1]                               # [a_pad, 1]
        for _ in range(2):                              # CGS2 passes
            q = q_ref[0]
            # coefficients against every filled column (cols >= j are
            # still zero, so they subtract nothing)
            c = jnp.sum(q * v, axis=0, keepdims=True)   # [1, r_pad]
            v = v - jnp.sum(q * c, axis=1, keepdims=True)
        nrm2 = jnp.sum(v * v)
        inv = jnp.where(nrm2 > _EPS, jax.lax.rsqrt(nrm2), 0.0)
        q_ref[0, :, j:j + 1] = v * inv


def batched_qr(p: jax.Array, *, interpret: bool = False) -> jax.Array:
    """Thin-QR Q factor over arbitrary leading batch dims:
    ``[..., a, r] -> Q [..., a, r]`` with ``a >= r`` (columns of a
    rank-deficient panel come back zero — see module docstring)."""
    *lead, a, r = p.shape
    if a < r:
        raise ValueError(
            f"batched_qr needs a tall panel (a >= r), got {tuple(p.shape)}")
    batch = math.prod(lead) if lead else 1
    x = p.reshape(batch, a, r).astype(jnp.float32)
    a_pad = -(-a // _SUBLANE) * _SUBLANE
    r_pad = -(-r // _LANE) * _LANE
    if (a_pad, r_pad) != (a, r):
        x = jnp.pad(x, ((0, 0), (0, a_pad - a), (0, r_pad - r)))

    q = pl.pallas_call(
        functools.partial(_qr_kernel, r=r),
        grid=(batch,),
        in_specs=[pl.BlockSpec((1, a_pad, r_pad), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, a_pad, r_pad), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, a_pad, r_pad), jnp.float32),
        compiler_params=compiler_params(("parallel",)),
        interpret=interpret,
    )(x)
    return q[:, :a, :r].reshape(p.shape).astype(p.dtype)
