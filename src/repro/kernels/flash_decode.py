"""Paged flash-decode attention as a Pallas TPU kernel.

The serving decode hot path: one query token per sequence against a paged
KV cache — fixed-size pages owned by a global pool, gathered per sequence
through a block table (serve/kvcache.py).  Reuses the online-softmax
blocking of kernels/flash_attention.py, adapted to the decode shape:

  * Grid = (batch, kv_heads, pages_per_seq).  The last axis is the
    **split-KV reduction over the cache length**: it is iterated
    sequentially ("arbitrary") and the running (m, l, acc) softmax state
    for the single query position lives in VMEM scratch across page
    steps, exactly like the kv axis of the prefill flash kernel.
  * The block table is a **scalar-prefetch** argument
    (pltpu.PrefetchScalarGridSpec): the K/V BlockSpec index map reads
    ``block_tables[b, i]`` to pick which physical page the next grid step
    streams from HBM — the gather never materializes a dense cache.
  * GQA is expressed in the grid: one program per (batch, kv head)
    handles all ``Hq // Hkv`` query heads of that group at once (they
    share the K/V stream), so K/V pages are read exactly once.
  * Pages past the sequence length short-circuit via ``pl.when``; the
    final partial page and the optional sliding window are masked with
    block-level iota.  Fully-masked sequences (inactive serving slots,
    ``lengths == 0``) output zeros.

Pool layout [Hkv, P, page, D] is head-major so a (page, D) tile streams
contiguously per kv head.  Validated against
kernels/ref.py::flash_decode_ref with interpret=True on CPU
(tests/test_kernels.py), auto-dispatched via kernels/ops.py.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params

NEG_INF = -1.0e30


def _decode_kernel(tables_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale: float, window: int,
                   page: int, pages_per_seq: int):
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    seq_len = lens_ref[b]                 # tokens incl. the query token
    base = i * page

    # skip pages entirely past the sequence end, and (with a sliding
    # window) pages that have entirely fallen out of the query's window
    # (query position = seq_len - 1)
    run = base < seq_len
    if window:
        run &= base + page - 1 >= seq_len - window

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # [G, D]
        k = k_ref[0, 0].astype(jnp.float32)          # [page, D]
        v = v_ref[0, 0].astype(jnp.float32)          # [page, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [G, page]

        kpos = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < seq_len
        if window:
            mask &= (seq_len - 1 - kpos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                          # [G, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(i == pages_per_seq - 1)
    def _finalize():
        l = l_scr[...]
        o_ref[0, 0] = (acc_scr[...] / jnp.where(l == 0.0, 1.0, l)
                       ).astype(o_ref.dtype)


def flash_decode(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                 block_tables: jax.Array, lengths: jax.Array, *,
                 window: int = 0, scale: Optional[float] = None,
                 interpret: bool = False) -> jax.Array:
    """q [B, Hq, D]; k_pages/v_pages [Hkv, P, page, D];
    block_tables [B, max_pages] int32 (page-order per sequence, null-page 0
    for unallocated tail entries); lengths [B] int32 incl. the query token.
    Returns [B, Hq, D] in q.dtype.
    """
    b, hq, d = q.shape
    hkv, _, page, _ = k_pages.shape
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    maxp = block_tables.shape[1]
    if scale is None:
        scale = 1.0 / float(d) ** 0.5

    qr = q.reshape(b, hkv, g, d)
    tables = block_tables.astype(jnp.int32)
    lens = lengths.astype(jnp.int32)

    def q_map(bi, h, i, tbl, ln):
        return (bi, h, 0, 0)

    def kv_map(bi, h, i, tbl, ln):
        return (h, tbl[bi, i], 0, 0)

    kernel = functools.partial(
        _decode_kernel, scale=float(scale), window=window, page=page,
        pages_per_seq=maxp)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, maxp),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), q_map),
            pl.BlockSpec((1, 1, page, d), kv_map),
            pl.BlockSpec((1, 1, page, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), q_map),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        compiler_params=compiler_params(
            ("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(tables, lens, qr, k_pages, v_pages)

    return out.reshape(b, hq, d)
