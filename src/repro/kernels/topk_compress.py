"""Fused top-k compression (flatten -> abs -> threshold -> gather) as a
Pallas TPU kernel — the sparse reducer's hot path (comm/sparse.py).

TPU-native design (no sort): an exact top-k via
  1. a 31-step binary search for the k-th magnitude in the fp32 *bit
     domain* — non-negative IEEE floats compare identically as int32, so
     building the threshold bit-by-bit distinguishes every representable
     magnitude (scale-free: a 1e8 outlier next to 1e-3 values costs no
     precision, unlike value-domain bisection) — pure VPU reductions over
     the row held in VMEM, then
  2. compaction of the selected coordinates in index order: a cumulative
     sum assigns each kept element its output slot and a chunked one-hot
     matmul ([block_n, k] per chunk, MXU-friendly) scatters values and
     indices into the [k]-wide outputs — no dynamic scatter needed.

Grid = (rows,): one program per learner-row, whole row in VMEM (the
per-leaf rows Hier-AVG produces are far below the ~16 MB VMEM budget; the
chunking bounds the one-hot to block_n*k words).  Ties at the k-th
magnitude resolve to the lowest indices, matching kernels/ref.py's oracle.

Caveat: the selection is bit-exact, but subnormal *values* (< ~1.2e-38)
flush to zero through the dot-product compaction (FTZ on the MXU and in the
XLA dot) — irrelevant for the EF reducer, whose residual re-accumulates
anything dropped.

Validated against ref.topk_compress_ref with interpret=True on CPU
(tests/test_kernels.py), including a heavy-tailed row (1e8 outlier next to
~1.0 values) that defeats value-domain bisection.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params

_BISECT_ITERS = 31   # one per magnitude bit of a non-negative fp32


def _topk_kernel(x_ref, vals_ref, idx_ref, *, n: int, k: int, block_n: int,
                 n_pad: int):
    x = x_ref[0, :].astype(jnp.float32)                     # [n_pad]
    gidx = jax.lax.broadcasted_iota(jnp.int32, (1, n_pad), 1)[0]
    # |x| >= 0 has sign bit 0, so its int32 bit pattern orders identically;
    # padding gets -1 (int32), below every candidate threshold
    bits = jnp.where(gidx < n,
                     jax.lax.bitcast_convert_type(jnp.abs(x), jnp.int32),
                     jnp.int32(-1))

    # -- exact k-th magnitude: build the largest threshold t (bit by bit,
    # high to low) such that count(bits >= t) >= k ----------------------- #
    def refine(i, t):
        cand = t | (1 << (30 - i))
        ok = jnp.sum(jnp.where(bits >= cand, 1, 0)) >= k
        return jnp.where(ok, cand, t)

    t = jax.lax.fori_loop(0, _BISECT_ITERS, refine, jnp.int32(0))

    # -- tie-exact selection: everything strictly above the k-th magnitude,
    # remaining slots filled with tied elements in index order — lax.top_k's
    # stable tie-break, so oracle and kernel agree even on tied (e.g. bf16)
    # magnitudes ---------------------------------------------------------- #
    gt = bits > t
    eq = bits == t
    fill = k - jnp.sum(gt.astype(jnp.int32))
    keep = gt | (eq & (jnp.cumsum(eq.astype(jnp.int32)) <= fill))
    slot = jnp.cumsum(keep.astype(jnp.int32)) - 1           # output position

    vals_ref[...] = jnp.zeros_like(vals_ref)
    idx_ref[...] = jnp.zeros_like(idx_ref)
    kcol = jax.lax.broadcasted_iota(jnp.int32, (block_n, k), 1)

    def chunk(c, _):
        def sl(v):
            return jax.lax.dynamic_slice_in_dim(v, c * block_n, block_n)

        # HIGHEST keeps the MXU passes in full fp32 — default precision
        # would truncate the float-encoded indices (and values) to bf16's
        # 8 mantissa bits on hardware
        onehot = jnp.where(
            (sl(slot)[:, None] == kcol) & sl(keep)[:, None], 1.0, 0.0)
        vals_ref[0, :] += jax.lax.dot_general(
            sl(x)[None, :], onehot, (((1,), (0,)), ((), ())),
            precision=jax.lax.Precision.HIGHEST,
            preferred_element_type=jnp.float32)[0]
        idx_ref[0, :] += jax.lax.dot_general(
            sl(gidx).astype(jnp.float32)[None, :], onehot,
            (((1,), (0,)), ((), ())),
            precision=jax.lax.Precision.HIGHEST,
            preferred_element_type=jnp.float32)[0]
        return 0

    jax.lax.fori_loop(0, n_pad // block_n, chunk, 0)


def topk_compress(x: jax.Array, k: int, *, block_n: int = 1024,
                  interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """x [rows, n] -> (values [rows, k] in x.dtype, indices [rows, k] int32,
    ascending per row).  Matches ref.topk_compress_ref exactly (ties at the
    k-th magnitude break to the lowest indices, like lax.top_k)."""
    rows, n = x.shape
    assert 1 <= k <= n, (k, n)
    assert n < 2 ** 24, "index compaction accumulates in fp32"
    block_n = min(block_n, n)
    n_pad = -(-n // block_n) * block_n
    if n_pad != n:
        x = jnp.pad(x, ((0, 0), (0, n_pad - n)))

    kernel = functools.partial(_topk_kernel, n=n, k=k, block_n=block_n,
                               n_pad=n_pad)
    vals, idxf = pl.pallas_call(
        kernel,
        grid=(rows,),
        in_specs=[pl.BlockSpec((1, n_pad), lambda r: (r, 0))],
        out_specs=[pl.BlockSpec((1, k), lambda r: (r, 0)),
                   pl.BlockSpec((1, k), lambda r: (r, 0))],
        out_shape=[jax.ShapeDtypeStruct((rows, k), jnp.float32),
                   jax.ShapeDtypeStruct((rows, k), jnp.float32)],
        compiler_params=compiler_params(("parallel",)),
        interpret=interpret,
    )(x)
    return vals.astype(x.dtype), idxf.astype(jnp.int32)
