"""Fused top-k compression (flatten -> abs -> threshold -> gather) as a
Pallas TPU kernel — the sparse reducer's hot path (comm/sparse.py).

TPU-native design (no global sort): an exact top-k via
  1. a 31-step binary search for the k-th magnitude in the fp32 *bit
     domain* — non-negative IEEE floats compare identically as int32, so
     building the threshold bit-by-bit distinguishes every representable
     magnitude (scale-free: a 1e8 outlier next to 1e-3 values costs no
     precision, unlike value-domain bisection) — pure VPU reductions over
     the row held in VMEM, then
  2. compaction of the selected coordinates in index order.  Two
     compaction engines:

     * ``compaction="scan"`` — per-chunk local cumsum assigns
       each kept element its slot *within the chunk*, a [block_n, block_n]
       one-hot contraction packs the chunk's survivors to the front, and a
       dynamic-slice store writes the packed (value, index) pairs at a
       *carried offset* (the running count of survivors) into the k-wide
       output; the next chunk's store overwrites the tail garbage.  Work
       is O(n * block_n) per row — independent of k — and indices are
       exact int32 (only the chunk-local offset, < block_n, rides the fp32
       contraction), so rows are no longer capped at 2^24 elements.
     * ``compaction="onehot"`` (legacy) — a chunked [block_n, k] one-hot
       matmul scatters values and float-encoded indices straight into the
       k-wide outputs: O(n * k) MXU work per row and an fp32 index
       round-trip capping rows at 2^24 elements.  Kept as the reference
       engine (kernels/ops.py gates its cap on this path only, and its
       "auto" default dispatches here while k < block_n under the cap —
       the [block_n, k] tile is cheaper than scan's fixed
       [block_n, block_n] for small k).

Grid = (rows,): one program per learner-row, whole row in VMEM (the
per-bucket rows Hier-AVG produces are sized by ``bucket_bytes`` to fit the
~16 MB VMEM budget; the chunking bounds each compaction tile to
block_n^2 words).  Ties at the k-th magnitude resolve to the lowest
indices, matching kernels/ref.py's oracle.

Caveat: the selection is bit-exact, but subnormal *values* (< ~1.2e-38)
flush to zero through the packing contraction (FTZ on the MXU and in the
XLA dot) — irrelevant for the EF reducer, whose residual re-accumulates
anything dropped.

Validated against ref.topk_compress_ref with interpret=True on CPU
(tests/test_kernels.py), including a heavy-tailed row (1e8 outlier next to
~1.0 values) that defeats value-domain bisection and a >2^24-element row
that defeats the legacy engine's fp32 index compaction.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params

_BISECT_ITERS = 31   # one per magnitude bit of a non-negative fp32


def _threshold_select(x, n: int, n_pad: int, k: int):
    """Shared selection logic: exact bit-domain k-th-magnitude bisection +
    the tie-exact keep mask (ties break to the lowest indices, matching
    lax.top_k).  Returns (gidx, keep) over the padded row."""
    gidx = jax.lax.broadcasted_iota(jnp.int32, (1, n_pad), 1)[0]
    # |x| >= 0 has sign bit 0, so its int32 bit pattern orders identically;
    # padding gets -1 (int32), below every candidate threshold
    bits = jnp.where(gidx < n,
                     jax.lax.bitcast_convert_type(jnp.abs(x), jnp.int32),
                     jnp.int32(-1))

    # -- exact k-th magnitude: build the largest threshold t (bit by bit,
    # high to low) such that count(bits >= t) >= k ----------------------- #
    def refine(i, t):
        cand = t | (1 << (30 - i))
        ok = jnp.sum(jnp.where(bits >= cand, 1, 0)) >= k
        return jnp.where(ok, cand, t)

    t = jax.lax.fori_loop(0, _BISECT_ITERS, refine, jnp.int32(0))

    # -- tie-exact selection: everything strictly above the k-th magnitude,
    # remaining slots filled with tied elements in index order — lax.top_k's
    # stable tie-break, so oracle and kernel agree even on tied (e.g. bf16)
    # magnitudes ---------------------------------------------------------- #
    gt = bits > t
    eq = bits == t
    fill = k - jnp.sum(gt.astype(jnp.int32))
    keep = gt | (eq & (jnp.cumsum(eq.astype(jnp.int32)) <= fill))
    return gidx, keep


def _topk_kernel_onehot(x_ref, vals_ref, idx_ref, *, n: int, k: int,
                        block_n: int, n_pad: int):
    """Legacy compaction: chunked [block_n, k] one-hot matmuls — O(n*k)
    MXU work per row, fp32 index accumulation (rows capped at 2^24)."""
    x = x_ref[0, :].astype(jnp.float32)                     # [n_pad]
    gidx, keep = _threshold_select(x, n, n_pad, k)
    slot = jnp.cumsum(keep.astype(jnp.int32)) - 1           # output position

    vals_ref[...] = jnp.zeros_like(vals_ref)
    idx_ref[...] = jnp.zeros_like(idx_ref)
    kcol = jax.lax.broadcasted_iota(jnp.int32, (block_n, k), 1)

    def chunk(c, _):
        def sl(v):
            return jax.lax.dynamic_slice_in_dim(v, c * block_n, block_n)

        # HIGHEST keeps the MXU passes in full fp32 — default precision
        # would truncate the float-encoded indices (and values) to bf16's
        # 8 mantissa bits on hardware
        onehot = jnp.where(
            (sl(slot)[:, None] == kcol) & sl(keep)[:, None], 1.0, 0.0)
        vals_ref[0, :] += jax.lax.dot_general(
            sl(x)[None, :], onehot, (((1,), (0,)), ((), ())),
            precision=jax.lax.Precision.HIGHEST,
            preferred_element_type=jnp.float32)[0]
        idx_ref[0, :] += jax.lax.dot_general(
            sl(gidx).astype(jnp.float32)[None, :], onehot,
            (((1,), (0,)), ((), ())),
            precision=jax.lax.Precision.HIGHEST,
            preferred_element_type=jnp.float32)[0]
        return 0

    jax.lax.fori_loop(0, n_pad // block_n, chunk, 0)


def _topk_kernel_scan(x_ref, vals_ref, idx_ref, *, n: int, k: int,
                      block_n: int, n_pad: int):
    """Scalable compaction: per-chunk local cumsum + carried offset.

    Each chunk packs its survivors to the front (slot = chunk-local
    cumsum; a [block_n, block_n] one-hot contraction, so the tile never
    scales with k) and stores the packed block at the carried offset via
    a dynamic-slice store.  Positions past this chunk's survivor count
    hold garbage that the NEXT chunk's store overwrites; the outputs are
    padded by one block (k_pad in the wrapper) so the final store never
    clamps back onto finished entries.  Global indices are rebuilt as
    ``chunk_base + local_offset`` in int32 — only the local offset
    (< block_n) rides the fp32 contraction, so arbitrarily long rows keep
    exact indices."""
    x = x_ref[0, :].astype(jnp.float32)                     # [n_pad]
    _, keep = _threshold_select(x, n, n_pad, k)

    vals_ref[...] = jnp.zeros_like(vals_ref)
    idx_ref[...] = jnp.zeros_like(idx_ref)
    pcol = jax.lax.broadcasted_iota(jnp.int32, (block_n, block_n), 1)
    liota = jax.lax.broadcasted_iota(jnp.int32, (1, block_n), 1)[0]

    def chunk(c, off):
        def sl(v):
            return jax.lax.dynamic_slice_in_dim(v, c * block_n, block_n)

        kc = sl(keep)
        lslot = jnp.cumsum(kc.astype(jnp.int32)) - 1        # local cumsum
        onehot = jnp.where((lslot[:, None] == pcol) & kc[:, None], 1.0, 0.0)
        packed_v = jax.lax.dot_general(
            sl(x)[None, :], onehot, (((1,), (0,)), ((), ())),
            precision=jax.lax.Precision.HIGHEST,
            preferred_element_type=jnp.float32)[0]          # [block_n]
        packed_l = jax.lax.dot_general(
            liota.astype(jnp.float32)[None, :], onehot,
            (((1,), (0,)), ((), ())),
            precision=jax.lax.Precision.HIGHEST,
            preferred_element_type=jnp.float32)[0]          # exact: <block_n
        packed_i = packed_l.astype(jnp.int32) + c * block_n
        vals_ref[0, pl.ds(off, block_n)] = packed_v
        idx_ref[0, pl.ds(off, block_n)] = packed_i
        return off + jnp.sum(kc.astype(jnp.int32))

    jax.lax.fori_loop(0, n_pad // block_n, chunk, jnp.int32(0))


def topk_compress(x: jax.Array, k: int, *, block_n: int = 1024,
                  interpret: bool = False,
                  compaction: str = "scan") -> Tuple[jax.Array, jax.Array]:
    """x [rows, n] -> (values [rows, k] in x.dtype, indices [rows, k] int32,
    ascending per row).  Matches ref.topk_compress_ref exactly (ties at the
    k-th magnitude break to the lowest indices, like lax.top_k).

    ``compaction="scan"`` is the k-independent carried-offset engine;
    ``"onehot"`` is the legacy O(n*k) matmul scatter (rows capped at
    2^24 elements — enforce via kernels/ops.py, whose "auto" default
    picks between them by k/block_n and row length).
    """
    rows, n = x.shape
    assert 1 <= k <= n, (k, n)
    block_n = min(block_n, n)
    n_pad = -(-n // block_n) * block_n
    if n_pad != n:
        x = jnp.pad(x, ((0, 0), (0, n_pad - n)))

    if compaction == "onehot":
        assert n < 2 ** 24, "onehot compaction accumulates indices in fp32"
        kernel = functools.partial(_topk_kernel_onehot, n=n, k=k,
                                   block_n=block_n, n_pad=n_pad)
        k_out = k
    elif compaction == "scan":
        kernel = functools.partial(_topk_kernel_scan, n=n, k=k,
                                   block_n=block_n, n_pad=n_pad)
        # one spare block: the last chunk's full-block store lands at
        # offset <= k, so the outputs carry block_n tail slots of garbage
        # that are sliced off below (never clamped back onto live entries)
        k_out = k + block_n
    else:
        raise ValueError(
            f"unknown compaction {compaction!r}; use 'scan' or 'onehot'")

    vals, idx = pl.pallas_call(
        kernel,
        grid=(rows,),
        in_specs=[pl.BlockSpec((1, n_pad), lambda r: (r, 0))],
        out_specs=[pl.BlockSpec((1, k_out), lambda r: (r, 0)),
                   pl.BlockSpec((1, k_out), lambda r: (r, 0))],
        out_shape=[jax.ShapeDtypeStruct((rows, k_out), jnp.float32),
                   jax.ShapeDtypeStruct(
                       (rows, k_out),
                       jnp.float32 if compaction == "onehot" else jnp.int32)],
        compiler_params=compiler_params(("parallel",)),
        interpret=interpret,
    )(x)
    if k_out != k:
        vals = vals[:, :k]
        idx = idx[:, :k]
    return vals.astype(x.dtype), idx.astype(jnp.int32)
