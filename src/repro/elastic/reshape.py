"""Checkpointed fleet reshape: resume a run onto a *different* learner count.

Hier-AVG state is learner-stacked — every params / opt-state / EF leaf
carries the ``[pods, G, S]`` lead axes — so joins and leaves at a round
boundary are a pure re-indexing of those lead axes:

  * **survivors** (old learners that stay) land in the new grid with
    their params, optimizer moments, and error-feedback residuals
    *bit-preserved* (the remap is a gather, never an arithmetic op);
  * **joiners** (new slots beyond the survivors) clone a donor learner's
    params/opt-state — the elastic analogue of the paper's shared-w_1
    init — and start with a ZERO error-feedback residual (a cloned
    residual would double-count the donor's untransmitted mass at the
    next fire).

Why this works for ``comm_state`` too: fsdp=1 :class:`BucketLayout`\\ s
pack per-learner runs with no learner-count-dependent padding
(comm/bucket.py pads runs to a multiple of the lead mesh size only when
a ShardPlan is attached), so bucket-space EF leaves keep their trailing
``(run,)`` — and PowerSGD's warm-start ``q`` its ``(b, rank)`` — across
any fleet size, and the same lead-axes gather applies.  Shard-aware
(fsdp>1) layouts break both properties: the codec view merges shards
into the local axis (``[pods, G, S*F, run]``) and run padding depends on
the lead count, so that state cannot be re-indexed — it is *dropped
loudly* (:class:`CommStateDropWarning`, naming the level and codec) and
re-initialized fresh, exactly like the ``PSpecDropWarning`` convention
for unshardable specs.  Dropping EF costs one round of compression error
(the residual restarts at zero), not correctness.

Entry points: :func:`reshape_state` (in-memory, round-boundary
join/leave), :func:`save_elastic_checkpoint` /
:func:`elastic_restore` (cross-process, stamps/reads the source
topology in the checkpoint manifest).
"""
from __future__ import annotations

import warnings
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import HierTopology


class CommStateDropWarning(UserWarning):
    """A reducer's carried state could not survive a fleet reshape and
    was re-initialized (EF residual restarts at zero)."""


def learner_index_map(old_topo: HierTopology, new_topo: HierTopology,
                      survivors: Optional[Sequence[int]] = None,
                      donor: Optional[int] = None
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """The lead-axes gather plan of a reshape.

    Returns ``(src, joiner)``: ``src[j]`` is the OLD flat learner id
    (row-major over ``[pods, G, S]``) whose state fills NEW flat slot
    ``j``, and ``joiner[j]`` marks slots filled by donor-cloning rather
    than survival.  ``survivors`` lists the old flat ids that stay, in
    the order they take the new slots (default: identity over the first
    ``min(old_P, new_P)`` learners); ``donor`` is the old flat id cloned
    into every remaining slot (default: the first survivor).
    """
    old_p, new_p = old_topo.n_learners, new_topo.n_learners
    if survivors is None:
        survivors = list(range(min(old_p, new_p)))
    survivors = [int(j) for j in survivors]
    if len(set(survivors)) != len(survivors):
        raise ValueError(f"duplicate survivor ids: {survivors}")
    if survivors and not all(0 <= j < old_p for j in survivors):
        raise ValueError(
            f"survivor ids must be old flat learner ids in [0, {old_p}), "
            f"got {survivors}")
    if len(survivors) > new_p:
        raise ValueError(
            f"{len(survivors)} survivors do not fit the new topology's "
            f"{new_p} learners ({new_topo.describe()})")
    if not survivors:
        raise ValueError("a reshape needs at least one survivor")
    if donor is None:
        donor = survivors[0]
    donor = int(donor)
    if not 0 <= donor < old_p:
        raise ValueError(f"donor must be an old flat learner id in "
                         f"[0, {old_p}), got {donor}")
    src = np.full(new_p, donor, dtype=np.int64)
    src[:len(survivors)] = survivors
    joiner = np.ones(new_p, dtype=bool)
    joiner[:len(survivors)] = False
    return src, joiner


def _remap_lead(x, old_shape, new_shape, src: np.ndarray):
    """Gather the flattened ``[pods*G*S, ...]`` lead onto the new grid —
    pure re-indexing, bit-preserving for every surviving row."""
    flat = jnp.reshape(x, (-1,) + tuple(x.shape[3:]))
    return jnp.reshape(flat[src], tuple(new_shape) + tuple(x.shape[3:]))


def _leaf_kind(shape, old_topo: HierTopology) -> str:
    """'stacked' (remappable lead-3), 'codec' (shard-merged local axis —
    NOT remappable), or 'other' (keys/scalars — count-independent)."""
    shape = tuple(shape)
    if len(shape) >= 3 and shape[:3] == old_topo.shape:
        return "stacked"
    if (len(shape) >= 3 and shape[:2] == old_topo.shape[:2]
            and shape[2] != old_topo.local and shape[2] % old_topo.local == 0):
        return "codec"
    return "other"


def _remap_tree(tree, old_topo, new_topo, src):
    """Remap every stacked leaf; raises ValueError on codec-view leaves
    (callers catch it to drop the level's state instead)."""
    def go(x):
        kind = _leaf_kind(getattr(x, "shape", ()), old_topo)
        if kind == "stacked":
            return _remap_lead(x, old_topo.shape, new_topo.shape, src)
        if kind == "codec":
            raise _CodecLeaf(tuple(x.shape))
        return x
    return jax.tree.map(go, tree)


class _CodecLeaf(Exception):
    pass


def _zero_joiner_err(lvl_state, new_topo, joiner: np.ndarray):
    """Zero the joiners' rows of a remapped level state's ``err`` leaves:
    a cloned residual is the donor's untransmitted mass, which the donor
    itself will still transmit — carrying a copy would inject it twice."""
    if not hasattr(lvl_state, "err") or not hasattr(lvl_state, "_replace"):
        return lvl_state
    keep = jnp.asarray(~joiner.reshape(new_topo.shape))

    def zero(x):
        if _leaf_kind(getattr(x, "shape", ()), new_topo) != "stacked":
            return x
        k = keep.reshape(keep.shape + (1,) * (x.ndim - keep.ndim))
        return jnp.where(k, x, jnp.zeros_like(x))

    return lvl_state._replace(err=jax.tree.map(zero, lvl_state.err))


def reshape_comm_state(comm_state, old_topo: HierTopology,
                       new_topo: HierTopology, src: np.ndarray,
                       joiner: np.ndarray, *, plan=None, params=None):
    """Remap per-level reducer carry across a reshape.

    Levels whose state is pure lead-stacked arrays (param-space EF,
    fsdp=1 bucket-space EF, PowerSGD warm-start q) are gathered like the
    params, with joiners' ``err`` zeroed.  Levels carrying codec-view
    (shard-merged) leaves raise :class:`CommStateDropWarning` and take a
    fresh ``init_state`` — which needs ``plan`` and the already-remapped
    ``params``; without them the level's state is dropped to ``()``.
    """
    if comm_state == () or comm_state is None:
        return comm_state
    by_level = {}
    for name, lvl_state in comm_state.items():
        try:
            new_lvl = _remap_tree(lvl_state, old_topo, new_topo, src)
        except _CodecLeaf as e:
            reducer = None
            if plan is not None:
                reducer = next((l.reducer for l in plan.levels
                                if l.name == name), None)
            desc = reducer.describe() if reducer is not None else "?"
            can_reinit = reducer is not None and params is not None
            warnings.warn(
                f"fleet reshape {old_topo.shape} -> {new_topo.shape}: "
                f"level '{name}' ({desc}) carries shard-space (codec-view "
                f"{e.args[0]}) reducer state whose layout depends on the "
                f"learner count; "
                + ("re-initializing it fresh" if can_reinit
                   else "dropping it (pass plan= and params= to re-init)")
                + " — the EF residual restarts at zero.",
                CommStateDropWarning, stacklevel=3)
            new_lvl = (reducer.init_state(params) if can_reinit else ())
            by_level[name] = new_lvl
            continue
        by_level[name] = _zero_joiner_err(new_lvl, new_topo, joiner)
    return by_level


def reshape_state(state, old_topo: HierTopology, new_topo: HierTopology,
                  *, plan=None, survivors: Optional[Sequence[int]] = None,
                  donor: Optional[int] = None):
    """Join/leave at a round boundary: re-stack a ``TrainState`` from
    ``old_topo`` onto ``new_topo`` (module docstring for semantics).

    ``plan`` — the resolved :class:`~repro.core.plan.ReductionPlan` of the
    run — is only needed to re-initialize reducer state that cannot be
    remapped (shard-aware layouts).  Survivors' params / opt-state / EF
    are bit-preserved (test-enforced).
    """
    src, joiner = learner_index_map(old_topo, new_topo, survivors, donor)
    params = _remap_tree(state.params, old_topo, new_topo, src)
    opt_state = _remap_tree(state.opt_state, old_topo, new_topo, src)
    comm_state = reshape_comm_state(
        state.comm_state, old_topo, new_topo, src, joiner,
        plan=plan, params=params)
    return state._replace(params=params, opt_state=opt_state,
                          comm_state=comm_state)


# ---------------------------------------------------------------------- #
# checkpointed reshape
# ---------------------------------------------------------------------- #

def save_elastic_checkpoint(path: str, state, topo: HierTopology, *,
                            step: int = 0, plan=None,
                            metadata=None) -> None:
    """``save_checkpoint`` stamping the source topology (and plan spec)
    into the manifest metadata, so :func:`elastic_restore` can infer the
    saved learner grid without the caller carrying it around."""
    from repro.checkpoint import save_checkpoint
    md = dict(metadata or {})
    md["topology"] = list(topo.shape)
    if plan is not None:
        md["plan"] = plan.describe()
    save_checkpoint(path, state, step=step, metadata=md)


def checkpoint_topology(path: str) -> Optional[HierTopology]:
    """The ``HierTopology`` stamped by :func:`save_elastic_checkpoint`,
    or None for plain checkpoints."""
    import json
    import os
    with open(os.path.join(path, "manifest.json")) as f:
        md = json.load(f).get("metadata", {})
    shape = md.get("topology")
    return HierTopology(*shape) if shape else None


def elastic_restore(path: str, like, *, new_topo: HierTopology,
                    old_topo: Optional[HierTopology] = None,
                    plan=None, survivors: Optional[Sequence[int]] = None,
                    donor: Optional[int] = None,
                    shardings: Any = None):
    """Resume a checkpoint onto a *different* learner count.

    ``like`` is a freshly-initialized ``TrainState`` (or any matching
    pytree) at the NEW topology — it supplies the target structure,
    dtypes, and placement exactly as ``restore_checkpoint`` does.
    ``old_topo`` is read from the manifest
    (:func:`save_elastic_checkpoint`) when not given.  Stacked leaves are
    gathered through :func:`learner_index_map` (survivors bit-preserved,
    joiners donor-cloned, joiner EF zeroed); codec-view reducer state
    follows the :func:`reshape_comm_state` drop-or-re-init policy; leaves
    whose saved shape already matches restore untouched.  Same learner
    count falls through to plain ``restore_checkpoint``.

    fsdp>1 NOTE: only the replicated-trailing-dims state round-trips —
    shard-space reducer state is re-initialized (warned), and ``like``'s
    shardings drive the final placement.
    """
    from repro.checkpoint.checkpoint import (_validate_manifest,
                                             load_checkpoint,
                                             restore_checkpoint)

    if old_topo is None:
        old_topo = checkpoint_topology(path)
        if old_topo is None:
            raise ValueError(
                f"checkpoint at '{path}' carries no topology metadata — "
                f"pass old_topo= (or re-save with save_elastic_checkpoint)")
    if old_topo.shape == new_topo.shape and survivors is None:
        return restore_checkpoint(path, like, shardings=shardings)

    arrays = load_checkpoint(path)
    _validate_manifest(path, arrays)
    src, joiner = learner_index_map(old_topo, new_topo, survivors, donor)

    # Re-stack every saved learner-stacked array onto the new grid in
    # numpy (host side, exact gather), then hand the result to the strict
    # restore path for structure/dtype validation and device placement.
    import os
    import tempfile

    from repro.checkpoint import save_checkpoint

    remapped = {}
    dropped = []
    for key, arr in arrays.items():
        kind = _leaf_kind(arr.shape, old_topo)
        if kind == "stacked":
            flat = arr.reshape((-1,) + arr.shape[3:])
            out = flat[src].reshape(new_topo.shape + arr.shape[3:])
            # EFState.err field component (named-tuple fields serialize
            # with a leading "." — ".comm_state/global/.err/0")
            if any(c.lstrip(".") == "err" for c in key.split("/")):
                out = out.copy()
                out.reshape((new_topo.n_learners,) + arr.shape[3:])[
                    joiner] = 0
            remapped[key] = out
        elif kind == "codec":
            dropped.append(key)
        else:
            remapped[key] = arr

    like_flat = jax.tree_util.tree_flatten_with_path(like)[0]
    from repro.checkpoint.checkpoint import _path_str
    for kp, leaf in like_flat:
        key = _path_str(kp)
        if key in remapped:
            continue
        # dropped codec-view state (or structural drift the strict
        # validator will flag): seed from the fresh `like` leaf
        if key in dropped or key not in arrays:
            if key in dropped:
                warnings.warn(
                    f"elastic restore {old_topo.shape} -> "
                    f"{new_topo.shape}: leaf '{key}' is shard-space "
                    f"(codec-view) reducer state whose layout depends on "
                    f"the learner count; keeping `like`'s fresh init — "
                    f"the EF residual restarts at zero.",
                    CommStateDropWarning, stacklevel=2)
            remapped[key] = np.asarray(jax.device_get(leaf))

    with tempfile.TemporaryDirectory() as tmp:
        tmp_ckpt = os.path.join(tmp, "reshaped")
        save_checkpoint(tmp_ckpt, jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like),
            [remapped[_path_str(kp)] for kp, _ in like_flat]))
        return restore_checkpoint(tmp_ckpt, like, shardings=shardings)
