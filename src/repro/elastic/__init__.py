"""Elastic membership for Hier-AVG fleets (PR 9).

Three legs, one thesis — learners run decoupled between reductions, so a
learner that misses a fire should cost the round nothing:

  * participation-masked reductions — the ``mask=`` / ``active=`` plumbing
    in core/topology.py + core/hier_avg.py (absent learners contribute
    weight 0; EF/params untouched across a missed fire);
  * deterministic fault injection — :class:`FaultSchedule`, a pure
    function of (seed, unit, round), driving masks through the Simulator
    and ``launch/train.py --faults``;
  * checkpointed fleet reshape — :func:`reshape_state` /
    :func:`elastic_restore`, resuming onto a different learner count with
    survivors bit-preserved and un-remappable reducer state dropped
    loudly (:class:`CommStateDropWarning`).

Expected-cost billing for unreliable tiers lives in core/theory.py
(``effective_participants``, ``plan_comm_per_round(..., drop_prob=)``).
"""
from repro.elastic.faults import (FaultClause, FaultSchedule,
                                  level_deadlines, parse_faults)
from repro.elastic.reshape import (CommStateDropWarning,
                                   checkpoint_topology, elastic_restore,
                                   learner_index_map, reshape_comm_state,
                                   reshape_state, save_elastic_checkpoint)

__all__ = [
    "CommStateDropWarning",
    "FaultClause",
    "FaultSchedule",
    "checkpoint_topology",
    "elastic_restore",
    "learner_index_map",
    "level_deadlines",
    "parse_faults",
    "reshape_comm_state",
    "reshape_state",
    "save_elastic_checkpoint",
]
