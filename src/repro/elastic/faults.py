"""Deterministic fault injection: the seeded :class:`FaultSchedule`.

Every robustness claim in this repo is a reproducible run, not an
anecdote: a fault schedule is a *pure function of (seed, unit, round)* —
no carried RNG state — so the same spec string rebuilds the exact same
drop pattern in a fresh process (the bench subprocess A/B legs rely on
this; test-enforced).  Each query seeds a fresh
``numpy.random.Generator`` from a ``SeedSequence`` over integer
coordinates, so masks can be queried out of order, in parallel, or from
different processes and always agree.

Spec grammar (``--faults`` on launch/train.py, ``faults=`` on the
Simulator) — ``/``-separated clauses, each ``kind:args[@level]``:

    crash:P                 each learner independently dies for good at a
                            Geometric(P)-distributed round (never rejoins)
    flaky[:GRAN]:P[:DOWN]   each GRAN unit (learner | group | pod; default
                            learner) goes down with per-round probability
                            P and rejoins after DOWN rounds (default 1)
    straggler:P[:SLACK]     each learner straggles with per-round
                            probability P, drawing an Exponential delay;
                            it misses every level whose deadline —
                            SLACK x that level's calibrated wall
                            (core/theory.py ``level_reduction_seconds``)
                            — the delay exceeds.  SLACK defaults to 1.5.

An ``@level`` suffix (``crash:0.1@global``) restricts a clause to one
plan level; without it a clause masks every level.  Example: a fleet
with 2% permanent crashes, 20% pod-level flaps lasting 3 rounds, and
10% stragglers against a 1.5x deadline::

    crash:0.02/flaky:pod:0.2:3/straggler:0.1:1.5

The deadline policy: straggler delays are drawn at the scale of the
*largest* level wall (the outermost reduction is the natural sync
horizon), and a straggler misses exactly the levels whose own deadline
is shorter than its delay — so cheap inner reductions are missed more
often than the expensive global one, matching how a real deadline-based
membership service degrades.  With no deadlines supplied every level's
wall defaults to 1.0 (miss probability ``exp(-SLACK)`` per straggler).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.topology import HierTopology

# salts keeping the three fault families' streams disjoint
_SALT_CRASH = 0x63727368
_SALT_FLAKY = 0x666c616b
_SALT_STRAG = 0x73747261

_GRANULARITIES = ("learner", "group", "pod")


def _rng(*coords: int) -> np.random.Generator:
    """A fresh generator keyed by integer coordinates only — the whole
    determinism story (reconstructable from (seed, unit, round) alone)."""
    return np.random.default_rng(
        np.random.SeedSequence([int(c) & 0xFFFFFFFF for c in coords]))


@dataclass(frozen=True)
class FaultClause:
    """One parsed clause of a fault spec string."""

    kind: str                      # "crash" | "flaky" | "straggler"
    p: float                       # per-unit (per-round) probability
    gran: str = "learner"          # flaky granularity
    down: int = 1                  # flaky outage length, rounds
    slack: float = 1.5             # straggler deadline multiplier
    level: Optional[str] = None    # clause restricted to one plan level

    def describe(self) -> str:
        if self.kind == "crash":
            body = f"crash:{self.p:g}"
        elif self.kind == "flaky":
            body = f"flaky:{self.gran}:{self.p:g}:{self.down}"
        else:
            body = f"straggler:{self.p:g}:{self.slack:g}"
        return body + (f"@{self.level}" if self.level else "")


def parse_faults(spec: str) -> Tuple[FaultClause, ...]:
    """Parse the ``/``-separated clause grammar (module docstring)."""
    clauses = []
    for part in str(spec).split("/"):
        part = part.strip()
        if not part:
            continue
        body, _, level = part.partition("@")
        level = level.strip() or None
        args = [a.strip() for a in body.split(":")]
        kind = args.pop(0)
        try:
            if kind == "crash":
                (p,) = args
                clauses.append(FaultClause("crash", float(p), level=level))
            elif kind == "flaky":
                gran = "learner"
                if args and args[0] in _GRANULARITIES:
                    gran = args.pop(0)
                p = float(args.pop(0))
                down = int(args.pop(0)) if args else 1
                if args:
                    raise ValueError(args)
                if down < 1:
                    raise ValueError(f"flaky down must be >= 1, got {down}")
                clauses.append(FaultClause("flaky", p, gran=gran, down=down,
                                           level=level))
            elif kind == "straggler":
                p = float(args.pop(0))
                slack = float(args.pop(0)) if args else 1.5
                if args:
                    raise ValueError(args)
                clauses.append(FaultClause("straggler", p, slack=slack,
                                           level=level))
            else:
                raise ValueError(
                    f"unknown fault kind {kind!r} in clause {part!r}; "
                    f"known: crash / flaky / straggler")
        except (ValueError, TypeError, IndexError) as e:
            if isinstance(e, ValueError) and e.args and \
                    isinstance(e.args[0], str) and "fault" in e.args[0]:
                raise
            raise ValueError(
                f"bad fault clause {part!r} (grammar: crash:P | "
                f"flaky[:learner|group|pod]:P[:down] | "
                f"straggler:P[:slack], each optionally @level)") from e
        if not 0.0 <= clauses[-1].p <= 1.0:
            raise ValueError(
                f"fault probability must be in [0, 1], got {clauses[-1].p} "
                f"in clause {part!r}")
    if not clauses:
        raise ValueError(f"empty fault spec {spec!r}")
    return tuple(clauses)


class FaultSchedule:
    """Per-round, per-level participation masks for one learner fleet.

    ``levels`` are the plan's level names innermost-first (matching the
    ``active[i]`` convention of the elastic ``make_hier_round``);
    ``deadlines`` maps level name -> wall seconds of one reduction at
    that level (price them with
    ``repro.elastic.level_deadlines(plan, topo, template, cm)`` from the
    calibrated CommModel) and only matters for straggler clauses.
    """

    def __init__(self, clauses, topo: HierTopology,
                 levels: Sequence[str], seed: int = 0,
                 deadlines: Optional[Dict[str, float]] = None):
        if isinstance(clauses, str):
            clauses = parse_faults(clauses)
        self.clauses: Tuple[FaultClause, ...] = tuple(clauses)
        self.topo = topo
        self.levels = tuple(levels)
        self.seed = int(seed)
        self.deadlines = {str(k): float(v)
                          for k, v in (deadlines or {}).items()}
        for c in self.clauses:
            if c.level is not None and c.level not in self.levels:
                raise ValueError(
                    f"fault clause {c.describe()!r} names level "
                    f"{c.level!r}, but the plan has {self.levels}")
        # delays are drawn at the scale of the slowest level (the round's
        # natural sync horizon); 1.0 when no calibrated walls were given
        walls = [self.deadlines.get(n, 1.0) for n in self.levels]
        self._delay_scale = max(walls) if walls else 1.0

    # ------------------------------------------------------------------ #
    # per-clause learner masks (True = active), each a pure function of
    # (seed, unit, round)
    # ------------------------------------------------------------------ #

    def _crash_mask(self, c: FaultClause, r: int) -> np.ndarray:
        P = self.topo.n_learners
        up = np.ones(P, bool)
        if c.p <= 0.0:
            return up
        for j in range(P):
            crash_round = _rng(self.seed, _SALT_CRASH, j).geometric(c.p)
            up[j] = r < crash_round
        return up

    def _flaky_unit_count(self, c: FaultClause) -> Tuple[int, int]:
        """(n_units, learners_per_unit) for a flaky granularity."""
        t = self.topo
        if c.gran == "pod":
            return t.pods, t.groups * t.local
        if c.gran == "group":
            return t.pods * t.groups, t.local
        return t.n_learners, 1

    def _flaky_mask(self, c: FaultClause, r: int) -> np.ndarray:
        n_units, per = self._flaky_unit_count(c)
        up = np.ones(n_units, bool)
        if c.p > 0.0:
            for u in range(n_units):
                for r0 in range(max(0, r - c.down + 1), r + 1):
                    if _rng(self.seed, _SALT_FLAKY, u, r0).random() < c.p:
                        up[u] = False
                        break
        return np.repeat(up, per)

    def _straggler_delays(self, c: FaultClause, r: int) -> np.ndarray:
        """Per-learner delay this round (0.0 = on time)."""
        P = self.topo.n_learners
        delays = np.zeros(P)
        if c.p <= 0.0:
            return delays
        for j in range(P):
            g = _rng(self.seed, _SALT_STRAG, j, r)
            if g.random() < c.p:
                delays[j] = g.exponential(scale=self._delay_scale)
        return delays

    # ------------------------------------------------------------------ #
    # the schedule surface
    # ------------------------------------------------------------------ #

    def active(self, r: int) -> np.ndarray:
        """The boolean ``[n_levels, pods, G, S]`` participation mask of
        round ``r`` — exactly what the elastic ``make_hier_round`` takes."""
        r = int(r)
        shape = self.topo.shape
        out = np.ones((len(self.levels),) + shape, bool)
        for c in self.clauses:
            if c.kind == "straggler":
                delays = self._straggler_delays(c, r)
                for i, name in enumerate(self.levels):
                    if c.level is not None and c.level != name:
                        continue
                    deadline = c.slack * self.deadlines.get(name, 1.0)
                    out[i] &= (delays <= deadline).reshape(shape)
                continue
            m = (self._crash_mask(c, r) if c.kind == "crash"
                 else self._flaky_mask(c, r)).reshape(shape)
            for i, name in enumerate(self.levels):
                if c.level is None or c.level == name:
                    out[i] &= m
        return out

    def active_frac(self, r: int) -> np.ndarray:
        """Per-level participation fraction of round ``r``."""
        return self.active(r).reshape(len(self.levels), -1).mean(axis=1)

    def describe(self) -> str:
        return "/".join(c.describe() for c in self.clauses)

    def __repr__(self) -> str:
        return (f"FaultSchedule({self.describe()!r}, seed={self.seed}, "
                f"levels={self.levels})")


def level_deadlines(plan, topo: HierTopology, template,
                    cm=None) -> Dict[str, float]:
    """Price each plan level's deadline base — the scheduled wall of ONE
    reduction at that level under the (calibrated) CommModel — for the
    straggler clauses' ``slack x wall`` policy."""
    from repro.core.theory import level_reduction_seconds
    return {lvl.name: level_reduction_seconds(lvl, topo, template, cm)[2]
            for lvl in plan.levels}
