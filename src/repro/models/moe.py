"""Mixture-of-Experts layer: top-k router + capacity-based einsum dispatch.

TPU-native formulation (no CUDA-style scatter/gather): tokens are assigned
expert/capacity slots with one-hot dispatch/combine tensors and the expert
FFN is a single batched einsum over the expert dimension.  With the expert
dim sharded over the ``model`` mesh axis (expert parallelism) GSPMD lowers
dispatch/combine into all-to-all-style collectives; the math is identical on
one device.

Token CHUNKING: the one-hot dispatch tensor is O(T * E * C) — at the pool's
train_4k scale (512k tokens per learner) that is terabytes.  We therefore
route in independent chunks of ``chunk`` tokens (grouped routing, as in
Switch/DeepSeek device-grouped capacity): capacity applies per chunk, the
dispatch working set is O(chunk^2 * top_k * cf / 1) and the chunk loop is a
``lax.map`` (sequential, VMEM-friendly).  With a dropless capacity factor
(cf >= E/top_k) chunking is mathematically invisible.

Supports DeepSeek-style shared experts and the switch-transformer auxiliary
load-balance loss (surfaced so the trainer adds router_aux_coef * aux).
"""
from __future__ import annotations

import functools
import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import Params, dense_init
from repro.models.mlp import mlp_apply, mlp_init

DEFAULT_CHUNK = 4096


def moe_init(key, d_model: int, expert_d_ff: int, n_experts: int,
             n_shared: int, act: str = "silu", dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    # experts stored stacked on a leading [E, ...] dim (shardable over tp)
    expert_keys = jax.random.split(ks[0], n_experts)
    experts = jax.vmap(
        lambda k: mlp_init(k, d_model, expert_d_ff, act))(expert_keys)
    experts = jax.tree.map(lambda x: x.astype(dtype), experts)
    p: Params = {
        "router": dense_init(ks[1], d_model, n_experts, jnp.float32),
        "experts": experts,
    }
    if n_shared:
        p["shared"] = mlp_init(ks[2], d_model, expert_d_ff * n_shared, act,
                               dtype)
    return p


def _expert_ffn(experts: Params, x_ecd: jax.Array, act: str) -> jax.Array:
    """x [E, C, d] through per-expert FFN (stacked weights [E, ...])."""
    if "w_gate" in experts:
        g = jnp.einsum("ecd,edf->ecf", x_ecd, experts["w_gate"])
        u = jnp.einsum("ecd,edf->ecf", x_ecd, experts["w_up"])
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", x_ecd, experts["w_up"]))
    return jnp.einsum("ecf,efd->ecd", h, experts["w_down"])


def _route_chunk(p: Params, xt: jax.Array, valid: jax.Array, *,
                 n_experts: int, top_k: int, capacity: int, act: str
                 ) -> Tuple[jax.Array, jax.Array]:
    """xt [Tc, d], valid [Tc] -> (y [Tc, d], aux scalar)."""
    n_tok = xt.shape[0]
    logits = xt.astype(jnp.float32) @ p["router"]               # [Tc, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)           # [Tc, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)
    gate_vals = gate_vals * valid[:, None]

    onehot = jax.nn.one_hot(gate_idx, n_experts, dtype=jnp.int32)  # [Tc,k,E]
    onehot = onehot * valid[:, None, None].astype(jnp.int32)
    flat = onehot.reshape(n_tok * top_k, n_experts)
    pos_in_expert = jnp.cumsum(flat, axis=0) * flat - 1            # [Tc*k, E]
    pos = pos_in_expert.max(axis=-1).reshape(n_tok, top_k)         # [Tc, k]
    fits = pos < capacity

    pos_oh = jax.nn.one_hot(jnp.where(fits, pos, capacity), capacity + 1,
                            dtype=xt.dtype)[..., :capacity]        # [Tc,k,C]
    disp = jnp.einsum("tke,tkc->tec", onehot.astype(xt.dtype), pos_oh)
    comb = jnp.einsum("tke,tkc,tk->tec", onehot.astype(jnp.float32),
                      pos_oh.astype(jnp.float32),
                      gate_vals.astype(jnp.float32)).astype(xt.dtype)

    x_ecd = jnp.einsum("tec,td->ecd", disp, xt)                    # [E,C,d]
    y_ecd = _expert_ffn(p["experts"], x_ecd, act)
    yt = jnp.einsum("tec,ecd->td", comb, y_ecd)

    # switch-style load-balance aux loss over valid tokens
    denom = jnp.maximum(valid.sum(), 1.0)
    me = (probs * valid[:, None]).sum(axis=0) / denom              # [E]
    ce = onehot.sum(axis=1).astype(jnp.float32).sum(axis=0) / denom
    aux = n_experts * jnp.sum(me * ce) / top_k
    return yt, aux


def moe_apply(p: Params, x: jax.Array, *, n_experts: int, top_k: int,
              capacity_factor: float = 1.25, act: str = "silu",
              chunk: int = DEFAULT_CHUNK) -> Tuple[jax.Array, jax.Array]:
    """x [B, S, d] -> (out [B, S, d], aux_loss scalar).

    Chunking is along the SEQUENCE axis only (the batch axis stays a vmap
    dim, so its data-parallel sharding is preserved; the seq-chunk loop axis
    is unsharded and safe to ``lax.map`` over).  Routing group = one
    (sequence row x seq chunk); capacity applies per group.
    """
    b, s, d = x.shape
    tc = min(chunk, s)
    n_chunks = -(-s // tc)
    pad = n_chunks * tc - s
    valid = jnp.concatenate([jnp.ones((s,), jnp.float32),
                             jnp.zeros((pad,), jnp.float32)])
    xt = x
    if pad:
        xt = jnp.concatenate([x, jnp.zeros((b, pad, d), x.dtype)], axis=1)

    capacity = max(1, int(math.ceil(tc * top_k / n_experts
                                    * capacity_factor)))

    route = functools.partial(_route_chunk, p, n_experts=n_experts,
                              top_k=top_k, capacity=capacity, act=act)
    vroute = jax.vmap(route, in_axes=(0, None))      # over batch rows

    # [B, nc, tc, d] -> map over nc (axis 0 after moveaxis)
    xc = jnp.moveaxis(xt.reshape(b, n_chunks, tc, d), 1, 0)
    vc = valid.reshape(n_chunks, tc)
    if n_chunks == 1:
        yt, aux = vroute(xc[0], vc[0])
        yt = yt[None]
        aux = aux.mean()
    else:
        yt, aux = jax.lax.map(lambda args: vroute(*args), (xc, vc))
        aux = aux.mean()

    yt = jnp.moveaxis(yt, 0, 1).reshape(b, n_chunks * tc, d)[:, :s]
    out = yt
    if "shared" in p:
        out = out + mlp_apply(p["shared"], x, act)
    return out, jnp.asarray(aux, jnp.float32)
