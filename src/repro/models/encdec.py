"""Seamless-style encoder–decoder backbone.

The speech frontend (mel + conformer conv) is STUBBED: the encoder consumes
precomputed frame embeddings [B, T_frames, d_model].  Encoder layers are
bidirectional self-attn + FFN; decoder layers are causal self-attn +
cross-attn + FFN.  Positional encoding uses RoPE on self-attention (a
backbone-level approximation of the release's conformer relative positions —
noted in DESIGN.md).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.attention import (cross_attention, full_attention,
                                    gqa_attention, gqa_decode, gqa_init,
                                    init_kv_cache, prefill_kv_cache)
from repro.models.common import (Params, apply_rope, dense_init, embed_init,
                                 rmsnorm, rmsnorm_init, rope_cos_sin,
                                 scan_layers_with_cache, softmax_cross_entropy,
                                 stacked_init, text_positions)
from repro.models.mlp import mlp_apply, mlp_init
from repro.models.transformer import ModelBundle


def _enc_layer_init(key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "attn": gqa_init(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                         cfg.resolved_head_dim, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
        "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dtype),
    }


def _dec_layer_init(key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 3)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "self_attn": gqa_init(ks[0], cfg.d_model, cfg.n_heads,
                              cfg.n_kv_heads, cfg.resolved_head_dim, dtype),
        "ln_x": rmsnorm_init(cfg.d_model, dtype),
        "cross_attn": gqa_init(ks[1], cfg.d_model, cfg.n_heads,
                               cfg.n_kv_heads, cfg.resolved_head_dim, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
        "mlp": mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.act, dtype),
    }


def build_encdec(cfg: ArchConfig, *, param_dtype=jnp.float32,
                 compute_dtype=None, remat: bool = False, impl: str = "xla",
                 cache_dtype=jnp.bfloat16, **_unused) -> ModelBundle:
    compute_dtype = compute_dtype or param_dtype
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim

    def init(key):
        ks = jax.random.split(key, 4)
        return {
            "embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model,
                                param_dtype),
            "enc_layers": stacked_init(
                lambda k: _enc_layer_init(k, cfg, param_dtype), ks[1],
                cfg.n_encoder_layers),
            "enc_norm": rmsnorm_init(cfg.d_model, param_dtype),
            "dec_layers": stacked_init(
                lambda k: _dec_layer_init(k, cfg, param_dtype), ks[2],
                cfg.n_layers),
            "final_norm": rmsnorm_init(cfg.d_model, param_dtype),
            "lm_head": dense_init(ks[3], cfg.d_model, cfg.padded_vocab,
                                  param_dtype),
        }

    def encode(params, frames):
        """frames [B,Tf,d] (stub frontend output) -> encoder states."""
        x = frames.astype(compute_dtype)
        b, t, _ = x.shape
        cos, sin = rope_cos_sin(text_positions(b, t), hd, cfg.rope_theta)

        def body(x, lp):
            h = gqa_attention(lp["attn"], rmsnorm(lp["ln1"], x, cfg.norm_eps),
                              cos, sin, n_heads=H, n_kv_heads=Hkv,
                              head_dim=hd, causal=False, impl=impl)
            x = x + h
            h = mlp_apply(lp["mlp"], rmsnorm(lp["ln2"], x, cfg.norm_eps),
                          cfg.act)
            return x + h

        fn = jax.checkpoint(body) if remat else body

        def step(c, lp):
            return fn(c, lp), None
        x, _ = jax.lax.scan(step, x, params["enc_layers"])
        return rmsnorm(params["enc_norm"], x, cfg.norm_eps)

    def _dec_body_full(enc, cos, sin):
        def body(x, lp):
            h = gqa_attention(lp["self_attn"],
                              rmsnorm(lp["ln1"], x, cfg.norm_eps), cos, sin,
                              n_heads=H, n_kv_heads=Hkv, head_dim=hd,
                              impl=impl)
            x = x + h
            hx = rmsnorm(lp["ln_x"], x, cfg.norm_eps)
            b, te, _ = enc.shape
            ek = (enc @ lp["cross_attn"]["wk"]).reshape(b, te, Hkv, hd)
            ev = (enc @ lp["cross_attn"]["wv"]).reshape(b, te, Hkv, hd)
            h = cross_attention(lp["cross_attn"], hx, ek, ev, None,
                                n_heads=H, n_kv_heads=Hkv, head_dim=hd)
            x = x + h
            h = mlp_apply(lp["mlp"], rmsnorm(lp["ln2"], x, cfg.norm_eps),
                          cfg.act)
            return x + h
        return body

    def loss_fn(params, batch):
        enc = encode(params, batch["frames"])
        tok = batch["tokens"]
        x = params["embed"][tok].astype(compute_dtype)
        b, s, _ = x.shape
        cos, sin = rope_cos_sin(text_positions(b, s), hd, cfg.rope_theta)
        body = _dec_body_full(enc, cos, sin)
        fn = jax.checkpoint(body) if remat else body

        def step(c, lp):
            return fn(c, lp), None
        x, _ = jax.lax.scan(step, x, params["dec_layers"])
        h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = h @ params["lm_head"]
        return softmax_cross_entropy(logits, batch["labels"],
                                     batch.get("mask"))

    # --------------------------- serving ----------------------------- #

    def init_cache(batch: int, max_len: int, enc_len: int = 0):
        enc_len = enc_len or cfg.frontend_tokens

        def one(_):
            return {
                "self": init_kv_cache(batch, max_len, Hkv, hd, cache_dtype),
                "cross_k": jnp.zeros((batch, enc_len, Hkv, hd), cache_dtype),
                "cross_v": jnp.zeros((batch, enc_len, Hkv, hd), cache_dtype),
            }
        caches = [one(i) for i in range(cfg.n_layers)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *caches)

    def prefill(params, batch):
        enc = encode(params, batch["frames"])
        tok = batch["tokens"]
        x = params["embed"][tok].astype(compute_dtype)
        b, s, _ = x.shape
        max_len = batch.get("max_len", s)
        if isinstance(max_len, jax.Array):
            max_len = int(max_len)
        cos, sin = rope_cos_sin(text_positions(b, s), hd, cfg.rope_theta)
        te = enc.shape[1]

        def body(x, lp, _st):
            h_in = rmsnorm(lp["ln1"], x, cfg.norm_eps)
            h = gqa_attention(lp["self_attn"], h_in, cos, sin, n_heads=H,
                              n_kv_heads=Hkv, head_dim=hd, impl=impl)
            kv = prefill_kv_cache(lp["self_attn"], h_in, cos, sin,
                                  n_heads=H, n_kv_heads=Hkv, head_dim=hd,
                                  max_len=max_len, dtype=cache_dtype)
            x = x + h
            hx = rmsnorm(lp["ln_x"], x, cfg.norm_eps)
            ek = (enc @ lp["cross_attn"]["wk"]).reshape(b, te, Hkv, hd)
            ev = (enc @ lp["cross_attn"]["wv"]).reshape(b, te, Hkv, hd)
            h = cross_attention(lp["cross_attn"], hx, ek, ev, None,
                                n_heads=H, n_kv_heads=Hkv, head_dim=hd)
            x = x + h
            h = mlp_apply(lp["mlp"], rmsnorm(lp["ln2"], x, cfg.norm_eps),
                          cfg.act)
            st = {"self": kv, "cross_k": ek.astype(cache_dtype),
                  "cross_v": ev.astype(cache_dtype)}
            return x + h, st

        dummy = init_cache(b, max_len, te)
        x, cache = scan_layers_with_cache(body, x, params["dec_layers"],
                                          dummy)
        h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return h[:, -1] @ params["lm_head"], cache

    def decode_step(params, tokens, cache):
        b = tokens.shape[0]
        cur = cache["self"]["pos"][0]
        pos = jnp.broadcast_to(cur, (b, 1)).astype(jnp.int32)
        cos, sin = rope_cos_sin(pos, hd, cfg.rope_theta)
        x = params["embed"][tokens][:, None].astype(compute_dtype)

        def body(x, lp, st):
            h, kv = gqa_decode(lp["self_attn"],
                               rmsnorm(lp["ln1"], x, cfg.norm_eps), st["self"],
                               cos, sin, n_heads=H, n_kv_heads=Hkv,
                               head_dim=hd)
            x = x + h
            hx = rmsnorm(lp["ln_x"], x, cfg.norm_eps)
            h = cross_attention(lp["cross_attn"], hx,
                                st["cross_k"].astype(x.dtype),
                                st["cross_v"].astype(x.dtype), None,
                                n_heads=H, n_kv_heads=Hkv, head_dim=hd)
            x = x + h
            h = mlp_apply(lp["mlp"], rmsnorm(lp["ln2"], x, cfg.norm_eps),
                          cfg.act)
            return x + h, dict(st, self=kv)

        x, new_cache = scan_layers_with_cache(body, x, params["dec_layers"],
                                              cache)
        h = rmsnorm(params["final_norm"], x[:, 0], cfg.norm_eps)
        return h @ params["lm_head"], new_cache

    return ModelBundle(cfg=cfg, init=init, loss_fn=loss_fn, prefill=prefill,
                       decode_step=decode_step, init_cache=init_cache,
                       forward=None)
