"""Feed-forward blocks: SwiGLU (3-matrix) and classic 2-matrix MLPs."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import Params, activation, dense_init


def mlp_init(key, d_model: int, d_ff: int, act: str = "silu",
             dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    if act == "silu":  # SwiGLU
        return {
            "w_gate": dense_init(ks[0], d_model, d_ff, dtype),
            "w_up": dense_init(ks[1], d_model, d_ff, dtype),
            "w_down": dense_init(ks[2], d_ff, d_model, dtype),
        }
    return {
        "w_up": dense_init(ks[0], d_model, d_ff, dtype),
        "w_down": dense_init(ks[1], d_ff, d_model, dtype),
    }


def mlp_apply(p: Params, x: jax.Array, act: str = "silu") -> jax.Array:
    fn = activation(act)
    if "w_gate" in p:
        return (fn(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    return fn(x @ p["w_up"]) @ p["w_down"]
