"""Selective SSM (Mamba-style) head used by the Hymba hybrid block.

    h_t = exp(dt_t * A) ⊙ h_{t-1} + dt_t * (B_t ⊗ u_t)
    y_t = C_t · h_t + D ⊙ u_t

with A diagonal (negative), and (dt, B, C) input-dependent ("selective").
Includes the causal depthwise conv1d front (kernel 4) with carried conv
state for decode.  Full-sequence path is a `lax.scan` over time (on TPU the
chunked-kernel pattern demonstrated by kernels/rwkv6_wkv.py applies; the SSM
scan shares its structure).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import Params, dense_init

CONV_K = 4
DT_RANK_DIV = 16


def mamba_init(key, d_model: int, d_inner: int, state: int,
               dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 7)
    dt_rank = max(1, d_model // DT_RANK_DIV)
    return {
        "in_proj": dense_init(ks[0], d_model, 2 * d_inner, dtype),
        "conv_w": (0.1 * jax.random.normal(ks[1], (CONV_K, d_inner))).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "x_proj": dense_init(ks[2], d_inner, dt_rank + 2 * state, dtype),
        "dt_proj": dense_init(ks[3], dt_rank, d_inner, dtype),
        "dt_bias": jnp.full((d_inner,), -4.6, dtype),  # softplus^-1(0.01)
        "A_log": jnp.log(jnp.tile(jnp.arange(1, state + 1, dtype=jnp.float32),
                                  (d_inner, 1))).astype(dtype),
        "D": jnp.ones((d_inner,), dtype),
        "out_proj": dense_init(ks[4], d_inner, d_model, dtype),
    }


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array,
                 conv_state=None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. u [B,S,C]; w [K,C].  Returns (y, tail [B,K-1,C])."""
    if conv_state is None:
        pad = jnp.zeros_like(u[:, : CONV_K - 1])
    else:
        pad = conv_state.astype(u.dtype)
    ext = jnp.concatenate([pad, u], axis=1)
    y = sum(ext[:, i:i + u.shape[1]] * w[i] for i in range(CONV_K))
    return jax.nn.silu(y + b), ext[:, -(CONV_K - 1):].astype(jnp.float32)


def _ssm_params(p: Params, u: jax.Array, state: int):
    dt_rank = p["dt_proj"].shape[0]
    proj = u @ p["x_proj"]
    dt = jax.nn.softplus(proj[..., :dt_rank] @ p["dt_proj"]
                         + p["dt_bias"]).astype(jnp.float32)     # [B,S,Ci]
    B = proj[..., dt_rank:dt_rank + state].astype(jnp.float32)   # [B,S,N]
    C = proj[..., dt_rank + state:].astype(jnp.float32)          # [B,S,N]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                 # [Ci,N]
    return dt, B, C, A


def mamba_apply(p: Params, x: jax.Array, *, state: int,
                ssm_state=None, conv_state=None, chunk: int = 256):
    """Full-sequence selective scan, time-chunked.

    The naive formulation materializes dA/dBu [B,S,Ci,N] (gigabytes at 4k
    seq).  We scan over sequence CHUNKS with a rematerialized chunk body:
    dA/dBu exist only per chunk ([B,chunk,Ci,N]) and the backward pass
    recomputes them, storing only the [B,Ci,N] states at chunk boundaries.
    """
    b, s, _ = x.shape
    ui = x @ p["in_proj"]
    d_inner = ui.shape[-1] // 2
    u, z = ui[..., :d_inner], ui[..., d_inner:]
    u, conv_tail = _causal_conv(u, p["conv_w"], p["conv_b"], conv_state)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))        # [Ci,N]

    if ssm_state is None:
        h0 = jnp.zeros((b, d_inner, state), jnp.float32)
    else:
        h0 = ssm_state

    def chunk_body(h, u_c):
        """u_c [B, tc, Ci] -> (h_end, y_c [B, tc, Ci])."""
        dt, Bm, Cm, _ = _ssm_params(p, u_c, state)
        dA = jnp.exp(dt[..., None] * A)                 # [B,tc,Ci,N]
        dBu = (dt * u_c.astype(jnp.float32))[..., None] * Bm[:, :, None]

        def step(hh, inp):
            dA_t, dBu_t, C_t = inp
            hh = dA_t * hh + dBu_t
            return hh, jnp.einsum("bcn,bn->bc", hh, C_t)

        hT, ys = jax.lax.scan(step, h,
                              (dA.swapaxes(0, 1), dBu.swapaxes(0, 1),
                               Cm.swapaxes(0, 1)))
        return hT, ys.swapaxes(0, 1)

    tc = min(chunk, s)
    if s % tc == 0 and s > tc:
        nc = s // tc
        uc = jnp.moveaxis(u.reshape(b, nc, tc, d_inner), 1, 0)
        hT, ys = jax.lax.scan(jax.checkpoint(chunk_body), h0, uc)
        y = jnp.moveaxis(ys, 0, 1).reshape(b, s, d_inner)
    else:
        hT, y = chunk_body(h0, u)
    y = y.astype(x.dtype)
    y = y + u * p["D"].astype(u.dtype)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"], hT, conv_tail


def mamba_decode(p: Params, x: jax.Array, states: Dict[str, jax.Array], *,
                 state: int):
    """One token. x [B,1,d]; states {ssm [B,Ci,N], conv [B,K-1,Ci]}."""
    ui = x @ p["in_proj"]
    d_inner = ui.shape[-1] // 2
    u, z = ui[..., :d_inner], ui[..., d_inner:]
    u, conv_tail = _causal_conv(u, p["conv_w"], p["conv_b"], states["conv"])
    dt, B, C, A = _ssm_params(p, u, state)
    dA = jnp.exp(dt[:, 0, :, None] * A)                 # [B,Ci,N]
    dBu = (dt[:, 0] * u[:, 0].astype(jnp.float32))[..., None] * B[:, 0, None]
    h = dA * states["ssm"] + dBu
    y = jnp.einsum("bcn,bn->bc", h, C[:, 0])[:, None].astype(x.dtype)
    y = y + u * p["D"].astype(u.dtype)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"], {"ssm": h, "conv": conv_tail}


def init_mamba_state(batch: int, d_inner: int, state: int):
    return {
        "ssm": jnp.zeros((batch, d_inner, state), jnp.float32),
        "conv": jnp.zeros((batch, CONV_K - 1, d_inner), jnp.float32),
    }
