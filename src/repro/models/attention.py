"""Attention variants: GQA (full / sliding-window) and MLA (DeepSeek-V2).

Three entry modes per variant:
  * train:   full-sequence causal self-attention (no cache)
  * prefill: same compute as train, but also returns a populated KV cache
  * decode:  one new token against an existing cache

Caches:
  * full cache   — [B, max_len, Hkv, Dh]; slot i valid iff i < pos
  * rolling cache — [B, window, Hkv, Dh]; write at pos % window (sub-quadratic
    memory for long_500k on full-attention archs)
  * MLA cache    — compressed latents [B, T, kv_lora] + shared rope key
                   [B, T, rope_dim]; decode uses the absorbed formulation
                   (q and out projections folded through the latent space) so
                   per-step compute is O(T * kv_lora), never materializing K/V.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import Params, apply_rope, dense_init, rmsnorm, rmsnorm_init

NEG_INF = -1.0e30


# ===================================================================== #
# shared masked attention core (XLA path; Pallas path in kernels/)
# ===================================================================== #

def _gqa_scores_attend(q, k, v, mask, scale):
    """q [B,S,Hq,D], k/v [B,T,Hkv,D], mask [B,1,S,T] bool -> [B,S,Hq,D]."""
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    q = q.reshape(b, s, hkv, g, d)
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k).astype(jnp.float32) * scale
    scores = jnp.where(mask[:, :, None], scores, NEG_INF)  # [B,1,1,S,T] bcast
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, hq, d)


def causal_mask(s: int, t: int, window: int = 0, q_offset: int = 0) -> jax.Array:
    """[s, t] bool mask; query i (global pos q_offset+i) sees key j iff
    j <= pos and (window == 0 or pos - j < window)."""
    qpos = jnp.arange(s)[:, None] + q_offset
    kpos = jnp.arange(t)[None, :]
    m = kpos <= qpos
    if window:
        m &= (qpos - kpos) < window
    return m


def _pick_q_chunk(t: int) -> int:
    """Bound the per-chunk score tensor to ~4M elements per (b, head)."""
    return max(64, min(1024, (1 << 22) // max(t, 1)))


def _chunked_causal_attend(q, k, v, *, window: int, scale, q_chunk: int):
    """Query-chunked attention (XLA stand-in for the flash kernel): scores
    are materialized only [.., q_chunk, T] at a time via a sequential
    ``lax.map`` over query blocks."""
    b, s, hq, d = q.shape
    t = k.shape[1]
    nc = s // q_chunk
    qs = jnp.moveaxis(q.reshape(b, nc, q_chunk, hq, d), 1, 0)
    idx = jnp.arange(nc)

    @jax.checkpoint
    def one(args):
        qi, i = args
        m = causal_mask(q_chunk, t, window, q_offset=i * q_chunk)
        m = jnp.broadcast_to(m[None, None], (b, 1, q_chunk, t))
        return _gqa_scores_attend(qi, k, v, m, scale)

    out = jax.lax.map(one, (qs, idx))
    return jnp.moveaxis(out, 0, 1).reshape(b, s, hq, d)


def full_attention(q, k, v, *, causal: bool = True, window: int = 0,
                   q_offset: int = 0, extra_mask: Optional[jax.Array] = None,
                   scale: Optional[float] = None, impl: str = "xla"):
    """Dispatchable attention; ``impl`` in {"xla", "pallas", "pallas_interpret"}."""
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d).astype(jnp.float32)
    # q_offset may be a traced scalar (paged chunk prefill) — only the
    # static-zero case is eligible for the offset-free fast paths
    static_zero_offset = isinstance(q_offset, int) and q_offset == 0
    if impl.startswith("pallas") and causal and extra_mask is None \
            and static_zero_offset:
        from repro.kernels import ops as kops
        return kops.flash_attention(
            q, k, v, causal=True, window=window, scale=float(scale),
            interpret=(impl == "pallas_interpret"))
    b, s, _, _ = q.shape
    t = k.shape[1]
    q_chunk = _pick_q_chunk(t)
    if (causal and extra_mask is None and static_zero_offset
            and s >= 2 * q_chunk and s % q_chunk == 0):
        return _chunked_causal_attend(q, k, v, window=window, scale=scale,
                                      q_chunk=q_chunk)
    if causal:
        m = causal_mask(s, t, window, q_offset)[None, None]
        m = jnp.broadcast_to(m, (b, 1, s, t))
    else:
        m = jnp.ones((b, 1, s, t), bool)
    if extra_mask is not None:
        m = m & extra_mask
    return _gqa_scores_attend(q, k, v, m, scale)


# ===================================================================== #
# GQA
# ===================================================================== #

def gqa_init(key, d_model: int, n_heads: int, n_kv_heads: int, head_dim: int,
             dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d_model, n_heads * head_dim, dtype),
        "wk": dense_init(ks[1], d_model, n_kv_heads * head_dim, dtype),
        "wv": dense_init(ks[2], d_model, n_kv_heads * head_dim, dtype),
        "wo": dense_init(ks[3], n_heads * head_dim, d_model, dtype),
    }


def _project_qkv(p: Params, x, n_heads, n_kv_heads, head_dim):
    b, s, _ = x.shape
    q = (x @ p["wq"]).reshape(b, s, n_heads, head_dim)
    k = (x @ p["wk"]).reshape(b, s, n_kv_heads, head_dim)
    v = (x @ p["wv"]).reshape(b, s, n_kv_heads, head_dim)
    return q, k, v


def gqa_attention(p: Params, x, cos, sin, *, n_heads: int, n_kv_heads: int,
                  head_dim: int, causal: bool = True, window: int = 0,
                  impl: str = "xla") -> jax.Array:
    """Train/prefill full-sequence path. cos/sin [B,S,head_dim//2]."""
    q, k, v = _project_qkv(p, x, n_heads, n_kv_heads, head_dim)
    if cos is not None:
        q = apply_rope(q, cos[:, :, None], sin[:, :, None])
        k = apply_rope(k, cos[:, :, None], sin[:, :, None])
    out = full_attention(q, k, v, causal=causal, window=window, impl=impl)
    return out.reshape(x.shape[0], x.shape[1], n_heads * head_dim) @ p["wo"]


def cross_attention(p: Params, x, enc_k, enc_v, enc_mask, *, n_heads: int,
                    n_kv_heads: int, head_dim: int) -> jax.Array:
    """Decoder cross-attn; enc_k/enc_v [B,Te,Hkv,D] precomputed."""
    b, s, _ = x.shape
    q = (x @ p["wq"]).reshape(b, s, n_heads, head_dim)
    m = None
    if enc_mask is not None:
        m = enc_mask[:, None, None, :]  # [B,1,1,Te]
        m = jnp.broadcast_to(m, (b, 1, s, enc_k.shape[1]))
    out = full_attention(q, enc_k, enc_v, causal=False, extra_mask=m)
    return out.reshape(b, s, n_heads * head_dim) @ p["wo"]


# --------------------------- caches ---------------------------------- #

def init_kv_cache(batch: int, max_len: int, n_kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16, rolling: bool = False,
                  window: int = 0) -> Dict[str, Any]:
    length = window if rolling else max_len
    return {
        "k": jnp.zeros((batch, length, n_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, length, n_kv_heads, head_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),       # tokens written so far
    }


def gqa_decode(p: Params, x, cache: Dict[str, Any], cos, sin, *,
               n_heads: int, n_kv_heads: int, head_dim: int,
               rolling: bool = False
               ) -> Tuple[jax.Array, Dict[str, Any]]:
    """One-token decode. x [B,1,d]; cos/sin [B,1,head_dim//2] at current pos.

    ``rolling`` is static: True means the cache is a circular window buffer.
    """
    b = x.shape[0]
    q, k, v = _project_qkv(p, x, n_heads, n_kv_heads, head_dim)
    if cos is not None:
        q = apply_rope(q, cos[:, :, None], sin[:, :, None])
        k = apply_rope(k, cos[:, :, None], sin[:, :, None])
    pos = cache["pos"]
    length = cache["k"].shape[1]
    slot = pos % length if rolling else pos
    new_k = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
    new_v = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
    # validity: slot i holds a real token iff i <= pos (non-rolling) or
    # i < min(pos+1, length) once the rolling buffer may have wrapped
    idx = jnp.arange(length)
    if rolling:
        valid = idx < jnp.minimum(pos + 1, length)
    else:
        valid = idx <= pos
    mask = jnp.broadcast_to(valid[None, None, None, :], (b, 1, 1, length))
    out = full_attention(q, new_k.astype(q.dtype), new_v.astype(q.dtype),
                         causal=False, extra_mask=mask)
    out = out.reshape(b, 1, n_heads * head_dim) @ p["wo"]
    new_cache = dict(cache, k=new_k, v=new_v, pos=pos + 1)
    return out, new_cache


def prefill_kv_cache(p: Params, x, cos, sin, *, n_heads, n_kv_heads, head_dim,
                     max_len: int, dtype=jnp.bfloat16, rolling: bool = False,
                     window: int = 0):
    """Compute roped K/V for the prompt and lay them into a fresh cache."""
    b, s, _ = x.shape
    _, k, v = _project_qkv(p, x, n_heads, n_kv_heads, head_dim)
    if cos is not None:
        k = apply_rope(k, cos[:, :, None], sin[:, :, None])
    cache = init_kv_cache(b, max_len, n_kv_heads, head_dim, dtype,
                          rolling=rolling, window=window)
    if rolling:
        keep = min(s, window)
        k, v = k[:, -keep:], v[:, -keep:]
        s_eff = keep
    else:
        s_eff = s
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(dtype), (0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(dtype), (0, 0, 0, 0))
    cache["pos"] = jnp.asarray(s_eff if rolling else s, jnp.int32)
    return cache


# --------------------------- paged cache ------------------------------ #
#
# The serving engine's paged layout (serve/kvcache.py): K/V live in a
# global pool of fixed-size pages, [Hkv, P, page, D] per layer (head-major
# so the flash-decode kernel streams one (page, D) tile per grid step);
# each sequence owns an ordered block table of page ids.  Page 0 is the
# null page — unallocated table entries point at it and inactive slots'
# writes are directed there.

def init_paged_kv(n_pages: int, page_size: int, n_kv_heads: int,
                  head_dim: int, dtype=jnp.bfloat16) -> Dict[str, Any]:
    return {
        "k": jnp.zeros((n_kv_heads, n_pages, page_size, head_dim), dtype),
        "v": jnp.zeros((n_kv_heads, n_pages, page_size, head_dim), dtype),
    }


def paged_slot_coords(block_tables, lengths, active, page_size: int):
    """(page_ids [B], offsets [B]) where each slot's NEXT token is written;
    inactive slots are redirected to the null page 0."""
    idx = lengths // page_size
    page_ids = jnp.take_along_axis(block_tables, idx[:, None], axis=1)[:, 0]
    page_ids = jnp.where(active, page_ids, 0)
    return page_ids, lengths % page_size


def gqa_decode_paged(p: Params, x, pages: Dict[str, Any], block_tables,
                     lengths, active, cos, sin, *, n_heads: int,
                     n_kv_heads: int, head_dim: int, window: int = 0,
                     impl: str = "auto"
                     ) -> Tuple[jax.Array, Dict[str, Any]]:
    """One-token decode against a paged pool (per-slot positions).

    x [B,1,d]; block_tables [B, max_pages] int32; lengths [B] int32 —
    tokens cached so far per slot (the new token is written at position
    ``lengths`` and the attend covers ``lengths + active`` tokens);
    active [B] bool masks serving slots that are mid-sequence.  Unlike
    the dense ``gqa_decode`` (one shared scalar ``pos``), every slot
    advances independently — the property continuous batching needs.
    ``impl`` routes the attend through kernels/ops.py::flash_decode.
    """
    b = x.shape[0]
    q, k, v = _project_qkv(p, x, n_heads, n_kv_heads, head_dim)
    if cos is not None:
        q = apply_rope(q, cos[:, :, None], sin[:, :, None])
        k = apply_rope(k, cos[:, :, None], sin[:, :, None])
    page = pages["k"].shape[2]
    page_ids, offs = paged_slot_coords(block_tables, lengths, active, page)
    # [B,1,Hkv,D] -> [Hkv,B,D] scatter rows into (page_id, offset) slots
    new_k = pages["k"].at[:, page_ids, offs].set(
        k[:, 0].transpose(1, 0, 2).astype(pages["k"].dtype))
    new_v = pages["v"].at[:, page_ids, offs].set(
        v[:, 0].transpose(1, 0, 2).astype(pages["v"].dtype))
    from repro.kernels import ops as kops
    att_len = lengths + active.astype(lengths.dtype)
    out = kops.flash_decode(q[:, 0], new_k, new_v, block_tables, att_len,
                            window=window, impl=impl)
    out = out.reshape(b, 1, n_heads * head_dim).astype(x.dtype) @ p["wo"]
    return out, {"k": new_k, "v": new_v}


def gqa_prefill_paged_chunk(p: Params, x, pages: Dict[str, Any],
                            block_tables, base, cos, sin, *, n_heads: int,
                            n_kv_heads: int, head_dim: int, window: int = 0
                            ) -> Tuple[jax.Array, Dict[str, Any]]:
    """One prompt chunk of a paged prefill.

    x [B,C,d] — chunk tokens at global positions base..base+C-1 (``base``
    may be traced, so any chunk count compiles once); K/V are written
    into the chunk's pages, then the chunk queries attend every cached
    position (earlier chunks + causal within this one) through the
    gathered pool.  The padded tail of the final chunk writes garbage
    past the true length — masked out of every later attend and
    overwritten by decode, exactly like unreached dense-cache slots.
    """
    b, c, _ = x.shape
    q, k, v = _project_qkv(p, x, n_heads, n_kv_heads, head_dim)
    if cos is not None:
        q = apply_rope(q, cos[:, :, None], sin[:, :, None])
        k = apply_rope(k, cos[:, :, None], sin[:, :, None])
    page = pages["k"].shape[2]
    pos = base + jnp.arange(c)                        # [C]
    tbl = jnp.broadcast_to(block_tables, (b, block_tables.shape[1]))
    page_ids = jnp.take_along_axis(tbl, pos[None] // page, axis=1)  # [B,C]
    offs = pos % page
    # [B,C,Hkv,D] -> per batch row scatter [Hkv, B, C, D]
    new_k = pages["k"].at[:, page_ids, offs[None]].set(
        k.transpose(2, 0, 1, 3).astype(pages["k"].dtype))
    new_v = pages["v"].at[:, page_ids, offs[None]].set(
        v.transpose(2, 0, 1, 3).astype(pages["v"].dtype))
    from repro.kernels import ref as kref
    kd = kref.gather_pages(new_k, tbl).astype(q.dtype)   # [B,T,Hkv,D]
    vd = kref.gather_pages(new_v, tbl).astype(q.dtype)
    out = full_attention(q, kd, vd, causal=True, window=window,
                         q_offset=base)
    out = out.reshape(b, c, n_heads * head_dim) @ p["wo"]
    return out, {"k": new_k, "v": new_v}


# ===================================================================== #
# MLA (Multi-head Latent Attention, DeepSeek-V2)
# ===================================================================== #

def mla_init(key, d_model: int, n_heads: int, kv_lora: int, qk_nope: int,
             qk_rope: int, v_dim: int, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], d_model, n_heads * (qk_nope + qk_rope), dtype),
        "w_dkv": dense_init(ks[1], d_model, kv_lora, dtype),
        "w_kr": dense_init(ks[2], d_model, qk_rope, dtype),
        "kv_norm": rmsnorm_init(kv_lora, dtype),
        "w_uk": dense_init(ks[3], kv_lora, n_heads * qk_nope, dtype),
        "w_uv": dense_init(ks[4], kv_lora, n_heads * v_dim, dtype),
        "wo": dense_init(ks[5], n_heads * v_dim, d_model, dtype),
    }


def _mla_q(p, x, n_heads, qk_nope, qk_rope, cos, sin):
    b, s, _ = x.shape
    q = (x @ p["wq"]).reshape(b, s, n_heads, qk_nope + qk_rope)
    q_nope, q_rope = q[..., :qk_nope], q[..., qk_nope:]
    q_rope = apply_rope(q_rope, cos[:, :, None], sin[:, :, None])
    return q_nope, q_rope


def _mla_latents(p, x, cos, sin, eps):
    ckv = rmsnorm({"scale": p["kv_norm"]["scale"]}, x @ p["w_dkv"], eps)
    kr = apply_rope((x @ p["w_kr"])[:, :, None, :], cos[:, :, None],
                    sin[:, :, None])[:, :, 0]
    return ckv, kr


def mla_attention(p: Params, x, cos, sin, *, n_heads: int, kv_lora: int,
                  qk_nope: int, qk_rope: int, v_dim: int,
                  eps: float = 1e-5) -> jax.Array:
    """Train/prefill: decompress latents into per-head K/V (standard path)."""
    b, s, _ = x.shape
    q_nope, q_rope = _mla_q(p, x, n_heads, qk_nope, qk_rope, cos, sin)
    ckv, kr = _mla_latents(p, x, cos, sin, eps)
    k_nope = (ckv @ p["w_uk"]).reshape(b, s, n_heads, qk_nope)
    v = (ckv @ p["w_uv"]).reshape(b, s, n_heads, v_dim)
    scale = 1.0 / jnp.sqrt(float(qk_nope + qk_rope))

    def attend_block(qn, qr, offset):
        """qn [b, qc, H, nope]; offset: first query position."""
        qc = qn.shape[1]
        mask = causal_mask(qc, s, 0, q_offset=offset)[None, None]
        scores = (jnp.einsum("bshd,bthd->bhst", qn, k_nope)
                  + jnp.einsum("bshd,btd->bhst", qr, kr)
                  ).astype(jnp.float32)
        scores = jnp.where(mask, scores * scale, NEG_INF)
        probs = jax.nn.softmax(scores, -1).astype(v.dtype)
        return jnp.einsum("bhst,bthd->bshd", probs, v)

    q_chunk = _pick_q_chunk(s)
    if s >= 2 * q_chunk and s % q_chunk == 0:
        nc = s // q_chunk
        qns = jnp.moveaxis(q_nope.reshape(b, nc, q_chunk, n_heads, qk_nope),
                           1, 0)
        qrs = jnp.moveaxis(q_rope.reshape(b, nc, q_chunk, n_heads, qk_rope),
                           1, 0)
        out = jax.lax.map(
            jax.checkpoint(
                lambda a: attend_block(a[0], a[1], a[2] * q_chunk)),
            (qns, qrs, jnp.arange(nc)))
        out = jnp.moveaxis(out, 0, 1).reshape(b, s, n_heads * v_dim)
    else:
        out = attend_block(q_nope, q_rope, 0).reshape(b, s, n_heads * v_dim)
    return out @ p["wo"]


def init_mla_cache(batch: int, max_len: int, kv_lora: int, qk_rope: int,
                   dtype=jnp.bfloat16) -> Dict[str, Any]:
    return {
        "ckv": jnp.zeros((batch, max_len, kv_lora), dtype),
        "k_rope": jnp.zeros((batch, max_len, qk_rope), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def mla_prefill_cache(p: Params, x, cos, sin, *, max_len: int, eps: float,
                      dtype=jnp.bfloat16) -> Dict[str, Any]:
    b, s, _ = x.shape
    ckv, kr = _mla_latents(p, x, cos, sin, eps)
    cache = init_mla_cache(b, max_len, ckv.shape[-1], kr.shape[-1], dtype)
    cache["ckv"] = jax.lax.dynamic_update_slice(
        cache["ckv"], ckv.astype(dtype), (0, 0, 0))
    cache["k_rope"] = jax.lax.dynamic_update_slice(
        cache["k_rope"], kr.astype(dtype), (0, 0, 0))
    cache["pos"] = jnp.asarray(s, jnp.int32)
    return cache


def mla_decode(p: Params, x, cache, cos, sin, *, n_heads: int, kv_lora: int,
               qk_nope: int, qk_rope: int, v_dim: int, eps: float = 1e-5
               ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Absorbed decode: score/value computed in latent space.

    per-step FLOPs ~ O(T * kv_lora * H) with NO K/V materialization — this is
    the production MLA decode and the reason long_500k is feasible with a
    full (non-windowed) cache for deepseek-v2-lite.
    """
    b = x.shape[0]
    q_nope, q_rope = _mla_q(p, x, n_heads, qk_nope, qk_rope, cos, sin)  # [B,1,H,*]
    ckv_new, kr_new = _mla_latents(p, x, cos, sin, eps)                  # [B,1,*]
    pos = cache["pos"]
    ckv = jax.lax.dynamic_update_slice(
        cache["ckv"], ckv_new.astype(cache["ckv"].dtype), (0, pos, 0))
    krc = jax.lax.dynamic_update_slice(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), (0, pos, 0))
    t = ckv.shape[1]
    # absorb w_uk into q:  q_lat [B,H,lora]
    w_uk = p["w_uk"].reshape(kv_lora, n_heads, qk_nope)
    q_lat = jnp.einsum("bhd,lhd->bhl", q_nope[:, 0], w_uk)
    scale = 1.0 / jnp.sqrt(float(qk_nope + qk_rope))
    scores = (jnp.einsum("bhl,btl->bht", q_lat, ckv.astype(q_lat.dtype))
              + jnp.einsum("bhd,btd->bht", q_rope[:, 0],
                           krc.astype(q_rope.dtype))).astype(jnp.float32)
    valid = (jnp.arange(t) <= pos)[None, None, :]
    scores = jnp.where(valid, scores * scale, NEG_INF)
    probs = jax.nn.softmax(scores, -1).astype(ckv.dtype)
    ctx_lat = jnp.einsum("bht,btl->bhl", probs, ckv)                # [B,H,lora]
    w_uv = p["w_uv"].reshape(kv_lora, n_heads, v_dim)
    out = jnp.einsum("bhl,lhv->bhv", ctx_lat.astype(x.dtype), w_uv)
    out = out.reshape(b, 1, n_heads * v_dim) @ p["wo"]
    new_cache = dict(cache, ckv=ckv, k_rope=krc, pos=pos + 1)
    return out, new_cache


# --------------------------- paged MLA -------------------------------- #
#
# Latent pages have no head axis — the pool is [P, page, kv_lora] (+ the
# shared rope key [P, page, qk_rope]), so paging the MLA cache is the same
# block-table indirection at ~1/8 the bytes of a GQA pool.  Both the
# decode step and the chunk prefill use the absorbed formulation (scores
# and context in latent space, K/V never materialized).

def init_paged_mla(n_pages: int, page_size: int, kv_lora: int,
                   qk_rope: int, dtype=jnp.bfloat16) -> Dict[str, Any]:
    return {
        "ckv": jnp.zeros((n_pages, page_size, kv_lora), dtype),
        "kr": jnp.zeros((n_pages, page_size, qk_rope), dtype),
    }


def _gather_latent(pages: jax.Array, block_tables: jax.Array) -> jax.Array:
    """pages [P, page, R], tables [B, maxp] -> dense [B, maxp*page, R]."""
    b, maxp = block_tables.shape
    page, r = pages.shape[1], pages.shape[2]
    return pages[block_tables].reshape(b, maxp * page, r)


def _mla_absorbed_attend(p, q_nope, q_rope, ckv_d, kr_d, mask, *,
                         n_heads, kv_lora, qk_nope, qk_rope, v_dim):
    """Absorbed-latent attention for S queries.

    q_nope [B,S,H,nope], q_rope [B,S,H,rope]; ckv_d [B,T,lora],
    kr_d [B,T,rope]; mask [B,S,T] bool.  Rows with no valid key (inactive
    serving slots) output zeros.  Returns [B, S, H*v_dim].
    """
    b, s = q_nope.shape[:2]
    w_uk = p["w_uk"].reshape(kv_lora, n_heads, qk_nope)
    q_lat = jnp.einsum("bshd,lhd->bshl", q_nope, w_uk)
    scale = 1.0 / jnp.sqrt(float(qk_nope + qk_rope))
    scores = (jnp.einsum("bshl,btl->bhst", q_lat,
                         ckv_d.astype(q_lat.dtype))
              + jnp.einsum("bshd,btd->bhst", q_rope,
                           kr_d.astype(q_rope.dtype))).astype(jnp.float32)
    scores = jnp.where(mask[:, None], scores * scale, NEG_INF)
    probs = jax.nn.softmax(scores, -1).astype(ckv_d.dtype)
    ctx = jnp.einsum("bhst,btl->bshl", probs, ckv_d)
    ctx = jnp.where(mask.any(-1)[:, :, None, None], ctx, 0.0)
    w_uv = p["w_uv"].reshape(kv_lora, n_heads, v_dim)
    out = jnp.einsum("bshl,lhv->bshv", ctx.astype(q_nope.dtype), w_uv)
    return out.reshape(b, s, n_heads * v_dim)


def mla_decode_paged(p: Params, x, pages: Dict[str, Any], block_tables,
                     lengths, active, cos, sin, *, n_heads: int,
                     kv_lora: int, qk_nope: int, qk_rope: int, v_dim: int,
                     eps: float = 1e-5
                     ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Absorbed one-token decode against latent pages (per-slot lengths)."""
    b = x.shape[0]
    q_nope, q_rope = _mla_q(p, x, n_heads, qk_nope, qk_rope, cos, sin)
    ckv_new, kr_new = _mla_latents(p, x, cos, sin, eps)        # [B,1,*]
    page = pages["ckv"].shape[1]
    page_ids, offs = paged_slot_coords(block_tables, lengths, active, page)
    ckv = pages["ckv"].at[page_ids, offs].set(
        ckv_new[:, 0].astype(pages["ckv"].dtype))
    kr = pages["kr"].at[page_ids, offs].set(
        kr_new[:, 0].astype(pages["kr"].dtype))
    ckv_d = _gather_latent(ckv, block_tables)
    kr_d = _gather_latent(kr, block_tables)
    att_len = lengths + active.astype(lengths.dtype)
    mask = (jnp.arange(ckv_d.shape[1])[None] < att_len[:, None])[:, None]
    out = _mla_absorbed_attend(p, q_nope, q_rope, ckv_d, kr_d, mask,
                               n_heads=n_heads, kv_lora=kv_lora,
                               qk_nope=qk_nope, qk_rope=qk_rope,
                               v_dim=v_dim)
    return out.astype(x.dtype) @ p["wo"], {"ckv": ckv, "kr": kr}


def mla_prefill_paged_chunk(p: Params, x, pages: Dict[str, Any],
                            block_tables, base, cos, sin, *, n_heads: int,
                            kv_lora: int, qk_nope: int, qk_rope: int,
                            v_dim: int, eps: float = 1e-5
                            ) -> Tuple[jax.Array, Dict[str, Any]]:
    """One prompt chunk of a paged MLA prefill (see gqa_prefill_paged_chunk)."""
    b, c, _ = x.shape
    q_nope, q_rope = _mla_q(p, x, n_heads, qk_nope, qk_rope, cos, sin)
    ckv_new, kr_new = _mla_latents(p, x, cos, sin, eps)        # [B,C,*]
    page = pages["ckv"].shape[1]
    pos = base + jnp.arange(c)
    tbl = jnp.broadcast_to(block_tables, (b, block_tables.shape[1]))
    page_ids = jnp.take_along_axis(tbl, pos[None] // page, axis=1)  # [B,C]
    offs = jnp.broadcast_to(pos % page, (b, c))
    ckv = pages["ckv"].at[page_ids, offs].set(
        ckv_new.astype(pages["ckv"].dtype))
    kr = pages["kr"].at[page_ids, offs].set(
        kr_new.astype(pages["kr"].dtype))
    ckv_d = _gather_latent(ckv, tbl)
    kr_d = _gather_latent(kr, tbl)
    kpos = jnp.arange(ckv_d.shape[1])[None, None]              # [1,1,T]
    mask = jnp.broadcast_to(kpos <= pos[None, :, None],
                            (b, c, ckv_d.shape[1]))
    out = _mla_absorbed_attend(p, q_nope, q_rope, ckv_d, kr_d, mask,
                               n_heads=n_heads, kv_lora=kv_lora,
                               qk_nope=qk_nope, qk_rope=qk_rope,
                               v_dim=v_dim)
    return out.astype(x.dtype) @ p["wo"], {"ckv": ckv, "kr": kr}
