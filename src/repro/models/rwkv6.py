"""RWKV-6 "Finch" blocks: time-mix with data-dependent decay + channel-mix.

The WKV6 recurrence per head (head size Dh, state S in R^{Dh x Dh}):

    y_t[i]   = sum_j r_t[j] * ( S_t[j,i] + u[j] * k_t[j] * v_t[i] )
    S_{t+1}  = diag(w_t) S_t + k_t^T v_t          (w_t = data-dependent decay)

Training/prefill run the recurrence through ``kernels.ops.rwkv6_wkv`` (Pallas
chunked kernel on TPU; pure-jnp oracle elsewhere).  Decode carries the
[B, H, Dh, Dh] state — O(1) per token, which is why long_500k is native.

Token-shift mixing uses the paper's ddlerp (dynamic low-rank interpolation
between the current and previous token).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (Params, dense_init, layernorm,
                                 layernorm_init, rmsnorm, rmsnorm_init)

LORA_DIM = 32
DECAY_LORA_DIM = 64
MIX_NAMES = ("w", "k", "v", "r", "g")


def timemix_init(key, d_model: int, n_heads: int, head_dim: int,
                 dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 12)
    d_attn = n_heads * head_dim
    p: Params = {
        # static token-shift interpolants
        "mu_x": jnp.full((d_model,), 0.5, dtype),
        "mu": jnp.full((5, d_model), 0.5, dtype),
        # ddlerp low-rank (shared A, per-target B)
        "mix_A": dense_init(ks[0], d_model, 5 * LORA_DIM, dtype, scale=1e-2),
        "mix_B": dense_init(ks[1], LORA_DIM, 5 * d_model, dtype, scale=1e-2),
        # projections
        "wr": dense_init(ks[2], d_model, d_attn, dtype),
        "wk": dense_init(ks[3], d_model, d_attn, dtype),
        "wv": dense_init(ks[4], d_model, d_attn, dtype),
        "wg": dense_init(ks[5], d_model, d_attn, dtype),
        "wo": dense_init(ks[6], d_attn, d_model, dtype),
        # data-dependent decay
        "decay_base": jnp.linspace(-6.0, -1.0, d_attn).astype(dtype),
        "decay_A": dense_init(ks[7], d_model, DECAY_LORA_DIM, dtype, scale=1e-2),
        "decay_B": dense_init(ks[8], DECAY_LORA_DIM, d_attn, dtype, scale=1e-2),
        # per-channel bonus ("time_faaaa")
        "u": (0.1 * jax.random.normal(ks[9], (d_attn,))).astype(dtype),
        "ln_out": layernorm_init(d_attn, dtype),  # group-norm over heads
    }
    return p


def _ddlerp(p: Params, x: jax.Array, x_prev: jax.Array):
    """Returns the 5 mixed inputs (w, k, v, r, g), each [B,S,d]."""
    dx = x_prev - x
    xxx = x + dx * p["mu_x"].astype(x.dtype)
    lora = jnp.tanh(xxx @ p["mix_A"])
    b, s, _ = x.shape
    lora = lora.reshape(b, s, 5, LORA_DIM)
    mix_b = p["mix_B"].reshape(LORA_DIM, 5, -1)
    dyn = jnp.einsum("bsfl,lfd->bsfd", lora, mix_b)   # [B,S,5,d]
    mixes = p["mu"].astype(x.dtype)[None, None] + dyn
    outs = [x + dx * mixes[:, :, i] for i in range(5)]
    return outs  # order matches MIX_NAMES


def _shift(x: jax.Array, prev: jax.Array = None) -> jax.Array:
    """Previous-token sequence shift. prev [B,d] fills position 0."""
    pad = jnp.zeros_like(x[:, :1]) if prev is None else prev[:, None]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def timemix_apply(p: Params, x: jax.Array, *, n_heads: int, head_dim: int,
                  eps: float, shift_state=None, wkv_state=None,
                  impl: str = "xla"
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Full-sequence time-mix.

    Returns (out [B,S,d], new_shift_state [B,d], new_wkv_state [B,H,Dh,Dh]).
    """
    b, s, d = x.shape
    xs = _shift(x, shift_state)
    xw, xk, xv, xr, xg = _ddlerp(p, x, xs)
    r = (xr @ p["wr"]).reshape(b, s, n_heads, head_dim)
    k = (xk @ p["wk"]).reshape(b, s, n_heads, head_dim)
    v = (xv @ p["wv"]).reshape(b, s, n_heads, head_dim)
    g = jax.nn.silu(xg @ p["wg"])
    # decay in (0,1): w = exp(-exp(base + lora))
    dec = p["decay_base"].astype(jnp.float32) + \
        (jnp.tanh(xw @ p["decay_A"]) @ p["decay_B"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(dec)).reshape(b, s, n_heads, head_dim)
    u = p["u"].astype(jnp.float32).reshape(n_heads, head_dim)

    from repro.kernels import ops as kops
    if wkv_state is None:
        wkv_state = jnp.zeros((b, n_heads, head_dim, head_dim), jnp.float32)
    y, new_state = kops.rwkv6_wkv(r, k, v, w, u, wkv_state, impl=impl)

    y = layernorm(p["ln_out"], y.reshape(b, s, n_heads * head_dim), eps)
    out = (y * g) @ p["wo"]
    return out, x[:, -1].astype(jnp.float32), new_state


def timemix_decode(p: Params, x, state: Dict[str, Any], *, n_heads: int,
                   head_dim: int, eps: float):
    """Single-token step. x [B,1,d]; state {shift [B,d], wkv [B,H,Dh,Dh]}."""
    b = x.shape[0]
    xs = state["shift"][:, None].astype(x.dtype)
    xw, xk, xv, xr, xg = _ddlerp(p, x, xs)
    r = (xr @ p["wr"]).reshape(b, n_heads, head_dim).astype(jnp.float32)
    k = (xk @ p["wk"]).reshape(b, n_heads, head_dim).astype(jnp.float32)
    v = (xv @ p["wv"]).reshape(b, n_heads, head_dim).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["wg"])
    dec = p["decay_base"].astype(jnp.float32) + \
        (jnp.tanh(xw @ p["decay_A"]) @ p["decay_B"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(dec)).reshape(b, n_heads, head_dim)
    u = p["u"].astype(jnp.float32).reshape(n_heads, head_dim)

    S = state["wkv"]                                   # [B,H,Dh,Dh]
    kv = k[..., :, None] * v[..., None, :]             # [B,H,Dh,Dh]
    y = jnp.einsum("bhj,bhji->bhi", r, S + u[None, :, :, None] * kv)
    new_S = w[..., :, None] * S + kv
    y = layernorm(p["ln_out"], y.reshape(b, 1, n_heads * head_dim)
                  .astype(x.dtype), eps)
    out = (y * g) @ p["wo"]
    return out, {"shift": x[:, 0].astype(jnp.float32), "wkv": new_S}


# --------------------------------------------------------------------- #
# channel mix
# --------------------------------------------------------------------- #

def channelmix_init(key, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((d_model,), 0.5, dtype),
        "mu_r": jnp.full((d_model,), 0.5, dtype),
        "wk": dense_init(ks[0], d_model, d_ff, dtype),
        "wv": dense_init(ks[1], d_ff, d_model, dtype),
        "wr": dense_init(ks[2], d_model, d_model, dtype),
    }


def channelmix_apply(p: Params, x, shift_state=None):
    xs = _shift(x, shift_state)
    dx = xs - x
    xk = x + dx * p["mu_k"].astype(x.dtype)
    xr = x + dx * p["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    out = jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"])
    return out, x[:, -1].astype(jnp.float32)


def channelmix_decode(p: Params, x, shift_state):
    xs = shift_state[:, None].astype(x.dtype)
    dx = xs - x
    xk = x + dx * p["mu_k"].astype(x.dtype)
    xr = x + dx * p["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    out = jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"])
    return out, x[:, 0].astype(jnp.float32)


# --------------------------------------------------------------------- #
# full block
# --------------------------------------------------------------------- #

def block_init(key, d_model: int, d_ff: int, n_heads: int, head_dim: int,
               dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 4)
    return {
        "ln1": rmsnorm_init(d_model, dtype),
        "tm": timemix_init(ks[0], d_model, n_heads, head_dim, dtype),
        "ln2": rmsnorm_init(d_model, dtype),
        "cm": channelmix_init(ks[1], d_model, d_ff, dtype),
    }


def block_apply(p: Params, x, *, n_heads, head_dim, eps, impl="xla"):
    h, _, _ = timemix_apply(p["tm"], rmsnorm(p["ln1"], x, eps),
                            n_heads=n_heads, head_dim=head_dim, eps=eps,
                            impl=impl)
    x = x + h
    h, _ = channelmix_apply(p["cm"], rmsnorm(p["ln2"], x, eps))
    return x + h


def block_decode(p: Params, x, state, *, n_heads, head_dim, eps):
    h, tm_state = timemix_decode(
        p["tm"], rmsnorm(p["ln1"], x, eps),
        {"shift": state["tm_shift"], "wkv": state["wkv"]},
        n_heads=n_heads, head_dim=head_dim, eps=eps)
    x = x + h
    h, cm_shift = channelmix_decode(p["cm"], rmsnorm(p["ln2"], x, eps),
                                    state["cm_shift"])
    new_state = {"tm_shift": tm_state["shift"], "wkv": tm_state["wkv"],
                 "cm_shift": cm_shift}
    return x + h, new_state


def init_block_state(batch: int, d_model: int, n_heads: int, head_dim: int
                     ) -> Dict[str, jax.Array]:
    return {
        "tm_shift": jnp.zeros((batch, d_model), jnp.float32),
        "wkv": jnp.zeros((batch, n_heads, head_dim, head_dim), jnp.float32),
        "cm_shift": jnp.zeros((batch, d_model), jnp.float32),
    }
