"""Shared functional building blocks for the model zoo.

Everything is pure-functional: ``init_*`` returns nested-dict param pytrees,
``apply``-style functions take (params, inputs) and return outputs.  Layer
stacks are stored *stacked* ([n_layers, ...] leading dim) so the forward pass
is a single ``lax.scan`` over layers — this keeps compiled HLO size constant
in depth, which matters for the 88–95 layer archs in the pool.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# --------------------------------------------------------------------- #
# initializers
# --------------------------------------------------------------------- #

def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32,
               scale: Optional[float] = None) -> jax.Array:
    """Truncated-normal fan-in init (MaxText/T5 style)."""
    if scale is None:
        scale = 1.0 / math.sqrt(d_in)
    return (scale * jax.random.truncated_normal(
        key, -2.0, 2.0, (d_in, d_out), jnp.float32)).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> jax.Array:
    # 1/sqrt(d) scale keeps tied unembedding logits O(1)
    return (jax.random.normal(key, (vocab, d), jnp.float32)
            / math.sqrt(d)).astype(dtype)


def zeros(shape, dtype=jnp.float32) -> jax.Array:
    return jnp.zeros(shape, dtype)


def ones(shape, dtype=jnp.float32) -> jax.Array:
    return jnp.ones(shape, dtype)


# --------------------------------------------------------------------- #
# norms
# --------------------------------------------------------------------- #

def rmsnorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dtype)


def layernorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": ones((d,), dtype), "bias": zeros((d,), dtype)}


def layernorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(dtype)


# --------------------------------------------------------------------- #
# activations
# --------------------------------------------------------------------- #

def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[name]


# --------------------------------------------------------------------- #
# RoPE and M-RoPE
# --------------------------------------------------------------------- #

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape [head_dim // 2]."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def rope_cos_sin(positions: jax.Array, head_dim: int, theta: float
                 ) -> Tuple[jax.Array, jax.Array]:
    """positions [...]: int -> cos/sin [..., head_dim // 2] (fp32)."""
    inv = rope_freqs(head_dim, theta)
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x [..., n_heads, head_dim]; cos/sin broadcast [..., 1, head_dim//2].

    Uses the "split-halves" convention (llama): rotate (x1, x2) halves.
    """
    dtype = x.dtype
    x = x.astype(jnp.float32)
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dtype)


def mrope_cos_sin(positions: jax.Array, head_dim: int, theta: float,
                  sections: Tuple[int, int, int]) -> Tuple[jax.Array, jax.Array]:
    """Qwen2-VL multimodal RoPE.

    positions: [..., 3] int (t, h, w) per token.  The head_dim//2 rotary
    frequency channels are split into ``sections`` (t, h, w) groups, each
    driven by its own position coordinate.
    Returns cos/sin of shape [..., head_dim // 2].
    """
    assert sum(sections) == head_dim // 2, (sections, head_dim)
    inv = rope_freqs(head_dim, theta)  # [d2]
    # angles per coordinate: [..., 3, d2]
    ang = positions.astype(jnp.float32)[..., None] * inv
    sel = jnp.repeat(jnp.arange(3), jnp.array(sections), total_repeat_length=head_dim // 2)
    ang = jnp.take_along_axis(
        ang, jnp.broadcast_to(sel, ang.shape[:-2] + (1, head_dim // 2)), axis=-2
    )[..., 0, :]
    return jnp.cos(ang), jnp.sin(ang)


def text_positions(batch: int, seq: int) -> jax.Array:
    return jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (batch, seq))


# --------------------------------------------------------------------- #
# losses
# --------------------------------------------------------------------- #

def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: Optional[jax.Array] = None
                          ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Token-level CE.  logits [..., V] (any dtype, upcast), labels [...]."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / denom
    acc = ((jnp.argmax(logits, -1) == labels) * mask).sum() / denom
    return loss, {"loss": loss, "accuracy": acc, "tokens": denom}


# --------------------------------------------------------------------- #
# stacked-layer helpers
# --------------------------------------------------------------------- #

def stacked_init(init_one, key, n_layers: int) -> Params:
    """vmap an init function over per-layer keys -> stacked pytree."""
    keys = jax.random.split(key, n_layers)
    return jax.vmap(init_one)(keys)


def scan_layers(body, x, stacked_params, *, remat: bool = False,
                unroll: int = 1):
    """Run ``body(x, layer_params) -> x`` over stacked layer params."""
    fn = jax.checkpoint(body) if remat else body

    def step(carry, layer_params):
        return fn(carry, layer_params), None

    out, _ = jax.lax.scan(step, x, stacked_params, unroll=unroll)
    return out


def scan_layers_with_cache(body, x, stacked_params, cache, *, remat: bool = False):
    """Like scan_layers but threads a per-layer cache pytree (stacked on the
    layer dim) through the scan: body(x, layer_params, layer_cache) ->
    (x, new_layer_cache)."""
    fn = jax.checkpoint(body) if remat else body

    def step(carry, inp):
        layer_params, layer_cache = inp
        new_carry, new_cache = fn(carry, layer_params, layer_cache)
        return new_carry, new_cache

    out, new_cache = jax.lax.scan(step, x, (stacked_params, cache))
    return out, new_cache


def count_params(params: Params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))
