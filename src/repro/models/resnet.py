"""Compact JAX ResNet + MLP classifiers — the paper's own model family,
used by the paper-validation benchmarks (K2 / K1 / S sweeps, vs-K-AVG).

Pure functional; narrow widths so CPU simulation of P in {8..64} learners is
fast.  Matches the paper's setup shape: CIFAR-like 32x32 inputs, softmax CE,
SGD with step-decayed learning rate.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.resnet18_cifar import CNNConfig, MLPConfig
from repro.models.common import (Params, dense_init, softmax_cross_entropy)


def _conv_init(key, k: int, cin: int, cout: int, dtype=jnp.float32):
    fan_in = k * k * cin
    return (jax.random.normal(key, (k, k, cin, cout), jnp.float32)
            * (2.0 / fan_in) ** 0.5).astype(dtype)


def _conv(x, w, stride: int = 1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _gn_init(c: int, dtype=jnp.float32):
    # group-norm (batch-independent; correct under per-learner vmap)
    return {"scale": jnp.ones((c,), dtype), "bias": jnp.zeros((c,), dtype)}


def _gn(p, x, groups: int = 8, eps: float = 1e-5):
    n, h, w, c = x.shape
    g = min(groups, c)
    while c % g:
        g -= 1
    xg = x.reshape(n, h, w, g, c // g).astype(jnp.float32)
    mu = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    x = xg.reshape(n, h, w, c)
    return (x * p["scale"] + p["bias"]).astype(x.dtype)


def _block_init(key, cin: int, cout: int, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    p = {
        "conv1": _conv_init(ks[0], 3, cin, cout, dtype),
        "gn1": _gn_init(cout, dtype),
        "conv2": _conv_init(ks[1], 3, cout, cout, dtype),
        "gn2": _gn_init(cout, dtype),
    }
    if cin != cout:
        p["proj"] = _conv_init(ks[2], 1, cin, cout, dtype)
    return p


def _block_apply(p: Params, x, stride: int):
    h = jax.nn.relu(_gn(p["gn1"], _conv(x, p["conv1"], stride)))
    h = _gn(p["gn2"], _conv(h, p["conv2"]))
    sc = x if "proj" not in p else _conv(x, p["proj"], stride)
    return jax.nn.relu(h + sc)


def resnet_init(key, cfg: CNNConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 2 + sum(cfg.depth_blocks))
    w = cfg.width
    p: Params = {"stem": _conv_init(ks[0], 3, cfg.channels, w, dtype),
                 "gn0": _gn_init(w, dtype), "blocks": []}
    blocks = []
    cin = w
    i = 1
    for stage, n in enumerate(cfg.depth_blocks):
        cout = w * (2 ** stage)
        for b in range(n):
            blocks.append(_block_init(ks[i], cin, cout, dtype))
            cin = cout
            i += 1
    p["blocks"] = blocks
    p["head"] = dense_init(ks[i], cin, cfg.n_classes, dtype)
    return p


def resnet_apply(p: Params, x: jax.Array, cfg: CNNConfig) -> jax.Array:
    h = jax.nn.relu(_gn(p["gn0"], _conv(x, p["stem"])))
    i = 0
    for stage, n in enumerate(cfg.depth_blocks):
        for b in range(n):
            stride = 2 if (b == 0 and stage > 0) else 1
            h = _block_apply(p["blocks"][i], h, stride)
            i += 1
    h = h.mean(axis=(1, 2))
    return h @ p["head"]


def resnet_loss(p: Params, batch: Dict[str, jax.Array], cfg: CNNConfig):
    logits = resnet_apply(p, batch["x"], cfg)
    return softmax_cross_entropy(logits, batch["y"])


# ---------------------------------------------------------------------- #

def mlp_cls_init(key, cfg: MLPConfig, dtype=jnp.float32) -> Params:
    dims = (cfg.in_dim,) + cfg.hidden + (cfg.n_classes,)
    ks = jax.random.split(key, len(dims) - 1)
    return {"w": [dense_init(k, a, b, dtype)
                  for k, a, b in zip(ks, dims[:-1], dims[1:])],
            "b": [jnp.zeros((b,), dtype) for b in dims[1:]]}


def mlp_cls_apply(p: Params, x: jax.Array) -> jax.Array:
    for i, (w, b) in enumerate(zip(p["w"], p["b"])):
        x = x @ w + b
        if i < len(p["w"]) - 1:
            x = jax.nn.relu(x)
    return x


def mlp_cls_loss(p: Params, batch: Dict[str, jax.Array]):
    return softmax_cross_entropy(mlp_cls_apply(p, batch["x"]), batch["y"])
