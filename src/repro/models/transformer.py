"""Decoder-LM assembly for every family in the pool.

One ``ModelBundle`` per architecture exposes:
  init(key)                 -> params
  loss_fn(params, batch)    -> (loss, metrics)          [train_* shapes]
  prefill(params, batch)    -> (last_logits, cache)     [prefill_* shapes]
  decode_step(params, tok, cache) -> (logits, cache)    [decode_* shapes]
  init_cache(batch, max_len)-> zeroed cache pytree      [dry-run specs]

Layer stacks are stacked pytrees scanned with ``lax.scan`` (HLO size is
depth-independent); caches are stacked on the same leading layer dim and
threaded through the scan.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import hybrid as hyb
from repro.models import rwkv6 as rwk
from repro.models.attention import (gqa_attention, gqa_decode,
                                    gqa_decode_paged, gqa_init,
                                    gqa_prefill_paged_chunk, init_kv_cache,
                                    init_mla_cache, init_paged_kv,
                                    init_paged_mla, mla_attention,
                                    mla_decode, mla_decode_paged, mla_init,
                                    mla_prefill_paged_chunk,
                                    prefill_kv_cache, mla_prefill_cache)
from repro.models.common import (Params, embed_init, dense_init,
                                 mrope_cos_sin, rmsnorm, rmsnorm_init,
                                 rope_cos_sin, scan_layers_with_cache,
                                 softmax_cross_entropy, stacked_init,
                                 text_positions)
from repro.models.mlp import mlp_apply, mlp_init
from repro.models.moe import moe_apply, moe_init


# ===================================================================== #
# generic decoder layer (dense / moe x GQA / MLA)
# ===================================================================== #

def _attn_init(key, cfg: ArchConfig, dtype):
    if cfg.kv_lora_rank:
        return mla_init(key, cfg.d_model, cfg.n_heads, cfg.kv_lora_rank,
                        cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                        cfg.v_head_dim, dtype)
    return gqa_init(key, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                    cfg.resolved_head_dim, dtype)


def layer_init(key, cfg: ArchConfig, use_moe: bool, dtype) -> Params:
    ks = jax.random.split(key, 2)
    p = {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "attn": _attn_init(ks[0], cfg, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
    }
    if use_moe:
        p["ffn"] = moe_init(ks[1], cfg.d_model, cfg.expert_d_ff or cfg.d_ff,
                            cfg.n_experts, cfg.n_shared_experts, cfg.act,
                            dtype)
    else:
        p["ffn"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dtype)
    return p


def layer_apply(p: Params, x, cos, sin, cfg: ArchConfig, use_moe: bool,
                window: int, impl: str):
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.kv_lora_rank:
        a = mla_attention(p["attn"], h, cos, sin, n_heads=cfg.n_heads,
                          kv_lora=cfg.kv_lora_rank,
                          qk_nope=cfg.qk_nope_head_dim,
                          qk_rope=cfg.qk_rope_head_dim,
                          v_dim=cfg.v_head_dim, eps=cfg.norm_eps)
    else:
        a = gqa_attention(p["attn"], h, cos, sin, n_heads=cfg.n_heads,
                          n_kv_heads=cfg.n_kv_heads,
                          head_dim=cfg.resolved_head_dim, window=window,
                          impl=impl)
    x = x + a
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if use_moe:
        f, aux = moe_apply(p["ffn"], h, n_experts=cfg.n_experts,
                           top_k=cfg.top_k, act=cfg.act,
                           capacity_factor=cfg.capacity_factor)
    else:
        f, aux = mlp_apply(p["ffn"], h, cfg.act), jnp.zeros((), jnp.float32)
    return x + f, aux


def layer_decode(p: Params, x, cache, cos, sin, cfg: ArchConfig,
                 use_moe: bool, rolling: bool):
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.kv_lora_rank:
        a, cache = mla_decode(p["attn"], h, cache, cos, sin,
                              n_heads=cfg.n_heads, kv_lora=cfg.kv_lora_rank,
                              qk_nope=cfg.qk_nope_head_dim,
                              qk_rope=cfg.qk_rope_head_dim,
                              v_dim=cfg.v_head_dim, eps=cfg.norm_eps)
    else:
        a, cache = gqa_decode(p["attn"], h, cache, cos, sin,
                              n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                              head_dim=cfg.resolved_head_dim, rolling=rolling)
    x = x + a
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if use_moe:
        f, _ = moe_apply(p["ffn"], h, n_experts=cfg.n_experts,
                         top_k=cfg.top_k, act=cfg.act,
                           capacity_factor=cfg.capacity_factor)
    else:
        f = mlp_apply(p["ffn"], h, cfg.act)
    return x + f, cache


def _layer_ffn(p: Params, x, cfg: ArchConfig, use_moe: bool):
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if use_moe:
        f, _ = moe_apply(p["ffn"], h, n_experts=cfg.n_experts,
                         top_k=cfg.top_k, act=cfg.act,
                         capacity_factor=cfg.capacity_factor)
    else:
        f = mlp_apply(p["ffn"], h, cfg.act)
    return x + f


def layer_decode_paged(p: Params, x, pages, block_tables, lengths, active,
                       cos, sin, cfg: ArchConfig, use_moe: bool,
                       decode_impl: str):
    """One layer of the paged decode step (per-slot positions)."""
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.kv_lora_rank:
        a, pages = mla_decode_paged(
            p["attn"], h, pages, block_tables, lengths, active, cos, sin,
            n_heads=cfg.n_heads, kv_lora=cfg.kv_lora_rank,
            qk_nope=cfg.qk_nope_head_dim, qk_rope=cfg.qk_rope_head_dim,
            v_dim=cfg.v_head_dim, eps=cfg.norm_eps)
    else:
        a, pages = gqa_decode_paged(
            p["attn"], h, pages, block_tables, lengths, active, cos, sin,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim, window=cfg.sliding_window,
            impl=decode_impl)
    return _layer_ffn(p, x + a, cfg, use_moe), pages


def layer_prefill_paged(p: Params, x, pages, block_tables, base, cos, sin,
                        cfg: ArchConfig, use_moe: bool):
    """One layer of one paged-prefill chunk (positions base..base+C-1)."""
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.kv_lora_rank:
        a, pages = mla_prefill_paged_chunk(
            p["attn"], h, pages, block_tables, base, cos, sin,
            n_heads=cfg.n_heads, kv_lora=cfg.kv_lora_rank,
            qk_nope=cfg.qk_nope_head_dim, qk_rope=cfg.qk_rope_head_dim,
            v_dim=cfg.v_head_dim, eps=cfg.norm_eps)
    else:
        a, pages = gqa_prefill_paged_chunk(
            p["attn"], h, pages, block_tables, base, cos, sin,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim, window=cfg.sliding_window)
    return _layer_ffn(p, x + a, cfg, use_moe), pages


# ===================================================================== #
# bundle
# ===================================================================== #

@dataclasses.dataclass
class ModelBundle:
    cfg: ArchConfig
    init: Callable
    loss_fn: Callable
    prefill: Callable
    decode_step: Callable
    init_cache: Callable
    # extras
    forward: Optional[Callable] = None
    # paged serving (None for families with constant-size state caches):
    #   init_paged_cache(n_pages, page_size) -> pages pytree [L, ...]
    #   prefill_paged_chunk(params, tokens [B,C], pages, tables, base)
    #       -> (logits [B,C,V], pages)
    #   decode_step_paged(params, tokens [B], pages, tables, lengths,
    #       active) -> (logits [B,V], pages)
    init_paged_cache: Optional[Callable] = None
    prefill_paged_chunk: Optional[Callable] = None
    decode_step_paged: Optional[Callable] = None


def _rope_for(cfg: ArchConfig, positions):
    """positions [B,S] (or [B,S,3] for M-RoPE) -> cos/sin [B,S,hd//2]."""
    hd = cfg.qk_rope_head_dim if cfg.kv_lora_rank else cfg.resolved_head_dim
    if cfg.mrope:
        return mrope_cos_sin(positions, hd, cfg.rope_theta,
                             cfg.mrope_sections)
    return rope_cos_sin(positions, hd, cfg.rope_theta)


def _unembed(params, cfg: ArchConfig, x):
    if cfg.tie_embeddings:
        return x @ params["embed"].T
    return x @ params["lm_head"]


def _split_layers(cfg: ArchConfig) -> Tuple[int, int]:
    """(n_dense_prefix, n_main) — prefix layers use a dense FFN."""
    if cfg.uses_moe and cfg.first_k_dense:
        return cfg.first_k_dense, cfg.n_layers - cfg.first_k_dense
    return 0, cfg.n_layers


def build_decoder_lm(cfg: ArchConfig, *, param_dtype=jnp.float32,
                     compute_dtype=None, remat: bool = False,
                     impl: str = "xla", rolling_decode: bool = False,
                     cache_dtype=jnp.bfloat16,
                     decode_impl: str = "auto") -> ModelBundle:
    """dense / moe / mla / vlm families.

    ``decode_impl`` picks the paged decode-attention kernel
    (kernels/ops.py::flash_decode dispatch): "auto" / "xla" / "pallas" /
    "pallas_interpret".  It only affects decode_step_paged.
    """
    compute_dtype = compute_dtype or param_dtype
    n_pre, n_main = _split_layers(cfg)
    window = cfg.sliding_window

    def init(key) -> Params:
        ks = jax.random.split(key, 4)
        p: Params = {
            "embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model,
                                param_dtype),
            "final_norm": rmsnorm_init(cfg.d_model, param_dtype),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = dense_init(ks[1], cfg.d_model, cfg.padded_vocab,
                                      param_dtype)
        p["layers"] = stacked_init(
            lambda k: layer_init(k, cfg, cfg.uses_moe, param_dtype),
            ks[2], n_main)
        if n_pre:
            p["layers_dense"] = stacked_init(
                lambda k: layer_init(k, cfg, False, param_dtype), ks[3], n_pre)
        return p

    def _stack_forward(params, x, cos, sin):
        """x [B,S,d] -> (hidden, aux_loss)."""
        def body_dense(carry, lp):
            x, aux = carry
            x, a = layer_apply(lp, x, cos, sin, cfg, False, window, impl)
            return (x, aux + a), None

        def body_main(carry, lp):
            x, aux = carry
            x, a = layer_apply(lp, x, cos, sin, cfg, cfg.uses_moe, window,
                               impl)
            return (x, aux + a), None

        carry = (x, jnp.zeros((), jnp.float32))
        if n_pre:
            fn = jax.checkpoint(body_dense) if remat else body_dense
            carry, _ = jax.lax.scan(fn, carry, params["layers_dense"])
        fn = jax.checkpoint(body_main) if remat else body_main
        carry, _ = jax.lax.scan(fn, carry, params["layers"])
        x, aux = carry
        return rmsnorm(params["final_norm"], x, cfg.norm_eps), aux

    def forward(params, embeds, positions):
        cos, sin = _rope_for(cfg, positions)
        h, aux = _stack_forward(params, embeds.astype(compute_dtype), cos, sin)
        return h, aux

    def _embed_batch(params, batch):
        """Returns (embeds [B,S,d], positions, label_offset)."""
        tok_emb = params["embed"][batch["tokens"]]
        if cfg.family == "vlm" and "vision_embeds" in batch:
            v = batch["vision_embeds"].astype(tok_emb.dtype)
            embeds = jnp.concatenate([v, tok_emb], axis=1)
            positions = batch["positions"]        # [B, Nv+St, 3]
            return embeds, positions, v.shape[1]
        if cfg.mrope:
            b, s = batch["tokens"].shape
            pos = text_positions(b, s)
            positions = jnp.stack([pos, pos, pos], axis=-1)
        else:
            positions = text_positions(*batch["tokens"].shape)
        return tok_emb, positions, 0

    def loss_fn(params, batch):
        embeds, positions, off = _embed_batch(params, batch)
        h, aux = forward(params, embeds, positions)
        if off:
            h = h[:, off:]
        logits = _unembed(params, cfg, h)
        mask = batch.get("mask")
        loss, metrics = softmax_cross_entropy(logits, batch["labels"], mask)
        if cfg.uses_moe:
            aux = aux / max(1, n_main)
            loss = loss + cfg.router_aux_coef * aux
            metrics["aux_loss"] = aux
        metrics["loss"] = loss
        return loss, metrics

    # ------------------------- serving ------------------------------- #

    def init_cache(batch: int, max_len: int):
        def one(_):
            if cfg.kv_lora_rank:
                return init_mla_cache(batch, max_len, cfg.kv_lora_rank,
                                      cfg.qk_rope_head_dim, cache_dtype)
            w = cfg.long_context_window if rolling_decode else 0
            return init_kv_cache(batch, max_len, cfg.n_kv_heads,
                                 cfg.resolved_head_dim, cache_dtype,
                                 rolling=rolling_decode, window=w)
        n_layers = cfg.n_layers
        caches = [one(i) for i in range(n_layers)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *caches)

    def prefill(params, batch):
        """Full-prompt forward; returns (last-position logits, cache)."""
        embeds, positions, off = _embed_batch(params, batch)
        cos, sin = _rope_for(cfg, positions)
        x = embeds.astype(compute_dtype)
        max_len = batch.get("max_len", x.shape[1])
        if isinstance(max_len, jax.Array):
            max_len = int(max_len)

        def make_body(use_moe):
            def body(carry, lp):
                x = carry[0]
                h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
                if cfg.kv_lora_rank:
                    a = mla_attention(
                        lp["attn"], h, cos, sin, n_heads=cfg.n_heads,
                        kv_lora=cfg.kv_lora_rank,
                        qk_nope=cfg.qk_nope_head_dim,
                        qk_rope=cfg.qk_rope_head_dim, v_dim=cfg.v_head_dim,
                        eps=cfg.norm_eps)
                    cache = mla_prefill_cache(lp["attn"], h, cos, sin,
                                              max_len=max_len,
                                              eps=cfg.norm_eps,
                                              dtype=cache_dtype)
                else:
                    a = gqa_attention(lp["attn"], h, cos, sin,
                                      n_heads=cfg.n_heads,
                                      n_kv_heads=cfg.n_kv_heads,
                                      head_dim=cfg.resolved_head_dim,
                                      window=window, impl=impl)
                    w = cfg.long_context_window if rolling_decode else 0
                    cache = prefill_kv_cache(
                        lp["attn"], h, cos, sin, n_heads=cfg.n_heads,
                        n_kv_heads=cfg.n_kv_heads,
                        head_dim=cfg.resolved_head_dim, max_len=max_len,
                        dtype=cache_dtype, rolling=rolling_decode, window=w)
                x = x + a
                h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
                if use_moe:
                    f, _ = moe_apply(lp["ffn"], h, n_experts=cfg.n_experts,
                                     top_k=cfg.top_k, act=cfg.act,
                           capacity_factor=cfg.capacity_factor)
                else:
                    f = mlp_apply(lp["ffn"], h, cfg.act)
                return (x + f, None), cache
            return body

        # dense prefix then main stack, collecting caches stacked on layer dim
        caches = []
        x_c = (x, None)
        if n_pre:
            x_c, pre_caches = jax.lax.scan(make_body(False), x_c,
                                           params["layers_dense"])
            caches.append(pre_caches)
        x_c, main_caches = jax.lax.scan(make_body(cfg.uses_moe), x_c,
                                        params["layers"])
        caches.append(main_caches)
        cache = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *caches) \
            if len(caches) > 1 else caches[0]
        h = rmsnorm(params["final_norm"], x_c[0], cfg.norm_eps)
        logits = _unembed(params, cfg, h[:, -1])
        return logits, cache

    def decode_step(params, tokens, cache):
        """tokens [B] int32 -> (logits [B,V], cache)."""
        b = tokens.shape[0]
        # every layer shares the same position counter (stacked pos [L])
        cur = cache["pos"][0]
        if cfg.mrope:
            positions = jnp.broadcast_to(cur, (b, 1, 3)).astype(jnp.int32)
        else:
            positions = jnp.broadcast_to(cur, (b, 1)).astype(jnp.int32)
        cos, sin = _rope_for(cfg, positions)
        x = params["embed"][tokens][:, None].astype(compute_dtype)

        if n_pre:
            x, new_cache = _decode_split(params, x, cache, cos, sin)
        else:
            x, new_cache = scan_layers_with_cache(
                lambda x, lp, lc: layer_decode(lp, x, lc, cos, sin, cfg,
                                               cfg.uses_moe, rolling_decode),
                x, params["layers"], cache)
        h = rmsnorm(params["final_norm"], x[:, 0:1], cfg.norm_eps)
        logits = _unembed(params, cfg, h[:, 0])
        return logits, new_cache

    def _decode_split(params, x, cache, cos, sin):
        """first_k_dense archs: split the cache between the two stacks."""
        pre_cache = jax.tree.map(lambda a: a[:n_pre], cache)
        main_cache = jax.tree.map(lambda a: a[n_pre:], cache)
        x, new_pre = scan_layers_with_cache(
            lambda x, lp, lc: layer_decode(lp, x, lc, cos, sin, cfg, False,
                                           rolling_decode),
            x, params["layers_dense"], pre_cache)
        x, new_main = scan_layers_with_cache(
            lambda x, lp, lc: layer_decode(lp, x, lc, cos, sin, cfg,
                                           cfg.uses_moe, rolling_decode),
            x, params["layers"], main_cache)
        new_cache = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0),
                                 new_pre, new_main)
        return x, new_cache

    # ---------------------- paged serving ---------------------------- #
    # Pages are stacked on the layer dim like the dense cache and
    # threaded through the same layer scan; the block table and per-slot
    # lengths stay OUTSIDE the per-layer pytree (one copy, closed over by
    # the scan bodies) because every layer shares them.

    def init_paged_cache(n_pages: int, page_size: int):
        def one(_):
            if cfg.kv_lora_rank:
                return init_paged_mla(n_pages, page_size, cfg.kv_lora_rank,
                                      cfg.qk_rope_head_dim, cache_dtype)
            return init_paged_kv(n_pages, page_size, cfg.n_kv_heads,
                                 cfg.resolved_head_dim, cache_dtype)
        return jax.tree.map(lambda *xs: jnp.stack(xs),
                            *[one(i) for i in range(cfg.n_layers)])

    def _scan_paged(params, x, pages, body_for):
        """Run the (dense-prefix +) main stacks over stacked pages."""
        if not n_pre:
            return scan_layers_with_cache(body_for(cfg.uses_moe), x,
                                          params["layers"], pages)
        pre = jax.tree.map(lambda a: a[:n_pre], pages)
        main = jax.tree.map(lambda a: a[n_pre:], pages)
        x, new_pre = scan_layers_with_cache(body_for(False), x,
                                            params["layers_dense"], pre)
        x, new_main = scan_layers_with_cache(body_for(cfg.uses_moe), x,
                                             params["layers"], main)
        return x, jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0),
                               new_pre, new_main)

    def _positions_for(pos):
        """pos [B,S] int32 -> rope positions ([B,S] or [B,S,3] M-RoPE)."""
        if cfg.mrope:
            return jnp.stack([pos, pos, pos], axis=-1)
        return pos

    def prefill_paged_chunk(params, tokens, pages, block_tables, base):
        """One prompt chunk: tokens [B,C] at global positions
        base..base+C-1 (base is traced — any chunk index reuses the one
        compiled program).  Returns (logits [B,C,V], pages)."""
        b, c = tokens.shape
        pos = base + jnp.broadcast_to(jnp.arange(c), (b, c))
        cos, sin = _rope_for(cfg, _positions_for(pos.astype(jnp.int32)))
        x = params["embed"][tokens].astype(compute_dtype)

        def body_for(use_moe):
            def body(x, lp, lpg):
                return layer_prefill_paged(lp, x, lpg, block_tables, base,
                                           cos, sin, cfg, use_moe)
            return body

        x, new_pages = _scan_paged(params, x, pages, body_for)
        h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return _unembed(params, cfg, h), new_pages

    def decode_step_paged(params, tokens, pages, block_tables, lengths,
                          active):
        """One decode step over the slot array: tokens [B], per-slot
        ``lengths`` [B] (cached tokens so far — the position each slot's
        token is written at), ``active`` [B] bool.  Returns
        (logits [B,V], pages)."""
        b = tokens.shape[0]
        pos = lengths.astype(jnp.int32)[:, None]          # [B,1] per slot
        cos, sin = _rope_for(cfg, _positions_for(pos))
        x = params["embed"][tokens][:, None].astype(compute_dtype)

        def body_for(use_moe):
            def body(x, lp, lpg):
                return layer_decode_paged(lp, x, lpg, block_tables,
                                          lengths, active, cos, sin, cfg,
                                          use_moe, decode_impl)
            return body

        x, new_pages = _scan_paged(params, x, pages, body_for)
        h = rmsnorm(params["final_norm"], x[:, 0:1], cfg.norm_eps)
        return _unembed(params, cfg, h[:, 0]), new_pages

    return ModelBundle(cfg=cfg, init=init, loss_fn=loss_fn, prefill=prefill,
                       decode_step=decode_step, init_cache=init_cache,
                       forward=forward, init_paged_cache=init_paged_cache,
                       prefill_paged_chunk=prefill_paged_chunk,
                       decode_step_paged=decode_step_paged)


# ===================================================================== #
# RWKV-6 LM
# ===================================================================== #

def build_rwkv_lm(cfg: ArchConfig, *, param_dtype=jnp.float32,
                  compute_dtype=None, remat: bool = False,
                  impl: str = "xla", **_unused) -> ModelBundle:
    compute_dtype = compute_dtype or param_dtype
    H, hd = cfg.ssm_heads, cfg.resolved_head_dim

    def init(key):
        ks = jax.random.split(key, 3)
        return {
            "embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model,
                                param_dtype),
            "layers": stacked_init(
                lambda k: rwk.block_init(k, cfg.d_model, cfg.d_ff, H, hd,
                                         param_dtype), ks[1], cfg.n_layers),
            "final_norm": rmsnorm_init(cfg.d_model, param_dtype),
            "lm_head": dense_init(ks[2], cfg.d_model, cfg.padded_vocab,
                                  param_dtype),
        }

    def forward(params, embeds, positions=None):
        def body(x, lp):
            return rwk.block_apply(lp, x, n_heads=H, head_dim=hd,
                                   eps=cfg.norm_eps, impl=impl)
        fn = jax.checkpoint(body) if remat else body

        def step(c, lp):
            return fn(c, lp), None
        x, _ = jax.lax.scan(step, embeds.astype(compute_dtype),
                            params["layers"])
        return rmsnorm(params["final_norm"], x, cfg.norm_eps), \
            jnp.zeros((), jnp.float32)

    def loss_fn(params, batch):
        h, _ = forward(params, params["embed"][batch["tokens"]])
        logits = h @ params["lm_head"]
        loss, metrics = softmax_cross_entropy(logits, batch["labels"],
                                              batch.get("mask"))
        return loss, metrics

    def init_cache(batch: int, max_len: int = 0):
        states = [rwk.init_block_state(batch, cfg.d_model, H, hd)
                  for _ in range(cfg.n_layers)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *states)

    def prefill(params, batch):
        """Run the recurrence across the prompt, keep final states."""
        x = params["embed"][batch["tokens"]].astype(compute_dtype)
        b = x.shape[0]

        def body(x, lp, st):
            h_in = rmsnorm(lp["ln1"], x, cfg.norm_eps)
            h, tm_shift, wkv = rwk.timemix_apply(
                lp["tm"], h_in, n_heads=H, head_dim=hd, eps=cfg.norm_eps,
                shift_state=None, wkv_state=st["wkv"], impl=impl)
            x = x + h
            h2_in = rmsnorm(lp["ln2"], x, cfg.norm_eps)
            h2, cm_shift = rwk.channelmix_apply(lp["cm"], h2_in)
            new_st = {"tm_shift": tm_shift, "wkv": wkv,
                      "cm_shift": cm_shift}
            return x + h2, new_st

        cache = init_cache(b)
        x, new_cache = scan_layers_with_cache(body, x, params["layers"],
                                              cache)
        h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return h[:, -1] @ params["lm_head"], new_cache

    def decode_step(params, tokens, cache):
        x = params["embed"][tokens][:, None].astype(compute_dtype)

        def body(x, lp, st):
            return rwk.block_decode(lp, x, st, n_heads=H, head_dim=hd,
                                    eps=cfg.norm_eps)
        x, new_cache = scan_layers_with_cache(body, x, params["layers"],
                                              cache)
        h = rmsnorm(params["final_norm"], x[:, 0], cfg.norm_eps)
        return h @ params["lm_head"], new_cache

    return ModelBundle(cfg=cfg, init=init, loss_fn=loss_fn, prefill=prefill,
                       decode_step=decode_step, init_cache=init_cache,
                       forward=forward)


# ===================================================================== #
# Hymba hybrid LM
# ===================================================================== #

def build_hymba_lm(cfg: ArchConfig, *, param_dtype=jnp.float32,
                   compute_dtype=None, remat: bool = False,
                   impl: str = "xla", cache_dtype=jnp.bfloat16,
                   **_unused) -> ModelBundle:
    compute_dtype = compute_dtype or param_dtype
    kw = dict(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
              head_dim=cfg.resolved_head_dim, ssm_state=cfg.ssm_state,
              eps=cfg.norm_eps, act=cfg.act)

    def init(key):
        ks = jax.random.split(key, 3)
        return {
            "embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model,
                                param_dtype),
            "layers": stacked_init(
                lambda k: hyb.hymba_block_init(
                    k, d_model=cfg.d_model, n_heads=cfg.n_heads,
                    n_kv_heads=cfg.n_kv_heads,
                    head_dim=cfg.resolved_head_dim, d_ff=cfg.d_ff,
                    ssm_state=cfg.ssm_state, ssm_expand=cfg.ssm_expand,
                    act=cfg.act, dtype=param_dtype), ks[1], cfg.n_layers),
            "final_norm": rmsnorm_init(cfg.d_model, param_dtype),
            "lm_head": dense_init(ks[2], cfg.d_model, cfg.padded_vocab,
                                  param_dtype),
        }

    def forward(params, embeds, positions=None):
        b, s, _ = embeds.shape
        pos = text_positions(b, s) if positions is None else positions
        cos, sin = rope_cos_sin(pos, cfg.resolved_head_dim, cfg.rope_theta)

        def body(x, lp):
            return hyb.hymba_block_apply(lp, x, cos, sin,
                                         window=cfg.sliding_window,
                                         impl=impl, **kw)
        fn = jax.checkpoint(body) if remat else body

        def step(c, lp):
            return fn(c, lp), None
        x, _ = jax.lax.scan(step, embeds.astype(compute_dtype),
                            params["layers"])
        return rmsnorm(params["final_norm"], x, cfg.norm_eps), \
            jnp.zeros((), jnp.float32)

    def loss_fn(params, batch):
        h, _ = forward(params, params["embed"][batch["tokens"]])
        logits = h @ params["lm_head"]
        return softmax_cross_entropy(logits, batch["labels"],
                                     batch.get("mask"))

    def init_cache(batch: int, max_len: int = 0):
        states = [hyb.init_hymba_state(
            batch, d_model=cfg.d_model, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim, ssm_state=cfg.ssm_state,
            ssm_expand=cfg.ssm_expand, window=cfg.sliding_window,
            dtype=cache_dtype) for _ in range(cfg.n_layers)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *states)

    def prefill(params, batch):
        x = params["embed"][batch["tokens"]].astype(compute_dtype)
        b, s, _ = x.shape
        pos = text_positions(b, s)
        cos, sin = rope_cos_sin(pos, cfg.resolved_head_dim, cfg.rope_theta)
        cache = init_cache(b)

        def body(x, lp, st):
            h = rmsnorm(lp["ln_in"], x, cfg.norm_eps)
            from repro.models.attention import (gqa_attention as _ga,
                                                prefill_kv_cache as _pf)
            a = _ga(lp["attn"], h, cos, sin, n_heads=cfg.n_heads,
                    n_kv_heads=cfg.n_kv_heads,
                    head_dim=cfg.resolved_head_dim,
                    window=cfg.sliding_window, impl=impl)
            kv = _pf(lp["attn"], h, cos, sin, n_heads=cfg.n_heads,
                     n_kv_heads=cfg.n_kv_heads,
                     head_dim=cfg.resolved_head_dim,
                     max_len=cfg.sliding_window, dtype=cache_dtype,
                     rolling=True, window=cfg.sliding_window)
            from repro.models import mamba as mam
            m, hT, conv_tail = mam.mamba_apply(lp["ssm"], h,
                                               state=cfg.ssm_state)
            fused = 0.5 * (rmsnorm(lp["ln_attn"], a, cfg.norm_eps)
                           + rmsnorm(lp["ln_ssm"], m, cfg.norm_eps))
            x = x + fused
            x = x + mlp_apply(lp["mlp"],
                              rmsnorm(lp["ln_mlp"], x, cfg.norm_eps), cfg.act)
            return x, {"kv": kv, "ssm": hT, "conv": conv_tail}

        x, new_cache = scan_layers_with_cache(body, x, params["layers"],
                                              cache)
        h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return h[:, -1] @ params["lm_head"], new_cache

    def decode_step(params, tokens, cache):
        b = tokens.shape[0]
        cur = cache["kv"]["pos"][0]
        pos = jnp.broadcast_to(cur, (b, 1)).astype(jnp.int32)
        cos, sin = rope_cos_sin(pos, cfg.resolved_head_dim, cfg.rope_theta)
        x = params["embed"][tokens][:, None].astype(compute_dtype)

        def body(x, lp, st):
            return hyb.hymba_block_decode(lp, x, st, cos, sin, **kw)
        x, new_cache = scan_layers_with_cache(body, x, params["layers"],
                                              cache)
        h = rmsnorm(params["final_norm"], x[:, 0], cfg.norm_eps)
        return h @ params["lm_head"], new_cache

    return ModelBundle(cfg=cfg, init=init, loss_fn=loss_fn, prefill=prefill,
                       decode_step=decode_step, init_cache=init_cache,
                       forward=forward)
