"""Model zoo: ``build(cfg, **options)`` returns a ModelBundle for any arch."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.transformer import (ModelBundle, build_decoder_lm,
                                      build_hymba_lm, build_rwkv_lm)


def build(cfg: ArchConfig, *, param_dtype=jnp.float32, compute_dtype=None,
          remat: bool = False, impl: str = "xla",
          rolling_decode: bool = False,
          cache_dtype=jnp.bfloat16,
          decode_impl: str = "auto") -> ModelBundle:
    kw = dict(param_dtype=param_dtype, compute_dtype=compute_dtype,
              remat=remat, impl=impl, cache_dtype=cache_dtype)
    if cfg.family == "ssm":
        return build_rwkv_lm(cfg, **kw)
    if cfg.family == "hybrid":
        return build_hymba_lm(cfg, **kw)
    if cfg.family == "audio" or cfg.is_encoder_decoder:
        from repro.models.encdec import build_encdec
        return build_encdec(cfg, **kw)
    # dense / moe / vlm share the decoder-LM assembly
    return build_decoder_lm(cfg, rolling_decode=rolling_decode,
                            decode_impl=decode_impl, **kw)
