"""Hymba hybrid block [arXiv:2411.13676]: attention heads and Mamba(SSM)
heads run in PARALLEL on the same normalized input; each branch output is
re-normalized and the two are averaged before the residual add.  Attention
uses a sliding window (the release's few global-attention layers are
approximated by the same window — noted in DESIGN.md).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import mamba as mam
from repro.models.attention import (gqa_attention, gqa_decode, gqa_init,
                                    init_kv_cache, prefill_kv_cache)
from repro.models.common import Params, rmsnorm, rmsnorm_init
from repro.models.mlp import mlp_apply, mlp_init


def hymba_block_init(key, *, d_model: int, n_heads: int, n_kv_heads: int,
                     head_dim: int, d_ff: int, ssm_state: int,
                     ssm_expand: int, act: str, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "ln_in": rmsnorm_init(d_model, dtype),
        "attn": gqa_init(ks[0], d_model, n_heads, n_kv_heads, head_dim, dtype),
        "ssm": mam.mamba_init(ks[1], d_model, d_model * ssm_expand,
                              ssm_state, dtype),
        "ln_attn": rmsnorm_init(d_model, dtype),
        "ln_ssm": rmsnorm_init(d_model, dtype),
        "ln_mlp": rmsnorm_init(d_model, dtype),
        "mlp": mlp_init(ks[2], d_model, d_ff, act, dtype),
    }


def hymba_block_apply(p: Params, x, cos, sin, *, n_heads, n_kv_heads,
                      head_dim, ssm_state, window, eps, act,
                      impl: str = "xla"):
    h = rmsnorm(p["ln_in"], x, eps)
    a = gqa_attention(p["attn"], h, cos, sin, n_heads=n_heads,
                      n_kv_heads=n_kv_heads, head_dim=head_dim,
                      window=window, impl=impl)
    m, _, _ = mam.mamba_apply(p["ssm"], h, state=ssm_state)
    fused = 0.5 * (rmsnorm(p["ln_attn"], a, eps) + rmsnorm(p["ln_ssm"], m, eps))
    x = x + fused
    x = x + mlp_apply(p["mlp"], rmsnorm(p["ln_mlp"], x, eps), act)
    return x


def hymba_block_decode(p: Params, x, state: Dict[str, Any], cos, sin, *,
                       n_heads, n_kv_heads, head_dim, ssm_state, eps, act
                       ) -> Tuple[jax.Array, Dict[str, Any]]:
    h = rmsnorm(p["ln_in"], x, eps)
    a, kv = gqa_decode(p["attn"], h, state["kv"], cos, sin, n_heads=n_heads,
                       n_kv_heads=n_kv_heads, head_dim=head_dim, rolling=True)
    m, ssm = mam.mamba_decode(p["ssm"], h,
                              {"ssm": state["ssm"], "conv": state["conv"]},
                              state=ssm_state)
    fused = 0.5 * (rmsnorm(p["ln_attn"], a, eps) + rmsnorm(p["ln_ssm"], m, eps))
    x = x + fused
    x = x + mlp_apply(p["mlp"], rmsnorm(p["ln_mlp"], x, eps), act)
    return x, {"kv": kv, "ssm": ssm["ssm"], "conv": ssm["conv"]}


def init_hymba_state(batch: int, *, d_model: int, n_kv_heads: int,
                     head_dim: int, ssm_state: int, ssm_expand: int,
                     window: int, dtype=jnp.bfloat16) -> Dict[str, Any]:
    kv = init_kv_cache(batch, window, n_kv_heads, head_dim, dtype,
                       rolling=True, window=window)
    ms = mam.init_mamba_state(batch, d_model * ssm_expand, ssm_state)
    return {"kv": kv, "ssm": ms["ssm"], "conv": ms["conv"]}
