"""Modality-frontend STUBS (the one allowed carve-out).

The assignment specifies that for [audio] and [vlm] architectures only the
transformer backbone is implemented; the conv/mel codec and the ViT encoder
are replaced by precomputed embeddings of the right shape.  These helpers
produce those embeddings (random but deterministic) and the corresponding
``ShapeDtypeStruct`` specs used by the dry-run.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def vision_patch_embeds(key, batch: int, n_patches: int, d_model: int,
                        dtype=jnp.float32) -> jax.Array:
    """Stub ViT output: [B, n_patches, d_model]."""
    return 0.02 * jax.random.normal(key, (batch, n_patches, d_model), dtype)


def mrope_positions(batch: int, n_patches: int, text_len: int,
                    grid: Tuple[int, int, int] = None) -> jax.Array:
    """Qwen2-VL position ids [B, n_patches + text_len, 3] (t, h, w).

    Vision tokens get grid coordinates; text tokens continue sequentially
    from max(vision position) + 1 with t == h == w.
    """
    if grid is None:
        side = int(round(n_patches ** 0.5))
        while n_patches % side:
            side -= 1
        grid = (1, side, n_patches // side)
    t, h, w = grid
    assert t * h * w == n_patches, (grid, n_patches)
    tt, hh, ww = jnp.meshgrid(jnp.arange(t), jnp.arange(h), jnp.arange(w),
                              indexing="ij")
    vis = jnp.stack([tt.ravel(), hh.ravel(), ww.ravel()], axis=-1)
    start = int(max(grid))
    txt = start + jnp.arange(text_len)
    txt = jnp.stack([txt, txt, txt], axis=-1)
    pos = jnp.concatenate([vis, txt], axis=0).astype(jnp.int32)
    return jnp.broadcast_to(pos[None], (batch, n_patches + text_len, 3))


def audio_frame_embeds(key, batch: int, n_frames: int, d_model: int,
                       dtype=jnp.float32) -> jax.Array:
    """Stub speech-frontend output: [B, n_frames, d_model]."""
    return 0.02 * jax.random.normal(key, (batch, n_frames, d_model), dtype)


def make_train_batch(key, cfg: ArchConfig, batch: int, seq_len: int,
                     dtype=jnp.float32) -> Dict[str, jax.Array]:
    """A runnable synthetic batch honoring the family's input contract."""
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.family == "vlm":
        nv = min(cfg.frontend_tokens, max(1, seq_len // 4))
        st = seq_len - nv
        return {
            "tokens": jax.random.randint(k1, (batch, st), 0, cfg.vocab_size),
            "labels": jax.random.randint(k2, (batch, st), 0, cfg.vocab_size),
            "vision_embeds": vision_patch_embeds(k3, batch, nv, cfg.d_model,
                                                 dtype),
            "positions": mrope_positions(batch, nv, st),
        }
    if cfg.family == "audio":
        tf = min(cfg.frontend_tokens, max(4, seq_len // 4))
        return {
            "frames": audio_frame_embeds(k3, batch, tf, cfg.d_model, dtype),
            "tokens": jax.random.randint(k1, (batch, seq_len), 0,
                                         cfg.vocab_size),
            "labels": jax.random.randint(k2, (batch, seq_len), 0,
                                         cfg.vocab_size),
        }
    return {
        "tokens": jax.random.randint(k1, (batch, seq_len), 0, cfg.vocab_size),
        "labels": jax.random.randint(k2, (batch, seq_len), 0, cfg.vocab_size),
    }


def train_batch_specs(cfg: ArchConfig, batch: int, seq_len: int,
                      dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    S = jax.ShapeDtypeStruct
    if cfg.family == "vlm":
        nv = cfg.frontend_tokens
        st = seq_len - nv
        return {
            "tokens": S((batch, st), jnp.int32),
            "labels": S((batch, st), jnp.int32),
            "vision_embeds": S((batch, nv, cfg.d_model), dtype),
            "positions": S((batch, seq_len, 3), jnp.int32),
        }
    if cfg.family == "audio":
        return {
            "frames": S((batch, cfg.frontend_tokens, cfg.d_model), dtype),
            "tokens": S((batch, seq_len), jnp.int32),
            "labels": S((batch, seq_len), jnp.int32),
        }
    return {
        "tokens": S((batch, seq_len), jnp.int32),
        "labels": S((batch, seq_len), jnp.int32),
    }
