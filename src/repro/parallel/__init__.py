from repro.parallel.sharding import (PartitionRules,  # noqa: F401
                                     PSpecDropWarning, ShardPlan,
                                     batch_pspec, make_constraint_fn,
                                     param_pspecs, replica_groups,
                                     resolve_pspec, safe_pspec, shard_plan)
