from repro.parallel.sharding import (PartitionRules,  # noqa: F401
                                     batch_pspec, make_constraint_fn,
                                     param_pspecs, safe_pspec)
