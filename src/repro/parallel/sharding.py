"""Partition-rule engine: param-path regex -> PartitionSpec.

Megatron-style tensor layout on the ``model`` (TP) axis with ZeRO-style
sharding on the ``fsdp`` axis *inside* one learner:

  input-side weights  [d_in, d_out_parallel]  ->  (fsdp, model)
  output-side weights [d_in_parallel, d_out]  ->  (model, fsdp)
  embeddings          [V, d]                  ->  (None, model)
  MoE expert stacks   [E, ...]                ->  (model, fsdp, ...) expert par.
  norms / vectors                             ->  replicated

Leading *extra* dims of every leaf (stacked learner axes [pods, G, S] from
the Hier-AVG layout, and/or the stacked layer dim) are inferred from rank:
trainer-state leaves get ("pod","group","local") on their first three dims,
remaining extras None.

``safe_pspec`` drops any axis whose mesh size does not divide the array dim
(e.g. hymba's 25 attention heads vs TP-16, seamless' 256206 vocab), keeping
every config lowerable without special cases.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ordered (regex, inner spec relative to the *logical* trailing dims)
# first match wins; matched against "/"-joined param path.
DEFAULT_RULES: List[Tuple[str, Tuple]] = [
    # --- MoE expert stacks (leading E dim) ---
    (r"ffn/experts/w_(gate|up)$", ("model", "fsdp", None)),
    (r"ffn/experts/w_down$", ("model", None, "fsdp")),
    (r"ffn/router$", (None, None)),
    # --- rwkv channel-mix (names collide with attention; match parent) ---
    (r"cm/wk$", ("fsdp", "model")),
    (r"cm/wv$", ("model", "fsdp")),
    (r"cm/wr$", ("fsdp", "model")),
    (r"cm/mu_[kr]$", (None,)),
    # --- rwkv time-mix ---
    (r"tm/mu_x$", (None,)),
    (r"tm/mu$", (None, None)),
    (r"tm/mix_A$", ("fsdp", None)),
    (r"tm/mix_B$", (None, "model")),
    (r"tm/decay_(base|A|B)$", None),   # resolved below by rank
    (r"tm/u$", (None,)),
    # --- mamba ---
    (r"ssm/in_proj$", ("fsdp", "model")),
    (r"ssm/conv_[wb]$", None),
    (r"ssm/x_proj$", ("model", None)),
    (r"ssm/dt_proj$", (None, "model")),
    (r"ssm/dt_bias$", ("model",)),
    (r"ssm/A_log$", ("model", None)),
    (r"ssm/D$", ("model",)),
    (r"ssm/out_proj$", ("model", "fsdp")),
    # --- attention (GQA + MLA) ---
    (r"(attn|self_attn|cross_attn)/w[qkv]$", ("fsdp", "model")),
    (r"(attn|self_attn|cross_attn)/wo$", ("model", "fsdp")),
    (r"attn/w_dkv$", ("fsdp", None)),
    (r"attn/w_kr$", ("fsdp", None)),
    (r"attn/w_u[kv]$", (None, "model")),
    (r"attn/kv_norm/.*$", (None,)),
    # --- rwkv top-level projections (wr/wk/wv/wg under tm) ---
    (r"tm/w[rkvg]$", ("fsdp", "model")),
    (r"tm/wo$", ("model", "fsdp")),
    # --- mlp ---
    (r"(mlp|ffn|ffn/shared)/w_(gate|up)$", ("fsdp", "model")),
    (r"(mlp|ffn|ffn/shared)/w_down$", ("model", "fsdp")),
    # --- embeddings / heads ---
    # vocab-sharded: token gather goes collective, but (tied) unembed logits
    # come out vocab-sharded — O(V) logits tensors never replicate over TP
    (r"embed$", ("model", None)),
    (r"lm_head$", ("fsdp", "model")),
    (r"head$", ("fsdp", None)),
    # --- norms and leftovers: replicate (resolved by rank) ---
]


class PartitionRules:
    """Resolve PartitionSpecs for a params pytree.

    axis_map renames the logical axes ("pod","group","local","fsdp","model")
    to the actual mesh axes (serving meshes use ("data","model") only).
    """

    def __init__(self, rules: Optional[List[Tuple[str, Tuple]]] = None,
                 *, learner_axes: Sequence[Optional[str]] =
                 ("pod", "group", "local"),
                 axis_map: Optional[Dict[str, Optional[str]]] = None):
        self.rules = [(re.compile(pat), spec)
                      for pat, spec in (rules or DEFAULT_RULES)]
        self.learner_axes = tuple(learner_axes)
        self.axis_map = axis_map or {}

    def _rename(self, ax):
        if ax is None:
            return None
        return self.axis_map.get(ax, ax)

    def inner_spec(self, path: str, rank: int) -> Tuple:
        for pat, spec in self.rules:
            if pat.search(path):
                if spec is not None and len(spec) <= rank:
                    return spec
                break
        # fallback by rank: replicate vectors; 2-D -> (fsdp, model)
        if rank >= 2:
            return ("fsdp", "model") + (None,) * (rank - 2)
        return (None,) * rank

    def spec_for(self, path: str, shape: Tuple[int, ...],
                 *, stacked_learners: bool) -> P:
        rank = len(shape)
        lead = len(self.learner_axes) if stacked_learners else 0
        # try decreasing inner rank until it fits (extra dims: layer stacks)
        for inner_rank in range(min(rank - lead, rank), -1, -1):
            inner = self.inner_spec(path, inner_rank)
            if len(inner) == inner_rank:
                break
        extras = rank - lead - len(inner)
        if extras < 0:           # tiny leaf, fewer dims than learner axes
            lead, extras, inner = 0, 0, (None,) * rank
        axes = (tuple(self.learner_axes[:lead]) + (None,) * extras
                + tuple(inner))
        axes = tuple(self._rename(a) for a in axes)
        return P(*axes)


def safe_pspec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop axis names whose mesh size does not divide the array dim."""
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            out.append(None)
            continue
        names = ax if isinstance(ax, tuple) else (ax,)
        size = 1
        for n in names:
            size *= mesh.shape[n]
        out.append(ax if dim % size == 0 else None)
    return P(*out)


def param_pspecs(params, mesh: Mesh, *, stacked_learners: bool,
                 rules: Optional[PartitionRules] = None):
    """Pytree of PartitionSpecs matching ``params`` (divisibility-safe)."""
    rules = rules or PartitionRules()
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    specs = {}
    out = jax.tree_util.tree_map_with_path(
        lambda kp, x: safe_pspec(
            rules.spec_for(_path_str(kp), x.shape,
                           stacked_learners=stacked_learners),
            x.shape, mesh),
        params)
    return out


def _path_str(kp) -> str:
    parts = []
    for k in kp:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
    return "/".join(parts)


def batch_pspec(ndim_after_learner: int, *, round_dims: int = 2,
                stacked_learners: bool = True,
                batch_axis: Optional[str] = "fsdp",
                axis_map: Optional[Dict[str, Optional[str]]] = None) -> P:
    """Spec for round batches [beta, K1, pods, G, S, B, ...trailing]."""
    axis_map = axis_map or {}
    ren = lambda a: axis_map.get(a, a) if a else None
    lead = (None,) * round_dims
    learner = (ren("pod"), ren("group"), ren("local")) if stacked_learners \
        else ()
    tail = (ren(batch_axis),) + (None,) * (ndim_after_learner - 1)
    return P(*(lead + learner + tail))


def make_constraint_fn(mesh: Mesh, specs):
    """constraint_fn for core.hier_avg: re-pin shardings after averaging."""
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs)

    def constrain(tree):
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            shardings)
    return constrain
