"""Partition-rule engine: param-path regex -> PartitionSpec.

Megatron-style tensor layout on the ``model`` (TP) axis with ZeRO-style
sharding on the ``fsdp`` axis *inside* one learner:

  input-side weights  [d_in, d_out_parallel]  ->  (fsdp, model)
  output-side weights [d_in_parallel, d_out]  ->  (model, fsdp)
  embeddings          [V, d]                  ->  (None, model)
  MoE expert stacks   [E, ...]                ->  (model, fsdp, ...) expert par.
  norms / vectors                             ->  replicated

Leading *extra* dims of every leaf (stacked learner axes [pods, G, S] from
the Hier-AVG layout, and/or the stacked layer dim) are inferred from rank:
trainer-state leaves get ("pod","group","local") on their first three dims,
remaining extras None.

``safe_pspec`` drops any axis whose mesh size does not divide the array dim
(e.g. hymba's 25 attention heads vs TP-16, seamless' 256206 vocab), keeping
every config lowerable without special cases.  The drop is *surfaced*: it
warns (:class:`PSpecDropWarning`) and ``resolve_pspec`` exposes the dropped
set, so the shard-aware bucket layout (comm/bucket.py) and the cost model
(core/theory.py) agree on which leaves are actually sharded instead of
double-billing a silently replicated fallback.

:class:`ShardPlan` is the handle the reduction stack carries for an
``fsdp > 1`` layout: which mesh axis shards the per-learner trailing dims,
which leaf dim it lands on (via the same rules + divisibility resolution as
``safe_pspec``), and the mesh itself — so bucket layouts, scatter-mean
collectives, and theory billing all resolve sharding identically.
"""
from __future__ import annotations

import math
import re
import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ordered (regex, inner spec relative to the *logical* trailing dims)
# first match wins; matched against "/"-joined param path.
DEFAULT_RULES: List[Tuple[str, Tuple]] = [
    # --- MoE expert stacks (leading E dim) ---
    (r"ffn/experts/w_(gate|up)$", ("model", "fsdp", None)),
    (r"ffn/experts/w_down$", ("model", None, "fsdp")),
    (r"ffn/router$", (None, None)),
    # --- rwkv channel-mix (names collide with attention; match parent) ---
    (r"cm/wk$", ("fsdp", "model")),
    (r"cm/wv$", ("model", "fsdp")),
    (r"cm/wr$", ("fsdp", "model")),
    (r"cm/mu_[kr]$", (None,)),
    # --- rwkv time-mix ---
    (r"tm/mu_x$", (None,)),
    (r"tm/mu$", (None, None)),
    (r"tm/mix_A$", ("fsdp", None)),
    (r"tm/mix_B$", (None, "model")),
    (r"tm/decay_(base|A|B)$", None),   # resolved below by rank
    (r"tm/u$", (None,)),
    # --- mamba ---
    (r"ssm/in_proj$", ("fsdp", "model")),
    (r"ssm/conv_[wb]$", None),
    (r"ssm/x_proj$", ("model", None)),
    (r"ssm/dt_proj$", (None, "model")),
    (r"ssm/dt_bias$", ("model",)),
    (r"ssm/A_log$", ("model", None)),
    (r"ssm/D$", ("model",)),
    (r"ssm/out_proj$", ("model", "fsdp")),
    # --- attention (GQA + MLA) ---
    (r"(attn|self_attn|cross_attn)/w[qkv]$", ("fsdp", "model")),
    (r"(attn|self_attn|cross_attn)/wo$", ("model", "fsdp")),
    (r"attn/w_dkv$", ("fsdp", None)),
    (r"attn/w_kr$", ("fsdp", None)),
    (r"attn/w_u[kv]$", (None, "model")),
    (r"attn/kv_norm/.*$", (None,)),
    # --- rwkv top-level projections (wr/wk/wv/wg under tm) ---
    (r"tm/w[rkvg]$", ("fsdp", "model")),
    (r"tm/wo$", ("model", "fsdp")),
    # --- mlp ---
    (r"(mlp|ffn|ffn/shared)/w_(gate|up)$", ("fsdp", "model")),
    (r"(mlp|ffn|ffn/shared)/w_down$", ("model", "fsdp")),
    # --- embeddings / heads ---
    # vocab-sharded: token gather goes collective, but (tied) unembed logits
    # come out vocab-sharded — O(V) logits tensors never replicate over TP
    (r"embed$", ("model", None)),
    (r"lm_head$", ("fsdp", "model")),
    (r"head$", ("fsdp", None)),
    # --- norms and leftovers: replicate (resolved by rank) ---
]


class PartitionRules:
    """Resolve PartitionSpecs for a params pytree.

    axis_map renames the logical axes ("pod","group","local","fsdp","model")
    to the actual mesh axes (serving meshes use ("data","model") only).
    """

    def __init__(self, rules: Optional[List[Tuple[str, Tuple]]] = None,
                 *, learner_axes: Sequence[Optional[str]] =
                 ("pod", "group", "local"),
                 axis_map: Optional[Dict[str, Optional[str]]] = None):
        self.rules = [(re.compile(pat), spec)
                      for pat, spec in (rules or DEFAULT_RULES)]
        self.learner_axes = tuple(learner_axes)
        self.axis_map = axis_map or {}

    def _rename(self, ax):
        if ax is None:
            return None
        return self.axis_map.get(ax, ax)

    def inner_spec(self, path: str, rank: int) -> Tuple:
        for pat, spec in self.rules:
            if pat.search(path):
                if spec is not None and len(spec) <= rank:
                    return spec
                break
        # fallback by rank: replicate vectors; 2-D -> (fsdp, model)
        if rank >= 2:
            return ("fsdp", "model") + (None,) * (rank - 2)
        return (None,) * rank

    def spec_for(self, path: str, shape: Tuple[int, ...],
                 *, stacked_learners: bool) -> P:
        rank = len(shape)
        lead = len(self.learner_axes) if stacked_learners else 0
        # try decreasing inner rank until it fits (extra dims: layer stacks)
        for inner_rank in range(min(rank - lead, rank), -1, -1):
            inner = self.inner_spec(path, inner_rank)
            if len(inner) == inner_rank:
                break
        extras = rank - lead - len(inner)
        if extras < 0:           # tiny leaf, fewer dims than learner axes
            lead, extras, inner = 0, 0, (None,) * rank
        axes = (tuple(self.learner_axes[:lead]) + (None,) * extras
                + tuple(inner))
        axes = tuple(self._rename(a) for a in axes)
        return P(*axes)


class PSpecDropWarning(UserWarning):
    """A requested partition axis was dropped (non-dividing dim): the leaf
    stays replicated over that mesh axis.  Layout and billing must use the
    *resolved* spec — see ``resolve_pspec``."""


def resolve_pspec(spec: P, shape: Tuple[int, ...], mesh: Mesh
                  ) -> Tuple[P, Tuple[Tuple[int, object], ...]]:
    """Resolve ``spec`` against ``shape``/``mesh``: drop axis names whose
    mesh size does not divide the array dim, and *return the drops* as
    ``(dim_index, axis_name)`` pairs so callers can bill / warn from the
    resolved layout instead of the requested one."""
    out, dropped = [], []
    for d, (dim, ax) in enumerate(
            zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec)))):
        if ax is None:
            out.append(None)
            continue
        names = ax if isinstance(ax, tuple) else (ax,)
        size = 1
        for n in names:
            size *= mesh.shape[n]
        if dim % size == 0:
            out.append(ax)
        else:
            out.append(None)
            dropped.append((d, ax))
    return P(*out), tuple(dropped)


def safe_pspec(spec: P, shape: Tuple[int, ...], mesh: Mesh,
               *, warn: bool = True) -> P:
    """Drop axis names whose mesh size does not divide the array dim.

    Dropping means the leaf silently stays *replicated* over that mesh
    axis — which matters to anything that assumes the spec it asked for
    (memory budgets, shard-aware bucket layouts, comm billing) — so the
    drop warns by default; pass ``warn=False`` where the replicated
    fallback is expected, or use ``resolve_pspec`` to inspect the drops.
    """
    out, dropped = resolve_pspec(spec, shape, mesh)
    if warn and dropped:
        warnings.warn(
            f"safe_pspec: dropping non-dividing axes {list(dropped)} of "
            f"spec {spec} for shape {tuple(shape)} — those dims stay "
            f"replicated; layouts/billing must use the resolved spec "
            f"{out}", PSpecDropWarning, stacklevel=2)
    return out


@dataclass(frozen=True)
class ShardPlan:
    """How an ``fsdp > 1`` ``ParallelLayout`` shards the per-learner
    trailing dims — the single handle the whole reduction stack keys off:

      * ``comm/bucket.py`` packs a per-shard run per bucket from
        ``leaf_shard_dim`` (the same rules + divisibility resolution as
        ``safe_pspec``, so layout and actual placement cannot disagree),
      * ``core/topology.py`` lowers the per-bucket grouped mean to
        reduce-scatter + all-gather over ``mesh``,
      * ``core/theory.py`` bills shard-local wire payloads (1/``size``).

    Hashable (the jax Mesh is); ``rules`` is excluded from eq/hash — two
    plans over the same mesh/axis resolve identically for the default
    rules, and layout caches key off identity-relevant fields only.
    """

    mesh: Mesh
    axis: str = "fsdp"
    lead: Tuple[str, ...] = ("pod", "group", "local")
    rules: Optional[PartitionRules] = field(default=None, compare=False,
                                            hash=False)

    @property
    def size(self) -> int:
        """Shards per learner (the fsdp mesh-axis size)."""
        return int(self.mesh.shape[self.axis])

    @property
    def n_lead(self) -> int:
        """Total learner count on the mesh — bucket runs are padded to a
        multiple of this so every level's reduce-scatter tiles evenly."""
        n = 1
        for a in self.lead:
            n *= int(self.mesh.shape.get(a, 1))
        return n

    def leaf_shard_dim(self, path: str, shape: Tuple[int, ...]
                       ) -> Optional[int]:
        """Which *trailing* (per-learner) dim of the leaf at ``path`` the
        shard axis lands on, or None when the leaf stays replicated
        (rules put the axis nowhere, or it does not divide — exactly the
        ``safe_pspec``/``resolve_pspec`` drop)."""
        if self.size <= 1:
            return None
        rules = self.rules or PartitionRules()
        spec = rules.spec_for(path, shape, stacked_learners=False)
        resolved, _ = resolve_pspec(spec, shape, self.mesh)
        for d, ax in enumerate(tuple(resolved)):
            if ax == self.axis:
                return d
        return None


def shard_plan(mesh: Mesh, *, axis: str = "fsdp",
               lead: Tuple[str, ...] = ("pod", "group", "local"),
               rules: Optional[PartitionRules] = None
               ) -> Optional[ShardPlan]:
    """ShardPlan for ``mesh``, or None when the shard axis is absent or
    trivial (``fsdp=1`` layouts run the replicated fast path)."""
    if axis not in mesh.shape or mesh.shape[axis] <= 1:
        return None
    return ShardPlan(mesh=mesh, axis=axis, lead=lead, rules=rules)


def replica_groups(mesh: Mesh, reduce_axes: Sequence[str]
                   ) -> List[List[int]]:
    """Device-id groups of the grouped collective that reduces over
    ``reduce_axes``: one group per coordinate of the *kept* axes (the
    pxla ShardingSpec recipe — row-major device order, reduced axes
    minor).  E.g. a global reduction on a (pod, group, local, fsdp) mesh
    keeps fsdp, so each fsdp shard averages only with its peers."""
    shape = mesh.devices.shape
    ids = np.arange(math.prod(shape)).reshape(shape)
    names = mesh.axis_names
    red = [i for i, n in enumerate(names) if n in tuple(reduce_axes)]
    keep = [i for i in range(len(names)) if i not in red]
    group_n = math.prod(shape[i] for i in red) if red else 1
    grouped = ids.transpose(keep + red).reshape(-1, group_n)
    return [[int(d) for d in row] for row in grouped]


def param_pspecs(params, mesh: Mesh, *, stacked_learners: bool,
                 rules: Optional[PartitionRules] = None):
    """Pytree of PartitionSpecs matching ``params`` (divisibility-safe)."""
    rules = rules or PartitionRules()
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    specs = {}
    out = jax.tree_util.tree_map_with_path(
        lambda kp, x: safe_pspec(
            rules.spec_for(_path_str(kp), x.shape,
                           stacked_learners=stacked_learners),
            x.shape, mesh),
        params)
    return out


def _path_str(kp) -> str:
    parts = []
    for k in kp:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
    return "/".join(parts)


def batch_pspec(ndim_after_learner: int, *, round_dims: int = 2,
                stacked_learners: bool = True,
                batch_axis: Optional[str] = "fsdp",
                axis_map: Optional[Dict[str, Optional[str]]] = None) -> P:
    """Spec for round batches [beta, K1, pods, G, S, B, ...trailing]."""
    axis_map = axis_map or {}
    ren = lambda a: axis_map.get(a, a) if a else None
    lead = (None,) * round_dims
    learner = (ren("pod"), ren("group"), ren("local")) if stacked_learners \
        else ()
    tail = (ren(batch_axis),) + (None,) * (ndim_after_learner - 1)
    return P(*(lead + learner + tail))


def make_constraint_fn(mesh: Mesh, specs):
    """constraint_fn for core.hier_avg: re-pin shardings after averaging."""
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs)

    def constrain(tree):
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            shardings)
    return constrain
