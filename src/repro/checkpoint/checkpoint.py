"""Sharding-aware pytree checkpointing (npz + json manifest; no orbax here).

save_checkpoint writes:
  <dir>/manifest.json   — tree structure, shapes, dtypes, step, user metadata
  <dir>/arrays.npz      — leaves keyed by their flattened path

restore_checkpoint(dir, like=...) re-places each leaf with the sharding of
the matching leaf in ``like`` (so a checkpoint taken on one mesh restores
onto another — resharding happens in device_put).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import numpy as np


def _path_str(kp) -> str:
    parts = []
    for k in kp:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def save_checkpoint(path: str, tree: Any, *, step: int = 0,
                    metadata: Optional[Dict] = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {}
    entries = []
    for kp, leaf in flat:
        key = _path_str(kp)
        arr = np.asarray(jax.device_get(leaf))
        arrays[key] = arr
        entries.append({"path": key, "shape": list(arr.shape),
                        "dtype": str(arr.dtype)})
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    manifest = {"step": step, "entries": entries,
                "metadata": metadata or {}}
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)


def load_checkpoint(path: str) -> Dict[str, np.ndarray]:
    with np.load(os.path.join(path, "arrays.npz")) as z:
        return {k: z[k] for k in z.files}


def checkpoint_step(path: str) -> int:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)["step"]


def restore_checkpoint(path: str, like: Any) -> Any:
    """Restore into the structure (and shardings, if any) of ``like``."""
    arrays = load_checkpoint(path)

    def restore(kp, leaf):
        key = _path_str(kp)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf '{key}'")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for '{key}': ckpt {arr.shape} vs "
                f"expected {leaf.shape}")
        sharding = getattr(leaf, "sharding", None)
        if sharding is not None and hasattr(sharding, "mesh"):
            return jax.device_put(arr.astype(leaf.dtype), sharding)
        return jax.numpy.asarray(arr, dtype=leaf.dtype)

    return jax.tree_util.tree_map_with_path(restore, like)
