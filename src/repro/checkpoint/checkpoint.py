"""Sharding-aware pytree checkpointing (npz + json manifest; no orbax here).

save_checkpoint writes:
  <dir>/manifest.json   — tree structure, shapes, dtypes, step, user metadata
  <dir>/arrays.npz      — leaves keyed by their flattened path

restore_checkpoint(dir, like=...) validates every restored array against
the manifest AND against ``like`` (exact path set, shape, dtype — any
mismatch raises naming the offending leaf; nothing is silently cast),
then re-places each leaf: onto the ``shardings=`` override if given, else
onto the matching ``like`` leaf's mesh-backed sharding (so a checkpoint
taken on one mesh restores onto another — resharding happens in
device_put), else onto a concrete ``like`` leaf's committed placement.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import numpy as np


def _path_str(kp) -> str:
    parts = []
    for k in kp:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def save_checkpoint(path: str, tree: Any, *, step: int = 0,
                    metadata: Optional[Dict] = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {}
    entries = []
    for kp, leaf in flat:
        key = _path_str(kp)
        arr = np.asarray(jax.device_get(leaf))
        arrays[key] = arr
        entries.append({"path": key, "shape": list(arr.shape),
                        "dtype": str(arr.dtype)})
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    manifest = {"step": step, "entries": entries,
                "metadata": metadata or {}}
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)


def load_checkpoint(path: str) -> Dict[str, np.ndarray]:
    with np.load(os.path.join(path, "arrays.npz")) as z:
        return {k: z[k] for k in z.files}


def checkpoint_step(path: str) -> int:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)["step"]


def _validate_manifest(path: str, arrays: Dict[str, np.ndarray]) -> None:
    """Cross-check arrays.npz against manifest.json: same leaf set, and
    each array's shape/dtype matches what the manifest recorded at save
    time.  Any drift means on-disk corruption (truncated npz, manifest
    from a different run) and raises naming the offending leaf."""
    with open(os.path.join(path, "manifest.json")) as f:
        entries = {e["path"]: e for e in json.load(f)["entries"]}
    man_only = sorted(set(entries) - set(arrays))
    npz_only = sorted(set(arrays) - set(entries))
    if man_only or npz_only:
        raise ValueError(
            f"corrupt checkpoint at '{path}': manifest.json and "
            f"arrays.npz disagree (manifest-only leaves: {man_only}, "
            f"npz-only leaves: {npz_only})")
    for key, e in entries.items():
        arr = arrays[key]
        if (list(arr.shape) != list(e["shape"])
                or str(arr.dtype) != e["dtype"]):
            raise ValueError(
                f"corrupt checkpoint at '{path}': leaf '{key}' is "
                f"{arr.dtype}{tuple(arr.shape)} in arrays.npz but the "
                f"manifest records "
                f"{e['dtype']}{tuple(e['shape'])}")


def restore_checkpoint(path: str, like: Any, *,
                       shardings: Any = None) -> Any:
    """Restore into the structure of ``like``.

    Validation: the checkpoint's leaf set must equal ``like``'s exactly
    (extra or missing paths raise listing them), each array must match
    its manifest entry (:func:`_validate_manifest`), and each array's
    shape AND dtype must match the corresponding ``like`` leaf — a dtype
    drift raises instead of silently casting, since for EF/quantized
    reducer state a cast would corrupt the carried error feedback.

    Placement per leaf: the matching ``shardings`` override leaf if one
    is given (a pytree mirroring ``like`` with Sharding-or-None leaves);
    else device_put onto the ``like`` leaf's sharding when it is
    mesh-backed (restores shard-space state directly onto the target
    mesh); else a concrete ``like`` leaf's committed placement; else a
    plain host-backed jnp array (abstract ``like`` leaves)."""
    arrays = load_checkpoint(path)
    _validate_manifest(path, arrays)

    like_flat = jax.tree_util.tree_flatten_with_path(like)[0]
    like_keys = [_path_str(kp) for kp, _ in like_flat]
    extra = sorted(set(arrays) - set(like_keys))
    if extra:
        raise ValueError(
            f"checkpoint at '{path}' has leaves with no counterpart in "
            f"`like` (tree path mismatch?): {extra}")
    missing = sorted(set(like_keys) - set(arrays))
    if missing:
        raise KeyError(
            f"checkpoint at '{path}' missing leaves: {missing}")

    override: Dict[str, Any] = {}
    if shardings is not None:
        s_leaves, s_def = jax.tree_util.tree_flatten(
            shardings, is_leaf=lambda x: x is None)
        l_def = jax.tree_util.tree_structure(like)
        if s_def != l_def:
            raise ValueError(
                "`shardings` must mirror the structure of `like` "
                f"(got {s_def}, expected {l_def})")
        override = dict(zip(like_keys, s_leaves))

    def restore(kp, leaf):
        key = _path_str(kp)
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            # learner-count drift: same per-learner payload, different
            # stacked [pods, groups, local] lead — the elastic-resume
            # case, which has its own entry point
            if (arr.ndim == len(leaf.shape) and arr.ndim > 3
                    and tuple(arr.shape[3:]) == tuple(leaf.shape[3:])
                    and tuple(arr.shape[:3]) != tuple(leaf.shape[:3])):
                old_n = int(np.prod(arr.shape[:3]))
                new_n = int(np.prod(leaf.shape[:3]))
                raise ValueError(
                    f"learner-count mismatch for '{key}': the checkpoint "
                    f"was saved on a {tuple(arr.shape[:3])} "
                    f"[pods, groups, local] learner grid ({old_n} "
                    f"learners) but `like` expects "
                    f"{tuple(leaf.shape[:3])} ({new_n} learners).  "
                    f"restore_checkpoint never resizes the learner axes "
                    f"— resume onto a different fleet with "
                    f"repro.elastic.elastic_restore(path, like, "
                    f"new_topo=...), which bit-preserves survivors and "
                    f"remaps (or loudly drops) reducer state.")
            raise ValueError(
                f"shape mismatch for '{key}': ckpt {arr.shape} vs "
                f"expected {tuple(leaf.shape)}")
        if arr.dtype != np.dtype(leaf.dtype):
            raise ValueError(
                f"dtype mismatch for '{key}': ckpt {arr.dtype} vs "
                f"expected {np.dtype(leaf.dtype)} (restore never casts "
                f"— fix `like` or re-save the checkpoint)")
        sh = override.get(key)
        if sh is not None:
            return jax.device_put(arr, sh)
        sh = getattr(leaf, "sharding", None)
        if sh is not None and getattr(sh, "mesh", None) is not None:
            return jax.device_put(arr, sh)
        if isinstance(leaf, jax.Array):
            return jax.device_put(arr, leaf.sharding)
        return jax.numpy.asarray(arr)

    return jax.tree_util.tree_map_with_path(restore, like)
