from repro.checkpoint.checkpoint import (load_checkpoint,  # noqa: F401
                                         restore_checkpoint, save_checkpoint)
