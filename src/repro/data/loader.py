"""Sharded data loader for Hier-AVG rounds.

Responsibilities:
  * per-learner INDEPENDENT streams — learner (p, g, s) draws from
    ``fold_in(round_key, learner_id)``; the paper's xi^j_{k,s} i.i.d.
    assumption is realized exactly;
  * round batching — leaves shaped [*plan.batch_dims, pods, G, S, B, ...]
    to feed ``make_hier_round`` ([beta, K1, ...] for the 2-level plan);
  * optional device placement with the launcher's NamedShardings.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import HierAvgParams
from repro.core.topology import HierTopology


class HierDataLoader:
    """sample_fn(key, n) -> batch with leading example dim n."""

    def __init__(self, sample_fn: Callable, *, topo: HierTopology,
                 hier: HierAvgParams, per_learner_batch: int,
                 seed: int = 0, shardings: Optional[Any] = None):
        self.sample = sample_fn
        self.topo = topo
        self.hier = hier
        self.B = per_learner_batch
        self.key = jax.random.PRNGKey(seed)
        self.shardings = shardings
        self._round = 0

    @property
    def tokens_per_round(self) -> int:
        return self.hier.steps_per_round * self.topo.n_learners * self.B

    def next_round(self) -> Dict[str, jax.Array]:
        key = jax.random.fold_in(self.key, self._round)
        self._round += 1
        shape = self.hier.batch_dims + self.topo.shape
        # one independent key per (step, learner) cell
        n_cells = self.hier.steps_per_round * self.topo.n_learners
        keys = jax.random.split(key, n_cells)
        flat = [self.sample(k, self.B) for k in keys]
        batch = jax.tree.map(lambda *xs: jnp.stack(xs), *flat)
        batch = jax.tree.map(
            lambda x: x.reshape(shape + (self.B,) + x.shape[2:]), batch)
        if self.shardings is not None:
            batch = jax.device_put(batch, self.shardings)
        return batch

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        while True:
            yield self.next_round()
