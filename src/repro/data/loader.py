"""Sharded data loader for Hier-AVG rounds.

Responsibilities:
  * per-learner INDEPENDENT streams — learner (p, g, s) draws from
    ``fold_in(round_key, learner_id)``; the paper's xi^j_{k,s} i.i.d.
    assumption is realized exactly;
  * round batching — leaves shaped [*plan.batch_dims, pods, G, S, B, ...]
    to feed ``make_hier_round`` ([beta, K1, ...] for the 2-level plan);
  * schedule-aware shard assignment — :func:`round_batch_shardings`
    builds the NamedShardings for a round batch of ANY plan depth
    (every caller used to hand-build the `(None,)*len(batch_dims)`
    prefix per site, baked for the 2-/3-level layouts); optional device
    placement with those (or the launcher's) NamedShardings.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import HierAvgParams
from repro.core.topology import HierTopology, LEARNER_AXES


def round_batch_pspec(batch_dims, leaf_ndim: int, mesh: Mesh,
                      leaf_shape=None,
                      data_axis: Optional[str] = "fsdp") -> P:
    """PartitionSpec of one round-batch leaf under a plan of ANY depth.

    The leading ``len(batch_dims)`` step axes (one per plan level —
    however many the plan has) are replicated, the three stacked learner
    axes shard over the mesh's learner axes, the per-learner example dim
    over ``data_axis`` (when the mesh carries it), and trailing
    per-example dims are replicated.  With ``leaf_shape`` given the spec
    is divisibility-checked (``safe_pspec``)."""
    n_lead = len(tuple(batch_dims))
    if leaf_ndim < n_lead + len(LEARNER_AXES):
        # refuse loudly rather than silently dropping learner axes off
        # the spec and mis-sharding the leaf
        raise ValueError(
            f"round-batch leaf has {leaf_ndim} dims but the plan needs "
            f"{n_lead} step dims + {len(LEARNER_AXES)} learner dims "
            f"(batch_dims={tuple(batch_dims)})")
    tail_names = (data_axis,) if (data_axis and data_axis
                                  in mesh.shape) else ()
    spec = ((None,) * n_lead + LEARNER_AXES + tail_names)
    spec = spec + (None,) * (leaf_ndim - len(spec))
    spec = P(*spec[:leaf_ndim])
    if leaf_shape is not None:
        from repro.parallel.sharding import safe_pspec
        spec = safe_pspec(spec, tuple(leaf_shape), mesh)
    return spec


def round_batch_shardings(mesh: Mesh, hier: HierAvgParams, batch,
                          data_axis: Optional[str] = "fsdp"):
    """NamedShardings for a whole round batch (arrays or
    ShapeDtypeStructs), generic in the plan depth via
    ``hier.batch_dims``."""
    dims = hier.batch_dims
    return jax.tree.map(
        lambda leaf: NamedSharding(
            mesh, round_batch_pspec(dims, leaf.ndim, mesh,
                                    leaf_shape=leaf.shape,
                                    data_axis=data_axis)),
        batch)


class HierDataLoader:
    """sample_fn(key, n) -> batch with leading example dim n."""

    def __init__(self, sample_fn: Callable, *, topo: HierTopology,
                 hier: HierAvgParams, per_learner_batch: int,
                 seed: int = 0, shardings: Optional[Any] = None,
                 mesh: Optional[Mesh] = None):
        self.sample = sample_fn
        self.topo = topo
        self.hier = hier
        self.B = per_learner_batch
        self.key = jax.random.PRNGKey(seed)
        # explicit shardings win; with only a mesh the loader derives
        # the schedule-aware ones from the first round's leaf shapes
        # (round_batch_shardings — any plan depth)
        self.shardings = shardings
        self.mesh = mesh
        self._round = 0

    @property
    def tokens_per_round(self) -> int:
        return self.hier.steps_per_round * self.topo.n_learners * self.B

    def next_round(self) -> Dict[str, jax.Array]:
        key = jax.random.fold_in(self.key, self._round)
        self._round += 1
        shape = self.hier.batch_dims + self.topo.shape
        # one independent key per (step, learner) cell
        n_cells = self.hier.steps_per_round * self.topo.n_learners
        keys = jax.random.split(key, n_cells)
        flat = [self.sample(k, self.B) for k in keys]
        batch = jax.tree.map(lambda *xs: jnp.stack(xs), *flat)
        batch = jax.tree.map(
            lambda x: x.reshape(shape + (self.B,) + x.shape[2:]), batch)
        if self.shardings is None and self.mesh is not None:
            self.shardings = round_batch_shardings(self.mesh, self.hier,
                                                   batch)
        if self.shardings is not None:
            batch = jax.device_put(batch, self.shardings)
        return batch

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        while True:
            yield self.next_round()
