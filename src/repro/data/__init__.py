from repro.data.synthetic import (gaussian_mixture_batch,  # noqa: F401
                                  markov_lm_batch, make_markov_task,
                                  make_classification_task)
from repro.data.loader import HierDataLoader  # noqa: F401
