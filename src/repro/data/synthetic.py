"""Synthetic but *learnable* data sources (offline container — no CIFAR).

Hier-AVG's analysis assumes each learner draws i.i.d. samples xi from the
same distribution; these generators are pure functions of a PRNG key, so
per-learner independence is exactly a ``fold_in`` (see loader.py).

  * markov LM: tokens follow a fixed random first-order Markov chain —
    cross-entropy has a known floor (the chain's conditional entropy) so
    convergence curves are interpretable.
  * gaussian-mixture classification: the CIFAR stand-in for the paper's
    K2/K1/S sweeps (fast enough for P up to 64 learners on one CPU core).
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp


def make_markov_task(vocab: int, temperature: float = 1.5, seed: int = 1234
                     ) -> Tuple[jax.Array, float]:
    """Returns (transition logits [V, V], per-token entropy floor in nats)."""
    key = jax.random.PRNGKey(seed)
    logits = jax.random.normal(key, (vocab, vocab)) * temperature
    logp = jax.nn.log_softmax(logits, -1)
    p = jnp.exp(logp)
    cond_ent = -jnp.sum(p * logp, -1)                 # [V]
    # stationary distribution via power iteration
    pi = jnp.ones((vocab,)) / vocab
    for _ in range(64):
        pi = pi @ p
    floor = float(jnp.sum(pi * cond_ent))
    return logits, floor


@functools.partial(jax.jit, static_argnums=(1, 2))
def _markov_sample(key, batch: int, seq: int, logits) -> jax.Array:
    vocab = logits.shape[0]
    k0, key = jax.random.split(key)
    first = jax.random.randint(k0, (batch,), 0, vocab)

    def step(tok, k):
        nxt = jax.random.categorical(k, logits[tok])
        return nxt, nxt

    keys = jax.random.split(key, seq - 1)
    _, rest = jax.lax.scan(step, first, keys)
    return jnp.concatenate([first[None], rest], 0).T   # [batch, seq]


def markov_lm_batch(key, n: int, seq: int, logits) -> Dict[str, jax.Array]:
    toks = _markov_sample(key, n, seq + 1, logits)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_classification_task(in_dim: int, n_classes: int, seed: int = 4321,
                             noise: float = 0.6) -> Callable:
    """Gaussian mixture: class means on a random simplex; returns sampler
    sample(key, n) -> {'x': [n, in_dim], 'y': [n]}."""
    key = jax.random.PRNGKey(seed)
    means = jax.random.normal(key, (n_classes, in_dim))
    means = means / jnp.linalg.norm(means, axis=-1, keepdims=True) * 2.0

    def sample(k, n: int) -> Dict[str, jax.Array]:
        k1, k2 = jax.random.split(k)
        y = jax.random.randint(k1, (n,), 0, n_classes)
        x = means[y] + noise * jax.random.normal(k2, (n, in_dim))
        return {"x": x, "y": y}

    return sample


def gaussian_mixture_batch(key, n: int, in_dim: int = 64,
                           n_classes: int = 10) -> Dict[str, jax.Array]:
    return make_classification_task(in_dim, n_classes)(key, n)
