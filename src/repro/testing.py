"""Shared harness for the 8-host-device serial-vs-pipelined reduction
A/B.

benchmarks/bench_bucketing.py (the wall-clock/record rows) and
tests/test_pipeline.py (the HLO overlap-structure assertions) must
measure the SAME program — this module is the single builder both call,
so the benchmarked reduction and the structurally-verified reduction
cannot drift apart.  The autotune probe (autotune/probe.py) reuses the
same builder with non-default ``topo_shape``/``level``/size arguments,
so calibration samples measure the same reduction program too.

Callers are responsible for forcing >= 8 host devices
(``--xla_force_host_platform_device_count=8``) before jax initializes.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.comm import Bucketed, Pipelined, get_reducer, reduce_with
from repro.core import HierTopology
from repro.core.topology import (global_average, local_average, pod_average,
                                 stack_like)

LEVEL_AVG_FNS = {
    "local": local_average,
    "pod": pod_average,
    "global": global_average,
}

# the A/B shape: 24 leaves x 96*64 fp32 = 24 KiB each, stacked over the
# 8-learner (1, 2, 4) mesh.  32 KiB cap -> 24 buckets (one leaf each);
# 4 MiB cap -> 1 bucket (the schedules provably coincide).
AB_LEAVES = 24
AB_LEAF_SHAPE: Tuple[int, int] = (96, 64)
AB_SMALL_CAP = 32 << 10
AB_LARGE_CAP = 4 << 20


def build_ab_reduction(sched: str, cap: int, *, n_leaves: int = AB_LEAVES,
                       leaf_shape: Tuple[int, ...] = AB_LEAF_SHAPE,
                       spec: str = "topk:0.05",
                       topo_shape: Tuple[int, int, int] = (1, 2, 4),
                       level: str = "global") -> Dict:
    """One A/B variant: the jitted ``level`` reduction (local / pod /
    global grouped mean) of a synthetic ``n_leaves``-leaf tree over the
    ``topo_shape`` learner mesh, on the serial (``Bucketed``) or
    pipelined (``Pipelined``) schedule at bucket cap ``cap``, or with
    ``sched="perleaf"`` the raw un-bucketed reducer (``cap`` unused) —
    the one-collective-per-leaf baseline of the codec A/B.  Returns
    the pieces the benchmark, the HLO test, and the autotune probe all
    need: reducer, single-learner tree, stacked params, carried state,
    shardings, the jitted fn, and the bucket count."""
    topo = HierTopology(*topo_shape)
    mesh = Mesh(np.array(jax.devices()[:topo.n_learners])
                .reshape(topo.shape), ("pod", "group", "local"))
    key = jax.random.PRNGKey(0)
    tree1 = {f"w{i:02d}": jax.random.normal(jax.random.fold_in(key, i),
                                            leaf_shape)
             for i in range(n_leaves)}
    params = stack_like(topo, tree1)

    def shard(leaf):
        pspec = P("pod", "group", "local") if leaf.ndim >= 3 else P()
        return NamedSharding(mesh, pspec)

    if sched == "perleaf":
        # the un-bucketed baseline: one collective per leaf (two for
        # two-message codecs), what the codec A/B rows beat
        red = get_reducer(spec)
    else:
        engine = Pipelined if sched == "pipelined" else Bucketed
        red = engine(get_reducer(spec), cap)
    state = red.init_state(jax.tree.map(jnp.zeros_like, params))
    shardings = (jax.tree.map(shard, params), jax.tree.map(shard, state))
    avg_fn = LEVEL_AVG_FNS[level]

    def reduction(p, s):
        return reduce_with(red, avg_fn, p, s)

    return {
        "reducer": red,
        "tree1": tree1,
        "params": params,
        "state": state,
        "shardings": shardings,
        "fn": jax.jit(reduction, in_shardings=shardings),
        "n_buckets": (red.layout_for(params).n_buckets
                      if hasattr(red, "layout_for") else n_leaves),
    }


def build_sharded_ab_reduction(sched: str, cap: int, *,
                               n_leaves: int = AB_LEAVES,
                               leaf_shape: Tuple[int, ...] = AB_LEAF_SHAPE,
                               spec: str = "topk:0.05",
                               topo_shape: Tuple[int, int, int] = (1, 2, 2),
                               fsdp: int = 2,
                               level: str = "global") -> Dict:
    """The fsdp>1 counterpart of :func:`build_ab_reduction`: the same
    ``level`` reduction on a 5-axis hier mesh (learners x fsdp x model=1)
    with a :class:`~repro.parallel.sharding.ShardPlan`, so the bucket
    engine packs per-shard runs and the grouped mean lowers to
    reduce-scatter + all-gather.  Default shape uses all 8 forced host
    devices as 4 learners x 2 shards.  Rank-2 leaves shard trailing dim 0
    over fsdp (DEFAULT_RULES fallback).  Returns the same dict keys as
    the replicated builder plus ``mesh`` and ``shards``."""
    from repro.parallel.sharding import shard_plan
    topo = HierTopology(*topo_shape)
    n_dev = topo.n_learners * fsdp
    mesh = Mesh(np.array(jax.devices()[:n_dev])
                .reshape(topo.shape + (fsdp, 1)),
                ("pod", "group", "local", "fsdp", "model"))
    sp = shard_plan(mesh)
    assert sp is not None, (topo_shape, fsdp)
    key = jax.random.PRNGKey(0)
    tree1 = {f"w{i:02d}": jax.random.normal(jax.random.fold_in(key, i),
                                            leaf_shape)
             for i in range(n_leaves)}
    params = stack_like(topo, tree1)
    s_sz = topo.local

    def shard(leaf):
        if leaf.ndim >= 4 and leaf.shape[:3] == topo.shape:
            # stacked param leaf: learner axes + fsdp on trailing dim 0
            return NamedSharding(mesh, P("pod", "group", "local", "fsdp",
                                         *(None,) * (leaf.ndim - 4)))
        if leaf.ndim >= 3 and leaf.shape[2] == s_sz * fsdp:
            # codec-view EF state (shard space): shards merged into the
            # local-learner axis, major-minor mesh order
            return NamedSharding(mesh, P("pod", "group",
                                         ("local", "fsdp"),
                                         *(None,) * (leaf.ndim - 3)))
        return NamedSharding(mesh, P())

    engine = Pipelined if sched == "pipelined" else Bucketed
    red = engine(get_reducer(spec), cap, shards=sp)
    state = red.init_state(jax.tree.map(jnp.zeros_like, params))
    shardings = (jax.tree.map(shard, params), jax.tree.map(shard, state))
    avg_fn = LEVEL_AVG_FNS[level]

    def reduction(p, s):
        return reduce_with(red, avg_fn, p, s)

    return {
        "reducer": red,
        "tree1": tree1,
        "params": params,
        "state": state,
        "shardings": shardings,
        "fn": jax.jit(reduction, in_shardings=shardings),
        "n_buckets": red.layout_for(params).n_buckets,
        "mesh": mesh,
        "shards": sp,
    }


def count_allreduce_ops(hlo_text: str) -> int:
    """All-reduce ops in a compiled module (sync or async spelling) —
    the program-size metric the A/B and the overlap test both gate on."""
    return hlo_text.count("all-reduce(") + hlo_text.count("all-reduce-start(")


def count_collective_ops(hlo_text: str) -> Dict[str, int]:
    """Per-kind collective op counts (sync + async spellings) — what the
    sharded RS/AG tests and benchmark rows gate on: a sharded bucket
    reduction must show reduce-scatter + all-gather, zero all-reduce for
    its buckets, and no stray all-to-all / collective-permute from a
    non-shard-local reshape."""
    c = hlo_text.count
    return {
        "all_reduce": c("all-reduce(") + c("all-reduce-start("),
        "reduce_scatter": c("reduce-scatter(") + c("reduce-scatter-start("),
        "all_gather": c("all-gather(") + c("all-gather-start("),
        "all_to_all": c("all-to-all(") + c("all-to-all-start("),
        "collective_permute": (c("collective-permute(")
                               + c("collective-permute-start(")),
    }
