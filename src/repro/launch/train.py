"""Distributed Hier-AVG training driver.

On real hardware this runs the exact programs the dry-run lowers; on this
CPU container it runs REDUCED configs end-to-end (``--reduced``, default)
so the full path — config, topology, loader, rounds, checkpointing,
LR decay — is exercised for real.

  PYTHONPATH=src python -m repro.launch.train --arch hymba-1.5b --reduced \
      --rounds 5 --k1 2 --k2 4 --learners 4 --s 2
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from contextlib import nullcontext

import jax
import jax.numpy as jnp

from repro.checkpoint import save_checkpoint
from repro.comm import DEFAULT_BUCKET_BYTES
from repro.configs import HierAvgParams, get_config
from repro.core import (HierTopology, init_state, make_hier_round,
                        unstack_first)
from repro.data.loader import HierDataLoader
from repro.data.synthetic import make_markov_task, markov_lm_batch
from repro.models import build
from repro.models.stubs import make_train_batch
from repro.optim import sgd, step_decay_lr


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--k1", type=int, default=2)
    ap.add_argument("--k2", type=int, default=4)
    ap.add_argument("--learners", type=int, default=4)
    ap.add_argument("--s", type=int, default=2)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--reducer", default="mean",
                    help="reduction payload spec (comm/): mean | "
                         "cast[:dtype] | topk[:ratio] | randk[:ratio] | "
                         "qint8[:block] | powersgd[:rank]")
    ap.add_argument("--plan", default=None,
                    help="N-level reduction plan spec, e.g. "
                         "'local@4:cast:bfloat16/pod@8/global@16:topk:0.05'"
                         " — wins over --k1/--k2/--reducer")
    ap.add_argument("--bucket-bytes", type=int,
                    default=DEFAULT_BUCKET_BYTES,
                    help="flat-buffer bucket cap for compressed reducers "
                         "(comm/bucket.py); 0 = per-leaf reductions")
    ap.add_argument("--no-overlap", action="store_true",
                    help="pin the serial bucket schedule (default: the "
                         "pipelined engine overlaps each bucket's grouped "
                         "collective with the next bucket's compress)")
    ap.add_argument("--fsdp", type=int, default=1,
                    help="shard the per-learner trailing dims F ways "
                         "(parallel/sharding.py ShardPlan): bucketed "
                         "reductions pack shard-local runs and lower "
                         "each level's mean to reduce-scatter + "
                         "all-gather.  Needs learners*fsdp devices "
                         "(XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N on CPU)")
    ap.add_argument("--autotune", default=None, metavar="CALIB_JSON",
                    help="calibration artifact (autotune/calibrate.py); "
                         "runs the cost-aware plan search over the real "
                         "param tree and trains the recommended plan — "
                         "wins over --plan/--k1/--k2")
    ap.add_argument("--faults", default=None, metavar="SPEC",
                    help="elastic membership: a deterministic fault "
                         "schedule (repro/elastic) driving per-round "
                         "participation masks, e.g. "
                         "'crash:0.02/flaky:pod:0.2:3/straggler:0.1:1.5' "
                         "— seeded by --seed, straggler deadlines priced "
                         "from the CommModel level walls")
    ap.add_argument("--drop-prob", type=float, default=0.0,
                    help="expected per-member miss probability the "
                         "--autotune plan search bills rounds under "
                         "(theory.py n_eff billing; 0 = dense)")
    ap.add_argument("--telemetry", action="store_true",
                    help="device-side gradient/divergence statistics "
                         "inside the jitted round (repro/telemetry "
                         "gradstats.py; losses bit-identical, extra "
                         "telemetry/* metric keys)")
    ap.add_argument("--metrics-out", default=None, metavar="JSONL",
                    help="write one schema-versioned train_round row "
                         "per round (telemetry/metrics.py JSONL sink)")
    ap.add_argument("--trace-out", default=None, metavar="TRACE_JSON",
                    help="export host-side round spans as a Chrome "
                         "trace (open in ui.perfetto.dev)")
    ap.add_argument("--profile-dir", default=None,
                    help="bracket rounds with jax.profiler trace "
                         "annotations into this directory (TensorBoard "
                         "/ Perfetto device timeline)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    assert args.learners % args.s == 0
    topo = HierTopology(pods=1, groups=args.learners // args.s,
                        local=args.s)
    hier = HierAvgParams(k1=args.k1, k2=args.k2, reducer=args.reducer,
                         plan=args.plan, bucket_bytes=args.bucket_bytes,
                         overlap=not args.no_overlap)
    bundle = build(cfg)
    shards = None
    if args.fsdp > 1:
        import numpy as np
        from jax.sharding import Mesh

        from repro.parallel.sharding import shard_plan
        need = topo.n_learners * args.fsdp
        devs = jax.devices()
        assert len(devs) >= need, (
            f"--fsdp {args.fsdp} needs {need} devices, have {len(devs)} "
            f"(set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{need} on CPU)")
        mesh = Mesh(
            np.array(devs[:need]).reshape(
                1, topo.groups, topo.local, args.fsdp, 1),
            ("pod", "group", "local", "fsdp", "model"))
        shards = shard_plan(mesh)
    controller = None
    if args.autotune:
        from repro.autotune import (Calibration, CostAwarePlan,
                                    search_plans)
        cal = Calibration.load(args.autotune)
        template = jax.eval_shape(
            bundle.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
        ranked = search_plans(topo, cal, template=template,
                              B=args.batch,
                              T_ref=args.rounds * hier.steps_per_round,
                              bucket_bytes=hier.bucket_bytes,
                              overlap=hier.overlap, top=3,
                              drop_prob=args.drop_prob)
        print(f"autotune [{args.autotune}; fitted {list(cal.fitted)}"
              + (f"; drop_prob={args.drop_prob:g}" if args.drop_prob
                 else "") + "]:")
        for i, sp in enumerate(ranked):
            print(f"  #{i} {sp.spec}  comm_ms/step="
                  f"{sp.comm_s_per_step * 1e3:.3f} score={sp.score:.3e} "
                  f"feasible={sp.feasible}")
        hier = dataclasses.replace(hier, plan=ranked[0].spec)
        # first telemetry consumer: the controller ingests measured
        # per-round walls / active fracs (observe) so measured-vs-
        # modeled wall is reported at the end of the run
        controller = CostAwarePlan(plan=ranked[0].spec, topo=topo,
                                   comm=cal, template=template,
                                   bucket_bytes=hier.bucket_bytes,
                                   overlap=hier.overlap, shards=shards,
                                   drop_prob=args.drop_prob)
    plan = hier.resolved_plan
    optimizer = sgd(step_decay_lr(
        args.lr, [args.rounds * hier.steps_per_round * 3 // 4], [0.1]))

    key = jax.random.PRNGKey(args.seed)

    def sample(k, n):
        return make_train_batch(k, cfg, batch=n, seq_len=args.seq)

    loader = HierDataLoader(sample, topo=topo, hier=hier,
                            per_learner_batch=args.batch, seed=args.seed)
    faults = None
    if args.faults:
        from repro.core.theory import level_reduction_seconds
        from repro.elastic import FaultSchedule, level_deadlines
        template = jax.eval_shape(
            bundle.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
        deadlines = level_deadlines(plan, topo, template, None)
        faults = FaultSchedule(args.faults, topo,
                               [lvl.name for lvl in plan.levels],
                               seed=args.seed, deadlines=deadlines)
        counts = dict(plan.counts_per_round())

        def round_wall(fracs):
            return sum(
                counts[lvl.name] * level_reduction_seconds(
                    lvl, topo, template, None,
                    drop_prob=1.0 - float(f))[2]
                for lvl, f in zip(plan.levels, fracs))

    # donate the carried TrainState (params/opt_state/EF update in place —
    # no doubled peak memory); the loop only ever uses the returned state
    round_fn = jax.jit(make_hier_round(bundle.loss_fn, optimizer, hier,
                                       shards=shards,
                                       elastic=faults is not None,
                                       telemetry=args.telemetry or None),
                       donate_argnums=(0,))
    state = init_state(topo, bundle.init, optimizer, key, plan=plan,
                       shards=shards)

    from repro.telemetry import MetricsLogger, SpanTracer
    logger = MetricsLogger(args.metrics_out) if args.metrics_out else None
    tracer = (SpanTracer(profile_dir=args.profile_dir)
              if (args.trace_out or args.profile_dir) else None)
    modeled_phases = None
    if tracer is not None:
        # one fused jit program cannot be host-decomposed: the per-level
        # compress/collective split rides as MODELED child spans priced
        # by the same bill every analytic surface reports
        from repro.core.theory import level_reduction_seconds
        tmpl = jax.eval_shape(
            bundle.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
        counts = dict(plan.counts_per_round())
        modeled_phases = []
        for lvl in plan.levels:
            comm_s, compute_s, _ = level_reduction_seconds(
                lvl, topo, tmpl, None)
            if counts[lvl.name]:
                modeled_phases += [
                    (f"{lvl.name}/compress", compute_s * counts[lvl.name]),
                    (f"{lvl.name}/collective", comm_s * counts[lvl.name])]
        tracer.start_profiler()

    print(f"Hier-AVG: {topo.describe()}  plan={plan.describe()} "
          f"arch={cfg.name}"
          + (f"  faults={faults.describe()}" if faults else ""))
    for r in range(args.rounds):
        t0 = time.time()
        drec = None
        with (tracer.span(f"round[{r}]", args={"round": r})
              if tracer else nullcontext()):
            with tracer.span("data") if tracer else nullcontext():
                batch = loader.next_round()
            with (tracer.span("device", cat="device")
                  if tracer else nullcontext()) as drec:
                if faults is not None:
                    state, metrics = round_fn(
                        state, batch, jnp.asarray(faults.active(r)))
                else:
                    state, metrics = round_fn(state, batch)
                if tracer:
                    # bill the device wait to this span, not host_sync
                    tracer.fence(metrics)
            with (tracer.span("host_sync")
                  if tracer else nullcontext()):
                # ONE device->host transfer for the whole metrics dict
                # (the old per-key float() calls each blocked)
                m = jax.device_get(metrics)
        wall = time.time() - t0
        if tracer and modeled_phases:
            tracer.add_modeled_children(drec, modeled_phases)
        if faults is not None:
            # host-side schedule mask: no extra device sync for fracs
            fracs = [float(f) for f in faults.active_frac(r)]
            extra = ("  active=" + "/".join(
                f"{lvl.name}:{f:.2f}" for lvl, f in zip(plan.levels, fracs))
                + f" wall~{round_wall(fracs) * 1e3:.2f}ms")
        else:
            fracs, extra = None, ""
        print(f"round {r:3d}  loss={float(m['loss']):.4f} "
              f"acc={float(m.get('accuracy', float('nan'))):.3f} "
              f"({wall:.1f}s, "
              f"{loader.tokens_per_round * args.seq} tokens)"
              + extra, flush=True)
        if logger is not None or controller is not None:
            row = {"round": r, "loss": float(m["loss"]),
                   "accuracy": float(m.get("accuracy", float("nan"))),
                   "wall_s": wall, "plan": plan.describe()}
            row.update({k: float(v) for k, v in m.items()
                        if k.startswith("telemetry/")})
            if fracs is not None:
                row["active_frac"] = {
                    lvl.name: f for lvl, f in zip(plan.levels, fracs)}
                row["modeled_wall_s"] = round_wall(fracs)
            if logger is not None:
                logger.log_row("train_round", **row)
            if controller is not None:
                controller.observe(row)

    if tracer is not None:
        tracer.stop_profiler()
        if args.trace_out:
            tracer.export_chrome_trace(args.trace_out)
            print(f"wrote Chrome trace to {args.trace_out} "
                  f"(open in ui.perfetto.dev)")
    if logger is not None:
        logger.close()
        print(f"wrote {args.rounds} train_round rows to "
              f"{args.metrics_out}")
    if controller is not None and controller.observed_wall_s is not None:
        print(f"controller: measured {controller.observed_wall_s * 1e3:.2f}"
              f"ms/round vs modeled comm "
              f"{controller.modeled_round_wall_s * 1e3:.3f}ms "
              f"(x{controller.wall_bias():.0f} incl. compute/host; live "
              f"re-planning is the ROADMAP online-control follow-up)")

    if args.ckpt:
        save_checkpoint(args.ckpt, unstack_first(state.params),
                        step=int(state.step))
        print(f"saved averaged model to {args.ckpt}")


if __name__ == "__main__":
    main()
