"""Production meshes.

``make_production_mesh`` is the target spec verbatim: a 256-chip v5e pod as
(16, 16) ("data", "model"), or 2 pods = 512 chips as (2, 16, 16)
("pod", "data", "model").  Serving dry-runs use it directly.

``make_hier_mesh`` is the SAME device set with the 16-way data axis factored
``groups x local x fsdp = 16`` so the Hier-AVG communicators are named mesh
axes: local reduction = all-reduce over "local" (intra-pod ICI), global
reduction = all-reduce over ("pod","group","local") (crosses DCI when
multi_pod).  Chip count and ICI layout are identical to the production mesh;
only the logical factorization of the data dimension differs.

Both are FUNCTIONS so importing this module never touches jax device state.
"""
from __future__ import annotations

import jax

from repro.configs.base import ParallelLayout

DATA_AXIS = 16
TP_AXIS = 16
PODS_MULTI = 2


def make_production_mesh(*, multi_pod: bool = False):
    shape = (PODS_MULTI, DATA_AXIS, TP_AXIS) if multi_pod \
        else (DATA_AXIS, TP_AXIS)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_hier_mesh(layout: ParallelLayout, *, multi_pod: bool = False):
    layout.validate(DATA_AXIS * TP_AXIS)
    pods = PODS_MULTI if multi_pod else 1
    shape = (pods, layout.groups, layout.local, layout.fsdp, layout.tp)
    axes = ("pod", "group", "local", "fsdp", "model")
    return jax.make_mesh(shape, axes)


def device_count_required(*, multi_pod: bool = False) -> int:
    return (PODS_MULTI if multi_pod else 1) * DATA_AXIS * TP_AXIS


# learner array axis index (core/topology.py) -> hier mesh axis name
LEARNER_MESH_AXES = ("pod", "group", "local")


def level_replica_groups(mesh, level: str):
    """Device-id groups of the grouped collective one plan level runs on
    a hier mesh: the reduction spans the level's learner mesh axes and
    *keeps* the fsdp/model axes — so each fsdp shard (and each TP slice)
    averages only with its peers, which is exactly the grouping the
    reduce-scatter/all-gather decomposition (core/topology.py
    ``_scatter_mean``) reduces over.  Built from the row-major device
    order of ``mesh.devices`` (parallel/sharding.py
    :func:`~repro.parallel.sharding.replica_groups`)."""
    from repro.core.plan import LEVEL_AXES
    from repro.parallel.sharding import replica_groups
    axes = tuple(LEARNER_MESH_AXES[a] for a in LEVEL_AXES[level])
    return replica_groups(mesh, axes)
