import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input shape) on the
production meshes, record memory/cost/collective analysis for §Roofline.

Run:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] \
      --out experiments/dryrun

Exit status is non-zero if any case fails to lower/compile — a failure here
is a sharding bug in the framework, per the assignment.
"""  # noqa: E402

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import ALL_ARCHS, INPUT_SHAPES, get_config  # noqa: E402
from repro.launch.cases import build_case, parse_layout        # noqa: E402
from repro.launch import hlo_analysis as ha                    # noqa: E402
from repro.launch.analytic import analytic_roofline            # noqa: E402


def applicable_shapes(cfg):
    """All 10 pool archs support all 4 shapes (long_500k via rolling-window
    SWA for full-attention archs, MLA latents for deepseek-v2, native state
    for ssm/hybrid) — see DESIGN.md long_500k policy."""
    return list(INPUT_SHAPES)


def run_case(arch: str, shape: str, multi_pod: bool, *, case_kwargs=None,
             layout=None, calibration=None) -> dict:
    case_kwargs = case_kwargs or {}
    cfg = get_config(arch)
    if layout is not None:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, layout=layout)
    case = build_case(cfg, shape, multi_pod=multi_pod, **case_kwargs)
    t0 = time.time()
    with case.mesh:
        lowered = case.jitted.lower(*case.arg_specs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        mem = compiled.memory_analysis()
        cost_list = compiled.cost_analysis()
        cost = cost_list if isinstance(cost_list, dict) else cost_list[0]
        hlo = compiled.as_text()
    colls = ha.parse_collectives(hlo)
    chips = case.mesh.devices.size
    # MODEL_FLOPS = 6 N_active D per step (train fwd+bwd); serving fwd = 2ND
    tokens = _tokens_per_step(cfg, shape)
    n_active = cfg.active_param_count()
    mult = 6.0 if INPUT_SHAPES[shape].kind == "train" else 2.0
    model_flops_total = mult * n_active * tokens * case.steps
    terms = ha.roofline_terms(
        cost, colls, model_flops_per_device=model_flops_total / chips,
        steps=case.steps)
    rec = {
        "arch": arch, "shape": shape,
        "mesh": "2pod-512" if multi_pod else "1pod-256",
        "chips": chips,
        "notes": case.notes,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_est_bytes": mem.argument_size_in_bytes
            + mem.temp_size_in_bytes + mem.output_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "collectives": {k: v for k, v in
                        ha.collective_summary(colls).items()},
        # HLO-derived terms are PER-SCAN-BODY (XLA cost analysis is
        # trip-count blind); the analytic model below gives per-step
        # magnitudes — see launch/analytic.py and EXPERIMENTS.md §Roofline.
        "roofline_hlo_per_body": terms,
        # --autotune: the artifact's roofline is costed with the SAME
        # calibration the recommended plan was chosen by
        "roofline": analytic_roofline(
            cfg, shape, multi_pod=multi_pod,
            hier=case_kwargs.get("hier"),
            comm_model=calibration).as_dict(),
    }
    return rec


def _tokens_per_step(cfg, shape) -> float:
    s = INPUT_SHAPES[shape]
    if s.kind == "decode":
        return s.global_batch          # one new token per sequence
    return s.global_batch * s.seq_len


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--layout", default=None,
                    help="override layout 'GxSxFxTP[:micro]' (hillclimb)")
    ap.add_argument("--k1", type=int, default=None)
    ap.add_argument("--k2", type=int, default=None)
    ap.add_argument("--plan", default=None,
                    help="N-level reduction plan spec (wins over "
                         "--k1/--k2), e.g. "
                         "'local@4:cast:bfloat16/pod@8/global@16:topk:0.05'")
    ap.add_argument("--no-overlap", action="store_true",
                    help="pin the serial bucket schedule when lowering "
                         "(default: pipelined/overlapped engine)")
    ap.add_argument("--autotune", default=None, metavar="CALIB_JSON",
                    help="calibration artifact (autotune/calibrate.py): "
                         "lower the plan the cost-aware search recommends "
                         "for each arch instead of --plan/--k1/--k2")
    args = ap.parse_args()

    cases = []
    archs = ALL_ARCHS if (args.all or not args.arch) else [args.arch]
    for a in archs:
        shapes = applicable_shapes(get_config(a)) \
            if (args.all or not args.shape) else [args.shape]
        for s in shapes:
            meshes = [False, True] if args.both_meshes else [args.multi_pod]
            for mp in meshes:
                cases.append((a, s, mp))

    os.makedirs(args.out, exist_ok=True)
    # --autotune: one artifact load, one plan search per (arch, layout,
    # mesh) — the recommendation does not depend on the input shape
    autotune_cal = None
    autotune_memo = {}
    if args.autotune:
        from repro.autotune import Calibration
        autotune_cal = Calibration.load(args.autotune)
    failures = 0
    for a, s, mp in cases:
        tag = f"{a}__{s}__{'2pod' if mp else '1pod'}"
        lay = parse_layout(args.layout) if args.layout else None
        if lay is not None:
            tag += f"__L{args.layout.replace(':', 'm')}"
        kw = {}
        if args.autotune:
            from repro.autotune import recommend_plan
            from repro.configs.base import HierAvgParams
            from repro.core.theory import param_template
            from repro.core.topology import HierTopology
            cfg = get_config(a)
            layc = lay or cfg.layout
            key = (a, args.layout, mp)
            best = autotune_memo.get(key)
            if best is None:
                best = recommend_plan(
                    HierTopology(pods=2 if mp else 1, groups=layc.groups,
                                 local=layc.local),
                    autotune_cal,
                    template=param_template(
                        cfg.param_count(),
                        n_leaves=max(1, 8 * cfg.n_layers)),
                    overlap=not args.no_overlap)
                autotune_memo[key] = best
                print(f"autotune {a}: {best.spec} "
                      f"(comm_ms/step={best.comm_s_per_step * 1e3:.3f}, "
                      f"feasible={best.feasible})", flush=True)
            kw["hier"] = HierAvgParams(plan=best.spec,
                                       overlap=not args.no_overlap)
            tag += "__AUTO"
        elif args.plan:
            from repro.configs.base import HierAvgParams
            hp = HierAvgParams(plan=args.plan,
                               overlap=not args.no_overlap)
            kw["hier"] = hp
            tag += "__P" + args.plan.replace("/", "-").replace(":", "_")
        elif args.k1 or args.k2 or args.no_overlap:
            from repro.configs.base import HierAvgParams
            hp = HierAvgParams(k1=args.k1 or 4, k2=args.k2 or 8,
                               overlap=not args.no_overlap)
            kw["hier"] = hp
            tag += f"__K{hp.k1}-{hp.k2}"
        try:
            rec = run_case(a, s, mp, layout=lay, case_kwargs=kw,
                           calibration=autotune_cal)
            path = os.path.join(args.out, tag + ".json")
            with open(path, "w") as f:
                json.dump(rec, f, indent=2)
            r = rec["roofline"]
            print(f"OK   {tag:58s} compile={rec['compile_s']:6.1f}s "
                  f"bottleneck={r['bottleneck']:10s} "
                  f"c/m/coll(ms)={1e3*r['compute_s']:.2f}/"
                  f"{1e3*r['memory_s']:.2f}/{1e3*r['collective_s']:.2f} "
                  f"peakGiB={rec['memory']['peak_est_bytes']/2**30:.2f}",
                  flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"FAIL {tag}: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} dry-run case(s) failed")


if __name__ == "__main__":
    main()
