"""Analytic roofline model for the dry-run cases.

WHY THIS EXISTS: XLA's ``cost_analysis()`` counts each ``while``/``scan``
body ONCE (trip-count blind).  Our programs are scan-over-layers inside
scan-over-SGD-steps inside scan-over-microbatches, so the raw HLO numbers
are per-body, off by the trip product (recorded in the dry-run JSONs as
``useful_flops_ratio`` ≫ 1).  Production frameworks (MaxText-style MFU
accounting) size the roofline analytically; the compiled dry-run still
supplies the ground truth for (a) the collective schedule — which ops, what
payloads, which replica groups — and (b) lowering/memory feasibility.

All terms are per-device, per-SGD-step (train) or per-decode-step/prefill,
in seconds, using the assignment's v5e constants.  A calibration artifact
(autotune/calibrate.py; ``comm_model=`` arg or ``$REPRO_CALIBRATION``)
replaces the link/codec constants with MEASURED ones for the reduction
terms — the built-in numbers apply only when nothing is calibrated.

Collective term components are itemized so §Perf can attack them:
  tp_act     — Megatron-style activation all-reduces over the TP axis
  fsdp       — ZeRO-3 param all-gather + grad reduce-scatter over fsdp
  local_avg  — the paper's local reduction (per K1 steps, over S)
  global_avg — the paper's global reduction (per K2 steps, over P;
               crosses DCI in the multi-pod mesh)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.configs.base import (ArchConfig, HierAvgParams, InputShape,
                                INPUT_SHAPES)

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
DCI_BW = 6.25e9       # effective per-chip cross-pod bandwidth (~ICI/8)

BF16 = 2


def _ring(n: int) -> float:
    return 2.0 * (n - 1) / n if n > 1 else 0.0


def _attn_flops_per_token_layer(cfg: ArchConfig, ctx: float) -> float:
    """fwd QK^T + PV flops per token per layer (2 flops/MAC)."""
    if cfg.family == "ssm":
        hd = cfg.resolved_head_dim
        return 4.0 * cfg.ssm_heads * hd * hd          # wkv state update+read
    hq = cfg.n_heads
    hd = cfg.v_head_dim if cfg.kv_lora_rank else cfg.resolved_head_dim
    f = 4.0 * hq * hd * ctx
    if cfg.family == "hybrid":
        di = cfg.d_model * cfg.ssm_expand
        f += 4.0 * di * cfg.ssm_state                 # selective scan
    return f


def _ctx(cfg: ArchConfig, shape: InputShape, rolling: bool) -> float:
    if shape.kind == "train":
        s = shape.seq_len
        w = cfg.sliding_window
        return (w if (w and w < s) else s / 2.0)      # causal avg
    # decode/prefill context length actually attended
    if shape.kind == "decode":
        t = shape.seq_len
        if rolling:
            t = min(t, cfg.long_context_window)
        if cfg.sliding_window:
            t = min(t, cfg.sliding_window)
        if cfg.family == "ssm":
            t = 1
        return float(t)
    s = shape.seq_len
    w = cfg.sliding_window
    return (w if (w and w < s) else s / 2.0)


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    collective_parts: Dict[str, float]
    bottleneck: str
    model_flops_per_device: float
    details: Dict[str, float]

    def as_dict(self):
        d = dataclasses.asdict(self)
        return d


def analytic_roofline(cfg: ArchConfig, shape_name: str, *,
                      multi_pod: bool = False,
                      hier: Optional[HierAvgParams] = None,
                      sliding_rolling: Optional[bool] = None,
                      comm_model=None) -> Roofline:
    shape = INPUT_SHAPES[shape_name]
    hier = hier or HierAvgParams(k1=4, k2=8)
    # measured link/codec constants for the reduction terms.  An
    # explicit CommModel wins wholesale; a Calibration — passed in
    # (dryrun --autotune forwards the one the plan was chosen by) or
    # configured via $REPRO_CALIBRATION — only displaces the constants
    # it actually FITTED: its unfitted fields are CommModel base
    # defaults, which differ from this module's v5e numbers (DCI_BW)
    # and carry no measurement
    from repro.autotune.calibrate import Calibration, resolve_calibration
    ici_bw, dci_bw, codec_bw = LINK_BW, DCI_BW, None
    cal = None
    if comm_model is None:
        cal = resolve_calibration()
    elif isinstance(comm_model, Calibration):
        cal = comm_model
    else:
        ici_bw, dci_bw = comm_model.fast_bw, comm_model.slow_bw
        codec_bw = comm_model.compress_bw
    if cal is not None:
        if "fast_bw" in cal.fitted:
            ici_bw = cal.model.fast_bw
        if "slow_bw" in cal.fitted:
            dci_bw = cal.model.slow_bw
        if "compress_bw" in cal.fitted:
            codec_bw = cal.model.compress_bw
    lay = cfg.layout
    pods = 2 if multi_pod else 1
    chips = pods * 256
    tp = lay.tp
    fsdp = lay.fsdp
    learners = pods * lay.learners_per_pod
    P = learners
    S = lay.local if lay.local > 1 else (pods if pods > 1 else 1)

    n_active = cfg.active_param_count()
    n_total = cfg.param_count()
    # per-learner param shard bytes (bf16), sharded over fsdp x tp
    p_shard = n_total * BF16 / (fsdp * tp)
    rolling = (shape.name == "long_500k" and cfg.family in
               ("dense", "moe", "vlm", "audio") and not cfg.kv_lora_rank) \
        if sliding_rolling is None else sliding_rolling
    ctx = _ctx(cfg, shape, rolling)
    L = cfg.n_layers

    parts: Dict[str, float] = {}
    det: Dict[str, float] = {}

    if shape.kind == "train":
        tokens_global = shape.global_batch * shape.seq_len
        tokens_dev = tokens_global / chips
        mult = 6.0  # fwd + bwd
        flops = mult * n_active * tokens_dev \
            + 3.0 * _attn_flops_per_token_layer(cfg, ctx) * L * tokens_dev
        micro = lay.microbatch
        # HBM: weights touched 3x (fwd read, bwd read, grad write) PER
        # microbatch pass + activation traffic ~ c * tokens * d * L
        bytes_w = 3.0 * p_shard * micro
        bytes_a = 12.0 * tokens_dev * cfg.d_model * BF16 * L
        bytes_ = bytes_w + bytes_a
        # collectives (per step, per device):
        tok_learner = tokens_global / learners / micro
        tok_tp_local = tok_learner / fsdp               # per-device tokens
        parts["tp_act"] = (4.0 * tok_tp_local * cfg.d_model * BF16 * L
                           * micro * _ring(tp)) / LINK_BW
        if cfg.uses_moe:
            # all-to-all dispatch/combine over the expert (tp) axis
            parts["moe_a2a"] = (4.0 * tok_tp_local * cfg.d_model * BF16
                                * (L - cfg.first_k_dense) * micro
                                * (tp - 1) / tp) / LINK_BW
        if fsdp > 1:
            parts["fsdp"] = (2.0 * p_shard * micro * (fsdp - 1)) / LINK_BW
        if hier.plan is None:
            if S > 1:
                bw = ici_bw if lay.local > 1 else dci_bw
                parts["local_avg"] = (p_shard * _ring(S)) / bw / hier.k1
            if P > 1:
                bw = dci_bw if multi_pod else ici_bw
                parts["global_avg"] = (p_shard * _ring(P)) / bw / hier.k2
        else:
            # N-level plan: each level over its own link tier and its own
            # compressed payload (reducer payload factor vs dense bf16).
            # Pipelined levels (comm/bucket.py) overlap each bucket's
            # collective with the next bucket's compress, so they expose
            # max(compute, comm) per stage + the fill/drain ramp instead
            # of the serial sum (same model as theory.plan_comm_per_round;
            # the realistic-leaf template makes the bucket count honest).
            from repro.core.theory import (CommModel, param_template,
                                           scheduled_wall)
            plan = hier.resolved_plan
            template = param_template(
                n_total, n_leaves=max(1, 8 * cfg.n_layers))
            dense_bytes = sum(2 * leaf.size for leaf in template.values())
            compress_bw = codec_bw if codec_bw is not None \
                else CommModel().compress_bw
            sizes = {0: pods, 1: lay.groups, 2: lay.local}
            for lvl in plan.levels:
                n = 1
                for ax in lvl.axes:
                    n *= sizes[ax]
                if n <= 1:
                    continue
                crosses = 0 in lvl.axes and pods > 1
                bw = dci_bw if crosses else ici_bw
                factor = lvl.reducer.payload_bytes(template) / dense_bytes
                comm = p_shard * factor * _ring(n) / bw
                m = lvl.reducer.n_messages(template)
                s_cmp = (p_shard / compress_bw / m
                         if getattr(lvl.reducer, "has_codec", True)
                         else 0.0)
                overlaps = getattr(lvl.reducer, "overlaps", False)
                wall = scheduled_wall(s_cmp, comm / m, m, overlaps)
                if overlaps and m > 1:
                    det[f"overlap_x_{lvl.name}"] = \
                        (comm + m * s_cmp) / wall
                parts[f"{lvl.name}_avg"] = wall / lvl.period
        det["tokens_per_device"] = tokens_dev
        model_flops = mult * n_active * tokens_dev
    elif shape.kind == "prefill":
        tokens_dev = shape.global_batch * shape.seq_len / chips
        flops = 2.0 * n_active * tokens_dev \
            + _attn_flops_per_token_layer(cfg, ctx) * L * tokens_dev
        bytes_ = n_total * BF16 / chips + 8.0 * tokens_dev * cfg.d_model \
            * BF16 * L
        parts["tp_act"] = (4.0 * tokens_dev * cfg.d_model * BF16 * L
                           * _ring(tp)) / LINK_BW
        if cfg.uses_moe:
            parts["moe_a2a"] = (4.0 * tokens_dev * cfg.d_model * BF16
                                * (L - cfg.first_k_dense)
                                * (tp - 1) / tp) / LINK_BW
        model_flops = 2.0 * n_active * tokens_dev
    else:  # decode
        B = shape.global_batch
        toks_dev = B / chips * tp   # batch shards over 'data' only
        flops = (2.0 * n_active * B
                 + _attn_flops_per_token_layer(cfg, ctx) * L * B) / chips
        # cache read per step: full context window per sequence
        if cfg.family == "ssm":
            hd = cfg.resolved_head_dim
            cache = B * L * cfg.ssm_heads * hd * hd * 4
        elif cfg.kv_lora_rank:
            cache = B * L * ctx * (cfg.kv_lora_rank
                                   + cfg.qk_rope_head_dim) * BF16
        else:
            cache = B * L * 2 * ctx * cfg.n_kv_heads \
                * cfg.resolved_head_dim * BF16
            if cfg.family == "hybrid":
                di = cfg.d_model * cfg.ssm_expand
                cache += B * L * di * cfg.ssm_state * 4
        bytes_ = n_total * BF16 / chips + cache / chips
        parts["tp_act"] = (4.0 * (B / chips * tp) * cfg.d_model * BF16 * L
                           * _ring(tp)) / LINK_BW / tp
        det["cache_bytes_per_device"] = cache / chips
        model_flops = 2.0 * n_active * B / chips

    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_ / HBM_BW
    collective_s = sum(parts.values())
    dom = max((("compute", compute_s), ("memory", memory_s),
               ("collective", collective_s)), key=lambda kv: kv[1])[0]
    return Roofline(compute_s, memory_s, collective_s, parts, dom,
                    model_flops, det)
