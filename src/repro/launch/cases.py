"""Dry-run case construction: (arch x input shape x mesh) -> a lowerable
jitted program with ShapeDtypeStruct inputs and NamedSharding in_shardings.

No arrays are ever allocated here: parameter/cache structures come from
``jax.eval_shape`` over the real init functions.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.comm import EFState, LowRankState
from repro.configs.base import (ArchConfig, HierAvgParams, InputShape,
                                INPUT_SHAPES, ParallelLayout)
from repro.core.hier_avg import init_state, make_hier_round
from repro.core.topology import HierTopology
from repro.launch.mesh import PODS_MULTI, make_hier_mesh, make_production_mesh
from repro.models import build
from repro.models.stubs import train_batch_specs
from repro.optim import sgd
from repro.parallel.sharding import (PartitionRules, param_pspecs,
                                     safe_pspec, shard_plan)


@dataclasses.dataclass
class DryrunCase:
    name: str
    mesh: Mesh
    jitted: Any                 # jax.jit(...) ready to .lower(*arg_specs)
    arg_specs: Tuple            # ShapeDtypeStructs
    steps: int                  # SGD steps (or decode steps) per program
    notes: str = ""


# --------------------------------------------------------------------- #
# training case (hier mesh)
# --------------------------------------------------------------------- #

def parse_layout(spec: str) -> ParallelLayout:
    """'GxSxFxTP[:micro]' -> ParallelLayout (hillclimb override)."""
    micro = 1
    if ":" in spec:
        spec, m = spec.split(":")
        micro = int(m)
    g, s, f, tp = (int(x) for x in spec.split("x"))
    return ParallelLayout(groups=g, local=s, fsdp=f, tp=tp,
                          microbatch=micro)


def default_hier_params(cfg: ArchConfig) -> HierAvgParams:
    """Paper-faithful defaults: K1=4, K2=8 (beta=2) — small enough to keep
    the lowered round compact, large enough that local+global reductions
    both appear in the collective schedule."""
    return HierAvgParams(k1=4, k2=8)


def train_case(cfg: ArchConfig, shape: InputShape, *, multi_pod: bool,
               hier: Optional[HierAvgParams] = None,
               remat: bool = True,
               param_dtype=jnp.bfloat16,
               sync_opt_state: bool = False,
               use_constraints: bool = True) -> DryrunCase:
    hier = hier or default_hier_params(cfg)
    plan = hier.resolved_plan
    lay = cfg.layout
    mesh = make_hier_mesh(lay, multi_pod=multi_pod)
    pods = PODS_MULTI if multi_pod else 1
    topo = HierTopology(pods=pods, groups=lay.groups, local=lay.local)

    bundle = build(cfg, param_dtype=param_dtype, remat=remat)
    optimizer = sgd(0.1)          # paper: plain SGD, step-decayed lr
    rules = PartitionRules()
    # fsdp>1: shard-aware bucket layout — buckets pack each device's
    # shard slice and every level's mean lowers to RS+AG (comm/bucket.py)
    shards = shard_plan(mesh, rules=rules) if lay.fsdp > 1 else None

    # ---- state structure without allocation ----
    state_struct = jax.eval_shape(
        lambda k: init_state(topo, bundle.init, optimizer, k, plan=plan,
                             shards=shards),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    pspecs = param_pspecs(state_struct.params, mesh, stacked_learners=True,
                          rules=rules)
    opt_specs = jax.tree.map(
        lambda leaf: safe_pspec(
            P(*(("pod", "group", "local") + (None,) * (leaf.ndim - 3))),
            leaf.shape, mesh),
        state_struct.opt_state) if jax.tree.leaves(state_struct.opt_state) \
        else state_struct.opt_state
    # momentum mirrors params: reuse param specs when structures match
    try:
        opt_specs = jax.tree.map(lambda s: s, pspecs) \
            if (jax.tree_util.tree_structure(state_struct.opt_state)
                == jax.tree_util.tree_structure(state_struct.params)) \
            else opt_specs
    except Exception:
        pass
    # reducer comm state, per plan level: EF ref/err (and PowerSGD ref/err)
    # mirror the params tree exactly (same shapes, fp32 err), so they reuse
    # the params' specs — learner axes AND trailing fsdp/tp shards; PRNG
    # keys stay replicated, and PowerSGD's warm Q shards over the learner
    # axes only (its trailing [b, rank] dims are tiny)
    params_treedef = jax.tree_util.tree_structure(state_struct.params)

    s_sz = int(mesh.shape["local"])
    f_sz = int(mesh.shape.get("fsdp", 1))

    def bucket_lead_spec(leaf) -> P:
        """Lead spec for bucket-space leaves: learner axes sharded,
        trailing dims replicated.  Under an fsdp>1 ShardPlan the bucket
        engine keeps EF state in the *codec view* — shards merged into
        the local-learner dim, [pods, G, S*F, run] — so dim 2 shards
        over the ("local", "fsdp") tuple (major-minor mesh order, the
        shard-local merge comm/bucket.py performs)."""
        lead = ("pod", "group", "local")
        if (shards is not None and leaf.ndim >= 3
                and leaf.shape[2] == s_sz * f_sz):
            lead = ("pod", "group", ("local", "fsdp"))
        return safe_pspec(P(*(lead + (None,) * (leaf.ndim - 3))),
                          leaf.shape, mesh)

    def stacked_specs(tree):
        """Learner axes sharded, trailing dims replicated — the fallback
        for state trees that do NOT mirror the params (bucket-space EF
        from comm/bucket.py: [pods, G, S, n] packed buckets, or
        [pods, G, S*F, n] codec-view buckets under fsdp sharding)."""
        return jax.tree.map(bucket_lead_spec, tree)

    def level_comm_specs(cs):
        if isinstance(cs, EFState):
            mirrors = (jax.tree_util.tree_structure(cs.ref)
                       == params_treedef)
            specs = pspecs if mirrors else stacked_specs(cs.ref)
            err_specs = pspecs if mirrors else stacked_specs(cs.err)
            return EFState(ref=specs, err=err_specs, key=P())
        if isinstance(cs, LowRankState):
            q_specs = stacked_specs(cs.q)
            mirrors = (jax.tree_util.tree_structure(cs.ref)
                       == params_treedef)
            specs = pspecs if mirrors else stacked_specs(cs.ref)
            err_specs = pspecs if mirrors else stacked_specs(cs.err)
            return LowRankState(ref=specs, err=err_specs, q=q_specs)
        return jax.tree.map(lambda leaf: P(), cs)

    if isinstance(state_struct.comm_state, dict):
        comm_specs = {name: level_comm_specs(cs)
                      for name, cs in state_struct.comm_state.items()}
    else:
        comm_specs = level_comm_specs(state_struct.comm_state)
    state_specs = state_struct.__class__(pspecs, opt_specs, P(), comm_specs)
    state_shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s), state_specs,
        is_leaf=lambda x: isinstance(x, P))

    # ---- per-learner batch ----
    per_learner_b = shape.global_batch // topo.n_learners
    assert per_learner_b >= 1, (cfg.name, shape.name, topo)
    inner = train_batch_specs(cfg, per_learner_b, shape.seq_len,
                              dtype=param_dtype)
    lead = plan.batch_dims + topo.shape

    def wrap(s):
        return jax.ShapeDtypeStruct(lead + s.shape, s.dtype)

    batch_specs = {k: wrap(v) for k, v in inner.items()}

    # schedule-aware round-batch shardings, generic in the plan depth
    # (data/loader.py owns the [*batch_dims, pod, group, local, fsdp]
    # assignment — the loader and the lowered case cannot disagree)
    from repro.data.loader import round_batch_shardings
    batch_shardings = round_batch_shardings(mesh, hier, batch_specs)

    constraint_fn = None
    if use_constraints:
        param_shardings = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                       pspecs, is_leaf=lambda x:
                                       isinstance(x, P))

        def pin_learner_axes(leaf):
            """Generic re-pin for trees that do NOT mirror the params
            (bucket-space reductions, comm/bucket.py): learner axes
            sharded, trailing bucket dims replicated (codec-view leaves
            keep their fsdp shard via ``bucket_lead_spec``)."""
            if getattr(leaf, "ndim", 0) < 3:
                return leaf
            return jax.lax.with_sharding_constraint(
                leaf, NamedSharding(mesh, bucket_lead_spec(leaf)))

        def constraint_fn(tree):
            try:
                return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                                    param_shardings)
            except Exception:
                pass
            try:
                return jax.tree.map(pin_learner_axes, tree)
            except Exception:
                return tree

    round_fn = make_hier_round(bundle.loss_fn, optimizer, hier,
                               sync_opt_state=sync_opt_state,
                               constraint_fn=constraint_fn,
                               microbatch=lay.microbatch,
                               shards=shards)

    jitted = jax.jit(round_fn,
                     in_shardings=(state_shardings, batch_shardings),
                     out_shardings=(state_shardings, None),
                     donate_argnums=(0,))
    return DryrunCase(
        name=f"{cfg.name}:{shape.name}:{'2pod' if multi_pod else '1pod'}",
        mesh=mesh, jitted=jitted, arg_specs=(state_struct, batch_specs),
        steps=hier.steps_per_round,
        notes=f"hier_round plan={plan.describe()} "
              f"{topo.describe()} fsdp={lay.fsdp} tp={lay.tp} "
              f"B/learner={per_learner_b}")


# --------------------------------------------------------------------- #
# serving cases (production mesh)
# --------------------------------------------------------------------- #

_SERVE_AXIS_MAP_1POD = {"pod": None, "group": None, "local": None,
                        "fsdp": "data", "model": "model"}


def _serve_param_shardings(params_struct, mesh: Mesh, multi_pod: bool):
    amap = dict(_SERVE_AXIS_MAP_1POD)
    if multi_pod:
        amap["fsdp"] = ("pod", "data")
    rules = PartitionRules(axis_map=amap)
    specs = param_pspecs(params_struct, mesh, stacked_learners=False,
                         rules=rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def _batch_axis(mesh: Mesh, multi_pod: bool):
    return ("pod", "data") if multi_pod else "data"


def _cache_pspec(path: str, leaf, mesh: Mesh, batch: int, multi_pod: bool
                 ) -> P:
    """Heuristic cache sharding:
    batch dim over data (when divisible), heads/state over model, and for
    batch-1 long-context the sequence dim over data."""
    bax = _batch_axis(mesh, multi_pod)
    ndim = leaf.ndim
    if ndim == 0:          # pos counters
        return P()
    if ndim == 1:          # stacked pos [L]
        return P(None)
    # leading dim is the layer stack L; dim 1 is batch
    spec = [None] * ndim
    spec[1] = bax
    name = path.split("/")[-1]
    tp = mesh.shape["model"]
    if name in ("k", "v", "cross_k", "cross_v") and ndim >= 5:
        # [L,B,T,H,D]: shard heads over TP when divisible; otherwise shard
        # HEAD_DIM over TP (keeps the per-step cache write local; avoids
        # 16x cache replication for kv-head counts < 16)
        if leaf.shape[3] % tp == 0:
            spec[3] = "model"
        elif leaf.shape[4] % tp == 0:
            spec[4] = "model"
        elif leaf.shape[2] % tp == 0:
            spec[2] = "model"
        if batch == 1:
            spec[1] = None
            spec[2] = bax if leaf.shape[2] % 16 == 0 else None
    elif name in ("ckv", "k_rope") and ndim >= 4:
        if leaf.shape[3] % tp == 0:
            spec[3] = "model"      # [L,B,T,lora] — latent dim over TP
        if batch == 1:
            spec[1] = None
            spec[2] = bax if leaf.shape[2] % 16 == 0 else None
    elif name == "wkv" and ndim >= 3:
        spec[2] = "model"          # [L,B,H,D,D]
    elif name in ("ssm", "conv") and ndim >= 3:
        spec[2] = "model" if name == "ssm" else None  # [L,B,Ci,N]/[L,B,K,Ci]
        if name == "conv" and ndim >= 4:
            spec[3] = "model"
    elif name in ("tm_shift", "cm_shift") and ndim >= 3:
        spec[2] = "model"          # [L,B,d]
    return safe_pspec(P(*spec), leaf.shape, mesh)


def decode_case(cfg: ArchConfig, shape: InputShape, *, multi_pod: bool,
                param_dtype=jnp.bfloat16,
                cache_dtype=jnp.bfloat16) -> DryrunCase:
    mesh = make_production_mesh(multi_pod=multi_pod)
    rolling = (shape.name == "long_500k"
               and cfg.family in ("dense", "moe", "vlm", "audio")
               and not cfg.kv_lora_rank)
    bundle = build(cfg, param_dtype=param_dtype, rolling_decode=rolling,
                   cache_dtype=cache_dtype)
    B = shape.global_batch
    max_len = shape.seq_len

    params_struct = jax.eval_shape(
        bundle.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
    p_shard = _serve_param_shardings(params_struct, mesh, multi_pod)

    cache_struct = jax.eval_shape(
        functools.partial(bundle.init_cache, B, max_len))
    c_shard = jax.tree_util.tree_map_with_path(
        lambda kp, leaf: NamedSharding(
            mesh, _cache_pspec("/".join(str(getattr(k, "key", k))
                                        for k in kp), leaf, mesh, B,
                               multi_pod)),
        cache_struct)

    bax = _batch_axis(mesh, multi_pod)
    tok_spec = jax.ShapeDtypeStruct((B,), jnp.int32)
    tok_shard = NamedSharding(mesh, safe_pspec(P(bax), (B,), mesh))

    def serve_step(params, tokens, cache):
        return bundle.decode_step(params, tokens, cache)

    jitted = jax.jit(serve_step,
                     in_shardings=(p_shard, tok_shard, c_shard),
                     out_shardings=(None, c_shard),
                     donate_argnums=(2,))   # cache updated in place
    kind = ("rolling-window" if rolling else
            "mla-latent" if cfg.kv_lora_rank else
            "state" if cfg.family in ("ssm", "hybrid") else "full-kv")
    return DryrunCase(
        name=f"{cfg.name}:{shape.name}:{'2pod' if multi_pod else '1pod'}",
        mesh=mesh, jitted=jitted,
        arg_specs=(params_struct, tok_spec, cache_struct), steps=1,
        notes=f"serve_step cache={kind} B={B} ctx={max_len}")


def prefill_case(cfg: ArchConfig, shape: InputShape, *, multi_pod: bool,
                 param_dtype=jnp.bfloat16, remat: bool = True) -> DryrunCase:
    mesh = make_production_mesh(multi_pod=multi_pod)
    bundle = build(cfg, param_dtype=param_dtype, remat=remat)
    B = shape.global_batch

    params_struct = jax.eval_shape(
        bundle.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
    p_shard = _serve_param_shardings(params_struct, mesh, multi_pod)

    inner = train_batch_specs(cfg, B, shape.seq_len, dtype=param_dtype)
    inner.pop("labels", None)
    bax = _batch_axis(mesh, multi_pod)
    b_shard = {k: NamedSharding(
        mesh, safe_pspec(P(*((bax,) + (None,) * (len(v.shape) - 1))),
                         v.shape, mesh))
        for k, v in inner.items()}

    def prefill(params, batch):
        logits, cache = bundle.prefill(params, batch)
        return logits

    jitted = jax.jit(prefill, in_shardings=(p_shard, b_shard),
                     out_shardings=None)
    return DryrunCase(
        name=f"{cfg.name}:{shape.name}:{'2pod' if multi_pod else '1pod'}",
        mesh=mesh, jitted=jitted, arg_specs=(params_struct, inner), steps=1,
        notes=f"prefill B={B} S={shape.seq_len}")


def build_case(cfg: ArchConfig, shape_name: str, *, multi_pod: bool,
               **kw) -> DryrunCase:
    shape = INPUT_SHAPES[shape_name]
    if shape.kind == "train":
        return train_case(cfg, shape, multi_pod=multi_pod, **kw)
    if shape.kind == "prefill":
        return prefill_case(cfg, shape, multi_pod=multi_pod)
    return decode_case(cfg, shape, multi_pod=multi_pod)
