"""Interactive roofline explorer — napkin math as a CLI.

Evaluate any (arch x shape x layout x K1/K2 x mesh) through the analytic
model without compiling; the §Perf workflow is: explore here, then verify
the winner with ``dryrun --layout``.

  PYTHONPATH=src python -m repro.launch.explore --arch rwkv6-1.6b \
      --shape train_4k --layout 32x4x1x2:1 --k2 8
  PYTHONPATH=src python -m repro.launch.explore --arch mistral-large-123b \
      --shape train_4k --sweep-k2 --multi-pod
"""
from __future__ import annotations

import argparse
import dataclasses

from repro.configs import get_config
from repro.configs.base import HierAvgParams
from repro.launch.analytic import analytic_roofline
from repro.launch.cases import parse_layout


def show(cfg, shape, *, multi_pod, hier):
    r = analytic_roofline(cfg, shape, multi_pod=multi_pod, hier=hier)
    lay = cfg.layout
    print(f"{cfg.name} x {shape} on {'2pod/512' if multi_pod else '1pod/256'}"
          f"  layout={lay.groups}x{lay.local}x{lay.fsdp}x{lay.tp}"
          f":{lay.microbatch}  K1={hier.k1} K2={hier.k2}")
    print(f"  compute    {1e3*r.compute_s:10.2f} ms")
    print(f"  memory     {1e3*r.memory_s:10.2f} ms")
    print(f"  collective {1e3*r.collective_s:10.2f} ms"
          f"   <- bottleneck: {r.bottleneck}")
    for k, v in sorted(r.collective_parts.items(), key=lambda kv: -kv[1]):
        print(f"      {k:12s} {1e3*v:10.2f} ms")
    mfu = r.model_flops_per_device / (
        max(r.compute_s, r.memory_s, r.collective_s) * 197e12)
    print(f"  projected MFU at the binding term: {mfu:.1%}")
    return r


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--layout", default=None)
    ap.add_argument("--k1", type=int, default=4)
    ap.add_argument("--k2", type=int, default=8)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--sweep-k2", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.layout:
        cfg = dataclasses.replace(cfg, layout=parse_layout(args.layout))
    if args.sweep_k2:
        for k2 in (1, 2, 4, 8, 16, 32, 64):
            k1 = min(args.k1, k2)
            r = analytic_roofline(cfg, args.shape, multi_pod=args.multi_pod,
                                  hier=HierAvgParams(k1, k2))
            g = r.collective_parts.get("global_avg", 0.0)
            lo = r.collective_parts.get("local_avg", 0.0)
            print(f"K2={k2:3d}: global_avg={1e3*g:8.3f} ms "
                  f"local_avg={1e3*lo:8.3f} ms "
                  f"total_coll={1e3*r.collective_s:9.2f} ms")
        return
    show(cfg, args.shape, multi_pod=args.multi_pod,
         hier=HierAvgParams(args.k1, args.k2))


if __name__ == "__main__":
    main()
