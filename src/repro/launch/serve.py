"""Batched serving driver (reduced configs run end-to-end on CPU).

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --reduced \
      --requests 6 --prompt-len 16 --max-new 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build
from repro.serve import GenerationConfig, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(args.seed))
    engine = ServeEngine(
        bundle, params, max_len=args.prompt_len + args.max_new,
        gen=GenerationConfig(max_new_tokens=args.max_new,
                             temperature=args.temperature, seed=args.seed))

    rng = np.random.default_rng(args.seed)
    reqs = [rng.integers(0, cfg.vocab_size, size=args.prompt_len)
            .astype(np.int32) for _ in range(args.requests)]
    t0 = time.time()
    results = engine.serve_queue(reqs, slots=args.slots)
    dt = time.time() - t0
    total_new = sum(r.steps for r in results)
    for r in results[:4]:
        print(f"req {r.request_id}: prompt[-4:]={r.prompt[-4:]} "
              f"-> {r.tokens[:8]}")
    print(f"{len(results)} requests, {total_new} tokens in {dt:.1f}s "
          f"({total_new/dt:.1f} tok/s incl. compile)")


if __name__ == "__main__":
    main()
