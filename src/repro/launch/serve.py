"""Batched serving driver (reduced configs run end-to-end on CPU).

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --reduced \
      --requests 6 --prompt-len 16 --max-new 8

  # paged continuous batching (token-level slot refill):
  PYTHONPATH=src python -m repro.launch.serve --arch yi-34b --paged \
      --requests 8 --slots 4 --block-size 16 --max-new 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build
from repro.serve import GenerationConfig, PagedServeEngine, ServeEngine
from repro.telemetry import MetricsLogger


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--paged", action="store_true",
                    help="continuous batching over a paged KV cache "
                         "(PagedServeEngine) instead of wave batching")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged KV page size in tokens")
    ap.add_argument("--budget-mb", type=float, default=0.0,
                    help="paged pool byte budget (0 => size for "
                         "slots x max_len)")
    ap.add_argument("--decode-impl", default="auto",
                    choices=["auto", "xla", "pallas", "pallas_interpret"],
                    help="flash-decode kernel dispatch for the paged path")
    ap.add_argument("--metrics-out", default=None,
                    help="write telemetry rows (serve_step per decode "
                         "step on the paged path, serve_summary per "
                         "queue) to this JSONL file")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    bundle = build(cfg, decode_impl=args.decode_impl)
    params = bundle.init(jax.random.PRNGKey(args.seed))
    max_len = args.prompt_len + args.max_new
    gen = GenerationConfig(max_new_tokens=args.max_new,
                           temperature=args.temperature, seed=args.seed)

    rng = np.random.default_rng(args.seed)
    reqs = [rng.integers(0, cfg.vocab_size, size=args.prompt_len)
            .astype(np.int32) for _ in range(args.requests)]
    logger = MetricsLogger(args.metrics_out) if args.metrics_out else None
    t0 = time.time()
    if args.paged:
        budget = int(args.budget_mb * 2 ** 20) or None
        engine = PagedServeEngine(
            bundle, params, slots=args.slots, page_size=args.block_size,
            max_len=max_len, budget_bytes=budget, gen=gen, metrics=logger)
        results = engine.serve_queue(reqs)
    else:
        engine = ServeEngine(bundle, params, max_len=max_len, gen=gen,
                             metrics=logger)
        results = engine.serve_queue(reqs, slots=args.slots)
    dt = time.time() - t0
    total_new = sum(r.steps for r in results)
    total_steps = sum(r.decode_steps for r in results)
    for r in results[:4]:
        print(f"req {r.request_id}: prompt[-4:]={r.prompt[-4:]} "
              f"-> {r.tokens[:8]}")
    print(f"{len(results)} requests, {total_new} tokens / {total_steps} "
          f"decode steps in {dt:.1f}s ({total_new/dt:.1f} tok/s incl. "
          f"compile)")
    if args.paged:
        print(f"pool: {engine.alloc.n_pages - 1} pages of "
              f"{args.block_size} tokens, peak in use "
              f"{engine.alloc.peak_in_use}")
    s = engine.steady_state_summary()
    print(f"steady-state: engine={s['engine']} tok/s={s['tokens_per_s']} "
          f"wasted={s['wasted_ratio']} occupancy={s['mean_occupancy']} "
          f"refills={s['refill_events']} "
          f"peak_pages={s['peak_pages_in_use']}/{s['pool_pages']}")
    if logger is not None:
        logger.close()
        print(f"telemetry rows -> {args.metrics_out}")


if __name__ == "__main__":
    main()
