"""Roofline-term extraction from compiled (SPMD-partitioned) HLO.

``compiled.cost_analysis()`` reports FLOPs / bytes for the PER-DEVICE
partitioned program; ``compiled.as_text()`` is likewise the per-device HLO,
so collective operand shapes are per-device shards.  The three roofline
terms (seconds) therefore come out per chip directly:

  compute    = flops_per_device / peak_flops_chip
  memory     = bytes_per_device / hbm_bw_chip
  collective = sum over collective ops of ring-model link-bytes / link_bw

Ring model per op (n = replica-group size, V = per-device payload bytes,
payload = the op's per-device RESULT shape):
  all-reduce        2 V (n-1)/n
  all-gather        V (n-1)/n   (result holds all n shards; (n-1)/n received)
  reduce-scatter    V (n-1)     (result is one shard; n-1 shard exchanges)
  all-to-all        V (n-1)/n
  collective-permute V
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

# TPU v5e-class constants (per assignment)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|([a-z0-9]+)\[([0-9,]*)\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUP_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUP_RE2 = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    payload_bytes: int       # per-device operand/result bytes
    group_size: int
    link_bytes: float        # ring-model bytes crossing links per device

    def as_dict(self):
        return dataclasses.asdict(self)


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _ring_factor(kind: str, n: int) -> float:
    if n <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (n - 1) / n
    if kind == "all-gather":
        # payload = per-device gathered result (all n shards): recv (n-1)/n
        return float(n - 1) / n
    if kind == "reduce-scatter":
        # payload = per-device scattered result shard: send/recv (n-1) shards
        return float(n - 1)
    if kind == "all-to-all":
        return float(n - 1) / n
    return 1.0  # collective-permute


def parse_collectives(hlo_text: str) -> List[CollectiveOp]:
    ops: List[CollectiveOp] = []
    seen_done = set()
    for line in hlo_text.splitlines():
        m = None
        kind = None
        for k in _COLLECTIVES:
            if f" {k}(" in line or f" {k}-start(" in line:
                kind = k
                break
        if kind is None:
            continue
        # result shape(s): first shape token(s) after '='
        eq = line.find("=")
        if eq < 0:
            continue
        rhs = line[eq + 1:]
        shapes = _SHAPE_RE.findall(rhs.split(kind)[0])
        payload = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        if payload == 0:
            continue
        gm = _GROUP_RE.search(line)
        if gm:
            group = len([x for x in gm.group(1).split(",") if x.strip()])
        else:
            gm2 = _GROUP_RE2.search(line)
            group = int(gm2.group(2)) if gm2 else 2
        ops.append(CollectiveOp(kind, payload, group,
                                payload * _ring_factor(kind, group)))
    return ops


def collective_summary(ops: List[CollectiveOp]) -> Dict:
    by_kind: Dict[str, Dict] = {}
    for o in ops:
        d = by_kind.setdefault(o.kind, {"count": 0, "payload_bytes": 0,
                                        "link_bytes": 0.0})
        d["count"] += 1
        d["payload_bytes"] += o.payload_bytes
        d["link_bytes"] += o.link_bytes
    return by_kind


def roofline_terms(cost: Dict, ops: List[CollectiveOp], *,
                   model_flops_per_device: Optional[float] = None,
                   steps: int = 1) -> Dict:
    """Three roofline terms in seconds (per executed program / steps)."""
    flops = float(cost.get("flops", 0.0))
    # 'bytes accessed' aggregates operand+output HBM traffic
    bytes_ = float(cost.get("bytes accessed", 0.0))
    link_bytes = sum(o.link_bytes for o in ops)
    compute_s = flops / PEAK_FLOPS / steps
    memory_s = bytes_ / HBM_BW / steps
    collective_s = link_bytes / LINK_BW / steps
    dom = max((("compute", compute_s), ("memory", memory_s),
               ("collective", collective_s)), key=lambda kv: kv[1])[0]
    out = {
        "flops_per_device": flops,
        "bytes_per_device": bytes_,
        "collective_link_bytes": link_bytes,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "bottleneck": dom,
        "steps": steps,
    }
    if model_flops_per_device:
        out["model_flops_per_device"] = model_flops_per_device / steps
        out["useful_flops_ratio"] = (model_flops_per_device / flops
                                     if flops else 0.0)
    return out
