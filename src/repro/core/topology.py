"""Learner topology for Hier-AVG.

The paper's communicators:
  * P  learners total
  * clusters of S learners each do the *local* reduction
  * all P learners do the *global* reduction

We realize a learner as a coordinate on the (pod, group, local) axes of the
training mesh; ``local`` has size S, ``group`` counts clusters per pod, and
``pod`` counts pods.  All parameter / optimizer-state leaves carry these
three leading axes (the *stacked-learner* layout), so:

  local  reduction == mean over the ``local``  array axis (index 2)
  global reduction == mean over ``pod, group, local`` (indices 0, 1, 2)

GSPMD lowers those means to grouped all-reduces over exactly the matching
mesh axes — intra-pod ICI for local, cross-pod DCI for global.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp

AXIS_POD = "pod"
AXIS_GROUP = "group"
AXIS_LOCAL = "local"
AXIS_FSDP = "fsdp"
AXIS_TP = "model"

LEARNER_AXES: Tuple[str, str, str] = (AXIS_POD, AXIS_GROUP, AXIS_LOCAL)
LOCAL_ARRAY_AXES: Tuple[int, ...] = (2,)
POD_ARRAY_AXES: Tuple[int, ...] = (1, 2)
GLOBAL_ARRAY_AXES: Tuple[int, ...] = (0, 1, 2)


@dataclass(frozen=True)
class HierTopology:
    """(pods, groups, local) learner grid; ``local`` is the paper's S."""

    pods: int = 1
    groups: int = 1
    local: int = 1

    def __post_init__(self):
        assert self.pods >= 1 and self.groups >= 1 and self.local >= 1

    @property
    def n_learners(self) -> int:  # the paper's P
        return self.pods * self.groups * self.local

    @property
    def s(self) -> int:          # the paper's S
        return self.local

    @property
    def shape(self) -> Tuple[int, int, int]:
        return (self.pods, self.groups, self.local)

    # local clusters never span pods: cluster id = (pod, group)
    @property
    def n_clusters(self) -> int:
        return self.pods * self.groups

    def describe(self) -> str:
        return (f"P={self.n_learners} learners = {self.pods} pod(s) x "
                f"{self.groups} cluster(s)/pod x S={self.local}")


def stack_like(topo: HierTopology, tree):
    """Replicate a single-learner pytree to the stacked layout
    [pods, G, S, ...] (paper: all learners start from the same w_1)."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, topo.shape + x.shape), tree)


def stack_distinct(topo: HierTopology, init_fn, key):
    """Independent per-learner init (for ablations): vmap init over learners."""
    keys = jax.random.split(key, topo.n_learners)
    keys = keys.reshape(topo.shape + keys.shape[1:])
    f = init_fn
    for _ in range(3):
        f = jax.vmap(f)
    return f(keys)


def unstack_first(tree):
    """Extract learner (0,0,0)'s copy (post-global-average they are equal)."""
    return jax.tree.map(lambda x: x[0, 0, 0], tree)


def _scatter_mean(x, sharding, axes: Tuple[int, ...], denom=None):
    """The grouped learner-axis mean of one bucket, lowered explicitly to
    reduce-scatter + all-gather instead of a full all-reduce.

    ``x`` is a packed bucket ``[pods, G, S, run]`` (or ``[pods, G, S, F,
    run]`` for fsdp-sharded buckets) whose placement is ``sharding`` — one
    mesh axis per lead dim, payload dim(s) trailing.  The chain matches
    GSPMD's decomposition of the multi-axis mean (one collective per mesh
    axis, minor axis first) so the summation order — and therefore every
    bit of the result — is identical to the all-reduce lowering; the run
    length must tile over the reduced axes (BucketLayout pads for this).
    Returns None when the mesh/spec cannot take the scatter path (caller
    falls back to the plain mean).

    ``denom`` — participation-masked (elastic) reductions pass the
    already-*weighted* bucket as ``x`` and the per-group survivor counts
    (broadcastable to ``x``, clipped >= 1) as ``denom``; the division then
    happens AFTER the gather, outside the shard_map block.  Elementwise
    division commutes with ``all_gather``, so at full participation
    (``denom == n`` everywhere) the result is bit-identical to the
    unmasked path — masking rides the same collectives, in wire space.
    """
    from jax.experimental.shard_map import shard_map

    mesh = sharding.mesh
    spec = tuple(sharding.spec) + (None,) * (x.ndim - len(sharding.spec))
    names = []
    for a in axes:
        ax = spec[a] if a < len(spec) else None
        if ax is None or isinstance(ax, tuple):
            return None                      # lead dim not mesh-mapped
        if x.shape[a] != int(mesh.shape.get(ax, 1)):
            return None                      # dim not fully sharded
        names.append(ax)
    active = [a for a in names if int(mesh.shape.get(a, 1)) > 1]
    n = 1
    for a in names:
        n *= int(mesh.shape.get(a, 1))
    if not active:                           # single-learner grid: local mean
        return None
    run = x.shape[-1]
    tile = 1
    for a in active:
        tile *= int(mesh.shape[a])
    if run % tile:
        return None                          # un-padded run: cannot tile

    def blk(xb):
        d = xb.ndim - 1
        s = xb
        for a in reversed(active):           # minor axis first, like GSPMD
            s = jax.lax.psum_scatter(s, a, scatter_dimension=d, tiled=True)
        if denom is None:
            s = s / n
        for a in active:
            s = jax.lax.all_gather(s, a, axis=d, tiled=True)
        return s

    pspec = jax.sharding.PartitionSpec(*spec)
    out = shard_map(blk, mesh=mesh, in_specs=pspec, out_specs=pspec,
                    check_rep=False)(x)
    if denom is not None:
        out = out / denom.astype(out.dtype)
    return out


def _mask_weights(mask, ndim: int, dtype):
    """The mask as multiplicative weights aligned to an ``ndim``-dim
    stacked leaf: ``[pods, G, S]`` broadcast over the trailing dims."""
    w = mask.astype(dtype)
    return w.reshape(w.shape + (1,) * (ndim - w.ndim))


def average_over(tree, axes: Tuple[int, ...], constraint_fn=None,
                 bucket_specs=None, mask=None):
    """Mean over stacked learner axes, broadcast back (== grouped all-reduce).

    ``constraint_fn(leaf) -> leaf`` optionally re-pins the sharding after the
    broadcast (used by the distributed launcher to keep GSPMD honest).

    ``bucket_specs`` — a leaf-aligned sequence of NamedShardings (or None
    per leaf), supplied by the shard-aware bucket engine (comm/bucket.py)
    for fsdp>1 layouts — switches matching leaves to the explicit
    reduce-scatter + all-gather lowering: each device contributes and
    receives only its shard slice, instead of the all-reduce
    re-materializing every shard.  Bit-identical to the plain path (same
    per-axis summation order); leaves whose spec is None (or cannot tile)
    keep the plain mean.  The specs pin the output placement, so
    ``constraint_fn`` is not applied on this path — the launcher's
    constraint targets param-shaped trees, not packed buckets.

    ``mask`` — elastic membership (repro/elastic): a boolean ``[pods, G,
    S]`` participation mask; absent learners contribute weight 0 and the
    sum renormalizes by the per-group survivor count, so the result is
    the mean over the *present* members of each group.  A group with no
    survivors divides by a clipped count of 1 and yields 0 — never NaN —
    and the caller (core/hier_avg.py ``where_active``) discards that
    value by keeping absent learners' own params.  At full participation
    the weights are exactly 1.0 and the counts exactly n, so masked ==
    unmasked bit-for-bit (test-enforced, all engines).  On the
    ``bucket_specs`` path the weighting is applied in *wire space*
    (weights broadcast over the ``[F, run]`` payload dims) before the
    reduce-scatter, so fsdp>1 layouts mask through the same RS/AG
    collectives.
    """
    def avg(x):
        if mask is not None:
            w = _mask_weights(mask, x.ndim, x.dtype)
            c = jnp.sum(w, axis=axes, keepdims=True)
            s = jnp.sum(x * w, axis=axes, keepdims=True)
            m = s / jnp.maximum(c, 1)        # all-absent group: 0, not NaN
        else:
            m = jnp.mean(x, axis=axes, keepdims=True)
        return jnp.broadcast_to(m, x.shape)

    if bucket_specs is not None:
        leaves, treedef = jax.tree.flatten(tree)
        specs = list(bucket_specs)
        assert len(specs) == len(leaves), \
            f"{len(specs)} bucket specs for {len(leaves)} bucket leaves"
        out = []
        for x, s in zip(leaves, specs):
            if s is None:
                y = None
            elif mask is not None:
                w = _mask_weights(mask, x.ndim, x.dtype)
                c = jnp.maximum(jnp.sum(w, axis=axes, keepdims=True), 1)
                y = _scatter_mean(x * w, s, axes, denom=c)
            else:
                y = _scatter_mean(x, s, axes)
            out.append(avg(x) if y is None else y)
        return treedef.unflatten(out)

    out = jax.tree.map(avg, tree)
    if constraint_fn is not None:
        out = constraint_fn(out)
    return out


def where_active(mask, new_tree, old_tree):
    """Per-learner select: active learners take ``new_tree``, absent ones
    keep ``old_tree`` — how elastic rounds (core/hier_avg.py) keep an
    absent learner's params AND its EF/``comm_state`` untouched across a
    missed fire.

    ``mask`` is the boolean ``[pods, G, S]`` participation mask.  Leaf
    alignment is by shape: leaves carrying the full stacked lead
    (``shape[:3] == mask.shape`` — params, opt state, param/bucket-space
    EF) select per learner; codec-view leaves of shard-aware bucket
    layouts (``[pods, G, S*F, ...]`` — shards merged into the local axis,
    comm/bucket.py) repeat each learner's bit over its F shard rows; all
    other leaves (PRNG keys, scalars) take ``new`` — they are global
    streams, not per-learner state.  With an all-true mask every branch
    returns ``new`` exactly, preserving full-participation bit-identity.
    """
    pg, s = mask.shape[:2], mask.shape[2]

    def sel(new, old):
        shape = tuple(getattr(new, "shape", ()))
        if len(shape) >= 3 and shape[:3] == tuple(mask.shape):
            m = mask
        elif (len(shape) >= 3 and shape[:2] == tuple(pg)
                and shape[2] != s and shape[2] % s == 0):
            m = jnp.repeat(mask, shape[2] // s, axis=2)   # codec view S*F
        else:
            return new
        return jnp.where(_mask_weights(m, len(shape), jnp.bool_), new, old)

    return jax.tree.map(sel, new_tree, old_tree)


def local_average(tree, constraint_fn=None, bucket_specs=None, mask=None):
    """The paper's local reduction: mean within each cluster of S learners."""
    return average_over(tree, LOCAL_ARRAY_AXES, constraint_fn, bucket_specs,
                        mask)


def global_average(tree, constraint_fn=None, bucket_specs=None, mask=None):
    """The paper's global reduction: mean over all P learners."""
    return average_over(tree, GLOBAL_ARRAY_AXES, constraint_fn, bucket_specs,
                        mask)


def pod_average(tree, constraint_fn=None, bucket_specs=None, mask=None):
    """Beyond-paper: intra-pod reduction (axes group+local, not pod) —
    a middle hierarchy level matching the ICI/DCI boundary."""
    return average_over(tree, POD_ARRAY_AXES, constraint_fn, bucket_specs,
                        mask)
