"""Learner topology for Hier-AVG.

The paper's communicators:
  * P  learners total
  * clusters of S learners each do the *local* reduction
  * all P learners do the *global* reduction

We realize a learner as a coordinate on the (pod, group, local) axes of the
training mesh; ``local`` has size S, ``group`` counts clusters per pod, and
``pod`` counts pods.  All parameter / optimizer-state leaves carry these
three leading axes (the *stacked-learner* layout), so:

  local  reduction == mean over the ``local``  array axis (index 2)
  global reduction == mean over ``pod, group, local`` (indices 0, 1, 2)

GSPMD lowers those means to grouped all-reduces over exactly the matching
mesh axes — intra-pod ICI for local, cross-pod DCI for global.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp

AXIS_POD = "pod"
AXIS_GROUP = "group"
AXIS_LOCAL = "local"
AXIS_FSDP = "fsdp"
AXIS_TP = "model"

LEARNER_AXES: Tuple[str, str, str] = (AXIS_POD, AXIS_GROUP, AXIS_LOCAL)
LOCAL_ARRAY_AXES: Tuple[int, ...] = (2,)
POD_ARRAY_AXES: Tuple[int, ...] = (1, 2)
GLOBAL_ARRAY_AXES: Tuple[int, ...] = (0, 1, 2)


@dataclass(frozen=True)
class HierTopology:
    """(pods, groups, local) learner grid; ``local`` is the paper's S."""

    pods: int = 1
    groups: int = 1
    local: int = 1

    def __post_init__(self):
        assert self.pods >= 1 and self.groups >= 1 and self.local >= 1

    @property
    def n_learners(self) -> int:  # the paper's P
        return self.pods * self.groups * self.local

    @property
    def s(self) -> int:          # the paper's S
        return self.local

    @property
    def shape(self) -> Tuple[int, int, int]:
        return (self.pods, self.groups, self.local)

    # local clusters never span pods: cluster id = (pod, group)
    @property
    def n_clusters(self) -> int:
        return self.pods * self.groups

    def describe(self) -> str:
        return (f"P={self.n_learners} learners = {self.pods} pod(s) x "
                f"{self.groups} cluster(s)/pod x S={self.local}")


def stack_like(topo: HierTopology, tree):
    """Replicate a single-learner pytree to the stacked layout
    [pods, G, S, ...] (paper: all learners start from the same w_1)."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, topo.shape + x.shape), tree)


def stack_distinct(topo: HierTopology, init_fn, key):
    """Independent per-learner init (for ablations): vmap init over learners."""
    keys = jax.random.split(key, topo.n_learners)
    keys = keys.reshape(topo.shape + keys.shape[1:])
    f = init_fn
    for _ in range(3):
        f = jax.vmap(f)
    return f(keys)


def unstack_first(tree):
    """Extract learner (0,0,0)'s copy (post-global-average they are equal)."""
    return jax.tree.map(lambda x: x[0, 0, 0], tree)


def average_over(tree, axes: Tuple[int, ...], constraint_fn=None):
    """Mean over stacked learner axes, broadcast back (== grouped all-reduce).

    ``constraint_fn(leaf) -> leaf`` optionally re-pins the sharding after the
    broadcast (used by the distributed launcher to keep GSPMD honest).
    """
    def avg(x):
        m = jnp.mean(x, axis=axes, keepdims=True)
        y = jnp.broadcast_to(m, x.shape)
        return y

    out = jax.tree.map(avg, tree)
    if constraint_fn is not None:
        out = constraint_fn(out)
    return out


def local_average(tree, constraint_fn=None):
    """The paper's local reduction: mean within each cluster of S learners."""
    return average_over(tree, LOCAL_ARRAY_AXES, constraint_fn)


def global_average(tree, constraint_fn=None):
    """The paper's global reduction: mean over all P learners."""
    return average_over(tree, GLOBAL_ARRAY_AXES, constraint_fn)


def pod_average(tree, constraint_fn=None):
    """Beyond-paper: intra-pod reduction (axes group+local, not pod) —
    a middle hierarchy level matching the ICI/DCI boundary."""
    return average_over(tree, POD_ARRAY_AXES, constraint_fn)
