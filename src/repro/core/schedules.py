"""K1/K2 schedules, including the Theorem-3.1 admissible K2 and an adaptive
controller motivated by §3.3 ("adaptive choice of K2 may be better").
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

from repro.configs.base import HierAvgParams


def thm31_k2(T: int, P: int, B: int) -> int:
    """K2 = T^{1/4} / (PB)^{3/4} — the largest interval that preserves the
    O(1/sqrt(PBT)) rate (Theorem 3.1, eq. 3.3)."""
    return max(1, int(round(T ** 0.25 / (P * B) ** 0.75)))


def thm31_gamma(P: int, B: int, T: int) -> float:
    """gamma = sqrt(PB/T) (Theorem 3.1, eq. 3.3) — parallelism-scaled step."""
    return math.sqrt(P * B / T)


@dataclass
class AdaptiveK2:
    """Far-from-optimum => large K2 (Thm 3.4 intuition: condition (3.11) holds
    when F(w1)-F* is large); near convergence => shrink K2 toward K1.

    A simple multiplicative controller on the observed training loss:
    K2 ladder descends when the loss drops below fractions of its initial
    value.  Deterministic, cheap, and documented as heuristic.
    """

    k1: int
    k2_max: int
    k2_min: Optional[int] = None
    _loss0: Optional[float] = None

    def __post_init__(self):
        self.k2_min = self.k2_min or self.k1

    def k2_for(self, loss: float) -> int:
        if self._loss0 is None:
            self._loss0 = max(loss, 1e-9)
        frac = max(loss, 1e-9) / self._loss0
        # frac 1.0 -> k2_max ; frac -> 0 shrinks to k2_min, in powers of two
        span = max(1, int(math.log2(max(2, self.k2_max // self.k2_min))))
        level = min(span, max(0, int(-math.log2(max(frac, 1e-9)))))
        k2 = max(self.k2_min, self.k2_max >> level)
        # keep divisibility K1 | K2
        k2 = max(self.k1, (k2 // self.k1) * self.k1)
        return k2

    def params_for(self, loss: float) -> HierAvgParams:
        return HierAvgParams(k1=self.k1, k2=self.k2_for(loss))
