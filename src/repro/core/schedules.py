"""K1/K2 schedules, including the Theorem-3.1 admissible K2 and adaptive
controllers motivated by §3.3 ("adaptive choice of K2 may be better").

:class:`AdaptivePlan` generalizes the K2 ladder to any N-level
ReductionPlan: it scales the *outermost* period (the expensive cross-DCI
reduction) while inner periods stay fixed — Jiang & Agrawal
(arXiv:2007.06134) show the averaging period is the lever worth adapting.
:class:`AdaptiveK2` is its 2-level specialization, kept for the legacy
(k1, k2) API.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Union

from repro.configs.base import HierAvgParams
from repro.core.plan import ReductionPlan


def thm31_k2(T: int, P: int, B: int) -> int:
    """K2 = T^{1/4} / (PB)^{3/4} — the largest interval that preserves the
    O(1/sqrt(PBT)) rate (Theorem 3.1, eq. 3.3)."""
    return max(1, int(round(T ** 0.25 / (P * B) ** 0.75)))


def thm31_gamma(P: int, B: int, T: int) -> float:
    """gamma = sqrt(PB/T) (Theorem 3.1, eq. 3.3) — parallelism-scaled step."""
    return math.sqrt(P * B / T)


@dataclass
class AdaptivePlan:
    """Far-from-optimum => large outermost period (Thm 3.4 intuition:
    condition (3.11) holds when F(w1)-F* is large); near convergence =>
    shrink it toward the next-inner period.  Inner periods never move —
    the controller only spaces out the expensive outermost (cross-DCI)
    reduction.

    A simple multiplicative ladder on the observed training loss: the
    outer period halves each time the loss drops below the next power-of-
    two fraction of its initial value, floored at ``outer_min`` and kept a
    multiple of the next-inner period.  Deterministic, cheap, and
    documented as heuristic.

    ``plan`` is the *widest* schedule (its outermost period is the
    ladder's maximum), as a ReductionPlan or spec string.
    """

    plan: Union[ReductionPlan, str]
    outer_min: Optional[int] = None
    _loss0: Optional[float] = field(default=None, repr=False)

    def __post_init__(self):
        if not isinstance(self.plan, ReductionPlan):
            self.plan = ReductionPlan.parse(self.plan)
        self.outer_max = self.plan.total_period
        # inner periods are fixed; the outer period never dips below the
        # next-inner one (a level reducing more often than its child
        # would violate period nesting)
        self.inner = (self.plan.levels[-2].period
                      if len(self.plan.levels) > 1 else 1)
        self.outer_min = self.outer_min or self.inner
        if (self.outer_min < self.inner
                or self.outer_min % self.inner != 0):
            raise ValueError(
                f"outer_min {self.outer_min} must be a multiple of the "
                f"next-inner period {self.inner}")

    def outer_for(self, loss: float) -> int:
        if self._loss0 is None:
            self._loss0 = max(loss, 1e-9)
        frac = max(loss, 1e-9) / self._loss0
        # frac 1.0 -> outer_max ; frac -> 0 shrinks to outer_min, in
        # powers of two
        span = max(1, int(math.log2(max(2, self.outer_max
                                        // self.outer_min))))
        level = min(span, max(0, int(-math.log2(max(frac, 1e-9)))))
        outer = max(self.outer_min, self.outer_max >> level)
        # keep divisibility inner | outer
        return max(self.inner, (outer // self.inner) * self.inner)

    def plan_for(self, loss: float) -> ReductionPlan:
        return self.plan.with_outer_period(self.outer_for(loss))

    def params_for(self, loss: float,
                   base: Optional[HierAvgParams] = None) -> HierAvgParams:
        """HierAvgParams for the current loss.  ``base`` carries every
        non-schedule field (``bucket_bytes``, ``overlap``, ...) into the
        result — only the plan is replaced.  Without it, defaults apply."""
        spec = self.plan_for(loss).describe()
        if base is None:
            return HierAvgParams(plan=spec)
        return dataclasses.replace(base, plan=spec)

    def reset(self) -> None:
        """Forget the loss anchor so the next ``*_for`` call re-anchors
        the ladder — call between independent runs (``_loss0`` otherwise
        carries over and a warm-started run never sees frac 1.0)."""
        self._loss0 = None


@dataclass
class AdaptiveK2:
    """2-level specialization of :class:`AdaptivePlan` for the legacy
    (k1, k2) API: K2 ladder from ``k2_max`` down toward ``k2_min``
    (default K1) as the loss falls, always keeping K1 | K2."""

    k1: int
    k2_max: int
    k2_min: Optional[int] = None

    def __post_init__(self):
        # the legacy API tolerated non-divisible bounds (it rounded inside
        # the ladder); keep that by flooring both to multiples of K1 here
        self.k2_max = max(self.k1, (self.k2_max // self.k1) * self.k1)
        k2_min = self.k2_min or self.k1
        self.k2_min = max(self.k1, (k2_min // self.k1) * self.k1)
        self._ctl = AdaptivePlan(
            ReductionPlan.from_k1_k2(self.k1, self.k2_max),
            outer_min=self.k2_min)

    def k2_for(self, loss: float) -> int:
        return self._ctl.outer_for(loss)

    def params_for(self, loss: float,
                   base: Optional[HierAvgParams] = None) -> HierAvgParams:
        """Legacy-trio params for the current loss; ``base`` (if given)
        keeps its other fields via ``dataclasses.replace`` — ``plan`` is
        cleared so the adapted (k1, k2) actually take effect."""
        k2 = self.k2_for(loss)
        if base is None:
            return HierAvgParams(k1=self.k1, k2=k2)
        return dataclasses.replace(base, k1=self.k1, k2=k2, plan=None)

    def reset(self) -> None:
        self._ctl.reset()
