"""Numeric evaluators of the paper's bounds and conditions.

These power the theory-validation tests and benchmarks: we check the paper's
*claims about its own bounds* (monotonicity in K1/S, the K2>1 condition, the
Hier-vs-K-AVG dominance region) exactly as stated, and we expose a
communication-cost model for the "trade local for global" accounting.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple


# --------------------------------------------------------------------- #
# Theorem 3.1 — convergence bound under the w-bar metric
# --------------------------------------------------------------------- #

def thm31_bound(F0_minus_Fstar: float, L: float, M: float, M_G: float,
                gamma: float, K2: int, P: int, B: int, T: int) -> float:
    """(3.2):  2(F0-F*)/(gamma T) + 4 L^2 gamma^2 K2^2 M_G^2 + L gamma M/(PB)."""
    return (2.0 * F0_minus_Fstar / (gamma * T)
            + 4.0 * L ** 2 * gamma ** 2 * K2 ** 2 * M_G ** 2
            + L * gamma * M / (P * B))


def thm31_rate_at_optimum(F0_minus_Fstar: float, L: float, M: float,
                          M_G: float, P: int, B: int, T: int) -> float:
    """(3.4) with gamma=sqrt(PB/T), K2=T^.25/(PB)^.75 — the O(1/sqrt(PBT))
    constant."""
    return (2.0 * F0_minus_Fstar + 4.0 * L ** 2 * M_G ** 2 + L * M) \
        / math.sqrt(P * B * T)


# --------------------------------------------------------------------- #
# Theorem 3.2 — bound under the w-tilde metric (captures K1 and S)
# --------------------------------------------------------------------- #

def third_term_poly(K2: int, K1: int, S: int) -> float:
    """The K1/S-dependent polynomial in (3.6):
    (K2-K1)(4K2+K1-3)/S + (K1-1)(3K2+K1-2)."""
    return ((K2 - K1) * (4 * K2 + K1 - 3) / S
            + (K1 - 1) * (3 * K2 + K1 - 2))


def thm32_bound(F1_minus_Fstar: float, L: float, M: float, gamma: float,
                K1: int, K2: int, S: int, P: int, B: int, N: int,
                delta: float = 0.5) -> float:
    """(3.6) with delta = L^2 gamma^2 (1+delta_{grad,w}) in (0,1)."""
    assert 0.0 < delta < 1.0
    denom = K2 - delta
    return (2.0 * F1_minus_Fstar / (N * denom * gamma)
            + L * gamma * M * K2 ** 2 / (P * B * denom)
            + L ** 2 * gamma ** 2 * M * K2 / (12.0 * B * denom)
            * third_term_poly(K2, K1, S))


def thm32_condition(L: float, gamma: float, K2: int,
                    delta_grad_w: float = 0.0) -> bool:
    """(3.5): 1 - L^2 g^2 (K2(K2-1)/2 - 1 - d) - L g K2 >= 0."""
    return (1.0 - L ** 2 * gamma ** 2
            * (K2 * (K2 - 1) / 2.0 - 1.0 - delta_grad_w)
            - L * gamma * K2) >= 0.0


# --------------------------------------------------------------------- #
# Theorem 3.4 — when is some K2 > 1 faster (fixed data budget T = N*K2)
# --------------------------------------------------------------------- #

def thm34_terms(F1_minus_Fstar: float, L: float, M: float, gamma: float,
                T: int, P: int, B: int) -> Tuple[float, float, float]:
    """alpha, beta, eta of the proof of Thm 3.4."""
    alpha = 2.0 * F1_minus_Fstar / (T * gamma)
    beta = L * gamma * M / (P * B)
    eta = L ** 2 * gamma ** 2 * M / (12.0 * B)
    return alpha, beta, eta


def thm34_condition(F1_minus_Fstar: float, L: float, M: float, gamma: float,
                    T: int, P: int, B: int, S: int,
                    delta: float = 0.5) -> bool:
    """(3.11): delta*alpha/(1-delta) > 2*beta + 12*eta/S  =>  K2*>1."""
    alpha, beta, eta = thm34_terms(F1_minus_Fstar, L, M, gamma, T, P, B)
    return delta * alpha / (1.0 - delta) > 2.0 * beta + 12.0 * eta / S


def thm34_objective(K2: int, K1: int, S: int, alpha: float, beta: float,
                    eta: float, delta: float = 0.5) -> float:
    """B(K2) = f(K2) * g(K2) from the proof (fixed data budget)."""
    K1_eff = min(K1, K2)
    f = alpha + beta * K2 + eta * third_term_poly(K2, K1_eff, S)
    g = K2 / (K2 - delta)
    return f * g


def optimal_k2(K1: int, S: int, alpha: float, beta: float, eta: float,
               delta: float = 0.5, k2_max: int = 512) -> int:
    """Numeric argmin of B(K2) over multiples of K1 (and K2=1)."""
    candidates = [1] + [k for k in range(K1, k2_max + 1, K1)]
    return min(candidates,
               key=lambda k: thm34_objective(k, K1, S, alpha, beta, eta,
                                             delta))


# --------------------------------------------------------------------- #
# Theorem 3.6 — Hier-AVG (K2=(1+a)K, K1=1, S=4) vs K-AVG (K)
# --------------------------------------------------------------------- #

def thm36_hier_bound(K: int, a: float, alpha: float, eta: float,
                     delta: float = 0.5) -> float:
    """H(K) from the proof of Thm 3.6 (second bound term dropped,
    L*gamma*P >> 1 regime).  eta here is L^2 g^2 M / (6B)."""
    Kp = (1.0 + a) * K
    f1 = alpha + eta * ((Kp - 1.0) * (2.0 * Kp - 1.0) / 4.0)
    g1 = Kp / (Kp - delta)
    return f1 * g1


def thm36_kavg_bound(K: int, alpha: float, eta: float,
                     delta: float = 0.5) -> float:
    """chi(K) for K-AVG in the same regime."""
    f2 = alpha + eta * (K - 1.0) * (2.0 * K - 1.0)
    g2 = K / (K - delta)
    return f2 * g2


# --------------------------------------------------------------------- #
# Communication-cost model (the paper's motivation, made quantitative)
# --------------------------------------------------------------------- #

def tier_for(axes, pods: int) -> str:
    """Link tier a reduction scope rides: ``"dci"`` iff it includes the
    pod axis of a multi-pod topology, ``"ici"`` otherwise.  The ONE
    classification rule — ``CommModel.bw_for_level`` bills with it and
    the autotune probe labels its calibration samples with it, so the
    fitted bandwidth columns cannot drift from the billed ones."""
    return "dci" if (0 in tuple(axes) and pods > 1) else "ici"


@dataclass(frozen=True)
class CommModel:
    """Ring all-reduce cost model: reducing V bytes over n participants on a
    fabric of bandwidth bw costs 2V(n-1)/(n*bw) seconds (+ latency per
    step).  Reductions confined to one pod (local / pod plan levels) ride
    the fast fabric (intra-pod ICI); levels whose scope crosses pods
    (global) pay the slow one (inter-pod DCI / the paper's InfiniBand).

    ``compress_bw`` models one learner's compress+reconstruct compute as
    an effective bytes/s over the *uncompressed* bucket (the codec is a
    few HBM-bound VPU passes: delta + select + scatter ≈ 5 passes of the
    819 GB/s v5e HBM, rounded down) — what the pipelined schedule
    overlaps against the wire time (see :func:`plan_comm_per_round`).

    ``codec_bw`` refines that single constant per codec family: a tuple
    of ``(codec_name, bytes/s)`` pairs (tuple-of-pairs so the model stays
    hashable/frozen) keyed by ``Reducer.codec_name`` — top-k's
    select+scatter, qint8's fused quantize+pack and PowerSGD's
    einsum+QR chains run at very different rates, and the calibration
    fit (autotune/calibrate.py) can observe each from codec-labeled
    probe points.  ``compress_bw_for`` falls back to the shared
    ``compress_bw`` for codecs without a fitted entry, so an uncalibrated
    model bills exactly as before."""

    fast_bw: float = 50.0e9          # intra-pod per-link (ICI)
    slow_bw: float = 2.5e9           # cross-pod effective per-chip (DCI)
    latency: float = 5.0e-6
    compress_bw: float = 150.0e9     # codec compute, bytes/s uncompressed
    codec_bw: Optional[Tuple[Tuple[str, float], ...]] = None

    def __post_init__(self):
        if self.codec_bw is not None:
            # normalize JSON-loaded lists-of-lists into the hashable
            # tuple-of-pairs form
            object.__setattr__(self, "codec_bw", tuple(
                (str(k), float(v)) for k, v in self.codec_bw))

    def compress_bw_for(self, codec: Optional[str]) -> float:
        """Codec-compute rate for a ``Reducer.codec_name`` label —
        the per-codec calibrated rate when one was fitted, else the
        shared ``compress_bw`` constant."""
        if codec and self.codec_bw:
            for name, bw in self.codec_bw:
                if name == codec:
                    return bw
        return self.compress_bw

    def allreduce_time(self, bytes_: float, n: float, bw: float) -> float:
        """``n`` may be fractional: expected-cost billing under elastic
        membership passes :func:`effective_participants` — the ring
        formula is smooth in n, and n_eff -> 1 correctly drives the bill
        to zero (a one-survivor group reduces with nobody)."""
        if n <= 1:
            return 0.0
        steps = 2.0 * (n - 1)
        return 2.0 * bytes_ * (n - 1) / (n * bw) + steps * self.latency

    def bw_for_level(self, axes, pods: int) -> float:
        """Link tier a plan level rides (see :func:`tier_for`)."""
        return self.slow_bw if tier_for(axes, pods) == "dci" \
            else self.fast_bw


def effective_participants(n: int, drop_prob: float = 0.0) -> float:
    """Expected ring size of a grouped reduction whose members each miss
    the fire independently with probability ``drop_prob``:
    ``n_eff = 1 + (n - 1)(1 - p)``.

    The masked reduction always runs *as if* from one anchor's
    perspective — a group never shrinks below its own survivor — so the
    expected number of OTHER contributors is ``(n-1)(1-p)``, and the
    ring terms of :meth:`CommModel.allreduce_time` scale with exactly
    that count.  ``p=0`` recovers ``n`` (dense billing, bit-identical
    plan scores); ``p=1`` gives 1 (no wire cost at all).  This is how
    ``plan_comm_per_round(..., drop_prob=)`` prices an unreliable tier
    for ``CostAwarePlan``/``--autotune``.
    """
    p = min(1.0, max(0.0, float(drop_prob)))
    return 1.0 + (n - 1) * (1.0 - p)


def comm_per_k2_steps(model_bytes: float, hier_k1: int, hier_k2: int,
                      P: int, S: int, cm: Optional[CommModel] = None
                      ) -> Tuple[float, float]:
    """(local_seconds, global_seconds) spent on reductions per K2-step cycle
    for Hier-AVG; K-AVG(K) is the special case k1=k2=K, S=1."""
    cm = cm or CommModel()
    n_local = hier_k2 // hier_k1 - 1 if hier_k1 < hier_k2 else 0
    # the local reduction right before the global one is subsumed by it
    local = n_local * cm.allreduce_time(model_bytes, S, cm.fast_bw)
    glob = cm.allreduce_time(model_bytes, P, cm.slow_bw)
    return local, glob


@dataclass(frozen=True)
class LevelCost:
    """One ReductionPlan level's communication bill per round."""

    name: str
    participants: int        # learners averaged together at this level
    period: int              # SGD steps between reductions
    payload_bytes: int       # per-learner wire bytes (compressed)
    count_per_round: int     # reductions per round (outer-subsumed removed)
    bandwidth: float         # link tier this level rides (ICI or DCI)
    seconds_per_round: float
    messages: int = 1        # grouped collectives dispatched per reduction
                             # (per-leaf: n_leaves; bucketed: n_buckets)
    wire_bytes: int = 0      # per-DEVICE wire bytes: == payload_bytes on
                             # the replicated path; fsdp-sharded buckets
                             # are billed at payload/F because the
                             # reduce-scatter/all-gather lowering moves
                             # only each device's shard slice (0 means
                             # "same as payload_bytes")
    compute_s: float = 0.0   # codec compute per round (compress+rebuild)
    codec: str = ""          # Reducer.codec_name — which codec_bw entry
                             # priced compute_s ("" = no codec / shared
                             # compress_bw constant)
    overlap_s: float = 0.0   # wall seconds per round incl compute on the
                             # level's actual schedule: pipelined levels
                             # pay max(compute, comm) per bucket stage plus
                             # the fill/drain ramp; serial levels pay the
                             # sum.  Compare against seconds_per_round +
                             # compute_s (the serial wall) for the win.
    drop_prob: float = 0.0   # per-member miss probability this level was
                             # billed under (elastic expected-cost mode)
    n_eff: float = 0.0       # effective_participants(participants,
                             # drop_prob) the ring terms used (0 means
                             # dense billing: n_eff == participants)

    @property
    def overlap_speedup(self) -> float:
        """Serial wall / scheduled wall — 1.0 when nothing overlaps."""
        serial = self.seconds_per_round + self.compute_s
        return serial / self.overlap_s if self.overlap_s > 0 else 1.0


def scheduled_wall(stage_compute: float, stage_comm: float, messages: int,
                   overlaps: bool) -> float:
    """Wall seconds of one reduction's bucket schedule.

    Serial: every stage pays compute then comm — the sum.  Pipelined
    (``overlaps`` and more than one stage): stage *i*'s collective runs
    concurrently with stage *i+1*'s compute, so the steady state costs
    ``max(compute, comm)`` per stage and the pipeline fill/drain ramp
    adds one stage of each.  The single formula both
    :func:`plan_comm_per_round` and ``launch/analytic.py`` bill from.
    """
    if overlaps and messages > 1:
        return (stage_compute + stage_comm
                + (messages - 1) * max(stage_compute, stage_comm))
    return messages * (stage_compute + stage_comm)


def level_reduction_seconds(lvl, topo, template,
                            cm: Optional[CommModel] = None, *,
                            drop_prob: float = 0.0
                            ) -> Tuple[float, float, float]:
    """The bill of ONE reduction at plan level ``lvl`` on ``topo``:
    ``(comm_s, compute_s, scheduled_wall_s)`` — schedule-count
    independent, so controllers (autotune/controller.py) can compare
    levels without dividing a round bill back by ``counts_per_round``
    (which is zero for a level subsumed by its outer neighbour).

    ``comm_s`` is the wire time (fused-message ring + per-message ring
    startups), ``compute_s`` the codec compute over the dense bytes, and
    ``scheduled_wall_s`` what the level's actual schedule pays
    (:func:`scheduled_wall`: pipelined levels overlap compute against
    comm per bucket stage).  :func:`plan_comm_per_round` multiplies
    these by the billable count per round.

    ``drop_prob`` — expected-cost billing under elastic membership: the
    ring terms run at ``effective_participants(n, drop_prob)`` instead of
    the dense ``n`` (codec compute is unchanged — survivors still
    compress their full bucket).  ``drop_prob=0`` bills identically to
    before."""
    import jax
    import jax.numpy as jnp
    cm = cm or CommModel()
    n = 1
    for a in lvl.axes:
        n *= topo.shape[a]
    wire = lvl.reducer.wire_payload_bytes(template)
    messages = lvl.reducer.n_messages(template)
    bw = cm.bw_for_level(lvl.axes, topo.pods)
    dense_bytes = int(sum(
        leaf.size * jnp.dtype(leaf.dtype).itemsize
        for leaf in jax.tree.leaves(template)))
    n_eff = effective_participants(n, drop_prob)
    # the RS+AG decomposition of a sharded bucket walks the same
    # 2(n-1)-step ring as the fused all-reduce, so the ring formula
    # applies verbatim with the per-device wire bytes
    comm_s = cm.allreduce_time(wire, n_eff, bw) \
        + (messages - 1) * 2.0 * (n_eff - 1) * cm.latency
    stage_compute = (dense_bytes / messages
                     / cm.compress_bw_for(getattr(lvl.reducer,
                                                  "codec_name", None))
                     if getattr(lvl.reducer, "has_codec", True) else 0.0)
    compute_s = messages * stage_compute
    wall_s = scheduled_wall(stage_compute, comm_s / messages, messages,
                            getattr(lvl.reducer, "overlaps", False))
    return comm_s, compute_s, wall_s


def param_template(n_params: int, dtype="bfloat16", n_leaves: int = 1):
    """A square-ish single-learner matrix standing in for the model's
    parameters — what ``Reducer.payload_bytes`` needs to size a level's
    compressed wire cost analytically (2-D so low-rank reducers apply).

    ``n_leaves > 1`` splits the budget into that many equal matrices —
    use it when the per-message latency term matters (the single-leaf
    default dispatches one collective on the per-leaf path too, so it
    cannot show bucketing's message-count advantage)."""
    import jax
    import jax.numpy as jnp
    per = max(1, n_params // n_leaves)
    side = max(1, int(round(per ** 0.5)))
    struct = jax.ShapeDtypeStruct((side, -(-per // side)), jnp.dtype(dtype))
    if n_leaves == 1:
        return {"params": struct}
    return {f"params{i}": struct for i in range(n_leaves)}


def plan_comm_per_round(plan, topo, template,
                        cm: Optional[CommModel] = None, *,
                        drop_prob=0.0) -> Tuple[LevelCost, ...]:
    """Cost every level of a ReductionPlan over its own link tier and its
    own *compressed* payload.

    ``template`` is a single-learner parameter tree (ShapeDtypeStructs
    suffice — see :func:`param_template`); ``topo`` a
    core.topology.HierTopology.  A level reduction coinciding with an
    outer level's is not billed (``plan.counts_per_round`` — the payload-
    aware-schedule convention, matching ``comm_per_k2_steps``'s
    "subsumed" accounting; see its docstring for the caveat that the
    scan-nest program still executes those inner reductions).

    Latency is billed per dispatched collective (``Reducer.n_messages``):
    the per-leaf path pays the ring's startup cost once per leaf, the
    bucketed path (comm/bucket.py) once per bucket — the wire-bytes term
    is message-count independent.  The term only differentiates the two
    paths when ``template`` has a realistic leaf structure (real param
    trees, or ``param_template(..., n_leaves=...)``); the default
    single-leaf template dispatches one message either way, since buckets
    never split a leaf.

    Each level also carries its codec compute (``compute_s``, the
    uncompressed bytes through ``cm.compress_bw``) and its *scheduled*
    wall time ``overlap_s``: pipelined levels (comm/bucket.py Pipelined,
    detected via ``reducer.overlaps``) run bucket stages double-buffered,
    so per reduction they pay one stage of compute (fill), one stage of
    comm (drain), and ``max(compute, comm)`` for every stage in between —
    instead of the serial ``sum`` for every stage.  With one message
    there is nothing to overlap and both forms coincide.

    ``drop_prob`` — expected-cost billing for unreliable fleets: a scalar
    per-member miss probability applied to every level, or a mapping
    ``{level_name: p}`` (levels not named bill dense).  Each level's ring
    terms then run at ``effective_participants(n, p)``; the resulting
    ``LevelCost`` records both ``drop_prob`` and ``n_eff`` so autotune
    reports can show what the score assumed.
    """
    cm = cm or CommModel()
    counts = dict(plan.counts_per_round())
    out = []
    for lvl in plan.levels:
        n = 1
        for a in lvl.axes:
            n *= topo.shape[a]
        p = (drop_prob.get(lvl.name, 0.0) if hasattr(drop_prob, "get")
             else float(drop_prob))
        payload = lvl.reducer.payload_bytes(template)
        wire = lvl.reducer.wire_payload_bytes(template)
        messages = lvl.reducer.n_messages(template)
        bw = cm.bw_for_level(lvl.axes, topo.pods)
        count = counts[lvl.name]
        comm_s, compute_s, wall_s = level_reduction_seconds(
            lvl, topo, template, cm, drop_prob=p)
        out.append(LevelCost(lvl.name, n, lvl.period, payload, count, bw,
                             count * comm_s, messages, wire_bytes=wire,
                             compute_s=count * compute_s,
                             codec=getattr(lvl.reducer, "codec_name", ""),
                             overlap_s=count * wall_s, drop_prob=p,
                             n_eff=effective_participants(n, p)))
    return tuple(out)


def comm_advantage(model_bytes: float, K: int, a: float, P: int, S: int = 4,
                   cm: Optional[CommModel] = None) -> float:
    """Seconds saved per *data-equivalent* K2 window by Hier-AVG with
    K2=(1+a)K, K1=1, S=4 versus K-AVG(K) (Thm 3.6 setup)."""
    cm = cm or CommModel()
    k2 = int(round((1 + a) * K))
    loc, glo = comm_per_k2_steps(model_bytes, 1, k2, P, S, cm)
    hier_per_step = (loc + glo) / k2
    _, glo_k = comm_per_k2_steps(model_bytes, K, K, P, 1, cm)
    kavg_per_step = glo_k / K
    return kavg_per_step - hier_per_step
