"""Hier-AVG core: the paper's contribution as composable JAX modules."""
from repro.core.topology import (HierTopology, global_average,  # noqa: F401
                                 local_average, pod_average, stack_like,
                                 unstack_first, where_active)
from repro.core.plan import (ReductionLevel, ReductionPlan,  # noqa: F401
                             resolve_plan)
from repro.core.hier_avg import (TrainState, init_state,  # noqa: F401
                                 make_hier_round, make_hier_step,
                                 make_sgd_step, stacked_grad_fn)
from repro.core.baselines import (make_kavg_round,  # noqa: F401
                                  make_sync_sgd_round)
from repro.core.schedules import (AdaptiveK2, AdaptivePlan,  # noqa: F401
                                  thm31_gamma, thm31_k2)
from repro.core.simulator import SimResult, Simulator  # noqa: F401
