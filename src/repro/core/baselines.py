"""Baselines the paper compares against, as Hier-AVG special cases.

  * K-AVG (Zhou & Cong 2018):   K1 == K2 (equivalently S == 1) — no local
    reductions, one global reduction every K steps.
  * Synchronous parallel SGD (Zinkevich et al. 2010): K1 == K2 == 1 — a
    global reduction after every step (== large-batch sequential SGD).

Both reuse the exact Hier-AVG round machinery so every comparison in
benchmarks/ is apples-to-apples (same data order, same optimizer, same
numerics).
"""
from __future__ import annotations

from typing import Callable, Optional

from repro.configs.base import HierAvgParams
from repro.core.hier_avg import make_hier_round
from repro.optim import Optimizer


def make_kavg_round(loss_fn: Callable, optimizer: Optimizer, k: int, *,
                    constraint_fn: Optional[Callable] = None,
                    grad_postprocess: Optional[Callable] = None,
                    reducer=None):
    """K-AVG with averaging interval K: local reductions disabled."""
    hier = HierAvgParams(k1=k, k2=k)
    return make_hier_round(loss_fn, optimizer, hier, skip_local=True,
                           constraint_fn=constraint_fn,
                           grad_postprocess=grad_postprocess,
                           reducer=reducer)


def make_sync_sgd_round(loss_fn: Callable, optimizer: Optimizer, *,
                        constraint_fn: Optional[Callable] = None,
                        grad_postprocess: Optional[Callable] = None,
                        reducer=None):
    """Fully synchronous parallel SGD: one round == one step == one
    global reduction."""
    hier = HierAvgParams(k1=1, k2=1)
    return make_hier_round(loss_fn, optimizer, hier, skip_local=True,
                           constraint_fn=constraint_fn,
                           grad_postprocess=grad_postprocess,
                           reducer=reducer)
